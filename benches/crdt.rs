//! CRDT datatype bench: what an ORSWOT costs at size. One set key
//! holding thousands of elements — add/remove churn at that size
//! (kernel and full cluster RMW), membership-read latency, full-state
//! merge vs single-op delta apply, and the replication-bytes evidence
//! for the delta-shaped fan-out: the encoded size of one add's delta
//! vs the full state, plus the cluster's live `(delta, full_fallback,
//! always_full)` ledger from the churn it just ran.
//!
//! Results land in `BENCH_crdt.json` (path override: `BENCH_CRDT_JSON`)
//! so the typed path has a machine-readable baseline; `rust/ci.sh`
//! runs this bench in quick mode to keep the file fresh.
//!
//! Regenerate with `cargo bench --bench crdt`.

use std::hint::black_box;

use dvvstore::bench_support::{Options, Stats, Suite};
use dvvstore::clocks::Actor;
use dvvstore::kernel::crdt::Orswot;
use dvvstore::server::LocalCluster;

fn elem(i: u64) -> Vec<u8> {
    format!("member-{i:06}").into_bytes()
}

/// An ORSWOT preloaded with `n` elements under one actor.
fn loaded_set(n: u64) -> Orswot {
    let mut s = Orswot::new();
    let actor = Actor::server(0);
    for i in 0..n {
        let dot = s.mint(actor);
        s.add(elem(i), dot);
    }
    s
}

fn bench_kernel(suite: &mut Suite, n: u64) {
    let param = format!("elems={n}");

    // steady-state churn: add a fresh element, remove it again — the
    // set stays at size n, the op pays the at-size insert/lookup cost
    suite.bench("set/add_remove_churn", &param, {
        let mut s = loaded_set(n);
        let mut i = n;
        move || {
            i += 1;
            let dot = s.mint(Actor::server(0));
            s.add(elem(i), dot);
            black_box(s.remove(&elem(i)).0.len());
        }
    });

    // membership read at size: the SMEMBERS hot loop
    suite.bench("set/members_read", &param, {
        let s = loaded_set(n);
        move || {
            black_box(s.members().count());
        }
    });

    // full-state replication: merge an identical n-element state (the
    // idempotent re-merge every anti-entropy exchange pays)
    suite.bench("set/merge_full_state", &param, {
        let src = loaded_set(n);
        let mut dst = src.clone();
        move || {
            dst.merge(black_box(&src));
        }
    });

    // delta replication: apply one add's delta to an up-to-date replica
    suite.bench("set/apply_delta", &param, {
        let mut src = loaded_set(n);
        let dot = src.mint(Actor::server(0));
        let delta = src.add(elem(n + 1), dot);
        let mut dst = src.clone();
        move || {
            black_box(dst.apply_delta(black_box(&delta)));
        }
    });
}

/// Cluster-level ops against one set key already holding `n` elements:
/// every op is a full quorum RMW (read, join, mutate, re-encode, write).
fn bench_cluster(suite: &mut Suite, n: u64) -> (u64, u64, u64) {
    let param = format!("elems={n}");
    let cluster = LocalCluster::new(3, 3, 2, 2).unwrap();
    for i in 0..n {
        cluster.set_add("big", &elem(i)).unwrap();
    }

    suite.bench("cluster/smembers", &param, {
        let cluster = &cluster;
        move || {
            black_box(cluster.set_members("big").unwrap().len());
        }
    });

    suite.bench("cluster/add_remove_churn", &param, {
        let cluster = &cluster;
        let mut i = n;
        move || {
            i += 1;
            cluster.set_add("big", &elem(i)).unwrap();
            black_box(cluster.set_remove("big", &elem(i)).unwrap().len());
        }
    });

    cluster.crdt_repl_bytes()
}

fn json_escape_free(s: &str) -> bool {
    s.chars().all(|c| c.is_ascii_alphanumeric() || "/_=.-".contains(c))
}

/// Hand-rolled JSON (no serde in the offline build): flat result rows
/// plus the delta-vs-full replication byte evidence.
#[allow(clippy::too_many_arguments)]
fn write_json(
    path: &str,
    quick: bool,
    n: u64,
    delta_bytes: usize,
    full_bytes: usize,
    repl: (u64, u64, u64),
    results: &[Stats],
) -> std::io::Result<()> {
    let mut rows = String::new();
    for (i, s) in results.iter().enumerate() {
        assert!(
            json_escape_free(&s.name) && json_escape_free(&s.param),
            "bench names are JSON-safe"
        );
        if i > 0 {
            rows.push_str(",\n");
        }
        rows.push_str(&format!(
            "    {{\"name\": \"{}\", \"param\": \"{}\", \"mean_ns\": {:.1}, \
             \"p50_ns\": {:.1}, \"p95_ns\": {:.1}, \"min_ns\": {:.1}}}",
            s.name, s.param, s.mean_ns, s.p50_ns, s.p95_ns, s.min_ns
        ));
    }
    let (repl_delta, repl_full, repl_allfull) = repl;
    let shipped = repl_delta + repl_full;
    let savings = if shipped > 0 {
        format!("{:.2}", repl_allfull as f64 / shipped as f64)
    } else {
        "null".to_string()
    };
    let json = format!(
        "{{\n  \"suite\": \"crdt\",\n  \"quick\": {quick},\n  \"elems\": {n},\n  \
         \"delta_bytes_one_add\": {delta_bytes},\n  \
         \"full_state_bytes\": {full_bytes},\n  \
         \"repl_delta_bytes\": {repl_delta},\n  \
         \"repl_full_fallback_bytes\": {repl_full},\n  \
         \"repl_always_full_bytes\": {repl_allfull},\n  \
         \"always_full_over_shipped\": {savings},\n  \
         \"results\": [\n{rows}\n  ]\n}}\n"
    );
    std::fs::write(path, json)
}

fn main() {
    let opts = Options::from_args();
    let quick = opts.quick;
    // "one key, thousands of elements" — trimmed in quick mode so the
    // ci smoke run stays fast
    let n: u64 = if quick { 512 } else { 4096 };
    let mut suite = Suite::new("crdt", opts);

    bench_kernel(&mut suite, n);
    let repl = bench_cluster(&mut suite, n);

    // byte evidence at size n: one add's delta vs the whole state
    let (delta_bytes, full_bytes) = {
        let mut s = loaded_set(n);
        let dot = s.mint(Actor::server(0));
        let delta = s.add(elem(n + 1), dot);
        let mut dbuf = Vec::new();
        delta.encode(&mut dbuf);
        let mut fbuf = Vec::new();
        s.encode(&mut fbuf);
        (dbuf.len(), fbuf.len())
    };

    let results: Vec<Stats> = suite.results().to_vec();
    let path =
        std::env::var("BENCH_CRDT_JSON").unwrap_or_else(|_| "BENCH_crdt.json".to_string());
    match write_json(&path, quick, n, delta_bytes, full_bytes, repl, &results) {
        Ok(()) => eprintln!("  wrote {path}"),
        Err(e) => eprintln!("  could not write {path}: {e}"),
    }
    suite.finish();
}
