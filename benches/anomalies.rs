//! E6: anomaly rates per mechanism as workload concurrency varies —
//! the quantified version of the paper's Figures 2–4 narratives.
//!
//! Sweeps the informed-write probability (blind writes are what
//! concurrency anomalies feed on) and reports permanently-lost updates
//! and false/true concurrency per mechanism, all on identical
//! deterministic interleavings. Regenerate with
//! `cargo bench --bench anomalies`.

use dvvstore::config::StoreConfig;
use dvvstore::kernel::mechs::{dispatch, MechVisitor};
use dvvstore::kernel::{MechKind, Mechanism};
use dvvstore::sim::Sim;
use dvvstore::workload::{RandomWorkload, WorkloadSpec};

struct Run {
    read_before_write: f64,
    clients: usize,
    seed: u64,
}

impl MechVisitor for Run {
    type Out = (u64, u64, u64, u64); // (writes, lost, false_conc, true_conc)

    fn visit<M: Mechanism>(self, mech: M) -> Self::Out {
        let mut cfg = StoreConfig::default();
        cfg.cluster.nodes = 6;
        cfg.cluster.replication = 3;
        cfg.cluster.read_quorum = 2;
        cfg.cluster.write_quorum = 2;
        cfg.antientropy.period_us = 100_000;
        let spec = WorkloadSpec {
            keys: 64,
            zipf_theta: 0.9,
            put_fraction: 0.6,
            read_before_write: self.read_before_write,
            mean_think_us: 500.0,
            ops_per_client: 150,
            value_len: 32,
        };
        let driver = Box::new(RandomWorkload::new(spec, self.clients));
        let mut sim =
            Sim::new(mech, cfg, self.clients, true, driver, self.seed).expect("sim");
        sim.start();
        sim.run(u64::MAX);
        sim.settle();
        (
            sim.writes_issued(),
            sim.audit_permanently_lost(),
            sim.metrics.false_concurrent_pairs,
            sim.metrics.true_concurrent_pairs,
        )
    }
}

fn main() {
    println!("## anomalies (E6: lost updates / concurrency classification)\n");
    println!("6 nodes, N=3 R=2 W=2, 24 clients × 150 ops, zipf(0.9)/64 keys, AE 100ms\n");
    for &informed in &[0.0f64, 0.25, 0.5, 0.75, 1.0] {
        println!("### informed-write probability {informed}\n");
        println!("| mechanism | writes | lost | lost% | false_conc | true_conc |");
        println!("|---|---|---|---|---|---|");
        for kind in MechKind::ALL {
            let (writes, lost, fc, tc) = dispatch(
                kind,
                Run { read_before_write: informed, clients: 24, seed: 1234 },
            );
            println!(
                "| {:<9} | {writes} | {lost} | {:.1}% | {fc} | {tc} |",
                kind.name(),
                100.0 * lost as f64 / writes.max(1) as f64
            );
            // shape assertions: the paper's qualitative table
            if kind.is_lossless() {
                assert_eq!(lost, 0, "{kind} must be lossless at informed={informed}");
            }
        }
        println!();
    }
    println!("E6 claims hold: lossless mechanisms lost 0 updates at every concurrency level");
}
