//! Wire-format bench: hex-text framing (protocol v1) vs length-prefixed
//! binary framing (protocol v2) on the PUT/GET hot path, plus the
//! lookup-table hex encoder on its own.
//!
//! Each `*_roundtrip` case measures one full encode→decode of the
//! message a client and server exchange per operation — the per-request
//! CPU cost the framing contributes. Results also land in
//! `BENCH_wire.json` (path override: `BENCH_WIRE_JSON`) so subsequent
//! changes have a machine-readable perf baseline; `rust/ci.sh` runs
//! this bench in quick mode to keep the file fresh.
//!
//! Regenerate with `cargo bench --bench wire`.

use std::hint::black_box;

use dvvstore::api::CausalCtx;
use dvvstore::bench_support::{Options, Stats, Suite};
use dvvstore::clocks::encoding::encode_vv;
use dvvstore::clocks::vv::vv;
use dvvstore::clocks::Actor;
use dvvstore::server::protocol::{
    self, decode_bin_request, encode_bin_request, format_values, hex_decode, hex_encode,
    parse_request, BinRequest, Request,
};

/// A realistic DVV context token: 3 replica entries + 2 observed ids.
fn token() -> Vec<u8> {
    let mut vv_bytes = Vec::new();
    encode_vv(
        &vv(&[(Actor::server(0), 12), (Actor::server(1), 7), (Actor::server(2), 40)]),
        &mut vv_bytes,
    );
    CausalCtx::new(vv_bytes, vec![101, 102]).encode()
}

fn value_of(len: usize) -> Vec<u8> {
    (0..len).map(|i| (i * 131 % 251) as u8).collect()
}

fn bench_put(suite: &mut Suite, len: usize) {
    let value = value_of(len);
    let tok = token();
    let param = format!("len={len}");

    // v1: PUT line with hex value + hex ctx, parsed back
    suite.bench("text/put_roundtrip", &param, {
        let value = value.clone();
        let tok = tok.clone();
        move || {
            let line = format!("PUT key:1 {} {}", hex_encode(&value), hex_encode(&tok));
            match parse_request(black_box(&line)).unwrap() {
                Request::Put { key, value, context } => {
                    black_box((key, value, context));
                }
                _ => unreachable!(),
            }
        }
    });

    // v2: PUT frame encoded + decoded
    suite.bench("binary/put_roundtrip", &param, {
        let value = value.clone();
        let tok = tok.clone();
        move || {
            let req = BinRequest::Put {
                key: "key:1".to_string(),
                value: value.clone(),
                actor: 1 << 20,
                ctx_token: tok.clone(),
            };
            let (opcode, payload) = encode_bin_request(black_box(&req));
            black_box(decode_bin_request(opcode, &payload).unwrap());
        }
    });
}

fn bench_get_reply(suite: &mut Suite, len: usize) {
    let values = vec![value_of(len), value_of(len / 2 + 1)];
    let tok = token();
    let param = format!("len={len}");

    // v1: VALUES header + per-sibling hex lines, values decoded back
    suite.bench("text/get_reply_roundtrip", &param, {
        let values = values.clone();
        let tok = tok.clone();
        move || {
            let text = format_values(black_box(&values), &tok);
            for line in text.lines().skip(1) {
                let hex = line.strip_prefix("VALUE ").unwrap();
                black_box(hex_decode(hex).unwrap());
            }
        }
    });

    // v2: VALUES frame payload encoded + decoded
    suite.bench("binary/get_reply_roundtrip", &param, {
        let values = values.clone();
        let tok = tok.clone();
        move || {
            let payload = protocol::encode_values(black_box(&values), &tok);
            black_box(protocol::decode_values(&payload).unwrap());
        }
    });
}

fn json_escape_free(s: &str) -> bool {
    s.chars().all(|c| c.is_ascii_alphanumeric() || "/_=.-".contains(c))
}

/// Hand-rolled JSON (no serde in the offline build): flat result rows
/// plus a text-vs-binary speedup summary per payload size.
fn write_json(path: &str, quick: bool, results: &[Stats]) -> std::io::Result<()> {
    let mut rows = String::new();
    for (i, s) in results.iter().enumerate() {
        assert!(json_escape_free(&s.name) && json_escape_free(&s.param), "bench names are JSON-safe");
        if i > 0 {
            rows.push_str(",\n");
        }
        rows.push_str(&format!(
            "    {{\"name\": \"{}\", \"param\": \"{}\", \"mean_ns\": {:.1}, \
             \"p50_ns\": {:.1}, \"p95_ns\": {:.1}, \"min_ns\": {:.1}}}",
            s.name, s.param, s.mean_ns, s.p50_ns, s.p95_ns, s.min_ns
        ));
    }
    let mean_of = |name: &str, param: &str| {
        results
            .iter()
            .find(|s| s.name == name && s.param == param)
            .map(|s| s.mean_ns)
    };
    let mut speedups = String::new();
    let mut first = true;
    for s in results.iter().filter(|s| s.name == "binary/put_roundtrip") {
        if let Some(text) = mean_of("text/put_roundtrip", &s.param) {
            if s.mean_ns > 0.0 {
                if !first {
                    speedups.push_str(", ");
                }
                first = false;
                speedups.push_str(&format!("\"{}\": {:.2}", s.param, text / s.mean_ns));
            }
        }
    }
    let json = format!(
        "{{\n  \"suite\": \"wire\",\n  \"quick\": {quick},\n  \
         \"put_roundtrip_speedup_text_over_binary\": {{{speedups}}},\n  \
         \"results\": [\n{rows}\n  ]\n}}\n"
    );
    std::fs::write(path, json)
}

fn main() {
    let opts = Options::from_args();
    let quick = opts.quick;
    let mut suite = Suite::new("wire", opts);

    suite.bench("text/hex_encode", "len=256", {
        let value = value_of(256);
        move || {
            black_box(hex_encode(black_box(&value)));
        }
    });

    for len in [16, 256, 4096] {
        bench_put(&mut suite, len);
        bench_get_reply(&mut suite, len);
    }

    let results: Vec<Stats> = suite.results().to_vec();
    let path =
        std::env::var("BENCH_WIRE_JSON").unwrap_or_else(|_| "BENCH_wire.json".to_string());
    match write_json(&path, quick, &results) {
        Ok(()) => eprintln!("  wrote {path}"),
        Err(e) => eprintln!("  could not write {path}: {e}"),
    }
    suite.finish();
}
