//! Topology/ring bench: preference-list lookup on the routing hot path
//! — the allocating `replicas_for` vs the buffer-reusing
//! `replicas_into` (per-op `Vec<NodeId>` allocation is exactly what the
//! cluster's GET/PUT paths shed), the lock-wrapped `Topology` read
//! path, and churn rebalance throughput (join + decommission cycles,
//! epoch bumps included).
//!
//! Results land in `BENCH_ring.json` (path override: `BENCH_RING_JSON`)
//! so subsequent routing changes have a machine-readable baseline;
//! `rust/ci.sh` runs this bench in quick mode to keep the file fresh.
//!
//! Regenerate with `cargo bench --bench ring`.

use std::hint::black_box;

use dvvstore::bench_support::{Options, Stats, Suite};
use dvvstore::cluster::{NodeId, Ring, Topology};

const NODES: usize = 5;
const VNODES: usize = 64;
const N: usize = 3;

fn bench_lookup(suite: &mut Suite, nodes: usize) {
    let param = format!("nodes={nodes}");
    let ring = Ring::new(nodes, VNODES).unwrap();
    let topo = Topology::new(nodes, VNODES).unwrap();

    suite.bench("ring/replicas_for_alloc", &param, {
        let ring = ring.clone();
        let mut key = 0u64;
        move || {
            key = key.wrapping_add(0x9E37_79B9);
            black_box(ring.replicas_for(black_box(key), N));
        }
    });

    suite.bench("ring/replicas_into_buffered", &param, {
        let ring = ring.clone();
        let mut buf: Vec<NodeId> = Vec::new();
        let mut key = 0u64;
        move || {
            key = key.wrapping_add(0x9E37_79B9);
            ring.replicas_into(black_box(key), N, &mut buf);
            black_box(buf.len());
        }
    });

    // the read-lock wrapper the cluster actually routes through
    suite.bench("topology/replicas_into", &param, {
        let mut buf: Vec<NodeId> = Vec::new();
        let mut key = 0u64;
        move || {
            key = key.wrapping_add(0x9E37_79B9);
            topo.replicas_into(black_box(key), N, &mut buf);
            black_box(buf.len());
        }
    });
}

fn bench_churn(suite: &mut Suite) {
    // one full elastic cycle: admit a node (vnode placement + sort),
    // then retire it (point removal), epoch bumps included. Slots grow
    // monotonically across iterations — ids are never reused — but the
    // live point count stays ~NODES * VNODES, so the cost measured is
    // the steady-state rebalance cost.
    let topo = Topology::new(NODES, VNODES).unwrap();
    suite.bench("topology/join_decommission_cycle", &format!("vnodes={VNODES}"), {
        move || {
            let (id, _) = topo.join();
            topo.decommission(black_box(id)).unwrap();
        }
    });

    let mut ring = Ring::new(NODES, VNODES).unwrap();
    suite.bench("ring/add_remove_cycle", &format!("vnodes={VNODES}"), {
        move || {
            let id = ring.add_node();
            ring.remove_node(black_box(id));
        }
    });
}

fn json_escape_free(s: &str) -> bool {
    s.chars().all(|c| c.is_ascii_alphanumeric() || "/_=.-".contains(c))
}

/// Hand-rolled JSON (no serde in the offline build): flat result rows
/// plus an alloc-vs-buffered speedup summary per cluster size.
fn write_json(path: &str, quick: bool, results: &[Stats]) -> std::io::Result<()> {
    let mut rows = String::new();
    for (i, s) in results.iter().enumerate() {
        assert!(
            json_escape_free(&s.name) && json_escape_free(&s.param),
            "bench names are JSON-safe"
        );
        if i > 0 {
            rows.push_str(",\n");
        }
        rows.push_str(&format!(
            "    {{\"name\": \"{}\", \"param\": \"{}\", \"mean_ns\": {:.1}, \
             \"p50_ns\": {:.1}, \"p95_ns\": {:.1}, \"min_ns\": {:.1}}}",
            s.name, s.param, s.mean_ns, s.p50_ns, s.p95_ns, s.min_ns
        ));
    }
    let mean_of = |name: &str, param: &str| {
        results
            .iter()
            .find(|s| s.name == name && s.param == param)
            .map(|s| s.mean_ns)
    };
    let mut speedups = String::new();
    let mut first = true;
    for s in results.iter().filter(|s| s.name == "ring/replicas_into_buffered") {
        if let Some(alloc) = mean_of("ring/replicas_for_alloc", &s.param) {
            if s.mean_ns > 0.0 {
                if !first {
                    speedups.push_str(", ");
                }
                first = false;
                speedups.push_str(&format!("\"{}\": {:.2}", s.param, alloc / s.mean_ns));
            }
        }
    }
    let json = format!(
        "{{\n  \"suite\": \"ring\",\n  \"quick\": {quick},\n  \
         \"lookup_speedup_alloc_over_buffered\": {{{speedups}}},\n  \
         \"results\": [\n{rows}\n  ]\n}}\n"
    );
    std::fs::write(path, json)
}

fn main() {
    let opts = Options::from_args();
    let quick = opts.quick;
    let mut suite = Suite::new("ring", opts);

    for nodes in [5usize, 16, 64] {
        bench_lookup(&mut suite, nodes);
    }
    bench_churn(&mut suite);

    let results: Vec<Stats> = suite.results().to_vec();
    let path =
        std::env::var("BENCH_RING_JSON").unwrap_or_else(|_| "BENCH_ring.json".to_string());
    match write_json(&path, quick, &results) {
        Ok(()) => eprintln!("  wrote {path}"),
        Err(e) => eprintln!("  could not write {path}: {e}"),
    }
    suite.finish();
}
