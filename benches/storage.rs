//! Storage-engine bench: [`DurableBackend`] (full map in memory, log
//! replays everything) vs [`LsmBackend`] (bounded memtable, sorted
//! runs on disk) across a dataset-size sweep.
//!
//! Three axes, each as a `backend=durable` / `backend=lsm` pair so the
//! numbers read as a direct trade-off:
//!
//! * **write** — one informed PUT through the `KeyStore` hot path
//!   (kernel write + encode + WAL append; the LSM side also absorbs
//!   its amortised flush/compaction work);
//! * **read** — one point lookup after the LSM store has flushed and
//!   compacted, so reads actually walk fence → bloom → block cache →
//!   block, not just the memtable;
//! * **reopen** — full backend open over the on-disk state: the
//!   durable log replays every surviving record, the LSM open reads
//!   run footers plus a WAL bounded by the memtable. This is the
//!   restart-latency claim of the LSM engine.
//!
//! Alongside the timings, the JSON artifact records a **residency
//! sweep**: `resident_bytes()` vs `durable_bytes()` for both backends
//! at each dataset size. Durable residency is linear in the dataset by
//! construction; LSM residency is bounded by memtable + block cache
//! and must grow sublinearly.
//!
//! Results land in `BENCH_storage.json` (path override:
//! `BENCH_STORAGE_JSON`); `rust/ci.sh` runs this bench in quick mode
//! and fails the gate when the artifact is missing.
//!
//! Regenerate with `cargo bench --bench storage`.

use std::hint::black_box;
use std::path::Path;

use dvvstore::bench_support::{Options, Stats, Suite};
use dvvstore::clocks::Actor;
use dvvstore::kernel::mechs::DvvMech;
use dvvstore::kernel::{Val, WriteMeta};
use dvvstore::store::wal::FsyncPolicy;
use dvvstore::store::{
    DurableBackend, KeyStore, LsmBackend, LsmOptions, StorageBackend, WalOptions,
};
use dvvstore::testkit::temp_dir;

const SHARDS: usize = 8;

fn wal_opts() -> WalOptions {
    WalOptions { segment_bytes: 1 << 20, fsync: FsyncPolicy::Never }
}

/// Memtable small enough that every sweep size spills to runs, cache
/// big enough to be useful but bounded (residency must not track the
/// dataset).
fn lsm_opts() -> LsmOptions {
    LsmOptions {
        wal: wal_opts(),
        memtable_bytes: 64 << 10,
        block_bytes: 4096,
        cache_blocks: 64,
        tier_runs: 4,
    }
}

fn open_durable(dir: &Path) -> KeyStore<DvvMech, DurableBackend<DvvMech>> {
    KeyStore::with_backend(DvvMech, DurableBackend::open(dir, SHARDS, wal_opts()).unwrap())
}

fn open_lsm(dir: &Path) -> KeyStore<DvvMech, LsmBackend<DvvMech>> {
    KeyStore::with_backend(DvvMech, LsmBackend::open(dir, SHARDS, lsm_opts()).unwrap())
}

/// One informed PUT per key — each key ends with a single sibling, so
/// state size is uniform and the sweep measures the engine, not
/// sibling growth.
fn fill<B: StorageBackend<DvvMech>>(store: &KeyStore<DvvMech, B>, keys: u64) {
    let meta = WriteMeta::basic(Actor::client(0));
    for i in 0..keys {
        let (_, ctx) = store.read(i);
        store.write(i, &ctx, Val::new(i + 1, 64), Actor::server(0), &meta);
    }
}

/// Multiplicative-hash probe order so point reads jump across blocks
/// instead of scanning one block linearly.
fn probe(i: u64, keys: u64) -> u64 {
    i.wrapping_mul(0x9E37_79B9_7F4A_7C15) % keys
}

fn bench_write<B, F>(suite: &mut Suite, backend: &str, keys: u64, open: F)
where
    B: StorageBackend<DvvMech>,
    F: Fn(&Path) -> KeyStore<DvvMech, B>,
{
    let dir = temp_dir("bench-storage-write");
    let store = open(&dir);
    let meta = WriteMeta::basic(Actor::client(0));
    let mut i = 0u64;
    suite.bench(&format!("write/backend={backend}"), &format!("keys={keys}"), move || {
        let key = i % keys;
        let (_, ctx) = store.read(key);
        store.write(key, &ctx, Val::new(i + 1, 64), Actor::server(0), &meta);
        black_box(&store);
        i += 1;
    });
    std::fs::remove_dir_all(&dir).ok();
}

fn bench_read_durable(suite: &mut Suite, keys: u64) {
    let dir = temp_dir("bench-storage-read-durable");
    let store = open_durable(&dir);
    fill(&store, keys);
    let mut i = 0u64;
    suite.bench("read/backend=durable", &format!("keys={keys}"), move || {
        black_box(store.read(probe(i, keys)).0.len());
        i += 1;
    });
    std::fs::remove_dir_all(&dir).ok();
}

fn bench_read_lsm(suite: &mut Suite, keys: u64) {
    let dir = temp_dir("bench-storage-read-lsm");
    let store = open_lsm(&dir);
    fill(&store, keys);
    // push everything through the full lifecycle so reads hit runs
    store.backend().flush_memtables();
    store.backend().compact_now();
    let mut i = 0u64;
    suite.bench("read/backend=lsm", &format!("keys={keys}"), move || {
        black_box(store.read(probe(i, keys)).0.len());
        i += 1;
    });
    std::fs::remove_dir_all(&dir).ok();
}

fn bench_reopen(suite: &mut Suite, keys: u64) {
    // durable: the log holds one surviving record per key and replay
    // decodes all of them
    let dir = temp_dir("bench-storage-reopen-durable");
    {
        let store = open_durable(&dir);
        fill(&store, keys);
        store.backend().flush().unwrap();
    }
    let log_dir = dir.clone();
    suite.bench("reopen/backend=durable", &format!("keys={keys}"), move || {
        let backend: DurableBackend<DvvMech> =
            DurableBackend::open(&log_dir, SHARDS, wal_opts()).unwrap();
        black_box(backend.key_count());
    });
    std::fs::remove_dir_all(&dir).ok();

    // lsm: runs are opened by footer, only the memtable's WAL replays
    let dir = temp_dir("bench-storage-reopen-lsm");
    {
        let store = open_lsm(&dir);
        fill(&store, keys);
        store.backend().flush_memtables();
        store.backend().compact_now();
    }
    let run_dir = dir.clone();
    suite.bench("reopen/backend=lsm", &format!("keys={keys}"), move || {
        let backend: LsmBackend<DvvMech> =
            LsmBackend::open(&run_dir, SHARDS, lsm_opts()).unwrap();
        black_box(backend.key_count());
    });
    std::fs::remove_dir_all(&dir).ok();
}

/// Residency row: what each backend keeps in memory vs on disk for the
/// same dataset.
struct Residency {
    keys: u64,
    durable_resident: u64,
    durable_disk: u64,
    lsm_resident: u64,
    lsm_disk: u64,
    lsm_runs: usize,
}

fn measure_residency(keys: u64) -> Residency {
    let ddir = temp_dir("bench-storage-resident-durable");
    let durable = open_durable(&ddir);
    fill(&durable, keys);
    let ldir = temp_dir("bench-storage-resident-lsm");
    let lsm = open_lsm(&ldir);
    fill(&lsm, keys);
    lsm.backend().flush_memtables();
    lsm.backend().compact_now();
    // touch a working set so the row shows a warm (not empty) cache
    for i in 0..keys.min(256) {
        black_box(lsm.read(probe(i, keys)).0.len());
    }
    let row = Residency {
        keys,
        durable_resident: durable.backend().resident_bytes(),
        durable_disk: durable.backend().durable_bytes(),
        lsm_resident: lsm.backend().resident_bytes(),
        lsm_disk: lsm.backend().durable_bytes(),
        lsm_runs: lsm.backend().run_count(),
    };
    std::fs::remove_dir_all(&ddir).ok();
    std::fs::remove_dir_all(&ldir).ok();
    row
}

fn json_escape_free(s: &str) -> bool {
    s.chars().all(|c| c.is_ascii_alphanumeric() || "/_=.-".contains(c))
}

/// Hand-rolled JSON (no serde in the offline build): flat timing rows
/// plus the residency sweep and the headline sublinearity ratio —
/// LSM resident bytes per key at the largest sweep size over the
/// smallest (≈1.0 means flat, durable's is ≈ its per-key state cost).
fn write_json(
    path: &str,
    quick: bool,
    results: &[Stats],
    residency: &[Residency],
) -> std::io::Result<()> {
    let mut rows = String::new();
    for (i, s) in results.iter().enumerate() {
        assert!(
            json_escape_free(&s.name) && json_escape_free(&s.param),
            "bench names are JSON-safe"
        );
        if i > 0 {
            rows.push_str(",\n");
        }
        rows.push_str(&format!(
            "    {{\"name\": \"{}\", \"param\": \"{}\", \"mean_ns\": {:.1}, \
             \"p50_ns\": {:.1}, \"p95_ns\": {:.1}, \"min_ns\": {:.1}}}",
            s.name, s.param, s.mean_ns, s.p50_ns, s.p95_ns, s.min_ns
        ));
    }
    let mut res_rows = String::new();
    for (i, r) in residency.iter().enumerate() {
        if i > 0 {
            res_rows.push_str(",\n");
        }
        res_rows.push_str(&format!(
            "    {{\"keys\": {}, \
             \"durable_resident_bytes\": {}, \"durable_disk_bytes\": {}, \
             \"lsm_resident_bytes\": {}, \"lsm_disk_bytes\": {}, \"lsm_runs\": {}}}",
            r.keys, r.durable_resident, r.durable_disk, r.lsm_resident, r.lsm_disk,
            r.lsm_runs
        ));
    }
    let per_key = |r: &Residency, bytes: u64| bytes as f64 / r.keys.max(1) as f64;
    let growth = |resident: fn(&Residency) -> u64| match (residency.first(), residency.last())
    {
        (Some(a), Some(b)) if a.keys < b.keys && per_key(a, resident(a)) > 0.0 => {
            per_key(b, resident(b)) / per_key(a, resident(a))
        }
        _ => 1.0,
    };
    let lsm_growth = growth(|r| r.lsm_resident);
    let durable_growth = growth(|r| r.durable_resident);
    let json = format!(
        "{{\n  \"suite\": \"storage\",\n  \"quick\": {quick},\n  \
         \"lsm_resident_per_key_growth\": {lsm_growth:.3},\n  \
         \"durable_resident_per_key_growth\": {durable_growth:.3},\n  \
         \"residency\": [\n{res_rows}\n  ],\n  \
         \"results\": [\n{rows}\n  ]\n}}\n"
    );
    std::fs::write(path, json)
}

fn main() {
    let opts = Options::from_args();
    let quick = opts.quick;
    let mut suite = Suite::new("storage", opts);

    let sweep: Vec<u64> = if quick { vec![2_000] } else { vec![2_000, 20_000, 100_000] };
    for &keys in &sweep {
        bench_write(&mut suite, "durable", keys, open_durable);
        bench_write(&mut suite, "lsm", keys, open_lsm);
        bench_read_durable(&mut suite, keys);
        bench_read_lsm(&mut suite, keys);
        bench_reopen(&mut suite, keys);
    }
    // the residency sweep needs at least two sizes to show a slope,
    // even in quick mode (it is a handful of fills, not a timing loop)
    let res_sweep: Vec<u64> =
        if quick { vec![1_000, 8_000] } else { vec![2_000, 20_000, 100_000] };
    let residency: Vec<Residency> =
        res_sweep.iter().map(|&keys| measure_residency(keys)).collect();

    let results: Vec<Stats> = suite.results().to_vec();
    let path = std::env::var("BENCH_STORAGE_JSON")
        .unwrap_or_else(|_| "BENCH_storage.json".to_string());
    match write_json(&path, quick, &results, &residency) {
        Ok(()) => eprintln!("  wrote {path}"),
        Err(e) => eprintln!("  could not write {path}: {e}"),
    }
    suite.finish();
}
