//! Geo-replication bench: what the zone-aware write path costs and
//! buys. Local-DC commit (per-DC sloppy quorum, remote homes parked
//! for the shipper) vs the flat synchronous fan-out on an identical
//! 6-node cluster; shipper drain and wire-batch apply throughput; and
//! whole-DC heal convergence (partition → divergent writes in both
//! halves → heal → anti-entropy quiesce). HLC stamp operations ride
//! along since every shipped batch pays them.
//!
//! Results land in `BENCH_geo.json` (path override: `BENCH_GEO_JSON`)
//! so the cross-DC path has a machine-readable baseline; `rust/ci.sh`
//! runs this bench in quick mode to keep the file fresh.
//!
//! Regenerate with `cargo bench --bench geo`.

use std::hint::black_box;
use std::sync::Arc;

use dvvstore::bench_support::{Options, Stats, Suite};
use dvvstore::clocks::{Actor, Hlc, HlcTimestamp};
use dvvstore::cluster::ring::hash_str;
use dvvstore::kernel::mechs::DvvMech;
use dvvstore::kernel::DurableMechanism;
use dvvstore::server::LocalCluster;
use dvvstore::workload::key_name;

const ZONES: [usize; 6] = [0, 0, 0, 1, 1, 1];
const KEYS: u64 = 64;

/// One informed read-modify-write (GET for context, PUT with it) —
/// the steady-state client op; siblings never accumulate.
fn rmw(cluster: &LocalCluster, zone: Option<usize>, key: u64, actor: Actor, op: u64) {
    let name = key_name(key);
    let (ctx, observed) = match cluster.get_in_zone(&name, zone) {
        Ok(ans) => (ans.context, ans.ids),
        Err(_) => (Vec::new(), Vec::new()),
    };
    let body = format!("b{op}").into_bytes();
    let _ = cluster.put_traced_in_zone(&name, body, &ctx, actor, &observed, zone);
}

fn bench_write_paths(suite: &mut Suite) {
    // the comparison pair: same node count, same quorum spec — one
    // cluster zone-aware (writes commit on the coordinator's DC, the
    // rest ship async), one flat (writes fan out to all homes inline)
    let geo = LocalCluster::with_zones(&ZONES, 3, 2, 2).unwrap();
    let flat = LocalCluster::new(ZONES.len(), 3, 2, 2).unwrap();
    let me = Actor::client(1);

    suite.bench("put/geo_local_dc_rmw", "zones=2", {
        let mut op = 0u64;
        move || {
            op += 1;
            rmw(&geo, Some((op % 2) as usize), op % KEYS, me, op);
            // keep the parked queue bounded: drain every 32 ops so the
            // measurement stays the write path, not queue growth
            if op % 32 == 0 {
                black_box(geo.ship_round());
            }
        }
    });

    suite.bench("put/flat_full_fanout_rmw", "zones=1", {
        let mut op = 0u64;
        move || {
            op += 1;
            rmw(&flat, None, op % KEYS, me, op);
        }
    });
}

fn bench_shipper(suite: &mut Suite) {
    let cluster = LocalCluster::with_zones(&ZONES, 3, 2, 2).unwrap();
    let me = Actor::client(2);

    // park a few cross-DC updates, then drain them — the per-round
    // shipper cost a serve loop pays every maintenance tick
    suite.bench("ship/drain_after_4_puts", "zones=2", {
        let mut op = 0u64;
        move || {
            for _ in 0..4 {
                op += 1;
                rmw(&cluster, Some(0), op % KEYS, me, op);
            }
            black_box(cluster.ship_round());
        }
    });

    // wire-side throughput: one 64-state OP_SHIP batch decoded
    // strictly and merged at every home (idempotent re-merge, so the
    // store does not grow across iterations)
    let target = Arc::new(LocalCluster::with_zones(&ZONES, 3, 2, 2).unwrap());
    let source = LocalCluster::new(1, 1, 1, 1).unwrap();
    let mut entries: Vec<(u64, Vec<u8>)> = Vec::new();
    for k in 0..64u64 {
        let name = key_name(k);
        source.put(&name, format!("s{k}").into_bytes(), &[]).unwrap();
        let state = source.node(0).store().state(hash_str(&name));
        let mut bytes = Vec::new();
        <DvvMech as DurableMechanism>::encode_state(&state, &mut bytes);
        entries.push((hash_str(&name), bytes));
    }
    suite.bench("ship/apply_wire_batch64", "zones=2", {
        let target = Arc::clone(&target);
        let mut l = 1u64;
        move || {
            l += 1;
            let (applied, _) =
                target.apply_ship(HlcTimestamp::new(l, 0), black_box(&entries)).unwrap();
            black_box(applied);
        }
    });
}

fn bench_heal_convergence(suite: &mut Suite) {
    // the marquee cycle end-to-end: DC 1 goes dark, both halves take
    // divergent writes on their sloppy quorums, the partition heals,
    // and anti-entropy (shipper round included) quiesces the cluster
    let cluster = LocalCluster::with_zones(&ZONES, 3, 2, 2).unwrap();
    let me = Actor::client(3);
    suite.bench("heal/dc_partition_converge", "zones=2", {
        let mut op = 0u64;
        move || {
            cluster.fabric().partition_groups(&[0, 1, 2], &[3, 4, 5]);
            for _ in 0..8 {
                op += 1;
                rmw(&cluster, Some((op % 2) as usize), op % 16, me, op);
            }
            cluster.fabric().heal_partitions();
            let mut rounds = 0;
            while cluster.anti_entropy_round() > 0 {
                rounds += 1;
                assert!(rounds < 64, "anti-entropy failed to quiesce");
            }
            black_box(rounds);
        }
    });
}

fn bench_hlc(suite: &mut Suite) {
    suite.bench("hlc/now", "local", {
        let mut hlc = Hlc::new();
        let mut pt = 0u64;
        move || {
            pt += 3;
            black_box(hlc.now(black_box(pt)));
        }
    });
    suite.bench("hlc/recv", "merge", {
        let mut a = Hlc::new();
        let mut b = Hlc::new();
        let mut pt = 0u64;
        move || {
            pt += 3;
            let sent = a.now(pt);
            black_box(b.recv(black_box(pt), sent));
        }
    });
}

fn json_escape_free(s: &str) -> bool {
    s.chars().all(|c| c.is_ascii_alphanumeric() || "/_=.-".contains(c))
}

/// Hand-rolled JSON (no serde in the offline build): flat result rows
/// plus the local-commit vs flat-fanout write ratio.
fn write_json(path: &str, quick: bool, results: &[Stats]) -> std::io::Result<()> {
    let mut rows = String::new();
    for (i, s) in results.iter().enumerate() {
        assert!(
            json_escape_free(&s.name) && json_escape_free(&s.param),
            "bench names are JSON-safe"
        );
        if i > 0 {
            rows.push_str(",\n");
        }
        rows.push_str(&format!(
            "    {{\"name\": \"{}\", \"param\": \"{}\", \"mean_ns\": {:.1}, \
             \"p50_ns\": {:.1}, \"p95_ns\": {:.1}, \"min_ns\": {:.1}}}",
            s.name, s.param, s.mean_ns, s.p50_ns, s.p95_ns, s.min_ns
        ));
    }
    let mean_of = |name: &str| results.iter().find(|s| s.name == name).map(|s| s.mean_ns);
    let ratio = match (mean_of("put/flat_full_fanout_rmw"), mean_of("put/geo_local_dc_rmw")) {
        (Some(flat), Some(geo)) if geo > 0.0 => format!("{:.2}", flat / geo),
        _ => "null".to_string(),
    };
    let json = format!(
        "{{\n  \"suite\": \"geo\",\n  \"quick\": {quick},\n  \
         \"flat_over_geo_local_rmw\": {ratio},\n  \
         \"results\": [\n{rows}\n  ]\n}}\n"
    );
    std::fs::write(path, json)
}

fn main() {
    let opts = Options::from_args();
    let quick = opts.quick;
    let mut suite = Suite::new("geo", opts);

    bench_write_paths(&mut suite);
    bench_shipper(&mut suite);
    bench_heal_convergence(&mut suite);
    bench_hlc(&mut suite);

    let results: Vec<Stats> = suite.results().to_vec();
    let path =
        std::env::var("BENCH_GEO_JSON").unwrap_or_else(|_| "BENCH_geo.json".to_string());
    match write_json(&path, quick, &results) {
        Ok(()) => eprintln!("  wrote {path}"),
        Err(e) => eprintln!("  could not write {path}: {e}"),
    }
    suite.finish();
}
