//! Write-ahead-log bench: append throughput per fsync policy, and
//! recovery-replay time per log size.
//!
//! The append cases measure one full durable write — kernel write +
//! state encode + framed, checksummed append — through a
//! `KeyStore<DvvMech, DurableBackend>` under each [`FsyncPolicy`], so
//! the numbers show exactly what each durability level costs on the
//! PUT hot path (fsync=always is the real price of a zero-loss window).
//! The recovery cases time `DurableBackend::open` over a pre-built log,
//! which is the restart-latency budget of a replica.
//!
//! Results also land in `BENCH_wal.json` (path override:
//! `BENCH_WAL_JSON`); `rust/ci.sh` runs this bench in quick mode and
//! fails the gate when the artifact is missing.
//!
//! Regenerate with `cargo bench --bench wal`.

use std::hint::black_box;
use std::path::Path;

use dvvstore::bench_support::{Options, Stats, Suite};
use dvvstore::clocks::Actor;
use dvvstore::kernel::mechs::DvvMech;
use dvvstore::kernel::{Val, WriteMeta};
use dvvstore::store::{DurableBackend, FsyncPolicy, KeyStore, WalOptions};
use dvvstore::testkit::temp_dir;

type DurableStore = KeyStore<DvvMech, DurableBackend<DvvMech>>;

fn open_store(dir: &Path, fsync: FsyncPolicy) -> DurableStore {
    let opts = WalOptions { segment_bytes: 4 << 20, fsync };
    KeyStore::with_backend(DvvMech, DurableBackend::open(dir, 8, opts).unwrap())
}

fn bench_append(suite: &mut Suite, policy: FsyncPolicy, keys: u64) {
    let dir = temp_dir("bench-wal-append");
    let store = open_store(&dir, policy);
    let meta = WriteMeta::basic(Actor::client(0));
    let coord = Actor::server(0);
    let mut i = 0u64;
    suite.bench(&format!("append/fsync={policy}"), &format!("keys={keys}"), move || {
        let key = i % keys;
        let (_, ctx) = store.read(key);
        store.write(key, &ctx, Val::new(i + 1, 64), coord, &meta);
        black_box(&store);
        i += 1;
    });
    std::fs::remove_dir_all(&dir).ok();
}

fn bench_recovery(suite: &mut Suite, records: u64) {
    // build the log once; informed writes keep one sibling per key, so
    // the replay cost is the record scan + decode, not sibling blowup
    let dir = temp_dir("bench-wal-recovery");
    {
        let store = open_store(&dir, FsyncPolicy::Never);
        let meta = WriteMeta::basic(Actor::client(0));
        for i in 0..records {
            let key = i % 512;
            let (_, ctx) = store.read(key);
            store.write(key, &ctx, Val::new(i + 1, 64), Actor::server(0), &meta);
        }
        store.backend().flush().unwrap();
    }
    let opts = WalOptions { segment_bytes: 4 << 20, fsync: FsyncPolicy::Never };
    let log_dir = dir.clone();
    suite.bench("recovery/replay", &format!("records={records}"), move || {
        let backend: DurableBackend<DvvMech> =
            DurableBackend::open(&log_dir, 8, opts).unwrap();
        black_box(backend.recovery_report().records);
    });
    std::fs::remove_dir_all(&dir).ok();
}

fn json_escape_free(s: &str) -> bool {
    s.chars().all(|c| c.is_ascii_alphanumeric() || "/_=.-".contains(c))
}

/// Hand-rolled JSON (no serde in the offline build): flat result rows
/// plus per-policy appends/sec and the fsync-never : fsync-always cost
/// ratio.
fn write_json(path: &str, quick: bool, results: &[Stats]) -> std::io::Result<()> {
    let mut rows = String::new();
    for (i, s) in results.iter().enumerate() {
        assert!(
            json_escape_free(&s.name) && json_escape_free(&s.param),
            "bench names are JSON-safe"
        );
        if i > 0 {
            rows.push_str(",\n");
        }
        rows.push_str(&format!(
            "    {{\"name\": \"{}\", \"param\": \"{}\", \"mean_ns\": {:.1}, \
             \"p50_ns\": {:.1}, \"p95_ns\": {:.1}, \"min_ns\": {:.1}}}",
            s.name, s.param, s.mean_ns, s.p50_ns, s.p95_ns, s.min_ns
        ));
    }
    let mut rates = String::new();
    let mut first = true;
    for s in results.iter().filter(|s| s.name.starts_with("append/")) {
        if s.mean_ns > 0.0 {
            if !first {
                rates.push_str(", ");
            }
            first = false;
            rates.push_str(&format!(
                "\"{}\": {:.0}",
                s.name.trim_start_matches("append/"),
                1e9 / s.mean_ns
            ));
        }
    }
    let mean_of = |name: &str| {
        results
            .iter()
            .find(|s| s.name == name)
            .map(|s| s.mean_ns)
            .unwrap_or(0.0)
    };
    let always = mean_of("append/fsync=always");
    let never = mean_of("append/fsync=never");
    let fsync_cost = if never > 0.0 { always / never } else { 0.0 };
    let json = format!(
        "{{\n  \"suite\": \"wal\",\n  \"quick\": {quick},\n  \
         \"appends_per_sec\": {{{rates}}},\n  \
         \"fsync_always_cost_over_never\": {fsync_cost:.2},\n  \
         \"results\": [\n{rows}\n  ]\n}}\n"
    );
    std::fs::write(path, json)
}

fn main() {
    let opts = Options::from_args();
    let quick = opts.quick;
    let mut suite = Suite::new("wal", opts);

    for policy in [FsyncPolicy::Never, FsyncPolicy::EveryN(64), FsyncPolicy::Always] {
        // fsync=always in quick mode still converges: the harness
        // calibrates iterations from wall time, not a fixed count
        bench_append(&mut suite, policy, 1024);
    }
    for records in if quick { vec![2_000] } else { vec![2_000, 50_000] } {
        bench_recovery(&mut suite, records);
    }

    let results: Vec<Stats> = suite.results().to_vec();
    let path =
        std::env::var("BENCH_WAL_JSON").unwrap_or_else(|_| "BENCH_wal.json".to_string());
    match write_json(&path, quick, &results) {
        Ok(()) => eprintln!("  wrote {path}"),
        Err(e) => eprintln!("  could not write {path}: {e}"),
    }
    suite.finish();
}
