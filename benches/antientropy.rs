//! E10: bulk anti-entropy — rust scalar kernel vs the AOT-compiled XLA
//! dominance kernel, sweeping the number of divergent keys per exchange.
//!
//! Requires `make artifacts`; skips the XLA rows when absent.
//! Regenerate with `cargo bench --bench antientropy`.

use dvvstore::antientropy::{sync_scalar, sync_xla, KeyPair};
use dvvstore::bench_support::{bb, Options, Suite};
use dvvstore::clocks::dvv::Dvv;
use dvvstore::clocks::{Actor, VersionVector};
use dvvstore::kernel::mechanism::Val;
use dvvstore::runtime::batch::SlotMap;
use dvvstore::runtime::{artifact, XlaEngine};
use dvvstore::testkit::Rng;

const REPLICAS: u32 = 8;

fn gen_pairs(keys: u64, rng: &mut Rng) -> Vec<KeyPair> {
    let mut next_id = 0u64;
    let mut gen_set = |rng: &mut Rng, next_id: &mut u64| {
        let mut set: Vec<(Dvv, Val)> = Vec::new();
        for _ in 0..rng.range(1, 3) {
            let vv = VersionVector::from_pairs(
                (0..REPLICAS).map(|i| (Actor::server(i), rng.below(50))),
            );
            let r = Actor::server(rng.below(REPLICAS as u64) as u32);
            let n = vv.get(r) + 1 + rng.below(3);
            *next_id += 1;
            dvvstore::kernel::ops::insert_candidate(
                &mut set,
                Dvv { vv, dot: Some((r, n)) },
                Val::new(*next_id, 0),
            );
        }
        set
    };
    (0..keys)
        .map(|key| KeyPair {
            key,
            local: gen_set(rng, &mut next_id),
            remote: gen_set(rng, &mut next_id),
        })
        .collect()
}

fn main() {
    let mut suite = Suite::new(
        "antientropy (E10: scalar vs XLA bulk dominance)",
        Options::from_args(),
    );
    let mut rng = Rng::new(2718);
    let have_artifacts = artifact::default_dir().join("manifest.txt").exists();
    let mut engine = if have_artifacts {
        let mut e = XlaEngine::open(&artifact::default_dir()).expect("engine");
        e.compile_all().expect("compile");
        Some(e)
    } else {
        eprintln!("artifacts missing: XLA rows skipped (run `make artifacts`)");
        None
    };
    let slots = SlotMap::dense(REPLICAS as usize);

    for &keys in &[32u64, 128, 512, 2048] {
        let pairs = gen_pairs(keys, &mut rng);
        let clocks: usize = pairs.iter().map(|p| p.local.len() + p.remote.len()).sum();
        let param = format!("keys={keys}/clocks={clocks}");
        suite.bench_with_items("sync/scalar", &param, clocks as f64, || {
            bb(sync_scalar(&pairs));
        });
        if let Some(eng) = engine.as_mut() {
            suite.bench_with_items("sync/xla", &param, clocks as f64, || {
                bb(sync_xla(eng, &pairs, &slots).expect("xla sync"));
            });
        }
    }
    suite.finish();
    println!(
        "\nNote: the XLA path runs the Pallas kernel in interpret-mode HLO on CPU; \
         its dominance matrix is O(N·M) while the scalar path is output-sensitive. \
         See EXPERIMENTS.md §E10 for the crossover discussion and DESIGN.md \
         §Hardware-Adaptation for the TPU projection."
    );
}
