//! Anti-entropy benches, two sections:
//!
//! * **E10 sync**: bulk reconciliation — rust scalar kernel vs the
//!   AOT-compiled XLA dominance kernel, sweeping divergent keys per
//!   exchange. Requires `make artifacts`; skips the XLA rows when
//!   absent.
//! * **ae_scale**: divergence *detection* over growing keyspaces —
//!   the whole-store scan ([`diff_pairs`]) vs the hash-tree walk
//!   ([`diff_pairs_merkle`]) on quiesced replica pairs at 10k/100k
//!   (and 1M keys in full mode), plus round cost vs diverged-key
//!   count at a fixed keyspace. The headline: quiesced tree-walk cost
//!   is sublinear in the keyspace (a handful of root comparisons)
//!   while the scan grows linearly.
//!
//! Results land in `BENCH_ae_scale.json` (path override:
//! `BENCH_AE_SCALE_JSON`); `rust/ci.sh` runs this bench in quick mode
//! and fails the gate when the artifact is missing.
//!
//! Regenerate with `cargo bench --bench antientropy`.

use dvvstore::antientropy::{diff_pairs, diff_pairs_merkle, sync_scalar, sync_xla, KeyPair};
use dvvstore::bench_support::{bb, Options, Stats, Suite};
use dvvstore::clocks::dvv::Dvv;
use dvvstore::clocks::{Actor, VersionVector};
use dvvstore::kernel::mechanism::Val;
use dvvstore::kernel::mechs::DvvMech;
use dvvstore::kernel::{Mechanism, WriteMeta};
use dvvstore::runtime::batch::SlotMap;
use dvvstore::runtime::{artifact, XlaEngine};
use dvvstore::store::{KeyStore, ShardedBackend};
use dvvstore::testkit::Rng;

const REPLICAS: u32 = 8;

fn gen_pairs(keys: u64, rng: &mut Rng) -> Vec<KeyPair> {
    let mut next_id = 0u64;
    let mut gen_set = |rng: &mut Rng, next_id: &mut u64| {
        let mut set: Vec<(Dvv, Val)> = Vec::new();
        for _ in 0..rng.range(1, 3) {
            let vv = VersionVector::from_pairs(
                (0..REPLICAS).map(|i| (Actor::server(i), rng.below(50))),
            );
            let r = Actor::server(rng.below(REPLICAS as u64) as u32);
            let n = vv.get(r) + 1 + rng.below(3);
            *next_id += 1;
            dvvstore::kernel::ops::insert_candidate(
                &mut set,
                Dvv { vv, dot: Some((r, n)) },
                Val::new(*next_id, 0),
            );
        }
        set
    };
    (0..keys)
        .map(|key| KeyPair {
            key,
            local: gen_set(rng, &mut next_id),
            remote: gen_set(rng, &mut next_id),
        })
        .collect()
}

type Store = KeyStore<DvvMech, ShardedBackend<DvvMech>>;

/// Two fully-converged replicas holding `keys` single-sibling keys —
/// the quiesced pair a periodic AE round usually meets.
fn converged_pair(keys: u64) -> (Store, Store) {
    let local = KeyStore::with_backend(DvvMech, ShardedBackend::with_shards(64));
    let remote = KeyStore::with_backend(DvvMech, ShardedBackend::with_shards(64));
    let meta = WriteMeta::basic(Actor::client(0));
    let empty = <DvvMech as Mechanism>::Context::default();
    for k in 0..keys {
        local.write(k, &empty, Val::new(k + 1, 8), Actor::server(0), &meta);
        remote.merge_key(k, &local.state(k));
    }
    (local, remote)
}

/// Large-keyspace detection soak: scan vs tree walk on quiesced pairs
/// per keyspace size, then round cost vs diverged-key count.
fn ae_scale(suite: &mut Suite, quick: bool) {
    let sizes: &[u64] =
        if quick { &[10_000, 100_000] } else { &[10_000, 100_000, 1_000_000] };
    for &keys in sizes {
        let (local, remote) = converged_pair(keys);
        let param = format!("keys={keys}");
        suite.bench("quiesced/scan", &param, || {
            bb(diff_pairs(&local, &remote).len());
        });
        suite.bench("quiesced/merkle", &param, || {
            bb(diff_pairs_merkle(&local, &remote).len());
        });
    }

    // round cost vs divergence at a fixed keyspace: diverge the first
    // `target` keys on the remote (cumulative) and re-measure
    const KEYS: u64 = 100_000;
    let (local, remote) = converged_pair(KEYS);
    let meta = WriteMeta::basic(Actor::client(0));
    let mut diverged = 0u64;
    for &target in &[1u64, 100, 10_000] {
        while diverged < target {
            let k = diverged;
            let (_, ctx) = remote.read(k);
            remote.write(k, &ctx, Val::new(KEYS + k + 1, 8), Actor::server(1), &meta);
            diverged += 1;
        }
        let param = format!("keys={KEYS}/diverged={target}");
        suite.bench("diverged/merkle", &param, || {
            bb(diff_pairs_merkle(&local, &remote).len());
        });
        suite.bench("diverged/scan", &param, || {
            bb(diff_pairs(&local, &remote).len());
        });
    }
}

fn json_escape_free(s: &str) -> bool {
    s.chars().all(|c| c.is_ascii_alphanumeric() || "/_=.-".contains(c))
}

/// Hand-rolled JSON (no serde in the offline build): flat result rows
/// plus the quiesced-round scaling evidence — the merkle cost ratio
/// between the smallest and largest keyspace must sit far below the
/// keyspace ratio (sublinear detection), and the per-size
/// scan-over-merkle speedup makes the win legible.
fn write_json(path: &str, quick: bool, results: &[Stats]) -> std::io::Result<()> {
    let mut rows = String::new();
    for (i, s) in results.iter().enumerate() {
        assert!(
            json_escape_free(&s.name) && json_escape_free(&s.param),
            "bench names are JSON-safe"
        );
        if i > 0 {
            rows.push_str(",\n");
        }
        rows.push_str(&format!(
            "    {{\"name\": \"{}\", \"param\": \"{}\", \"mean_ns\": {:.1}, \
             \"p50_ns\": {:.1}, \"p95_ns\": {:.1}, \"min_ns\": {:.1}}}",
            s.name, s.param, s.mean_ns, s.p50_ns, s.p95_ns, s.min_ns
        ));
    }

    let keys_of = |s: &Stats| -> Option<u64> {
        s.param.strip_prefix("keys=").and_then(|r| r.parse().ok())
    };
    let quiesced: Vec<(u64, f64, f64)> = results
        .iter()
        .filter(|s| s.name == "quiesced/merkle")
        .filter_map(|m| {
            let keys = keys_of(m)?;
            let scan = results
                .iter()
                .find(|s| s.name == "quiesced/scan" && s.param == m.param)?;
            Some((keys, m.mean_ns, scan.mean_ns))
        })
        .collect();
    let mut speedups = String::new();
    for (i, (keys, merkle_ns, scan_ns)) in quiesced.iter().enumerate() {
        if i > 0 {
            speedups.push_str(", ");
        }
        let x = if *merkle_ns > 0.0 { scan_ns / merkle_ns } else { 0.0 };
        speedups.push_str(&format!("\"keys={keys}\": {x:.1}"));
    }
    let scaling = match (quiesced.first(), quiesced.last()) {
        (Some(&(k0, m0, _)), Some(&(k1, m1, _))) if k1 > k0 && m0 > 0.0 => {
            let size_ratio = k1 as f64 / k0 as f64;
            let cost_ratio = m1 / m0;
            format!(
                "{{\"size_ratio\": {size_ratio:.1}, \"merkle_cost_ratio\": {cost_ratio:.2}, \
                 \"sublinear\": {}}}",
                cost_ratio < size_ratio
            )
        }
        _ => "{}".to_string(),
    };
    let json = format!(
        "{{\n  \"suite\": \"ae_scale\",\n  \"quick\": {quick},\n  \
         \"scan_over_merkle_speedup\": {{{speedups}}},\n  \
         \"quiesced_scaling\": {scaling},\n  \
         \"results\": [\n{rows}\n  ]\n}}\n"
    );
    std::fs::write(path, json)
}

fn main() {
    let opts = Options::from_args();
    let quick = opts.quick;
    let mut suite = Suite::new(
        "antientropy (E10 bulk sync + ae_scale divergence detection)",
        opts,
    );
    let mut rng = Rng::new(2718);
    let have_artifacts = artifact::default_dir().join("manifest.txt").exists();
    let mut engine = if have_artifacts {
        let mut e = XlaEngine::open(&artifact::default_dir()).expect("engine");
        e.compile_all().expect("compile");
        Some(e)
    } else {
        eprintln!("artifacts missing: XLA rows skipped (run `make artifacts`)");
        None
    };
    let slots = SlotMap::dense(REPLICAS as usize);

    for &keys in &[32u64, 128, 512, 2048] {
        let pairs = gen_pairs(keys, &mut rng);
        let clocks: usize = pairs.iter().map(|p| p.local.len() + p.remote.len()).sum();
        let param = format!("keys={keys}/clocks={clocks}");
        suite.bench_with_items("sync/scalar", &param, clocks as f64, || {
            bb(sync_scalar(&pairs));
        });
        if let Some(eng) = engine.as_mut() {
            suite.bench_with_items("sync/xla", &param, clocks as f64, || {
                bb(sync_xla(eng, &pairs, &slots).expect("xla sync"));
            });
        }
    }

    ae_scale(&mut suite, quick);

    let results: Vec<Stats> = suite.results().to_vec();
    let path = std::env::var("BENCH_AE_SCALE_JSON")
        .unwrap_or_else(|_| "BENCH_ae_scale.json".to_string());
    match write_json(&path, quick, &results) {
        Ok(()) => eprintln!("  wrote {path}"),
        Err(e) => eprintln!("  could not write {path}: {e}"),
    }
    suite.finish();
    println!(
        "\nNote: the XLA path runs the Pallas kernel in interpret-mode HLO on CPU; \
         its dominance matrix is O(N·M) while the scalar path is output-sensitive. \
         See EXPERIMENTS.md §E10 for the crossover discussion and DESIGN.md \
         §Hardware-Adaptation for the TPU projection."
    );
}
