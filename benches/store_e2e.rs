//! E9: end-to-end store throughput/latency per mechanism on the
//! simulated cluster — the DVV-costs-about-a-VV claim at system level.
//!
//! Wall-clock throughput here measures the *simulator's* processing rate
//! (events/s), which is dominated by mechanism costs: clock compares on
//! every write/merge, state clones on every replication message.
//! Regenerate with `cargo bench --bench store_e2e`.

use dvvstore::bench_support::{fmt_count, time_once};
use dvvstore::config::StoreConfig;
use dvvstore::kernel::mechs::{dispatch, MechVisitor};
use dvvstore::kernel::{MechKind, Mechanism};
use dvvstore::sim::Sim;
use dvvstore::workload::{RandomWorkload, WorkloadSpec};

struct Run {
    clients: usize,
    ops: u64,
    seed: u64,
}

impl MechVisitor for Run {
    type Out = (u64, f64, u64, u64); // ops, wall_s, get_p99, put_p99

    fn visit<M: Mechanism>(self, mech: M) -> Self::Out {
        let mut cfg = StoreConfig::default();
        cfg.cluster.nodes = 6;
        cfg.cluster.replication = 3;
        cfg.cluster.read_quorum = 2;
        cfg.cluster.write_quorum = 2;
        let spec = WorkloadSpec {
            keys: 256,
            zipf_theta: 0.9,
            put_fraction: 0.5,
            read_before_write: 0.6,
            mean_think_us: 400.0,
            ops_per_client: self.ops,
            value_len: 64,
        };
        let driver = Box::new(RandomWorkload::new(spec, self.clients));
        let mut sim = Sim::new(mech, cfg, self.clients, true, driver, self.seed).expect("sim");
        sim.start();
        let ((), wall) = time_once(|| sim.run(u64::MAX));
        (
            sim.metrics.ops(),
            wall.as_secs_f64(),
            sim.metrics.get_latency.percentile(0.99),
            sim.metrics.put_latency.percentile(0.99),
        )
    }
}

fn main() {
    println!("## store_e2e (E9: simulated cluster throughput per mechanism)\n");
    println!("6 nodes, N=3 R=2 W=2, 32 clients, 256 keys zipf(0.9)\n");
    println!("| mechanism | ops | wall(ms) | sim ops/s | get_p99(µs) | put_p99(µs) | vs dvv |");
    println!("|---|---|---|---|---|---|---|");
    let mut dvv_rate = 0.0;
    let mut rows = Vec::new();
    for kind in MechKind::ALL {
        let (ops, wall, gp99, pp99) = dispatch(kind, Run { clients: 32, ops: 300, seed: 77 });
        let rate = ops as f64 / wall;
        if kind == MechKind::Dvv {
            dvv_rate = rate;
        }
        rows.push((kind, ops, wall, rate, gp99, pp99));
    }
    for (kind, ops, wall, rate, gp99, pp99) in rows {
        println!(
            "| {:<9} | {ops} | {:.0} | {} | {gp99} | {pp99} | {:.2}x |",
            kind.name(),
            wall * 1e3,
            fmt_count(rate),
            rate / dvv_rate
        );
    }
    println!("\n(ratios ≈1 for vv/dvv confirm the paper's 'DVV costs about a version vector')");
}
