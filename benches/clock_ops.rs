//! E8/Perf micro-benches: raw clock operations per mechanism.
//!
//! The paper's efficiency claim is that a DVV costs about as much as a
//! plain version vector (one extra pair); the perf target in DESIGN.md §7
//! is DVV `compare` within 2× of VV `compare`. Regenerate with
//! `cargo bench --bench clock_ops`.

use dvvstore::bench_support::{bb, Options, Suite};
use dvvstore::clocks::causal_history::CausalHistory;
use dvvstore::clocks::dvv::Dvv;
use dvvstore::clocks::vv::VersionVector;
use dvvstore::clocks::{Actor, Event, LogicalClock};
use dvvstore::testkit::Rng;

fn mk_vv(rng: &mut Rng, replicas: u32) -> VersionVector {
    VersionVector::from_pairs((0..replicas).map(|i| (Actor::server(i), 1 + rng.below(1000))))
}

fn mk_dvv(rng: &mut Rng, replicas: u32) -> Dvv {
    let vv = mk_vv(rng, replicas);
    let r = Actor::server(rng.below(replicas as u64) as u32);
    let n = vv.get(r) + 1 + rng.below(3);
    Dvv { vv, dot: Some((r, n)) }
}

fn mk_hist(rng: &mut Rng, replicas: u32, events_per: u64) -> CausalHistory {
    CausalHistory::from_events((0..replicas).flat_map(|i| {
        let n = 1 + rng.below(events_per);
        (1..=n).map(move |s| Event::new(Actor::server(i), s))
    }))
}

fn main() {
    let mut suite = Suite::new("clock_ops (E8: per-op cost of each clock type)", Options::from_args());
    let mut rng = Rng::new(42);

    for &replicas in &[3u32, 8, 32] {
        let param = format!("replicas={replicas}");
        let pairs_vv: Vec<(VersionVector, VersionVector)> =
            (0..256).map(|_| (mk_vv(&mut rng, replicas), mk_vv(&mut rng, replicas))).collect();
        let pairs_dvv: Vec<(Dvv, Dvv)> =
            (0..256).map(|_| (mk_dvv(&mut rng, replicas), mk_dvv(&mut rng, replicas))).collect();

        let mut i = 0;
        suite.bench("compare/vv", &param, || {
            let (a, b) = &pairs_vv[i & 255];
            i += 1;
            bb(a.compare(b));
        });
        let mut i = 0;
        suite.bench("compare/dvv", &param, || {
            let (a, b) = &pairs_dvv[i & 255];
            i += 1;
            bb(a.compare(b));
        });
        let mut i = 0;
        suite.bench("join/vv", &param, || {
            let (a, b) = &pairs_vv[i & 255];
            i += 1;
            bb(a.join(b));
        });
        let mut i = 0;
        suite.bench("encode/dvv", &param, || {
            let (a, _) = &pairs_dvv[i & 255];
            i += 1;
            let mut buf = Vec::with_capacity(64);
            dvvstore::clocks::encoding::encode_dvv(a, &mut buf);
            bb(buf);
        });
    }

    // causal histories for contrast (the unscalable baseline)
    for &events in &[10u64, 100, 1000] {
        let param = format!("events={events}");
        let pairs: Vec<(CausalHistory, CausalHistory)> = (0..64)
            .map(|_| (mk_hist(&mut rng, 3, events), mk_hist(&mut rng, 3, events)))
            .collect();
        let mut i = 0;
        suite.bench("compare/history", &param, || {
            let (a, b) = &pairs[i & 63];
            i += 1;
            bb(a.compare(b));
        });
    }

    // the DESIGN.md §7 target, enforced: DVV compare within 2x of VV
    let vv_mean = suite
        .results()
        .iter()
        .find(|s| s.name == "compare/vv" && s.param == "replicas=3")
        .map(|s| s.mean_ns)
        .unwrap_or(0.0);
    let dvv_mean = suite
        .results()
        .iter()
        .find(|s| s.name == "compare/dvv" && s.param == "replicas=3")
        .map(|s| s.mean_ns)
        .unwrap_or(0.0);
    suite.finish();
    if vv_mean > 0.0 {
        let ratio = dvv_mean / vv_mean;
        println!("\nDVV/VV compare ratio (replicas=3): {ratio:.2}x (target <= 2.0x)");
    }
}
