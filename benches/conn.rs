//! Connection-scalability bench: reactor vs thread-per-connection serve
//! loop under 10/100/1k/10k concurrent connections.
//!
//! Each level opens N binary-v2 connections against a fresh server and
//! drives a fixed GET budget through them from a small pool of driver
//! threads (connections idle between their turns, as real fleets do),
//! recording per-op latency. Reported per `(mode, level)`: achieved
//! throughput and p50/p99 tail latency. Results land in
//! `BENCH_conn.json` (path override: `BENCH_CONN_JSON`); `rust/ci.sh`
//! runs the quick levels so the file stays fresh.
//!
//! Connection counts are *requested*; if the environment's fd limit (or
//! thread limit, in threaded mode) stops a level short, the level runs
//! with what it got and the JSON records both numbers — a silent clamp
//! would misread as "10k conns measured".
//!
//! Regenerate with `cargo bench --bench conn`.

use std::sync::{Barrier, Mutex};
use std::time::Instant;

use dvvstore::api::{KvClient, TcpClient};
use dvvstore::bench_support::{fmt_count, Options};
use dvvstore::clocks::Actor;
use dvvstore::server::tcp::{ServeMode, ServeOptions, Server};
use dvvstore::server::LocalCluster;
use std::sync::Arc;

const DRIVERS: usize = 8;

struct LevelResult {
    mode: &'static str,
    conns_requested: usize,
    conns: usize,
    ops: u64,
    wall_ms: f64,
    throughput: f64,
    p50_us: f64,
    p99_us: f64,
}

fn percentile(sorted: &[u64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[(((sorted.len() - 1) as f64) * p) as usize] as f64
}

fn mode_name(mode: ServeMode) -> &'static str {
    match mode {
        ServeMode::Reactor { .. } => "reactor",
        ServeMode::Threaded => "threaded",
    }
}

/// One `(mode, level)` measurement against a fresh server.
fn run_level(mode: ServeMode, requested: usize, total_ops: u64) -> LevelResult {
    let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
    let server =
        Server::start_with("127.0.0.1:0", Arc::clone(&cluster), ServeOptions { mode }).unwrap();
    let addr = server.addr();

    // seed the key every GET will hit
    let mut seeder = TcpClient::connect(addr, Actor::client(0)).unwrap();
    seeder.put("bench", b"payload-0123456789abcdef".to_vec(), None).unwrap();
    seeder.quit().unwrap();

    // open the fleet, clamping (loudly) at environment limits
    let mut fleet: Vec<TcpClient> = Vec::with_capacity(requested);
    for i in 0..requested {
        match TcpClient::connect(addr, Actor::client(i as u32 + 1)) {
            Ok(c) => fleet.push(c),
            Err(e) => {
                eprintln!(
                    "  conns={requested}: clamped to {} ({e})",
                    fleet.len()
                );
                break;
            }
        }
    }
    let conns = fleet.len();
    if conns == 0 {
        server.shutdown();
        return LevelResult {
            mode: mode_name(mode),
            conns_requested: requested,
            conns: 0,
            ops: 0,
            wall_ms: 0.0,
            throughput: 0.0,
            p50_us: 0.0,
            p99_us: 0.0,
        };
    }

    // shard the fleet over the driver pool round-robin
    let drivers = DRIVERS.min(conns);
    let mut shards: Vec<Vec<TcpClient>> = (0..drivers).map(|_| Vec::new()).collect();
    for (i, client) in fleet.into_iter().enumerate() {
        shards[i % drivers].push(client);
    }
    let ops_per_driver = total_ops / drivers as u64;

    let barrier = Barrier::new(drivers + 1);
    let latencies: Mutex<Vec<u64>> = Mutex::new(Vec::new());
    let t0 = std::thread::scope(|scope| {
        for mut shard in shards {
            let barrier = &barrier;
            let latencies = &latencies;
            scope.spawn(move || {
                barrier.wait();
                let mut local = Vec::with_capacity(ops_per_driver as usize);
                for op in 0..ops_per_driver {
                    let client = &mut shard[(op as usize) % shard.len()];
                    let t = Instant::now();
                    let reply = client.get("bench").expect("bench GET failed");
                    assert!(!reply.values.is_empty());
                    local.push(t.elapsed().as_micros() as u64);
                }
                latencies.lock().unwrap().append(&mut local);
                // connections die here (no QUIT): teardown cost is the
                // server's problem, not part of the measured window
            });
        }
        barrier.wait();
        Instant::now()
    });
    let wall = t0.elapsed();
    server.shutdown();

    let mut lat = latencies.into_inner().unwrap();
    lat.sort_unstable();
    let ops = lat.len() as u64;
    let throughput = ops as f64 / wall.as_secs_f64().max(1e-9);
    LevelResult {
        mode: mode_name(mode),
        conns_requested: requested,
        conns,
        ops,
        wall_ms: wall.as_secs_f64() * 1e3,
        throughput,
        p50_us: percentile(&lat, 0.50),
        p99_us: percentile(&lat, 0.99),
    }
}

fn write_json(path: &str, quick: bool, results: &[LevelResult]) -> std::io::Result<()> {
    let mut rows = String::new();
    for (i, r) in results.iter().enumerate() {
        if i > 0 {
            rows.push_str(",\n");
        }
        rows.push_str(&format!(
            "    {{\"mode\": \"{}\", \"conns_requested\": {}, \"conns\": {}, \
             \"ops\": {}, \"wall_ms\": {:.1}, \"throughput_ops_s\": {:.1}, \
             \"p50_us\": {:.1}, \"p99_us\": {:.1}}}",
            r.mode, r.conns_requested, r.conns, r.ops, r.wall_ms, r.throughput, r.p50_us, r.p99_us
        ));
    }
    // reactor-over-threaded ratios per level (>1 = reactor ahead)
    let find = |mode: &str, requested: usize| {
        results.iter().find(|r| r.mode == mode && r.conns_requested == requested)
    };
    let mut ratios = String::new();
    let mut first = true;
    for r in results.iter().filter(|r| r.mode == "reactor") {
        if let Some(t) = find("threaded", r.conns_requested) {
            if t.throughput > 0.0 && r.p99_us > 0.0 {
                if !first {
                    ratios.push_str(", ");
                }
                first = false;
                ratios.push_str(&format!(
                    "\"conns={}\": {{\"throughput\": {:.2}, \"p99\": {:.2}}}",
                    r.conns_requested,
                    r.throughput / t.throughput,
                    t.p99_us / r.p99_us
                ));
            }
        }
    }
    let json = format!(
        "{{\n  \"suite\": \"conn\",\n  \"quick\": {quick},\n  \
         \"reactor_vs_threaded\": {{{ratios}}},\n  \
         \"results\": [\n{rows}\n  ]\n}}\n"
    );
    std::fs::write(path, json)
}

fn main() {
    let opts = Options::from_args();
    let quick = opts.quick;
    // quick mode (CI) keeps to the levels a small container handles in
    // seconds; the full run sweeps the paper-scale fan-out
    let levels: &[usize] = if quick { &[10, 100] } else { &[10, 100, 1000, 10000] };
    let total_ops: u64 = if quick { 2_000 } else { 20_000 };

    let mut results = Vec::new();
    for &level in levels {
        for mode in [ServeMode::Reactor { workers: 0 }, ServeMode::Threaded] {
            if let Some(f) = &opts.filter {
                let tag = format!("{}/conns={level}", mode_name(mode));
                if !tag.contains(f.as_str()) {
                    continue;
                }
            }
            let r = run_level(mode, level, total_ops);
            eprintln!(
                "  {:<9} conns={:<6} ops={:<6} {:>10}/s  p50 {:>8.1}µs  p99 {:>8.1}µs",
                r.mode,
                r.conns,
                r.ops,
                fmt_count(r.throughput),
                r.p50_us,
                r.p99_us
            );
            results.push(r);
        }
    }

    let path =
        std::env::var("BENCH_CONN_JSON").unwrap_or_else(|_| "BENCH_conn.json".to_string());
    match write_json(&path, quick, &results) {
        Ok(()) => eprintln!("  wrote {path}"),
        Err(e) => eprintln!("  could not write {path}: {e}"),
    }

    println!("\n## conn\n");
    println!("| mode | conns | ops | throughput | p50 | p99 |");
    println!("|---|---|---|---|---|---|");
    for r in &results {
        println!(
            "| {} | {} | {} | {}/s | {:.1}µs | {:.1}µs |",
            r.mode,
            r.conns,
            r.ops,
            fmt_count(r.throughput),
            r.p50_us,
            r.p99_us
        );
    }
}
