//! E7: metadata size scaling — the paper's headline contrast (§1, §7).
//!
//! Per-key causality metadata after `writes` updates issued by `clients`
//! distinct clients through `replicas` coordinators, for every mechanism.
//! The paper's claim: client-VV grows linearly with the client
//! population; DVV stays bounded by the replication degree; causal
//! histories grow with the number of updates.
//!
//! This bench prints a size table (bytes, not time). Regenerate with
//! `cargo bench --bench metadata`.

use dvvstore::clocks::Actor;
use dvvstore::kernel::mechs::{dispatch, MechVisitor};
use dvvstore::kernel::{MechKind, Mechanism, Val, WriteMeta};
use dvvstore::testkit::Rng;

struct Probe {
    clients: u32,
    writes: u64,
    replicas: u32,
    informed: f64,
    seed: u64,
}

impl MechVisitor for Probe {
    type Out = (usize, usize, usize); // (state bytes, context bytes, siblings)

    fn visit<M: Mechanism>(self, mech: M) -> Self::Out {
        let mut rng = Rng::new(self.seed);
        let mut st = M::State::default();
        let mut counters = vec![0u64; self.clients as usize];
        for i in 0..self.writes {
            let client = rng.below(self.clients as u64) as u32;
            let coord = Actor::server(rng.below(self.replicas as u64) as u32);
            counters[client as usize] += 1;
            let meta = WriteMeta {
                client: Actor::client(client),
                physical_us: i,
                client_seq: Some(counters[client as usize]),
            };
            let ctx = if rng.chance(self.informed) {
                mech.read(&st).1
            } else {
                M::Context::default()
            };
            mech.write(&mut st, &ctx, Val::new(i + 1, 0), coord, &meta);
        }
        let (_, ctx) = mech.read(&st);
        (mech.metadata_bytes(&st), mech.context_bytes(&ctx), mech.sibling_count(&st))
    }
}

fn main() {
    println!("## metadata (E7: per-key causality metadata, bytes)\n");
    println!("replicas=3, 2000 writes per cell, 60% informed writes\n");
    print!("| mechanism |");
    let client_counts = [4u32, 16, 64, 256, 1024];
    for c in client_counts {
        print!(" {c} clients |");
    }
    println!(" growth |");
    println!("|---|---|---|---|---|---|---|");
    for kind in MechKind::ALL {
        let mut sizes = Vec::new();
        for &clients in &client_counts {
            let (state_b, ctx_b, _sib) = dispatch(
                kind,
                Probe { clients, writes: 2000, replicas: 3, informed: 0.6, seed: 9 },
            );
            sizes.push((state_b, ctx_b));
        }
        let growth = if sizes[0].0 > 0 {
            sizes[4].0 as f64 / sizes[0].0 as f64
        } else {
            0.0
        };
        print!("| {:<9} |", kind.name());
        for (s, _) in &sizes {
            print!(" {s} |");
        }
        println!(" {growth:.1}x |");
    }

    println!("\n### context bytes shipped to clients (same sweep)\n");
    print!("| mechanism |");
    for c in client_counts {
        print!(" {c} clients |");
    }
    println!();
    println!("|---|---|---|---|---|---|");
    for kind in MechKind::ALL {
        print!("| {:<9} |", kind.name());
        for &clients in &client_counts {
            let (_s, ctx_b, _) = dispatch(
                kind,
                Probe { clients, writes: 2000, replicas: 3, informed: 0.6, seed: 9 },
            );
            print!(" {ctx_b} |");
        }
        println!();
    }

    // the paper's claim, enforced: DVV metadata is flat in clients while
    // client-VV grows with them
    let dvv_small = dispatch(MechKind::Dvv, Probe { clients: 4, writes: 2000, replicas: 3, informed: 0.6, seed: 9 });
    let dvv_big = dispatch(MechKind::Dvv, Probe { clients: 1024, writes: 2000, replicas: 3, informed: 0.6, seed: 9 });
    let cvv_small = dispatch(MechKind::ClientVv, Probe { clients: 4, writes: 2000, replicas: 3, informed: 0.6, seed: 9 });
    let cvv_big = dispatch(MechKind::ClientVv, Probe { clients: 1024, writes: 2000, replicas: 3, informed: 0.6, seed: 9 });
    let dvv_growth = dvv_big.0 as f64 / dvv_small.0.max(1) as f64;
    let cvv_growth = cvv_big.0 as f64 / cvv_small.0.max(1) as f64;
    println!("\nDVV growth 4→1024 clients: {dvv_growth:.1}x; client-VV growth: {cvv_growth:.1}x");
    assert!(dvv_growth < 3.0, "DVV metadata must be ~flat in client count");
    assert!(cvv_growth > 10.0, "client-VV metadata must grow with clients");
    println!("E7 claims hold");
}
