//! Flat single-lock vs. lock-striped sharded store under a
//! multi-threaded Zipf workload — the tentpole claim behind the
//! `StorageBackend` split.
//!
//! The *flat* rows reproduce the seed layout: one `Mutex` around a whole
//! [`KeyStore`], every operation serialized (what `server::LocalCluster`
//! used per replica before sharding). The *sharded* rows run the same
//! operation mix against `KeyStore<DvvMech, ShardedBackend>` shared by
//! plain `Arc` — stripe locks only. Expectation: parity at 1 thread
//! (sharding costs nothing), ≥2x throughput once threads contend.
//!
//! Mix: 70% GET / 30% PUT (half the PUTs informed by a fresh read, half
//! blind), keys drawn Zipf(0.9) from a 4096-key space, so hot keys make
//! the single lock hurt exactly the way skewed production traffic does.
//!
//! Regenerate with `cargo bench --bench sharded_store` (add `--quick`
//! for a CI-sized run).

use std::sync::{Arc, Mutex};

use dvvstore::bench_support::{fmt_count, time_threads, Options};
use dvvstore::clocks::vv::VersionVector;
use dvvstore::clocks::Actor;
use dvvstore::kernel::mechs::DvvMech;
use dvvstore::kernel::{Val, WriteMeta};
use dvvstore::store::{KeyStore, ShardedBackend};
use dvvstore::testkit::Rng;
use dvvstore::workload::zipf::Zipf;

const KEYS: u64 = 4096;
const ZIPF_THETA: f64 = 0.9;
const SHARDS: usize = 64;
const GET_FRACTION: f64 = 0.7;

/// One thread's slice of the workload against any `&self` store API.
fn drive(
    thread: usize,
    ops: u64,
    zipf: &Zipf,
    read: &impl Fn(u64) -> (Vec<Val>, VersionVector),
    write: &impl Fn(u64, &VersionVector, Val),
) {
    let mut rng = Rng::new(0xBEEF ^ ((thread as u64) << 32));
    let empty_ctx = VersionVector::new();
    for i in 0..ops {
        let key = zipf.sample(&mut rng);
        if rng.chance(GET_FRACTION) {
            let (vals, _ctx) = read(key);
            std::hint::black_box(vals);
        } else {
            let id = ((thread as u64) << 40) | i;
            let val = Val::new(id, 64);
            if rng.chance(0.5) {
                // informed write: supersede what we just read
                let (_, ctx) = read(key);
                write(key, &ctx, val);
            } else {
                // blind write: makes siblings under contention
                write(key, &empty_ctx, val);
            }
        }
    }
}

fn meta() -> WriteMeta {
    WriteMeta::basic(Actor::client(0))
}

fn bench_flat(threads: usize, ops_per_thread: u64, zipf: &Zipf) -> f64 {
    let store = Arc::new(Mutex::new(KeyStore::new(DvvMech)));
    let wall = time_threads(threads, |t| {
        let read = |k: u64| store.lock().unwrap().read(k);
        let write = |k: u64, ctx: &VersionVector, val: Val| {
            store.lock().unwrap().write(k, ctx, val, Actor::server(0), &meta())
        };
        drive(t, ops_per_thread, zipf, &read, &write);
    });
    (threads as u64 * ops_per_thread) as f64 / wall.as_secs_f64()
}

fn bench_sharded(threads: usize, ops_per_thread: u64, zipf: &Zipf) -> f64 {
    let store = Arc::new(KeyStore::with_backend(
        DvvMech,
        ShardedBackend::with_shards(SHARDS),
    ));
    let wall = time_threads(threads, |t| {
        let read = |k: u64| store.read(k);
        let write = |k: u64, ctx: &VersionVector, val: Val| {
            store.write(k, ctx, val, Actor::server(0), &meta())
        };
        drive(t, ops_per_thread, zipf, &read, &write);
    });
    (threads as u64 * ops_per_thread) as f64 / wall.as_secs_f64()
}

fn main() {
    let opts = Options::from_args();
    let ops_per_thread: u64 = if opts.quick { 8_000 } else { 50_000 };
    let zipf = Zipf::new(KEYS, ZIPF_THETA);

    println!("## sharded_store (flat single-mutex vs. {SHARDS}-way lock-striped)\n");
    println!(
        "{KEYS} keys zipf({ZIPF_THETA}), {:.0}% GET, {ops_per_thread} ops/thread\n",
        GET_FRACTION * 100.0
    );
    println!("| threads | flat ops/s | sharded ops/s | speedup |");
    println!("|---|---|---|---|");
    for &threads in &[1usize, 2, 4, 8] {
        // warm both paths once so allocator/map growth is off the clock
        let _ = bench_flat(threads, ops_per_thread / 10, &zipf);
        let _ = bench_sharded(threads, ops_per_thread / 10, &zipf);
        let flat = bench_flat(threads, ops_per_thread, &zipf);
        let sharded = bench_sharded(threads, ops_per_thread, &zipf);
        println!(
            "| {threads} | {}/s | {}/s | {:.2}x |",
            fmt_count(flat),
            fmt_count(sharded),
            sharded / flat
        );
    }
    println!("\n(acceptance: sharded >= 2x flat once threads > 1 on multicore hosts)");
}
