//! E8: cost of the §4 kernel operations (`sync`, `update`) as sibling
//! count and replica count grow, per mechanism.
//!
//! Regenerate with `cargo bench --bench kernel_ops`.

use dvvstore::bench_support::{bb, Options, Suite};
use dvvstore::clocks::Actor;
use dvvstore::kernel::mechs::{DvvMech, DvvSetMech, HistoryMech, ServerVvMech};
use dvvstore::kernel::{Mechanism, Val, WriteMeta};
use dvvstore::testkit::Rng;

/// Build a state with `siblings` concurrent versions across `replicas`
/// coordinators (blind writes).
fn mk_state<M: Mechanism>(mech: &M, siblings: usize, replicas: u32, rng: &mut Rng) -> M::State {
    let mut st = M::State::default();
    for i in 0..siblings {
        let coord = Actor::server(rng.below(replicas as u64) as u32);
        mech.write(
            &mut st,
            &M::Context::default(),
            Val::new(i as u64 + 1, 0),
            coord,
            &WriteMeta::basic(Actor::client(i as u32)),
        );
    }
    st
}

fn bench_mech<M: Mechanism>(suite: &mut Suite, mech: M, rng: &mut Rng) {
    for &siblings in &[1usize, 4, 16] {
        for &replicas in &[3u32, 8] {
            let param = format!("sib={siblings}/rep={replicas}");
            let st = mk_state(&mech, siblings, replicas, rng);
            let incoming = mk_state(&mech, siblings, replicas, rng);

            // update: the coordinator-side write (§4.1 put steps 2-3)
            let meta = WriteMeta::basic(Actor::client(999));
            let (_, ctx) = mech.read(&st);
            suite.bench(&format!("update/{}", M::NAME), &param, || {
                let mut s = st.clone();
                mech.write(&mut s, &ctx, Val::new(u64::MAX, 0), Actor::server(0), &meta);
                bb(&s);
            });

            // sync: replica-to-replica merge
            suite.bench(&format!("sync/{}", M::NAME), &param, || {
                let mut s = st.clone();
                mech.merge(&mut s, &incoming);
                bb(&s);
            });

            // read: GET reduction (values + context)
            suite.bench(&format!("read/{}", M::NAME), &param, || {
                bb(mech.read(&st));
            });
        }
    }
}

fn main() {
    let mut suite = Suite::new("kernel_ops (E8: §4 sync/update cost)", Options::from_args());
    let mut rng = Rng::new(7);
    bench_mech(&mut suite, ServerVvMech, &mut rng);
    bench_mech(&mut suite, DvvMech, &mut rng);
    bench_mech(&mut suite, DvvSetMech, &mut rng);
    bench_mech(&mut suite, HistoryMech, &mut rng);
    suite.finish();
}
