//! Integration: failure injection — crashes, partitions, message loss —
//! against the DVV store. Writes accepted on either side of a partition
//! must survive healing (the paper's write-availability motivation).

use dvvstore::config::StoreConfig;
use dvvstore::kernel::mechs::DvvMech;
use dvvstore::sim::failure::FaultPlan;
use dvvstore::sim::Sim;
use dvvstore::testkit::Rng;
use dvvstore::workload::{RandomWorkload, WorkloadSpec};

fn spec(ops: u64) -> WorkloadSpec {
    WorkloadSpec {
        keys: 24,
        ops_per_client: ops,
        put_fraction: 0.7,
        read_before_write: 0.5,
        mean_think_us: 500.0,
        ..Default::default()
    }
}

#[test]
fn writes_survive_full_partition_and_heal() {
    let mut cfg = StoreConfig::default();
    cfg.cluster.nodes = 4;
    cfg.cluster.replication = 2;
    cfg.cluster.read_quorum = 1;
    cfg.cluster.write_quorum = 1;
    cfg.antientropy.period_us = 30_000;
    let driver = Box::new(RandomWorkload::new(spec(50), 8));
    let mut sim = Sim::new(DvvMech, cfg, 8, true, driver, 31).unwrap();
    FaultPlan::new()
        .partition_window(vec![0, 1], vec![2, 3], 10_000, 300_000)
        .apply(&mut sim);
    sim.start();
    sim.run(5_000_000);
    sim.settle();
    assert!(sim.metrics.ops() > 200, "{}", sim.metrics.summary());
    assert_eq!(
        sim.audit_permanently_lost(),
        0,
        "partitioned writes lost: {}",
        sim.metrics.summary()
    );
}

#[test]
fn rolling_crashes_do_not_lose_acknowledged_writes() {
    let mut cfg = StoreConfig::default();
    cfg.cluster.nodes = 5;
    cfg.cluster.replication = 3;
    cfg.cluster.read_quorum = 2;
    cfg.cluster.write_quorum = 2;
    cfg.antientropy.period_us = 40_000;
    let driver = Box::new(RandomWorkload::new(spec(60), 8));
    let mut sim = Sim::new(DvvMech, cfg, 8, true, driver, 33).unwrap();
    let mut frng = Rng::new(1);
    FaultPlan::new()
        .random_crashes(5, 2, 60_000, 400_000, &mut frng)
        .apply(&mut sim);
    sim.start();
    sim.run(10_000_000);
    sim.settle();
    assert!(sim.metrics.ops() > 100, "{}", sim.metrics.summary());
    assert_eq!(sim.audit_permanently_lost(), 0, "{}", sim.metrics.summary());
}

#[test]
fn lossy_network_converges_via_antientropy() {
    let mut cfg = StoreConfig::default();
    cfg.cluster.nodes = 4;
    cfg.cluster.replication = 3;
    cfg.cluster.read_quorum = 1;
    cfg.cluster.write_quorum = 1;
    cfg.net.drop_prob = 0.25;
    cfg.antientropy.period_us = 20_000;
    let driver = Box::new(RandomWorkload::new(spec(40), 6));
    let mut sim = Sim::new(DvvMech, cfg, 6, true, driver, 35).unwrap();
    sim.start();
    sim.run(10_000_000);
    assert!(sim.metrics.dropped_messages > 0, "drops expected");
    assert!(sim.metrics.ae_rounds > 0);
    sim.settle();
    assert_eq!(sim.audit_permanently_lost(), 0, "{}", sim.metrics.summary());
}

#[test]
fn total_outage_fails_ops_then_recovers() {
    let mut cfg = StoreConfig::default();
    cfg.cluster.nodes = 2;
    cfg.cluster.replication = 2;
    cfg.cluster.read_quorum = 1;
    cfg.cluster.write_quorum = 1;
    let driver = Box::new(RandomWorkload::new(spec(40), 4));
    let mut sim = Sim::new(DvvMech, cfg, 4, true, driver, 37).unwrap();
    FaultPlan::new()
        .crash_window(0, 5_000, 100_000)
        .crash_window(1, 5_000, 100_000)
        .apply(&mut sim);
    sim.start();
    sim.run(10_000_000);
    assert!(sim.metrics.failed_ops > 0, "outage must fail some ops");
    // clients have no retry policy, so ops issued during the outage are
    // consumed as failures; the ones issued after recovery must succeed
    assert!(sim.metrics.ops() > 20, "cluster must recover: {}", sim.metrics.summary());
}
