//! Elastic-topology churn correctness: one seeded join/decommission
//! schedule — layered on top of random chaos — drives the discrete-event
//! simulator, the threaded `LocalCluster`, and live TCP, and every world
//! must come out oracle-clean:
//!
//! 1. **zero lost updates** — DVVs never destroy a concurrent write,
//!    churn or not;
//! 2. **convergence** — after healing, the active members agree on every
//!    key (a retiree is excluded: it drains, it does not participate);
//! 3. **complete re-homing** — every value a decommissioned node still
//!    holds is present on (or causally superseded at) the key's current
//!    homes: nothing is stranded on a retiree;
//! 4. a `TcpClient` session keeps serving across topology epoch bumps.
//!
//! Plus `Ring`/`Topology` invariant property tests: distinct preference
//! lists, bounded key movement on join, epoch monotonicity.
//!
//! The default gate runs fixed seeds; `CHURN_ITERS=<n>` appends `n`
//! derived seeds so local runs can soak (`CHURN_ITERS=20 rust/ci.sh`).
//! Failures print in the uniform `testkit::soak` format and replay with
//! `DVV_SEED=<seed>`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use dvvstore::antientropy::diff_pairs;
use dvvstore::api::{drive_workload, key_name, KvClient, LocalClient, TcpClient};
use dvvstore::clocks::Actor;
use dvvstore::cluster::topology::INITIAL_EPOCH;
use dvvstore::cluster::{NodeId, Ring, Topology};
use dvvstore::config::StoreConfig;
use dvvstore::kernel::mechs::DvvMech;
use dvvstore::oracle::SharedOracle;
use dvvstore::server::tcp::Server;
use dvvstore::server::LocalCluster;
use dvvstore::sim::failure::{Fault, FaultPlan};
use dvvstore::sim::Sim;
use dvvstore::store::{Key, ShardedBackend, StorageBackend};
use dvvstore::testkit::{run_seeded, soak_seeds, Rng};
use dvvstore::workload::{RandomWorkload, WorkloadSpec};

const BASE_NODES: usize = 5;
const KEYS: u64 = 8;
const CLIENTS: u32 = 4;
const HORIZON_US: u64 = 400_000;

/// Fixed seeds in the default gate, plus `CHURN_ITERS` derived extras.
fn seeds() -> Vec<u64> {
    soak_seeds(&[404, 505, 606], "CHURN_ITERS")
}

/// The decommission victim a plan names (there is exactly one).
fn victim_of(plan: &FaultPlan) -> NodeId {
    plan.faults
        .iter()
        .find_map(|f| match f {
            Fault::Decommission { node, .. } => Some(*node),
            _ => None,
        })
        .expect("plan has a decommission")
}

/// Assert that everything `retiree` still holds is present on — or
/// causally superseded at — the key's current homes.
fn assert_rehomed<B: StorageBackend<DvvMech>>(
    cluster: &LocalCluster<B>,
    oracle: &SharedOracle,
    retiree: NodeId,
    tag: &str,
) {
    let node = cluster.node(retiree);
    let keys: Vec<Key> = node.store().keys().collect();
    let n = cluster.quorum().n;
    for k in keys {
        let homes = cluster.topology().replicas_for(k, n);
        for v in node.store().values(k) {
            let covered = homes.iter().any(|&h| {
                cluster
                    .node(h)
                    .store()
                    .values(k)
                    .iter()
                    .any(|s| s.id == v.id || oracle.with_inner(|o| o.leq(v.id, s.id)))
            });
            assert!(covered, "{tag}: value {} on key {k} stranded on retiree {retiree}", v.id);
        }
    }
}

/// Heal, quiesce anti-entropy, and assert pairwise member convergence,
/// hint drainage, and the oracle's zero-lost-updates verdict.
fn heal_and_audit<B: StorageBackend<DvvMech>>(
    cluster: &LocalCluster<B>,
    oracle: &SharedOracle,
    tag: &str,
) {
    cluster.fabric().heal_all();
    let mut rounds = 0;
    while cluster.anti_entropy_round() > 0 {
        rounds += 1;
        assert!(rounds < 32, "{tag}: anti-entropy failed to quiesce");
    }
    assert_eq!(cluster.pending_hints(), 0, "{tag}: hints not drained");
    let members = cluster.members();
    for (ai, &a) in members.iter().enumerate() {
        for &b in members.iter().skip(ai + 1) {
            let diverged = diff_pairs(cluster.node(a).store(), cluster.node(b).store());
            assert!(
                diverged.is_empty(),
                "{tag}: members {a}/{b} diverged on {} keys",
                diverged.len()
            );
        }
    }
    let verdict = oracle.verdict();
    assert!(verdict.tracked > 0, "{tag}: no writes registered");
    assert_eq!(verdict.unaudited_drops, 0, "{tag}: untraced writes leaked in");
    assert_eq!(
        verdict.lost_updates, 0,
        "{tag}: {} lost updates ({} correct supersessions)",
        verdict.lost_updates, verdict.correct_supersessions
    );
}

// -------------------------------------------------------------------
// churn under full random chaos, threaded world, real concurrency
// -------------------------------------------------------------------

/// One churn-chaos run: random crash/partition/degrade windows *plus* a
/// join and a decommission, stepped against the threaded cluster while
/// client threads hammer session-tracked quorum ops.
fn churn_chaos_run(seed: u64) {
    let cluster =
        LocalCluster::with_backends(BASE_NODES, 3, 2, 2, |_| ShardedBackend::with_shards(8))
            .unwrap();
    let oracle = Arc::new(SharedOracle::new());
    cluster.attach_oracle(Arc::clone(&oracle));
    cluster.fabric().reseed(seed ^ 0xE1A5);
    let cluster = Arc::new(cluster);

    let mut rng = Rng::new(seed);
    let plan = FaultPlan::random_chaos(BASE_NODES, HORIZON_US, &mut rng)
        .random_churn(BASE_NODES, 1, HORIZON_US, &mut rng);
    let victim = victim_of(&plan);

    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    for t in 0..CLIENTS {
        let cluster = Arc::clone(&cluster);
        let stop = Arc::clone(&stop);
        workers.push(std::thread::spawn(move || {
            let me = Actor::client(t);
            let mut rng = Rng::new(seed.wrapping_mul(0x9E37).wrapping_add(u64::from(t)));
            let mut sessions: Vec<Option<(Vec<u8>, Vec<u64>)>> = vec![None; KEYS as usize];
            let mut ok_ops = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let ki = rng.below(KEYS) as usize;
                let key = format!("churn-{ki}");
                let outcome = if rng.chance(0.5) {
                    cluster.get(&key).map(|ans| {
                        sessions[ki] = Some((ans.context, ans.ids));
                    })
                } else {
                    let (ctx, observed) = sessions[ki].clone().unwrap_or_default();
                    let body = format!("c{t}-{ok_ops}").into_bytes();
                    cluster.put_traced(&key, body, &ctx, me, &observed).map(|_| ())
                };
                // ops may fail under active faults; that is the exercise
                if outcome.is_ok() {
                    ok_ops += 1;
                }
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            ok_ops
        }));
    }

    // step the schedule's virtual clock — including the membership
    // events — while the workers run
    const STEPS: u64 = 50;
    for step in 1..=STEPS {
        cluster.advance_plan(&plan, HORIZON_US * step / STEPS);
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    stop.store(true, Ordering::Relaxed);
    let total_ok: u64 = workers.into_iter().map(|w| w.join().unwrap()).sum();
    assert!(total_ok > 0, "seed {seed}: no operation ever succeeded");

    // the whole schedule fired: one join, one decommission
    assert_eq!(cluster.node_count(), BASE_NODES + 1, "seed {seed}: join fired");
    assert_eq!(cluster.member_count(), BASE_NODES, "seed {seed}: decommission fired");
    assert_eq!(cluster.epoch(), INITIAL_EPOCH + 2, "seed {seed}: two epoch bumps");
    assert!(!cluster.members().contains(&victim), "seed {seed}");

    heal_and_audit(&cluster, &oracle, &format!("seed {seed}"));
    assert_rehomed(&cluster, &oracle, victim, &format!("seed {seed}"));
}

#[test]
fn churn_chaos_converges_without_lost_updates() {
    run_seeded("churn_chaos", &seeds(), churn_chaos_run);
}

// -------------------------------------------------------------------
// one churn plan, three worlds (acceptance criterion)
// -------------------------------------------------------------------

const SEED: u64 = 6161;
const WORKLOAD_OPS: u64 = 40;

fn spec() -> WorkloadSpec {
    WorkloadSpec {
        keys: KEYS,
        zipf_theta: 0.9,
        put_fraction: 0.5,
        read_before_write: 0.5,
        mean_think_us: 300.0,
        ops_per_client: WORKLOAD_OPS,
        value_len: 24,
    }
}

/// Churn plus crash-free chaos: partitions and degradation only, so the
/// DES permanent-loss audit stays exact (a client→coordinator hop is
/// never refused in the simulator; with crashes an issued write can land
/// nowhere, which is a different property than churn safety).
fn churn_plan() -> FaultPlan {
    let mut rng = Rng::new(SEED ^ 0xC4);
    FaultPlan::new()
        .random_partitions(BASE_NODES, 2, 60_000, HORIZON_US, &mut rng)
        .degrade_window(0.2, 300, 20_000, 150_000)
        .random_churn(BASE_NODES, 1, HORIZON_US, &mut rng)
}

#[test]
fn same_churn_plan_drives_sim_local_and_tcp() {
    let plan = churn_plan();
    let victim = victim_of(&plan);
    let joined = BASE_NODES; // dense ids: the join takes the next slot

    // --- simulator: the plan schedules as DES events --------------
    let mut cfg = StoreConfig::default();
    cfg.cluster.nodes = BASE_NODES;
    cfg.cluster.replication = 3;
    cfg.cluster.read_quorum = 2;
    cfg.cluster.write_quorum = 2;
    cfg.antientropy.period_us = 20_000;
    let driver = Box::new(RandomWorkload::new(spec(), CLIENTS as usize));
    let mut sim = Sim::new(DvvMech, cfg, CLIENTS as usize, true, driver, SEED).unwrap();
    plan.apply(&mut sim);
    sim.start();
    sim.run(10_000_000);
    assert_eq!(sim.topology_epoch(), INITIAL_EPOCH + 2, "sim: two epoch bumps");
    assert_eq!(sim.nodes.len(), BASE_NODES + 1, "sim: join fired");
    assert!(!sim.members().contains(&victim), "sim: decommission fired");
    sim.settle();
    assert_eq!(sim.metrics.lost_updates, 0, "{}", sim.metrics.summary());
    assert_eq!(sim.audit_permanently_lost(), 0, "{}", sim.metrics.summary());
    // sim re-homing: everything the retiree holds is covered on members
    let retiree_keys: Vec<Key> = sim.nodes[victim].store.keys().collect();
    for key in retiree_keys {
        for v in sim.nodes[victim].store.values(key) {
            let covered = sim.members().iter().any(|&m| {
                sim.nodes[m]
                    .store
                    .values(key)
                    .iter()
                    .any(|s| s.id == v.id || sim.oracle.leq(v.id, s.id))
            });
            assert!(covered, "sim: value {} on key {key} stranded", v.id);
        }
    }
    assert!(sim.nodes[joined].store.key_count() > 0, "sim: joined node serves data");

    // --- threaded cluster + live TCP: the same plan value ----------
    let expected_ops = u64::from(CLIENTS) * WORKLOAD_OPS;
    enum Transport {
        Local,
        Tcp,
    }
    for which in [Transport::Local, Transport::Tcp] {
        let tag = match which {
            Transport::Local => "local",
            Transport::Tcp => "tcp",
        };
        let cluster = Arc::new(LocalCluster::new(BASE_NODES, 3, 2, 2).unwrap());
        let oracle = Arc::new(SharedOracle::new());
        cluster.attach_oracle(Arc::clone(&oracle));
        let step = {
            let cluster = Arc::clone(&cluster);
            let plan = plan.clone();
            move |completed: u64| {
                let t = HORIZON_US.saturating_mul(completed) / expected_ops.max(1);
                cluster.advance_plan(&plan, t);
            }
        };
        match which {
            Transport::Local => {
                let mut clients: Vec<_> = (0..CLIENTS)
                    .map(|i| LocalClient::new(Arc::clone(&cluster), Actor::client(i)))
                    .collect();
                let mut driver = RandomWorkload::new(spec(), CLIENTS as usize);
                let report = drive_workload(&mut clients, &mut driver, SEED, step);
                assert!(report.ok_ops > 0, "{tag}: some ops succeed under churn");
            }
            Transport::Tcp => {
                let server = Server::start("127.0.0.1:0", Arc::clone(&cluster)).unwrap();
                let mut clients: Vec<_> = (0..CLIENTS)
                    .map(|i| TcpClient::connect(server.addr(), Actor::client(i)).unwrap())
                    .collect();
                let mut driver = RandomWorkload::new(spec(), CLIENTS as usize);
                let report = drive_workload(&mut clients, &mut driver, SEED, step);
                assert!(report.ok_ops > 0, "{tag}: some ops succeed under churn");
                // the acceptance clincher: these sessions opened at epoch
                // 1 and lived through a join *and* a decommission — the
                // same connection must keep serving and can observe the
                // new epoch on demand
                let view = clients[0].topology().unwrap();
                assert_eq!(view.epoch, INITIAL_EPOCH + 2, "{tag}: epoch visible");
                assert_eq!(view.slots, (BASE_NODES + 1) as u64);
                assert!(!view.members.contains(&(victim as u64)));
                let reply = clients[0].get(&key_name(0)).unwrap();
                drop(reply); // any non-error reply proves the session survived
                for c in clients {
                    c.quit().unwrap();
                }
                server.shutdown();
            }
        }
        assert_eq!(cluster.epoch(), INITIAL_EPOCH + 2, "{tag}: two epoch bumps");
        assert_eq!(cluster.node_count(), BASE_NODES + 1, "{tag}: join fired");
        assert!(!cluster.members().contains(&victim), "{tag}: decommission fired");
        heal_and_audit(&cluster, &oracle, tag);
        assert_rehomed(&cluster, &oracle, victim, tag);
    }
}

// -------------------------------------------------------------------
// TcpClient keeps a session across an epoch bump (focused)
// -------------------------------------------------------------------

#[test]
fn tcp_session_survives_join_and_decommission() {
    let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
    let server = Server::start("127.0.0.1:0", Arc::clone(&cluster)).unwrap();
    let mut client = TcpClient::connect(server.addr(), Actor::client(0)).unwrap();
    let mut admin = TcpClient::connect(server.addr(), Actor::client(99)).unwrap();

    let reply = client.put("stable", b"v1".to_vec(), None).unwrap();
    assert!(reply.ctx.is_some());
    assert_eq!(client.seen_epoch(), 0, "no topology observation yet");

    // JOIN over the admin plane: the worker session is untouched
    let (id, view) = admin.join().unwrap();
    assert_eq!(id, 3);
    assert_eq!(view.epoch, INITIAL_EPOCH + 1);
    assert_eq!(view.members, vec![0, 1, 2, 3]);
    let got = client.get("stable").unwrap();
    assert_eq!(got.values, vec![b"v1".to_vec()], "session serves across the bump");

    // DECOMMISSION over the admin plane, mid-session
    let view = admin.decommission(0).unwrap();
    assert_eq!(view.epoch, INITIAL_EPOCH + 2);
    assert_eq!(view.members, vec![1, 2, 3]);
    assert!(admin.decommission(0).is_err(), "already retired");
    assert!(admin.decommission(9).is_err(), "unknown id");

    // the worker session still reads and writes, with its causal chain
    let got = client.get("stable").unwrap();
    client.put("stable", b"v2".to_vec(), Some(&got.ctx)).unwrap();
    assert_eq!(client.get("stable").unwrap().values, vec![b"v2".to_vec()]);

    // epoch is discoverable mid-session through STATS and TOPOLOGY
    let stats = client.stats().unwrap();
    assert_eq!(stats.epoch, INITIAL_EPOCH + 2, "epoch travels in STATS");
    assert_eq!(client.seen_epoch(), INITIAL_EPOCH + 2);
    assert_eq!(client.topology().unwrap().members, vec![1, 2, 3]);

    client.quit().unwrap();
    admin.quit().unwrap();
    server.shutdown();
}

// -------------------------------------------------------------------
// Ring / Topology invariant property tests
// -------------------------------------------------------------------

#[test]
fn preference_lists_stay_distinct_members_only_under_churn() {
    run_seeded("churn_preference_lists", &seeds(), |seed| {
        let mut rng = Rng::new(seed);
        let topo = Topology::new(4, 64).unwrap();
        for step in 0..12 {
            // random walk over membership, keeping at least 2 members
            if rng.chance(0.5) || topo.member_count() <= 2 {
                topo.join();
            } else {
                let members = topo.members();
                let pick = members[rng.below(members.len() as u64) as usize];
                topo.decommission(pick).unwrap();
            }
            let members = topo.members();
            let n = 3.min(members.len());
            for key in 0..100u64 {
                let reps = topo.replicas_for(key, 3);
                assert_eq!(reps.len(), n, "seed {seed} step {step}: list size");
                let mut sorted = reps.clone();
                sorted.sort_unstable();
                sorted.dedup();
                assert_eq!(sorted.len(), n, "seed {seed} step {step}: distinct");
                for node in reps {
                    assert!(
                        members.contains(&node),
                        "seed {seed} step {step}: non-member {node} routed"
                    );
                }
            }
        }
    });
}

#[test]
fn epoch_monotone_one_bump_per_change() {
    run_seeded("churn_epoch_monotone", &seeds(), |seed| {
        let mut rng = Rng::new(seed ^ 0xE9);
        let topo = Topology::new(3, 32).unwrap();
        let mut last = topo.epoch();
        assert_eq!(last, INITIAL_EPOCH);
        for _ in 0..20 {
            if rng.chance(0.6) || topo.member_count() <= 2 {
                let (_, epoch) = topo.join();
                assert_eq!(epoch, last + 1, "seed {seed}: join bumps by one");
                last = epoch;
            } else {
                let members = topo.members();
                let pick = members[rng.below(members.len() as u64) as usize];
                let epoch = topo.decommission(pick).unwrap();
                assert_eq!(epoch, last + 1, "seed {seed}: decommission bumps by one");
                last = epoch;
            }
            assert_eq!(topo.epoch(), last);
        }
        // failed changes do not bump
        assert!(topo.decommission(10_000).is_err());
        assert_eq!(topo.epoch(), last);
    });
}

#[test]
fn join_moves_a_bounded_key_fraction() {
    run_seeded("churn_join_movement", &seeds(), |seed| {
        // consistent hashing's point: adding the (n+1)-th node moves
        // roughly 1/(n+1) of the keys, never a wholesale reshuffle
        let mut ring = Ring::new(4, 128).unwrap();
        let sample: Vec<u64> = {
            let mut rng = Rng::new(seed);
            (0..2000).map(|_| rng.next_u64()).collect()
        };
        let before: Vec<_> = sample.iter().map(|&k| ring.primary_for(k).unwrap()).collect();
        ring.add_node();
        let moved = sample
            .iter()
            .zip(&before)
            .filter(|&(&k, &b)| ring.primary_for(k).unwrap() != b)
            .count();
        // ideal is 2000/5 = 400; generous slack, but far below "all"
        assert!(
            (100..900).contains(&moved),
            "seed {seed}: moved {moved} of 2000 keys"
        );
        // and every moved key moved *to the newcomer*, never between
        // the old nodes
        for (&k, &b) in sample.iter().zip(&before) {
            let now = ring.primary_for(k).unwrap();
            assert!(now == b || now == 4, "seed {seed}: key {k} moved {b}->{now}");
        }
    });
}

#[test]
fn topology_replicas_into_matches_allocating_form() {
    let topo = Topology::new(5, 64).unwrap();
    topo.join();
    topo.decommission(2).unwrap();
    let mut buf = Vec::new();
    for key in 0..300u64 {
        topo.replicas_into(key, 3, &mut buf);
        assert_eq!(buf, topo.replicas_for(key, 3), "key {key}");
        assert!(!buf.contains(&2), "retired node never routed");
    }
}
