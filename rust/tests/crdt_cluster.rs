//! CRDT cluster acceptance: one seeded ORSWOT workload, one seeded
//! `FaultPlan` (an intra-DC partition, a loss/delay window, and a
//! whole-DC cut across a two-zone topology), two worlds — the
//! discrete-event simulator and the threaded `LocalCluster` — driven
//! through every [`TypedKvClient`] transport. Both worlds must reach
//! the same oracle verdict: zero lost acked adds, zero add-wins
//! violations, zero phantoms, and full post-heal convergence.
//!
//! Also covers the typed surface end to end over the wire: fault-free
//! cross-transport equivalence for all three datatypes, `WrongType`
//! rejection over TCP, and the STATS typed-key counts in both the text
//! and binary protocols.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;

use dvvstore::antientropy::diff_pairs;
use dvvstore::api::{
    drive_set_workload, KvClient, LocalClient, SimTransport, TcpClient, TypedKvClient,
};
use dvvstore::clocks::Actor;
use dvvstore::cluster::ring::hash_str;
use dvvstore::config::StoreConfig;
use dvvstore::kernel::crdt::TypedState;
use dvvstore::oracle::{SetAudit, SetVerdict};
use dvvstore::server::tcp::Server;
use dvvstore::server::LocalCluster;
use dvvstore::sim::failure::FaultPlan;
use dvvstore::workload::{SetWorkload, SetWorkloadSpec};

/// Two data centers of three nodes each.
const ZONES: [usize; 6] = [0, 0, 0, 1, 1, 1];
const NODES: usize = 6;
const CLIENTS: usize = 3;
const SEED: u64 = 0x5E7C4A05;
const KEY: &str = "chaos-set";

/// Virtual horizon the fault windows live inside. The DES spends
/// roughly 2–4ms of virtual time per typed RMW (a read round plus a
/// write round at 500µs mean hops), so ~120 ops fill this span; the
/// threaded world maps completed-op fractions onto the same clock.
const HORIZON_US: u64 = 400_000;

fn spec() -> SetWorkloadSpec {
    SetWorkloadSpec {
        universe: 12,
        remove_fraction: 0.3,
        read_fraction: 0.1,
        ops_per_client: 40,
    }
}

/// The shared chaos schedule: a degraded-network window (drops plus
/// extra delay), a whole-DC cut of zone 1, and an intra-DC partition —
/// overlapping the op stream, all healed before the horizon.
fn chaos_plan() -> FaultPlan {
    FaultPlan::new()
        .degrade_window(0.15, 200, 20_000, 120_000)
        .partition_dc_at(&ZONES, 1, 60_000, 200_000)
        .partition_window(vec![0, 1], vec![2, 3, 4, 5], 220_000, 320_000)
}

fn geo_cfg() -> StoreConfig {
    let mut cfg = StoreConfig::default();
    cfg.cluster.nodes = NODES;
    cfg.cluster.replication = 3;
    cfg.cluster.read_quorum = 2;
    cfg.cluster.write_quorum = 2;
    cfg.cluster.zones = ZONES.to_vec();
    cfg
}

fn assert_clean(world: &str, verdict: &SetVerdict) {
    assert_eq!(verdict.lost_adds, 0, "{world}: acked add lost: {verdict:?}");
    assert_eq!(
        verdict.resurrections, 0,
        "{world}: removed element resurfaced: {verdict:?}"
    );
    assert_eq!(verdict.phantoms, 0, "{world}: phantom member: {verdict:?}");
    assert!(verdict.acked_adds > 0, "{world}: no add was ever acked");
}

// -------------------------------------------------------------------
// the marquee: one plan, two worlds, three transports, one verdict
// -------------------------------------------------------------------

#[test]
fn orswot_chaos_reaches_identical_verdicts_in_both_worlds() {
    let expected_ops = (CLIENTS as u64) * spec().ops_per_client;

    // --- DES world: the plan schedules as simulator events ---------
    let transport = SimTransport::new(geo_cfg(), CLIENTS, SEED).unwrap();
    transport.with_sim(|sim| chaos_plan().apply(sim));
    let mut clients: Vec<_> = (0..CLIENTS).map(|i| transport.client(i)).collect();
    let mut workload = SetWorkload::new(spec(), CLIENTS);
    let audit = SetAudit::new();
    let report = drive_set_workload(&mut clients, &mut workload, KEY, SEED, &audit, |_| {});
    assert!(report.ok_ops > 0, "some DES ops must succeed under chaos");
    assert!(report.adds > 0, "the DES run acked at least one SADD");
    let members = transport.with_sim(|sim| {
        sim.run(u64::MAX); // drain remaining fault/heal events
        sim.settle();
        // every replica holding the set converged to one state
        let k = hash_str(KEY);
        let states: Vec<TypedState> =
            (0..NODES).filter_map(|n| sim.typed_state_at(n, k)).collect();
        assert!(!states.is_empty(), "the set landed on at least one replica");
        let digest = states[0].state_digest();
        for st in &states {
            assert_eq!(st.state_digest(), digest, "replica set states diverged");
        }
        let TypedState::Set(s) = &states[0] else {
            panic!("the audited key holds a non-set state")
        };
        s.members().map(|e| e.to_vec()).collect::<Vec<_>>()
    });
    assert_clean("DES", &audit.verdict(&members));

    // --- threaded world: the same plan steps the fabric, over both
    // the in-process client and live TCP ---------------------------
    enum Transport {
        Local,
        Tcp,
    }
    for which in [Transport::Local, Transport::Tcp] {
        let world = match which {
            Transport::Local => "threaded/local",
            Transport::Tcp => "threaded/tcp",
        };
        let cluster = Arc::new(LocalCluster::with_zones(&ZONES, 3, 2, 2).unwrap());
        let plan = chaos_plan();
        let step = {
            let cluster = Arc::clone(&cluster);
            move |completed: u64| {
                let t = HORIZON_US.saturating_mul(completed) / expected_ops.max(1);
                cluster.advance_plan(&plan, t);
            }
        };
        let audit = SetAudit::new();
        let mut workload = SetWorkload::new(spec(), CLIENTS);
        let report = match which {
            Transport::Local => {
                let mut clients: Vec<_> = (0..CLIENTS)
                    .map(|i| LocalClient::new(Arc::clone(&cluster), Actor::client(i as u32)))
                    .collect();
                drive_set_workload(&mut clients, &mut workload, KEY, SEED, &audit, step)
            }
            Transport::Tcp => {
                let server = Server::start("127.0.0.1:0", Arc::clone(&cluster)).unwrap();
                let mut clients: Vec<_> = (0..CLIENTS)
                    .map(|i| {
                        TcpClient::connect(server.addr(), Actor::client(i as u32)).unwrap()
                    })
                    .collect();
                let report =
                    drive_set_workload(&mut clients, &mut workload, KEY, SEED, &audit, step);
                for c in clients {
                    c.quit().unwrap();
                }
                server.shutdown();
                report
            }
        };
        assert!(report.ok_ops > 0, "{world}: some ops must succeed under chaos");
        assert!(report.adds > 0, "{world}: at least one SADD acked");

        // fire any windows the run outpaced, heal, converge, audit —
        // the same closing ritual as the DES
        cluster.advance_plan(&plan, HORIZON_US);
        cluster.fabric().heal_all();
        let mut rounds = 0;
        while cluster.anti_entropy_round() > 0 {
            rounds += 1;
            assert!(rounds < 32, "{world}: anti-entropy failed to quiesce");
        }
        for a in 0..NODES {
            for b in (a + 1)..NODES {
                assert!(
                    diff_pairs(cluster.node(a).store(), cluster.node(b).store()).is_empty(),
                    "{world}: nodes {a}/{b} diverged after heal"
                );
            }
        }
        let members = cluster.set_members(KEY).unwrap();
        assert_clean(world, &audit.verdict(&members));
    }
}

// -------------------------------------------------------------------
// fault-free: all three datatypes agree across all three transports
// -------------------------------------------------------------------

/// Apply the same typed script through one client and return the
/// observable outcome (sorted members, counter value, map field).
fn typed_script<C: TypedKvClient>(c: &mut C) -> (Vec<Vec<u8>>, i64, Option<Vec<u8>>) {
    c.sadd("s", b"alpha").unwrap();
    c.sadd("s", b"beta").unwrap();
    c.sadd("s", b"gamma").unwrap();
    assert!(!c.srem("s", b"beta").unwrap().is_empty(), "observed dots removed");
    assert!(c.srem("s", b"never-added").unwrap().is_empty(), "nothing observed");
    c.incr("c", 10).unwrap();
    c.incr("c", -3).unwrap();
    c.mput("m", b"field", b"v1").unwrap();
    c.mput("m", b"field", b"v2").unwrap();
    let mut members = c.smembers("s").unwrap();
    members.sort();
    (members, c.count("c").unwrap(), c.mget("m", b"field").unwrap())
}

#[test]
fn typed_ops_agree_across_all_three_transports() {
    let expected = (
        vec![b"alpha".to_vec(), b"gamma".to_vec()],
        7,
        Some(b"v2".to_vec()),
    );

    let transport = SimTransport::new(geo_cfg(), 1, SEED).unwrap();
    let mut sim_client = transport.client(0);
    assert_eq!(typed_script(&mut sim_client), expected, "sim transport");

    let cluster = Arc::new(LocalCluster::with_zones(&ZONES, 3, 2, 2).unwrap());
    let mut local = LocalClient::new(Arc::clone(&cluster), Actor::client(1));
    assert_eq!(typed_script(&mut local), expected, "local transport");

    let cluster = Arc::new(LocalCluster::with_zones(&ZONES, 3, 2, 2).unwrap());
    let server = Server::start("127.0.0.1:0", Arc::clone(&cluster)).unwrap();
    let mut tcp = TcpClient::connect(server.addr(), Actor::client(2)).unwrap();
    assert_eq!(typed_script(&mut tcp), expected, "tcp transport");
    tcp.quit().unwrap();
    server.shutdown();
}

// -------------------------------------------------------------------
// wire-level semantics: WrongType over TCP, STATS typed counts
// -------------------------------------------------------------------

#[test]
fn wrong_type_is_rejected_over_the_wire_and_connection_survives() {
    let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
    let server = Server::start("127.0.0.1:0", Arc::clone(&cluster)).unwrap();
    let mut c = TcpClient::connect(server.addr(), Actor::client(7)).unwrap();
    c.sadd("k", b"x").unwrap();
    let err = c.incr("k", 1).unwrap_err();
    assert!(
        err.to_string().contains("wrong datatype"),
        "remote WrongType surfaces verbatim: {err}"
    );
    // the rejected op corrupted nothing and the connection still works
    assert_eq!(c.smembers("k").unwrap(), vec![b"x".to_vec()]);
    c.quit().unwrap();
    server.shutdown();
}

#[test]
fn stats_reports_typed_counts_in_text_and_binary() {
    let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
    let server = Server::start("127.0.0.1:0", Arc::clone(&cluster)).unwrap();
    cluster.set_add("s1", b"a").unwrap();
    cluster.set_add("s2", b"b").unwrap();
    cluster.counter_incr("c1", 5).unwrap();
    cluster.map_put("m1", b"f", b"v").unwrap();

    // binary STATS: the v7 struct carries the typed-key census
    let mut bin = TcpClient::connect(server.addr(), Actor::client(3)).unwrap();
    let stats = bin.stats().unwrap();
    assert_eq!(stats.sets, 2, "sets");
    assert_eq!(stats.counters, 1, "counters");
    assert_eq!(stats.maps, 1, "maps");
    bin.quit().unwrap();

    // text STATS: same numbers on the human-readable line
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    stream.write_all(b"STATS\n").unwrap();
    let mut line = String::new();
    std::io::BufRead::read_line(&mut reader, &mut line).unwrap();
    assert!(line.contains("sets=2"), "{line}");
    assert!(line.contains("counters=1"), "{line}");
    assert!(line.contains("maps=1"), "{line}");
    server.shutdown();
}
