//! WAL recovery fuzz: replay must never panic, must recover **exactly
//! the longest valid record prefix**, and must **report** (not silently
//! drop) every discarded byte — for every truncation point and under
//! random byte corruption.
//!
//! Strategy: build a log of known records, snapshot the pristine segment
//! bytes, compute the record boundaries independently (re-parsing the
//! frame format in this test, so a framing bug can't hide by agreeing
//! with itself), then sweep:
//!
//! 1. **truncation sweep** — cut the segment at *every* byte offset;
//! 2. **corruption sweep** — XOR one byte at seeded random offsets;
//! 3. **multi-segment corruption** — corrupt a middle segment and check
//!    later segments are discarded (the prefix rule is log-global, not
//!    per-file).
//!
//! Every failing seed prints in the uniform `testkit::soak` format.

use std::path::{Path, PathBuf};

use dvvstore::clocks::encoding::get_varint;
use dvvstore::store::wal::{crc32, FsyncPolicy, ShardWal, WalOptions, SEGMENT_MAGIC};
use dvvstore::testkit::{run_seeded, soak_seeds, temp_dir, Rng};

/// Deterministic record payloads (the shard-log layer is
/// mechanism-agnostic: payload bytes in, payload bytes out).
fn payloads(count: usize) -> Vec<Vec<u8>> {
    (0..count)
        .map(|i| {
            let len = (i * 7) % 23 + 1;
            (0..len).map(|j| ((i * 31 + j * 11) % 251) as u8).collect()
        })
        .collect()
}

/// Build a fresh single-segment log holding `records`.
fn build_log(dir: &Path, records: &[Vec<u8>]) {
    let opts = WalOptions { fsync: FsyncPolicy::Never, ..Default::default() };
    let (mut wal, report) = ShardWal::open(dir, opts, |_| Ok(())).unwrap();
    assert_eq!(report.records, 0);
    for p in records {
        wal.append(p).unwrap();
    }
    wal.sync().unwrap();
}

/// Replay a log dir, collecting payloads (panics here = test failure,
/// which is the point: the property is "replay never panics").
fn replay(dir: &Path) -> (Vec<Vec<u8>>, dvvstore::store::RecoveryReport) {
    let opts = WalOptions { fsync: FsyncPolicy::Never, ..Default::default() };
    let mut seen = Vec::new();
    let (_, report) = ShardWal::open(dir, opts, |payload| {
        seen.push(payload.to_vec());
        Ok(())
    })
    .unwrap();
    (seen, report)
}

/// Independent re-parse of a segment's record boundaries: offsets where
/// each record starts, plus the end offset of the last valid record.
fn record_starts(bytes: &[u8]) -> Vec<usize> {
    assert_eq!(&bytes[..8], &SEGMENT_MAGIC, "fixture segment is intact");
    let mut starts = Vec::new();
    let mut pos = 8usize;
    while pos < bytes.len() {
        starts.push(pos);
        let mut p = pos;
        let len = get_varint(bytes, &mut p).unwrap() as usize;
        let crc = u32::from_le_bytes(bytes[p..p + 4].try_into().unwrap());
        assert_eq!(crc, crc32(&bytes[p + 4..p + 4 + len]), "fixture record intact");
        pos = p + 4 + len;
    }
    starts.push(bytes.len());
    starts
}

fn segment0(dir: &Path) -> PathBuf {
    dir.join("segment-00000000.wal")
}

#[test]
fn truncation_sweep_recovers_exactly_the_valid_prefix() {
    let records = payloads(24);
    let pristine_dir = temp_dir("walfuzz-pristine");
    build_log(&pristine_dir, &records);
    let pristine = std::fs::read(segment0(&pristine_dir)).unwrap();
    let starts = record_starts(&pristine);

    let work = temp_dir("walfuzz-trunc");
    for cut in 0..=pristine.len() {
        // fresh dir per cut: recovery mutates (truncates) the file
        let dir = work.join(format!("cut-{cut}"));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(segment0(&dir), &pristine[..cut]).unwrap();

        let (seen, report) = replay(&dir);
        // exactly the records wholly inside the cut survive
        let n_expected = starts[..starts.len() - 1]
            .iter()
            .zip(starts[1..].iter())
            .filter(|(_, &end)| end <= cut)
            .count();
        assert_eq!(
            seen.len(),
            n_expected,
            "cut at {cut}: longest valid prefix is {n_expected} records"
        );
        assert_eq!(seen, records[..n_expected], "cut at {cut}: prefix content");
        // every byte past the prefix is accounted for, never silent:
        // a cut inside the magic discards the whole (sub-8-byte) file;
        // past it, everything after the last whole record
        let expected_discard = if cut < SEGMENT_MAGIC.len() {
            cut as u64
        } else {
            (cut - starts[n_expected].min(cut)) as u64
        };
        assert_eq!(
            report.discarded_bytes, expected_discard,
            "cut at {cut}: discarded bytes reported"
        );
        // recovery is idempotent: a second open is clean and identical
        let (seen2, report2) = replay(&dir);
        assert_eq!(seen2, seen, "cut at {cut}: reopen stable");
        assert_eq!(report2.discarded_bytes, 0, "cut at {cut}: reopen clean");
        std::fs::remove_dir_all(&dir).unwrap();
    }
    std::fs::remove_dir_all(&work).unwrap();
    std::fs::remove_dir_all(&pristine_dir).unwrap();
}

#[test]
fn random_corruption_never_panics_and_reports_discards() {
    let records = payloads(24);
    let pristine_dir = temp_dir("walfuzz-corrupt-pristine");
    build_log(&pristine_dir, &records);
    let pristine = std::fs::read(segment0(&pristine_dir)).unwrap();
    let starts = record_starts(&pristine);
    std::fs::remove_dir_all(&pristine_dir).unwrap();

    let seeds = soak_seeds(&[11, 22, 33], "WAL_ITERS");
    run_seeded("wal_random_corruption", &seeds, |seed| {
        let mut rng = Rng::new(seed);
        for case in 0..40 {
            let at = rng.below(pristine.len() as u64) as usize;
            let dir = temp_dir("walfuzz-corrupt");
            let mut bytes = pristine.clone();
            bytes[at] ^= (1 + rng.below(255)) as u8; // guaranteed different
            std::fs::write(segment0(&dir), &bytes).unwrap();

            let (seen, report) = replay(&dir);
            if at < SEGMENT_MAGIC.len() {
                // damaged magic: the whole segment is untrusted
                assert!(seen.is_empty(), "seed {seed} case {case}: magic hit at {at}");
                assert_eq!(report.discarded_bytes, bytes.len() as u64);
            } else {
                // the record containing `at` (and everything after) is
                // cut; records strictly before it replay intact
                let victim = (0..starts.len() - 1)
                    .find(|&i| (starts[i]..starts[i + 1]).contains(&at))
                    .expect("offset inside some record");
                assert_eq!(
                    seen.len(),
                    victim,
                    "seed {seed} case {case}: corrupt byte {at} cuts record {victim}"
                );
                assert_eq!(seen, records[..victim], "seed {seed} case {case}: prefix content");
                assert!(report.truncated, "seed {seed} case {case}: discard reported");
                assert_eq!(
                    report.discarded_bytes,
                    (bytes.len() - starts[victim]) as u64,
                    "seed {seed} case {case}: discarded byte count"
                );
            }
            // replay after recovery is clean (idempotent truncation)
            let (_, report2) = replay(&dir);
            assert!(!report2.truncated, "seed {seed} case {case}: reopen clean");
            std::fs::remove_dir_all(&dir).unwrap();
        }
    });
}

#[test]
fn corruption_in_an_early_segment_discards_all_later_segments() {
    let dir = temp_dir("walfuzz-multiseg");
    let opts = WalOptions { segment_bytes: 128, fsync: FsyncPolicy::Never };
    let records = payloads(30);
    {
        let (mut wal, _) = ShardWal::open(&dir, opts, |_| Ok(())).unwrap();
        for p in &records {
            wal.append(p).unwrap();
            if wal.needs_roll() {
                wal.roll(None).unwrap(); // plain roll: preserve history
            }
        }
        wal.sync().unwrap();
    }
    let mut segs: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .collect();
    segs.sort();
    assert!(segs.len() >= 3, "fixture produced {} segments", segs.len());

    // count records in the segments before the victim
    let victim_idx = 1;
    let mut survivors = 0usize;
    for seg in &segs[..victim_idx] {
        let bytes = std::fs::read(seg).unwrap();
        survivors += record_starts(&bytes).len() - 1;
    }
    // corrupt a byte inside the victim's *first* record (second byte of
    // its frame: length varint or CRC, either way the record dies)
    let mut bytes = std::fs::read(&segs[victim_idx]).unwrap();
    let at = record_starts(&bytes)[0] + 1;
    bytes[at] ^= 0xFF;
    std::fs::write(&segs[victim_idx], &bytes).unwrap();

    let opts_reopen = WalOptions { segment_bytes: 1 << 20, fsync: FsyncPolicy::Never };
    let mut seen = Vec::new();
    let (_, report) = ShardWal::open(&dir, opts_reopen, |p| {
        seen.push(p.to_vec());
        Ok(())
    })
    .unwrap();
    assert_eq!(seen.len(), survivors, "only pre-victim segments replay");
    assert_eq!(seen, records[..survivors], "prefix content");
    assert!(report.truncated);
    assert!(
        report.discarded_bytes > 0,
        "victim tail and every later segment are reported"
    );
    let remaining: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .collect();
    assert_eq!(remaining.len(), victim_idx + 1, "later segments deleted");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn payloads_rejected_by_the_codec_cut_the_prefix_too() {
    // a record whose bytes are intact (CRC passes) but whose *payload*
    // the state codec rejects must also end the valid prefix — the
    // "corrupt" axis recovery can only detect by decoding
    let dir = temp_dir("walfuzz-codec");
    let opts = WalOptions { fsync: FsyncPolicy::Never, ..Default::default() };
    {
        let (mut wal, _) = ShardWal::open(&dir, opts, |_| Ok(())).unwrap();
        for i in 0..6u8 {
            wal.append(&[i; 4]).unwrap();
        }
        wal.sync().unwrap();
    }
    let mut seen = 0;
    let (_, report) = ShardWal::open(&dir, opts, |payload| {
        if payload[0] == 3 {
            return Err(dvvstore::Error::Codec("synthetic decode failure".into()));
        }
        seen += 1;
        Ok(())
    })
    .unwrap();
    assert_eq!(seen, 3, "records before the rejected one replay");
    assert!(report.truncated);
    assert_eq!(report.records, 3);
    std::fs::remove_dir_all(&dir).unwrap();
}
