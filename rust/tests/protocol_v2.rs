//! Hardening tests for the binary wire protocol v2: negotiation,
//! fuzz-style malformed-frame rejection (truncated frames, bad magic,
//! oversized lengths, version skew), and graceful degradation of a live
//! server — mirroring the `hex_decode` hardening of the text protocol.
//! Remote bytes must never panic a connection thread; the server must
//! keep serving well-formed clients after every abuse.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use dvvstore::api::{CausalCtx, KvClient, TcpClient};
use dvvstore::clocks::Actor;
use dvvstore::server::protocol::{self, BinRequest};
use dvvstore::server::tcp::Server;
use dvvstore::server::LocalCluster;
use dvvstore::testkit::prop::{forall, from_fn, Config};
use dvvstore::testkit::Rng;

fn server() -> (Server, Arc<LocalCluster>) {
    let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
    let server = Server::start("127.0.0.1:0", cluster.clone()).unwrap();
    (server, cluster)
}

// -------------------------------------------------------------------
// pure decoder fuzzing: malformed input errors, never panics
// -------------------------------------------------------------------

#[test]
fn prop_random_payloads_never_panic_decoders() {
    forall(
        &Config::default().cases(300),
        from_fn(|rng: &mut Rng, size| {
            let len = rng.below(size as u64 + 2) as usize;
            let opcode = rng.below(256) as u8;
            let payload: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            (opcode, payload)
        }),
        |(opcode, payload)| {
            // the property is simply "no panic, Ok or Err"
            let _ = protocol::decode_bin_request(*opcode, payload);
            let _ = protocol::decode_values(payload);
            let _ = protocol::decode_put_ok(payload);
            let _ = protocol::decode_stats_reply(payload);
            let _ = protocol::decode_dot_reply(payload);
            let _ = protocol::decode_dots_reply(payload);
            let _ = protocol::decode_members_reply(payload);
            let _ = protocol::decode_count_reply(payload);
            let _ = protocol::decode_field_reply(payload);
            let _ = CausalCtx::decode(payload);
            true
        },
    );
}

#[test]
fn prop_truncated_typed_frames_are_rejected() {
    forall(
        &Config::default().cases(150),
        from_fn(|rng: &mut Rng, size| {
            let key: String = (0..rng.below(8) + 1).map(|_| 'k').collect();
            let blob: Vec<u8> =
                (0..rng.below(size as u64 + 1)).map(|_| rng.below(256) as u8).collect();
            let req = match rng.below(5) {
                0 => BinRequest::SAdd { key, elem: blob },
                1 => BinRequest::SRem { key, elem: blob },
                2 => BinRequest::Incr { key, by: rng.next_u64() as i64 },
                3 => BinRequest::MPut {
                    key,
                    field: blob.clone(),
                    value: blob,
                },
                _ => BinRequest::MGet { key, field: blob },
            };
            let (opcode, payload) = protocol::encode_bin_request(&req);
            let cut = rng.below(payload.len() as u64) as usize;
            (opcode, payload, cut)
        }),
        |(opcode, payload, cut)| {
            // any strict prefix must fail to decode
            protocol::decode_bin_request(*opcode, &payload[..*cut]).is_err()
        },
    );
}

#[test]
fn prop_truncated_put_frames_are_rejected() {
    forall(
        &Config::default().cases(100),
        from_fn(|rng: &mut Rng, size| {
            let key: String = (0..rng.below(8) + 1).map(|_| 'k').collect();
            let value: Vec<u8> = (0..rng.below(size as u64 + 1)).map(|_| rng.below(256) as u8).collect();
            let token = CausalCtx::new(
                (0..rng.below(6)).map(|_| rng.below(256) as u8).collect(),
                (0..rng.below(4)).map(|_| rng.next_u64()).collect(),
            )
            .encode();
            let (_, payload) = protocol::encode_bin_request(&BinRequest::Put {
                key,
                value,
                actor: rng.below(1 << 21) as u32,
                ctx_token: token,
            });
            let cut = rng.below(payload.len() as u64) as usize;
            (payload, cut)
        }),
        |(payload, cut)| {
            // any strict prefix must fail to decode
            protocol::decode_bin_request(protocol::OP_PUT, &payload[..*cut]).is_err()
        },
    );
}

// -------------------------------------------------------------------
// live server: abuse one connection, then prove the server still works
// -------------------------------------------------------------------

/// A well-formed v2 client still works against the server.
fn assert_server_healthy(addr: std::net::SocketAddr) {
    let mut c = TcpClient::connect(addr, Actor::client(9)).unwrap();
    let reply = c.put("healthy", b"ok".to_vec(), None).unwrap();
    assert!(reply.id > 0);
    assert_eq!(c.get("healthy").unwrap().values, vec![b"ok".to_vec()]);
    c.quit().unwrap();
}

#[test]
fn version_skew_is_rejected_cleanly() {
    let (server, _cluster) = server();
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.write_all(&protocol::MAGIC).unwrap();
    stream.write_all(&[99, b'\n']).unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let (opcode, payload) = protocol::read_frame(&mut reader).unwrap();
    assert_eq!(opcode, protocol::OP_ERR);
    let msg = String::from_utf8_lossy(&payload).into_owned();
    assert!(msg.contains("unsupported protocol version 99"), "{msg}");
    // the server closes after version skew
    let mut rest = Vec::new();
    assert_eq!(reader.read_to_end(&mut rest).unwrap(), 0);
    assert_server_healthy(server.addr());
    server.shutdown();
}

#[test]
fn connect_helper_surfaces_version_skew() {
    // drive the negotiation failure through the client helper path too:
    // a raw socket pretending to be a v3 client gets the server's error
    let (server, _cluster) = server();
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.write_all(&protocol::MAGIC).unwrap();
    stream.write_all(&[protocol::VERSION + 1, b'\n']).unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let (opcode, _) = protocol::read_frame(&mut reader).unwrap();
    assert_eq!(opcode, protocol::OP_ERR);
    server.shutdown();
}

#[test]
fn stale_client_version_is_rejected() {
    // the typed opcodes changed the wire surface; a v6 client must be
    // turned away at negotiation, not misparsed mid-stream
    let (server, _cluster) = server();
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.write_all(&protocol::MAGIC).unwrap();
    stream.write_all(&[protocol::VERSION - 1, b'\n']).unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let (opcode, payload) = protocol::read_frame(&mut reader).unwrap();
    assert_eq!(opcode, protocol::OP_ERR);
    assert!(
        String::from_utf8_lossy(&payload).contains("unsupported protocol version"),
        "{payload:?}"
    );
    assert_server_healthy(server.addr());
    server.shutdown();
}

#[test]
fn truncated_typed_payloads_over_the_wire_err_and_keep_connection() {
    // every typed opcode, fed an intact frame holding a truncated
    // payload, answers ERR without dropping the connection or the server
    let (server, _cluster) = server();
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.write_all(&protocol::MAGIC).unwrap();
    stream.write_all(&[protocol::VERSION, b'\n']).unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let (opcode, _) = protocol::read_frame(&mut reader).unwrap();
    assert_eq!(opcode, protocol::OP_HELLO_ACK);

    let full_frames = [
        protocol::encode_bin_request(&BinRequest::SAdd {
            key: "set".into(),
            elem: b"elem".to_vec(),
        }),
        protocol::encode_bin_request(&BinRequest::SRem {
            key: "set".into(),
            elem: b"elem".to_vec(),
        }),
        protocol::encode_bin_request(&BinRequest::Incr { key: "ctr".into(), by: -9 }),
        protocol::encode_bin_request(&BinRequest::MPut {
            key: "map".into(),
            field: b"f".to_vec(),
            value: b"v".to_vec(),
        }),
        protocol::encode_bin_request(&BinRequest::MGet {
            key: "map".into(),
            field: b"f".to_vec(),
        }),
    ];
    for (op, payload) in &full_frames {
        for cut in [0, 1, payload.len().saturating_sub(1)] {
            protocol::write_frame(&mut stream, *op, &payload[..cut]).unwrap();
            let (opcode, _) = protocol::read_frame(&mut reader).unwrap();
            assert_eq!(opcode, protocol::OP_ERR, "op {op:#04x} cut {cut} must ERR");
        }
    }

    // the abused connection still executes a real typed op end to end
    let (op, payload) = protocol::encode_bin_request(&BinRequest::SAdd {
        key: "survivor".into(),
        elem: b"x".to_vec(),
    });
    protocol::write_frame(&mut stream, op, &payload).unwrap();
    let (opcode, payload) = protocol::read_frame(&mut reader).unwrap();
    assert_eq!(opcode, protocol::OP_DOT_REPLY);
    protocol::decode_dot_reply(&payload).unwrap();
    assert_server_healthy(server.addr());
    server.shutdown();
}

#[test]
fn oversized_length_header_errors_and_closes() {
    let (server, _cluster) = server();
    let mut c = TcpClient::connect(server.addr(), Actor::client(0)).unwrap();
    c.put("k", b"v".to_vec(), None).unwrap();
    // now abuse a fresh connection with a length far past MAX_FRAME_LEN
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.write_all(&protocol::MAGIC).unwrap();
    stream.write_all(&[protocol::VERSION, b'\n']).unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let (opcode, _) = protocol::read_frame(&mut reader).unwrap();
    assert_eq!(opcode, protocol::OP_HELLO_ACK);
    stream.write_all(&u32::MAX.to_be_bytes()).unwrap();
    let (opcode, payload) = protocol::read_frame(&mut reader).unwrap();
    assert_eq!(opcode, protocol::OP_ERR);
    assert!(String::from_utf8_lossy(&payload).contains("oversized frame"));
    let mut rest = Vec::new();
    assert_eq!(reader.read_to_end(&mut rest).unwrap(), 0, "connection dropped");
    assert_server_healthy(server.addr());
    server.shutdown();
}

#[test]
fn zero_length_frame_errors_and_closes() {
    let (server, _cluster) = server();
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.write_all(&protocol::MAGIC).unwrap();
    stream.write_all(&[protocol::VERSION, b'\n']).unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let (opcode, _) = protocol::read_frame(&mut reader).unwrap();
    assert_eq!(opcode, protocol::OP_HELLO_ACK);
    stream.write_all(&0u32.to_be_bytes()).unwrap();
    let (opcode, _) = protocol::read_frame(&mut reader).unwrap();
    assert_eq!(opcode, protocol::OP_ERR);
    assert_server_healthy(server.addr());
    server.shutdown();
}

#[test]
fn truncated_frame_on_hangup_is_tolerated() {
    let (server, _cluster) = server();
    {
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.write_all(&protocol::MAGIC).unwrap();
        stream.write_all(&[protocol::VERSION, b'\n']).unwrap();
        let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
        let (opcode, _) = protocol::read_frame(&mut reader).unwrap();
        assert_eq!(opcode, protocol::OP_HELLO_ACK);
        // promise 100 bytes, send 3, hang up
        stream.write_all(&100u32.to_be_bytes()).unwrap();
        stream.write_all(&[protocol::OP_GET, b'k', b'e']).unwrap();
    } // drop = disconnect
    assert_server_healthy(server.addr());
    server.shutdown();
}

#[test]
fn malformed_payload_in_intact_frame_keeps_connection_usable() {
    let (server, _cluster) = server();
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.write_all(&protocol::MAGIC).unwrap();
    stream.write_all(&[protocol::VERSION, b'\n']).unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let (opcode, _) = protocol::read_frame(&mut reader).unwrap();
    assert_eq!(opcode, protocol::OP_HELLO_ACK);

    // unknown opcode: ERR, connection lives
    protocol::write_frame(&mut stream, 0x66, b"junk").unwrap();
    let (opcode, _) = protocol::read_frame(&mut reader).unwrap();
    assert_eq!(opcode, protocol::OP_ERR);

    // truncated PUT payload inside a well-formed frame: ERR, lives
    protocol::write_frame(&mut stream, protocol::OP_PUT, &[5, b'a']).unwrap();
    let (opcode, _) = protocol::read_frame(&mut reader).unwrap();
    assert_eq!(opcode, protocol::OP_ERR);

    // the same connection then serves a real request
    let (op, payload) = protocol::encode_bin_request(&BinRequest::Get { key: "x".into() });
    protocol::write_frame(&mut stream, op, &payload).unwrap();
    let (opcode, _) = protocol::read_frame(&mut reader).unwrap();
    assert_eq!(opcode, protocol::OP_VALUES);

    server.shutdown();
}

#[test]
fn bad_magic_falls_back_to_text_protocol() {
    let (server, _cluster) = server();
    // a near-miss magic ("DVV3…") must be answered by the text parser
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.write_all(b"DVV3 x\n").unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    std::io::BufRead::read_line(&mut reader, &mut line).unwrap();
    assert!(line.starts_with("ERR "), "{line}");
    // and the same connection keeps speaking text
    stream.write_all(b"STATS\n").unwrap();
    line.clear();
    std::io::BufRead::read_line(&mut reader, &mut line).unwrap();
    assert!(line.starts_with("STATS nodes=3"), "{line}");
    server.shutdown();
}

#[test]
fn binary_and_text_clients_share_one_store() {
    let (server, _cluster) = server();
    // binary client writes with a context chain
    let mut bin = TcpClient::connect(server.addr(), Actor::client(1)).unwrap();
    bin.put("shared", b"from-binary".to_vec(), None).unwrap();

    // text client reads the same key (hex protocol)
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    stream.write_all(b"GET shared\n").unwrap();
    let mut header = String::new();
    std::io::BufRead::read_line(&mut reader, &mut header).unwrap();
    assert!(header.starts_with("VALUES 1 "), "{header}");
    let mut value_line = String::new();
    std::io::BufRead::read_line(&mut reader, &mut value_line).unwrap();
    let hex = value_line.trim_end().strip_prefix("VALUE ").unwrap().to_string();
    assert_eq!(
        dvvstore::server::protocol::hex_decode(&hex).unwrap(),
        b"from-binary".to_vec()
    );

    // admin over the binary connection drives the same fabric
    bin.admin("FAULT DELAY 150").unwrap();
    let stats = bin.stats().unwrap();
    assert_eq!(stats.nodes, 3, "nodes");
    bin.admin("HEAL").unwrap();
    bin.quit().unwrap();
    server.shutdown();
}
