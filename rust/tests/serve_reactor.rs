//! Reactor serve-loop contracts: pipelining (N in-flight binary frames
//! on one connection, N replies in request order), serialized
//! per-connection execution (a pipelined read observes the write before
//! it), backpressure past the in-flight window (deadlock-free even for
//! batches past the socket buffers), framing errors and QUIT in
//! pipeline position, hostile frame headers across many connections,
//! deterministic shutdown, and reactor/threaded equivalence on the
//! same wire bytes.

use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use dvvstore::api::{KvClient, TcpClient};
use dvvstore::clocks::Actor;
use dvvstore::server::protocol::{self, BinRequest};
use dvvstore::server::tcp::{ServeMode, ServeOptions, Server};
use dvvstore::server::LocalCluster;

const MODES: [ServeMode; 2] = [ServeMode::Reactor { workers: 2 }, ServeMode::Threaded];

fn start(mode: ServeMode) -> (Server, Arc<LocalCluster>) {
    let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
    let server =
        Server::start_with("127.0.0.1:0", Arc::clone(&cluster), ServeOptions { mode }).unwrap();
    (server, cluster)
}

/// Raw protocol-v2 socket: negotiate hello, return (reader, writer).
fn raw_v2(server: &Server) -> (BufReader<TcpStream>, TcpStream) {
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_nodelay(true).ok();
    stream.write_all(&protocol::MAGIC).unwrap();
    stream.write_all(&[protocol::VERSION, b'\n']).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let (opcode, payload) = protocol::read_frame(&mut reader).unwrap();
    assert_eq!(opcode, protocol::OP_HELLO_ACK);
    assert_eq!(payload, [protocol::VERSION]);
    (reader, stream)
}

// -------------------------------------------------------------------
// the pipelining contract
// -------------------------------------------------------------------

#[test]
fn pipelined_requests_reply_in_request_order() {
    let (server, _cluster) = start(ServeMode::Reactor { workers: 2 });
    let mut client = TcpClient::connect(server.addr(), Actor::client(1)).unwrap();

    // N PUTs to N distinct keys in one batch write, then N pipelined
    // GETs: reply i must carry exactly the value written by request i.
    const N: usize = 48;
    let puts: Vec<BinRequest> = (0..N)
        .map(|i| BinRequest::Put {
            key: format!("pipe-{i}"),
            value: format!("value-{i}").into_bytes(),
            actor: 1,
            ctx_token: Vec::new(),
        })
        .collect();
    for (i, reply) in client.pipeline(&puts).unwrap().into_iter().enumerate() {
        assert_eq!(reply.0, protocol::OP_PUT_OK, "PUT {i} failed: {:?}", reply);
    }

    let keys: Vec<String> = (0..N).map(|i| format!("pipe-{i}")).collect();
    let key_refs: Vec<&str> = keys.iter().map(String::as_str).collect();
    let replies = client.pipeline_get(&key_refs).unwrap();
    assert_eq!(replies.len(), N);
    for (i, reply) in replies.iter().enumerate() {
        assert_eq!(
            reply.values,
            vec![format!("value-{i}").into_bytes()],
            "GET reply {i} out of order"
        );
    }
    client.quit().unwrap();
    server.shutdown();
}

#[test]
fn deep_pipeline_survives_backpressure_window() {
    // 500 requests on one connection — far past the reactor's 64-deep
    // in-flight window, so parsing must stall and resume off the
    // completion path (no POLLIN ever re-announces bytes already read)
    let (server, _cluster) = start(ServeMode::Reactor { workers: 3 });
    let mut client = TcpClient::connect(server.addr(), Actor::client(7)).unwrap();
    client.put("deep", b"v".to_vec(), None).unwrap();

    const N: usize = 500;
    let reqs: Vec<BinRequest> =
        (0..N).map(|_| BinRequest::Get { key: "deep".to_string() }).collect();
    let replies = client.pipeline(&reqs).unwrap();
    assert_eq!(replies.len(), N);
    for (i, (opcode, payload)) in replies.into_iter().enumerate() {
        assert_eq!(opcode, protocol::OP_VALUES, "reply {i}");
        let (values, _) = protocol::decode_values(&payload).unwrap();
        assert_eq!(values, vec![b"v".to_vec()], "reply {i} wrong value");
    }
    client.quit().unwrap();
    server.shutdown();
}

#[test]
fn pipelined_read_observes_the_write_before_it() {
    // the regression this guards: pipelined requests from one
    // connection used to execute concurrently on the worker pool (only
    // the replies were reordered), so with 2+ workers a GET pipelined
    // right after a PUT could pop on another worker, run first, and
    // answer VALUES 0. Execution is now serialized per connection.
    let (server, _cluster) = start(ServeMode::Reactor { workers: 4 });
    let mut client = TcpClient::connect(server.addr(), Actor::client(21)).unwrap();

    const ROUNDS: usize = 32;
    let mut reqs = Vec::with_capacity(2 * ROUNDS);
    for i in 0..ROUNDS {
        reqs.push(BinRequest::Put {
            key: format!("ryw-{i}"),
            value: format!("v{i}").into_bytes(),
            actor: 21,
            ctx_token: Vec::new(),
        });
        reqs.push(BinRequest::Get { key: format!("ryw-{i}") });
    }
    let replies = client.pipeline(&reqs).unwrap();
    assert_eq!(replies.len(), 2 * ROUNDS);
    for (i, pair) in replies.chunks(2).enumerate() {
        assert_eq!(pair[0].0, protocol::OP_PUT_OK, "PUT {i}");
        assert_eq!(pair[1].0, protocol::OP_VALUES, "GET {i}");
        let (values, _) = protocol::decode_values(&pair[1].1).unwrap();
        assert_eq!(
            values,
            vec![format!("v{i}").into_bytes()],
            "GET {i} executed before the PUT pipelined ahead of it"
        );
    }
    client.quit().unwrap();
    server.shutdown();
}

#[test]
fn pipeline_batch_larger_than_socket_buffers_does_not_deadlock() {
    // the regression this guards: TcpClient::pipeline used to write the
    // whole batch before reading any reply; once the server's reply
    // backlog passed its write-backlog bound it stopped reading, and a
    // batch whose unsent request bytes no longer fit the socket buffers
    // deadlocked both sides. Sized so the reply bytes (48 × 512 KiB)
    // and the request bytes (128 × 512 KiB) both dwarf any auto-tuned
    // loopback socket buffer.
    for mode in MODES {
        let (server, _cluster) = start(mode);
        let addr = server.addr();
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        let worker = std::thread::spawn(move || {
            let mut client = TcpClient::connect(addr, Actor::client(9)).unwrap();
            let value = vec![0xab_u8; 512 * 1024];
            client.put("big", value.clone(), None).unwrap();
            let mut reqs: Vec<BinRequest> =
                (0..48).map(|_| BinRequest::Get { key: "big".to_string() }).collect();
            for i in 0..128 {
                reqs.push(BinRequest::Put {
                    key: format!("bulk-{i}"),
                    value: value.clone(),
                    actor: 9,
                    ctx_token: Vec::new(),
                });
            }
            let replies = client.pipeline(&reqs).unwrap();
            assert_eq!(replies.len(), reqs.len());
            for (i, (opcode, _)) in replies.iter().enumerate() {
                let want = if i < 48 { protocol::OP_VALUES } else { protocol::OP_PUT_OK };
                assert_eq!(*opcode, want, "reply {i}");
            }
            client.quit().unwrap();
            done_tx.send(()).unwrap();
        });
        done_rx
            .recv_timeout(std::time::Duration::from_secs(60))
            .expect("pipeline deadlocked against the server's read-refusal backpressure");
        worker.join().unwrap();
        server.shutdown();
    }
}

#[test]
fn text_lines_pipeline_through_one_write() {
    for mode in MODES {
        let (server, _cluster) = start(mode);
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        // every command in a single segment; replies must come back in
        // line order on both serve loops
        stream.write_all(b"PUT a 61\nPUT b 62\nGET a\nGET b\nQUIT\n").unwrap();
        let mut all = String::new();
        BufReader::new(stream).read_to_string(&mut all).unwrap();
        // PUT → "OK"; GET → "VALUES <n> <ctx>" + one "VALUE <hex>" line
        let lines: Vec<&str> = all.lines().collect();
        assert_eq!(lines.len(), 7, "mode {mode:?}: {all:?}");
        assert_eq!(lines[0], "OK", "mode {mode:?}: {all:?}");
        assert_eq!(lines[1], "OK", "mode {mode:?}: {all:?}");
        assert!(lines[2].starts_with("VALUES 1 "), "mode {mode:?}: {all:?}");
        assert_eq!(lines[3], "VALUE 61", "mode {mode:?}: {all:?}");
        assert!(lines[4].starts_with("VALUES 1 "), "mode {mode:?}: {all:?}");
        assert_eq!(lines[5], "VALUE 62", "mode {mode:?}: {all:?}");
        assert_eq!(lines[6], "BYE", "mode {mode:?}: {all:?}");
        server.shutdown();
    }
}

// -------------------------------------------------------------------
// errors and close in pipeline position
// -------------------------------------------------------------------

#[test]
fn framing_error_mid_pipeline_answers_in_position_then_closes() {
    let (server, _cluster) = start(ServeMode::Reactor { workers: 2 });
    let (mut reader, mut stream) = raw_v2(&server);

    // frame 1: honest GET; frame 2: zero-length header (framing-level
    // poison — the stream cannot be resynchronized past it)
    let (opcode, payload) =
        protocol::encode_bin_request(&BinRequest::Get { key: "k".to_string() });
    let mut batch = Vec::new();
    protocol::write_frame(&mut batch, opcode, &payload).unwrap();
    batch.extend_from_slice(&[0, 0, 0, 0]);
    stream.write_all(&batch).unwrap();

    let first = protocol::read_frame(&mut reader).unwrap();
    assert_eq!(first.0, protocol::OP_VALUES, "honest frame answered first");
    let second = protocol::read_frame(&mut reader).unwrap();
    assert_eq!(second.0, protocol::OP_ERR, "framing error answered in position");
    // ... and nothing after: server closed the connection
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "no bytes after the framing ERR: {rest:?}");
    server.shutdown();
}

#[test]
fn malformed_payload_mid_pipeline_errs_but_connection_survives() {
    let (server, _cluster) = start(ServeMode::Reactor { workers: 2 });
    let (mut reader, mut stream) = raw_v2(&server);

    // GET with a truncated payload (length byte promises more key than
    // follows) between two honest GETs — all three answered, in order,
    // connection intact
    let (op_get, honest) = protocol::encode_bin_request(&BinRequest::Get { key: "k".to_string() });
    let mut batch = Vec::new();
    protocol::write_frame(&mut batch, op_get, &honest).unwrap();
    protocol::write_frame(&mut batch, op_get, &[9, b'x']).unwrap();
    protocol::write_frame(&mut batch, op_get, &honest).unwrap();
    stream.write_all(&batch).unwrap();

    assert_eq!(protocol::read_frame(&mut reader).unwrap().0, protocol::OP_VALUES);
    assert_eq!(protocol::read_frame(&mut reader).unwrap().0, protocol::OP_ERR);
    assert_eq!(protocol::read_frame(&mut reader).unwrap().0, protocol::OP_VALUES);
    // still serviceable
    let (op_stats, stats) = protocol::encode_bin_request(&BinRequest::Stats);
    protocol::write_frame(&mut stream, op_stats, &stats).unwrap();
    assert_eq!(protocol::read_frame(&mut reader).unwrap().0, protocol::OP_STATS_REPLY);
    server.shutdown();
}

#[test]
fn quit_mid_pipeline_replies_then_bye_then_eof() {
    let (server, _cluster) = start(ServeMode::Reactor { workers: 2 });
    let (mut reader, mut stream) = raw_v2(&server);

    // GET, QUIT, GET in one write: the GET before the QUIT is answered,
    // the QUIT gets its BYE, the GET after it gets nothing
    let (op_get, get) = protocol::encode_bin_request(&BinRequest::Get { key: "k".to_string() });
    let (op_quit, quit) = protocol::encode_bin_request(&BinRequest::Quit);
    let mut batch = Vec::new();
    protocol::write_frame(&mut batch, op_get, &get).unwrap();
    protocol::write_frame(&mut batch, op_quit, &quit).unwrap();
    protocol::write_frame(&mut batch, op_get, &get).unwrap();
    stream.write_all(&batch).unwrap();

    assert_eq!(protocol::read_frame(&mut reader).unwrap().0, protocol::OP_VALUES);
    assert_eq!(protocol::read_frame(&mut reader).unwrap().0, protocol::OP_BYE);
    let mut rest = Vec::new();
    reader.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "no replies past the BYE: {rest:?}");
    server.shutdown();
}

// -------------------------------------------------------------------
// hostile input across many connections
// -------------------------------------------------------------------

#[test]
fn hostile_frame_headers_across_many_connections_leave_server_healthy() {
    // each connection claims a max-size frame and never sends the
    // payload; the serve loop must not pre-allocate the claimed 16 MiB
    // (64 connections × 16 MiB would be a GiB of attacker-priced
    // memory), and honest clients must keep working throughout
    for mode in MODES {
        let (server, _cluster) = start(mode);
        let mut hostiles = Vec::new();
        for _ in 0..64 {
            let mut stream = TcpStream::connect(server.addr()).unwrap();
            stream.write_all(&protocol::MAGIC).unwrap();
            stream.write_all(&[protocol::VERSION, b'\n']).unwrap();
            stream.write_all(&protocol::MAX_FRAME_LEN.to_be_bytes()).unwrap();
            hostiles.push(stream); // held open, payload never sent
        }
        let mut client = TcpClient::connect(server.addr(), Actor::client(3)).unwrap();
        client.put("healthy", b"yes".to_vec(), None).unwrap();
        assert_eq!(
            client.get("healthy").unwrap().values,
            vec![b"yes".to_vec()],
            "mode {mode:?}"
        );
        client.quit().unwrap();
        drop(hostiles);
        server.shutdown();
    }
}

// -------------------------------------------------------------------
// deterministic shutdown
// -------------------------------------------------------------------

#[test]
fn shutdown_joins_every_thread_holding_the_cluster() {
    // the bug this guards: detached per-connection workers holding the
    // cluster Arc could outlive shutdown() and still be mid-WAL-write
    // when the caller deletes the data dir
    for mode in MODES {
        let (server, cluster) = start(mode);
        let mut clients: Vec<TcpClient> = (0..4)
            .map(|i| TcpClient::connect(server.addr(), Actor::client(i)).unwrap())
            .collect();
        for (i, c) in clients.iter_mut().enumerate() {
            c.put(&format!("sd-{i}"), vec![i as u8], None).unwrap();
        }
        drop(clients); // sessions die abruptly, no QUIT
        server.shutdown();
        assert_eq!(
            Arc::strong_count(&cluster),
            1,
            "mode {mode:?}: a serve-loop thread outlived shutdown()"
        );
    }
}

// -------------------------------------------------------------------
// reactor and threaded modes speak the same protocol
// -------------------------------------------------------------------

#[test]
fn both_modes_give_identical_answers_to_the_same_session() {
    let run = |mode: ServeMode| {
        let (server, _cluster) = start(mode);
        let mut client = TcpClient::connect(server.addr(), Actor::client(11)).unwrap();
        let mut transcript = Vec::new();
        let put = client.put("eq-key", b"one".to_vec(), None).unwrap();
        transcript.push(format!("put id={}", put.id));
        let ctx = client.get("eq-key").unwrap();
        transcript.push(format!("get {:?}", ctx.values));
        // contextual overwrite, then a sibling-free read
        let put2 = client.put("eq-key", b"two".to_vec(), Some(&ctx.ctx)).unwrap();
        transcript.push(format!("put2 id={}", put2.id));
        transcript.push(format!("get2 {:?}", client.get("eq-key").unwrap().values));
        let stats = client.stats().unwrap();
        transcript.push(format!("nodes={} epoch={}", stats.nodes, stats.epoch));
        client.quit().unwrap();
        server.shutdown();
        transcript
    };
    assert_eq!(
        run(ServeMode::Reactor { workers: 2 }),
        run(ServeMode::Threaded),
        "the two serve loops disagreed on an identical session"
    );
}
