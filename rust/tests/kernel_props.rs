//! Property tests: the §4 kernel conditions and the §5.4 downset
//! invariant hold for every lossless mechanism under randomized client /
//! replication / anti-entropy interleavings (E12).

use dvvstore::clocks::causal_history::CausalHistory;
use dvvstore::clocks::{Actor, LogicalClock};
use dvvstore::kernel::conditions::{check_sync_conditions, is_downset};
use dvvstore::kernel::mechs::{DvvMech, DvvSetMech, HistoryMech};
use dvvstore::kernel::ops::{pairwise_concurrent, sync_sets};
use dvvstore::kernel::{Mechanism, Val, WriteMeta};
use dvvstore::testkit::prop::{forall, from_fn, Config};
use dvvstore::testkit::Rng;

fn arb_history(rng: &mut Rng, actors: u32, max_seq: u64) -> CausalHistory {
    // downset histories (what replicas actually hold)
    CausalHistory::from_events((0..actors).flat_map(|a| {
        let n = rng.below(max_seq + 1);
        (1..=n).map(move |s| dvvstore::clocks::Event::new(Actor::server(a), s))
    }))
}

#[test]
fn sync_conditions_hold_for_random_history_sets() {
    forall(
        &Config::default().cases(150),
        from_fn(|rng, _| {
            let mut mk_set = |rng: &mut Rng| {
                let mut set: Vec<(CausalHistory, u8)> = Vec::new();
                for i in 0..rng.range(0, 4) {
                    dvvstore::kernel::ops::insert_candidate(
                        &mut set,
                        arb_history(rng, 3, 4),
                        i as u8,
                    );
                }
                set
            };
            (mk_set(rng), mk_set(rng))
        }),
        |(s1, s2)| {
            let out = sync_sets(s1, s2);
            check_sync_conditions(s1, s2, &out).is_ok()
        },
    );
}

/// Random client/replica interplay for a mechanism whose clocks expose
/// their causal history; checks downsets + pairwise concurrency (§5.4).
fn run_random_ops<M, H>(mech: M, history_of: H, seed: u64)
where
    M: Mechanism,
    H: Fn(&M::State) -> Vec<CausalHistory>,
{
    let mut rng = Rng::new(seed);
    let nodes = 3usize;
    let mut states: Vec<M::State> = (0..nodes).map(|_| M::State::default()).collect();
    let mut contexts: Vec<M::Context> = vec![M::Context::default(); 5];
    for op in 0..600 {
        let node = rng.below(nodes as u64) as usize;
        let client = rng.below(5) as usize;
        match rng.below(4) {
            0 => contexts[client] = mech.read(&states[node]).1,
            1 => {
                let meta = WriteMeta::basic(Actor::client(client as u32));
                let ctx = contexts[client].clone();
                mech.write(&mut states[node], &ctx, Val::new(op + 1, 0), Actor::server(node as u32), &meta);
            }
            2 => {
                let other = rng.below(nodes as u64) as usize;
                let incoming = states[other].clone();
                mech.merge(&mut states[node], &incoming);
            }
            _ => {
                // read repair: reduce all and push back
                let mut merged = M::State::default();
                for st in &states {
                    mech.merge(&mut merged, st);
                }
                for st in states.iter_mut() {
                    mech.merge(st, &merged);
                }
            }
        }
        for st in &states {
            let hists = history_of(st);
            assert!(is_downset(&hists), "downset violated at op {op}");
            let tagged: Vec<(CausalHistory, ())> =
                hists.iter().cloned().map(|h| (h, ())).collect();
            assert!(
                pairwise_concurrent(&tagged),
                "sibling set not pairwise concurrent at op {op}: {hists:?}"
            );
        }
    }
}

#[test]
fn dvv_random_ops_maintain_invariants() {
    for seed in [1u64, 2, 3] {
        run_random_ops(
            DvvMech,
            |st| st.iter().map(|(d, _)| d.history()).collect(),
            seed,
        );
    }
}

#[test]
fn history_mech_random_ops_maintain_invariants() {
    for seed in [4u64, 5] {
        run_random_ops(
            HistoryMech,
            |st| st.iter().map(|(h, _)| h.clone()).collect(),
            seed,
        );
    }
}

#[test]
fn dvv_and_history_agree_on_survivors() {
    // identical op sequences through both mechanisms end with the same
    // surviving value ids — DVV is a lossless compression of causal
    // histories (the §5 claim)
    for seed in [11u64, 12, 13, 14] {
        let mut rng = Rng::new(seed);
        let dvv = DvvMech;
        let hist = HistoryMech;
        let mut d_states: Vec<<DvvMech as Mechanism>::State> = vec![Vec::new(), Vec::new()];
        let mut h_states: Vec<<HistoryMech as Mechanism>::State> = vec![Vec::new(), Vec::new()];
        let mut d_ctx: Vec<<DvvMech as Mechanism>::Context> = vec![Default::default(); 4];
        let mut h_ctx: Vec<<HistoryMech as Mechanism>::Context> = vec![Default::default(); 4];
        for op in 0..400 {
            let node = rng.below(2) as usize;
            let client = rng.below(4) as usize;
            match rng.below(3) {
                0 => {
                    d_ctx[client] = dvv.read(&d_states[node]).1;
                    h_ctx[client] = hist.read(&h_states[node]).1;
                }
                1 => {
                    let meta = WriteMeta::basic(Actor::client(client as u32));
                    dvv.write(&mut d_states[node], &d_ctx[client].clone(), Val::new(op + 1, 0), Actor::server(node as u32), &meta);
                    hist.write(&mut h_states[node], &h_ctx[client].clone(), Val::new(op + 1, 0), Actor::server(node as u32), &meta);
                }
                _ => {
                    let d_in = d_states[1 - node].clone();
                    dvv.merge(&mut d_states[node], &d_in);
                    let h_in = h_states[1 - node].clone();
                    hist.merge(&mut h_states[node], &h_in);
                }
            }
            for node in 0..2 {
                let mut dv: Vec<u64> = dvv.values(&d_states[node]).iter().map(|v| v.id).collect();
                let mut hv: Vec<u64> = hist.values(&h_states[node]).iter().map(|v| v.id).collect();
                dv.sort_unstable();
                hv.sort_unstable();
                assert_eq!(dv, hv, "divergence at op {op} node {node} (seed {seed})");
            }
        }
    }
}

#[test]
fn dvvset_agrees_with_dvv_on_survivors() {
    for seed in [21u64, 22] {
        let mut rng = Rng::new(seed);
        let dvv = DvvMech;
        let dset = DvvSetMech;
        let mut a: <DvvMech as Mechanism>::State = Vec::new();
        let mut b: <DvvSetMech as Mechanism>::State = Default::default();
        let mut ctx_a: Vec<<DvvMech as Mechanism>::Context> = vec![Default::default(); 3];
        let mut ctx_b: Vec<<DvvSetMech as Mechanism>::Context> = vec![Default::default(); 3];
        for op in 0..300 {
            let client = rng.below(3) as usize;
            match rng.below(2) {
                0 => {
                    ctx_a[client] = dvv.read(&a).1;
                    ctx_b[client] = dset.read(&b).1;
                }
                _ => {
                    let meta = WriteMeta::basic(Actor::client(client as u32));
                    let coord = Actor::server(rng.below(2) as u32);
                    dvv.write(&mut a, &ctx_a[client].clone(), Val::new(op + 1, 0), coord, &meta);
                    dset.write(&mut b, &ctx_b[client].clone(), Val::new(op + 1, 0), coord, &meta);
                }
            }
            let mut va: Vec<u64> = dvv.values(&a).iter().map(|v| v.id).collect();
            let mut vb: Vec<u64> = dset.values(&b).iter().map(|v| v.id).collect();
            va.sort_unstable();
            vb.sort_unstable();
            assert_eq!(va, vb, "op {op} seed {seed}");
        }
    }
}

#[test]
fn dvv_order_equals_history_order_under_store_reachable_clocks() {
    // §5.2: the computed order must equal causal-history inclusion for
    // every pair of clocks a store can actually produce
    let dvv = DvvMech;
    forall(
        &Config::default().cases(60),
        from_fn(|rng, _| {
            // produce reachable clocks by running random ops
            let mut st: <DvvMech as Mechanism>::State = Vec::new();
            let mut clocks = Vec::new();
            let mut ctx: <DvvMech as Mechanism>::Context = Default::default();
            for op in 0..rng.range(2, 20) {
                if rng.chance(0.4) {
                    ctx = dvv.read(&st).1;
                }
                let coord = Actor::server(rng.below(3) as u32);
                dvv.write(
                    &mut st,
                    &ctx,
                    Val::new(op as u64 + 1, 0),
                    coord,
                    &WriteMeta::basic(Actor::client(0)),
                );
                for (c, _) in &st {
                    clocks.push(c.clone());
                }
            }
            clocks
        }),
        |clocks| {
            clocks.iter().all(|x| {
                clocks.iter().all(|y| {
                    x.compare(y) == x.history().compare(&y.history())
                })
            })
        },
    );
}
