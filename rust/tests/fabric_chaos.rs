//! Chaos property test: random [`FaultPlan`] schedules against the
//! *threaded* cluster, under real concurrency, audited by the causal
//! ground-truth oracle.
//!
//! For each seed, a random schedule of crash windows, partitions, and
//! link degradation is stepped through the cluster's chaos fabric while
//! client threads hammer quorum GET/PUT. The properties:
//!
//! 1. after healing, anti-entropy quiesces and every replica pair holds
//!    identical (order-insensitive) sibling sets for every key;
//! 2. the oracle classifies **zero** discarded versions as lost updates —
//!    DVVs never destroy a concurrent write, partitions or not;
//! 3. all hints drain once the cluster is healthy.
//!
//! Both storage backends run the same property (the fabric and quorum
//! logic must not depend on the locking layout).
//!
//! The default gate runs 3 fixed seeds per backend; `CHAOS_ITERS=<n>`
//! appends `n` extra derived seeds so local runs can soak
//! (`CHAOS_ITERS=50 rust/ci.sh`). Failures print in the uniform
//! `testkit::soak` format and replay with `DVV_SEED=<seed>`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use dvvstore::antientropy::diff_pairs;
use dvvstore::clocks::Actor;
use dvvstore::kernel::mechs::DvvMech;
use dvvstore::oracle::SharedOracle;
use dvvstore::server::LocalCluster;
use dvvstore::sim::failure::FaultPlan;
use dvvstore::store::{InMemoryBackend, ShardedBackend, StorageBackend};
use dvvstore::testkit::{run_seeded, soak_seeds, Rng};

const NODES: usize = 5;
const KEYS: u64 = 8;
const CLIENTS: u32 = 4;
const HORIZON_US: u64 = 400_000;

/// Fixed seeds in the default gate, plus `CHAOS_ITERS` derived extras.
fn seeds() -> Vec<u64> {
    soak_seeds(&[101, 202, 303], "CHAOS_ITERS")
}

/// One chaos run: drive a random schedule while client threads do
/// session-tracked quorum ops, then heal, converge, and audit.
fn chaos_run<B: StorageBackend<DvvMech>>(
    seed: u64,
    make: impl FnMut(usize) -> B + Send + 'static,
) {
    let cluster = LocalCluster::with_backends(NODES, 3, 2, 2, make).unwrap();
    let oracle = Arc::new(SharedOracle::new());
    cluster.attach_oracle(Arc::clone(&oracle));
    cluster.fabric().reseed(seed ^ 0xFA_B21C);
    let cluster = Arc::new(cluster);

    let mut rng = Rng::new(seed);
    let plan = FaultPlan::random_chaos(NODES, HORIZON_US, &mut rng);

    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    for t in 0..CLIENTS {
        let cluster = Arc::clone(&cluster);
        let stop = Arc::clone(&stop);
        workers.push(std::thread::spawn(move || {
            let me = Actor::client(t);
            let mut rng = Rng::new(seed.wrapping_mul(0x9E37).wrapping_add(u64::from(t)));
            // per-key session state: (context, observed ids) of last GET
            let mut sessions: Vec<Option<(Vec<u8>, Vec<u64>)>> =
                vec![None; KEYS as usize];
            let (mut ok_ops, mut failed_ops) = (0u64, 0u64);
            while !stop.load(Ordering::Relaxed) {
                let ki = rng.below(KEYS) as usize;
                let key = format!("chaos-{ki}");
                let outcome = if rng.chance(0.5) {
                    cluster.get(&key).map(|ans| {
                        sessions[ki] = Some((ans.context, ans.ids));
                    })
                } else {
                    let (ctx, observed) = sessions[ki].clone().unwrap_or_default();
                    let body = format!("c{t}-{ok_ops}").into_bytes();
                    cluster.put_traced(&key, body, &ctx, me, &observed).map(|_| ())
                };
                // under active faults ops may fail (quorum not met /
                // unavailable); that is the point of the exercise
                match outcome {
                    Ok(()) => ok_ops += 1,
                    Err(_) => failed_ops += 1,
                }
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            (ok_ops, failed_ops)
        }));
    }

    // step the schedule's virtual clock while the workers run
    const STEPS: u64 = 50;
    for step in 1..=STEPS {
        cluster.fabric().advance(&plan, HORIZON_US * step / STEPS);
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    stop.store(true, Ordering::Relaxed);
    let mut total_ok = 0;
    for worker in workers {
        let (ok_ops, _failed) = worker.join().unwrap();
        total_ok += ok_ops;
    }
    assert!(total_ok > 0, "seed {seed}: no operation ever succeeded");

    // heal everything, then anti-entropy until quiescent
    cluster.fabric().heal_all();
    let mut rounds = 0;
    while cluster.anti_entropy_round() > 0 {
        rounds += 1;
        assert!(rounds < 32, "seed {seed}: anti-entropy failed to quiesce");
    }
    assert_eq!(cluster.pending_hints(), 0, "seed {seed}: hints not drained");

    // full pairwise convergence, order-insensitive
    for a in 0..NODES {
        for b in (a + 1)..NODES {
            let diverged = diff_pairs(cluster.node(a).store(), cluster.node(b).store());
            assert!(
                diverged.is_empty(),
                "seed {seed}: nodes {a}/{b} diverged after heal on {} keys",
                diverged.len()
            );
        }
    }

    // the headline property: nothing the mechanism discarded was a
    // concurrent update — and the workload is fully traced, so every
    // single drop was auditable
    assert!(oracle.tracked() > 0, "seed {seed}: no writes registered");
    assert_eq!(oracle.unaudited_drops(), 0, "seed {seed}: untraced writes leaked in");
    assert_eq!(
        oracle.lost_updates(),
        0,
        "seed {seed}: {} lost updates ({} correct supersessions)",
        oracle.lost_updates(),
        oracle.correct_supersessions()
    );
}

#[test]
fn chaos_schedules_converge_without_lost_updates_sharded() {
    run_seeded("fabric_chaos_sharded", &seeds(), |seed| {
        chaos_run(seed, |_| ShardedBackend::with_shards(8));
    });
}

#[test]
fn chaos_schedules_converge_without_lost_updates_flat() {
    run_seeded("fabric_chaos_flat", &seeds(), |seed| {
        chaos_run(seed, |_| InMemoryBackend::new());
    });
}

#[test]
fn same_plan_drives_sim_and_threaded_cluster() {
    // the acceptance-criteria property in miniature: one FaultPlan value
    // applied to both the DES and the fabric. Partition + degradation
    // windows only: client→coordinator hops are never partitioned or
    // dropped in the DES, so every issued write lands somewhere and the
    // permanent-loss audit is exact.
    let mut rng = Rng::new(7);
    let plan = FaultPlan::new()
        .random_partitions(4, 2, 30_000, 70_000, &mut rng)
        .degrade_window(0.3, 200, 10_000, 60_000);

    // simulator path
    let mut cfg = dvvstore::config::StoreConfig::default();
    cfg.cluster.nodes = 4;
    cfg.cluster.replication = 2;
    cfg.cluster.read_quorum = 1;
    cfg.cluster.write_quorum = 1;
    cfg.antientropy.period_us = 20_000;
    let driver = Box::new(dvvstore::workload::RandomWorkload::new(
        dvvstore::workload::WorkloadSpec {
            keys: 8,
            ops_per_client: 30,
            put_fraction: 0.6,
            read_before_write: 0.5,
            mean_think_us: 300.0,
            ..Default::default()
        },
        4,
    ));
    let mut sim = dvvstore::sim::Sim::new(DvvMech, cfg, 4, true, driver, 7).unwrap();
    plan.apply(&mut sim);
    sim.start();
    sim.run(5_000_000);
    sim.settle();
    assert_eq!(sim.audit_permanently_lost(), 0, "{}", sim.metrics.summary());

    // threaded path: the same plan value steps the fabric. Mid-schedule
    // the degradation window is active; past the horizon every window
    // has closed by construction.
    let cluster = LocalCluster::new(4, 2, 1, 1).unwrap();
    cluster.fabric().advance(&plan, 30_000);
    assert!(cluster.fabric().drop_prob() > 0.0, "degrade window active at 30ms");
    cluster.fabric().advance(&plan, 100_000);
    assert_eq!(cluster.fabric().drop_prob(), 0.0, "degrade window closed");
    for a in 0..4 {
        for b in (a + 1)..4 {
            assert!(!cluster.fabric().is_partitioned(a, b), "partitions healed");
        }
    }
    cluster.put("k", b"after-chaos".to_vec(), &[]).unwrap();
    assert_eq!(cluster.get("k").unwrap().values, vec![b"after-chaos".to_vec()]);
}
