//! Sorted-run damage fuzz: [`Run::open`] must never panic, must reject
//! a damaged file with an `Err` (so the LSM open can quarantine it),
//! and a damaged run must cost **exactly that run** — every other run's
//! keys stay readable and the damage is reported in
//! [`RecoveryReport::quarantined_runs`].
//!
//! Strategy, mirroring `wal_recovery.rs`:
//!
//! 1. **truncation sweep** — cut a pristine run at *every* byte offset
//!    (this crosses every boundary: head magic, each block's frame and
//!    body, the footer's fence/index/bloom/digest regions, the tail);
//! 2. **corruption sweep** — XOR each byte of the file in turn; every
//!    single-byte flip must be caught (head/tail magic by comparison,
//!    footer and block bodies by CRC, block framing by the
//!    index-length cross-check);
//! 3. **backend quarantine** — damage one run of a two-run
//!    [`LsmBackend`]; reopen must quarantine only that file, report it,
//!    keep the other run's keys serving, and keep the store writable.
//!
//! Seeded random sweeps scale with `LSM_ITERS` and print failures in
//! the uniform `testkit::soak` format.

use std::path::{Path, PathBuf};

use dvvstore::clocks::Actor;
use dvvstore::kernel::mechs::DvvMech;
use dvvstore::kernel::{Val, WriteMeta};
use dvvstore::store::sst::{Run, RunWriter};
use dvvstore::store::wal::FsyncPolicy;
use dvvstore::store::{KeyStore, LsmBackend, LsmOptions, WalOptions};
use dvvstore::testkit::{run_seeded, soak_seeds, temp_dir, Rng};

/// Deterministic raw "state" payloads (the sst layer is
/// mechanism-agnostic: state bytes in, state bytes out).
fn state_bytes(key: u64, salt: u64) -> Vec<u8> {
    let len = ((key * 7 + salt) % 23 + 1) as usize;
    (0..len).map(|j| ((key * 31 + salt * 13 + j as u64 * 11) % 251) as u8).collect()
}

/// Write a pristine run of `keys` (96-byte blocks, so a few dozen keys
/// span several blocks) and return its bytes.
fn build_run(path: &Path, keys: &[u64], salt: u64) -> Vec<u8> {
    let mut w = RunWriter::new(96);
    for &k in keys {
        w.add(k, k.wrapping_mul(0x9E37_79B9) ^ salt, &state_bytes(k, salt));
    }
    w.finish(path).unwrap();
    std::fs::read(path).unwrap()
}

#[test]
fn every_truncation_point_is_rejected_without_panic() {
    let dir = temp_dir("sst-trunc-sweep");
    let path = dir.join("run-00000000-0000.sst");
    let keys: Vec<u64> = (0..60).map(|i| i * 3 + 1).collect();
    let pristine = build_run(&path, &keys, 1);
    for cut in 0..pristine.len() {
        std::fs::write(&path, &pristine[..cut]).unwrap();
        assert!(Run::open(&path).is_err(), "truncation at byte {cut} must be rejected");
    }
    std::fs::write(&path, &pristine).unwrap();
    let (run, digests) = Run::open(&path).unwrap();
    assert!(run.block_count() > 1, "sweep must cross block boundaries");
    assert_eq!(run.entry_count() as usize, keys.len(), "pristine bytes still open");
    assert_eq!(digests.len(), keys.len());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn every_single_byte_corruption_is_rejected_without_panic() {
    let dir = temp_dir("sst-xor-sweep");
    let path = dir.join("run-00000000-0000.sst");
    let keys: Vec<u64> = (0..40).collect();
    let pristine = build_run(&path, &keys, 2);
    for off in 0..pristine.len() {
        let mut bytes = pristine.clone();
        bytes[off] ^= 0x5A;
        std::fs::write(&path, &bytes).unwrap();
        assert!(
            Run::open(&path).is_err(),
            "byte {off} of {} flipped yet the run still opened",
            pristine.len()
        );
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Big memtable (no auto-flush), huge tier fan-in (no compaction): the
/// test controls exactly which runs exist.
fn quiet_opts() -> LsmOptions {
    LsmOptions {
        wal: WalOptions { segment_bytes: 1 << 20, fsync: FsyncPolicy::Never },
        memtable_bytes: 1 << 20,
        block_bytes: 128,
        cache_blocks: 8,
        tier_runs: 1000,
    }
}

fn lsm_store(dir: &Path) -> KeyStore<DvvMech, LsmBackend<DvvMech>> {
    KeyStore::with_backend(DvvMech, LsmBackend::open(dir, 1, quiet_opts()).unwrap())
}

fn put(s: &KeyStore<DvvMech, LsmBackend<DvvMech>>, k: u64, v: u64) {
    let meta = WriteMeta::basic(Actor::client(0));
    let (_, ctx) = s.read(k);
    s.write(k, &ctx, Val::new(v, 8), Actor::server(0), &meta);
}

/// The single shard dir of a 1-shard backend.
fn shard_dir(root: &Path) -> PathBuf {
    root.join("shard-000")
}

#[test]
fn damaged_run_is_quarantined_alone_and_the_rest_keeps_serving() {
    let root = temp_dir("sst-quarantine-backend");
    {
        let s = lsm_store(&root);
        for k in 0..20u64 {
            put(&s, k, k + 1);
        }
        s.backend().flush_memtables(); // run-00000000: keys 0..20
        for k in 20..40u64 {
            put(&s, k, k + 1);
        }
        s.backend().flush_memtables(); // run-00000001: keys 20..40
        assert_eq!(s.backend().run_count(), 2);
    }
    // flip one byte in the middle of the newer run
    let victim = shard_dir(&root).join("run-00000001-0000.sst");
    let mut bytes = std::fs::read(&victim).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&victim, &bytes).unwrap();

    let s = lsm_store(&root);
    let report = s.backend().recovery_report();
    assert_eq!(report.quarantined_runs, 1, "exactly the damaged run is quarantined");
    assert!(!victim.exists(), "damaged file left the live set");
    assert!(
        shard_dir(&root).join("run-00000001-0000.sst.quarantined").exists(),
        "damaged file is renamed for inspection, not deleted"
    );
    for k in 0..20u64 {
        assert_eq!(s.values(k), vec![Val::new(k + 1, 8)], "undamaged run still serves {k}");
    }
    for k in 20..40u64 {
        assert!(s.values(k).is_empty(), "quarantined key {k} reads absent (AE refills it)");
    }
    // the store stays writable, and a clean reopen reports nothing new
    put(&s, 99, 500);
    assert_eq!(s.values(99).len(), 1);
    drop(s);
    let s = lsm_store(&root);
    assert_eq!(s.backend().recovery_report().quarantined_runs, 0);
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn seeded_random_damage_soak() {
    let seeds = soak_seeds(&[11, 22, 33], "LSM_ITERS");
    run_seeded("sst_recovery::seeded_random_damage_soak", &seeds, |seed| {
        let mut rng = Rng::new(seed);
        let dir = temp_dir(&format!("sst-soak-{seed}"));
        let path = dir.join("run-00000000-0000.sst");

        // random ascending key set with random state sizes
        let mut keys: Vec<u64> = Vec::new();
        let mut next = 0u64;
        for _ in 0..rng.range(8, 120) {
            next += rng.range_u64(1, 9);
            keys.push(next);
        }
        let pristine = build_run(&path, &keys, seed);

        // random truncations and random byte flips — never a panic,
        // never a silent acceptance
        for _ in 0..40 {
            // `range` is inclusive, so cap below len: a full-length
            // "cut" is the pristine file and rightly opens
            let cut = rng.range(0, pristine.len() - 1);
            std::fs::write(&path, &pristine[..cut]).unwrap();
            assert!(Run::open(&path).is_err(), "seed {seed}: truncation at {cut} accepted");

            let off = rng.range(0, pristine.len() - 1);
            let mut bytes = pristine.clone();
            bytes[off] ^= rng.range_u64(1, 255) as u8;
            std::fs::write(&path, &bytes).unwrap();
            assert!(Run::open(&path).is_err(), "seed {seed}: flip at {off} accepted");
        }
        std::fs::write(&path, &pristine).unwrap();
        assert!(Run::open(&path).is_ok(), "seed {seed}: pristine run must reopen");
        std::fs::remove_dir_all(&dir).unwrap();
    });
}
