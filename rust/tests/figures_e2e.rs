//! Integration: every paper figure replays with its exact states, and the
//! cross-figure story holds (same run, different mechanisms, different
//! survivors).

use dvvstore::figures;

#[test]
fn figure1_causal_histories() {
    let rep = figures::fig1();
    let text = rep.render();
    assert!(text.contains("{b1}"), "{text}");
    assert!(text.contains("{b2}"), "{text}");
    assert!(text.contains("{a1,a2}"), "{text}");
}

#[test]
fn figure2_lww_converges_to_latest_stamp() {
    let text = figures::fig2().render();
    assert!(text.contains("v overwritten"), "{text}");
    assert!(text.contains("lost"), "{text}");
}

#[test]
fn figure3_server_vv_anomaly() {
    let text = figures::fig3().render();
    assert!(text.contains("FALSELY dominated"), "{text}");
    assert!(text.contains("{(b,2)}"), "{text}");
}

#[test]
fn figure4_client_vv_stateless_anomaly() {
    let text = figures::fig4().render();
    assert!(text.contains("falsely dominates v"), "{text}");
    assert!(text.contains("(C1,1)"), "{text}");
}

#[test]
fn figure7_dvv_exact_clocks() {
    let text = figures::fig7().render();
    // every clock the paper prints for the run
    for clock in ["{(b,0,1)}", "{(b,0,2)}", "{(a,0,1)}", "{(a,1,2)}", "{(b,2),(a,0,3)}"] {
        assert!(text.contains(clock), "missing {clock} in:\n{text}");
    }
}

#[test]
fn same_run_different_survivors() {
    // Figures 3 and 7 replay the same client run; v survives only under DVV.
    let f3 = figures::fig3().render();
    let f7 = figures::fig7().render();
    assert!(f3.contains("v lost"));
    assert!(f7.contains("v:{(b,0,1)}"));
}

#[test]
fn replay_api_covers_expected_set() {
    assert_eq!(figures::REPLAYABLE, [1, 2, 3, 4, 7]);
    for f in figures::REPLAYABLE {
        figures::replay(f).unwrap();
    }
    assert!(figures::replay(6).is_err());
}
