//! Geo-replication chaos: whole-DC partitions, hybrid-logical-clock
//! anomalies, and the async cross-DC shipper — run against **both
//! worlds** (the DES and the threaded zone-aware cluster) from one
//! [`FaultPlan`], oracle-verified.
//!
//! The marquee scenario, pinned and seeded: partition an entire
//! datacenter away from the rest, keep serving reads *and* writes in
//! both halves on their per-DC sloppy quorums, heal, and converge —
//! with zero lost acknowledged updates and identical verdicts in the
//! simulator and the threaded cluster.
//!
//! Also here: the HLC property soaks (monotonicity under backward
//! physical jumps, receive dominance, bounded drift, codec order
//! preservation), the zoned preference-list invariant, the `OP_SHIP`
//! wire roundtrip (including whole-batch rejection), and the v6 STATS
//! strict-decode regression.
//!
//! The default gate runs fixed seeds; `GEO_ITERS=<n>` appends derived
//! seeds (uniform failure format via `testkit::soak`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use dvvstore::antientropy::diff_pairs;
use dvvstore::api::{KvClient, TcpClient};
use dvvstore::clocks::hlc::{decode_hlc, encode_hlc};
use dvvstore::clocks::{Actor, Hlc, HlcTimestamp};
use dvvstore::cluster::ring::{hash_str, Ring};
use dvvstore::kernel::mechs::DvvMech;
use dvvstore::kernel::DurableMechanism;
use dvvstore::oracle::SharedOracle;
use dvvstore::server::tcp::Server;
use dvvstore::server::{protocol, LocalCluster};
use dvvstore::sim::failure::FaultPlan;
use dvvstore::testkit::{run_seeded, soak_seeds, Rng};
use dvvstore::workload::key_name;

/// Two 3-node datacenters.
const ZONES: [usize; 6] = [0, 0, 0, 1, 1, 1];
const NODES: usize = 6;
const KEYS: u64 = 8;
const CLIENTS: u32 = 4;
const HORIZON_US: u64 = 300_000;

fn seeds() -> Vec<u64> {
    soak_seeds(&[81, 82, 83], "GEO_ITERS")
}

/// The acceptance plan: DC 1 cut off for the middle 60% of the run,
/// plus one two-second backward clock jump inside the dark window.
fn dc_partition_plan() -> FaultPlan {
    FaultPlan::new()
        .partition_dc_at(&ZONES, 1, 60_000, 240_000)
        .clock_skew_at(100_000, 4, -2_000_000)
}

/// Random whole-DC chaos for the soak seeds.
fn geo_chaos_plan(seed: u64) -> FaultPlan {
    FaultPlan::random_geo_chaos(&ZONES, HORIZON_US, &mut Rng::new(seed))
}

// -------------------------------------------------------------------
// world 1: the DES
// -------------------------------------------------------------------

fn des_run(seed: u64, plan: &FaultPlan) {
    let mut cfg = dvvstore::config::StoreConfig::default();
    cfg.cluster.nodes = NODES;
    cfg.cluster.replication = 3;
    cfg.cluster.read_quorum = 2;
    cfg.cluster.write_quorum = 2;
    cfg.cluster.zones = ZONES.to_vec();
    cfg.antientropy.period_us = 20_000;
    cfg.geo.ship_interval_us = 10_000;
    // a generous cross-DC AE backstop so the bounded settle converges
    // even when the partition swallowed shipper batches
    cfg.geo.cross_dc_ae_prob = 0.5;
    let driver = Box::new(dvvstore::workload::RandomWorkload::new(
        dvvstore::workload::WorkloadSpec {
            keys: KEYS,
            ops_per_client: 40,
            put_fraction: 0.6,
            read_before_write: 0.5,
            mean_think_us: 400.0,
            ..Default::default()
        },
        CLIENTS as usize,
    ));
    let mut sim =
        dvvstore::sim::Sim::new(DvvMech, cfg, CLIENTS as usize, true, driver, seed).unwrap();
    plan.apply(&mut sim);
    sim.start();
    sim.run(5_000_000);
    sim.settle();
    assert!(sim.writes_acked() > 0, "seed {seed}: nothing acked");
    assert_eq!(
        sim.audit_acked_lost(),
        0,
        "seed {seed}: acked update lost in the DES ({})",
        sim.metrics.summary()
    );
    assert_eq!(
        sim.metrics.lost_updates, 0,
        "seed {seed}: mechanism lost updates in the DES"
    );
    // HLCs stayed monotone through the backward jump: every node's
    // final timestamp is sane (the Hlc would have panicked on a
    // regression; here we assert the clocks actually moved)
    assert!(
        (0..NODES).any(|n| sim.node_hlc(n) > HlcTimestamp::default()),
        "seed {seed}: no hybrid clock ever advanced"
    );
    // post-settle convergence across members, pairwise
    let members = sim.members();
    for (ai, &a) in members.iter().enumerate() {
        for &b in members.iter().skip(ai + 1) {
            for key in 0..KEYS {
                assert_eq!(
                    sim.nodes[a].store.state(key),
                    sim.nodes[b].store.state(key),
                    "seed {seed}: members {a}/{b} diverged on key {key}"
                );
            }
        }
    }
}

// -------------------------------------------------------------------
// world 2: the threaded zone-aware cluster
// -------------------------------------------------------------------

/// Drive the plan against a live zone-aware cluster while client
/// threads hammer traced quorum ops **in their own DC**; returns the
/// acked `(key, id)` pairs plus per-zone ack counts. With
/// `probe_mid_partition`, the main thread additionally writes and
/// reads in *both* halves while the DC partition is dark — the "keep
/// serving locally on both sides" marquee property, asserted directly.
fn threaded_run(
    seed: u64,
    plan: &FaultPlan,
    probe_mid_partition: bool,
    cluster: &Arc<LocalCluster>,
) -> (Vec<(u64, u64)>, [usize; 2]) {
    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    for t in 0..CLIENTS {
        let cluster = Arc::clone(cluster);
        let stop = Arc::clone(&stop);
        workers.push(std::thread::spawn(move || {
            let zone = (t as usize) % 2;
            let me = Actor::client(t);
            let mut rng = Rng::new(seed.wrapping_mul(0x9E37).wrapping_add(u64::from(t)));
            let mut sessions: Vec<Option<(Vec<u8>, Vec<u64>)>> = vec![None; KEYS as usize];
            let mut acked: Vec<(u64, u64)> = Vec::new();
            let mut op = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let ki = rng.below(KEYS);
                let key = key_name(ki);
                if rng.chance(0.5) {
                    if let Ok(ans) = cluster.get_in_zone(&key, Some(zone)) {
                        sessions[ki as usize] = Some((ans.context, ans.ids));
                    }
                } else {
                    let (ctx, observed) = sessions[ki as usize].clone().unwrap_or_default();
                    let body = format!("c{t}-{op}").into_bytes();
                    if let Ok(id) =
                        cluster.put_traced_in_zone(&key, body, &ctx, me, &observed, Some(zone))
                    {
                        acked.push((ki, id));
                    }
                }
                op += 1;
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            (zone, acked)
        }));
    }
    const STEPS: u64 = 50;
    let mut probe_acks: Vec<(u64, u64)> = Vec::new();
    for step in 1..=STEPS {
        cluster.advance_plan(plan, HORIZON_US * step / STEPS);
        if probe_mid_partition && step == STEPS / 2 {
            // cursor is at 150_000µs — squarely inside the pinned
            // 60_000..240_000 dark window: both halves must still
            // serve reads and writes on their per-DC sloppy quorums
            for z in 0..2usize {
                let key = key_name(z as u64);
                let id = cluster
                    .put_traced_in_zone(
                        &key,
                        format!("probe-z{z}").into_bytes(),
                        &[],
                        Actor::client(90 + z as u32),
                        &[],
                        Some(z),
                    )
                    .unwrap_or_else(|e| {
                        panic!("seed {seed}: zone {z} write failed mid-partition: {e}")
                    });
                probe_acks.push((z as u64, id));
                cluster.get_in_zone(&key, Some(z)).unwrap_or_else(|e| {
                    panic!("seed {seed}: zone {z} read failed mid-partition: {e}")
                });
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    stop.store(true, Ordering::Relaxed);
    let mut acked = probe_acks;
    let mut per_zone = [0usize; 2];
    for w in workers {
        let (zone, mine) = w.join().unwrap();
        per_zone[zone] += mine.len();
        acked.extend(mine);
    }
    (acked, per_zone)
}

/// Heal, quiesce (shipper included), and assert the geo properties.
fn audit_threaded(
    seed: u64,
    cluster: &LocalCluster,
    oracle: &SharedOracle,
    acked: &[(u64, u64)],
    per_zone: &[usize; 2],
) {
    cluster.fabric().heal_all();
    let mut rounds = 0;
    // anti_entropy_round drains hints and runs a shipper round first,
    // so this loop also flushes the cross-DC queue
    while cluster.anti_entropy_round() > 0 {
        rounds += 1;
        assert!(rounds < 32, "seed {seed}: anti-entropy failed to quiesce");
    }
    assert_eq!(cluster.pending_hints(), 0, "seed {seed}: hints not drained");
    assert_eq!(cluster.ship_lag(), 0, "seed {seed}: shipper backlog not drained");
    for a in 0..NODES {
        for b in (a + 1)..NODES {
            let diverged = diff_pairs(cluster.node(a).store(), cluster.node(b).store());
            assert!(
                diverged.is_empty(),
                "seed {seed}: nodes {a}/{b} diverged after heal on {} keys",
                diverged.len()
            );
        }
    }
    let verdict = oracle.verdict();
    assert_eq!(verdict.unaudited_drops, 0, "seed {seed}: untraced writes leaked in");
    assert_eq!(
        verdict.lost_updates, 0,
        "seed {seed}: mechanism lost updates under DC partition"
    );
    assert!(
        per_zone[0] > 0 && per_zone[1] > 0,
        "seed {seed}: a DC stopped acking writes entirely ({per_zone:?})"
    );
    // the headline: every acked write survives (itself, or causally
    // covered by a survivor) even though a whole DC went dark
    for &(ki, id) in acked {
        let k = hash_str(&key_name(ki));
        let covered = (0..NODES).any(|n| {
            cluster
                .node(n)
                .store()
                .values(k)
                .iter()
                .any(|v| v.id == id || oracle.with_inner(|o| o.leq(id, v.id)))
        });
        assert!(covered, "seed {seed}: acked write {id} on key {ki} lost");
    }
}

fn threaded_case(seed: u64, plan: &FaultPlan, probe_mid_partition: bool) {
    let cluster = LocalCluster::with_zones(&ZONES, 3, 2, 2).unwrap();
    assert!(cluster.geo(), "two DCs make a geo cluster");
    assert_eq!(cluster.zone_count(), 2);
    let oracle = Arc::new(SharedOracle::new());
    cluster.attach_oracle(Arc::clone(&oracle));
    cluster.fabric().reseed(seed ^ 0xD00D);
    let cluster = Arc::new(cluster);
    let (acked, per_zone) = threaded_run(seed, plan, probe_mid_partition, &cluster);
    audit_threaded(seed, &cluster, &oracle, &acked, &per_zone);
}

// -------------------------------------------------------------------
// the marquee + the soaks
// -------------------------------------------------------------------

/// The acceptance scenario end-to-end, one pinned seed: the identical
/// plan value partitions DC 1 away in the DES and in the threaded
/// cluster, both halves keep serving (probed directly mid-partition in
/// the threaded world), and both worlds reach the same verdicts —
/// zero lost acknowledged updates and post-heal convergence.
#[test]
fn dc_partition_same_plan_same_verdicts_in_both_worlds() {
    let seed = 4242;
    let plan = dc_partition_plan();
    des_run(seed, &plan);
    threaded_case(seed, &plan, true);
}

#[test]
fn geo_chaos_des_across_seeds() {
    run_seeded("geo_chaos_des", &seeds(), |seed| des_run(seed, &geo_chaos_plan(seed)));
}

#[test]
fn geo_chaos_threaded_across_seeds() {
    run_seeded("geo_chaos_threaded", &seeds(), |seed| {
        threaded_case(seed, &geo_chaos_plan(seed), false);
    });
}

// -------------------------------------------------------------------
// HLC property soaks
// -------------------------------------------------------------------

/// `now` is strictly monotone even when the physical input jumps
/// backward by seconds mid-stream.
#[test]
fn hlc_now_stays_strictly_monotone_under_backward_jumps() {
    run_seeded("hlc_monotone", &seeds(), |seed| {
        let mut rng = Rng::new(seed);
        let mut hlc = Hlc::new();
        let mut pt: i64 = 1_000_000;
        let mut prev = hlc.last();
        for _ in 0..2_000 {
            // random walk with occasional multi-second backward jumps
            pt += if rng.chance(0.1) {
                -(rng.below(3_000_000) as i64)
            } else {
                rng.below(2_000) as i64
            };
            let ts = hlc.now(pt.max(0) as u64);
            assert!(ts > prev, "seed {seed}: now() regressed: {prev} !< {ts}");
            prev = ts;
        }
    });
}

/// `recv` dominates every input: the merged timestamp is strictly
/// above both the local clock's previous reading and the remote stamp.
#[test]
fn hlc_recv_dominates_both_clocks() {
    run_seeded("hlc_recv", &seeds(), |seed| {
        let mut rng = Rng::new(seed);
        let mut a = Hlc::new();
        let mut b = Hlc::new();
        for i in 0..2_000u64 {
            let pt_a = rng.below(1_000_000);
            let pt_b = rng.below(1_000_000);
            let (tx, rx, pt) =
                if i % 2 == 0 { (&mut a, &mut b, pt_b) } else { (&mut b, &mut a, pt_a) };
            let sent = tx.now(if i % 2 == 0 { pt_a } else { pt_b });
            let before = rx.last();
            let got = rx.recv(pt, sent);
            assert!(got > before, "seed {seed}: recv did not advance: {before} !< {got}");
            assert!(got > sent, "seed {seed}: recv below the remote stamp: {sent} !< {got}");
            assert!(got.l >= pt, "seed {seed}: recv dropped the physical input");
        }
    });
}

/// Drift bound: with no remote input, `l` never exceeds the largest
/// physical reading ever fed in — the clock cannot run ahead of the
/// wall it has seen (Kulkarni et al.'s |l - pt| bound, local half).
#[test]
fn hlc_l_never_exceeds_the_largest_physical_input() {
    run_seeded("hlc_drift", &seeds(), |seed| {
        let mut rng = Rng::new(seed);
        let mut hlc = Hlc::new();
        let mut max_pt = 0u64;
        for _ in 0..2_000 {
            let pt = rng.below(10_000_000);
            max_pt = max_pt.max(pt);
            let ts = hlc.now(pt);
            assert!(
                ts.l <= max_pt,
                "seed {seed}: l={} drifted past the largest physical input {max_pt}",
                ts.l
            );
        }
    });
}

/// The varint codec roundtrips, and `pack` preserves the HLC order for
/// in-range components.
#[test]
fn hlc_codec_roundtrips_and_pack_preserves_order() {
    run_seeded("hlc_codec", &seeds(), |seed| {
        let mut rng = Rng::new(seed);
        let mut prev: Option<HlcTimestamp> = None;
        for _ in 0..500 {
            let ts = HlcTimestamp::new(rng.below(1 << 48), rng.below(1 << 16));
            let mut buf = Vec::new();
            encode_hlc(&ts, &mut buf);
            let mut pos = 0;
            let back = decode_hlc(&buf, &mut pos).unwrap();
            assert_eq!(pos, buf.len(), "seed {seed}: codec left trailing bytes");
            assert_eq!(ts, back, "seed {seed}: codec roundtrip changed the stamp");
            if let Some(p) = prev {
                assert_eq!(
                    p.cmp(&ts),
                    p.pack().cmp(&ts.pack()),
                    "seed {seed}: pack() broke the order of {p} vs {ts}"
                );
            }
            prev = Some(ts);
        }
        // truncated stamps are rejected, never zero-filled
        let mut buf = Vec::new();
        encode_hlc(&HlcTimestamp::new(1 << 20, 3), &mut buf);
        for cut in 0..buf.len() {
            let mut pos = 0;
            assert!(
                decode_hlc(&buf[..cut], &mut pos).is_err(),
                "seed {seed}: truncated stamp ({cut} bytes) decoded"
            );
        }
    });
}

// -------------------------------------------------------------------
// zoned placement invariant
// -------------------------------------------------------------------

/// Zone-aware preference lists are distinct and cover every zone
/// before doubling up in any — for every key.
#[test]
fn zoned_preference_lists_cover_every_zone_first() {
    let ring = Ring::new(NODES, 32).unwrap();
    for key in 0..512u64 {
        let homes = ring.replicas_for_zoned(hash_str(&key_name(key)), 3, &ZONES);
        assert_eq!(homes.len(), 3, "key {key}: short preference list");
        let mut sorted = homes.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 3, "key {key}: duplicate home in {homes:?}");
        let zones: std::collections::HashSet<usize> =
            homes.iter().map(|&n| ZONES[n]).collect();
        assert_eq!(zones.len(), 2, "key {key}: a DC holds no replica ({homes:?})");
    }
}

// -------------------------------------------------------------------
// OP_SHIP over live TCP + v6 STATS
// -------------------------------------------------------------------

/// A shipper batch applied over the wire lands on every home of the
/// key, advances the receivers' hybrid clocks, and acks with a stamp
/// at or above the sender's.
#[test]
fn ship_opcode_applies_batches_over_the_wire() {
    // source world: a tiny flat cluster fabricates a real DVV state
    let source = LocalCluster::new(1, 1, 1, 1).unwrap();
    source.put("geo-k", b"from-remote-dc".to_vec(), &[]).unwrap();
    let k = hash_str("geo-k");
    let state = source.node(0).store().state(k);
    let mut bytes = Vec::new();
    <DvvMech as DurableMechanism>::encode_state(&state, &mut bytes);

    let cluster = Arc::new(LocalCluster::with_zones(&[0, 1], 2, 1, 1).unwrap());
    let server = Server::start("127.0.0.1:0", cluster.clone()).unwrap();
    let mut client = TcpClient::connect(server.addr(), Actor::client(7)).unwrap();

    let sent = HlcTimestamp::new(5_000_000, 3);
    let (applied, acked) = client.ship(1, sent, vec![(k, bytes.clone())]).unwrap();
    assert_eq!(applied, 1, "one state in the batch");
    assert!(acked >= sent, "ack stamp below the sender's: {acked} < {sent}");
    let ans = client.get("geo-k").unwrap();
    assert_eq!(ans.values, vec![b"from-remote-dc".to_vec()]);
    assert!(
        (0..2).any(|n| cluster.node(n).hlc_last() >= sent),
        "no receiver clock folded in the remote stamp"
    );

    // whole-batch rejection: one malformed state poisons the batch and
    // nothing from it — not even the valid entry — may apply
    let k2 = hash_str("geo-k2");
    assert!(
        client.ship(1, sent, vec![(k2, bytes), (k2, vec![0xFF, 0x01, 0x02])]).is_err(),
        "a half-decodable batch must be refused"
    );
    for n in 0..2 {
        assert!(
            cluster.node(n).store().values(k2).is_empty(),
            "node {n}: a rejected batch half-applied"
        );
    }
    client.quit().unwrap();
    server.shutdown();
}

/// The v6 STATS reply carries `zones` and `ship_lag` over the wire,
/// and the strict decoder rejects every truncation — including the
/// pre-v6 seven-field shape.
#[test]
fn stats_reports_zones_and_ship_lag_and_rejects_truncation() {
    let cluster = Arc::new(LocalCluster::with_zones(&[0, 0, 1], 3, 2, 2).unwrap());
    let server = Server::start("127.0.0.1:0", cluster.clone()).unwrap();
    let mut client = TcpClient::connect(server.addr(), Actor::client(9)).unwrap();

    client.put("geo-stats", b"v".to_vec(), None).unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.nodes, 3, "node count");
    assert_eq!(stats.zones, 2, "zones field reports both DCs");
    assert!(stats.ship_lag >= 1, "the zone-1 home of the write is parked for the shipper");
    cluster.anti_entropy_round();
    let drained = client.stats().unwrap();
    assert_eq!(drained.ship_lag, 0, "ship_lag drains to zero after a shipper round");
    client.quit().unwrap();
    server.shutdown();

    // strict decode: all nine single-byte varints, then cut everywhere
    let payload = protocol::encode_stats_reply(3, 64, 99, 2, 7, 100, 90, 2, 5);
    assert_eq!(
        protocol::decode_stats_reply(&payload).unwrap(),
        (3, 64, 99, 2, 7, 100, 90, 2, 5)
    );
    for cut in 0..payload.len() {
        assert!(
            protocol::decode_stats_reply(&payload[..cut]).is_err(),
            "a {cut}-byte prefix (including the pre-v6 seven-field shape) must be rejected"
        );
    }
}
