//! Kernel-level CRDT properties: merge laws (commutative, associative,
//! idempotent) for all three datatypes, add-wins over concurrent
//! remove, tombstone-free removal, and delta/full-state replication
//! equivalence — then the "rides the storage stack unchanged" claim:
//! [`CrdtMech`] states installed through `merge_key` over the
//! in-memory, sharded, and durable/WAL backends keep identical
//! incremental Merkle roots, survive crash-restart, and heal a wiped
//! replica through the merge path alone.

use dvvstore::clocks::Actor;
use dvvstore::kernel::crdt::{CrdtMech, Dot, OrMap, Orswot, PnCounter, TypedState};
use dvvstore::kernel::Mechanism;
use dvvstore::store::{
    DurableBackend, FsyncPolicy, KeyStore, ShardedBackend, StorageBackend, WalOptions,
};
use dvvstore::testkit::{run_seeded, soak_seeds, temp_dir, Rng};

fn seeds() -> Vec<u64> {
    soak_seeds(&[91, 92, 93], "CRDT_ITERS")
}

fn elem(i: u64) -> Vec<u8> {
    format!("e{i}").into_bytes()
}

/// Evolve `replicas` divergent ORSWOT replicas: each mints dots under
/// its own actor, removes what it has observed, and occasionally pulls
/// a peer's full state — the states merge laws must hold over.
fn random_orswots(rng: &mut Rng, replicas: usize, ops: u64) -> Vec<Orswot> {
    let mut reps: Vec<Orswot> = (0..replicas).map(|_| Orswot::new()).collect();
    for _ in 0..ops {
        let i = rng.below(replicas as u64) as usize;
        match rng.below(5) {
            0 => {
                let j = rng.below(replicas as u64) as usize;
                if i != j {
                    let other = reps[j].clone();
                    reps[i].merge(&other);
                }
            }
            1 => {
                let e = elem(rng.below(8));
                reps[i].remove(&e);
            }
            _ => {
                let e = elem(rng.below(8));
                let dot = reps[i].mint(Actor::server(i as u32));
                reps[i].add(e, dot);
            }
        }
    }
    reps
}

fn random_ormaps(rng: &mut Rng, replicas: usize, ops: u64) -> Vec<OrMap> {
    let mut reps: Vec<OrMap> = (0..replicas).map(|_| OrMap::new()).collect();
    for _ in 0..ops {
        let i = rng.below(replicas as u64) as usize;
        match rng.below(5) {
            0 => {
                let j = rng.below(replicas as u64) as usize;
                if i != j {
                    let other = reps[j].clone();
                    reps[i].merge(&other);
                }
            }
            1 => {
                let f = elem(rng.below(6));
                reps[i].remove(&f);
            }
            _ => {
                let f = elem(rng.below(6));
                let v = format!("v{}", rng.below(100)).into_bytes();
                let dot = reps[i].mint(Actor::server(i as u32));
                reps[i].put(f, v, dot);
            }
        }
    }
    reps
}

// -------------------------------------------------------------------
// merge laws: the join is a semilattice for every datatype
// -------------------------------------------------------------------

#[test]
fn prop_orswot_merge_is_commutative_associative_idempotent() {
    run_seeded("orswot_merge_laws", &seeds(), |seed| {
        let mut rng = Rng::new(seed);
        let reps = random_orswots(&mut rng, 3, 120);
        let (a, b, c) = (&reps[0], &reps[1], &reps[2]);

        let mut ab = a.clone();
        ab.merge(b);
        let mut ba = b.clone();
        ba.merge(a);
        assert_eq!(ab, ba, "seed {seed}: merge not commutative");

        let mut ab_c = ab.clone();
        ab_c.merge(c);
        let mut bc = b.clone();
        bc.merge(c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc, "seed {seed}: merge not associative");

        let mut aa = a.clone();
        aa.merge(a);
        assert_eq!(&aa, a, "seed {seed}: merge not idempotent");
    });
}

#[test]
fn prop_pncounter_merges_to_the_global_sum_in_any_order() {
    run_seeded("pncounter_merge_laws", &seeds(), |seed| {
        let mut rng = Rng::new(seed);
        let mut reps: Vec<PnCounter> = (0..3).map(|_| PnCounter::new()).collect();
        let mut expected: i64 = 0;
        for _ in 0..200 {
            let i = rng.below(3) as usize;
            let by = rng.below(11) as i64 - 5;
            reps[i].incr(Actor::server(i as u32), by);
            expected += by;
        }
        // merge in two different orders — and once redundantly
        let (a, b, c) = (&reps[0], &reps[1], &reps[2]);
        let mut fwd = a.clone();
        fwd.merge(b);
        fwd.merge(c);
        let mut rev = c.clone();
        rev.merge(b);
        rev.merge(a);
        rev.merge(b); // duplicate delivery is a no-op
        assert_eq!(fwd, rev, "seed {seed}: counter merge order-dependent");
        assert_eq!(fwd.value(), expected, "seed {seed}: merged value is not the global sum");
    });
}

#[test]
fn prop_ormap_merge_is_commutative_associative_idempotent() {
    run_seeded("ormap_merge_laws", &seeds(), |seed| {
        let mut rng = Rng::new(seed);
        let reps = random_ormaps(&mut rng, 3, 120);
        let (a, b, c) = (&reps[0], &reps[1], &reps[2]);

        let mut ab = a.clone();
        ab.merge(b);
        let mut ba = b.clone();
        ba.merge(a);
        assert_eq!(ab, ba, "seed {seed}: map merge not commutative");

        let mut ab_c = ab.clone();
        ab_c.merge(c);
        let mut bc = b.clone();
        bc.merge(c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc, "seed {seed}: map merge not associative");

        let mut aa = a.clone();
        aa.merge(a);
        assert_eq!(&aa, a, "seed {seed}: map merge not idempotent");
    });
}

// -------------------------------------------------------------------
// observed-remove semantics: add-wins, and removal without tombstones
// -------------------------------------------------------------------

#[test]
fn concurrent_add_wins_over_remove() {
    // common past: both replicas observe e under dot (s0, 1)
    let mut a = Orswot::new();
    a.add(b"e".to_vec(), a.mint(Actor::server(0)));
    let mut b = a.clone();

    // concurrently: A re-adds e under a fresh dot, B removes what it saw
    a.add(b"e".to_vec(), a.mint(Actor::server(0)));
    let (removed, _) = b.remove(b"e");
    assert_eq!(removed.len(), 1, "B removed the observed dot");
    assert!(!b.contains(b"e"));

    // both merge orders keep e: the unobserved dot survives the remove
    let mut ab = a.clone();
    ab.merge(&b);
    let mut ba = b.clone();
    ba.merge(&a);
    assert_eq!(ab, ba);
    assert!(ab.contains(b"e"), "add-wins: the concurrent add survives");
    assert_eq!(ab.dot_count(), 1, "only the unobserved dot remains");
}

#[test]
fn removal_keeps_no_tombstone_and_still_beats_stale_state() {
    // A holds e; B has fully observed A
    let mut a = Orswot::new();
    a.add(b"e".to_vec(), a.mint(Actor::server(0)));
    let mut b = Orswot::new();
    b.merge(&a);

    // B removes e — its state must shrink back to (clock-only) empty
    let before_len = {
        let mut buf = Vec::new();
        b.encode(&mut buf);
        buf.len()
    };
    b.remove(b"e");
    assert!(b.is_empty());
    assert_eq!(b.dot_count(), 0, "no per-element residue after remove");
    let after_len = {
        let mut buf = Vec::new();
        b.encode(&mut buf);
        buf.len()
    };
    assert!(after_len < before_len, "removal shrinks the encoded state — no tombstone");

    // the stale replica A still carries e under its observed dot; the
    // merge must NOT resurrect it (B's clock covers the dot)
    b.merge(&a);
    assert!(!b.contains(b"e"), "covered dot stays removed without a tombstone");
    // and the reverse direction converges to the same (empty) membership
    a.merge(&b);
    assert!(!a.contains(b"e"));
    assert_eq!(a, b);
}

// -------------------------------------------------------------------
// delta replication ≡ full-state replication (and the fallback)
// -------------------------------------------------------------------

#[test]
fn prop_set_deltas_replicate_exactly_until_a_gap_forces_full_state() {
    run_seeded("set_delta_vs_full", &seeds(), |seed| {
        let mut rng = Rng::new(seed);
        let mut a = Orswot::new();
        let mut mirror = Orswot::new(); // receives every delta, in order
        let mut gapped = Orswot::new(); // misses the first half
        let mut deltas = Vec::new();
        for i in 0..60u64 {
            let e = elem(rng.below(8));
            let d = if rng.chance(0.3) {
                let (_, d) = a.remove(&e);
                d
            } else {
                let dot = a.mint(Actor::server(0));
                a.add(e, dot)
            };
            assert!(mirror.apply_delta(&d), "seed {seed}: in-order delta covered");
            assert_eq!(mirror, a, "seed {seed}: delta stream tracks the full state");
            if i >= 30 {
                deltas.push(d);
            }
        }
        // the gapped receiver cannot cover the late deltas' pre-context…
        let mut applied_any = false;
        for d in &deltas {
            applied_any |= gapped.apply_delta(d);
        }
        assert!(!applied_any, "seed {seed}: a gapped receiver must reject deltas");
        assert_ne!(gapped, a);
        // …so replication falls back to full state, and converges
        gapped.merge(&a);
        assert_eq!(gapped, a, "seed {seed}: full-state fallback converges");
    });
}

#[test]
fn counter_deltas_are_idempotent_under_duplicate_delivery() {
    let mut a = PnCounter::new();
    let mut mirror = PnCounter::new();
    for (actor, by) in [(0u32, 5i64), (1, -2), (0, 3), (2, 7), (1, -1)] {
        let d = a.incr(Actor::server(actor), by);
        mirror.apply_delta(&d);
        mirror.apply_delta(&d); // duplicated on the wire
    }
    assert_eq!(mirror, a);
    assert_eq!(mirror.value(), 12);
}

// -------------------------------------------------------------------
// CrdtMech rides every backend: merkle roots, crash, wipe, heal
// -------------------------------------------------------------------

/// A deterministic typed state for `key`: kind by residue, content
/// seeded from the key — identical across stores, so converged stores
/// must agree on every digest.
fn typed_state_for(key: u64, rng: &mut Rng) -> TypedState {
    match key % 3 {
        0 => {
            let mut s = Orswot::new();
            for _ in 0..(rng.below(5) + 1) {
                let dot = s.mint(Actor::server((key % 4) as u32));
                s.add(elem(rng.below(8)), dot);
            }
            if rng.chance(0.4) {
                let e = elem(rng.below(8));
                s.remove(&e);
            }
            TypedState::Set(s)
        }
        1 => {
            let mut c = PnCounter::new();
            for _ in 0..(rng.below(4) + 1) {
                c.incr(Actor::server(rng.below(3) as u32), rng.below(9) as i64 - 4);
            }
            TypedState::Counter(c)
        }
        _ => {
            let mut m = OrMap::new();
            for _ in 0..(rng.below(4) + 1) {
                let dot = m.mint(Actor::server((key % 4) as u32));
                m.put(elem(rng.below(6)), format!("v{}", rng.below(50)).into_bytes(), dot);
            }
            TypedState::Map(m)
        }
    }
}

/// Per-shard incremental roots must equal trees rebuilt from scratch —
/// the same scan-equivalence invariant the DVV stores maintain, now
/// driven by the CRDT join.
fn assert_matches_rebuild<B: StorageBackend<CrdtMech>>(
    seed: u64,
    label: &str,
    store: &KeyStore<CrdtMech, B>,
) {
    use dvvstore::antientropy::merkle;
    let backend = store.backend();
    for shard in 0..backend.shard_count() {
        let incremental = backend.merkle_root(shard);
        let mut fresh = merkle::ShardTree::rebuild(backend.keys_in_shard(shard).into_iter().map(
            |k| {
                let sd = backend
                    .with_state(k, |st| CrdtMech::state_digest(st.expect("listed key present")));
                (k, sd)
            },
        ));
        assert_eq!(
            incremental,
            fresh.root(),
            "seed {seed}: {label} shard {shard} incremental root drifted from rebuild"
        );
    }
}

#[test]
fn crdt_states_ride_every_backend_with_identical_merkle_roots() {
    run_seeded("crdt_backend_ride", &seeds(), |seed| {
        let flat = KeyStore::new(CrdtMech);
        let striped = KeyStore::with_backend(CrdtMech, ShardedBackend::with_shards(8));
        let dir = temp_dir("crdt-ride");
        let opts = WalOptions { fsync: FsyncPolicy::Always, ..WalOptions::default() };
        let durable =
            KeyStore::with_backend(CrdtMech, DurableBackend::open(&dir, 4, opts).unwrap());

        // install the same typed states into all three backends through
        // the ordinary replica-merge path
        for key in 0..96u64 {
            let mut rng = Rng::new(seed ^ key.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let st = Some(typed_state_for(key, &mut rng));
            flat.merge_key(key, &st);
            striped.merge_key(key, &st);
            durable.merge_key(key, &st);
        }
        assert_matches_rebuild(seed, "flat", &flat);
        assert_matches_rebuild(seed, "striped", &striped);
        assert_matches_rebuild(seed, "durable", &durable);
        let root = flat.merkle_root();
        assert_ne!(root, 0, "seed {seed}: stores are non-empty");
        assert_eq!(root, striped.merkle_root(), "seed {seed}: striped root diverges");
        assert_eq!(root, durable.merkle_root(), "seed {seed}: durable root diverges");

        // crash-restart: WAL replay rebuilds the identical typed states
        durable.backend().crash_restart();
        assert_eq!(durable.merkle_root(), root, "seed {seed}: crdt state lost in crash");
        assert_matches_rebuild(seed, "durable-restarted", &durable);

        // wipe one replica, heal it back through merges alone
        striped.backend().wipe();
        assert_eq!(striped.merkle_root(), 0);
        for k in flat.keys() {
            striped.merge_key(k, &flat.state(k));
        }
        assert_eq!(striped.merkle_root(), root, "seed {seed}: merge-healed replica diverges");

        // diverge one key, locate it by digest scan, converge again
        let hot = 42u64;
        let extra = {
            let mut s = Orswot::new();
            // a different actor, so this state is concurrent news
            let dot = s.mint(Actor::server(9));
            s.add(b"late".to_vec(), dot);
            Some(TypedState::Set(s))
        };
        flat.merge_key(hot, &extra);
        assert_ne!(flat.merkle_root(), striped.merkle_root());
        let differing: Vec<u64> = flat
            .keys()
            .into_iter()
            .filter(|&k| {
                CrdtMech::state_digest(&flat.state(k)) != CrdtMech::state_digest(&striped.state(k))
            })
            .collect();
        assert_eq!(differing, vec![hot], "seed {seed}: digest scan pinpoints the drift");
        striped.merge_key(hot, &flat.state(hot));
        assert_eq!(flat.merkle_root(), striped.merkle_root(), "seed {seed}: healed");

        std::fs::remove_dir_all(&dir).unwrap();
    });
}
