//! Round-trip tests for the TCP wire protocol (`server::protocol`):
//! request parsing, response formatting, the `FAULT`/`HEAL` admin
//! commands, and malformed-input rejection — plus an end-to-end pass
//! through a live TCP server driving the chaos fabric.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use dvvstore::server::protocol::{
    format_values, hex_decode, hex_encode, parse_request, FaultCmd, Request,
};
use dvvstore::server::tcp::Server;
use dvvstore::server::LocalCluster;
use dvvstore::testkit::prop::{forall, from_fn, Config};
use dvvstore::testkit::Rng;

// -------------------------------------------------------------------
// pure parse/format round trips
// -------------------------------------------------------------------

#[test]
fn hex_roundtrips_arbitrary_bytes() {
    let cases: Vec<Vec<u8>> = vec![
        vec![],
        vec![0],
        vec![0xff],
        (0..=255).collect(),
        b"hello world".to_vec(),
    ];
    for data in cases {
        let encoded = hex_encode(&data);
        assert_eq!(hex_decode(&encoded).unwrap(), data, "case {encoded}");
    }
    assert_eq!(hex_encode(&[]), "-", "empty encodes as the dash sentinel");
    assert_eq!(hex_decode("-").unwrap(), Vec::<u8>::new());
}

#[test]
fn prop_hex_roundtrips_and_matches_reference_encoder() {
    // the lookup-table encoder must behave exactly like the per-byte
    // `format!("{b:02x}")` it replaced, and decode must invert it
    forall(
        &Config::default().cases(300),
        from_fn(|rng: &mut Rng, size| {
            let len = rng.below(size as u64 * 4 + 2) as usize;
            (0..len).map(|_| rng.below(256) as u8).collect::<Vec<u8>>()
        }),
        |data| {
            let encoded = hex_encode(data);
            let reference: String = data.iter().map(|b| format!("{b:02x}")).collect();
            let expected = if data.is_empty() { "-".to_string() } else { reference };
            encoded == expected && hex_decode(&encoded).unwrap() == *data
        },
    );
}

#[test]
fn hex_rejects_malformed_input() {
    // "+1+2" guards the from_str_radix leading-sign loophole: it must
    // not be silently accepted as [0x01, 0x02]
    for bad in ["a", "abc", "zz", "0g", "0x1f", "+1+2", "-1", "1 2", "🦀"] {
        assert!(hex_decode(bad).is_err(), "{bad:?} must be rejected");
    }
}

#[test]
fn request_lines_roundtrip_through_parse() {
    let cases = [
        ("GET user:1", Request::Get { key: "user:1".into() }),
        (
            "PUT k 6869",
            Request::Put { key: "k".into(), value: b"hi".to_vec(), context: vec![] },
        ),
        (
            "PUT k - 0101",
            Request::Put { key: "k".into(), value: vec![], context: vec![1, 1] },
        ),
        ("STATS", Request::Stats),
        ("QUIT", Request::Quit),
        ("FAULT CRASH 0", Request::Fault(FaultCmd::Crash { node: 0 })),
        (
            "FAULT PARTITION 0,1 2,3,4",
            Request::Fault(FaultCmd::Partition {
                left: vec![0, 1],
                right: vec![2, 3, 4],
            }),
        ),
        ("FAULT DROP 0", Request::Fault(FaultCmd::Drop { ppm: 0 })),
        ("FAULT DROP 1", Request::Fault(FaultCmd::Drop { ppm: 1_000_000 })),
        ("FAULT DROP 0.125", Request::Fault(FaultCmd::Drop { ppm: 125_000 })),
        ("FAULT DELAY 0", Request::Fault(FaultCmd::Delay { us: 0 })),
        ("FAULT DELAY 50000", Request::Fault(FaultCmd::Delay { us: 50_000 })),
        ("HEAL", Request::Heal { node: None }),
        ("HEAL 3", Request::Heal { node: Some(3) }),
        ("  get  padded  ", Request::Get { key: "padded".into() }),
    ];
    for (line, want) in cases {
        assert_eq!(parse_request(line).unwrap(), want, "line {line:?}");
    }
}

#[test]
fn malformed_requests_are_rejected() {
    for bad in [
        "",
        "   ",
        "GET",
        "PUT",
        "PUT k",
        "PUT k xyz",
        "PUT k 00 zz",
        "NOPE x",
        "FAULT",
        "FAULT CRASH",
        "FAULT CRASH -1",
        "FAULT CRASH two",
        "FAULT PARTITION",
        "FAULT PARTITION 0,1",
        "FAULT PARTITION 0;1 2",
        "FAULT PARTITION , 1",
        "FAULT DROP",
        "FAULT DROP 2",
        "FAULT DROP -0.5",
        "FAULT DROP half",
        "FAULT DELAY",
        "FAULT DELAY -1",
        "FAULT DELAY soon",
        "FAULT JITTER 5",
        "HEAL one",
        "HEAL -2",
    ] {
        assert!(parse_request(bad).is_err(), "{bad:?} must be rejected");
    }
}

#[test]
fn format_values_shapes() {
    // empty answer: header only, dash context
    assert_eq!(format_values(&[], &[]), "VALUES 0 -\n");
    // values and context render hex, one VALUE line each
    let text = format_values(&[b"a".to_vec(), vec![]], &[0xab]);
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines, vec!["VALUES 2 ab", "VALUE 61", "VALUE -"]);
    // round trip: every VALUE line decodes back to the original bytes
    for (line, want) in lines[1..].iter().zip([b"a".to_vec(), vec![]]) {
        let hex = line.strip_prefix("VALUE ").unwrap();
        assert_eq!(hex_decode(hex).unwrap(), want);
    }
}

// -------------------------------------------------------------------
// end-to-end: FAULT/HEAL over a live TCP connection
// -------------------------------------------------------------------

fn client(addr: std::net::SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
    let stream = TcpStream::connect(addr).unwrap();
    (BufReader::new(stream.try_clone().unwrap()), stream)
}

fn send(w: &mut TcpStream, line: &str) {
    w.write_all(line.as_bytes()).unwrap();
    w.write_all(b"\n").unwrap();
}

fn recv(r: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    line.trim_end().to_string()
}

#[test]
fn fault_and_heal_admin_commands_drive_the_fabric() {
    let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
    let server = Server::start("127.0.0.1:0", cluster.clone()).unwrap();
    let (mut r, mut w) = client(server.addr());

    send(&mut w, "FAULT CRASH 2");
    assert_eq!(recv(&mut r), "OK");
    assert!(!cluster.fabric().is_up(2));

    // the cluster still serves under the fault (R=W=2 of 3)
    send(&mut w, &format!("PUT k {}", hex_encode(b"x")));
    assert_eq!(recv(&mut r), "OK");
    send(&mut w, "GET k");
    assert!(recv(&mut r).starts_with("VALUES 1 "));
    let _ = recv(&mut r); // VALUE line

    send(&mut w, "FAULT PARTITION 0 1");
    assert_eq!(recv(&mut r), "OK");
    assert!(cluster.fabric().is_partitioned(0, 1));

    send(&mut w, "FAULT DROP 0.5");
    assert_eq!(recv(&mut r), "OK");
    assert!((cluster.fabric().drop_prob() - 0.5).abs() < 1e-9);

    send(&mut w, "FAULT DELAY 200");
    assert_eq!(recv(&mut r), "OK");
    assert_eq!(cluster.fabric().extra_delay_us(), 200);

    // out-of-range targets are refused, connection stays usable
    send(&mut w, "FAULT CRASH 9");
    assert!(recv(&mut r).starts_with("ERR "));
    send(&mut w, "FAULT PARTITION 0 9");
    assert!(recv(&mut r).starts_with("ERR "));
    send(&mut w, "HEAL 9");
    assert!(recv(&mut r).starts_with("ERR "));

    // HEAL resets every axis
    send(&mut w, "HEAL");
    assert_eq!(recv(&mut r), "OK");
    assert!(cluster.fabric().is_up(2));
    assert!(!cluster.fabric().is_partitioned(0, 1));
    assert_eq!(cluster.fabric().drop_prob(), 0.0);
    assert_eq!(cluster.fabric().extra_delay_us(), 0);

    // STATS reports the hint backlog field
    send(&mut w, "STATS");
    let stats = recv(&mut r);
    assert!(stats.contains(" hints=0"), "{stats}");

    send(&mut w, "QUIT");
    assert_eq!(recv(&mut r), "BYE");
    server.shutdown();
}

#[test]
fn heal_drains_hints_created_under_fault() {
    // W = N = 3: crashing a home replica forces a hinted stand-in write
    let cluster = Arc::new(LocalCluster::new(5, 3, 2, 3).unwrap());
    let server = Server::start("127.0.0.1:0", cluster.clone()).unwrap();
    let (mut r, mut w) = client(server.addr());

    let down = cluster.replicas_of("hh")[1];
    send(&mut w, &format!("FAULT CRASH {down}"));
    assert_eq!(recv(&mut r), "OK");
    send(&mut w, &format!("PUT hh {}", hex_encode(b"v")));
    assert_eq!(recv(&mut r), "OK");
    send(&mut w, "STATS");
    assert!(recv(&mut r).contains(" hints=1"));

    send(&mut w, &format!("HEAL {down}"));
    assert_eq!(recv(&mut r), "OK");
    send(&mut w, "STATS");
    assert!(recv(&mut r).contains(" hints=0"), "HEAL <node> drains hints");

    send(&mut w, "QUIT");
    assert_eq!(recv(&mut r), "BYE");
    server.shutdown();
}
