//! Sloppy quorum + hinted handoff: a partitioned or crashed home replica
//! must not block writes (the paper's write-availability motivation);
//! once the fault heals, parked hints drain to their home and all home
//! replicas hold order-insensitive equal sibling sets.

use dvvstore::antientropy::same_siblings;
use dvvstore::cluster::ring::hash_str;
use dvvstore::server::LocalCluster;
use dvvstore::Error;

/// W = N = 3 on a 5-node ring: with a home replica down, a strict quorum
/// could never ack — the stand-in must.
fn strict_write_cluster() -> LocalCluster {
    LocalCluster::new(5, 3, 2, 3).unwrap()
}

#[test]
fn crashed_home_replica_gets_a_hint_then_heals() {
    let c = strict_write_cluster();
    let key = "handoff";
    let k = hash_str(key);
    let replicas = c.replicas_of(key);
    let down = replicas[1];
    c.fabric().crash(down);

    // the write still reaches W=3 acks through a stand-in
    c.put(key, b"v1".to_vec(), &[]).unwrap();
    assert_eq!(c.pending_hints(), 1, "one hint parked for the dead home");
    // reads answer from the two live home replicas
    assert_eq!(c.get(key).unwrap().values, vec![b"v1".to_vec()]);
    // the dead replica saw nothing
    assert_eq!(c.node(down).store().sibling_count(k), 0);
    // a stand-in outside the preference list holds the write
    let holder = (0..c.node_count())
        .find(|n| !replicas.contains(n) && c.node(*n).store().sibling_count(k) > 0)
        .expect("some stand-in stores the sloppy write");

    // heal: the hint drains home
    c.fabric().recover(down);
    assert_eq!(c.drain_hints(), 1);
    assert_eq!(c.pending_hints(), 0);
    let base = c.node(replicas[0]).store().state(k);
    assert!(!base.is_empty());
    for &r in &replicas {
        assert!(
            same_siblings(&base, &c.node(r).store().state(k)),
            "home replica {r} diverged after handoff"
        );
    }
    // the stand-in keeps its copy until anti-entropy; it is off the
    // preference list so reads never consult it
    assert!(c.node(holder).store().sibling_count(k) > 0);
}

#[test]
fn partitioned_home_replica_gets_a_hint_then_heals() {
    let c = strict_write_cluster();
    let key = "handoff-partition";
    let k = hash_str(key);
    let replicas = c.replicas_of(key);
    let isolated = replicas[1];
    let rest: Vec<usize> = (0..c.node_count()).filter(|&n| n != isolated).collect();
    c.fabric().partition_groups(&[isolated], &rest);

    c.put(key, b"v1".to_vec(), &[]).unwrap();
    assert_eq!(c.pending_hints(), 1);
    assert_eq!(c.node(isolated).store().sibling_count(k), 0, "isolated, not crashed");

    c.fabric().heal_all();
    assert_eq!(c.drain_hints(), 1);
    for &r in &replicas {
        assert!(
            same_siblings(&c.node(replicas[0]).store().state(k), &c.node(r).store().state(k)),
            "home replica {r} diverged after handoff"
        );
    }
}

#[test]
fn hints_are_parked_even_when_the_quorum_is_already_met() {
    // W = 2 of N = 3: the write succeeds without the crashed home, but
    // the stand-in + hint are still created — the hint, not a later
    // anti-entropy round, is what gets the write home promptly on heal
    let c = LocalCluster::new(5, 3, 2, 2).unwrap();
    let key = "eager-hint";
    let k = hash_str(key);
    let down = c.replicas_of(key)[1];
    c.fabric().crash(down);
    c.put(key, b"v".to_vec(), &[]).unwrap();
    assert_eq!(c.pending_hints(), 1, "hint parked despite met quorum");
    c.fabric().recover(down);
    assert_eq!(c.drain_hints(), 1);
    assert_eq!(c.node(down).store().sibling_count(k), 1);
}

#[test]
fn hints_stay_parked_while_the_home_is_down() {
    let c = strict_write_cluster();
    let key = "parked";
    let down = c.replicas_of(key)[2];
    c.fabric().crash(down);
    c.put(key, b"v1".to_vec(), &[]).unwrap();
    assert_eq!(c.pending_hints(), 1);
    // the home is still down: nothing drains
    assert_eq!(c.drain_hints(), 0);
    assert_eq!(c.pending_hints(), 1);
    // even an anti-entropy round cannot reach the dead node
    c.anti_entropy_round();
    assert_eq!(c.pending_hints(), 1);
    assert_eq!(c.node(down).store().sibling_count(hash_str(key)), 0);
}

#[test]
fn anti_entropy_round_drains_hints_after_recovery() {
    let c = strict_write_cluster();
    let key = "ae-drains";
    let k = hash_str(key);
    let down = c.replicas_of(key)[1];
    c.fabric().crash(down);
    c.put(key, b"v1".to_vec(), &[]).unwrap();
    assert_eq!(c.pending_hints(), 1);

    c.fabric().recover(down);
    c.anti_entropy_round();
    assert_eq!(c.pending_hints(), 0, "AE maintenance drains hints");
    assert_eq!(c.node(down).store().sibling_count(k), 1);
}

#[test]
fn write_fails_when_no_stand_in_can_reach_quorum() {
    let c = strict_write_cluster();
    let key = "doomed";
    let replicas = c.replicas_of(key);
    // crash everything except the coordinator: 1 ack < W=3, and no
    // reachable stand-in exists
    for n in 0..c.node_count() {
        if n != replicas[0] {
            c.fabric().crash(n);
        }
    }
    let err = c.put(key, b"v1".to_vec(), &[]).unwrap_err();
    assert!(
        matches!(err, Error::QuorumNotMet { got: 1, needed: 3 }),
        "sloppy quorum must still fail honestly: {err}"
    );

    // heal and retry the write the honest way: read (the failed attempt
    // persists at the coordinator — no rollback), then write with the
    // context so the retry supersedes it everywhere
    c.fabric().heal_all();
    let ans = c.get(key).unwrap();
    c.put(key, b"v1-retry".to_vec(), &ans.context).unwrap();
    assert_eq!(c.pending_hints(), 0);
    for &r in &replicas {
        assert_eq!(c.node(r).store().sibling_count(hash_str(key)), 1);
    }
    assert_eq!(c.get(key).unwrap().values, vec![b"v1-retry".to_vec()]);
}

#[test]
fn sloppy_write_supersedes_correctly_after_heal() {
    // the full cycle: write around a dead home, heal, read-modify-write
    // must supersede the hinted sibling everywhere
    let c = strict_write_cluster();
    let key = "cycle";
    let k = hash_str(key);
    let down = c.replicas_of(key)[1];
    c.fabric().crash(down);
    c.put(key, b"old".to_vec(), &[]).unwrap();
    c.fabric().recover(down);
    c.drain_hints();

    let ans = c.get(key).unwrap();
    assert_eq!(ans.values, vec![b"old".to_vec()]);
    c.put(key, b"new".to_vec(), &ans.context).unwrap();
    assert_eq!(c.get(key).unwrap().values, vec![b"new".to_vec()]);
    // convergence via anti-entropy: every node ends with exactly the
    // superseding version
    while c.anti_entropy_round() > 0 {}
    for n in 0..c.node_count() {
        let st = c.node(n).store().state(k);
        if !st.is_empty() {
            assert_eq!(st.len(), 1, "node {n} holds stale siblings: {st:?}");
        }
    }
}
