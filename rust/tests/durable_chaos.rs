//! Crash-with-state-loss chaos: seeded [`FaultPlan`]s mixing
//! `Wipe`/`Restart` with partitions, crashes, and drops — run against
//! **both worlds** (the DES and the threaded durable cluster), oracle-
//! verified.
//!
//! The properties, per seed:
//!
//! 1. zero lost **acknowledged** updates: a write acked to a client
//!    survives one node's state loss, because the write quorum put a
//!    copy somewhere else and recovery-from-disk plus hinted handoff
//!    plus anti-entropy bring it back;
//! 2. post-heal convergence: after the schedule ends, every member pair
//!    holds identical sibling sets;
//! 3. the mechanism itself still never discards a concurrent update
//!    (oracle `lost_updates == 0`) — state loss must not masquerade as
//!    a causality bug or vice versa.
//!
//! One plan value drives the simulator ([`FaultPlan::apply`] →
//! `schedule_restart`/`schedule_wipe` with the DES persisted-prefix
//! model) and the threaded cluster ([`LocalCluster::advance_plan`] →
//! `restart_node`/`wipe_node` against real WAL files), so the
//! acceptance scenario — restart from a real on-disk log, rejoin, zero
//! acked loss — holds identically in both.
//!
//! The default gate runs fixed seeds; `WAL_ITERS=<n>` appends derived
//! seeds (uniform failure format via `testkit::soak`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use dvvstore::antientropy::diff_pairs;
use dvvstore::clocks::Actor;
use dvvstore::cluster::ring::hash_str;
use dvvstore::kernel::mechs::DvvMech;
use dvvstore::oracle::SharedOracle;
use dvvstore::server::LocalCluster;
use dvvstore::sim::failure::FaultPlan;
use dvvstore::store::{DurableBackend, FsyncPolicy, WalOptions};
use dvvstore::testkit::{run_seeded, soak_seeds, temp_dir, Rng};
use dvvstore::workload::key_name;

const NODES: usize = 5;
const KEYS: u64 = 8;
const CLIENTS: u32 = 3;
const HORIZON_US: u64 = 300_000;

fn seeds() -> Vec<u64> {
    soak_seeds(&[71, 72, 73], "WAL_ITERS")
}

/// Random crash/partition/degrade schedule plus exactly one state-loss
/// event (wipe or restart) — the scenario class this test owns.
fn loss_plan(seed: u64) -> FaultPlan {
    let mut rng = Rng::new(seed);
    FaultPlan::random_chaos(NODES, HORIZON_US, &mut rng)
        .random_loss_event(NODES, HORIZON_US, &mut rng)
}

/// WAL tuning for the threaded runs: small segments so compaction and
/// rolls actually happen mid-test, every-4 fsync so a restart has a
/// real (but bounded) loss window.
fn wal_opts() -> WalOptions {
    WalOptions { segment_bytes: 16 * 1024, fsync: FsyncPolicy::EveryN(4) }
}

/// Drive the plan against a durable threaded cluster while client
/// threads hammer traced quorum ops; returns the acked `(key, id)`
/// pairs for the survivor audit.
fn threaded_run(
    seed: u64,
    cluster: &Arc<LocalCluster<DurableBackend<DvvMech>>>,
) -> Vec<(u64, u64)> {
    let plan = loss_plan(seed);
    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    for t in 0..CLIENTS {
        let cluster = Arc::clone(cluster);
        let stop = Arc::clone(&stop);
        workers.push(std::thread::spawn(move || {
            let me = Actor::client(t);
            let mut rng = Rng::new(seed.wrapping_mul(0x9E37).wrapping_add(u64::from(t)));
            let mut sessions: Vec<Option<(Vec<u8>, Vec<u64>)>> = vec![None; KEYS as usize];
            let mut acked: Vec<(u64, u64)> = Vec::new();
            let mut op = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let ki = rng.below(KEYS);
                let key = key_name(ki);
                if rng.chance(0.5) {
                    if let Ok(ans) = cluster.get(&key) {
                        sessions[ki as usize] = Some((ans.context, ans.ids));
                    }
                } else {
                    let (ctx, observed) =
                        sessions[ki as usize].clone().unwrap_or_default();
                    let body = format!("c{t}-{op}").into_bytes();
                    if let Ok(id) = cluster.put_traced(&key, body, &ctx, me, &observed) {
                        acked.push((ki, id));
                    }
                }
                op += 1;
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            acked
        }));
    }
    const STEPS: u64 = 50;
    for step in 1..=STEPS {
        cluster.advance_plan(&plan, HORIZON_US * step / STEPS);
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    stop.store(true, Ordering::Relaxed);
    let mut acked = Vec::new();
    for w in workers {
        acked.extend(w.join().unwrap());
    }
    acked
}

/// Heal, quiesce, and assert the three durability-chaos properties.
fn audit_threaded(
    seed: u64,
    cluster: &LocalCluster<DurableBackend<DvvMech>>,
    oracle: &SharedOracle,
    acked: &[(u64, u64)],
) {
    cluster.fabric().heal_all();
    cluster.drain_hints();
    let mut rounds = 0;
    while cluster.anti_entropy_round() > 0 {
        rounds += 1;
        assert!(rounds < 32, "seed {seed}: anti-entropy failed to quiesce");
    }
    assert_eq!(cluster.pending_hints(), 0, "seed {seed}: hints not drained");
    for a in 0..NODES {
        for b in (a + 1)..NODES {
            let diverged = diff_pairs(cluster.node(a).store(), cluster.node(b).store());
            assert!(
                diverged.is_empty(),
                "seed {seed}: nodes {a}/{b} diverged after heal on {} keys",
                diverged.len()
            );
        }
    }
    let verdict = oracle.verdict();
    assert_eq!(verdict.unaudited_drops, 0, "seed {seed}: untraced writes leaked in");
    assert_eq!(
        verdict.lost_updates, 0,
        "seed {seed}: mechanism lost updates under state loss"
    );
    assert!(!acked.is_empty(), "seed {seed}: no write was ever acknowledged");
    // the headline: every acked write survives (itself, or causally
    // covered by a survivor) even though one node lost state
    for &(ki, id) in acked {
        let k = hash_str(&key_name(ki));
        let covered = (0..NODES).any(|n| {
            cluster
                .node(n)
                .store()
                .values(k)
                .iter()
                .any(|v| v.id == id || oracle.with_inner(|o| o.leq(id, v.id)))
        });
        assert!(covered, "seed {seed}: acked write {id} on key {ki} lost");
    }
}

#[test]
fn state_loss_chaos_threaded_durable_cluster() {
    run_seeded("durable_chaos_threaded", &seeds(), |seed| {
        let dir = temp_dir("durable-chaos");
        let cluster =
            LocalCluster::with_data_dir(NODES, 3, 2, 2, 4, &dir, wal_opts()).unwrap();
        let oracle = Arc::new(SharedOracle::new());
        cluster.attach_oracle(Arc::clone(&oracle));
        cluster.fabric().reseed(seed ^ 0xD00D);
        let cluster = Arc::new(cluster);
        let acked = threaded_run(seed, &cluster);
        audit_threaded(seed, &cluster, &oracle, &acked);
        std::fs::remove_dir_all(&dir).unwrap();
    });
}

/// The same plan generator against the DES with the persisted-prefix
/// durability model (`flush_every_ops = 4`, mirroring the threaded
/// `FsyncPolicy::EveryN(4)`).
fn des_run(seed: u64) {
    let mut cfg = dvvstore::config::StoreConfig::default();
    cfg.cluster.nodes = NODES;
    cfg.cluster.replication = 3;
    cfg.cluster.read_quorum = 2;
    cfg.cluster.write_quorum = 2;
    cfg.antientropy.period_us = 20_000;
    cfg.durability.flush_every_ops = 4;
    let driver = Box::new(dvvstore::workload::RandomWorkload::new(
        dvvstore::workload::WorkloadSpec {
            keys: KEYS as usize,
            ops_per_client: 40,
            put_fraction: 0.6,
            read_before_write: 0.5,
            mean_think_us: 400.0,
            ..Default::default()
        },
        CLIENTS as usize,
    ));
    let mut sim =
        dvvstore::sim::Sim::new(DvvMech, cfg, CLIENTS as usize, true, driver, seed).unwrap();
    loss_plan(seed).apply(&mut sim);
    sim.start();
    sim.run(5_000_000);
    sim.settle();
    assert!(sim.writes_acked() > 0, "seed {seed}: nothing acked");
    assert_eq!(
        sim.audit_acked_lost(),
        0,
        "seed {seed}: acked update lost in the DES ({})",
        sim.metrics.summary()
    );
    assert_eq!(
        sim.metrics.lost_updates, 0,
        "seed {seed}: mechanism lost updates in the DES"
    );
    // post-settle convergence across members, pairwise
    let members = sim.members();
    for (ai, &a) in members.iter().enumerate() {
        for &b in members.iter().skip(ai + 1) {
            for key in 0..KEYS {
                assert_eq!(
                    sim.nodes[a].store.state(key),
                    sim.nodes[b].store.state(key),
                    "seed {seed}: members {a}/{b} diverged on key {key}"
                );
            }
        }
    }
}

#[test]
fn state_loss_chaos_des_with_persisted_prefix_model() {
    run_seeded("durable_chaos_des", &seeds(), des_run);
}

/// The acceptance scenario end-to-end, one pinned seed: the identical
/// plan value drives the DES and the threaded durable cluster, and both
/// reach the same verdicts — zero lost acknowledged updates and
/// post-heal convergence.
#[test]
fn same_seeded_plan_reaches_the_same_verdicts_in_both_worlds() {
    let seed = 4242;
    des_run(seed);
    let dir = temp_dir("durable-parity");
    let cluster = LocalCluster::with_data_dir(NODES, 3, 2, 2, 4, &dir, wal_opts()).unwrap();
    let oracle = Arc::new(SharedOracle::new());
    cluster.attach_oracle(Arc::clone(&oracle));
    cluster.fabric().reseed(seed ^ 0xD00D);
    let cluster = Arc::new(cluster);
    let acked = threaded_run(seed, &cluster);
    audit_threaded(seed, &cluster, &oracle, &acked);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The acceptance criterion's torn-tail leg: a cluster whose node logs
/// were damaged after shutdown (a torn final record on every shard)
/// reopens without panic, reports the discarded bytes, rejoins, and
/// serves every write after one anti-entropy round.
#[test]
fn torn_tail_restart_recovers_and_rejoins() {
    let dir = temp_dir("durable-torn");
    let opts = WalOptions { fsync: FsyncPolicy::Always, ..WalOptions::default() };
    {
        let c = LocalCluster::with_data_dir(4, 3, 2, 2, 4, &dir, opts).unwrap();
        for i in 0..40 {
            c.put(&key_name(i), format!("val{i}").into_bytes(), &[]).unwrap();
        }
    }
    // tear node 1's logs: chop bytes off the tail of every segment so
    // the final record of each is a torn, CRC-failing fragment
    let mut torn_files = 0;
    for entry in walk(&dir.join("node-1")) {
        let len = std::fs::metadata(&entry).unwrap().len();
        if len > 12 {
            let f = std::fs::OpenOptions::new().write(true).open(&entry).unwrap();
            f.set_len(len - 3).unwrap();
            torn_files += 1;
        }
    }
    assert!(torn_files > 0, "fixture wrote logs to tear");

    let c = LocalCluster::with_data_dir(4, 3, 2, 2, 4, &dir, opts).unwrap();
    let report = c.node(1).store().backend().recovery_report().clone();
    assert!(report.truncated, "torn tails were detected");
    assert!(report.discarded_bytes > 0, "discarded bytes are reported, not silent");
    // rejoin: anti-entropy re-delivers what the torn records lost
    // (bounded: a convergence bug must fail, not hang)
    let mut rounds = 0;
    while c.anti_entropy_round() > 0 {
        rounds += 1;
        assert!(rounds < 32, "anti-entropy failed to quiesce");
    }
    for i in 0..40 {
        let ans = c.get(&key_name(i)).unwrap();
        assert_eq!(ans.ids.len(), 1, "key {i} readable with one survivor");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Recursively list files under `root`.
fn walk(root: &std::path::Path) -> Vec<std::path::PathBuf> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir).unwrap() {
            let path = entry.unwrap().path();
            if path.is_dir() {
                stack.push(path);
            } else {
                out.push(path);
            }
        }
    }
    out
}
