//! Transport equivalence: the same seeded workload (and the same
//! `FaultPlan`) driven through [`KvClient`] against all three
//! transports — the discrete-event simulator, the threaded
//! `LocalCluster`, and live TCP — must produce identical oracle
//! verdicts (zero lost updates, fully audited) and, fault-free,
//! identical converged sibling values. Includes the first end-to-end
//! chaos + oracle verification over real sockets.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use dvvstore::antientropy::diff_pairs;
use dvvstore::api::{
    drive_workload, key_name, snapshot_values, KvClient, LocalClient, Session, SimTransport,
    TcpClient,
};
use dvvstore::clocks::Actor;
use dvvstore::config::StoreConfig;
use dvvstore::oracle::SharedOracle;
use dvvstore::server::tcp::Server;
use dvvstore::server::LocalCluster;
use dvvstore::sim::failure::FaultPlan;
use dvvstore::testkit::Rng;
use dvvstore::workload::{RandomWorkload, WorkloadSpec};

const NODES: usize = 5;
const CLIENTS: usize = 3;
const KEYS: u64 = 12;
const SEED: u64 = 4242;

fn spec(ops: u64) -> WorkloadSpec {
    WorkloadSpec {
        keys: KEYS,
        zipf_theta: 0.9,
        put_fraction: 0.5,
        read_before_write: 0.5,
        mean_think_us: 300.0,
        ops_per_client: ops,
        value_len: 24,
    }
}

fn sim_cfg() -> StoreConfig {
    let mut cfg = StoreConfig::default();
    cfg.cluster.nodes = NODES;
    cfg.cluster.replication = 3;
    cfg.cluster.read_quorum = 2;
    cfg.cluster.write_quorum = 2;
    cfg
}

/// Final sorted sibling values per key, read through a client.
type Snapshot = Vec<(u64, Vec<Vec<u8>>)>;

// -------------------------------------------------------------------
// fault-free equivalence: identical outcomes, bit for bit
// -------------------------------------------------------------------

#[test]
fn same_workload_same_outcome_across_all_three_transports() {
    let ops = 30;

    // --- simulator ------------------------------------------------
    let transport = SimTransport::new(sim_cfg(), CLIENTS, SEED).unwrap();
    let mut clients: Vec<_> = (0..CLIENTS).map(|i| transport.client(i)).collect();
    let mut driver = RandomWorkload::new(spec(ops), CLIENTS);
    let sim_report = drive_workload(&mut clients, &mut driver, SEED, |_| {});
    let sim_snapshot: Snapshot = snapshot_values(&mut clients[0], KEYS).unwrap();
    transport.with_sim(|sim| {
        assert_eq!(sim.metrics.lost_updates, 0);
        sim.settle();
        assert_eq!(sim.audit_permanently_lost(), 0, "{}", sim.metrics.summary());
    });

    // --- threaded cluster -----------------------------------------
    let (local_report, local_snapshot, local_verdict) = {
        let cluster = Arc::new(LocalCluster::new(NODES, 3, 2, 2).unwrap());
        let oracle = Arc::new(SharedOracle::new());
        cluster.attach_oracle(Arc::clone(&oracle));
        let mut clients: Vec<_> = (0..CLIENTS)
            .map(|i| LocalClient::new(Arc::clone(&cluster), Actor::client(i as u32)))
            .collect();
        let mut driver = RandomWorkload::new(spec(ops), CLIENTS);
        let report = drive_workload(&mut clients, &mut driver, SEED, |_| {});
        let snapshot = snapshot_values(&mut clients[0], KEYS).unwrap();
        (report, snapshot, oracle.verdict())
    };

    // --- live TCP (binary protocol v2) ----------------------------
    let (tcp_report, tcp_snapshot, tcp_verdict) = {
        let cluster = Arc::new(LocalCluster::new(NODES, 3, 2, 2).unwrap());
        let oracle = Arc::new(SharedOracle::new());
        cluster.attach_oracle(Arc::clone(&oracle));
        let server = Server::start("127.0.0.1:0", Arc::clone(&cluster)).unwrap();
        let mut clients: Vec<_> = (0..CLIENTS)
            .map(|i| TcpClient::connect(server.addr(), Actor::client(i as u32)).unwrap())
            .collect();
        let mut driver = RandomWorkload::new(spec(ops), CLIENTS);
        let report = drive_workload(&mut clients, &mut driver, SEED, |_| {});
        let snapshot = snapshot_values(&mut clients[0], KEYS).unwrap();
        for c in clients {
            c.quit().unwrap();
        }
        server.shutdown();
        (report, snapshot, oracle.verdict())
    };

    // identical op accounting: no transport failed anything fault-free
    assert_eq!(sim_report.failed_ops, 0);
    assert_eq!(sim_report, local_report, "sim vs local report");
    assert_eq!(sim_report, tcp_report, "sim vs tcp report");

    // identical oracle verdicts: zero lost updates, fully audited
    assert_eq!(local_verdict.lost_updates, 0);
    assert_eq!(local_verdict.unaudited_drops, 0);
    assert_eq!(local_verdict, tcp_verdict, "local vs tcp verdict");

    // identical converged sibling values, key by key
    assert_eq!(sim_snapshot, local_snapshot, "sim vs local final values");
    assert_eq!(sim_snapshot, tcp_snapshot, "sim vs tcp final values");
    // the workload actually wrote something
    assert!(sim_snapshot.iter().any(|(_, vals)| !vals.is_empty()));
}

// -------------------------------------------------------------------
// one FaultPlan, three worlds: identical verdicts under chaos
// -------------------------------------------------------------------

const HORIZON_US: u64 = 200_000;

fn chaos_plan() -> FaultPlan {
    // partition + degradation windows (no crashes: the DES permanent-
    // loss audit is exact when every issued write lands somewhere)
    let mut rng = Rng::new(SEED ^ 0xFA17);
    FaultPlan::new()
        .random_partitions(NODES, 2, 60_000, HORIZON_US, &mut rng)
        .degrade_window(0.25, 300, 20_000, 150_000)
}

#[test]
fn same_fault_plan_same_verdict_across_all_three_transports() {
    let ops = 40;
    let expected_ops = (CLIENTS as u64) * ops;

    // --- simulator: the plan schedules as DES events --------------
    let transport = SimTransport::new(sim_cfg(), CLIENTS, SEED).unwrap();
    transport.with_sim(|sim| chaos_plan().apply(sim));
    let mut clients: Vec<_> = (0..CLIENTS).map(|i| transport.client(i)).collect();
    let mut driver = RandomWorkload::new(spec(ops), CLIENTS);
    let report = drive_workload(&mut clients, &mut driver, SEED, |_| {});
    assert!(report.ok_ops > 0, "some sim ops must succeed");
    transport.with_sim(|sim| {
        sim.run(u64::MAX); // drain remaining fault/heal events
        sim.settle();
        assert_eq!(sim.metrics.lost_updates, 0, "{}", sim.metrics.summary());
        assert_eq!(sim.audit_permanently_lost(), 0, "{}", sim.metrics.summary());
    });

    // --- threaded cluster + live TCP: the same plan steps the fabric
    enum Transport {
        Local,
        Tcp,
    }
    for which in [Transport::Local, Transport::Tcp] {
        let cluster = Arc::new(LocalCluster::new(NODES, 3, 2, 2).unwrap());
        let oracle = Arc::new(SharedOracle::new());
        cluster.attach_oracle(Arc::clone(&oracle));
        let plan = chaos_plan();
        let step = {
            let cluster = Arc::clone(&cluster);
            move |completed: u64| {
                let t = HORIZON_US.saturating_mul(completed) / expected_ops.max(1);
                cluster.fabric().advance(&plan, t);
            }
        };
        let report = match which {
            Transport::Local => {
                let mut clients: Vec<_> = (0..CLIENTS)
                    .map(|i| LocalClient::new(Arc::clone(&cluster), Actor::client(i as u32)))
                    .collect();
                let mut driver = RandomWorkload::new(spec(ops), CLIENTS);
                drive_workload(&mut clients, &mut driver, SEED, step)
            }
            Transport::Tcp => {
                let server = Server::start("127.0.0.1:0", Arc::clone(&cluster)).unwrap();
                let mut clients: Vec<_> = (0..CLIENTS)
                    .map(|i| {
                        TcpClient::connect(server.addr(), Actor::client(i as u32)).unwrap()
                    })
                    .collect();
                let mut driver = RandomWorkload::new(spec(ops), CLIENTS);
                let report = drive_workload(&mut clients, &mut driver, SEED, step);
                for c in clients {
                    c.quit().unwrap();
                }
                server.shutdown();
                report
            }
        };
        assert!(report.ok_ops > 0, "some ops must succeed under chaos");

        // heal, converge, audit — the same closing ritual as the DES
        cluster.fabric().heal_all();
        let mut rounds = 0;
        while cluster.anti_entropy_round() > 0 {
            rounds += 1;
            assert!(rounds < 32, "anti-entropy failed to quiesce");
        }
        assert_eq!(cluster.pending_hints(), 0, "hints drained after heal");
        for a in 0..NODES {
            for b in (a + 1)..NODES {
                assert!(
                    diff_pairs(cluster.node(a).store(), cluster.node(b).store()).is_empty(),
                    "nodes {a}/{b} diverged after heal"
                );
            }
        }
        let verdict = oracle.verdict();
        assert!(verdict.tracked > 0, "writes registered");
        assert_eq!(verdict.unaudited_drops, 0, "API writes are fully traced");
        assert_eq!(
            verdict.lost_updates, 0,
            "zero lost updates ({} correct supersessions)",
            verdict.correct_supersessions
        );
    }
}

// -------------------------------------------------------------------
// end-to-end chaos + oracle over live TCP, under real concurrency
// -------------------------------------------------------------------

#[test]
fn tcp_chaos_with_concurrent_clients_is_oracle_clean() {
    let cluster = Arc::new(LocalCluster::new(NODES, 3, 2, 2).unwrap());
    let oracle = Arc::new(SharedOracle::new());
    cluster.attach_oracle(Arc::clone(&oracle));
    cluster.fabric().reseed(SEED ^ 0x7C9);
    let server = Server::start("127.0.0.1:0", Arc::clone(&cluster)).unwrap();
    let addr = server.addr();

    let mut rng = Rng::new(SEED);
    let plan = FaultPlan::random_chaos(NODES, HORIZON_US, &mut rng);

    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    for t in 0..3u32 {
        let stop = Arc::clone(&stop);
        workers.push(std::thread::spawn(move || {
            let mut client = TcpClient::connect(addr, Actor::client(t)).unwrap();
            let mut session = Session::new();
            let mut rng = Rng::new(u64::from(t) ^ SEED);
            let (mut ok_ops, mut failed_ops) = (0u64, 0u64);
            while !stop.load(Ordering::Relaxed) {
                let key = key_name(rng.below(8));
                let outcome = if rng.chance(0.5) {
                    client.get(&key).map(|reply| session.record_get(&key, &reply))
                } else {
                    let body = format!("t{t}-{ok_ops}").into_bytes();
                    let ctx = session.ctx_for(&key).cloned();
                    client
                        .put(&key, body, ctx.as_ref())
                        .map(|reply| session.record_put(&key, &reply))
                };
                // under active faults ops may fail; that is the exercise
                match outcome {
                    Ok(()) => ok_ops += 1,
                    Err(_) => failed_ops += 1,
                }
                std::thread::sleep(std::time::Duration::from_micros(300));
            }
            let _ = client.quit();
            (ok_ops, failed_ops)
        }));
    }

    // step the schedule's virtual clock while the workers hammer TCP
    const STEPS: u64 = 40;
    for step in 1..=STEPS {
        cluster.fabric().advance(&plan, HORIZON_US * step / STEPS);
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    stop.store(true, Ordering::Relaxed);
    let mut total_ok = 0;
    for worker in workers {
        total_ok += worker.join().unwrap().0;
    }
    assert!(total_ok > 0, "no TCP operation ever succeeded");

    // heal over the wire (admin frame), then converge in-process
    let mut admin = TcpClient::connect(addr, Actor::client(99)).unwrap();
    admin.admin("HEAL").unwrap();
    let mut rounds = 0;
    while cluster.anti_entropy_round() > 0 {
        rounds += 1;
        assert!(rounds < 32, "anti-entropy failed to quiesce");
    }
    let stats = admin.stats().unwrap();
    assert_eq!(stats.hints, 0, "hints drained after HEAL");
    admin.quit().unwrap();

    for a in 0..NODES {
        for b in (a + 1)..NODES {
            assert!(
                diff_pairs(cluster.node(a).store(), cluster.node(b).store()).is_empty(),
                "nodes {a}/{b} diverged after heal"
            );
        }
    }
    // fully converged stores share one hash-tree root, and that common
    // root is exactly what STATS reported over the wire
    assert_eq!(
        stats.merkle_root,
        cluster.node(0).store().merkle_root(),
        "STATS merkle_root matches the converged store root"
    );
    let verdict = oracle.verdict();
    assert!(verdict.tracked > 0);
    assert_eq!(verdict.unaudited_drops, 0, "every TCP write was traced");
    assert_eq!(verdict.lost_updates, 0, "zero lost updates over live TCP chaos");
    server.shutdown();
}
