//! Property test: [`ShardedBackend`], [`InMemoryBackend`],
//! [`DurableBackend`], and [`LsmBackend`] are observationally
//! equivalent — the backend decides *where* states live, *what locks*
//! cover them, and *whether they survive a process death*, never *what*
//! the §4 kernel computes.
//!
//! A random sequence of client PUTs (blind and informed) and
//! replica-to-replica state shipments is applied to a pair of replicas
//! per backend; every externally observable quantity must match exactly.
//! The durable variant additionally closes and reopens its stores from
//! disk mid-check: the same ops must yield the same sibling sets after
//! recovery. Failures shrink to a minimal op sequence via
//! `testkit::prop` and replay with `DVV_PROP_SEED`.

use dvvstore::clocks::Actor;
use dvvstore::kernel::mechs::DvvMech;
use dvvstore::kernel::{Val, WriteMeta};
use dvvstore::store::{
    DurableBackend, FsyncPolicy, KeyStore, LsmBackend, LsmOptions, ShardedBackend,
    StorageBackend, WalOptions,
};
use dvvstore::testkit::prop::{forall, from_fn, vecs, Config, Gen};
use dvvstore::testkit::{temp_dir, Rng};

const REPLICAS: usize = 2;
const KEYS: u64 = 16;

#[derive(Debug, Clone)]
enum Op {
    /// Client PUT at one replica; informed PUTs carry that replica's
    /// current read context, blind PUTs an empty one.
    Put { replica: usize, key: u64, informed: bool },
    /// Replication shipment: `src`'s state for `key` merged into `dst`.
    Ship { src: usize, key: u64 },
}

fn gen_ops() -> impl Gen<Value = Vec<Op>> {
    vecs(
        from_fn(|rng: &mut Rng, _size| {
            let key = rng.below(KEYS);
            if rng.chance(0.6) {
                Op::Put {
                    replica: rng.below(REPLICAS as u64) as usize,
                    key,
                    informed: rng.chance(0.5),
                }
            } else {
                Op::Ship { src: rng.below(REPLICAS as u64) as usize, key }
            }
        }),
        1,
        120,
    )
}

/// Run one op sequence against a replica pair. Val ids derive from the
/// op index, so the two backend runs see byte-identical writes.
fn apply<B: StorageBackend<DvvMech>>(stores: &[KeyStore<DvvMech, B>], ops: &[Op]) {
    let meta = WriteMeta::basic(Actor::client(0));
    for (i, op) in ops.iter().enumerate() {
        match op {
            Op::Put { replica, key, informed } => {
                let s = &stores[*replica];
                let ctx = if *informed { s.read(*key).1 } else { Default::default() };
                let val = Val::new(i as u64 + 1, 8);
                s.write(*key, &ctx, val, Actor::server(*replica as u32), &meta);
            }
            Op::Ship { src, key } => {
                let dst = (*src + 1) % REPLICAS;
                let st = stores[*src].state(*key);
                stores[dst].merge_key(*key, &st);
            }
        }
    }
}

fn flat_pair() -> Vec<KeyStore<DvvMech>> {
    (0..REPLICAS).map(|_| KeyStore::new(DvvMech)).collect()
}

fn sharded_pair() -> Vec<KeyStore<DvvMech, ShardedBackend<DvvMech>>> {
    (0..REPLICAS)
        .map(|_| KeyStore::with_backend(DvvMech, ShardedBackend::with_shards(4)))
        .collect()
}

/// Small segments so a 120-op sequence actually rolls and compacts;
/// fsync never so the sweep stays fast (a clean close loses nothing —
/// the crash-loss axis is `rust/tests/durable_chaos.rs`'s job).
fn durable_opts() -> WalOptions {
    WalOptions { segment_bytes: 2048, fsync: FsyncPolicy::Never }
}

fn durable_pair(
    dirs: &[std::path::PathBuf],
) -> Vec<KeyStore<DvvMech, DurableBackend<DvvMech>>> {
    dirs.iter()
        .map(|dir| {
            KeyStore::with_backend(
                DvvMech,
                DurableBackend::open(dir, 2, durable_opts()).unwrap(),
            )
        })
        .collect()
}

/// Tiny memtable/block/tier thresholds so a 120-op sequence exercises
/// the whole lifecycle — flushes, multi-run reads, compaction — not
/// just the memtable.
fn lsm_opts() -> LsmOptions {
    LsmOptions {
        wal: durable_opts(),
        memtable_bytes: 256,
        block_bytes: 128,
        cache_blocks: 4,
        tier_runs: 3,
    }
}

fn lsm_pair(dirs: &[std::path::PathBuf]) -> Vec<KeyStore<DvvMech, LsmBackend<DvvMech>>> {
    dirs.iter()
        .map(|dir| {
            KeyStore::with_backend(DvvMech, LsmBackend::open(dir, 2, lsm_opts()).unwrap())
        })
        .collect()
}

/// Every externally observable quantity of two stores matches.
fn equivalent<A: StorageBackend<DvvMech>, B: StorageBackend<DvvMech>>(
    a: &KeyStore<DvvMech, A>,
    b: &KeyStore<DvvMech, B>,
) -> bool {
    let mut ak: Vec<u64> = a.keys().collect();
    let mut bk: Vec<u64> = b.keys().collect();
    ak.sort_unstable();
    bk.sort_unstable();
    ak == bk
        && a.key_count() == b.key_count()
        && a.metadata_bytes() == b.metadata_bytes()
        && a.max_siblings() == b.max_siblings()
        && (0..KEYS).all(|key| {
            a.state(key) == b.state(key)
                && a.read(key) == b.read(key)
                && a.sibling_count(key) == b.sibling_count(key)
        })
}

#[test]
fn sharded_and_flat_backends_are_observationally_equivalent() {
    forall(&Config::default().cases(60), gen_ops(), |ops| {
        let flat = flat_pair();
        let sharded = sharded_pair();
        apply(&flat, ops);
        apply(&sharded, ops);
        (0..REPLICAS).all(|r| equivalent(&flat[r], &sharded[r]))
    });
}

#[test]
fn durable_backend_is_observationally_equivalent_and_survives_reopen() {
    let root = temp_dir("backend-equiv");
    let mut case = 0u64;
    forall(&Config::default().cases(30), gen_ops(), |ops| {
        case += 1;
        let dirs: Vec<std::path::PathBuf> =
            (0..REPLICAS).map(|r| root.join(format!("case{case}-r{r}"))).collect();
        let flat = flat_pair();
        let durable = durable_pair(&dirs);
        apply(&flat, ops);
        apply(&durable, ops);
        let live_ok = (0..REPLICAS).all(|r| equivalent(&flat[r], &durable[r]));

        // close-and-reopen: the same ops must yield the same sibling
        // sets after recovery from the logs alone
        drop(durable);
        let recovered = durable_pair(&dirs);
        let recovered_ok = (0..REPLICAS).all(|r| {
            recovered[r].backend().recovery_report().discarded_bytes == 0
                && equivalent(&flat[r], &recovered[r])
        });
        for dir in &dirs {
            std::fs::remove_dir_all(dir).unwrap();
        }
        live_ok && recovered_ok
    });
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn lsm_backend_is_observationally_equivalent_and_survives_reopen() {
    let root = temp_dir("backend-equiv-lsm");
    let mut case = 0u64;
    forall(&Config::default().cases(30), gen_ops(), |ops| {
        case += 1;
        let dirs: Vec<std::path::PathBuf> =
            (0..REPLICAS).map(|r| root.join(format!("case{case}-r{r}"))).collect();
        let flat = flat_pair();
        let lsm = lsm_pair(&dirs);
        apply(&flat, ops);
        apply(&lsm, ops);
        // force the rest of the lifecycle before comparing: whatever is
        // still in memtables goes to runs, and tiering merges them
        for s in &lsm {
            s.backend().flush_memtables();
            s.backend().compact_now();
        }
        let live_ok = (0..REPLICAS).all(|r| equivalent(&flat[r], &lsm[r]));

        // close-and-reopen: the same observations must come back from
        // the run files + WAL alone, with nothing quarantined
        drop(lsm);
        let recovered = lsm_pair(&dirs);
        let recovered_ok = (0..REPLICAS).all(|r| {
            let report = recovered[r].backend().recovery_report();
            report.discarded_bytes == 0
                && report.quarantined_runs == 0
                && equivalent(&flat[r], &recovered[r])
        });
        for dir in &dirs {
            std::fs::remove_dir_all(dir).unwrap();
        }
        live_ok && recovered_ok
    });
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn lsm_batched_merges_match_per_key_merges() {
    let root = temp_dir("backend-batch-lsm");
    let mut case = 0u64;
    forall(&Config::default().cases(20), gen_ops(), |ops| {
        case += 1;
        let src = flat_pair();
        apply(&src, ops);
        let items: Vec<(u64, _)> = src[0].keys().map(|k| (k, src[0].state(k))).collect();

        let dirs =
            [root.join(format!("case{case}-batched")), root.join(format!("case{case}-seq"))];
        let pair = lsm_pair(&dirs);
        pair[0].merge_batch(&items);
        for (k, st) in &items {
            pair[1].merge_key(*k, st);
        }
        let ok = (0..KEYS).all(|key| pair[0].state(key) == pair[1].state(key))
            && pair[0].key_count() == pair[1].key_count();
        drop(pair);
        for dir in &dirs {
            std::fs::remove_dir_all(dir).unwrap();
        }
        ok
    });
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn batched_merges_match_per_key_merges_across_backends() {
    forall(&Config::default().cases(40), gen_ops(), |ops| {
        let src = flat_pair();
        apply(&src, ops);
        let items: Vec<(u64, _)> = src[0].keys().map(|k| (k, src[0].state(k))).collect();

        let batched = sharded_pair().remove(0);
        batched.merge_batch(&items);
        let sequential = flat_pair().remove(0);
        for (k, st) in &items {
            sequential.merge_key(*k, st);
        }
        (0..KEYS).all(|key| batched.state(key) == sequential.state(key))
            && batched.key_count() == sequential.key_count()
    });
}
