//! Property test: [`ShardedBackend`] and [`InMemoryBackend`] are
//! observationally equivalent — the backend decides *where* states live
//! and *what locks* cover them, never *what* the §4 kernel computes.
//!
//! A random sequence of client PUTs (blind and informed) and
//! replica-to-replica state shipments is applied to a pair of replicas
//! per backend; every externally observable quantity must match exactly.
//! Failures shrink to a minimal op sequence via `testkit::prop` and
//! replay with `DVV_PROP_SEED`.

use dvvstore::clocks::Actor;
use dvvstore::kernel::mechs::DvvMech;
use dvvstore::kernel::{Val, WriteMeta};
use dvvstore::store::{KeyStore, ShardedBackend, StorageBackend};
use dvvstore::testkit::prop::{forall, from_fn, vecs, Config, Gen};
use dvvstore::testkit::Rng;

const REPLICAS: usize = 2;
const KEYS: u64 = 16;

#[derive(Debug, Clone)]
enum Op {
    /// Client PUT at one replica; informed PUTs carry that replica's
    /// current read context, blind PUTs an empty one.
    Put { replica: usize, key: u64, informed: bool },
    /// Replication shipment: `src`'s state for `key` merged into `dst`.
    Ship { src: usize, key: u64 },
}

fn gen_ops() -> impl Gen<Value = Vec<Op>> {
    vecs(
        from_fn(|rng: &mut Rng, _size| {
            let key = rng.below(KEYS);
            if rng.chance(0.6) {
                Op::Put {
                    replica: rng.below(REPLICAS as u64) as usize,
                    key,
                    informed: rng.chance(0.5),
                }
            } else {
                Op::Ship { src: rng.below(REPLICAS as u64) as usize, key }
            }
        }),
        1,
        120,
    )
}

/// Run one op sequence against a replica pair. Val ids derive from the
/// op index, so the two backend runs see byte-identical writes.
fn apply<B: StorageBackend<DvvMech>>(stores: &[KeyStore<DvvMech, B>], ops: &[Op]) {
    let meta = WriteMeta::basic(Actor::client(0));
    for (i, op) in ops.iter().enumerate() {
        match op {
            Op::Put { replica, key, informed } => {
                let s = &stores[*replica];
                let ctx = if *informed { s.read(*key).1 } else { Default::default() };
                let val = Val::new(i as u64 + 1, 8);
                s.write(*key, &ctx, val, Actor::server(*replica as u32), &meta);
            }
            Op::Ship { src, key } => {
                let dst = (*src + 1) % REPLICAS;
                let st = stores[*src].state(*key);
                stores[dst].merge_key(*key, &st);
            }
        }
    }
}

fn flat_pair() -> Vec<KeyStore<DvvMech>> {
    (0..REPLICAS).map(|_| KeyStore::new(DvvMech)).collect()
}

fn sharded_pair() -> Vec<KeyStore<DvvMech, ShardedBackend<DvvMech>>> {
    (0..REPLICAS)
        .map(|_| KeyStore::with_backend(DvvMech, ShardedBackend::with_shards(4)))
        .collect()
}

#[test]
fn sharded_and_flat_backends_are_observationally_equivalent() {
    forall(&Config::default().cases(60), gen_ops(), |ops| {
        let flat = flat_pair();
        let sharded = sharded_pair();
        apply(&flat, ops);
        apply(&sharded, ops);
        (0..REPLICAS).all(|r| {
            let mut fk: Vec<u64> = flat[r].keys().collect();
            let mut sk: Vec<u64> = sharded[r].keys().collect();
            fk.sort_unstable();
            sk.sort_unstable();
            fk == sk
                && flat[r].key_count() == sharded[r].key_count()
                && flat[r].metadata_bytes() == sharded[r].metadata_bytes()
                && flat[r].max_siblings() == sharded[r].max_siblings()
                && (0..KEYS).all(|key| {
                    flat[r].state(key) == sharded[r].state(key)
                        && flat[r].read(key) == sharded[r].read(key)
                        && flat[r].sibling_count(key) == sharded[r].sibling_count(key)
                })
        })
    });
}

#[test]
fn batched_merges_match_per_key_merges_across_backends() {
    forall(&Config::default().cases(40), gen_ops(), |ops| {
        let src = flat_pair();
        apply(&src, ops);
        let items: Vec<(u64, _)> = src[0].keys().map(|k| (k, src[0].state(k))).collect();

        let batched = sharded_pair().remove(0);
        batched.merge_batch(&items);
        let sequential = flat_pair().remove(0);
        for (k, st) in &items {
            sequential.merge_key(*k, st);
        }
        (0..KEYS).all(|key| batched.state(key) == sequential.state(key))
            && batched.key_count() == sequential.key_count()
    });
}
