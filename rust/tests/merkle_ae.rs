//! Hash-tree anti-entropy equivalence suite: the tree path is only
//! allowed to exist because these properties hold.
//!
//! 1. **Incremental ≡ rebuilt** — after any seeded mix of informed
//!    writes, blind writes, merges, wipes, and crash-restarts, every
//!    shard's incrementally-maintained [`ShardTree`] root equals a tree
//!    rebuilt from scratch over the shard's current states — on all
//!    three backends, whose whole-store roots also agree with each
//!    other (the additive digest is sharding/backend independent).
//! 2. **Merkle diff ≡ scan diff** — over seeded divergent store pairs
//!    (and the adversarial corners: empty-vs-full, single-key,
//!    order-only difference) [`diff_pairs_merkle`] returns the
//!    *byte-identical* worklist of [`diff_pairs`]: same keys, same
//!    order, same sibling snapshots; likewise per shard. The tree walk
//!    is also shown to do O(divergence · log n) work, not O(keyspace).
//! 3. **Chaos regression** — one seeded [`FaultPlan`] mixing
//!    partitions, message drops, a crash-restart, and a live join runs
//!    against both worlds with tree-walk AE on: zero lost acknowledged
//!    updates, post-heal convergence, and equal final hash-tree roots
//!    across every member.
//!
//! The default gate runs fixed seeds; `MERKLE_ITERS=<n>` appends
//! derived seeds (uniform failure format via `testkit::soak`, replay
//! with `DVV_SEED=<s>`).
//!
//! [`ShardTree`]: dvvstore::antientropy::merkle::ShardTree
//! [`diff_pairs_merkle`]: dvvstore::antientropy::diff_pairs_merkle
//! [`diff_pairs`]: dvvstore::antientropy::diff_pairs
//! [`FaultPlan`]: dvvstore::sim::failure::FaultPlan

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use dvvstore::antientropy::{
    diff_pairs, diff_pairs_in_shard, diff_pairs_in_shard_merkle, diff_pairs_merkle, merkle,
    KeyPair,
};
use dvvstore::clocks::Actor;
use dvvstore::cluster::ring::hash_str;
use dvvstore::kernel::mechs::DvvMech;
use dvvstore::kernel::{Mechanism, Val, WriteMeta};
use dvvstore::oracle::SharedOracle;
use dvvstore::server::LocalCluster;
use dvvstore::sim::failure::FaultPlan;
use dvvstore::store::{
    DurableBackend, FsyncPolicy, KeyStore, ShardedBackend, StorageBackend, WalOptions,
};
use dvvstore::testkit::{run_seeded, soak_seeds, temp_dir, Rng};
use dvvstore::workload::key_name;

fn seeds() -> Vec<u64> {
    soak_seeds(&[61, 62, 63], "MERKLE_ITERS")
}

fn meta() -> WriteMeta {
    WriteMeta::basic(Actor::client(0))
}

fn empty_ctx() -> <DvvMech as Mechanism>::Context {
    <DvvMech as Mechanism>::Context::default()
}

// -------------------------------------------------------------------
// Property 1: incremental trees ≡ from-scratch rebuilds
// -------------------------------------------------------------------

/// One deterministic op burst: the same `seed` produces the same store
/// content on any backend (informed writes read their context from the
/// store itself, which is identical across replays of the sequence).
fn apply_ops<B: StorageBackend<DvvMech>>(store: &KeyStore<DvvMech, B>, seed: u64, ops: u64) {
    let mut rng = Rng::new(seed);
    let meta = meta();
    let empty = empty_ctx();
    for _ in 0..ops {
        let key = rng.below(512);
        let val = Val::new(rng.next_u64(), 8);
        let actor = Actor::server(rng.below(4) as u32);
        if rng.chance(0.5) {
            // informed write: supersedes what was read
            let (_, ctx) = store.read(key);
            store.write(key, &ctx, val, actor, &meta);
        } else {
            // blind write: accumulates a concurrent sibling
            store.write(key, &empty, val, actor, &meta);
        }
    }
}

/// Every shard's incremental root must equal a tree rebuilt from the
/// shard's current states — the invariant the write-path maintenance
/// claims to preserve.
fn assert_matches_rebuild<B: StorageBackend<DvvMech>>(
    seed: u64,
    label: &str,
    store: &KeyStore<DvvMech, B>,
) {
    let backend = store.backend();
    for shard in 0..backend.shard_count() {
        let incremental = backend.merkle_root(shard);
        let mut fresh = merkle::ShardTree::rebuild(backend.keys_in_shard(shard).into_iter().map(
            |k| {
                let sd = backend
                    .with_state(k, |st| DvvMech::state_digest(st.expect("listed key present")));
                (k, sd)
            },
        ));
        assert_eq!(
            incremental,
            fresh.root(),
            "seed {seed}: {label} shard {shard} incremental root drifted from rebuild"
        );
    }
}

#[test]
fn incremental_trees_equal_rebuilt_trees_across_backends() {
    run_seeded("merkle_incremental_vs_rebuild", &seeds(), |seed| {
        let flat = KeyStore::new(DvvMech);
        let striped = KeyStore::with_backend(DvvMech, ShardedBackend::with_shards(8));
        let dir = temp_dir("merkle-incr");
        // Always-fsync so a crash-restart is lossless and the rebuilt
        // tree must land on exactly the pre-crash root
        let opts = WalOptions { fsync: FsyncPolicy::Always, ..WalOptions::default() };
        let durable =
            KeyStore::with_backend(DvvMech, DurableBackend::open(&dir, 4, opts).unwrap());

        let mut stamp = seed;
        for round in 0..3u64 {
            stamp = stamp.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(round + 1);
            apply_ops(&flat, stamp, 300);
            apply_ops(&striped, stamp, 300);
            apply_ops(&durable, stamp, 300);

            assert_matches_rebuild(seed, "flat", &flat);
            assert_matches_rebuild(seed, "striped", &striped);
            assert_matches_rebuild(seed, "durable", &durable);

            // identical content ⇒ identical store roots, across backend
            // types and shard counts (1 vs 8 vs 4)
            let root = flat.merkle_root();
            assert_eq!(root, striped.merkle_root(), "seed {seed}: striped root diverges");
            assert_eq!(root, durable.merkle_root(), "seed {seed}: durable root diverges");
            assert_ne!(root, 0, "seed {seed}: stores are non-empty");

            match round {
                0 => {
                    // crash-restart: replay-on-open rebuilds the tree;
                    // with Always-fsync nothing is lost, so the rebuilt
                    // root is exactly the incremental one
                    let before = durable.merkle_root();
                    durable.backend().crash_restart();
                    assert_eq!(
                        durable.merkle_root(),
                        before,
                        "seed {seed}: rebuild-on-open drifted from the incremental tree"
                    );
                    assert_matches_rebuild(seed, "durable-restarted", &durable);
                }
                1 => {
                    // wipe: the tree resets with the map, then refills
                    // through the merge path (how anti-entropy restores
                    // a wiped replica)
                    striped.backend().wipe();
                    assert_eq!(striped.merkle_root(), 0, "seed {seed}: wiped root nonzero");
                    assert_matches_rebuild(seed, "striped-wiped", &striped);
                    for k in flat.keys() {
                        striped.merge_key(k, &flat.state(k));
                    }
                    assert_eq!(
                        striped.merkle_root(),
                        flat.merkle_root(),
                        "seed {seed}: merge-refilled replica root diverges"
                    );
                    assert_matches_rebuild(seed, "striped-refilled", &striped);
                }
                _ => {}
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    });
}

// -------------------------------------------------------------------
// Property 2: tree-walk worklists ≡ scan worklists, byte for byte
// -------------------------------------------------------------------

type Sharded = KeyStore<DvvMech, ShardedBackend<DvvMech>>;

fn sharded() -> Sharded {
    KeyStore::with_backend(DvvMech, ShardedBackend::with_shards(8))
}

/// Byte-identical worklist equality: same keys, same order, same
/// sibling snapshots — whole-store and shard by shard.
fn assert_same_worklists(seed: u64, local: &Sharded, remote: &Sharded) -> usize {
    let assert_pairs_eq = |scan: &[KeyPair], tree: &[KeyPair], what: &str| {
        assert_eq!(
            scan.iter().map(|p| p.key).collect::<Vec<_>>(),
            tree.iter().map(|p| p.key).collect::<Vec<_>>(),
            "seed {seed}: {what} worklist keys differ"
        );
        for (s, t) in scan.iter().zip(tree.iter()) {
            assert_eq!(s.local, t.local, "seed {seed}: {what} key {} local snapshot", s.key);
            assert_eq!(s.remote, t.remote, "seed {seed}: {what} key {} remote snapshot", s.key);
        }
    };
    let scan = diff_pairs(local, remote);
    let tree = diff_pairs_merkle(local, remote);
    assert_pairs_eq(&scan, &tree, "whole-store");
    for shard in 0..local.shard_count() {
        let scan_s = diff_pairs_in_shard(local, remote, shard);
        let tree_s = diff_pairs_in_shard_merkle(local, remote, shard);
        assert_pairs_eq(&scan_s, &tree_s, &format!("shard {shard}"));
    }
    scan.len()
}

#[test]
fn merkle_diff_equals_scan_diff_on_seeded_divergent_pairs() {
    run_seeded("merkle_diff_vs_scan", &seeds(), |seed| {
        let mut rng = Rng::new(seed);
        let local = sharded();
        let remote = sharded();
        let meta = meta();
        let empty = empty_ctx();
        let mut expect_diverged = 0usize;
        for key in 0..600u64 {
            match rng.below(6) {
                0 => {
                    // local-only key
                    local.write(key, &empty, Val::new(rng.next_u64(), 8), Actor::server(0), &meta);
                    expect_diverged += 1;
                }
                1 => {
                    // remote-only key
                    remote.write(key, &empty, Val::new(rng.next_u64(), 8), Actor::server(1), &meta);
                    expect_diverged += 1;
                }
                2 => {
                    // concurrent unsynced siblings on both sides
                    local.write(key, &empty, Val::new(rng.next_u64(), 8), Actor::server(0), &meta);
                    remote.write(key, &empty, Val::new(rng.next_u64(), 8), Actor::server(1), &meta);
                    expect_diverged += 1;
                }
                3 => {
                    // converged by one-way copy
                    local.write(key, &empty, Val::new(rng.next_u64(), 8), Actor::server(0), &meta);
                    remote.merge_key(key, &local.state(key));
                }
                4 => {
                    // converged with order-only difference: both hold
                    // {x, y}, in opposite Vec orders
                    local.write(key, &empty, Val::new(rng.next_u64(), 8), Actor::server(0), &meta);
                    remote.write(key, &empty, Val::new(rng.next_u64(), 8), Actor::server(1), &meta);
                    let (sl, sr) = (local.state(key), remote.state(key));
                    local.merge_key(key, &sr);
                    remote.merge_key(key, &sl);
                }
                _ => {} // absent on both sides
            }
        }
        let found = assert_same_worklists(seed, &local, &remote);
        assert_eq!(found, expect_diverged, "seed {seed}: detector missed/invented divergence");
    });
}

#[test]
fn merkle_diff_matches_scan_on_empty_vs_full() {
    let local = sharded();
    let remote = sharded();
    let meta = meta();
    let empty = empty_ctx();
    for key in 0..200u64 {
        remote.write(key, &empty, Val::new(key + 1, 8), Actor::server(1), &meta);
    }
    let found = assert_same_worklists(0, &local, &remote);
    assert_eq!(found, 200, "every remote key flagged against the empty store");
    // and the fully-symmetric case: two empty stores, nothing flagged
    let found = assert_same_worklists(0, &sharded(), &sharded());
    assert_eq!(found, 0);
}

#[test]
fn single_key_divergence_costs_log_n_digests_not_a_scan() {
    let local = sharded();
    let remote = sharded();
    let meta = meta();
    let empty = empty_ctx();
    const KEYSPACE: u64 = 2_000;
    for key in 0..KEYSPACE {
        local.write(key, &empty, Val::new(key + 1, 8), Actor::server(0), &meta);
        remote.merge_key(key, &local.state(key));
    }
    // one extra write on one side
    let (_, ctx) = remote.read(1_234);
    remote.write(1_234, &ctx, Val::new(9_999, 8), Actor::server(1), &meta);

    let found = assert_same_worklists(0, &local, &remote);
    assert_eq!(found, 1, "exactly the touched key is flagged");

    // walk cost: the diverged shard descends one root-to-leaf path
    // (≤ 1 + DEPTH·16 digest comparisons); every other shard prunes at
    // its root — far below the 2 000-key scan
    let mut nodes_compared = 0u64;
    for shard in 0..local.shard_count() {
        let (_, stats) = local.backend().with_merkle(shard, |tl| {
            remote.backend().with_merkle(shard, |tr| merkle::diff(tl, tr))
        });
        nodes_compared += stats.nodes_compared;
    }
    let bound = local.shard_count() as u64 + u64::from(merkle::DEPTH) * 16;
    assert!(
        nodes_compared <= bound,
        "tree walk did {nodes_compared} digest comparisons (bound {bound}, keyspace {KEYSPACE})"
    );
}

#[test]
fn order_only_difference_is_divergence_for_neither_detector() {
    let local = sharded();
    let remote = sharded();
    let meta = meta();
    let empty = empty_ctx();
    for key in 0..64u64 {
        local.write(key, &empty, Val::new(key * 2 + 1, 8), Actor::server(0), &meta);
        remote.write(key, &empty, Val::new(key * 2 + 2, 8), Actor::server(1), &meta);
        let (sl, sr) = (local.state(key), remote.state(key));
        local.merge_key(key, &sr);
        remote.merge_key(key, &sl);
    }
    assert_eq!(assert_same_worklists(0, &local, &remote), 0, "order alone is not divergence");
    // the per-sibling digest fold is order-independent, so the roots
    // agree too and a quiesced exchange is one root comparison per shard
    assert_eq!(local.merkle_root(), remote.merkle_root());
    for shard in 0..local.shard_count() {
        let (_, stats) = local.backend().with_merkle(shard, |tl| {
            remote.backend().with_merkle(shard, |tr| merkle::diff(tl, tr))
        });
        assert_eq!(stats.nodes_compared, 1, "shard {shard} did not prune at the root");
    }
}

// -------------------------------------------------------------------
// Property 3: chaos regression with tree-walk AE, both worlds
// -------------------------------------------------------------------

const NODES: usize = 5;
const KEYS: u64 = 8;
const CLIENTS: u32 = 3;
const HORIZON_US: u64 = 300_000;

/// Partitions + crash windows + a message-drop window
/// ([`FaultPlan::random_chaos`]), plus one mid-run crash-restart and
/// one live join — the scenario class this regression owns.
fn chaos_plan(seed: u64) -> FaultPlan {
    let mut rng = Rng::new(seed);
    let restart_node = rng.below(NODES as u64) as usize;
    FaultPlan::random_chaos(NODES, HORIZON_US, &mut rng)
        .restart_at(HORIZON_US / 3, restart_node)
        .join_at(HORIZON_US / 2)
}

fn des_run(seed: u64) {
    let mut cfg = dvvstore::config::StoreConfig::default();
    cfg.cluster.nodes = NODES;
    cfg.cluster.replication = 3;
    cfg.cluster.read_quorum = 2;
    cfg.cluster.write_quorum = 2;
    cfg.antientropy.period_us = 20_000;
    cfg.antientropy.merkle = true;
    cfg.durability.flush_every_ops = 4;
    let driver = Box::new(dvvstore::workload::RandomWorkload::new(
        dvvstore::workload::WorkloadSpec {
            keys: KEYS,
            ops_per_client: 40,
            put_fraction: 0.6,
            read_before_write: 0.5,
            mean_think_us: 400.0,
            ..Default::default()
        },
        CLIENTS as usize,
    ));
    let mut sim =
        dvvstore::sim::Sim::new(DvvMech, cfg, CLIENTS as usize, true, driver, seed).unwrap();
    chaos_plan(seed).apply(&mut sim);
    sim.start();
    sim.run(5_000_000);
    sim.settle();
    assert!(sim.writes_acked() > 0, "seed {seed}: nothing acked");
    assert_eq!(
        sim.audit_acked_lost(),
        0,
        "seed {seed}: acked update lost under tree-walk AE ({})",
        sim.metrics.summary()
    );
    assert_eq!(sim.metrics.lost_updates, 0, "seed {seed}: mechanism lost updates");
    assert!(
        sim.metrics.ae_digests_compared > 0,
        "seed {seed}: the tree walk never ran — merkle AE was not exercised"
    );
    // post-settle convergence across members (the joiner included),
    // pairwise — and therefore equal store roots
    let members = sim.members();
    for (ai, &a) in members.iter().enumerate() {
        for &b in members.iter().skip(ai + 1) {
            for key in 0..KEYS {
                assert_eq!(
                    sim.nodes[a].store.state(key),
                    sim.nodes[b].store.state(key),
                    "seed {seed}: members {a}/{b} diverged on key {key}"
                );
            }
            assert_eq!(
                sim.nodes[a].store.merkle_root(),
                sim.nodes[b].store.merkle_root(),
                "seed {seed}: members {a}/{b} roots diverged"
            );
        }
    }
}

/// Drive the plan against a durable threaded cluster while client
/// threads hammer traced quorum ops; returns the acked `(key, id)`
/// pairs for the survivor audit.
fn threaded_run(
    seed: u64,
    cluster: &Arc<LocalCluster<DurableBackend<DvvMech>>>,
) -> Vec<(u64, u64)> {
    let plan = chaos_plan(seed);
    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    for t in 0..CLIENTS {
        let cluster = Arc::clone(cluster);
        let stop = Arc::clone(&stop);
        workers.push(std::thread::spawn(move || {
            let me = Actor::client(t);
            let mut rng = Rng::new(seed.wrapping_mul(0x9E37).wrapping_add(u64::from(t)));
            let mut sessions: Vec<Option<(Vec<u8>, Vec<u64>)>> = vec![None; KEYS as usize];
            let mut acked: Vec<(u64, u64)> = Vec::new();
            let mut op = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let ki = rng.below(KEYS);
                let key = key_name(ki);
                if rng.chance(0.5) {
                    if let Ok(ans) = cluster.get(&key) {
                        sessions[ki as usize] = Some((ans.context, ans.ids));
                    }
                } else {
                    let (ctx, observed) = sessions[ki as usize].clone().unwrap_or_default();
                    let body = format!("c{t}-{op}").into_bytes();
                    if let Ok(id) = cluster.put_traced(&key, body, &ctx, me, &observed) {
                        acked.push((ki, id));
                    }
                }
                op += 1;
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            acked
        }));
    }
    const STEPS: u64 = 50;
    for step in 1..=STEPS {
        cluster.advance_plan(&plan, HORIZON_US * step / STEPS);
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    stop.store(true, Ordering::Relaxed);
    let mut acked = Vec::new();
    for w in workers {
        acked.extend(w.join().unwrap());
    }
    acked
}

/// Heal, quiesce over tree-walk AE, and audit: convergence, zero acked
/// loss, equal roots — then let the scan path second the verdict.
fn audit_threaded(
    seed: u64,
    cluster: &LocalCluster<DurableBackend<DvvMech>>,
    oracle: &SharedOracle,
    acked: &[(u64, u64)],
) {
    assert!(cluster.ae_merkle(), "tree walk is the default detector");
    cluster.fabric().heal_all();
    cluster.drain_hints();
    let mut rounds = 0;
    while cluster.anti_entropy_round() > 0 {
        rounds += 1;
        assert!(rounds < 32, "seed {seed}: tree-walk anti-entropy failed to quiesce");
    }
    let members = cluster.members();
    for (ai, &a) in members.iter().enumerate() {
        for &b in members.iter().skip(ai + 1) {
            let diverged = diff_pairs(cluster.node(a).store(), cluster.node(b).store());
            assert!(
                diverged.is_empty(),
                "seed {seed}: members {a}/{b} diverged after heal on {} keys",
                diverged.len()
            );
        }
    }
    // equal roots across every member — the cheap convergence witness
    // the expensive pairwise scan above just vouched for
    let roots = cluster.merkle_roots();
    assert!(
        roots.windows(2).all(|w| w[0].1 == w[1].1),
        "seed {seed}: member roots diverge after convergence: {roots:?}"
    );
    assert_eq!(cluster.merkle_root(), roots[0].1, "seed {seed}: common root is reported");
    // the exact oracle seconds the verdict: the scan detector finds
    // nothing the tree walk missed
    cluster.set_ae_merkle(false);
    assert_eq!(
        cluster.anti_entropy_round(),
        0,
        "seed {seed}: the scan path found divergence the tree walk left behind"
    );
    cluster.set_ae_merkle(true);

    let verdict = oracle.verdict();
    assert_eq!(verdict.unaudited_drops, 0, "seed {seed}: untraced writes leaked in");
    assert_eq!(verdict.lost_updates, 0, "seed {seed}: mechanism lost updates");
    assert!(!acked.is_empty(), "seed {seed}: no write was ever acknowledged");
    for &(ki, id) in acked {
        let k = hash_str(&key_name(ki));
        let covered = members.iter().any(|&n| {
            cluster
                .node(n)
                .store()
                .values(k)
                .iter()
                .any(|v| v.id == id || oracle.with_inner(|o| o.leq(id, v.id)))
        });
        assert!(covered, "seed {seed}: acked write {id} on key {ki} lost");
    }
}

#[test]
fn chaos_with_tree_walk_ae_converges_in_both_worlds() {
    // one pinned plan (partition + drop + restart + join), replayed in
    // the DES and against the threaded durable cluster
    let seed = 6_161;
    des_run(seed);
    let dir = temp_dir("merkle-chaos");
    let opts = WalOptions { segment_bytes: 16 * 1024, fsync: FsyncPolicy::EveryN(4) };
    let cluster = LocalCluster::with_data_dir(NODES, 3, 2, 2, 4, &dir, opts).unwrap();
    let oracle = Arc::new(SharedOracle::new());
    cluster.attach_oracle(Arc::clone(&oracle));
    cluster.fabric().reseed(seed ^ 0xD00D);
    let cluster = Arc::new(cluster);
    let acked = threaded_run(seed, &cluster);
    audit_threaded(seed, &cluster, &oracle, &acked);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn seeded_chaos_with_tree_walk_ae_des() {
    run_seeded("merkle_chaos_des", &seeds(), des_run);
}
