//! Integration: eventual consistency — every mechanism converges to an
//! identical value set on all replicas once deliveries settle, and the
//! lossless/lossy split matches the paper's classification on identical
//! interleavings.

use dvvstore::config::StoreConfig;
use dvvstore::kernel::mechs::{dispatch, MechVisitor};
use dvvstore::kernel::{MechKind, Mechanism};
use dvvstore::sim::Sim;
use dvvstore::store::Key;
use dvvstore::workload::{RandomWorkload, WorkloadSpec};

fn cfg() -> StoreConfig {
    let mut c = StoreConfig::default();
    c.cluster.nodes = 5;
    c.cluster.replication = 3;
    c.cluster.read_quorum = 2;
    c.cluster.write_quorum = 2;
    c.antientropy.period_us = 50_000;
    c
}

struct Convergence {
    seed: u64,
}

impl MechVisitor for Convergence {
    type Out = (u64, u64, bool); // (writes, lost, converged)

    fn visit<M: Mechanism>(self, mech: M) -> Self::Out {
        let spec = WorkloadSpec {
            keys: 40,
            ops_per_client: 60,
            put_fraction: 0.6,
            read_before_write: 0.5,
            mean_think_us: 400.0,
            ..Default::default()
        };
        let driver = Box::new(RandomWorkload::new(spec, 10));
        let mut sim = Sim::new(mech, cfg(), 10, true, driver, self.seed).expect("sim");
        sim.start();
        sim.run(u64::MAX);
        sim.settle();
        // convergence: every replica set for a key holds the same values
        let mut converged = true;
        for key in 0..40u64 {
            let replicas = sim.ring.replicas_for(key as Key, 3);
            let mut sets: Vec<Vec<u64>> = replicas
                .iter()
                .map(|&n| {
                    let mut ids: Vec<u64> =
                        sim.nodes[n].store.values(key).iter().map(|v| v.id).collect();
                    ids.sort_unstable();
                    ids
                })
                .collect();
            sets.dedup();
            if sets.len() > 1 {
                converged = false;
            }
        }
        (sim.writes_issued(), sim.audit_permanently_lost(), converged)
    }
}

#[test]
fn all_mechanisms_converge_after_settle() {
    for kind in MechKind::ALL {
        let (_w, _lost, converged) = dispatch(kind, Convergence { seed: 99 });
        assert!(converged, "{kind} did not converge");
    }
}

#[test]
fn lossless_split_matches_paper_classification() {
    for kind in MechKind::ALL {
        let (writes, lost, _) = dispatch(kind, Convergence { seed: 99 });
        assert!(writes > 200, "writes={writes}");
        if kind.is_lossless() {
            assert_eq!(lost, 0, "{kind} lost updates but is classified lossless");
        } else {
            assert!(lost > 0, "{kind} lost nothing but is classified lossy");
        }
    }
}

#[test]
fn identical_seeds_identical_outcomes_across_runs() {
    let a = dispatch(MechKind::Dvv, Convergence { seed: 5 });
    let b = dispatch(MechKind::Dvv, Convergence { seed: 5 });
    assert_eq!(a.0, b.0);
    assert_eq!(a.1, b.1);
}
