#!/usr/bin/env bash
# Tier-1 gate + hygiene for the rust tree (see README "Tests and CI").
#
#   rust/ci.sh           full run
#   rust/ci.sh --quick   skip the release build (debug test cycle only)
#
# Requires the repo toolchain (rustfmt + clippy components). The XLA
# runtime paths self-skip when AOT artifacts are absent, so this runs on
# a fresh checkout.
set -euo pipefail
cd "$(dirname "$0")/.."

quick=0
[[ "${1:-}" == "--quick" ]] && quick=1

# Chaos soak knob: the fabric chaos property test always runs its fixed
# seeds; CHAOS_ITERS appends that many extra derived seeds per backend.
# The gate default (2) keeps CI bounded; crank it locally to soak, e.g.
#   CHAOS_ITERS=50 rust/ci.sh
export CHAOS_ITERS="${CHAOS_ITERS:-2}"

# Churn soak knob, same shape: the elastic-topology churn tests always
# run their fixed seeds; CHURN_ITERS appends extra derived seeds to the
# churn-plus-chaos property test and the ring/topology invariant tests.
#   CHURN_ITERS=20 rust/ci.sh
export CHURN_ITERS="${CHURN_ITERS:-2}"

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

if [[ $quick -eq 0 ]]; then
    echo "==> cargo build --release"
    cargo build --release
fi

echo "==> cargo test -q"
cargo test -q

echo "==> cargo doc --no-deps (warnings are errors)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# Wire-format perf baseline: a quick (1-iteration-scale) smoke run of
# the hex-text vs binary-v2 framing bench, emitting BENCH_wire.json at
# the repo root so subsequent changes can diff against it.
echo "==> cargo bench --bench wire (smoke run, quick mode)"
DVV_BENCH_QUICK=1 cargo bench --bench wire
if [[ -f BENCH_wire.json ]]; then echo "    wrote BENCH_wire.json"; fi

# Routing perf baseline: preference-list lookup (alloc vs buffered) and
# churn rebalance throughput, emitting BENCH_ring.json at the repo root.
echo "==> cargo bench --bench ring (smoke run, quick mode)"
DVV_BENCH_QUICK=1 cargo bench --bench ring
if [[ -f BENCH_ring.json ]]; then echo "    wrote BENCH_ring.json"; fi

echo "ci OK"
