#!/usr/bin/env bash
# Tier-1 gate + hygiene for the rust tree (see README "Tests and CI").
#
#   rust/ci.sh           full run
#   rust/ci.sh --quick   skip the release build (debug test cycle only)
#
# Requires the repo toolchain (rustfmt + clippy components). The XLA
# runtime paths self-skip when AOT artifacts are absent, so this runs on
# a fresh checkout.
set -euo pipefail
cd "$(dirname "$0")/.."

quick=0
[[ "${1:-}" == "--quick" ]] && quick=1

# Chaos soak knob: the fabric chaos property test always runs its fixed
# seeds; CHAOS_ITERS appends that many extra derived seeds per backend.
# The gate default (2) keeps CI bounded; crank it locally to soak, e.g.
#   CHAOS_ITERS=50 rust/ci.sh
export CHAOS_ITERS="${CHAOS_ITERS:-2}"

# Churn soak knob, same shape: the elastic-topology churn tests always
# run their fixed seeds; CHURN_ITERS appends extra derived seeds to the
# churn-plus-chaos property test and the ring/topology invariant tests.
#   CHURN_ITERS=20 rust/ci.sh
export CHURN_ITERS="${CHURN_ITERS:-2}"

# Durability soak knob, same shape: the WAL recovery fuzz and the
# crash-with-state-loss chaos tests (rust/tests/wal_recovery.rs,
# rust/tests/durable_chaos.rs) always run their fixed seeds; WAL_ITERS
# appends extra derived seeds. Any soak failure prints a uniform
# "[seeded] ... seed=<s> iter=<i>" line; replay with DVV_SEED=<s>.
#   WAL_ITERS=20 rust/ci.sh
export WAL_ITERS="${WAL_ITERS:-2}"

# Merkle anti-entropy soak knob, same shape: the hash-tree equivalence
# properties (rust/tests/merkle_ae.rs — incremental-vs-rebuilt roots,
# tree-diff-vs-scan-diff worklists, chaos with tree-walk AE) always run
# their fixed seeds; MERKLE_ITERS appends extra derived seeds.
#   MERKLE_ITERS=20 rust/ci.sh
export MERKLE_ITERS="${MERKLE_ITERS:-2}"

# Geo-replication soak knob, same shape: the whole-DC partition chaos
# runs (both worlds) and the HLC property tests
# (rust/tests/geo_replication.rs) always run their fixed seeds;
# GEO_ITERS appends extra derived seeds.
#   GEO_ITERS=20 rust/ci.sh
export GEO_ITERS="${GEO_ITERS:-2}"

# CRDT soak knob, same shape: the datatype merge-law and backend
# ride-along properties (rust/tests/crdt_types.rs) always run their
# fixed seeds; CRDT_ITERS appends extra derived seeds.
#   CRDT_ITERS=20 rust/ci.sh
export CRDT_ITERS="${CRDT_ITERS:-2}"

# LSM soak knob, same shape: the sorted-run damage fuzz
# (rust/tests/sst_recovery.rs — random truncation/corruption sweeps)
# always runs its fixed seeds; LSM_ITERS appends extra derived seeds.
#   LSM_ITERS=20 rust/ci.sh
export LSM_ITERS="${LSM_ITERS:-2}"

# Target-registration guard: with the non-standard layout (lib under
# rust/src) cargo does NOT auto-discover rust/tests/*.rs or benches/*.rs
# — an unregistered file silently never runs. Fail loudly instead.
echo "==> target registration check (Cargo.toml vs rust/tests, benches)"
missing=0
for f in rust/tests/*.rs; do
    name="$(basename "$f" .rs)"
    if ! grep -qF "path = \"$f\"" Cargo.toml; then
        echo "ERROR: $f has no [[test]] entry in Cargo.toml (name = \"$name\")" >&2
        missing=1
    fi
done
for f in benches/*.rs; do
    name="$(basename "$f" .rs)"
    if ! grep -qF "path = \"$f\"" Cargo.toml; then
        echo "ERROR: $f has no [[bench]] entry in Cargo.toml (name = \"$name\")" >&2
        missing=1
    fi
done
if [[ $missing -ne 0 ]]; then
    echo "ERROR: unregistered targets never run under 'cargo test/bench' — add them" >&2
    exit 1
fi

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

if [[ $quick -eq 0 ]]; then
    echo "==> cargo build --release"
    cargo build --release
fi

echo "==> cargo test -q"
cargo test -q

echo "==> cargo doc --no-deps (warnings are errors)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# Perf-baseline smoke runs. Each bench must emit its BENCH_<name>.json
# at the repo root; a bench that silently fails to produce its artifact
# fails the gate (a missing baseline used to pass unnoticed — the `if`
# only echoed).
bench_smoke() {
    local name="$1" artifact="BENCH_${2:-$1}.json"
    echo "==> cargo bench --bench $name (smoke run, quick mode)"
    rm -f "$artifact"
    DVV_BENCH_QUICK=1 cargo bench --bench "$name"
    if [[ ! -f "$artifact" ]]; then
        echo "ERROR: bench '$name' did not emit $artifact" >&2
        exit 1
    fi
    echo "    wrote $artifact"
}

# wire: hex-text vs binary-v2 framing on the PUT/GET hot path.
bench_smoke wire
# ring: preference-list lookup (alloc vs buffered) + churn rebalance.
bench_smoke ring
# wal: append throughput per fsync policy + recovery replay time.
bench_smoke wal
# antientropy → ae_scale: scan vs hash-tree divergence detection over
# growing keyspaces (quiesced-round cost must stay sublinear in keys).
bench_smoke antientropy ae_scale
# conn: reactor vs thread-per-connection serve loop (throughput + tail
# latency across connection-count levels).
bench_smoke conn
# geo: local-DC vs flat write path, shipper drain/apply throughput, and
# whole-DC heal convergence (plus HLC stamp ops).
bench_smoke geo
# crdt: ORSWOT at size — add/remove churn, membership reads, delta vs
# full-state replication bytes (one key, thousands of elements).
bench_smoke crdt
# storage: durable vs lsm backends — write/read/reopen timings plus the
# residency sweep (LSM resident bytes must grow sublinearly in keys).
bench_smoke storage

echo "ci OK"
