//! Version vectors with per-server entries (§3.2, Dynamo-style).
//!
//! Tracks causality correctly *across* servers but linearizes concurrent
//! updates handled by the *same* server (a plausible-clocks effect): the
//! second same-server write's vector "does not correctly summarize its
//! causal history" and falsely dominates the first (Figure 3). E6
//! quantifies the resulting lost updates.

use crate::clocks::encoding::{decode_vv, encode_vv, get_varint, put_varint};
use crate::clocks::vv::VersionVector;
use crate::clocks::{Actor, LogicalClock};
use crate::kernel::mechanism::{decode_val, encode_val, DurableMechanism, Mechanism, Val, WriteMeta};
use crate::kernel::ops;

/// See module docs.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerVvMech;

impl Mechanism for ServerVvMech {
    const NAME: &'static str = "vv";
    type Context = VersionVector;
    type State = Vec<(VersionVector, Val)>;

    fn read(&self, st: &Self::State) -> (Vec<Val>, Self::Context) {
        let mut ctx = VersionVector::new();
        let mut vals = Vec::with_capacity(st.len());
        for (vv, v) in st {
            ctx.join_from(vv);
            vals.push(*v);
        }
        (vals, ctx)
    }

    fn write(
        &self,
        st: &mut Self::State,
        ctx: &Self::Context,
        val: Val,
        coord: Actor,
        _meta: &WriteMeta,
    ) {
        // "The replica node increments its local counter ... and stores it
        // in the entry of the received vector corresponding to its own
        // identifier."
        let counter = st.iter().map(|(v, _)| v.get(coord)).max().unwrap_or(0) + 1;
        let mut vv = ctx.clone();
        vv.set(coord, counter);
        // "It then checks if this new vector causally dominates any version
        // currently stored, and discards any version made obsolete."
        st.retain(|(v, _)| !v.compare(&vv).is_leq());
        st.push((vv, val));
    }

    fn merge(&self, st: &mut Self::State, incoming: &Self::State) {
        ops::sync_into(st, incoming);
    }

    fn values(&self, st: &Self::State) -> Vec<Val> {
        st.iter().map(|(_, v)| *v).collect()
    }

    fn metadata_bytes(&self, st: &Self::State) -> usize {
        st.iter().map(|(vv, _)| vv.encoded_size()).sum()
    }

    fn context_bytes(&self, ctx: &Self::Context) -> usize {
        ctx.encoded_size()
    }

    fn state_digest(st: &Self::State) -> u64 {
        // Order-independent multiset digest: sibling order depends on
        // which replica merged what first.
        st.iter().fold(0u64, |acc, (vv, v)| {
            acc.wrapping_add(crate::kernel::digest::of_encoded(|buf| {
                encode_vv(vv, buf);
                encode_val(v, buf);
            }))
        })
    }
}

impl DurableMechanism for ServerVvMech {
    fn encode_state(st: &Self::State, buf: &mut Vec<u8>) {
        put_varint(buf, st.len() as u64);
        for (vv, v) in st {
            encode_vv(vv, buf);
            encode_val(v, buf);
        }
    }

    fn decode_state(buf: &[u8], pos: &mut usize) -> crate::Result<Self::State> {
        let count = get_varint(buf, pos)?;
        let mut st = Vec::new();
        for _ in 0..count {
            let vv = decode_vv(buf, pos)?;
            let v = decode_val(buf, pos)?;
            st.push((vv, v));
        }
        Ok(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clocks::vv::vv;
    use crate::clocks::ClockOrd;

    fn ra() -> Actor {
        Actor::server(0)
    }
    fn rb() -> Actor {
        Actor::server(1)
    }
    fn c(i: u32) -> Actor {
        Actor::client(i)
    }

    /// The Figure 3 run: w falsely dominates v at Rb while y and w are
    /// correctly concurrent across replicas.
    #[test]
    fn figure3_run() {
        let m = ServerVvMech;
        let mut ra_st: <ServerVvMech as Mechanism>::State = Vec::new();
        let mut rb_st: <ServerVvMech as Mechanism>::State = Vec::new();
        let empty = VersionVector::new();

        // C1: PUT v at Rb -> {(b,1)}
        m.write(&mut rb_st, &empty, Val::new(1, 0), rb(), &WriteMeta::basic(c(0)));
        assert_eq!(rb_st[0].0, vv(&[(rb(), 1)]));

        // C3: PUT x at Ra -> {(a,1)}
        m.write(&mut ra_st, &empty, Val::new(2, 0), ra(), &WriteMeta::basic(c(2)));

        // C2: PUT w at Rb with empty context -> {(b,2)}: v is *falsely*
        // discarded (the §3.2 anomaly — one concurrent update lost)
        m.write(&mut rb_st, &empty, Val::new(3, 0), rb(), &WriteMeta::basic(c(1)));
        assert_eq!(rb_st.len(), 1, "v was linearized away");
        assert_eq!(rb_st[0].0, vv(&[(rb(), 2)]));
        assert_eq!(rb_st[0].1, Val::new(3, 0));

        // C1: GET at Ra then PUT y -> {(a,2)}
        let (_, ctx) = m.read(&ra_st);
        m.write(&mut ra_st, &ctx, Val::new(4, 0), ra(), &WriteMeta::basic(c(0)));
        assert_eq!(ra_st[0].0, vv(&[(ra(), 2)]));

        // cross-server concurrency is still detected: {(a,2)} || {(b,2)}
        assert_eq!(ra_st[0].0.compare(&rb_st[0].0), ClockOrd::Concurrent);
    }

    #[test]
    fn cross_server_merge_keeps_both() {
        let m = ServerVvMech;
        let mut st = vec![(vv(&[(ra(), 2)]), Val::new(4, 0))];
        let incoming = vec![(vv(&[(rb(), 2)]), Val::new(3, 0))];
        m.merge(&mut st, &incoming);
        assert_eq!(st.len(), 2);
    }

    #[test]
    fn informed_write_supersedes() {
        let m = ServerVvMech;
        let mut st: <ServerVvMech as Mechanism>::State = Vec::new();
        m.write(&mut st, &VersionVector::new(), Val::new(1, 0), ra(), &WriteMeta::basic(c(0)));
        let (_, ctx) = m.read(&st);
        m.write(&mut st, &ctx, Val::new(2, 0), rb(), &WriteMeta::basic(c(0)));
        // {(a,1)} < {(a,1),(b,1)}
        assert_eq!(st.len(), 1);
        assert_eq!(st[0].1, Val::new(2, 0));
    }

    #[test]
    fn counter_monotonic_per_server() {
        let m = ServerVvMech;
        let mut st: <ServerVvMech as Mechanism>::State = Vec::new();
        for i in 0..5 {
            m.write(
                &mut st,
                &VersionVector::new(),
                Val::new(i, 0),
                rb(),
                &WriteMeta::basic(c(i as u32)),
            );
        }
        // every blind write bumps b's counter; only the last survives
        assert_eq!(st.len(), 1);
        assert_eq!(st[0].0.get(rb()), 5);
    }

    #[test]
    fn state_codec_roundtrips() {
        let st = vec![
            (vv(&[(ra(), 2)]), Val::new(4, 1)),
            (vv(&[(rb(), 2), (ra(), 1)]), Val::new(3, 0)),
        ];
        let mut buf = Vec::new();
        ServerVvMech::encode_state(&st, &mut buf);
        let mut pos = 0;
        assert_eq!(ServerVvMech::decode_state(&buf, &mut pos).unwrap(), st);
        assert_eq!(pos, buf.len());
        let mut p = 0;
        assert!(ServerVvMech::decode_state(&buf[..buf.len() - 1], &mut p).is_err());
    }

    #[test]
    fn metadata_bounded_by_servers() {
        let m = ServerVvMech;
        let mut st: <ServerVvMech as Mechanism>::State = Vec::new();
        for i in 0..100u32 {
            let (_, ctx) = m.read(&st);
            m.write(
                &mut st,
                &ctx,
                Val::new(i as u64, 0),
                Actor::server(i % 3),
                &WriteMeta::basic(c(i)),
            );
        }
        // three servers -> at most 3 entries per vector
        assert!(m.metadata_bytes(&st) < 40, "got {}", m.metadata_bytes(&st));
    }
}
