//! Physical-clock last-writer-wins (§3.1, Cassandra-style).
//!
//! "Replica nodes never store multiple versions and writes do not need to
//! provide a get context." The total order silently linearizes concurrent
//! writes (Figure 2) and, under clock skew, systematically favours the
//! fastest clock — both effects measured by E6.

use crate::clocks::encoding::{decode_rt, encode_rt};
use crate::clocks::realtime::RtClock;
use crate::clocks::{Actor, LogicalClock};
use crate::kernel::mechanism::{decode_val, encode_val, DurableMechanism, Mechanism, Val, WriteMeta};

/// See module docs.
#[derive(Debug, Clone, Copy, Default)]
pub struct LwwMech;

impl Mechanism for LwwMech {
    const NAME: &'static str = "lww";
    /// LWW needs no causal context at all.
    type Context = ();
    type State = Option<(RtClock, Val)>;

    fn read(&self, st: &Self::State) -> (Vec<Val>, Self::Context) {
        (st.iter().map(|(_, v)| *v).collect(), ())
    }

    fn write(
        &self,
        st: &mut Self::State,
        _ctx: &Self::Context,
        val: Val,
        _coord: Actor,
        meta: &WriteMeta,
    ) {
        let clock = RtClock::new(meta.physical_us, meta.client);
        match st {
            Some((cur, _)) if clock.compare(cur).is_leq() => {} // older: drop
            _ => *st = Some((clock, val)),
        }
    }

    fn merge(&self, st: &mut Self::State, incoming: &Self::State) {
        if let Some((inc_clock, inc_val)) = incoming {
            match st {
                Some((cur, _)) if inc_clock.compare(cur).is_leq() => {}
                _ => *st = Some((*inc_clock, *inc_val)),
            }
        }
    }

    fn values(&self, st: &Self::State) -> Vec<Val> {
        st.iter().map(|(_, v)| *v).collect()
    }

    fn metadata_bytes(&self, st: &Self::State) -> usize {
        st.as_ref().map(|(c, _)| c.encoded_size()).unwrap_or(0)
    }

    fn context_bytes(&self, _ctx: &Self::Context) -> usize {
        0
    }

    fn state_digest(st: &Self::State) -> u64 {
        // `Option<(clock, val)>` is already canonical; hash the codec
        // output directly.
        crate::kernel::digest::of_encoded(|buf| Self::encode_state(st, buf))
    }
}

impl DurableMechanism for LwwMech {
    fn encode_state(st: &Self::State, buf: &mut Vec<u8>) {
        match st {
            None => buf.push(0),
            Some((clock, val)) => {
                buf.push(1);
                encode_rt(clock, buf);
                encode_val(val, buf);
            }
        }
    }

    fn decode_state(buf: &[u8], pos: &mut usize) -> crate::Result<Self::State> {
        let flag = *buf
            .get(*pos)
            .ok_or_else(|| crate::Error::Codec("lww state: missing flag".into()))?;
        *pos += 1;
        match flag {
            0 => Ok(None),
            1 => {
                let clock = decode_rt(buf, pos)?;
                let val = decode_val(buf, pos)?;
                Ok(Some((clock, val)))
            }
            other => Err(crate::Error::Codec(format!("lww state: bad flag {other}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(i: u32) -> Actor {
        Actor::client(i)
    }
    fn meta(client: Actor, t: u64) -> WriteMeta {
        WriteMeta { client, physical_us: t, client_seq: None }
    }

    /// Figure 2: perfectly synchronized clocks order everything; only the
    /// latest write survives — v and w are lost.
    #[test]
    fn figure2_loses_concurrent_updates() {
        let m = LwwMech;
        let mut rb: <LwwMech as Mechanism>::State = None;
        m.write(&mut rb, &(), Val::new(1, 0), Actor::server(1), &meta(c(0), 10)); // v
        m.write(&mut rb, &(), Val::new(3, 0), Actor::server(1), &meta(c(1), 30)); // w
        assert_eq!(m.values(&rb), vec![Val::new(3, 0)]); // v lost

        let mut ra: <LwwMech as Mechanism>::State = None;
        m.write(&mut ra, &(), Val::new(2, 0), Actor::server(0), &meta(c(2), 20)); // x
        m.write(&mut ra, &(), Val::new(4, 0), Actor::server(0), &meta(c(0), 40)); // y
        // after anti-entropy both replicas converge on the max timestamp
        m.merge(&mut rb, &ra);
        m.merge(&mut ra, &rb);
        assert_eq!(m.values(&ra), vec![Val::new(4, 0)]);
        assert_eq!(m.values(&rb), vec![Val::new(4, 0)]);
    }

    #[test]
    fn skewed_clock_always_loses() {
        // §3.1: "a client with systematically delayed clock values will
        // never see its updates committed"
        let m = LwwMech;
        let mut st: <LwwMech as Mechanism>::State = None;
        m.write(&mut st, &(), Val::new(1, 0), Actor::server(0), &meta(c(0), 1000));
        // the slow-clock client writes later in real time but stamps lower
        m.write(&mut st, &(), Val::new(2, 0), Actor::server(0), &meta(c(1), 500));
        assert_eq!(m.values(&st), vec![Val::new(1, 0)]);
    }

    #[test]
    fn tiebreak_on_actor_id() {
        let m = LwwMech;
        let mut st: <LwwMech as Mechanism>::State = None;
        m.write(&mut st, &(), Val::new(1, 0), Actor::server(0), &meta(c(1), 7));
        m.write(&mut st, &(), Val::new(2, 0), Actor::server(0), &meta(c(0), 7));
        // same stamp: higher client id wins the total order
        assert_eq!(m.values(&st), vec![Val::new(1, 0)]);
    }

    #[test]
    fn merge_is_idempotent_and_commutative() {
        let m = LwwMech;
        let a: <LwwMech as Mechanism>::State =
            Some((RtClock::new(5, c(0)), Val::new(1, 0)));
        let b: <LwwMech as Mechanism>::State =
            Some((RtClock::new(9, c(1)), Val::new(2, 0)));
        let mut ab = a.clone();
        m.merge(&mut ab, &b);
        let mut ba = b.clone();
        m.merge(&mut ba, &a);
        assert_eq!(ab, ba);
        let snap = ab.clone();
        m.merge(&mut ab, &b);
        assert_eq!(ab, snap);
    }

    #[test]
    fn state_codec_roundtrips() {
        for st in [None, Some((RtClock::new(1234, c(3)), Val::new(7, 12)))] {
            let mut buf = Vec::new();
            LwwMech::encode_state(&st, &mut buf);
            let mut pos = 0;
            assert_eq!(LwwMech::decode_state(&buf, &mut pos).unwrap(), st);
            assert_eq!(pos, buf.len());
        }
        let mut pos = 0;
        assert!(LwwMech::decode_state(&[9], &mut pos).is_err(), "bad flag");
    }

    #[test]
    fn never_keeps_siblings() {
        let m = LwwMech;
        let mut st: <LwwMech as Mechanism>::State = None;
        for i in 0..10 {
            m.write(&mut st, &(), Val::new(i, 0), Actor::server(0), &meta(c(i as u32), i));
            assert!(m.sibling_count(&st) <= 1);
        }
    }
}
