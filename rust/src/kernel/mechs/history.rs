//! Causal-history mechanism (§3): the lossless but unscalable reference.
//!
//! State keeps one explicit event set per sibling. The `update` follows
//! the paper's reference definition: the new history is the union of the
//! context plus one fresh event minted from the coordinator's replica id
//! and a per-key counter recovered from the stored state.

use crate::clocks::causal_history::CausalHistory;
use crate::clocks::encoding::{decode_history, encode_history, get_varint, put_varint};
use crate::clocks::{Actor, Event, LogicalClock};
use crate::kernel::mechanism::{decode_val, encode_val, DurableMechanism, Mechanism, Val, WriteMeta};
use crate::kernel::ops;

/// See module docs.
#[derive(Debug, Clone, Copy, Default)]
pub struct HistoryMech;

impl Mechanism for HistoryMech {
    const NAME: &'static str = "history";
    type Context = CausalHistory;
    type State = Vec<(CausalHistory, Val)>;

    fn read(&self, st: &Self::State) -> (Vec<Val>, Self::Context) {
        let mut ctx = CausalHistory::new();
        let mut vals = Vec::with_capacity(st.len());
        for (h, v) in st {
            ctx.merge_from(h);
            vals.push(*v);
        }
        (vals, ctx)
    }

    fn write(
        &self,
        st: &mut Self::State,
        ctx: &Self::Context,
        val: Val,
        coord: Actor,
        _meta: &WriteMeta,
    ) {
        // n = max({0} ∪ {x | r_x ∈ ∪ S_r}) — the replica's own counter,
        // recovered from stored histories (§4's reference update).
        let n = st.iter().map(|(h, _)| h.max_seq(coord)).max().unwrap_or(0);
        let mut h = ctx.clone();
        h.insert(Event::new(coord, n + 1));
        ops::insert_version(st, h, val);
    }

    fn merge(&self, st: &mut Self::State, incoming: &Self::State) {
        ops::sync_into(st, incoming);
    }

    fn values(&self, st: &Self::State) -> Vec<Val> {
        st.iter().map(|(_, v)| *v).collect()
    }

    fn metadata_bytes(&self, st: &Self::State) -> usize {
        st.iter().map(|(h, _)| h.encoded_size()).sum()
    }

    fn context_bytes(&self, ctx: &Self::Context) -> usize {
        ctx.encoded_size()
    }

    fn state_digest(st: &Self::State) -> u64 {
        // Order-independent multiset digest: sibling order depends on
        // which replica merged what first.
        st.iter().fold(0u64, |acc, (h, v)| {
            acc.wrapping_add(crate::kernel::digest::of_encoded(|buf| {
                encode_history(h, buf);
                encode_val(v, buf);
            }))
        })
    }
}

impl DurableMechanism for HistoryMech {
    fn encode_state(st: &Self::State, buf: &mut Vec<u8>) {
        put_varint(buf, st.len() as u64);
        for (h, v) in st {
            encode_history(h, buf);
            encode_val(v, buf);
        }
    }

    fn decode_state(buf: &[u8], pos: &mut usize) -> crate::Result<Self::State> {
        let count = get_varint(buf, pos)?;
        let mut st = Vec::new();
        for _ in 0..count {
            let h = decode_history(buf, pos)?;
            let v = decode_val(buf, pos)?;
            st.push((h, v));
        }
        Ok(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clocks::causal_history::hist;

    fn ra() -> Actor {
        Actor::server(0)
    }
    fn rb() -> Actor {
        Actor::server(1)
    }
    fn c(i: u32) -> Actor {
        Actor::client(i)
    }

    /// Replays Figure 1 exactly and checks every committed state.
    #[test]
    fn figure1_run() {
        let m = HistoryMech;
        let mut ra_st: <HistoryMech as Mechanism>::State = Vec::new();
        let mut rb_st: <HistoryMech as Mechanism>::State = Vec::new();

        // all three clients read the initial empty state
        let (_, ctx0) = m.read(&ra_st);

        // C1: PUT v at Rb  -> {b1}
        m.write(&mut rb_st, &ctx0, Val::new(1, 0), rb(), &WriteMeta::basic(c(0)));
        assert_eq!(rb_st[0].0, hist(&[(rb(), 1)]));

        // C3: PUT x at Ra -> {a1}
        m.write(&mut ra_st, &ctx0, Val::new(2, 0), ra(), &WriteMeta::basic(c(2)));
        assert_eq!(ra_st[0].0, hist(&[(ra(), 1)]));

        // C2: PUT w at Rb with empty context -> {b2}, concurrent with v
        m.write(&mut rb_st, &ctx0, Val::new(3, 0), rb(), &WriteMeta::basic(c(1)));
        assert_eq!(rb_st.len(), 2);
        assert_eq!(rb_st[1].0, hist(&[(rb(), 2)]));

        // C1: GET from Ra (sees x, ctx {a1}), PUT y at Ra -> {a1,a2}
        let (vals, ctx_a) = m.read(&ra_st);
        assert_eq!(vals, vec![Val::new(2, 0)]);
        m.write(&mut ra_st, &ctx_a, Val::new(4, 0), ra(), &WriteMeta::basic(c(0)));
        // y supersedes x
        assert_eq!(ra_st.len(), 1);
        assert_eq!(ra_st[0].0, hist(&[(ra(), 1), (ra(), 2)]));

        // final: y || v, y || w
        let y = &ra_st[0].0;
        for (h, _) in &rb_st {
            assert_eq!(y.compare(h), crate::clocks::ClockOrd::Concurrent);
        }
    }

    #[test]
    fn merge_discards_obsolete_across_replicas() {
        let m = HistoryMech;
        let mut s1 = vec![(hist(&[(ra(), 1)]), Val::new(1, 0))];
        let s2 = vec![(hist(&[(ra(), 1), (rb(), 1)]), Val::new(2, 0))];
        m.merge(&mut s1, &s2);
        assert_eq!(s1.len(), 1);
        assert_eq!(s1[0].1, Val::new(2, 0));
    }

    #[test]
    fn server_counter_survives_supersession() {
        // after versions are replaced, the coordinator's counter must not
        // regress (fresh events stay unique)
        let m = HistoryMech;
        let mut st: <HistoryMech as Mechanism>::State = Vec::new();
        let meta = WriteMeta::basic(c(0));
        m.write(&mut st, &CausalHistory::new(), Val::new(1, 0), ra(), &meta);
        let (_, ctx) = m.read(&st);
        m.write(&mut st, &ctx, Val::new(2, 0), ra(), &meta);
        let (_, ctx) = m.read(&st);
        m.write(&mut st, &ctx, Val::new(3, 0), ra(), &meta);
        assert_eq!(st.len(), 1);
        assert_eq!(st[0].0.max_seq(ra()), 3);
    }

    #[test]
    fn state_codec_roundtrips() {
        let st = vec![
            (hist(&[(ra(), 1), (ra(), 2)]), Val::new(4, 3)),
            (hist(&[(rb(), 1)]), Val::new(1, 0)),
        ];
        let mut buf = Vec::new();
        HistoryMech::encode_state(&st, &mut buf);
        let mut pos = 0;
        assert_eq!(HistoryMech::decode_state(&buf, &mut pos).unwrap(), st);
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn metadata_grows_linearly_with_updates() {
        // the §3 complaint that motivates compression
        let m = HistoryMech;
        let mut st: <HistoryMech as Mechanism>::State = Vec::new();
        let meta = WriteMeta::basic(c(0));
        let mut sizes = Vec::new();
        for i in 0..50 {
            let (_, ctx) = m.read(&st);
            m.write(&mut st, &ctx, Val::new(i, 0), ra(), &meta);
            sizes.push(m.metadata_bytes(&st));
        }
        assert!(sizes[49] > sizes[9] * 3);
    }
}
