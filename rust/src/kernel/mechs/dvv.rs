//! Dotted version vectors (§5): the paper's mechanism.
//!
//! The coordinator-side `write` is the §5.3 update function:
//!
//! ```text
//! update(S, S_r, r) = {(i, ⌈S⌉_i) | i ∈ ids(S)} ∪ {(r, ⌈S⌉_r, ⌈S_r⌉_r + 1)}
//! ```
//!
//! i.e. the new clock's vector part is the ceiling of the *client context*
//! and its dot is one past the ceiling of the *replica state* — lossless
//! causality with one entry per replica server plus a single dot.

use crate::clocks::dvv::Dvv;
use crate::clocks::encoding::{decode_dvv, encode_dvv, get_varint, put_varint};
use crate::clocks::vv::VersionVector;
use crate::clocks::{Actor, LogicalClock};
use crate::kernel::mechanism::{decode_val, encode_val, DurableMechanism, Mechanism, Val, WriteMeta};
use crate::kernel::ops;

/// See module docs.
#[derive(Debug, Clone, Copy, Default)]
pub struct DvvMech;

impl Mechanism for DvvMech {
    const NAME: &'static str = "dvv";
    /// The context is the ceiling vector of the clocks the client read —
    /// sufficient because replica sets are downsets (§5.4).
    type Context = VersionVector;
    type State = Vec<(Dvv, Val)>;

    fn read(&self, st: &Self::State) -> (Vec<Val>, Self::Context) {
        let mut ctx = VersionVector::new();
        let mut vals = Vec::with_capacity(st.len());
        for (d, v) in st {
            d.join_ceil_into(&mut ctx);
            vals.push(*v);
        }
        (vals, ctx)
    }

    fn write(
        &self,
        st: &mut Self::State,
        ctx: &Self::Context,
        val: Val,
        coord: Actor,
        _meta: &WriteMeta,
    ) {
        // n = ⌈S_r⌉_coord + 1: the dot comes from the replica's knowledge
        let n = st.iter().map(|(d, _)| d.ceil(coord)).max().unwrap_or(0) + 1;
        let u = Dvv::with_dot(ctx.clone(), coord, n);
        // S'_C = sync(S_C, {u}): u's dot is fresh, so u is never dominated
        st.retain(|(d, _)| !d.compare(&u).is_leq());
        st.push((u, val));
    }

    fn merge(&self, st: &mut Self::State, incoming: &Self::State) {
        ops::sync_into(st, incoming);
    }

    fn values(&self, st: &Self::State) -> Vec<Val> {
        st.iter().map(|(_, v)| *v).collect()
    }

    fn metadata_bytes(&self, st: &Self::State) -> usize {
        st.iter().map(|(d, _)| d.encoded_size()).sum()
    }

    fn context_bytes(&self, ctx: &Self::Context) -> usize {
        ctx.encoded_size()
    }

    fn state_digest(st: &Self::State) -> u64 {
        // Sibling order is replica-history-dependent, so fold an
        // order-independent multiset digest of per-sibling encodings.
        st.iter().fold(0u64, |acc, (d, v)| {
            acc.wrapping_add(crate::kernel::digest::of_encoded(|buf| {
                encode_dvv(d, buf);
                encode_val(v, buf);
            }))
        })
    }
}

impl DurableMechanism for DvvMech {
    fn encode_state(st: &Self::State, buf: &mut Vec<u8>) {
        put_varint(buf, st.len() as u64);
        for (d, v) in st {
            encode_dvv(d, buf);
            encode_val(v, buf);
        }
    }

    fn decode_state(buf: &[u8], pos: &mut usize) -> crate::Result<Self::State> {
        let count = get_varint(buf, pos)?;
        let mut st = Vec::new();
        for _ in 0..count {
            let d = decode_dvv(buf, pos)?;
            let v = decode_val(buf, pos)?;
            st.push((d, v));
        }
        Ok(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clocks::dvv::dvv;
    use crate::clocks::ClockOrd;

    fn ra() -> Actor {
        Actor::server(0)
    }
    fn rb() -> Actor {
        Actor::server(1)
    }
    fn c(i: u32) -> Actor {
        Actor::client(i)
    }

    /// The full Figure 7 run, asserting every clock the paper prints.
    #[test]
    fn figure7_run() {
        let m = DvvMech;
        let mut ra_st: <DvvMech as Mechanism>::State = Vec::new();
        let mut rb_st: <DvvMech as Mechanism>::State = Vec::new();
        let empty = VersionVector::new();

        // C1: PUT v at Rb -> (b,0,1)
        m.write(&mut rb_st, &empty, Val::new(1, 0), rb(), &WriteMeta::basic(c(0)));
        assert_eq!(rb_st[0].0, dvv(&[], Some((rb(), 1))));

        // C3: PUT x at Ra -> (a,0,1)
        m.write(&mut ra_st, &empty, Val::new(2, 0), ra(), &WriteMeta::basic(c(2)));
        assert_eq!(ra_st[0].0, dvv(&[], Some((ra(), 1))));

        // C2: PUT w at Rb, empty context -> (b,0,2); v kept as sibling
        m.write(&mut rb_st, &empty, Val::new(3, 0), rb(), &WriteMeta::basic(c(1)));
        assert_eq!(rb_st.len(), 2, "same-server concurrency preserved");
        assert_eq!(rb_st[1].0, dvv(&[], Some((rb(), 2))));

        // C1: GET at Ra (reads x, ctx {(a,1)}), PUT y at Ra -> (a,1,2)
        let (vals, ctx) = m.read(&ra_st);
        assert_eq!(vals, vec![Val::new(2, 0)]);
        assert_eq!(ctx, crate::clocks::vv::vv(&[(ra(), 1)]));
        m.write(&mut ra_st, &ctx, Val::new(4, 0), ra(), &WriteMeta::basic(c(0)));
        assert_eq!(ra_st.len(), 1, "y supersedes x");
        assert_eq!(ra_st[0].0, dvv(&[(ra(), 1)], Some((ra(), 2))));

        // anti-entropy: Rb sends state to Ra; Ra syncs
        let rb_snapshot = rb_st.clone();
        m.merge(&mut ra_st, &rb_snapshot);
        assert_eq!(ra_st.len(), 3, "y, v, w all concurrent at Ra");

        // C2 reads at Rb (sees v,w; ctx {(b,2)}), writes z at Ra
        let (_, ctx_b) = m.read(&rb_st);
        assert_eq!(ctx_b, crate::clocks::vv::vv(&[(rb(), 2)]));
        m.write(&mut ra_st, &ctx_b, Val::new(5, 0), ra(), &WriteMeta::basic(c(1)));

        // z = {(a,0,3),(b,2)}: subsumes v,w; concurrent with y
        let z = ra_st
            .iter()
            .find(|(_, v)| *v == Val::new(5, 0))
            .map(|(d, _)| d.clone())
            .unwrap();
        assert_eq!(z, dvv(&[(rb(), 2)], Some((ra(), 3))));
        assert_eq!(ra_st.len(), 2, "only y and z survive: {ra_st:?}");
        let y = ra_st
            .iter()
            .find(|(_, v)| *v == Val::new(4, 0))
            .map(|(d, _)| d.clone())
            .unwrap();
        assert_eq!(y.compare(&z), ClockOrd::Concurrent);
    }

    #[test]
    fn overwrite_read_version_with_dot() {
        // §5.3: "the generated clock is (a,1,2), as the read context
        // dominates ... the clock of the version in the replica node"
        let m = DvvMech;
        let mut st: <DvvMech as Mechanism>::State = Vec::new();
        m.write(&mut st, &VersionVector::new(), Val::new(1, 0), ra(), &WriteMeta::basic(c(0)));
        let (_, ctx) = m.read(&st);
        m.write(&mut st, &ctx, Val::new(2, 0), ra(), &WriteMeta::basic(c(0)));
        assert_eq!(st.len(), 1);
        assert_eq!(st[0].0, dvv(&[(ra(), 1)], Some((ra(), 2))));
    }

    #[test]
    fn stale_context_concurrent_same_server() {
        // the §5.2 situation: {(r,4)} in store, client holds ctx {(r,3)}
        let m = DvvMech;
        let mut st = vec![(dvv(&[(ra(), 4)], None), Val::new(1, 0))];
        let ctx = crate::clocks::vv::vv(&[(ra(), 3)]);
        m.write(&mut st, &ctx, Val::new(2, 0), ra(), &WriteMeta::basic(c(0)));
        assert_eq!(st.len(), 2, "concurrent, both kept: {st:?}");
        assert_eq!(st[1].0, dvv(&[(ra(), 3)], Some((ra(), 5))));
    }

    #[test]
    fn merge_matches_kernel_sync() {
        let m = DvvMech;
        let mut st = vec![(dvv(&[], Some((rb(), 1))), Val::new(1, 0))];
        let incoming = vec![(dvv(&[(rb(), 2)], Some((ra(), 3))), Val::new(5, 0))];
        m.merge(&mut st, &incoming);
        assert_eq!(st.len(), 1);
        assert_eq!(st[0].1, Val::new(5, 0));
    }

    #[test]
    fn metadata_bounded_by_replicas_not_clients() {
        // many clients, two replica servers: metadata stays tiny (E7)
        let m = DvvMech;
        let mut st: <DvvMech as Mechanism>::State = Vec::new();
        for i in 0..500u32 {
            let (_, ctx) = m.read(&st);
            let coord = if i % 2 == 0 { ra() } else { rb() };
            m.write(&mut st, &ctx, Val::new(i as u64, 0), coord, &WriteMeta::basic(c(i)));
        }
        assert_eq!(st.len(), 1);
        assert!(m.metadata_bytes(&st) < 24, "got {}", m.metadata_bytes(&st));
    }

    #[test]
    fn state_codec_roundtrips_and_rejects_truncation() {
        let m = DvvMech;
        let mut st: <DvvMech as Mechanism>::State = Vec::new();
        let empty = VersionVector::new();
        m.write(&mut st, &empty, Val::new(1, 4), ra(), &WriteMeta::basic(c(0)));
        m.write(&mut st, &empty, Val::new(2, 9), rb(), &WriteMeta::basic(c(1)));
        for state in [Vec::new(), st] {
            let mut buf = Vec::new();
            DvvMech::encode_state(&state, &mut buf);
            let mut pos = 0;
            assert_eq!(DvvMech::decode_state(&buf, &mut pos).unwrap(), state);
            assert_eq!(pos, buf.len());
            for cut in 0..buf.len() {
                let mut p = 0;
                assert!(
                    DvvMech::decode_state(&buf[..cut], &mut p).is_err(),
                    "prefix {cut} decoded"
                );
            }
        }
    }

    #[test]
    fn downset_invariant_holds_under_random_ops() {
        use crate::testkit::Rng;
        let m = DvvMech;
        let mut rng = Rng::new(99);
        let mut states: Vec<<DvvMech as Mechanism>::State> = vec![Vec::new(), Vec::new()];
        let mut contexts: Vec<VersionVector> = vec![VersionVector::new(); 4];
        for op in 0..400 {
            let node = rng.below(2) as usize;
            let client = rng.below(4) as usize;
            match rng.below(3) {
                0 => {
                    // GET
                    let (_, ctx) = m.read(&states[node]);
                    contexts[client] = ctx;
                }
                1 => {
                    // PUT with the client's stored context
                    let coord = Actor::server(node as u32);
                    let ctx = contexts[client].clone();
                    m.write(
                        &mut states[node],
                        &ctx,
                        Val::new(op, 0),
                        coord,
                        &WriteMeta::basic(Actor::client(client as u32)),
                    );
                }
                _ => {
                    // anti-entropy
                    let other = states[1 - node].clone();
                    m.merge(&mut states[node], &other);
                }
            }
            // §5.4: every replica set is a downset
            for st in &states {
                let mut union = crate::clocks::CausalHistory::new();
                for (d, _) in st {
                    union.merge_from(&d.history());
                }
                assert!(union.is_downset(), "downset violated: {st:?}");
            }
        }
    }
}
