//! DVVSet mechanism (extension): compact sibling sets with positional dots.
//!
//! Same causal behaviour as [`super::dvv::DvvMech`] — the E-index ablation
//! (`benches/metadata.rs`) contrasts their metadata footprints when many
//! siblings accumulate.

use crate::clocks::dvvset::DvvSet;
use crate::clocks::encoding::{get_varint, put_varint};
use crate::clocks::vv::VersionVector;
use crate::clocks::Actor;
use crate::kernel::mechanism::{decode_val, encode_val, DurableMechanism, Mechanism, Val, WriteMeta};

/// See module docs.
#[derive(Debug, Clone, Copy, Default)]
pub struct DvvSetMech;

impl Mechanism for DvvSetMech {
    const NAME: &'static str = "dvvset";
    type Context = VersionVector;
    type State = DvvSet<Val>;

    fn read(&self, st: &Self::State) -> (Vec<Val>, Self::Context) {
        (st.values().into_iter().copied().collect(), st.vv())
    }

    fn write(
        &self,
        st: &mut Self::State,
        ctx: &Self::Context,
        val: Val,
        coord: Actor,
        _meta: &WriteMeta,
    ) {
        st.update(ctx, val, coord);
    }

    fn merge(&self, st: &mut Self::State, incoming: &Self::State) {
        st.sync_from(incoming);
    }

    fn values(&self, st: &Self::State) -> Vec<Val> {
        st.values().into_iter().copied().collect()
    }

    fn metadata_bytes(&self, st: &Self::State) -> usize {
        st.metadata_bytes()
    }

    fn context_bytes(&self, ctx: &Self::Context) -> usize {
        use crate::clocks::LogicalClock;
        ctx.encoded_size()
    }

    fn state_digest(st: &Self::State) -> u64 {
        // `columns()` iterates actors in ascending order, so the codec
        // output is canonical; hash it directly.
        crate::kernel::digest::of_encoded(|buf| Self::encode_state(st, buf))
    }
}

impl DurableMechanism for DvvSetMech {
    fn encode_state(st: &Self::State, buf: &mut Vec<u8>) {
        put_varint(buf, st.columns().count() as u64);
        for (actor, n, vals) in st.columns() {
            put_varint(buf, u64::from(actor.0));
            put_varint(buf, n);
            put_varint(buf, vals.len() as u64);
            for v in vals {
                encode_val(v, buf);
            }
        }
    }

    fn decode_state(buf: &[u8], pos: &mut usize) -> crate::Result<Self::State> {
        let columns = get_varint(buf, pos)?;
        let mut st = DvvSet::new();
        for _ in 0..columns {
            let actor = get_varint(buf, pos)?;
            let actor = u32::try_from(actor)
                .map_err(|_| crate::Error::Codec(format!("dvvset actor {actor} out of range")))?;
            let n = get_varint(buf, pos)?;
            let count = get_varint(buf, pos)?;
            let mut vals = Vec::new();
            for _ in 0..count {
                vals.push(decode_val(buf, pos)?);
            }
            // push_column re-validates the set invariants (ascending
            // actors, n covering the values), so a corrupt encoding can
            // never materialize an invalid DvvSet
            st.push_column(Actor(actor), n, vals)?;
        }
        Ok(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ra() -> Actor {
        Actor::server(0)
    }
    fn rb() -> Actor {
        Actor::server(1)
    }
    fn c(i: u32) -> Actor {
        Actor::client(i)
    }

    /// The Figure 7 value flow under DVVSet: identical survivors to DVV.
    #[test]
    fn figure7_equivalent_outcome() {
        let m = DvvSetMech;
        let mut ra_st: <DvvSetMech as Mechanism>::State = DvvSet::new();
        let mut rb_st: <DvvSetMech as Mechanism>::State = DvvSet::new();
        let empty = VersionVector::new();

        m.write(&mut rb_st, &empty, Val::new(1, 0), rb(), &WriteMeta::basic(c(0))); // v
        m.write(&mut ra_st, &empty, Val::new(2, 0), ra(), &WriteMeta::basic(c(2))); // x
        m.write(&mut rb_st, &empty, Val::new(3, 0), rb(), &WriteMeta::basic(c(1))); // w
        assert_eq!(m.sibling_count(&rb_st), 2);

        let (_, ctx) = m.read(&ra_st);
        m.write(&mut ra_st, &ctx, Val::new(4, 0), ra(), &WriteMeta::basic(c(0))); // y
        assert_eq!(m.values(&ra_st), vec![Val::new(4, 0)]);

        // anti-entropy Rb -> Ra
        m.merge(&mut ra_st, &rb_st);
        assert_eq!(m.sibling_count(&ra_st), 3);

        // C2 reads Rb, writes z at Ra
        let (_, ctx_b) = m.read(&rb_st);
        m.write(&mut ra_st, &ctx_b, Val::new(5, 0), ra(), &WriteMeta::basic(c(1)));
        let vals = m.values(&ra_st);
        assert_eq!(vals.len(), 2, "y and z: {ra_st}");
        assert!(vals.contains(&Val::new(4, 0)) && vals.contains(&Val::new(5, 0)));
    }

    #[test]
    fn merge_is_convergent() {
        let m = DvvSetMech;
        let empty = VersionVector::new();
        let mut s1: <DvvSetMech as Mechanism>::State = DvvSet::new();
        let mut s2: <DvvSetMech as Mechanism>::State = DvvSet::new();
        m.write(&mut s1, &empty, Val::new(1, 0), ra(), &WriteMeta::basic(c(0)));
        m.write(&mut s2, &empty, Val::new(2, 0), rb(), &WriteMeta::basic(c(1)));
        let mut m1 = s1.clone();
        m.merge(&mut m1, &s2);
        let mut m2 = s2.clone();
        m.merge(&mut m2, &s1);
        assert_eq!(m.values(&m1).len(), 2);
        let (mut v1, mut v2) = (m.values(&m1), m.values(&m2));
        v1.sort();
        v2.sort();
        assert_eq!(v1, v2);
    }

    #[test]
    fn state_codec_roundtrips_and_validates() {
        let m = DvvSetMech;
        let empty = VersionVector::new();
        let mut st: <DvvSetMech as Mechanism>::State = DvvSet::new();
        m.write(&mut st, &empty, Val::new(1, 4), ra(), &WriteMeta::basic(c(0)));
        m.write(&mut st, &empty, Val::new(2, 4), rb(), &WriteMeta::basic(c(1)));
        m.write(&mut st, &empty, Val::new(3, 4), rb(), &WriteMeta::basic(c(2)));
        for state in [DvvSet::new(), st] {
            let mut buf = Vec::new();
            DvvSetMech::encode_state(&state, &mut buf);
            let mut pos = 0;
            assert_eq!(DvvSetMech::decode_state(&buf, &mut pos).unwrap(), state);
            assert_eq!(pos, buf.len());
        }
        // out-of-order columns are a corrupt encoding, not a panic
        let mut bad = Vec::new();
        put_varint(&mut bad, 2);
        for _ in 0..2 {
            put_varint(&mut bad, u64::from(rb().0)); // same actor twice
            put_varint(&mut bad, 1);
            put_varint(&mut bad, 0);
        }
        let mut pos = 0;
        assert!(DvvSetMech::decode_state(&bad, &mut pos).is_err());
    }

    #[test]
    fn sibling_metadata_cheaper_than_dvv() {
        use crate::kernel::mechs::dvv::DvvMech;
        let set_m = DvvSetMech;
        let dvv_m = DvvMech;
        let empty = VersionVector::new();
        let mut set_st = DvvSet::new();
        let mut dvv_st = Vec::new();
        for i in 0..20u64 {
            set_m.write(&mut set_st, &empty, Val::new(i, 0), rb(), &WriteMeta::basic(c(i as u32)));
            dvv_m.write(&mut dvv_st, &empty, Val::new(i, 0), rb(), &WriteMeta::basic(c(i as u32)));
        }
        assert_eq!(set_m.sibling_count(&set_st), 20);
        assert_eq!(dvv_m.sibling_count(&dvv_st), 20);
        assert!(
            set_m.metadata_bytes(&set_st) * 4 < dvv_m.metadata_bytes(&dvv_st),
            "dvvset {} vs dvv {}",
            set_m.metadata_bytes(&set_st),
            dvv_m.metadata_bytes(&dvv_st)
        );
    }
}
