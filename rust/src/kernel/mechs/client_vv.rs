//! Version vectors with per-client entries (§3.3).
//!
//! Lossless when clients are *stateful* (each carries its own counter),
//! but the vectors grow with the number of clients that ever wrote — the
//! scalability problem DVVs remove. With *stateless* clients the server
//! must infer the client's counter ("the maximum of the respective entry
//! in the received context and all vectors at the server"), which loses
//! updates when a client switches servers (Figure 4).

use crate::clocks::encoding::{decode_vv, encode_vv, get_varint, put_varint};
use crate::clocks::vv::VersionVector;
use crate::clocks::{Actor, LogicalClock};
use crate::kernel::mechanism::{decode_val, encode_val, DurableMechanism, Mechanism, Val, WriteMeta};
use crate::kernel::ops;

/// See module docs. Vectors are indexed by *client* actors.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClientVvMech;

impl Mechanism for ClientVvMech {
    const NAME: &'static str = "clientvv";
    type Context = VersionVector;
    type State = Vec<(VersionVector, Val)>;

    fn read(&self, st: &Self::State) -> (Vec<Val>, Self::Context) {
        let mut ctx = VersionVector::new();
        let mut vals = Vec::with_capacity(st.len());
        for (vv, v) in st {
            ctx.join_from(vv);
            vals.push(*v);
        }
        (vals, ctx)
    }

    fn write(
        &self,
        st: &mut Self::State,
        ctx: &Self::Context,
        val: Val,
        _coord: Actor,
        meta: &WriteMeta,
    ) {
        let client = meta.client;
        let seq = match meta.client_seq {
            // stateful client: its own monotonic counter (correct mode)
            Some(s) => s,
            // stateless client: server-side inference (Figure 4's anomaly)
            None => {
                let local_max = st.iter().map(|(v, _)| v.get(client)).max().unwrap_or(0);
                ctx.get(client).max(local_max) + 1
            }
        };
        let mut vv = ctx.clone();
        vv.set(client, seq);
        st.retain(|(v, _)| !v.compare(&vv).is_leq());
        st.push((vv, val));
    }

    fn merge(&self, st: &mut Self::State, incoming: &Self::State) {
        ops::sync_into(st, incoming);
    }

    fn values(&self, st: &Self::State) -> Vec<Val> {
        st.iter().map(|(_, v)| *v).collect()
    }

    fn metadata_bytes(&self, st: &Self::State) -> usize {
        st.iter().map(|(vv, _)| vv.encoded_size()).sum()
    }

    fn context_bytes(&self, ctx: &Self::Context) -> usize {
        ctx.encoded_size()
    }

    fn state_digest(st: &Self::State) -> u64 {
        // Order-independent multiset digest: sibling order depends on
        // which replica merged what first.
        st.iter().fold(0u64, |acc, (vv, v)| {
            acc.wrapping_add(crate::kernel::digest::of_encoded(|buf| {
                encode_vv(vv, buf);
                encode_val(v, buf);
            }))
        })
    }
}

impl DurableMechanism for ClientVvMech {
    fn encode_state(st: &Self::State, buf: &mut Vec<u8>) {
        put_varint(buf, st.len() as u64);
        for (vv, v) in st {
            encode_vv(vv, buf);
            encode_val(v, buf);
        }
    }

    fn decode_state(buf: &[u8], pos: &mut usize) -> crate::Result<Self::State> {
        let count = get_varint(buf, pos)?;
        let mut st = Vec::new();
        for _ in 0..count {
            let vv = decode_vv(buf, pos)?;
            let v = decode_val(buf, pos)?;
            st.push((vv, v));
        }
        Ok(st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clocks::vv::vv;

    fn ra() -> Actor {
        Actor::server(0)
    }
    fn rb() -> Actor {
        Actor::server(1)
    }
    fn c(i: u32) -> Actor {
        Actor::client(i)
    }

    fn stateless(client: Actor) -> WriteMeta {
        WriteMeta { client, physical_us: 0, client_seq: None }
    }
    fn stateful(client: Actor, seq: u64) -> WriteMeta {
        WriteMeta { client, physical_us: 0, client_seq: Some(seq) }
    }

    /// Figure 4: a stateless client writing through a different server is
    /// re-registered as (C1,1); its earlier update v is falsely dominated.
    #[test]
    fn figure4_stateless_anomaly() {
        let m = ClientVvMech;
        let mut ra_st: <ClientVvMech as Mechanism>::State = Vec::new();
        let mut rb_st: <ClientVvMech as Mechanism>::State = Vec::new();
        let empty = VersionVector::new();

        // C1: PUT v at Rb -> {(C1,1)}
        m.write(&mut rb_st, &empty, Val::new(1, 0), rb(), &stateless(c(0)));
        assert_eq!(rb_st[0].0, vv(&[(c(0), 1)]));

        // C3: PUT x at Ra -> {(C3,1)}
        m.write(&mut ra_st, &empty, Val::new(2, 0), ra(), &stateless(c(2)));

        // C1: GET at Ra (context {(C3,1)}), PUT y at Ra — Ra has never
        // seen C1, so it infers (C1,1) *again*
        let (_, ctx) = m.read(&ra_st);
        m.write(&mut ra_st, &ctx, Val::new(4, 0), ra(), &stateless(c(0)));
        assert_eq!(ra_st[0].0, vv(&[(c(0), 1), (c(2), 1)]));

        // anti-entropy: y={(C1,1),(C3,1)} falsely dominates v={(C1,1)}
        m.merge(&mut rb_st, &ra_st);
        assert!(
            !m.values(&rb_st).contains(&Val::new(1, 0)),
            "v survived but the paper's anomaly loses it: {rb_st:?}"
        );
    }

    /// The same run with stateful clients is lossless.
    #[test]
    fn figure4_stateful_is_correct() {
        let m = ClientVvMech;
        let mut ra_st: <ClientVvMech as Mechanism>::State = Vec::new();
        let mut rb_st: <ClientVvMech as Mechanism>::State = Vec::new();
        let empty = VersionVector::new();

        m.write(&mut rb_st, &empty, Val::new(1, 0), rb(), &stateful(c(0), 1)); // v
        m.write(&mut ra_st, &empty, Val::new(2, 0), ra(), &stateful(c(2), 1)); // x
        let (_, ctx) = m.read(&ra_st);
        m.write(&mut ra_st, &ctx, Val::new(4, 0), ra(), &stateful(c(0), 2)); // y

        m.merge(&mut rb_st, &ra_st);
        // v={(C1,1)} < y={(C1,2),(C3,1)}: correctly superseded?? No —
        // v IS dominated here because C1 read nothing: y's vector includes
        // (C1,2) which covers (C1,1). That is *correct*: C1's second write
        // causally follows its first (same sequential client).
        assert!(!m.values(&rb_st).contains(&Val::new(1, 0)));
        // but a *different* client's blind write stays concurrent:
        let mut other: <ClientVvMech as Mechanism>::State = Vec::new();
        m.write(&mut other, &empty, Val::new(9, 0), rb(), &stateful(c(1), 1)); // w
        m.merge(&mut rb_st, &other);
        assert!(m.values(&rb_st).contains(&Val::new(9, 0)));
        assert!(m.values(&rb_st).contains(&Val::new(4, 0)));
    }

    #[test]
    fn same_server_concurrency_detected() {
        // unlike §3.2's per-server vectors, per-client vectors keep both
        // blind writes handled by one server
        let m = ClientVvMech;
        let mut st: <ClientVvMech as Mechanism>::State = Vec::new();
        let empty = VersionVector::new();
        m.write(&mut st, &empty, Val::new(1, 0), rb(), &stateful(c(0), 1));
        m.write(&mut st, &empty, Val::new(2, 0), rb(), &stateful(c(1), 1));
        assert_eq!(st.len(), 2, "both siblings kept");
    }

    #[test]
    fn state_codec_roundtrips() {
        let st = vec![
            (vv(&[(c(0), 1), (c(2), 1)]), Val::new(4, 2)),
            (vv(&[(c(1), 1)]), Val::new(9, 0)),
        ];
        let mut buf = Vec::new();
        ClientVvMech::encode_state(&st, &mut buf);
        let mut pos = 0;
        assert_eq!(ClientVvMech::decode_state(&buf, &mut pos).unwrap(), st);
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn metadata_grows_with_clients() {
        // the §3.3 scalability drawback (E7's headline contrast with DVV)
        let m = ClientVvMech;
        let mut st: <ClientVvMech as Mechanism>::State = Vec::new();
        for i in 0..200u32 {
            let (_, ctx) = m.read(&st);
            m.write(&mut st, &ctx, Val::new(i as u64, 0), rb(), &stateful(c(i), 1));
        }
        assert_eq!(st.len(), 1, "sequentially informed writes supersede");
        // ...but the surviving vector carries every client ever seen
        assert!(st[0].0.len() == 200);
        assert!(m.metadata_bytes(&st) > 600);
    }
}
