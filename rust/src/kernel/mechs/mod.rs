//! Mechanism implementations: §3's baselines + §5's contribution.

pub mod client_vv;
pub mod dvv;
pub mod dvvset;
pub mod history;
pub mod lamport;
pub mod lww;
pub mod server_vv;

pub use client_vv::ClientVvMech;
pub use dvv::DvvMech;
pub use dvvset::DvvSetMech;
pub use history::HistoryMech;
pub use lamport::LamportMech;
pub use lww::LwwMech;
pub use server_vv::ServerVvMech;

use super::mechanism::MechKind;

/// A visitor dispatched with the concrete mechanism for a [`MechKind`] —
/// the bridge from runtime config strings to the monomorphized store.
pub trait MechVisitor {
    /// Result type returned by the visit.
    type Out;

    /// Called with the selected mechanism instance.
    fn visit<M: super::mechanism::Mechanism>(self, mech: M) -> Self::Out;
}

/// Dispatch `visitor` with the mechanism named by `kind`.
pub fn dispatch<V: MechVisitor>(kind: MechKind, visitor: V) -> V::Out {
    match kind {
        MechKind::History => visitor.visit(HistoryMech),
        MechKind::Lww => visitor.visit(LwwMech),
        MechKind::Lamport => visitor.visit(LamportMech),
        MechKind::ServerVv => visitor.visit(ServerVvMech),
        MechKind::ClientVv => visitor.visit(ClientVvMech),
        MechKind::Dvv => visitor.visit(DvvMech),
        MechKind::DvvSet => visitor.visit(DvvSetMech),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::mechanism::Mechanism;

    struct NameOf;
    impl MechVisitor for NameOf {
        type Out = &'static str;
        fn visit<M: Mechanism>(self, _m: M) -> &'static str {
            M::NAME
        }
    }

    #[test]
    fn dispatch_reaches_every_mechanism() {
        for kind in MechKind::ALL {
            assert_eq!(dispatch(kind, NameOf), kind.name());
        }
    }
}
