//! Lamport-clock total order (§3.1's alternative baseline).
//!
//! Avoids wall-clock synchronization but still linearizes concurrent
//! updates: "again, this total order would not represent concurrent
//! events". The context carries the highest counter the client has
//! observed so the order stays causally compliant.

use crate::clocks::encoding::{decode_lamport, encode_lamport};
use crate::clocks::lamport::LamportClock;
use crate::clocks::{Actor, LogicalClock};
use crate::kernel::mechanism::{decode_val, encode_val, DurableMechanism, Mechanism, Val, WriteMeta};

/// See module docs.
#[derive(Debug, Clone, Copy, Default)]
pub struct LamportMech;

impl Mechanism for LamportMech {
    const NAME: &'static str = "lamport";
    /// Highest Lamport counter the client has observed for the key.
    type Context = u64;
    type State = Option<(LamportClock, Val)>;

    fn read(&self, st: &Self::State) -> (Vec<Val>, Self::Context) {
        (
            st.iter().map(|(_, v)| *v).collect(),
            st.as_ref().map(|(c, _)| c.counter).unwrap_or(0),
        )
    }

    fn write(
        &self,
        st: &mut Self::State,
        ctx: &Self::Context,
        val: Val,
        coord: Actor,
        _meta: &WriteMeta,
    ) {
        let local = st.as_ref().map(|(c, _)| c.counter).unwrap_or(0);
        let clock = LamportClock::tick(*ctx, local, coord);
        match st {
            Some((cur, _)) if clock.compare(cur).is_leq() => {}
            _ => *st = Some((clock, val)),
        }
    }

    fn merge(&self, st: &mut Self::State, incoming: &Self::State) {
        if let Some((inc_clock, inc_val)) = incoming {
            match st {
                Some((cur, _)) if inc_clock.compare(cur).is_leq() => {}
                _ => *st = Some((*inc_clock, *inc_val)),
            }
        }
    }

    fn values(&self, st: &Self::State) -> Vec<Val> {
        st.iter().map(|(_, v)| *v).collect()
    }

    fn metadata_bytes(&self, st: &Self::State) -> usize {
        st.as_ref().map(|(c, _)| c.encoded_size()).unwrap_or(0)
    }

    fn context_bytes(&self, ctx: &Self::Context) -> usize {
        crate::clocks::encoding::varint_len(*ctx)
    }

    fn state_digest(st: &Self::State) -> u64 {
        // `Option<(clock, val)>` is already canonical; hash the codec
        // output directly.
        crate::kernel::digest::of_encoded(|buf| Self::encode_state(st, buf))
    }
}

impl DurableMechanism for LamportMech {
    fn encode_state(st: &Self::State, buf: &mut Vec<u8>) {
        match st {
            None => buf.push(0),
            Some((clock, val)) => {
                buf.push(1);
                encode_lamport(clock, buf);
                encode_val(val, buf);
            }
        }
    }

    fn decode_state(buf: &[u8], pos: &mut usize) -> crate::Result<Self::State> {
        let flag = *buf
            .get(*pos)
            .ok_or_else(|| crate::Error::Codec("lamport state: missing flag".into()))?;
        *pos += 1;
        match flag {
            0 => Ok(None),
            1 => {
                let clock = decode_lamport(buf, pos)?;
                let val = decode_val(buf, pos)?;
                Ok(Some((clock, val)))
            }
            other => Err(crate::Error::Codec(format!("lamport state: bad flag {other}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ra() -> Actor {
        Actor::server(0)
    }
    fn rb() -> Actor {
        Actor::server(1)
    }

    #[test]
    fn causal_writes_order_correctly() {
        let m = LamportMech;
        let mut st: <LamportMech as Mechanism>::State = None;
        m.write(&mut st, &0, Val::new(1, 0), ra(), &WriteMeta::basic(Actor::client(0)));
        let (_, ctx) = m.read(&st);
        m.write(&mut st, &ctx, Val::new(2, 0), ra(), &WriteMeta::basic(Actor::client(0)));
        assert_eq!(m.values(&st), vec![Val::new(2, 0)]);
        assert_eq!(st.unwrap().0.counter, 2);
    }

    #[test]
    fn concurrent_writes_are_linearized() {
        // same counter from both sides: replica id decides — a concurrent
        // update is silently dropped (the §3.1 point)
        let m = LamportMech;
        let mut a: <LamportMech as Mechanism>::State = None;
        let mut b: <LamportMech as Mechanism>::State = None;
        m.write(&mut a, &0, Val::new(1, 0), ra(), &WriteMeta::basic(Actor::client(0)));
        m.write(&mut b, &0, Val::new(2, 0), rb(), &WriteMeta::basic(Actor::client(1)));
        m.merge(&mut a, &b);
        m.merge(&mut b, &a);
        assert_eq!(m.values(&a), m.values(&b));
        assert_eq!(m.values(&a), vec![Val::new(2, 0)]); // rb > ra tiebreak
    }

    #[test]
    fn stale_context_still_advances() {
        let m = LamportMech;
        let mut st: <LamportMech as Mechanism>::State = None;
        m.write(&mut st, &0, Val::new(1, 0), ra(), &WriteMeta::basic(Actor::client(0)));
        m.write(&mut st, &0, Val::new(2, 0), ra(), &WriteMeta::basic(Actor::client(1)));
        // local counter (1) bumps past the stale context (0)
        assert_eq!(st.as_ref().unwrap().0.counter, 2);
        assert_eq!(m.values(&st), vec![Val::new(2, 0)]);
    }

    #[test]
    fn state_codec_roundtrips() {
        for st in [None, Some((LamportClock::new(42, rb()), Val::new(5, 8)))] {
            let mut buf = Vec::new();
            LamportMech::encode_state(&st, &mut buf);
            let mut pos = 0;
            assert_eq!(LamportMech::decode_state(&buf, &mut pos).unwrap(), st);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn merge_converges() {
        let m = LamportMech;
        let a: <LamportMech as Mechanism>::State =
            Some((LamportClock::new(3, ra()), Val::new(1, 0)));
        let b: <LamportMech as Mechanism>::State =
            Some((LamportClock::new(3, rb()), Val::new(2, 0)));
        let mut ab = a.clone();
        m.merge(&mut ab, &b);
        let mut ba = b.clone();
        m.merge(&mut ba, &a);
        assert_eq!(ab, ba);
    }
}
