//! The §4 kernel operations over *sets* of clocks.
//!
//! `sync(S1, S2)` "returns a set of concurrent clocks, each belonging to
//! one of the sets, and that together cover both sets while discarding
//! obsolete knowledge" — implemented generically, "defined only in terms
//! of the partial order on clocks, regardless of their actual
//! representation".
//!
//! `update` is representation-specific (it must mint new events), so each
//! mechanism provides its own (see [`super::mechs`]); the causal-history
//! reference implementation lives in `mechs::history`.

use crate::clocks::{ClockOrd, LogicalClock};

/// The paper's `sync(S1, S2)` over tagged clock sets.
///
/// Keeps the elements of `S1` not strictly dominated by any element of
/// `S2`, plus the elements of `S2` not dominated-or-equal by any element
/// of `S1` (equal pairs keep the `S1` copy, so exactly one representative
/// of each maximal history survives). Matches the reference definition
///
/// ```text
/// sync(S1,S2) = {x ∈ S1 | ∄y ∈ S2. x < y} ∪ {x ∈ S2 | ∄y ∈ S1. x ≤ y}
/// ```
pub fn sync_sets<C: LogicalClock, V: Clone>(
    s1: &[(C, V)],
    s2: &[(C, V)],
) -> Vec<(C, V)> {
    let mut out: Vec<(C, V)> = Vec::with_capacity(s1.len() + s2.len());
    for (c1, v1) in s1 {
        let dominated = s2.iter().any(|(c2, _)| c1.compare(c2) == ClockOrd::Less);
        if !dominated {
            out.push((c1.clone(), v1.clone()));
        }
    }
    for (c2, v2) in s2 {
        let covered = s1.iter().any(|(c1, _)| c2.compare(c1).is_leq());
        if !covered {
            out.push((c2.clone(), v2.clone()));
        }
    }
    out
}

/// In-place variant used on the store's hot path: merge `incoming` into
/// `state`. Avoids cloning the surviving `state` entries.
pub fn sync_into<C: LogicalClock, V: Clone>(
    state: &mut Vec<(C, V)>,
    incoming: &[(C, V)],
) {
    state.retain(|(c1, _)| {
        !incoming.iter().any(|(c2, _)| c1.compare(c2) == ClockOrd::Less)
    });
    for (c2, v2) in incoming {
        let covered = state.iter().any(|(c1, _)| c2.compare(c1).is_leq());
        if !covered {
            state.push((c2.clone(), v2.clone()));
        }
    }
}

/// Insert one freshly minted version (the tail of a mechanism's `update`):
/// drop state entries its clock dominates, then append. The new clock is
/// assumed not to be dominated by any state entry (updates mint new
/// events; §4's condition 3).
pub fn insert_version<C: LogicalClock, V>(state: &mut Vec<(C, V)>, clock: C, value: V) {
    debug_assert!(
        !state.iter().any(|(c, _)| clock.compare(c).is_leq()),
        "a fresh update clock must not be dominated by existing state"
    );
    state.retain(|(c, _)| !c.compare(&clock).is_leq());
    state.push((clock, value));
}

/// Insert a candidate version into a winnowed set, preserving the
/// pairwise-concurrency invariant: the candidate is dropped when covered
/// by an existing entry, and drops entries it dominates. (Unlike
/// [`insert_version`], the candidate may be dominated — useful for test
/// generators and bulk loaders.)
pub fn insert_candidate<C: LogicalClock, V>(state: &mut Vec<(C, V)>, clock: C, value: V) {
    if state.iter().any(|(c, _)| clock.compare(c).is_leq()) {
        return;
    }
    state.retain(|(c, _)| !c.compare(&clock).is_leq());
    state.push((clock, value));
}

/// Are all elements of the set pairwise concurrent? (§4 sync condition 2:
/// `∀x,y ∈ S. x ≰ y`.)
pub fn pairwise_concurrent<C: LogicalClock, V>(set: &[(C, V)]) -> bool {
    for (i, (ci, _)) in set.iter().enumerate() {
        for (cj, _) in set.iter().skip(i + 1) {
            if ci.compare(cj) != ClockOrd::Concurrent {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clocks::causal_history::hist;
    use crate::clocks::{Actor, CausalHistory};

    fn a() -> Actor {
        Actor::server(0)
    }
    fn b() -> Actor {
        Actor::server(1)
    }

    fn tag(hs: Vec<CausalHistory>) -> Vec<(CausalHistory, u64)> {
        hs.into_iter().enumerate().map(|(i, h)| (h, i as u64)).collect()
    }

    #[test]
    fn sync_drops_obsolete() {
        let s1 = tag(vec![hist(&[(a(), 1)])]);
        let s2 = tag(vec![hist(&[(a(), 1), (a(), 2)])]);
        let out = sync_sets(&s1, &s2);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, hist(&[(a(), 1), (a(), 2)]));
    }

    #[test]
    fn sync_keeps_concurrent_from_both() {
        let s1 = tag(vec![hist(&[(a(), 1)])]);
        let s2 = tag(vec![hist(&[(b(), 1)])]);
        let out = sync_sets(&s1, &s2);
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn sync_dedups_equal_histories() {
        let s1 = tag(vec![hist(&[(a(), 1)])]);
        let s2 = tag(vec![hist(&[(a(), 1)])]);
        let out = sync_sets(&s1, &s2);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].1, 0, "the S1 copy is kept");
    }

    #[test]
    fn sync_conditions_hold() {
        // §4: (1) results come from the inputs, (2) pairwise concurrent,
        // (3) every input is covered by some output.
        let s1 = tag(vec![hist(&[(a(), 1)]), hist(&[(b(), 1)])]);
        let s2 = tag(vec![hist(&[(a(), 1), (a(), 2)]), hist(&[(b(), 1)])]);
        let out = sync_sets(&s1, &s2);
        assert!(pairwise_concurrent(&out));
        for (c, _) in s1.iter().chain(s2.iter()) {
            assert!(
                out.iter().any(|(o, _)| c.compare(o).is_leq()),
                "input {c} not covered"
            );
        }
        for (c, _) in &out {
            assert!(
                s1.iter().chain(s2.iter()).any(|(i, _)| i == c),
                "output {c} not from inputs"
            );
        }
    }

    #[test]
    fn sync_into_matches_sync_sets() {
        let s1 = tag(vec![hist(&[(a(), 1)]), hist(&[(b(), 2), (b(), 1)])]);
        let s2 = tag(vec![hist(&[(a(), 1), (b(), 1)])]);
        let by_value = sync_sets(&s1, &s2);
        let mut in_place = s1.clone();
        sync_into(&mut in_place, &s2);
        // order may differ; compare as sets of clocks
        assert_eq!(by_value.len(), in_place.len());
        for (c, _) in &by_value {
            assert!(in_place.iter().any(|(c2, _)| c2 == c));
        }
    }

    #[test]
    fn insert_version_discards_dominated() {
        let mut st = tag(vec![hist(&[(a(), 1)]), hist(&[(b(), 1)])]);
        insert_version(&mut st, hist(&[(a(), 1), (a(), 2)]), 9);
        assert_eq!(st.len(), 2);
        assert!(st.iter().any(|(_, v)| *v == 9));
        assert!(st.iter().any(|(c, _)| *c == hist(&[(b(), 1)])));
    }

    #[test]
    fn pairwise_concurrent_detects_order() {
        let ok = tag(vec![hist(&[(a(), 1)]), hist(&[(b(), 1)])]);
        assert!(pairwise_concurrent(&ok));
        let bad = tag(vec![hist(&[(a(), 1)]), hist(&[(a(), 1), (a(), 2)])]);
        assert!(!pairwise_concurrent(&bad));
    }
}
