//! Checkable forms of the paper's §4 kernel conditions and the §5.4
//! `downset` invariant — used by integration/property tests to validate
//! any mechanism against the specification.

use crate::clocks::{CausalHistory, ClockOrd, LogicalClock};

/// §4 sync conditions over tagged clock sets:
/// 1. every output is drawn from the inputs;
/// 2. outputs are pairwise non-dominating;
/// 3. every input is covered by some output.
pub fn check_sync_conditions<C: LogicalClock + PartialEq, V>(
    s1: &[(C, V)],
    s2: &[(C, V)],
    out: &[(C, V)],
) -> Result<(), String> {
    for (c, _) in out {
        if !s1.iter().chain(s2.iter()).any(|(i, _)| i == c) {
            return Err(format!("condition 1 violated: {c:?} not from inputs"));
        }
    }
    for (i, (ci, _)) in out.iter().enumerate() {
        for (j, (cj, _)) in out.iter().enumerate() {
            if i != j && ci.compare(cj).is_leq() {
                return Err(format!("condition 2 violated: {ci:?} <= {cj:?}"));
            }
        }
    }
    for (c, _) in s1.iter().chain(s2.iter()) {
        if !out.iter().any(|(o, _)| c.compare(o).is_leq()) {
            return Err(format!("condition 3 violated: {c:?} not covered"));
        }
    }
    Ok(())
}

/// §4 update conditions, evaluated on the *true* causal histories that a
/// test harness tracks alongside the mechanism:
/// 1. the new clock dominates every context clock;
/// 2. anything it dominates is covered by the context join;
/// 3. it is not dominated by any clock in the system.
pub fn check_update_conditions(
    context: &[CausalHistory],
    system: &[CausalHistory],
    new_clock: &CausalHistory,
) -> Result<(), String> {
    let mut ctx_join = CausalHistory::new();
    for c in context {
        if !c.is_subset(new_clock) {
            return Err(format!("update condition 1 violated: {c} not <= {new_clock}"));
        }
        ctx_join.merge_from(c);
    }
    for x in system {
        if x.is_subset(new_clock) && !x.is_subset(&ctx_join) {
            return Err(format!(
                "update condition 2 violated: {x} <= u but not <= ⊔S"
            ));
        }
        if new_clock.is_subset(x) {
            return Err(format!("update condition 3 violated: u <= {x}"));
        }
    }
    Ok(())
}

/// §5.4 `downset` predicate over a set of histories.
pub fn is_downset(histories: &[CausalHistory]) -> bool {
    let mut union = CausalHistory::new();
    for h in histories {
        union.merge_from(h);
    }
    union.is_downset()
}

/// Relation table between two clock sets, for diagnostics: how many pairs
/// are equal / ordered / concurrent.
pub fn relation_census<C: LogicalClock>(xs: &[C], ys: &[C]) -> (usize, usize, usize) {
    let (mut equal, mut ordered, mut concurrent) = (0, 0, 0);
    for x in xs {
        for y in ys {
            match x.compare(y) {
                ClockOrd::Equal => equal += 1,
                ClockOrd::Less | ClockOrd::Greater => ordered += 1,
                ClockOrd::Concurrent => concurrent += 1,
            }
        }
    }
    (equal, ordered, concurrent)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clocks::causal_history::hist;
    use crate::clocks::Actor;
    use crate::kernel::ops::sync_sets;

    fn a() -> Actor {
        Actor::server(0)
    }
    fn b() -> Actor {
        Actor::server(1)
    }

    #[test]
    fn sync_output_passes_conditions() {
        let s1 = vec![(hist(&[(a(), 1)]), 0u8), (hist(&[(b(), 1)]), 1)];
        let s2 = vec![(hist(&[(a(), 1), (a(), 2)]), 2)];
        let out = sync_sets(&s1, &s2);
        check_sync_conditions(&s1, &s2, &out).unwrap();
    }

    #[test]
    fn bad_sync_outputs_are_rejected() {
        let s1 = vec![(hist(&[(a(), 1)]), 0u8)];
        let s2 = vec![(hist(&[(b(), 1)]), 1u8)];
        // fabricated output not from inputs
        let fake = vec![(hist(&[(a(), 9)]), 9u8)];
        assert!(check_sync_conditions(&s1, &s2, &fake).is_err());
        // output dropping s2's clock violates coverage
        let partial = vec![(hist(&[(a(), 1)]), 0u8)];
        assert!(check_sync_conditions(&s1, &s2, &partial).is_err());
        // dominated pair violates condition 2
        let dominated = vec![
            (hist(&[(a(), 1)]), 0u8),
            (hist(&[(a(), 1), (b(), 1)]), 1u8),
        ];
        assert!(check_sync_conditions(&s1, &s2, &dominated).is_err());
    }

    #[test]
    fn update_conditions_accept_fresh_event() {
        let ctx = vec![hist(&[(a(), 1)])];
        let system = vec![hist(&[(a(), 1)]), hist(&[(b(), 1)])];
        let u = hist(&[(a(), 1), (a(), 2)]);
        check_update_conditions(&ctx, &system, &u).unwrap();
    }

    #[test]
    fn update_conditions_reject_stale_or_overreaching() {
        let ctx = vec![hist(&[(a(), 1)])];
        let system = vec![hist(&[(a(), 1)]), hist(&[(b(), 1)])];
        // no fresh event: dominated by a system clock
        assert!(check_update_conditions(&ctx, &system, &hist(&[(a(), 1)])).is_err());
        // swallows b1 without having it in the context
        let grabby = hist(&[(a(), 1), (a(), 2), (b(), 1)]);
        assert!(check_update_conditions(&ctx, &system, &grabby).is_err());
    }

    #[test]
    fn downset_check() {
        assert!(is_downset(&[hist(&[(a(), 1)]), hist(&[(a(), 2)])]));
        assert!(!is_downset(&[hist(&[(a(), 1)]), hist(&[(a(), 3)])]));
    }

    #[test]
    fn census_counts() {
        let xs = vec![hist(&[(a(), 1)])];
        let ys = vec![hist(&[(a(), 1)]), hist(&[(a(), 1), (a(), 2)]), hist(&[(b(), 1)])];
        assert_eq!(relation_census(&xs, &ys), (1, 1, 1));
    }
}
