//! Hash primitives for the anti-entropy Merkle trees
//! ([`crate::antientropy::merkle`]).
//!
//! Everything here is deliberately tiny and dependency-free: a 64-bit
//! mixer (the splitmix64 finalizer, same construction as
//! [`crate::cluster::ring::hash64`]), an FNV-1a byte hash fed through it,
//! and a helper for hashing a state's codec output. The trees combine
//! per-key digests with **wrapping addition**, so the per-key digest must
//! already be well-mixed — a single flipped input bit flips about half of
//! the output bits, which is what makes the 2^-64 collision bound of the
//! tree walk credible.
//!
//! Addition (not XOR) is the combiner because it is order-independent
//! *and* invertible (`wrapping_sub` removes a stale contribution), which
//! is exactly what incremental maintenance needs: replacing one key's
//! digest under a node is `sum - old + new`, touching O(depth) interior
//! hashes instead of rebuilding the subtree.

/// The splitmix64 finalizer: a cheap bijective mixer on `u64`.
///
/// Bijectivity matters: distinct inputs stay distinct, so `mix64` never
/// *introduces* collisions — only the additive combination of many keys
/// can, at the usual birthday bound.
pub fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// FNV-1a over `bytes`, finished with [`mix64`] — the digest of one
/// encoded sibling (or one whole canonical state encoding).
pub fn bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    mix64(h)
}

/// Digest a state whose encoding is *canonical* (equal states encode to
/// equal bytes regardless of replica history): encode it with `f` into a
/// scratch buffer and hash the bytes.
///
/// Mechanisms whose state is an unordered sibling `Vec` must NOT use
/// this directly on the whole encoding — converged replicas can hold the
/// same multiset in different orders. They instead fold
/// [`bytes`]-of-each-sibling with `wrapping_add` (an order-independent
/// multiset digest); see the per-mechanism `state_digest` impls.
pub fn of_encoded(f: impl FnOnce(&mut Vec<u8>)) -> u64 {
    let mut buf = Vec::with_capacity(64);
    f(&mut buf);
    bytes(&buf)
}

/// The leaf digest the Merkle trees store for `(key, state)`: the key is
/// mixed in so that the same state under two different keys contributes
/// two unrelated terms to the additive node sums.
pub fn leaf(key: u64, state_digest: u64) -> u64 {
    mix64(mix64(key) ^ state_digest)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix64_is_injective_on_a_sample() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            assert!(seen.insert(mix64(i)));
        }
    }

    #[test]
    fn bytes_distinguishes_near_misses() {
        assert_ne!(bytes(b"abc"), bytes(b"abd"));
        assert_ne!(bytes(b""), bytes(b"\0"));
        assert_ne!(bytes(b"ab"), bytes(b"ba"));
    }

    #[test]
    fn leaf_depends_on_both_key_and_state() {
        assert_ne!(leaf(1, 42), leaf(2, 42));
        assert_ne!(leaf(1, 42), leaf(1, 43));
    }

    #[test]
    fn of_encoded_matches_manual_encoding() {
        let d = of_encoded(|buf| buf.extend_from_slice(b"state"));
        assert_eq!(d, bytes(b"state"));
    }
}
