//! OR-Map: observed-remove field map with register values.
//!
//! Fields are keyed exactly like ORSWOT elements — each put mints a dot,
//! each remove deletes the *observed* dots — so field presence follows
//! add-wins/observed-remove semantics with no tombstones. The surviving
//! field's value is taken from whichever side holds the field's **max
//! surviving dot**: among concurrent puts that both survive a merge, the
//! winner is deterministic (dots are unique per write, so equal dots
//! carry equal values), and a put that superseded another (its `replaced`
//! list) wins outright because the superseded dot does not survive.

use crate::clocks::encoding::{encode_vv, get_bytes, get_varint, put_varint};
use crate::clocks::{Actor, VersionVector};
use crate::error::{Error, Result};

use super::{decode_dots, encode_dots, Dot};

/// An observed-remove field map.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct OrMap {
    /// Every dot this replica has observed (per-actor contiguous).
    clock: VersionVector,
    /// Present fields: `(field, live dots, value)`, sorted by field;
    /// dot lists sorted ascending and never empty; `value` is the bytes
    /// written by the put that minted the max live dot.
    entries: Vec<(Vec<u8>, Vec<Dot>, Vec<u8>)>,
}

/// The change one map mutation made (see [`super::CrdtDelta`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MapDelta {
    /// The mutating replica's clock before the op.
    pub ctx_before: VersionVector,
    /// The clock after the op.
    pub ctx_after: VersionVector,
    /// What changed.
    pub change: MapChange,
}

/// The concrete mutation inside a [`MapDelta`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MapChange {
    /// `field` was set to `value`, tagged `dot`, superseding `replaced`.
    Put {
        /// Field bytes.
        field: Vec<u8>,
        /// New value bytes.
        value: Vec<u8>,
        /// The freshly minted dot tagging this put.
        dot: Dot,
        /// The putter's previously observed dots for `field`.
        replaced: Vec<Dot>,
    },
    /// `field`'s observed `dots` were removed.
    Remove {
        /// Field bytes.
        field: Vec<u8>,
        /// The exact dots the remover observed and deleted.
        dots: Vec<Dot>,
    },
}

impl OrMap {
    /// The empty map.
    pub fn new() -> OrMap {
        OrMap::default()
    }

    /// The map's causal clock.
    pub fn clock(&self) -> &VersionVector {
        &self.clock
    }

    /// The next dot `actor` may mint from this state (same contiguity
    /// contract as [`super::Orswot::mint`]).
    pub fn mint(&self, actor: Actor) -> Dot {
        Dot::new(actor, self.clock.get(actor) + 1)
    }

    /// Number of present fields.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no field is present.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Current value of `field`, if present.
    pub fn get(&self, field: &[u8]) -> Option<&[u8]> {
        self.find(field).ok().map(|i| self.entries[i].2.as_slice())
    }

    /// Present `(field, value)` pairs, ascending by field.
    pub fn fields(&self) -> impl Iterator<Item = (&[u8], &[u8])> + '_ {
        self.entries.iter().map(|(f, _, v)| (f.as_slice(), v.as_slice()))
    }

    fn find(&self, field: &[u8]) -> std::result::Result<usize, usize> {
        self.entries.binary_search_by(|(f, _, _)| f.as_slice().cmp(field))
    }

    fn absorb(&mut self, dot: Dot) {
        if dot.counter > self.clock.get(dot.actor) {
            self.clock.set(dot.actor, dot.counter);
        }
    }

    /// Set `field` to `value`, tagged with `dot` (minted via
    /// [`mint`](OrMap::mint)). Observed dots collapse into the new one.
    pub fn put(&mut self, field: Vec<u8>, value: Vec<u8>, dot: Dot) -> MapDelta {
        let ctx_before = self.clock.clone();
        let replaced = match self.find(&field) {
            Ok(i) => {
                self.entries[i].2 = value.clone();
                std::mem::replace(&mut self.entries[i].1, vec![dot])
            }
            Err(i) => {
                self.entries.insert(i, (field.clone(), vec![dot], value.clone()));
                Vec::new()
            }
        };
        self.absorb(dot);
        MapDelta {
            ctx_before,
            ctx_after: self.clock.clone(),
            change: MapChange::Put { field, value, dot, replaced },
        }
    }

    /// Remove `field`: delete its observed dots (remove-wins only over
    /// dots the remover saw). Returns the removed dots plus the delta.
    pub fn remove(&mut self, field: &[u8]) -> (Vec<Dot>, MapDelta) {
        let dots = match self.find(field) {
            Ok(i) => self.entries.remove(i).1,
            Err(_) => Vec::new(),
        };
        let ctx = self.clock.clone();
        let delta = MapDelta {
            ctx_before: ctx.clone(),
            ctx_after: ctx,
            change: MapChange::Remove { field: field.to_vec(), dots: dots.clone() },
        };
        (dots, delta)
    }

    /// Join another replica's state: ORSWOT survival per field dot, the
    /// surviving value from the side holding the max surviving dot.
    pub fn merge(&mut self, other: &OrMap) {
        let mut out: Vec<(Vec<u8>, Vec<Dot>, Vec<u8>)> =
            Vec::with_capacity(self.entries.len().max(other.entries.len()));
        let (mut i, mut j) = (0, 0);
        while i < self.entries.len() || j < other.entries.len() {
            let ord = match (self.entries.get(i), other.entries.get(j)) {
                (Some((a, _, _)), Some((b, _, _))) => a.cmp(b),
                (Some(_), None) => std::cmp::Ordering::Less,
                (None, Some(_)) => std::cmp::Ordering::Greater,
                (None, None) => unreachable!("loop condition"),
            };
            match ord {
                std::cmp::Ordering::Less => {
                    let (field, dots, value) = &self.entries[i];
                    let keep: Vec<Dot> = dots
                        .iter()
                        .filter(|d| d.counter > other.clock.get(d.actor))
                        .copied()
                        .collect();
                    if !keep.is_empty() {
                        out.push((field.clone(), keep, value.clone()));
                    }
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    let (field, dots, value) = &other.entries[j];
                    let keep: Vec<Dot> = dots
                        .iter()
                        .filter(|d| d.counter > self.clock.get(d.actor))
                        .copied()
                        .collect();
                    if !keep.is_empty() {
                        out.push((field.clone(), keep, value.clone()));
                    }
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    let (field, mine, my_value) = &self.entries[i];
                    let (_, theirs, their_value) = &other.entries[j];
                    let mut keep: Vec<Dot> = mine
                        .iter()
                        .filter(|d| {
                            theirs.contains(d) || d.counter > other.clock.get(d.actor)
                        })
                        .copied()
                        .collect();
                    for d in theirs {
                        if !keep.contains(d) && d.counter > self.clock.get(d.actor) {
                            keep.push(*d);
                        }
                    }
                    keep.sort_unstable();
                    if let Some(&max) = keep.last() {
                        // unique dots: if the max survivor is in my
                        // entry, my value was written with it
                        let value = if mine.contains(&max) {
                            my_value.clone()
                        } else {
                            their_value.clone()
                        };
                        out.push((field.clone(), keep, value));
                    }
                    i += 1;
                    j += 1;
                }
            }
        }
        self.entries = out;
        self.clock.join_from(&other.clock);
    }

    /// Apply a sender's delta (same contract as
    /// [`super::Orswot::apply_delta`]: receiver must dominate
    /// `ctx_before`, else `false` and untouched).
    pub fn apply_delta(&mut self, d: &MapDelta) -> bool {
        if !d.ctx_before.dominated_by(&self.clock) {
            return false;
        }
        match &d.change {
            MapChange::Put { field, value, dot, replaced } => match self.find(field) {
                Ok(i) => {
                    let dots = &mut self.entries[i].1;
                    dots.retain(|x| !replaced.contains(x));
                    if let Err(at) = dots.binary_search(dot) {
                        dots.insert(at, *dot);
                    }
                    if dots.last() == Some(dot) {
                        self.entries[i].2 = value.clone();
                    }
                }
                Err(i) => {
                    self.entries.insert(i, (field.clone(), vec![*dot], value.clone()));
                }
            },
            MapChange::Remove { field, dots } => {
                if let Ok(i) = self.find(field) {
                    self.entries[i].1.retain(|x| !dots.contains(x));
                    if self.entries[i].1.is_empty() {
                        self.entries.remove(i);
                    }
                }
            }
        }
        self.clock.join_from(&d.ctx_after);
        true
    }

    /// Append the canonical encoding.
    pub fn encode(&self, buf: &mut Vec<u8>) {
        encode_vv(&self.clock, buf);
        put_varint(buf, self.entries.len() as u64);
        for (field, dots, value) in &self.entries {
            put_varint(buf, field.len() as u64);
            buf.extend_from_slice(field);
            put_varint(buf, value.len() as u64);
            buf.extend_from_slice(value);
            encode_dots(dots, buf);
        }
    }

    /// Decode one map with the same strictness as
    /// [`super::Orswot::decode`].
    pub fn decode(buf: &[u8], pos: &mut usize) -> Result<OrMap> {
        let clock = crate::clocks::encoding::decode_vv(buf, pos)?;
        let count = get_varint(buf, pos)?;
        let cap = (count as usize).min(buf.len().saturating_sub(*pos) / 5);
        let mut entries: Vec<(Vec<u8>, Vec<Dot>, Vec<u8>)> = Vec::with_capacity(cap);
        for _ in 0..count {
            let flen = get_varint(buf, pos)?;
            let field = get_bytes(buf, pos, flen as usize)?.to_vec();
            if let Some((last, _, _)) = entries.last() {
                if *last >= field {
                    return Err(Error::Codec("map fields out of order".into()));
                }
            }
            let vlen = get_varint(buf, pos)?;
            let value = get_bytes(buf, pos, vlen as usize)?.to_vec();
            let dots = decode_dots(buf, pos)?;
            if dots.is_empty() {
                return Err(Error::Codec("map field with no dots".into()));
            }
            for d in &dots {
                if d.counter > clock.get(d.actor) {
                    return Err(Error::Codec(format!("dot {d} not covered by map clock")));
                }
            }
            entries.push((field, dots, value));
        }
        Ok(OrMap { clock, entries })
    }
}

impl MapDelta {
    /// Append the wire encoding.
    pub fn encode(&self, buf: &mut Vec<u8>) {
        encode_vv(&self.ctx_before, buf);
        encode_vv(&self.ctx_after, buf);
        match &self.change {
            MapChange::Put { field, value, dot, replaced } => {
                buf.push(0);
                put_varint(buf, field.len() as u64);
                buf.extend_from_slice(field);
                put_varint(buf, value.len() as u64);
                buf.extend_from_slice(value);
                super::encode_dot(dot, buf);
                encode_dots(replaced, buf);
            }
            MapChange::Remove { field, dots } => {
                buf.push(1);
                put_varint(buf, field.len() as u64);
                buf.extend_from_slice(field);
                encode_dots(dots, buf);
            }
        }
    }

    /// Decode one map delta.
    pub fn decode(buf: &[u8], pos: &mut usize) -> Result<MapDelta> {
        let ctx_before = crate::clocks::encoding::decode_vv(buf, pos)?;
        let ctx_after = crate::clocks::encoding::decode_vv(buf, pos)?;
        let tag = *buf
            .get(*pos)
            .ok_or_else(|| Error::Codec("map delta truncated".into()))?;
        *pos += 1;
        let change = match tag {
            0 => {
                let flen = get_varint(buf, pos)?;
                let field = get_bytes(buf, pos, flen as usize)?.to_vec();
                let vlen = get_varint(buf, pos)?;
                let value = get_bytes(buf, pos, vlen as usize)?.to_vec();
                let dot = super::decode_dot(buf, pos)?;
                let replaced = decode_dots(buf, pos)?;
                MapChange::Put { field, value, dot, replaced }
            }
            1 => {
                let flen = get_varint(buf, pos)?;
                let field = get_bytes(buf, pos, flen as usize)?.to_vec();
                let dots = decode_dots(buf, pos)?;
                MapChange::Remove { field, dots }
            }
            other => return Err(Error::Codec(format!("bad map-change tag {other}"))),
        };
        Ok(MapDelta { ctx_before, ctx_after, change })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::{forall, from_fn, Config};
    use crate::testkit::Rng;

    fn a(i: u32) -> Actor {
        Actor::server(i)
    }

    fn put(m: &mut OrMap, actor: Actor, field: &[u8], value: &[u8]) -> MapDelta {
        let dot = m.mint(actor);
        m.put(field.to_vec(), value.to_vec(), dot)
    }

    #[test]
    fn put_get_remove() {
        let mut m = OrMap::new();
        put(&mut m, a(0), b"name", b"ada");
        put(&mut m, a(0), b"name", b"grace");
        assert_eq!(m.get(b"name"), Some(&b"grace"[..]));
        assert_eq!(m.len(), 1);
        let (dots, _) = m.remove(b"name");
        assert_eq!(dots, vec![Dot::new(a(0), 2)], "only the live dot");
        assert!(m.get(b"name").is_none());
        assert!(m.is_empty(), "no tombstone entry");
    }

    #[test]
    fn concurrent_put_survives_observed_remove() {
        let mut base = OrMap::new();
        put(&mut base, a(0), b"f", b"v0");
        let (mut ra, mut rb) = (base.clone(), base);
        ra.remove(b"f");
        put(&mut rb, a(1), b"f", b"v1");
        let mut m = ra.clone();
        m.merge(&rb);
        assert_eq!(m.get(b"f"), Some(&b"v1"[..]), "unobserved put survives");
        let mut m2 = rb;
        m2.merge(&ra);
        assert_eq!(m, m2);
    }

    #[test]
    fn concurrent_puts_pick_max_dot_deterministically() {
        let mut base = OrMap::new();
        put(&mut base, a(0), b"f", b"v0");
        let (mut ra, mut rb) = (base.clone(), base);
        put(&mut ra, a(1), b"f", b"from-a");
        put(&mut rb, a(2), b"f", b"from-b");
        let mut m = ra.clone();
        m.merge(&rb);
        let mut m2 = rb.clone();
        m2.merge(&ra);
        assert_eq!(m, m2, "merge order must not change the winner");
        // both dots survive (concurrent puts), value is the max dot's
        assert_eq!(m.entries[0].1, vec![Dot::new(a(1), 2), Dot::new(a(2), 2)]);
        assert_eq!(m.get(b"f"), Some(&b"from-b"[..]));
    }

    fn arb_map(rng: &mut Rng, size: usize) -> OrMap {
        let mut m = OrMap::new();
        for _ in 0..(size % 10) {
            let actor = a(rng.below(3) as u32);
            let field = vec![b'f', rng.below(4) as u8];
            if rng.chance(0.3) {
                m.remove(&field);
            } else {
                let dot = m.mint(actor);
                m.put(field, vec![b'v', rng.below(200) as u8], dot);
            }
        }
        m
    }

    #[test]
    fn prop_merge_laws() {
        forall(
            &Config::default().cases(200),
            from_fn(|rng, size| {
                (arb_map(rng, size), arb_map(rng, size), arb_map(rng, size))
            }),
            |(x, y, z)| {
                let mut xy = x.clone();
                xy.merge(y);
                let mut yx = y.clone();
                yx.merge(x);
                let mut xx = x.clone();
                xx.merge(x);
                let mut xy_z = xy.clone();
                xy_z.merge(z);
                let mut yz = y.clone();
                yz.merge(z);
                let mut x_yz = x.clone();
                x_yz.merge(&yz);
                xy == yx && xx == *x && xy_z == x_yz
            },
        );
    }

    #[test]
    fn prop_delta_chain_replay_reproduces_full_state() {
        forall(
            &Config::default().cases(150),
            from_fn(|rng, size| {
                let ops: Vec<(bool, u8, u8, u32)> = (0..(size % 12))
                    .map(|_| {
                        (
                            rng.chance(0.3),
                            rng.below(4) as u8,
                            rng.below(200) as u8,
                            rng.below(3) as u32,
                        )
                    })
                    .collect();
                ops
            }),
            |ops| {
                let mut sender = OrMap::new();
                let mut follower = OrMap::new();
                for &(is_remove, f, v, actor) in ops {
                    let field = vec![b'f', f];
                    let delta = if is_remove {
                        sender.remove(&field).1
                    } else {
                        let dot = sender.mint(a(actor));
                        sender.put(field, vec![b'v', v], dot)
                    };
                    if !follower.apply_delta(&delta) {
                        return false;
                    }
                }
                follower == sender
            },
        );
    }

    #[test]
    fn state_and_delta_codecs_roundtrip() {
        let mut m = OrMap::new();
        let d1 = put(&mut m, a(0), b"x", b"one");
        let d2 = put(&mut m, a(1), b"y", b"");
        let (_, d3) = m.remove(b"x");
        let mut buf = Vec::new();
        m.encode(&mut buf);
        let mut pos = 0;
        assert_eq!(OrMap::decode(&buf, &mut pos).unwrap(), m);
        assert_eq!(pos, buf.len());
        for d in [d1, d2, d3] {
            let mut buf = Vec::new();
            d.encode(&mut buf);
            let mut pos = 0;
            assert_eq!(MapDelta::decode(&buf, &mut pos).unwrap(), d);
            assert_eq!(pos, buf.len());
        }
    }
}
