//! CRDT datatype layer on the causal kernel (ROADMAP item 4).
//!
//! The paper's per-server dot names each write's exact causal position —
//! which is precisely the identifier an *observed-remove* datatype needs
//! to distinguish "remove what I saw" from "remove what I never saw".
//! This module builds three datatypes on that identifier:
//!
//! * [`Orswot`] — an optimized observed-remove set (the Riak bigsets
//!   lineage): adds are tagged with dots minted from per-`(key, actor)`
//!   contiguous counters, removes keep **no tombstones** — the set's
//!   causal clock covers them;
//! * [`PnCounter`] — per-actor P/N pairs merged by pointwise max;
//! * [`OrMap`] — ORSWOT-keyed fields carrying register values,
//!   remove-wins on the field's *observed* dots, add-wins against
//!   unobserved concurrent puts.
//!
//! Each state is wrapped in [`TypedState`] with a one-byte kind tag and a
//! canonical self-delimiting codec (`encode_state`/`decode_state`) plus a
//! [`state_digest`](TypedState::state_digest), so a typed value rides the
//! existing register paths — `StorageBackend`, WAL, Merkle anti-entropy,
//! SHIP — as an opaque payload, completely unchanged.
//!
//! # Dot-minting discipline (the false-cover hazard)
//!
//! An ORSWOT's clock is a [`VersionVector`]: holding `(a, 5)` silently
//! claims *every* `a:n` with `n <= 5` was observed. Minting dots from any
//! gap-producing source (a global id counter, say) therefore lets a
//! replica's clock "cover" dots it never saw, and a later merge would
//! destroy the concurrent adds carrying them. The safe discipline, used
//! by every mutator here and enforced by the server's typed read-mutate-
//! write path, is: **a dot for actor `a` is `a`'s clock entry + 1, minted
//! from a state that contains all of `a`'s prior mints** (per-actor
//! contiguous counters — the same rule the paper's per-server DVV dots
//! follow). Restart/wipe state loss is handled one level up by bumping
//! the actor's *epoch* (a fresh actor id), never by reusing counters.
//!
//! # Delta replication
//!
//! Every mutator also returns a [`CrdtDelta`]: the added/removed dots
//! plus the causal context before and after the op — bytes proportional
//! to the *change*, not the collection. A delta applies to a receiver
//! whose clock dominates `ctx_before` (it has seen everything the sender
//! had); receivers that can't cover it fall back to full-state merge.
//! Replaying a sender's delta stream in causal order reproduces its full
//! state exactly; an out-of-order receiver is never corrupted — the
//! precondition fails closed. See `ARCHITECTURE.md` "CRDT layer".

pub mod counter;
pub mod mech;
pub mod ormap;
pub mod orswot;

pub use counter::{CounterDelta, PnCounter};
pub use mech::CrdtMech;
pub use ormap::{MapDelta, OrMap};
pub use orswot::{Orswot, SetDelta};

use std::fmt;

use crate::clocks::encoding::{get_varint, put_varint};
use crate::clocks::{Actor, VersionVector};
use crate::error::{Error, Result};

/// One write's exact causal position: `(actor, counter)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Dot {
    /// The minting actor (a server id + restart epoch, see module docs).
    pub actor: Actor,
    /// Per-`(key, actor)` contiguous counter, starting at 1.
    pub counter: u64,
}

impl Dot {
    /// Construct a dot.
    pub fn new(actor: Actor, counter: u64) -> Dot {
        Dot { actor, counter }
    }
}

impl fmt::Display for Dot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.actor.0, self.counter)
    }
}

/// The epoch-namespaced actor a node mints typed dots under: 1024 ids
/// per node, one per restart/wipe generation, all below
/// [`Actor::CLIENT_BASE`]. Shared by the threaded cluster's typed RMW
/// and the DES mirror — both worlds must agree on the actor space for
/// the mint discipline above to compose across transports.
pub fn mint_actor(node: usize, epoch: u64) -> Actor {
    debug_assert!(node < 1024, "typed actor space assumes < 1024 nodes");
    Actor::server((epoch.min(1023) as u32) * 1024 + node as u32)
}

/// Append a dot (varint actor + varint counter).
pub(crate) fn encode_dot(d: &Dot, buf: &mut Vec<u8>) {
    put_varint(buf, u64::from(d.actor.0));
    put_varint(buf, d.counter);
}

/// Decode a dot; counters of 0 are malformed (mints start at 1).
pub(crate) fn decode_dot(buf: &[u8], pos: &mut usize) -> Result<Dot> {
    let actor = get_varint(buf, pos)?;
    let actor = u32::try_from(actor)
        .map_err(|_| Error::Codec(format!("dot actor {actor} out of range")))?;
    let counter = get_varint(buf, pos)?;
    if counter == 0 {
        return Err(Error::Codec("dot counter 0 (mints start at 1)".into()));
    }
    Ok(Dot::new(Actor(actor), counter))
}

/// Append a sorted dot list with a count prefix.
pub(crate) fn encode_dots(dots: &[Dot], buf: &mut Vec<u8>) {
    put_varint(buf, dots.len() as u64);
    for d in dots {
        encode_dot(d, buf);
    }
}

/// Decode a dot list, requiring strictly ascending order (canonical
/// encodings digest stably) and capping the pre-allocation by the bytes
/// actually remaining (remote input must not pick allocation sizes).
pub(crate) fn decode_dots(buf: &[u8], pos: &mut usize) -> Result<Vec<Dot>> {
    let count = get_varint(buf, pos)?;
    let cap = (count as usize).min(buf.len().saturating_sub(*pos) / 2);
    let mut dots = Vec::with_capacity(cap);
    for _ in 0..count {
        let d = decode_dot(buf, pos)?;
        if let Some(&last) = dots.last() {
            if d <= last {
                return Err(Error::Codec(format!("dots out of order: {d} after {last}")));
            }
        }
        dots.push(d);
    }
    Ok(dots)
}

/// Datatype kind: the first byte of every encoded [`TypedState`], and
/// what a typed op checks before touching a key (see
/// [`Error::WrongType`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CrdtKind {
    /// Observed-remove set ([`Orswot`]).
    Set,
    /// Per-actor P/N counter ([`PnCounter`]).
    Counter,
    /// Observed-remove field map ([`OrMap`]).
    Map,
}

impl CrdtKind {
    /// Wire tag byte.
    pub fn tag(self) -> u8 {
        match self {
            CrdtKind::Set => 1,
            CrdtKind::Counter => 2,
            CrdtKind::Map => 3,
        }
    }

    /// Parse a wire tag byte.
    pub fn from_tag(tag: u8) -> Result<CrdtKind> {
        match tag {
            1 => Ok(CrdtKind::Set),
            2 => Ok(CrdtKind::Counter),
            3 => Ok(CrdtKind::Map),
            other => Err(Error::Codec(format!("unknown datatype tag {other}"))),
        }
    }

    /// Human name (error messages, STATS).
    pub fn name(self) -> &'static str {
        match self {
            CrdtKind::Set => "set",
            CrdtKind::Counter => "counter",
            CrdtKind::Map => "map",
        }
    }
}

impl fmt::Display for CrdtKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A typed CRDT value, stored as a register payload: the kind tag plus
/// the datatype state. This is what the server's typed ops decode from
/// sibling blobs, join, mutate, and write back — concurrent register
/// siblings collapse by CRDT merge at the next read.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TypedState {
    /// An observed-remove set.
    Set(Orswot),
    /// A P/N counter.
    Counter(PnCounter),
    /// An observed-remove field map.
    Map(OrMap),
}

impl TypedState {
    /// Fresh (empty) state of the given kind.
    pub fn fresh(kind: CrdtKind) -> TypedState {
        match kind {
            CrdtKind::Set => TypedState::Set(Orswot::new()),
            CrdtKind::Counter => TypedState::Counter(PnCounter::new()),
            CrdtKind::Map => TypedState::Map(OrMap::new()),
        }
    }

    /// This state's kind.
    pub fn kind(&self) -> CrdtKind {
        match self {
            TypedState::Set(_) => CrdtKind::Set,
            TypedState::Counter(_) => CrdtKind::Counter,
            TypedState::Map(_) => CrdtKind::Map,
        }
    }

    /// The state's causal clock (empty for counters, which carry no
    /// dots) — what a replication coverage check compares a delta's
    /// `ctx_before` against.
    pub fn clock(&self) -> VersionVector {
        match self {
            TypedState::Set(s) => s.clock().clone(),
            TypedState::Counter(_) => VersionVector::new(),
            TypedState::Map(m) => m.clock().clone(),
        }
    }

    /// Join another state of the same kind into this one. A kind
    /// mismatch (two clients raced different types onto one key) keeps
    /// `self` untouched and reports the conflict — it never panics.
    pub fn merge(&mut self, other: &TypedState) -> Result<()> {
        match (self, other) {
            (TypedState::Set(a), TypedState::Set(b)) => {
                a.merge(b);
                Ok(())
            }
            (TypedState::Counter(a), TypedState::Counter(b)) => {
                a.merge(b);
                Ok(())
            }
            (TypedState::Map(a), TypedState::Map(b)) => {
                a.merge(b);
                Ok(())
            }
            (me, other) => Err(Error::WrongType {
                expected: me.kind().name(),
                found: other.kind().name(),
            }),
        }
    }

    /// Append the canonical encoding: kind tag byte + state body.
    pub fn encode_state(&self, buf: &mut Vec<u8>) {
        buf.push(self.kind().tag());
        match self {
            TypedState::Set(s) => s.encode(buf),
            TypedState::Counter(c) => c.encode(buf),
            TypedState::Map(m) => m.encode(buf),
        }
    }

    /// Canonical encoding as a fresh buffer (the register payload).
    pub fn encode_to_vec(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(64);
        self.encode_state(&mut buf);
        buf
    }

    /// Decode one state starting at `pos`. Strict: truncation,
    /// out-of-order entries, uncovered dots, and trailing garbage after
    /// a [`decode`](TypedState::decode) all error — never panic.
    pub fn decode_state(buf: &[u8], pos: &mut usize) -> Result<TypedState> {
        let tag = *buf
            .get(*pos)
            .ok_or_else(|| Error::Codec("empty typed state".into()))?;
        *pos += 1;
        match CrdtKind::from_tag(tag)? {
            CrdtKind::Set => Ok(TypedState::Set(Orswot::decode(buf, pos)?)),
            CrdtKind::Counter => Ok(TypedState::Counter(PnCounter::decode(buf, pos)?)),
            CrdtKind::Map => Ok(TypedState::Map(OrMap::decode(buf, pos)?)),
        }
    }

    /// Decode a whole buffer as one state (rejects trailing bytes).
    pub fn decode(buf: &[u8]) -> Result<TypedState> {
        let mut pos = 0;
        let st = TypedState::decode_state(buf, &mut pos)?;
        crate::clocks::encoding::expect_end(buf, pos)?;
        Ok(st)
    }

    /// 64-bit digest of the state for the anti-entropy Merkle trees.
    /// The codec is canonical (entries sorted, clocks sorted), so
    /// converged replicas digest identically regardless of merge order.
    pub fn state_digest(&self) -> u64 {
        crate::kernel::digest::of_encoded(|buf| self.encode_state(buf))
    }
}

/// The change one typed mutation made: added/removed dots plus the
/// mutating replica's causal context before and after the op. Bytes are
/// proportional to the change, not the collection — what a delta-shaped
/// PUT fan-out or shipper batch carries instead of the whole state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CrdtDelta {
    /// An ORSWOT add or remove.
    Set(SetDelta),
    /// One counter row's new absolute value.
    Counter(CounterDelta),
    /// An OR-Map field put or remove.
    Map(MapDelta),
}

impl CrdtDelta {
    /// The datatype this delta mutates.
    pub fn kind(&self) -> CrdtKind {
        match self {
            CrdtDelta::Set(_) => CrdtKind::Set,
            CrdtDelta::Counter(_) => CrdtKind::Counter,
            CrdtDelta::Map(_) => CrdtKind::Map,
        }
    }

    /// The sender's causal context *before* the op: a receiver may apply
    /// the delta only when its own clock dominates this (it has observed
    /// everything the sender had — the full-state-fallback decision).
    /// Counter deltas carry no context (row max-merge is always safe).
    pub fn ctx_before(&self) -> Option<&VersionVector> {
        match self {
            CrdtDelta::Set(d) => Some(&d.ctx_before),
            CrdtDelta::Counter(_) => None,
            CrdtDelta::Map(d) => Some(&d.ctx_before),
        }
    }

    /// Append the wire encoding: kind tag + delta body.
    pub fn encode(&self, buf: &mut Vec<u8>) {
        buf.push(self.kind().tag());
        match self {
            CrdtDelta::Set(d) => d.encode(buf),
            CrdtDelta::Counter(d) => d.encode(buf),
            CrdtDelta::Map(d) => d.encode(buf),
        }
    }

    /// Wire size of this delta — the replication-bytes accounting the
    /// delta-vs-full-state evidence is built on.
    pub fn encoded_len(&self) -> usize {
        let mut buf = Vec::with_capacity(32);
        self.encode(&mut buf);
        buf.len()
    }

    /// Decode one delta (strict; rejects trailing bytes).
    pub fn decode(buf: &[u8]) -> Result<CrdtDelta> {
        let mut pos = 0;
        let tag = *buf
            .get(pos)
            .ok_or_else(|| Error::Codec("empty delta".into()))?;
        pos += 1;
        let d = match CrdtKind::from_tag(tag)? {
            CrdtKind::Set => CrdtDelta::Set(SetDelta::decode(buf, &mut pos)?),
            CrdtKind::Counter => CrdtDelta::Counter(CounterDelta::decode(buf, &mut pos)?),
            CrdtKind::Map => CrdtDelta::Map(MapDelta::decode(buf, &mut pos)?),
        };
        crate::clocks::encoding::expect_end(buf, pos)?;
        Ok(d)
    }

    /// Apply this delta to a receiver state. Returns `Ok(false)` — and
    /// leaves the state untouched — when the receiver's clock cannot
    /// cover `ctx_before` (the caller must fall back to full-state
    /// merge); `Err` on a kind mismatch.
    pub fn apply(&self, st: &mut TypedState) -> Result<bool> {
        match (self, st) {
            (CrdtDelta::Set(d), TypedState::Set(s)) => Ok(s.apply_delta(d)),
            (CrdtDelta::Counter(d), TypedState::Counter(c)) => {
                c.apply_delta(d);
                Ok(true)
            }
            (CrdtDelta::Map(d), TypedState::Map(m)) => Ok(m.apply_delta(d)),
            (d, st) => Err(Error::WrongType {
                expected: st.kind().name(),
                found: d.kind().name(),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a(i: u32) -> Actor {
        Actor::server(i)
    }

    #[test]
    fn kind_tags_roundtrip() {
        for k in [CrdtKind::Set, CrdtKind::Counter, CrdtKind::Map] {
            assert_eq!(CrdtKind::from_tag(k.tag()).unwrap(), k);
        }
        assert!(CrdtKind::from_tag(0).is_err());
        assert!(CrdtKind::from_tag(9).is_err());
    }

    #[test]
    fn typed_state_codec_roundtrips_every_kind() {
        let mut set = Orswot::new();
        set.add(b"x".to_vec(), Dot::new(a(0), 1));
        set.add(b"y".to_vec(), Dot::new(a(1), 1));
        let mut ctr = PnCounter::new();
        ctr.incr(a(0), 5);
        ctr.incr(a(1), -2);
        let mut map = OrMap::new();
        map.put(b"f".to_vec(), b"v".to_vec(), Dot::new(a(0), 1));
        for st in [
            TypedState::Set(set),
            TypedState::Counter(ctr),
            TypedState::Map(map),
            TypedState::fresh(CrdtKind::Set),
            TypedState::fresh(CrdtKind::Counter),
            TypedState::fresh(CrdtKind::Map),
        ] {
            let bytes = st.encode_to_vec();
            assert_eq!(TypedState::decode(&bytes).unwrap(), st, "{st:?}");
            // every strict prefix is rejected, never a panic
            for cut in 0..bytes.len() {
                assert!(TypedState::decode(&bytes[..cut]).is_err(), "prefix {cut}");
            }
            let mut long = bytes.clone();
            long.push(0);
            assert!(TypedState::decode(&long).is_err(), "trailing byte");
        }
    }

    #[test]
    fn merge_rejects_kind_mismatch_without_mutating() {
        let mut set = TypedState::fresh(CrdtKind::Set);
        if let TypedState::Set(s) = &mut set {
            s.add(b"x".to_vec(), Dot::new(a(0), 1));
        }
        let before = set.clone();
        let err = set.merge(&TypedState::fresh(CrdtKind::Counter)).unwrap_err();
        assert!(matches!(err, Error::WrongType { .. }));
        assert_eq!(set, before, "mismatched merge must not mutate");
    }

    #[test]
    fn delta_apply_rejects_kind_mismatch() {
        let mut set = Orswot::new();
        let delta = CrdtDelta::Set(set.add(b"x".to_vec(), Dot::new(a(0), 1)));
        let mut ctr = TypedState::fresh(CrdtKind::Counter);
        assert!(matches!(delta.apply(&mut ctr), Err(Error::WrongType { .. })));
    }

    #[test]
    fn digest_is_canonical_under_merge_order() {
        let (mut x, mut y) = (Orswot::new(), Orswot::new());
        x.add(b"p".to_vec(), Dot::new(a(0), 1));
        x.add(b"q".to_vec(), Dot::new(a(0), 2));
        y.add(b"q".to_vec(), Dot::new(a(1), 1));
        y.add(b"r".to_vec(), Dot::new(a(1), 2));
        let mut xy = x.clone();
        xy.merge(&y);
        let mut yx = y.clone();
        yx.merge(&x);
        let (xy, yx) = (TypedState::Set(xy), TypedState::Set(yx));
        assert_eq!(xy, yx);
        assert_eq!(xy.state_digest(), yx.state_digest());
        assert_ne!(
            xy.state_digest(),
            TypedState::fresh(CrdtKind::Set).state_digest()
        );
    }

    #[test]
    fn dot_codec_rejects_zero_counter_and_disorder() {
        let dots = vec![Dot::new(a(0), 1), Dot::new(a(0), 3), Dot::new(a(2), 1)];
        let mut buf = Vec::new();
        encode_dots(&dots, &mut buf);
        let mut pos = 0;
        assert_eq!(decode_dots(&buf, &mut pos).unwrap(), dots);
        assert_eq!(pos, buf.len());

        // zero counter
        let mut bad = Vec::new();
        encode_dots(&[Dot { actor: a(0), counter: 1 }], &mut bad);
        *bad.last_mut().unwrap() = 0;
        let mut pos = 0;
        assert!(decode_dots(&bad, &mut pos).is_err());

        // out of order
        let mut buf = Vec::new();
        put_varint(&mut buf, 2);
        encode_dot(&Dot::new(a(1), 1), &mut buf);
        encode_dot(&Dot::new(a(0), 1), &mut buf);
        let mut pos = 0;
        assert!(decode_dots(&buf, &mut pos).is_err());
    }
}
