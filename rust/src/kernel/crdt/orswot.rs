//! ORSWOT: an optimized observed-remove set without tombstones.
//!
//! The Riak bigsets lineage: the set keeps a causal clock (a
//! [`VersionVector`] over minting actors) plus, per present element, the
//! dots of the adds that are *live* — adds observed by no remove. A
//! remove simply deletes the element's observed dots; the clock still
//! covers them, which is exactly what lets a merge distinguish "removed"
//! (dot covered by my clock but absent from my entry) from "never seen"
//! (dot not covered at all). Concurrent adds therefore survive removes
//! that did not observe them — **add-wins** — and no per-element
//! tombstone is ever stored.

use crate::clocks::encoding::{encode_vv, get_bytes, get_varint, put_varint};
use crate::clocks::{Actor, VersionVector};
use crate::error::{Error, Result};

use super::{decode_dots, encode_dots, Dot};

/// An observed-remove set: causal clock + live add-dots per element.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Orswot {
    /// Every dot this replica has observed (per-actor contiguous).
    clock: VersionVector,
    /// Present elements with their live add-dots; sorted by element,
    /// dots sorted ascending, never empty.
    entries: Vec<(Vec<u8>, Vec<Dot>)>,
}

/// The change one set mutation made (see [`super::CrdtDelta`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SetDelta {
    /// The mutating replica's clock before the op.
    pub ctx_before: VersionVector,
    /// The clock after the op (covers the minted dot for adds).
    pub ctx_after: VersionVector,
    /// What changed.
    pub change: SetChange,
}

/// The concrete mutation inside a [`SetDelta`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SetChange {
    /// `elem` was added with `dot`, superseding the `replaced` dots the
    /// adder observed for it.
    Add {
        /// Element bytes.
        elem: Vec<u8>,
        /// The freshly minted dot tagging this add.
        dot: Dot,
        /// The adder's previously observed dots for `elem` (collapsed
        /// into the new dot — the "optimized" in ORSWOT).
        replaced: Vec<Dot>,
    },
    /// `elem`'s observed `dots` were removed (no tombstone kept).
    Remove {
        /// Element bytes.
        elem: Vec<u8>,
        /// The exact dots the remover observed and deleted.
        dots: Vec<Dot>,
    },
}

impl Orswot {
    /// The empty set.
    pub fn new() -> Orswot {
        Orswot::default()
    }

    /// The set's causal clock.
    pub fn clock(&self) -> &VersionVector {
        &self.clock
    }

    /// The next dot `actor` may mint from this state. Only sound when
    /// this state contains all of `actor`'s prior mints (see the module
    /// docs on the false-cover hazard).
    pub fn mint(&self, actor: Actor) -> Dot {
        Dot::new(actor, self.clock.get(actor) + 1)
    }

    /// Number of present elements.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no element is present.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Is `elem` present?
    pub fn contains(&self, elem: &[u8]) -> bool {
        self.find(elem).is_ok()
    }

    /// Present elements, ascending.
    pub fn members(&self) -> impl Iterator<Item = &[u8]> + '_ {
        self.entries.iter().map(|(e, _)| e.as_slice())
    }

    /// Total live dots across all elements (metadata accounting).
    pub fn dot_count(&self) -> usize {
        self.entries.iter().map(|(_, d)| d.len()).sum()
    }

    fn find(&self, elem: &[u8]) -> std::result::Result<usize, usize> {
        self.entries.binary_search_by(|(e, _)| e.as_slice().cmp(elem))
    }

    fn absorb(&mut self, dot: Dot) {
        if dot.counter > self.clock.get(dot.actor) {
            self.clock.set(dot.actor, dot.counter);
        }
    }

    /// Add `elem` tagged with `dot` (minted via [`mint`](Orswot::mint)
    /// by the op's coordinator). The element's previously observed dots
    /// collapse into the new one. Returns the op's delta.
    pub fn add(&mut self, elem: Vec<u8>, dot: Dot) -> SetDelta {
        let ctx_before = self.clock.clone();
        let replaced = match self.find(&elem) {
            Ok(i) => std::mem::replace(&mut self.entries[i].1, vec![dot]),
            Err(i) => {
                self.entries.insert(i, (elem.clone(), vec![dot]));
                Vec::new()
            }
        };
        self.absorb(dot);
        SetDelta {
            ctx_before,
            ctx_after: self.clock.clone(),
            change: SetChange::Add { elem, dot, replaced },
        }
    }

    /// Remove `elem`: delete its observed dots (no tombstone — the clock
    /// keeps covering them). Returns the removed dots plus the op's
    /// delta; removing an absent element removes nothing.
    pub fn remove(&mut self, elem: &[u8]) -> (Vec<Dot>, SetDelta) {
        let dots = match self.find(elem) {
            Ok(i) => self.entries.remove(i).1,
            Err(_) => Vec::new(),
        };
        let ctx = self.clock.clone();
        let delta = SetDelta {
            ctx_before: ctx.clone(),
            ctx_after: ctx,
            change: SetChange::Remove { elem: elem.to_vec(), dots: dots.clone() },
        };
        (dots, delta)
    }

    /// Join another replica's state: a dot survives if both sides hold
    /// it, or one side holds it and the other's clock has not observed
    /// it (an unobserved add beats any remove — add-wins). Elements with
    /// no surviving dots disappear.
    pub fn merge(&mut self, other: &Orswot) {
        let mut out: Vec<(Vec<u8>, Vec<Dot>)> =
            Vec::with_capacity(self.entries.len().max(other.entries.len()));
        let (mut i, mut j) = (0, 0);
        while i < self.entries.len() || j < other.entries.len() {
            let ord = match (self.entries.get(i), other.entries.get(j)) {
                (Some((a, _)), Some((b, _))) => a.cmp(b),
                (Some(_), None) => std::cmp::Ordering::Less,
                (None, Some(_)) => std::cmp::Ordering::Greater,
                (None, None) => unreachable!("loop condition"),
            };
            match ord {
                std::cmp::Ordering::Less => {
                    // only mine: dots the other side never observed live
                    let (elem, dots) = &self.entries[i];
                    let keep: Vec<Dot> = dots
                        .iter()
                        .filter(|d| d.counter > other.clock.get(d.actor))
                        .copied()
                        .collect();
                    if !keep.is_empty() {
                        out.push((elem.clone(), keep));
                    }
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    let (elem, dots) = &other.entries[j];
                    let keep: Vec<Dot> = dots
                        .iter()
                        .filter(|d| d.counter > self.clock.get(d.actor))
                        .copied()
                        .collect();
                    if !keep.is_empty() {
                        out.push((elem.clone(), keep));
                    }
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    let (elem, mine) = &self.entries[i];
                    let theirs = &other.entries[j].1;
                    let mut keep: Vec<Dot> = mine
                        .iter()
                        .filter(|d| {
                            theirs.contains(d) || d.counter > other.clock.get(d.actor)
                        })
                        .copied()
                        .collect();
                    for d in theirs {
                        if !keep.contains(d) && d.counter > self.clock.get(d.actor) {
                            keep.push(*d);
                        }
                    }
                    keep.sort_unstable();
                    if !keep.is_empty() {
                        out.push((elem.clone(), keep));
                    }
                    i += 1;
                    j += 1;
                }
            }
        }
        self.entries = out;
        self.clock.join_from(&other.clock);
    }

    /// Apply a sender's delta. Sound only when this replica's clock
    /// dominates the sender's `ctx_before` (it has observed everything
    /// the sender had — e.g. it is replaying the sender's delta stream
    /// in causal order); returns `false` untouched otherwise, and the
    /// caller falls back to full-state merge. Dots this replica holds
    /// concurrently with the delta survive it — add-wins is preserved.
    pub fn apply_delta(&mut self, d: &SetDelta) -> bool {
        if !d.ctx_before.dominated_by(&self.clock) {
            return false;
        }
        match &d.change {
            SetChange::Add { elem, dot, replaced } => {
                match self.find(elem) {
                    Ok(i) => {
                        let dots = &mut self.entries[i].1;
                        dots.retain(|x| !replaced.contains(x));
                        if let Err(at) = dots.binary_search(dot) {
                            dots.insert(at, *dot);
                        }
                    }
                    Err(i) => self.entries.insert(i, (elem.clone(), vec![*dot])),
                }
            }
            SetChange::Remove { elem, dots } => {
                if let Ok(i) = self.find(elem) {
                    self.entries[i].1.retain(|x| !dots.contains(x));
                    if self.entries[i].1.is_empty() {
                        self.entries.remove(i);
                    }
                }
            }
        }
        self.clock.join_from(&d.ctx_after);
        true
    }

    /// Append the canonical encoding: clock, then sorted
    /// `(elem, dots)` entries.
    pub fn encode(&self, buf: &mut Vec<u8>) {
        encode_vv(&self.clock, buf);
        put_varint(buf, self.entries.len() as u64);
        for (elem, dots) in &self.entries {
            put_varint(buf, elem.len() as u64);
            buf.extend_from_slice(elem);
            encode_dots(dots, buf);
        }
    }

    /// Decode one set, validating every reachable-state invariant:
    /// elements strictly ascending, dot lists non-empty and sorted,
    /// every dot covered by the clock. Errors (never panics) on
    /// violations — corrupt WAL or wire bytes must not build impossible
    /// states.
    pub fn decode(buf: &[u8], pos: &mut usize) -> Result<Orswot> {
        let clock = crate::clocks::encoding::decode_vv(buf, pos)?;
        let count = get_varint(buf, pos)?;
        let cap = (count as usize).min(buf.len().saturating_sub(*pos) / 4);
        let mut entries: Vec<(Vec<u8>, Vec<Dot>)> = Vec::with_capacity(cap);
        for _ in 0..count {
            let elen = get_varint(buf, pos)?;
            let elem = get_bytes(buf, pos, elen as usize)?.to_vec();
            if let Some((last, _)) = entries.last() {
                if *last >= elem {
                    return Err(Error::Codec("set elements out of order".into()));
                }
            }
            let dots = decode_dots(buf, pos)?;
            if dots.is_empty() {
                return Err(Error::Codec("set element with no dots".into()));
            }
            for d in &dots {
                if d.counter > clock.get(d.actor) {
                    return Err(Error::Codec(format!("dot {d} not covered by set clock")));
                }
            }
            entries.push((elem, dots));
        }
        Ok(Orswot { clock, entries })
    }
}

impl SetDelta {
    /// Append the wire encoding (see [`super::CrdtDelta::encode`] for
    /// the kind-tagged wrapper).
    pub fn encode(&self, buf: &mut Vec<u8>) {
        encode_vv(&self.ctx_before, buf);
        encode_vv(&self.ctx_after, buf);
        match &self.change {
            SetChange::Add { elem, dot, replaced } => {
                buf.push(0);
                put_varint(buf, elem.len() as u64);
                buf.extend_from_slice(elem);
                super::encode_dot(dot, buf);
                encode_dots(replaced, buf);
            }
            SetChange::Remove { elem, dots } => {
                buf.push(1);
                put_varint(buf, elem.len() as u64);
                buf.extend_from_slice(elem);
                encode_dots(dots, buf);
            }
        }
    }

    /// Decode one set delta.
    pub fn decode(buf: &[u8], pos: &mut usize) -> Result<SetDelta> {
        let ctx_before = crate::clocks::encoding::decode_vv(buf, pos)?;
        let ctx_after = crate::clocks::encoding::decode_vv(buf, pos)?;
        let tag = *buf
            .get(*pos)
            .ok_or_else(|| Error::Codec("set delta truncated".into()))?;
        *pos += 1;
        let change = match tag {
            0 => {
                let elen = get_varint(buf, pos)?;
                let elem = get_bytes(buf, pos, elen as usize)?.to_vec();
                let dot = super::decode_dot(buf, pos)?;
                let replaced = decode_dots(buf, pos)?;
                SetChange::Add { elem, dot, replaced }
            }
            1 => {
                let elen = get_varint(buf, pos)?;
                let elem = get_bytes(buf, pos, elen as usize)?.to_vec();
                let dots = decode_dots(buf, pos)?;
                SetChange::Remove { elem, dots }
            }
            other => return Err(Error::Codec(format!("bad set-change tag {other}"))),
        };
        Ok(SetDelta { ctx_before, ctx_after, change })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::{forall, from_fn, Config};
    use crate::testkit::Rng;

    fn a(i: u32) -> Actor {
        Actor::server(i)
    }

    fn add(s: &mut Orswot, actor: Actor, elem: &[u8]) -> SetDelta {
        let dot = s.mint(actor);
        s.add(elem.to_vec(), dot)
    }

    #[test]
    fn add_remove_basics() {
        let mut s = Orswot::new();
        add(&mut s, a(0), b"x");
        add(&mut s, a(0), b"y");
        assert!(s.contains(b"x") && s.contains(b"y"));
        assert_eq!(s.len(), 2);
        let (dots, _) = s.remove(b"x");
        assert_eq!(dots, vec![Dot::new(a(0), 1)]);
        assert!(!s.contains(b"x"));
        // no tombstone: the entry is gone, only the clock remembers
        assert_eq!(s.len(), 1);
        assert_eq!(s.clock().get(a(0)), 2);
        // removing an absent element removes nothing
        let (dots, _) = s.remove(b"zz");
        assert!(dots.is_empty());
    }

    #[test]
    fn readd_mints_a_fresh_dot() {
        let mut s = Orswot::new();
        add(&mut s, a(0), b"x");
        s.remove(b"x");
        let d = add(&mut s, a(0), b"x");
        assert!(s.contains(b"x"));
        match d.change {
            SetChange::Add { dot, ref replaced, .. } => {
                assert_eq!(dot, Dot::new(a(0), 2));
                assert!(replaced.is_empty(), "removed dots are not re-replaced");
            }
            _ => panic!("add delta expected"),
        }
    }

    #[test]
    fn concurrent_add_survives_remove() {
        // replica A and B both hold {x}; A removes x while B
        // concurrently re-adds it — add-wins: the merge keeps x
        let mut base = Orswot::new();
        add(&mut base, a(0), b"x");
        let (mut ra, mut rb) = (base.clone(), base);
        ra.remove(b"x");
        add(&mut rb, a(1), b"x");
        let mut m = ra.clone();
        m.merge(&rb);
        assert!(m.contains(b"x"), "unobserved add must survive the remove");
        // and the observed dot is gone: only B's fresh dot remains
        assert_eq!(m.entries[0].1, vec![Dot::new(a(1), 1)]);
        // merging the other way agrees
        let mut m2 = rb.clone();
        m2.merge(&ra);
        assert_eq!(m, m2);
    }

    #[test]
    fn observed_remove_wins_after_sync() {
        // B's add was *observed* by A before A removed: stay removed
        let mut ra = Orswot::new();
        let mut rb = Orswot::new();
        add(&mut rb, a(1), b"x");
        ra.merge(&rb);
        ra.remove(b"x");
        let mut m = rb.clone();
        m.merge(&ra);
        assert!(!m.contains(b"x"), "observed add must not resurrect");
    }

    fn arb_set(rng: &mut Rng, size: usize) -> Orswot {
        let mut s = Orswot::new();
        let actors = 1 + size / 30;
        for _ in 0..(size % 12) {
            let actor = a(rng.below(actors as u64) as u32);
            let elem = vec![b'e', rng.below(6) as u8];
            if rng.chance(0.3) {
                s.remove(&elem);
            } else {
                let dot = s.mint(actor);
                s.add(elem, dot);
            }
        }
        s
    }

    #[test]
    fn prop_merge_laws() {
        forall(
            &Config::default().cases(200),
            from_fn(|rng, size| {
                (arb_set(rng, size), arb_set(rng, size), arb_set(rng, size))
            }),
            |(x, y, z)| {
                let mut xy = x.clone();
                xy.merge(y);
                let mut yx = y.clone();
                yx.merge(x);
                let mut xx = x.clone();
                xx.merge(x);
                let mut xy_z = xy.clone();
                xy_z.merge(z);
                let mut yz = y.clone();
                yz.merge(z);
                let mut x_yz = x.clone();
                x_yz.merge(&yz);
                xy == yx && xx == *x && xy_z == x_yz
            },
        );
    }

    #[test]
    fn prop_delta_chain_replay_reproduces_full_state() {
        // a follower that applies the sender's delta stream in causal
        // order must end byte-identical to the sender's full state
        forall(
            &Config::default().cases(150),
            from_fn(|rng, size| {
                let ops: Vec<(bool, u8, u32)> = (0..(size % 15))
                    .map(|_| {
                        (rng.chance(0.3), rng.below(5) as u8, rng.below(2) as u32)
                    })
                    .collect();
                ops
            }),
            |ops| {
                let mut sender = Orswot::new();
                let mut follower = Orswot::new();
                for &(is_remove, e, actor) in ops {
                    let elem = vec![b'e', e];
                    let delta = if is_remove {
                        sender.remove(&elem).1
                    } else {
                        let dot = sender.mint(a(actor));
                        sender.add(elem, dot)
                    };
                    if !follower.apply_delta(&delta) {
                        return false;
                    }
                }
                follower == sender
            },
        );
    }

    #[test]
    fn delta_apply_fails_closed_on_a_gap() {
        let mut sender = Orswot::new();
        let mut follower = Orswot::new();
        let d1 = add(&mut sender, a(0), b"x");
        let d2 = add(&mut sender, a(0), b"y"); // depends on d1's clock
        assert!(!follower.apply_delta(&d2), "gap must refuse");
        assert!(follower.is_empty(), "refused delta must not mutate");
        assert!(follower.apply_delta(&d1));
        assert!(follower.apply_delta(&d2));
        assert_eq!(follower, sender);
    }

    #[test]
    fn delta_apply_preserves_concurrent_receiver_dots() {
        // receiver holds a concurrent add the sender never saw; the
        // sender's remove-delta lists only its own observed dots, so the
        // receiver's dot survives (add-wins), and a later full merge
        // converges both ways
        let mut base = Orswot::new();
        add(&mut base, a(0), b"x");
        let mut sender = base.clone();
        let mut receiver = base;
        add(&mut receiver, a(1), b"x"); // concurrent, unobserved by sender
        let (_, rm) = sender.remove(b"x");
        assert!(receiver.apply_delta(&rm));
        assert!(receiver.contains(b"x"), "concurrent add survives");
        let mut m = sender.clone();
        m.merge(&receiver);
        receiver.merge(&sender);
        assert_eq!(m, receiver);
    }

    #[test]
    fn state_codec_roundtrips_and_validates() {
        let mut s = Orswot::new();
        add(&mut s, a(0), b"alpha");
        add(&mut s, a(1), b"beta");
        s.remove(b"alpha");
        add(&mut s, a(2), b"");
        let mut buf = Vec::new();
        s.encode(&mut buf);
        let mut pos = 0;
        assert_eq!(Orswot::decode(&buf, &mut pos).unwrap(), s);
        assert_eq!(pos, buf.len());

        // an uncovered dot is a corrupt state, not a panic
        let mut buf = Vec::new();
        encode_vv(&VersionVector::new(), &mut buf); // empty clock
        put_varint(&mut buf, 1);
        put_varint(&mut buf, 1);
        buf.push(b'x');
        encode_dots(&[Dot::new(a(0), 1)], &mut buf);
        let mut pos = 0;
        assert!(Orswot::decode(&buf, &mut pos).is_err(), "uncovered dot");
    }

    #[test]
    fn delta_codec_roundtrips_and_rejects_truncation() {
        let mut s = Orswot::new();
        let deltas = [
            add(&mut s, a(0), b"x"),
            add(&mut s, a(1), b"x"),
            s.remove(b"x").1,
            s.remove(b"never-there").1,
        ];
        for d in deltas {
            let mut buf = Vec::new();
            d.encode(&mut buf);
            let mut pos = 0;
            assert_eq!(SetDelta::decode(&buf, &mut pos).unwrap(), d, "{d:?}");
            assert_eq!(pos, buf.len());
            for cut in 0..buf.len() {
                let mut pos = 0;
                // a prefix either errors or under-consumes; never panics
                if let Ok(short) = SetDelta::decode(&buf[..cut], &mut pos) {
                    assert_ne!((short, pos), (d.clone(), buf.len()));
                }
            }
        }
    }

    #[test]
    fn delta_bytes_stay_small_as_the_set_grows() {
        let mut s = Orswot::new();
        for i in 0..500u32 {
            let dot = s.mint(a(0));
            s.add(format!("element-{i:04}").into_bytes(), dot);
        }
        let full = {
            let mut buf = Vec::new();
            s.encode(&mut buf);
            buf.len()
        };
        let dot = s.mint(a(0));
        let delta = s.add(b"one-more".to_vec(), dot);
        let mut buf = Vec::new();
        delta.encode(&mut buf);
        assert!(
            buf.len() * 20 < full,
            "delta ({}) must be far smaller than the state ({full})",
            buf.len()
        );
    }
}
