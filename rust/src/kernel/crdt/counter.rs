//! PN-counter: per-actor increment/decrement pairs merged by max.
//!
//! Each actor owns one `(pos, neg)` row that only it ever advances (the
//! server's typed read-modify-write path guarantees single-writer rows
//! the same way it guarantees contiguous dot mints). Rows are monotone
//! non-decreasing, so pointwise max is a join and the counter's value is
//! `Σpos − Σneg`. A row is also its own delta: shipping the new absolute
//! `(actor, pos, neg)` is always safe to max-merge, no causal context
//! needed.

use crate::clocks::encoding::{get_varint, put_varint};
use crate::clocks::Actor;
use crate::error::{Error, Result};

/// A P/N counter: sorted per-actor `(pos, neg)` rows.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PnCounter {
    /// `(actor, increments, decrements)`, sorted by actor; never both 0.
    rows: Vec<(Actor, u64, u64)>,
}

/// One counter row's new absolute value — the whole delta of an
/// increment (see [`super::CrdtDelta`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CounterDelta {
    /// The incrementing actor.
    pub actor: Actor,
    /// The actor's total increments after the op.
    pub pos: u64,
    /// The actor's total decrements after the op.
    pub neg: u64,
}

impl PnCounter {
    /// The zero counter.
    pub fn new() -> PnCounter {
        PnCounter::default()
    }

    /// Current value: `Σpos − Σneg`, saturating at the `i64` bounds.
    pub fn value(&self) -> i64 {
        let mut acc: i64 = 0;
        for &(_, p, n) in &self.rows {
            acc = acc.saturating_add_unsigned(p).saturating_sub_unsigned(n);
        }
        acc
    }

    /// Number of actor rows (metadata accounting).
    pub fn rows(&self) -> usize {
        self.rows.len()
    }

    fn row_mut(&mut self, actor: Actor) -> &mut (Actor, u64, u64) {
        let i = match self.rows.binary_search_by_key(&actor, |&(a, _, _)| a) {
            Ok(i) => i,
            Err(i) => {
                self.rows.insert(i, (actor, 0, 0));
                i
            }
        };
        &mut self.rows[i]
    }

    /// Apply a (possibly negative) increment as `actor` and return the
    /// row delta. Only sound when this state holds all of `actor`'s
    /// prior increments (single-writer rows). A zero increment changes
    /// nothing but still reports the current row.
    pub fn incr(&mut self, actor: Actor, by: i64) -> CounterDelta {
        if by == 0 {
            let (p, n) = match self.rows.binary_search_by_key(&actor, |&(a, _, _)| a) {
                Ok(i) => (self.rows[i].1, self.rows[i].2),
                Err(_) => (0, 0),
            };
            return CounterDelta { actor, pos: p, neg: n };
        }
        let row = self.row_mut(actor);
        if by > 0 {
            row.1 = row.1.saturating_add(by as u64);
        } else {
            row.2 = row.2.saturating_add(by.unsigned_abs());
        }
        CounterDelta { actor, pos: row.1, neg: row.2 }
    }

    /// Join: pointwise max per row (rows are monotone, single-writer).
    pub fn merge(&mut self, other: &PnCounter) {
        for &(actor, p, n) in &other.rows {
            let row = self.row_mut(actor);
            row.1 = row.1.max(p);
            row.2 = row.2.max(n);
        }
    }

    /// Apply a row delta: max-merge the absolute row. Always safe — no
    /// causal precondition (see module docs).
    pub fn apply_delta(&mut self, d: &CounterDelta) {
        if d.pos == 0 && d.neg == 0 {
            return;
        }
        let row = self.row_mut(d.actor);
        row.1 = row.1.max(d.pos);
        row.2 = row.2.max(d.neg);
    }

    /// Append the canonical encoding: sorted rows.
    pub fn encode(&self, buf: &mut Vec<u8>) {
        put_varint(buf, self.rows.len() as u64);
        for &(a, p, n) in &self.rows {
            put_varint(buf, u64::from(a.0));
            put_varint(buf, p);
            put_varint(buf, n);
        }
    }

    /// Decode one counter: rows strictly ascending by actor and never
    /// all-zero (canonical states don't store empty rows).
    pub fn decode(buf: &[u8], pos: &mut usize) -> Result<PnCounter> {
        let count = get_varint(buf, pos)?;
        let cap = (count as usize).min(buf.len().saturating_sub(*pos) / 3);
        let mut rows: Vec<(Actor, u64, u64)> = Vec::with_capacity(cap);
        for _ in 0..count {
            let a = get_varint(buf, pos)?;
            let a = u32::try_from(a)
                .map_err(|_| Error::Codec(format!("counter actor {a} out of range")))?;
            let p = get_varint(buf, pos)?;
            let n = get_varint(buf, pos)?;
            if p == 0 && n == 0 {
                return Err(Error::Codec("empty counter row".into()));
            }
            if let Some(&(last, _, _)) = rows.last() {
                if last >= Actor(a) {
                    return Err(Error::Codec("counter rows out of order".into()));
                }
            }
            rows.push((Actor(a), p, n));
        }
        Ok(PnCounter { rows })
    }
}

impl CounterDelta {
    /// Append the wire encoding.
    pub fn encode(&self, buf: &mut Vec<u8>) {
        put_varint(buf, u64::from(self.actor.0));
        put_varint(buf, self.pos);
        put_varint(buf, self.neg);
    }

    /// Decode one row delta.
    pub fn decode(buf: &[u8], pos: &mut usize) -> Result<CounterDelta> {
        let a = get_varint(buf, pos)?;
        let a = u32::try_from(a)
            .map_err(|_| Error::Codec(format!("counter actor {a} out of range")))?;
        let p = get_varint(buf, pos)?;
        let n = get_varint(buf, pos)?;
        Ok(CounterDelta { actor: Actor(a), pos: p, neg: n })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::{forall, from_fn, Config};

    fn a(i: u32) -> Actor {
        Actor::server(i)
    }

    #[test]
    fn incr_decr_value() {
        let mut c = PnCounter::new();
        c.incr(a(0), 5);
        c.incr(a(1), 3);
        c.incr(a(0), -2);
        assert_eq!(c.value(), 6);
        assert_eq!(c.rows(), 2);
        let d = c.incr(a(0), 0);
        assert_eq!((d.pos, d.neg), (5, 2), "zero incr reports the row");
        assert_eq!(c.value(), 6);
    }

    #[test]
    fn concurrent_rows_sum_after_merge() {
        let (mut x, mut y) = (PnCounter::new(), PnCounter::new());
        x.incr(a(0), 10);
        y.incr(a(1), -4);
        let mut xy = x.clone();
        xy.merge(&y);
        let mut yx = y.clone();
        yx.merge(&x);
        assert_eq!(xy, yx);
        assert_eq!(xy.value(), 6);
    }

    #[test]
    fn merge_is_max_not_sum_per_row() {
        // the same actor's history merged twice must not double-count
        let mut x = PnCounter::new();
        x.incr(a(0), 7);
        let snapshot = x.clone();
        x.incr(a(0), 1);
        x.merge(&snapshot);
        assert_eq!(x.value(), 8, "stale row must not add");
    }

    #[test]
    fn row_delta_max_merges() {
        let mut x = PnCounter::new();
        let mut follower = PnCounter::new();
        let d1 = x.incr(a(0), 3);
        let d2 = x.incr(a(0), -1);
        // out-of-order and duplicated delivery both converge
        follower.apply_delta(&d2);
        follower.apply_delta(&d1);
        follower.apply_delta(&d2);
        assert_eq!(follower, x);
    }

    #[test]
    fn value_saturates() {
        let mut c = PnCounter::new();
        c.incr(a(0), i64::MAX);
        c.incr(a(0), i64::MAX);
        assert_eq!(c.value(), i64::MAX);
        let mut d = PnCounter::new();
        d.incr(a(0), i64::MIN);
        d.incr(a(0), i64::MIN);
        assert_eq!(d.value(), i64::MIN);
    }

    #[test]
    fn prop_merge_laws() {
        let arb = |rng: &mut crate::testkit::Rng, size: usize| {
            let mut c = PnCounter::new();
            for _ in 0..(size % 8) {
                let actor = a(rng.below(4) as u32);
                let by = rng.below(20) as i64 - 10;
                c.incr(actor, by);
            }
            c
        };
        forall(
            &Config::default().cases(200),
            from_fn(move |rng, size| (arb(rng, size), arb(rng, size), arb(rng, size))),
            |(x, y, z)| {
                let mut xy = x.clone();
                xy.merge(y);
                let mut yx = y.clone();
                yx.merge(x);
                let mut xx = x.clone();
                xx.merge(x);
                let mut xy_z = xy.clone();
                xy_z.merge(z);
                let mut yz = y.clone();
                yz.merge(z);
                let mut x_yz = x.clone();
                x_yz.merge(&yz);
                xy == yx && xx == *x && xy_z == x_yz
            },
        );
    }

    #[test]
    fn codec_roundtrips_and_rejects_corruption() {
        let mut c = PnCounter::new();
        c.incr(a(0), 500);
        c.incr(a(3), -1);
        let mut buf = Vec::new();
        c.encode(&mut buf);
        let mut pos = 0;
        assert_eq!(PnCounter::decode(&buf, &mut pos).unwrap(), c);
        assert_eq!(pos, buf.len());

        // truncation at every boundary errors, never panics
        for cut in 0..buf.len() {
            let mut pos = 0;
            if let Ok(short) = PnCounter::decode(&buf[..cut], &mut pos) {
                assert_ne!((short, pos), (c.clone(), buf.len()));
            }
        }

        // an all-zero row is non-canonical
        let mut bad = Vec::new();
        put_varint(&mut bad, 1);
        put_varint(&mut bad, 0);
        put_varint(&mut bad, 0);
        put_varint(&mut bad, 0);
        let mut pos = 0;
        assert!(PnCounter::decode(&bad, &mut pos).is_err());

        // out-of-order rows are non-canonical
        let mut bad = Vec::new();
        put_varint(&mut bad, 2);
        for row in [(1u64, 1u64, 0u64), (0, 1, 0)] {
            put_varint(&mut bad, row.0);
            put_varint(&mut bad, row.1);
            put_varint(&mut bad, row.2);
        }
        let mut pos = 0;
        assert!(PnCounter::decode(&bad, &mut pos).is_err());
    }
}
