//! [`CrdtMech`]: a [`Mechanism`] adapter that lets a [`TypedState`] ride
//! the storage stack *directly* — `KeyStore` stripe locks, every
//! [`StorageBackend`](crate::store::StorageBackend) (in-memory, sharded,
//! durable/WAL), Merkle anti-entropy — with zero changes to any of them.
//!
//! The server's typed ops don't need this adapter (they store encoded
//! [`TypedState`] blobs as register payloads over the existing DVV
//! mechanism); it exists so tests can demonstrate the "rides paths
//! unchanged" claim at the `KeyStore` level: install typed states with
//! `merge_key`, crash and recover a [`DurableBackend`]
//! (crate::store::DurableBackend), walk Merkle trees — all driven by the
//! CRDT join.
//!
//! State is `Option<TypedState>`: `None` is the absent key (the
//! `Default` the store conjures on first touch), and a merge into it
//! adopts the incoming state's kind. Merging mismatched kinds keeps the
//! left state (never panics) — the server-level typed ops reject the op
//! with [`Error::WrongType`](crate::Error::WrongType) before any state
//! is touched, so at this layer a mismatch only arises from hostile or
//! corrupt input and keep-left is the conservative join.

use crate::clocks::Actor;
use crate::kernel::mechanism::{DurableMechanism, Mechanism, Val, WriteMeta};

use super::TypedState;

/// Mechanism adapter exposing CRDT join as the replica-merge operation.
#[derive(Debug, Clone, Copy, Default)]
pub struct CrdtMech;

impl Mechanism for CrdtMech {
    const NAME: &'static str = "crdt";

    /// Typed ops carry their context inside the state; the register-path
    /// context is unused.
    type Context = ();

    type State = Option<TypedState>;

    fn read(&self, _st: &Self::State) -> (Vec<Val>, ()) {
        // Typed reads go through `TypedState` accessors, not sibling
        // lists; the register view of a CRDT key has no siblings.
        (Vec::new(), ())
    }

    fn write(
        &self,
        _st: &mut Self::State,
        _ctx: &(),
        _val: Val,
        _coord: Actor,
        _meta: &WriteMeta,
    ) {
        // Mutation happens through the datatype APIs (add/remove/incr/
        // put) under the server's typed read-mutate-write path; the
        // register write verb is deliberately inert here.
    }

    fn merge(&self, st: &mut Self::State, incoming: &Self::State) {
        match (st.as_mut(), incoming) {
            (None, Some(inc)) => *st = Some(inc.clone()),
            (Some(mine), Some(inc)) => {
                // keep-left on kind mismatch; see module docs
                let _ = mine.merge(inc);
            }
            (_, None) => {}
        }
    }

    fn values(&self, _st: &Self::State) -> Vec<Val> {
        Vec::new()
    }

    fn metadata_bytes(&self, st: &Self::State) -> usize {
        let mut buf = Vec::new();
        Self::encode_state(st, &mut buf);
        buf.len()
    }

    fn context_bytes(&self, _ctx: &()) -> usize {
        0
    }

    fn state_digest(st: &Self::State) -> u64 {
        crate::kernel::digest::of_encoded(|buf| Self::encode_state(st, buf))
    }
}

impl DurableMechanism for CrdtMech {
    /// A leading `0` byte is the absent state; otherwise the
    /// [`TypedState`] codec's kind tags (1..=3) follow.
    fn encode_state(st: &Self::State, buf: &mut Vec<u8>) {
        match st {
            None => buf.push(0),
            Some(st) => st.encode_state(buf),
        }
    }

    fn decode_state(buf: &[u8], pos: &mut usize) -> crate::Result<Self::State> {
        match buf.get(*pos) {
            Some(0) => {
                *pos += 1;
                Ok(None)
            }
            Some(_) => Ok(Some(TypedState::decode_state(buf, pos)?)),
            None => Err(crate::Error::Codec("empty crdt state".into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{CrdtKind, Dot, Orswot};
    use super::*;

    fn set_with(elems: &[&[u8]]) -> TypedState {
        let mut s = Orswot::new();
        for (i, e) in elems.iter().enumerate() {
            s.add(e.to_vec(), Dot::new(Actor::server(0), (i + 1) as u64));
        }
        TypedState::Set(s)
    }

    #[test]
    fn merge_adopts_incoming_kind_on_absent_state() {
        let m = CrdtMech;
        let mut st: Option<TypedState> = None;
        m.merge(&mut st, &Some(set_with(&[b"x"])));
        assert_eq!(st.as_ref().map(TypedState::kind), Some(CrdtKind::Set));
    }

    #[test]
    fn merge_keeps_left_on_kind_mismatch() {
        let m = CrdtMech;
        let mut st = Some(set_with(&[b"x"]));
        let before = st.clone();
        m.merge(&mut st, &Some(TypedState::fresh(CrdtKind::Counter)));
        assert_eq!(st, before);
    }

    #[test]
    fn codec_roundtrips_absent_and_present() {
        for st in [None, Some(set_with(&[b"x", b"y"]))] {
            let mut buf = Vec::new();
            CrdtMech::encode_state(&st, &mut buf);
            let mut pos = 0;
            assert_eq!(CrdtMech::decode_state(&buf, &mut pos).unwrap(), st);
            assert_eq!(pos, buf.len());
        }
        let mut pos = 0;
        assert!(CrdtMech::decode_state(&[], &mut pos).is_err());
    }

    #[test]
    fn digest_distinguishes_states_and_is_merge_stable() {
        let m = CrdtMech;
        let a = Some(set_with(&[b"x"]));
        let b = Some(set_with(&[b"x", b"y"]));
        assert_ne!(CrdtMech::state_digest(&a), CrdtMech::state_digest(&b));
        assert_ne!(CrdtMech::state_digest(&None), CrdtMech::state_digest(&a));
        let mut ab = a.clone();
        m.merge(&mut ab, &b);
        let mut ba = b.clone();
        m.merge(&mut ba, &a);
        assert_eq!(CrdtMech::state_digest(&ab), CrdtMech::state_digest(&ba));
    }
}
