//! The mechanism abstraction: what a replica node must implement so the
//! store can run with *any* of the paper's causality-tracking approaches.
//!
//! This is the repo-level analogue of the paper's observation that only
//! ~100 lines of Riak had to change to adopt DVVs: the coordinator,
//! simulator, figures, benches and examples are all written against
//! [`Mechanism`]; each of §3's baselines and §5's contribution is one impl
//! in [`super::mechs`].

use std::fmt;

use crate::clocks::Actor;

/// A stored value. The simulator tracks identity (`id`, globally unique
/// per write) and payload size (`len`); the TCP server keeps real bytes in
/// a side table keyed by `id`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Val {
    /// Globally unique write identity (doubles as the oracle's event id).
    pub id: u64,
    /// Payload size in bytes (for wire accounting).
    pub len: u32,
}

impl Val {
    /// Construct a value.
    pub fn new(id: u64, len: u32) -> Val {
        Val { id, len }
    }
}

impl fmt::Display for Val {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "v{}", self.id)
    }
}

/// Per-write metadata a coordinator sees (who wrote, when, with what
/// client-side counter).
#[derive(Debug, Clone)]
pub struct WriteMeta {
    /// The writing client.
    pub client: Actor,
    /// The client's (possibly skewed) wall clock, µs — used by the LWW
    /// baseline (§3.1).
    pub physical_us: u64,
    /// The client's own per-key write counter when the client is
    /// *stateful*; `None` models the stateless clients of §3.3, forcing
    /// the server to infer the counter (Figure 4's anomaly).
    pub client_seq: Option<u64>,
}

impl WriteMeta {
    /// Metadata for an anonymous, clockless write (unit tests, figures).
    pub fn basic(client: Actor) -> WriteMeta {
        WriteMeta { client, physical_us: 0, client_seq: None }
    }
}

/// A causality-tracking mechanism: per-key replica state + the paper's
/// kernel operations over it.
pub trait Mechanism: Clone + Send + Sync + 'static {
    /// Name used in configs and CLI (`--mechanism`).
    const NAME: &'static str;

    /// The opaque causal context returned by GET and supplied to PUT.
    type Context: Clone + fmt::Debug + Default + PartialEq;

    /// Per-key state kept by a replica node. `Sync` because storage
    /// backends hand out shared references under their stripe locks
    /// (see [`crate::store::StorageBackend`]).
    type State: Clone + fmt::Debug + Default + Send + Sync;

    /// GET: current concurrent values plus the context describing them.
    fn read(&self, st: &Self::State) -> (Vec<Val>, Self::Context);

    /// PUT at coordinator `coord`: the paper's `update` followed by a
    /// local `sync` (§4.1 put steps 2–3).
    fn write(
        &self,
        st: &mut Self::State,
        ctx: &Self::Context,
        val: Val,
        coord: Actor,
        meta: &WriteMeta,
    );

    /// Replica-to-replica merge: replication fan-out (§4.1 put step 4),
    /// read repair, and anti-entropy all funnel here.
    fn merge(&self, st: &mut Self::State, incoming: &Self::State);

    /// Current live values (siblings).
    fn values(&self, st: &Self::State) -> Vec<Val>;

    /// Number of live siblings.
    fn sibling_count(&self, st: &Self::State) -> usize {
        self.values(st).len()
    }

    /// Causality metadata footprint of the state, in encoded bytes (E7).
    fn metadata_bytes(&self, st: &Self::State) -> usize;

    /// Wire size of a client context (E7's client-side column).
    fn context_bytes(&self, ctx: &Self::Context) -> usize;

    /// 64-bit digest of the state, fed to the anti-entropy Merkle trees
    /// ([`crate::antientropy::merkle`]).
    ///
    /// Contract:
    ///
    /// * **converged replicas agree**: if two states would be reported
    ///   identical by the sync layer (same sibling multiset, in any
    ///   order), their digests are equal — otherwise a quiesced pair
    ///   would diff forever;
    /// * **divergent states collide only by accident**: distinct
    ///   reachable states produce distinct digests except with ~2^-64
    ///   probability — the Merkle walk prunes a subtree when digests
    ///   match, so a collision silently skips real divergence (the same
    ///   probabilistic bet the Riak hashtree lineage makes);
    /// * the default state digests to the same value as an absent key is
    ///   treated by [`merge`](Mechanism::merge) — in-tree mechanisms
    ///   derive the digest from their `DurableMechanism` codec, so this
    ///   follows from `encode(default)` being stable.
    ///
    /// Associated (no `&self`) for the same reason as the codec: storage
    /// backends maintain trees without holding a mechanism instance.
    fn state_digest(st: &Self::State) -> u64;
}

/// A [`Mechanism`] whose per-key state has a byte codec — what the
/// write-ahead-logged storage backend ([`crate::store::DurableBackend`])
/// needs to persist states and replay them on recovery.
///
/// The codec contract mirrors [`crate::clocks::encoding`]: encodings are
/// self-delimiting (decode knows where the state ends), and decoding
/// untrusted bytes must error — never panic — on truncation or
/// out-of-range fields, because recovery feeds it whatever survived a
/// crash. `decode(encode(st)) == st` for every reachable state, and the
/// functions are associated (no `&self`): mechanisms are stateless unit
/// structs, so a backend can run the codec without holding an instance.
///
/// Every in-tree mechanism implements this (each in its own module, next
/// to its `Mechanism` impl), so any of the paper's §3 baselines and the
/// §5 contribution can run durably.
pub trait DurableMechanism: Mechanism {
    /// Append the state's encoding to `buf`.
    fn encode_state(st: &Self::State, buf: &mut Vec<u8>);

    /// Decode one state starting at `pos`, advancing it past the
    /// encoding. Errors on any malformed input.
    fn decode_state(buf: &[u8], pos: &mut usize) -> crate::Result<Self::State>;
}

/// Append a [`Val`]'s encoding (varint id + varint len) — the shared
/// piece of every [`DurableMechanism`] state codec.
pub fn encode_val(val: &Val, buf: &mut Vec<u8>) {
    crate::clocks::encoding::put_varint(buf, val.id);
    crate::clocks::encoding::put_varint(buf, u64::from(val.len));
}

/// Decode a [`Val`] (see [`encode_val`]).
pub fn decode_val(buf: &[u8], pos: &mut usize) -> crate::Result<Val> {
    let id = crate::clocks::encoding::get_varint(buf, pos)?;
    let len = crate::clocks::encoding::get_varint(buf, pos)?;
    let len = u32::try_from(len)
        .map_err(|_| crate::Error::Codec(format!("val len {len} out of range")))?;
    Ok(Val::new(id, len))
}

/// Runtime-selectable mechanism kind (string names in config/CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MechKind {
    /// Explicit causal histories (ground truth; §3).
    History,
    /// Physical-clock last-writer-wins (§3.1).
    Lww,
    /// Lamport-clock total order (§3.1).
    Lamport,
    /// Version vectors with per-server entries (§3.2).
    ServerVv,
    /// Version vectors with per-client entries (§3.3).
    ClientVv,
    /// Dotted version vectors (§5).
    Dvv,
    /// Compact sibling-set DVVs (extension).
    DvvSet,
}

impl MechKind {
    /// All kinds, in the paper's presentation order.
    pub const ALL: [MechKind; 7] = [
        MechKind::History,
        MechKind::Lww,
        MechKind::Lamport,
        MechKind::ServerVv,
        MechKind::ClientVv,
        MechKind::Dvv,
        MechKind::DvvSet,
    ];

    /// Canonical config name.
    pub fn name(self) -> &'static str {
        match self {
            MechKind::History => "history",
            MechKind::Lww => "lww",
            MechKind::Lamport => "lamport",
            MechKind::ServerVv => "vv",
            MechKind::ClientVv => "clientvv",
            MechKind::Dvv => "dvv",
            MechKind::DvvSet => "dvvset",
        }
    }

    /// Parse a config/CLI name.
    pub fn parse(s: &str) -> crate::Result<MechKind> {
        match s {
            "history" | "ch" => Ok(MechKind::History),
            "lww" | "realtime" => Ok(MechKind::Lww),
            "lamport" => Ok(MechKind::Lamport),
            "vv" | "servervv" => Ok(MechKind::ServerVv),
            "clientvv" | "client-vv" => Ok(MechKind::ClientVv),
            "dvv" => Ok(MechKind::Dvv),
            "dvvset" => Ok(MechKind::DvvSet),
            other => Err(crate::Error::Config(format!(
                "unknown mechanism {other:?}; expected one of {:?}",
                crate::clocks::MECHANISM_NAMES
            ))),
        }
    }

    /// Does this mechanism ever lose concurrent updates? (Paper's claim
    /// table; asserted by E6.)
    pub fn is_lossless(self) -> bool {
        matches!(
            self,
            MechKind::History | MechKind::ClientVv | MechKind::Dvv | MechKind::DvvSet
        )
    }
}

impl fmt::Display for MechKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_roundtrip() {
        for k in MechKind::ALL {
            assert_eq!(MechKind::parse(k.name()).unwrap(), k);
        }
        assert!(MechKind::parse("bogus").is_err());
    }

    #[test]
    fn lossless_classification_matches_paper() {
        assert!(MechKind::Dvv.is_lossless());
        assert!(MechKind::ClientVv.is_lossless());
        assert!(!MechKind::ServerVv.is_lossless());
        assert!(!MechKind::Lww.is_lossless());
        assert!(!MechKind::Lamport.is_lossless());
    }

    #[test]
    fn val_display() {
        assert_eq!(Val::new(7, 100).to_string(), "v7");
    }
}
