//! The paper's §4 "kernel for eventual consistency": the `sync`/`update`
//! operations every key-value-store mechanism is built from, the
//! [`mechanism::Mechanism`] abstraction, and the concrete mechanism
//! implementations in [`mechs`].

pub mod conditions;
pub mod crdt;
pub mod digest;
pub mod mechanism;
pub mod mechs;
pub mod ops;

pub use mechanism::{decode_val, encode_val, DurableMechanism, MechKind, Mechanism, Val, WriteMeta};
pub use mechs::{dispatch, MechVisitor};
pub use ops::{insert_version, pairwise_concurrent, sync_into, sync_sets};
