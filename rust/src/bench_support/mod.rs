//! In-tree micro-benchmark harness (offline `criterion` substitute).
//!
//! Used by every binary in `benches/` (declared with `harness = false`).
//! Provides warmup, timed sampling, robust statistics (mean/p50/p95/p99),
//! throughput accounting, and machine-readable output:
//!
//! * human: aligned markdown tables on stdout;
//! * CSV: `--csv <path>` appends `suite,bench,param,mean_ns,p50_ns,...`.
//!
//! CLI contract shared by all bench binaries:
//! `bench_bin [--filter SUBSTR] [--quick] [--csv PATH]`.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// Re-export so benches can `use dvvstore::bench_support::black_box`.
pub use std::hint::black_box as bb;

/// One measured benchmark's statistics (nanoseconds).
#[derive(Debug, Clone)]
pub struct Stats {
    /// Benchmark label.
    pub name: String,
    /// Parameter column (e.g. "clients=128").
    pub param: String,
    /// Number of measured samples.
    pub samples: usize,
    /// Iterations per sample.
    pub iters_per_sample: u64,
    /// Mean ns/iter.
    pub mean_ns: f64,
    /// Median ns/iter.
    pub p50_ns: f64,
    /// 95th percentile ns/iter.
    pub p95_ns: f64,
    /// 99th percentile ns/iter.
    pub p99_ns: f64,
    /// Minimum ns/iter.
    pub min_ns: f64,
    /// Std-dev of per-sample means.
    pub std_ns: f64,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: f64,
}

impl Stats {
    /// Items per second implied by the mean (0 when `items_per_iter` unset).
    pub fn throughput(&self) -> f64 {
        if self.items_per_iter > 0.0 && self.mean_ns > 0.0 {
            self.items_per_iter * 1e9 / self.mean_ns
        } else {
            0.0
        }
    }
}

/// Harness options (parsed from CLI args).
#[derive(Debug, Clone)]
pub struct Options {
    /// Only run benches whose `name/param` contains this substring.
    pub filter: Option<String>,
    /// Quick mode: fewer samples + shorter warmup (CI-friendly).
    pub quick: bool,
    /// Append CSV rows here when set.
    pub csv: Option<String>,
}

impl Options {
    /// Parse the shared bench CLI contract from `std::env::args`.
    pub fn from_args() -> Options {
        let args: Vec<String> = std::env::args().skip(1).collect();
        let mut opts = Options { filter: None, quick: false, csv: None };
        let mut i = 0;
        while i < args.len() {
            match args[i].as_str() {
                "--filter" => {
                    i += 1;
                    opts.filter = args.get(i).cloned();
                }
                "--quick" => opts.quick = true,
                "--csv" => {
                    i += 1;
                    opts.csv = args.get(i).cloned();
                }
                // `cargo bench` passes --bench; ignore unknown flags so the
                // harness stays forward-compatible.
                _ => {}
            }
            i += 1;
        }
        if std::env::var("DVV_BENCH_QUICK").is_ok() {
            opts.quick = true;
        }
        opts
    }
}

/// A benchmark suite: collects results, prints one table at the end.
pub struct Suite {
    name: String,
    opts: Options,
    results: Vec<Stats>,
    warmup: Duration,
    sample_time: Duration,
    samples: usize,
}

impl Suite {
    /// Create a suite with the given name and parsed options.
    pub fn new(name: &str, opts: Options) -> Suite {
        let (warmup, sample_time, samples) = if opts.quick {
            (Duration::from_millis(20), Duration::from_millis(30), 10)
        } else {
            (Duration::from_millis(200), Duration::from_millis(100), 30)
        };
        Suite {
            name: name.to_string(),
            opts,
            results: Vec::new(),
            warmup,
            sample_time,
            samples,
        }
    }

    fn enabled(&self, name: &str, param: &str) -> bool {
        match &self.opts.filter {
            Some(f) => format!("{name}/{param}").contains(f.as_str()),
            None => true,
        }
    }

    /// Measure `f` (one iteration per call) under `name`/`param`.
    pub fn bench<F: FnMut()>(&mut self, name: &str, param: &str, f: F) {
        self.bench_with_items(name, param, 1.0, f)
    }

    /// Measure `f`, reporting `items` units of work per iteration.
    pub fn bench_with_items<F: FnMut()>(
        &mut self,
        name: &str,
        param: &str,
        items: f64,
        mut f: F,
    ) {
        if !self.enabled(name, param) {
            return;
        }
        // Warmup + calibration: find iters that fill ~sample_time.
        let warm_start = Instant::now();
        let mut warm_iters: u64 = 0;
        while warm_start.elapsed() < self.warmup {
            f();
            warm_iters += 1;
        }
        let per_iter = self.warmup.as_nanos() as f64 / warm_iters.max(1) as f64;
        let iters = ((self.sample_time.as_nanos() as f64 / per_iter).ceil() as u64).max(1);

        let mut sample_means = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed().as_nanos() as f64;
            sample_means.push(dt / iters as f64);
        }
        sample_means.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sample_means.len();
        let mean = sample_means.iter().sum::<f64>() / n as f64;
        let var = sample_means.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let pct = |p: f64| sample_means[(((n - 1) as f64) * p) as usize];
        let stats = Stats {
            name: name.to_string(),
            param: param.to_string(),
            samples: n,
            iters_per_sample: iters,
            mean_ns: mean,
            p50_ns: pct(0.50),
            p95_ns: pct(0.95),
            p99_ns: pct(0.99),
            min_ns: sample_means[0],
            std_ns: var.sqrt(),
            items_per_iter: items,
        };
        eprintln!(
            "  {:<38} {:<20} mean {:>12}  p50 {:>12}",
            stats.name,
            stats.param,
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p50_ns)
        );
        self.results.push(stats);
    }

    /// Access collected results (for custom reporting in bench mains).
    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Print the markdown table and write CSV if requested.
    pub fn finish(self) {
        println!("\n## {}\n", self.name);
        println!(
            "| bench | param | mean | p50 | p95 | p99 | min | throughput |"
        );
        println!("|---|---|---|---|---|---|---|---|");
        for s in &self.results {
            let tp = if s.throughput() > 0.0 {
                format!("{}/s", fmt_count(s.throughput()))
            } else {
                "-".to_string()
            };
            println!(
                "| {} | {} | {} | {} | {} | {} | {} | {} |",
                s.name,
                s.param,
                fmt_ns(s.mean_ns),
                fmt_ns(s.p50_ns),
                fmt_ns(s.p95_ns),
                fmt_ns(s.p99_ns),
                fmt_ns(s.min_ns),
                tp
            );
        }
        if let Some(path) = &self.opts.csv {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .expect("open csv");
            for s in &self.results {
                writeln!(
                    f,
                    "{},{},{},{:.1},{:.1},{:.1},{:.1},{:.1},{:.3}",
                    self.name,
                    s.name,
                    s.param,
                    s.mean_ns,
                    s.p50_ns,
                    s.p95_ns,
                    s.p99_ns,
                    s.min_ns,
                    s.throughput()
                )
                .expect("write csv");
            }
        }
    }
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.3}s", ns / 1_000_000_000.0)
    }
}

/// Format a count with k/M suffixes.
pub fn fmt_count(x: f64) -> String {
    if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.1}k", x / 1e3)
    } else {
        format!("{x:.0}")
    }
}

/// Run a closure and return (result, elapsed) — one-shot measurements for
/// end-to-end drivers (examples/, EXPERIMENTS.md numbers).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = black_box(f());
    (out, t0.elapsed())
}

/// Run `f(thread_index)` on `threads` OS threads at once and return the
/// wall-clock time from release to last completion — the multi-threaded
/// throughput measurement used by `benches/sharded_store.rs`. A barrier
/// lines every thread up before the clock starts so slow spawns don't
/// count.
pub fn time_threads<F>(threads: usize, f: F) -> Duration
where
    F: Fn(usize) + Sync,
{
    use std::sync::Barrier;
    let barrier = Barrier::new(threads + 1);
    let f = &f;
    let barrier = &barrier;
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                scope.spawn(move || {
                    barrier.wait();
                    f(t);
                })
            })
            .collect();
        barrier.wait();
        let t0 = Instant::now();
        for h in handles {
            h.join().expect("bench thread panicked");
        }
        t0.elapsed()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_suite(name: &str) -> Suite {
        Suite::new(
            name,
            Options { filter: None, quick: true, csv: None },
        )
    }

    #[test]
    fn bench_produces_sane_stats() {
        let mut s = quick_suite("t");
        let mut acc = 0u64;
        s.bench("noop", "x", || {
            acc = acc.wrapping_add(1);
            bb(acc);
        });
        let st = &s.results()[0];
        assert!(st.mean_ns > 0.0);
        assert!(st.min_ns <= st.p50_ns && st.p50_ns <= st.p99_ns);
        assert_eq!(st.samples, 10);
    }

    #[test]
    fn filter_skips_nonmatching() {
        let mut s = Suite::new(
            "t",
            Options { filter: Some("only".into()), quick: true, csv: None },
        );
        s.bench("other", "x", || {});
        assert!(s.results().is_empty());
        s.bench("only_this", "x", || {});
        assert_eq!(s.results().len(), 1);
    }

    #[test]
    fn throughput_computed_from_items() {
        let mut s = quick_suite("t");
        s.bench_with_items("b", "p", 100.0, || {
            bb(12u64);
        });
        assert!(s.results()[0].throughput() > 0.0);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ns(12.3), "12.3ns");
        assert_eq!(fmt_ns(1_500.0), "1.50µs");
        assert_eq!(fmt_ns(2_000_000.0), "2.00ms");
        assert_eq!(fmt_count(1_234_567.0), "1.23M");
        assert_eq!(fmt_count(1_500.0), "1.5k");
        assert_eq!(fmt_count(42.0), "42");
    }

    #[test]
    fn time_once_measures() {
        let ((), dt) = time_once(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(dt >= Duration::from_millis(5));
    }

    #[test]
    fn time_threads_runs_every_thread() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let hits = AtomicUsize::new(0);
        let dt = time_threads(4, |_t| {
            hits.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(2));
        });
        assert_eq!(hits.load(Ordering::Relaxed), 4);
        assert!(dt >= Duration::from_millis(2));
    }
}
