//! Deterministic PRNG: xoshiro256++ seeded via splitmix64.
//!
//! Every simulated experiment in this crate is reproducible bit-for-bit
//! from `(seed, config)`; this is the single source of randomness. The
//! generator is the public-domain xoshiro256++ of Blackman & Vigna, which
//! passes BigCrush and is more than adequate for workload generation and
//! network-latency sampling (no cryptographic use).

/// Deterministic random number generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for per-node streams).
    pub fn fork(&mut self) -> Self {
        Rng::new(self.next_u64())
    }

    /// Next raw 64-bit output (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform integer in `[0, bound)`; `bound` must be > 0.
    /// Uses Lemire's multiply-shift rejection method (unbiased).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` (53-bit resolution).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose from empty slice");
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Exponentially distributed sample with the given mean (latency model).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller (clock-skew model).
    pub fn normal(&mut self, mean: f64, std_dev: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std_dev * z
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit: {seen:?}");
    }

    #[test]
    fn range_inclusive_endpoints() {
        let mut r = Rng::new(9);
        let (mut lo_hit, mut hi_hit) = (false, false);
        for _ in 0..2000 {
            match r.range(3, 5) {
                3 => lo_hit = true,
                5 => hi_hit = true,
                4 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(lo_hit && hi_hit);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn chance_mean_near_p() {
        let mut r = Rng::new(13);
        let hits = (0..10_000).filter(|_| r.chance(0.3)).count();
        let mean = hits as f64 / 10_000.0;
        assert!((mean - 0.3).abs() < 0.03, "mean={mean}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle changed order");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(19);
        let n = 20_000;
        let sum: f64 = (0..n).map(|_| r.exponential(5.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 5.0).abs() < 0.25, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(23);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(10.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.1, "mean={mean}");
        assert!((var - 4.0).abs() < 0.3, "var={var}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(31);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..32).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
