//! Minimal property-based testing harness (in-tree `proptest` substitute).
//!
//! Capabilities:
//!
//! * **Sized generation** — generators receive a `size` hint that grows
//!   over the run, so early cases are small and late cases stress larger
//!   structures.
//! * **Seed reporting + replay** — a failing case prints its seed; set
//!   `DVV_PROP_SEED` to replay exactly that case.
//! * **Greedy shrinking** — on failure the harness asks the generator for
//!   simpler variants of the failing value (via [`Gen::shrink`]) and
//!   recurses while the property keeps failing.
//!
//! ```no_run
//! // (no_run: doctest binaries don't get the xla rpath link flags)
//! use dvvstore::testkit::prop::{forall, Config, ints, vecs};
//!
//! forall(&Config::default().cases(64), vecs(ints(0, 100), 0, 16), |v| {
//!     let mut s = v.clone();
//!     s.sort_unstable();
//!     s.windows(2).all(|w| w[0] <= w[1])
//! });
//! ```

use super::rng::Rng;

/// A sized, shrinkable value generator.
pub trait Gen {
    /// Generated value type.
    type Value: Clone + std::fmt::Debug;

    /// Produce a value; `size` in `[0, 100]` scales structure sizes.
    fn generate(&self, rng: &mut Rng, size: usize) -> Self::Value;

    /// Candidate simplifications of a failing value, simplest first.
    /// Default: no shrinking.
    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; each case derives its own stream from it.
    pub seed: u64,
    /// Cap on shrinking iterations.
    pub max_shrinks: usize,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("DVV_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x5EED_CAFE);
        Config { cases: 100, seed, max_shrinks: 400 }
    }
}

impl Config {
    /// Override the number of cases.
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Override the base seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run `prop` against `cases` generated values; panic with a minimal
/// counterexample (plus replay seed) on failure.
pub fn forall<G, F>(cfg: &Config, gen: G, mut prop: F)
where
    G: Gen,
    F: FnMut(&G::Value) -> bool,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        let size = 1 + (case * 100) / cfg.cases.max(1);
        let value = gen.generate(&mut rng, size);
        if !prop(&value) {
            let minimal = shrink_loop(&gen, value, &mut prop, cfg.max_shrinks);
            // same `seed=… iter=…` shape as `testkit::soak::run_seeded`,
            // so every property failure in a log reads the same way
            panic!(
                "[seeded] property FAILED: seed={} iter={}/{} \
                 (replay: DVV_PROP_SEED={}):\n  counterexample = {minimal:?}",
                cfg.seed,
                case + 1,
                cfg.cases,
                cfg.seed
            );
        }
    }
}

fn shrink_loop<G, F>(gen: &G, mut value: G::Value, prop: &mut F, budget: usize) -> G::Value
where
    G: Gen,
    F: FnMut(&G::Value) -> bool,
{
    let mut spent = 0;
    'outer: while spent < budget {
        for candidate in gen.shrink(&value) {
            spent += 1;
            if !prop(&candidate) {
                value = candidate;
                continue 'outer;
            }
            if spent >= budget {
                break;
            }
        }
        break;
    }
    value
}

// ---------------------------------------------------------------------------
// Combinators
// ---------------------------------------------------------------------------

/// Uniform `i64` in `[lo, hi]`, shrinking toward `lo` (and toward 0 when in
/// range).
pub fn ints(lo: i64, hi: i64) -> IntGen {
    IntGen { lo, hi }
}

/// See [`ints`].
#[derive(Clone)]
pub struct IntGen {
    lo: i64,
    hi: i64,
}

impl Gen for IntGen {
    type Value = i64;

    fn generate(&self, rng: &mut Rng, _size: usize) -> i64 {
        self.lo + rng.below((self.hi - self.lo + 1) as u64) as i64
    }

    fn shrink(&self, v: &i64) -> Vec<i64> {
        let mut out = Vec::new();
        let anchor = if self.lo <= 0 && 0 <= self.hi { 0 } else { self.lo };
        if *v != anchor {
            out.push(anchor);
            let mid = anchor + (v - anchor) / 2;
            if mid != *v && mid != anchor {
                out.push(mid);
            }
            if (v - anchor).abs() == 1 {
                // already adjacent
            } else {
                out.push(v - (v - anchor).signum());
            }
        }
        out
    }
}

/// Vector of values from `inner`, with length in `[min_len, max_len]`
/// (scaled by the size hint). Shrinks by removing elements, then by
/// shrinking individual elements.
pub fn vecs<G: Gen + Clone>(inner: G, min_len: usize, max_len: usize) -> VecGen<G> {
    VecGen { inner, min_len, max_len }
}

/// See [`vecs`].
#[derive(Clone)]
pub struct VecGen<G> {
    inner: G,
    min_len: usize,
    max_len: usize,
}

impl<G: Gen + Clone> Gen for VecGen<G> {
    type Value = Vec<G::Value>;

    fn generate(&self, rng: &mut Rng, size: usize) -> Vec<G::Value> {
        let span = self.max_len - self.min_len;
        let scaled_max = self.min_len + (span * size.min(100)) / 100;
        let len = rng.range(self.min_len, scaled_max.max(self.min_len));
        (0..len).map(|_| self.inner.generate(rng, size)).collect()
    }

    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        // drop halves, then single elements
        if v.len() > self.min_len {
            let half = v.len() / 2;
            if half >= self.min_len {
                out.push(v[..half].to_vec());
            }
            for i in 0..v.len() {
                let mut c = v.clone();
                c.remove(i);
                if c.len() >= self.min_len {
                    out.push(c);
                }
            }
        }
        // shrink one element at a time
        for i in 0..v.len() {
            for candidate in self.inner.shrink(&v[i]) {
                let mut c = v.clone();
                c[i] = candidate;
                out.push(c);
            }
        }
        out
    }
}

/// Pair of independent generators.
pub fn pairs<A: Gen + Clone, B: Gen + Clone>(a: A, b: B) -> PairGen<A, B> {
    PairGen { a, b }
}

/// See [`pairs`].
#[derive(Clone)]
pub struct PairGen<A, B> {
    a: A,
    b: B,
}

impl<A: Gen + Clone, B: Gen + Clone> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Rng, size: usize) -> Self::Value {
        (self.a.generate(rng, size), self.b.generate(rng, size))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .a
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.b.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Generator from a plain function (no shrinking).
pub fn from_fn<T, F>(f: F) -> FnGen<F>
where
    T: Clone + std::fmt::Debug,
    F: Fn(&mut Rng, usize) -> T,
{
    FnGen { f }
}

/// See [`from_fn`].
#[derive(Clone)]
pub struct FnGen<F> {
    f: F,
}

impl<T, F> Gen for FnGen<F>
where
    T: Clone + std::fmt::Debug,
    F: Fn(&mut Rng, usize) -> T,
{
    type Value = T;

    fn generate(&self, rng: &mut Rng, size: usize) -> T {
        (self.f)(rng, size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(&Config::default().cases(50), ints(0, 10), |v| (0..=10).contains(v));
    }

    #[test]
    #[should_panic(expected = "counterexample")]
    fn failing_property_panics_with_counterexample() {
        forall(&Config::default().cases(200), ints(0, 1000), |v| *v < 900);
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        let result = std::panic::catch_unwind(|| {
            forall(&Config::default().cases(200), ints(0, 100_000), |v| *v < 500);
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().expect("panic payload"),
            Ok(()) => panic!("property should have failed"),
        };
        // greedy shrink should land near the boundary, far below the max
        let n: i64 = msg
            .rsplit("counterexample = ")
            .next()
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert!((500..2000).contains(&n), "shrunk to {n}; msg={msg}");
    }

    #[test]
    fn vec_gen_respects_bounds() {
        forall(&Config::default().cases(100), vecs(ints(-5, 5), 2, 9), |v| {
            (2..=9).contains(&v.len()) && v.iter().all(|x| (-5..=5).contains(x))
        });
    }

    #[test]
    fn sized_generation_grows() {
        let g = vecs(ints(0, 1), 0, 100);
        let mut rng = Rng::new(1);
        let small = g.generate(&mut rng, 1);
        let mut rng = Rng::new(1);
        let large = g.generate(&mut rng, 100);
        assert!(small.len() <= large.len());
    }

    #[test]
    fn pair_gen_shrinks_both_sides() {
        let g = pairs(ints(0, 10), ints(0, 10));
        let shrinks = g.shrink(&(5, 7));
        assert!(shrinks.iter().any(|(a, _)| *a < 5));
        assert!(shrinks.iter().any(|(_, b)| *b < 7));
    }

    #[test]
    fn replay_seed_reproduces_values() {
        let g = ints(0, 1_000_000);
        let cfg = Config::default().seed(1234).cases(10);
        let mut first = Vec::new();
        forall(&cfg, g.clone(), |v| {
            first.push(*v);
            true
        });
        let mut second = Vec::new();
        forall(&cfg, g, |v| {
            second.push(*v);
            true
        });
        assert_eq!(first, second);
    }
}
