//! Test + simulation support substrates.
//!
//! The offline build environment carries no `rand` or `proptest`, so this
//! module provides the two pieces the rest of the crate needs:
//!
//! * [`rng`] — a deterministic, seedable PRNG (splitmix64-seeded
//!   xoshiro256++) with the distribution helpers the simulator and
//!   workload generators use.
//! * [`prop`] — a small property-based testing harness: sized generators,
//!   seed-reporting on failure, and greedy shrinking for the common
//!   container shapes.

pub mod prop;
pub mod rng;

pub use prop::{forall, Config as PropConfig, Gen};
pub use rng::Rng;
