//! Test + simulation support substrates.
//!
//! The offline build environment carries no `rand` or `proptest`, so this
//! module provides the two pieces the rest of the crate needs:
//!
//! * [`rng`] — a deterministic, seedable PRNG (splitmix64-seeded
//!   xoshiro256++) with the distribution helpers the simulator and
//!   workload generators use.
//! * [`prop`] — a small property-based testing harness: sized generators,
//!   seed-reporting on failure, and greedy shrinking for the common
//!   container shapes.
//! * [`soak`] — the seeded-soak loop every `*_ITERS` chaos/churn/
//!   durability property test runs through, so a soak failure prints its
//!   seed and iteration in one uniform, replayable format.

pub mod prop;
pub mod rng;
pub mod soak;

pub use prop::{forall, Config as PropConfig, Gen};
pub use rng::Rng;
pub use soak::{run_seeded, soak_seeds, temp_dir};
