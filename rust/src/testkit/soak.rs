//! Seeded-soak harness: one uniform failure format for every seed-loop
//! property test.
//!
//! The repo's chaos/churn/durability property tests all share a shape:
//! a fixed seed list for the CI gate, an `*_ITERS` env knob appending
//! derived seeds for local soaking, and a per-seed run whose assertion
//! messages embed the seed. Before this module each test rolled its own
//! seed loop, and a soak failure's reproduction recipe depended on which
//! test tripped. Now every seed loop goes through [`run_seeded`], which
//! prints **one uniform line** on failure:
//!
//! ```text
//! [seeded] <label> FAILED: seed=<s> iter=<i>/<n> (replay: DVV_SEED=<s>)
//! ```
//!
//! and [`soak_seeds`] honors `DVV_SEED=<s>` to replay exactly that seed,
//! so any failure in a `CHAOS_ITERS`/`CHURN_ITERS`/`WAL_ITERS` soak is
//! reproducible straight from the log.

use super::rng::Rng;

/// The replay override: when set, [`soak_seeds`] returns exactly this
/// one seed, ignoring the fixed list and the iteration knob.
pub const REPLAY_ENV: &str = "DVV_SEED";

/// Build a seed list: `fixed` gate seeds plus `$iters_env` derived
/// extras (the soak knob), unless [`REPLAY_ENV`] pins a single seed.
///
/// Derived seeds come from a seed stream keyed by `iters_env`, so two
/// knobs soaking in one process do not correlate.
pub fn soak_seeds(fixed: &[u64], iters_env: &str) -> Vec<u64> {
    if let Some(seed) = std::env::var(REPLAY_ENV)
        .ok()
        .and_then(|s| s.parse().ok())
    {
        return vec![seed];
    }
    let mut seeds = fixed.to_vec();
    let iters: u64 = std::env::var(iters_env)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let knob_hash = iters_env
        .bytes()
        .fold(0u64, |h, b| h.wrapping_mul(131).wrapping_add(u64::from(b)));
    let mut stream = Rng::new(0x50AC_5EED ^ knob_hash);
    for _ in 0..iters {
        seeds.push(stream.next_u64() >> 16);
    }
    seeds
}

/// Run `f` once per seed; on panic, print the uniform
/// `[seeded] … seed=… iter=…` line (with the [`REPLAY_ENV`] recipe) and
/// resume the panic so the test still fails.
pub fn run_seeded(label: &str, seeds: &[u64], f: impl Fn(u64)) {
    for (iter, &seed) in seeds.iter().enumerate() {
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(seed)));
        if let Err(panic) = outcome {
            eprintln!(
                "[seeded] {label} FAILED: seed={seed} iter={}/{} (replay: {REPLAY_ENV}={seed})",
                iter + 1,
                seeds.len()
            );
            std::panic::resume_unwind(panic);
        }
    }
}

/// Create (and return) a fresh unique scratch directory under the OS
/// temp dir — the offline substitute for the `tempfile` crate, used by
/// the WAL tests and benches. Callers remove it when done (a leaked dir
/// under `$TMPDIR` on a panicking test is acceptable and aids debugging).
pub fn temp_dir(label: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let nonce = COUNTER.fetch_add(1, Ordering::Relaxed);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.subsec_nanos())
        .unwrap_or(0);
    let dir = std::env::temp_dir().join(format!(
        "dvvstore-{label}-{}-{nanos}-{nonce}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).expect("create temp dir");
    dir
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_seeds_pass_through() {
        // (no env manipulation: tests run multi-threaded)
        let seeds = soak_seeds(&[1, 2, 3], "DVV_TEST_NO_SUCH_KNOB");
        assert_eq!(seeds, vec![1, 2, 3]);
    }

    #[test]
    fn run_seeded_visits_every_seed() {
        let mut seen = Vec::new();
        let cell = std::cell::RefCell::new(&mut seen);
        run_seeded("visit", &[7, 8, 9], |s| {
            cell.borrow_mut().push(s);
        });
        assert_eq!(seen, vec![7, 8, 9]);
    }

    #[test]
    fn run_seeded_reports_and_repanics() {
        let result = std::panic::catch_unwind(|| {
            run_seeded("boom", &[4, 5], |s| assert_ne!(s, 5, "seed 5 trips"));
        });
        assert!(result.is_err(), "the panic must propagate");
    }

    #[test]
    fn temp_dirs_are_unique_and_exist() {
        let a = temp_dir("soak-test");
        let b = temp_dir("soak-test");
        assert_ne!(a, b);
        assert!(a.is_dir() && b.is_dir());
        std::fs::remove_dir_all(&a).unwrap();
        std::fs::remove_dir_all(&b).unwrap();
    }
}
