//! Flat single-lock backend: the seed's original `HashMap` layout.

use std::collections::HashMap;
use std::fmt;
use std::sync::RwLock;

use super::backend::StorageBackend;
use super::Key;
use crate::antientropy::merkle::ShardTree;
use crate::kernel::Mechanism;

/// Map plus its anti-entropy hash tree, kept consistent under the one
/// lock: every mutation records the key's new state digest before the
/// lock drops, so the tree never lags the map.
struct Inner<M: Mechanism> {
    map: HashMap<Key, M::State>,
    tree: ShardTree,
}

impl<M: Mechanism> Inner<M> {
    fn empty() -> Inner<M> {
        Inner { map: HashMap::new(), tree: ShardTree::new() }
    }
}

/// One flat map behind one store-wide reader/writer lock.
///
/// This is the simplest correct backend and the baseline the sharded
/// variant is benchmarked against (`benches/sharded_store.rs`): every
/// write serializes against every other operation on the store. Fine for
/// the single-threaded simulator and unit tests; a bottleneck for the
/// threaded TCP server.
pub struct InMemoryBackend<M: Mechanism> {
    inner: RwLock<Inner<M>>,
}

impl<M: Mechanism> InMemoryBackend<M> {
    /// Empty backend.
    pub fn new() -> InMemoryBackend<M> {
        InMemoryBackend { inner: RwLock::new(Inner::empty()) }
    }
}

impl<M: Mechanism> Default for InMemoryBackend<M> {
    fn default() -> Self {
        InMemoryBackend::new()
    }
}

impl<M: Mechanism> Clone for InMemoryBackend<M> {
    fn clone(&self) -> Self {
        let g = self.inner.read().unwrap();
        InMemoryBackend {
            inner: RwLock::new(Inner { map: g.map.clone(), tree: g.tree.clone() }),
        }
    }
}

impl<M: Mechanism> fmt::Debug for InMemoryBackend<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("InMemoryBackend")
            .field("keys", &self.inner.read().unwrap().map.len())
            .finish()
    }
}

impl<M: Mechanism> StorageBackend<M> for InMemoryBackend<M> {
    fn with_state<R>(&self, key: Key, f: impl FnOnce(Option<&M::State>) -> R) -> R {
        f(self.inner.read().unwrap().map.get(&key))
    }

    fn update<R>(&self, key: Key, f: impl FnOnce(&mut M::State) -> R) -> R {
        let mut g = self.inner.write().unwrap();
        let inner = &mut *g;
        let st = inner.map.entry(key).or_default();
        let r = f(st);
        inner.tree.record(key, M::state_digest(st));
        r
    }

    fn update_batch<T>(&self, items: &[(Key, T)], mut f: impl FnMut(&mut M::State, &T)) {
        let mut g = self.inner.write().unwrap();
        let inner = &mut *g;
        for (key, payload) in items {
            let st = inner.map.entry(*key).or_default();
            f(st, payload);
            inner.tree.record(*key, M::state_digest(st));
        }
    }

    fn for_each(&self, mut f: impl FnMut(Key, &M::State)) {
        for (k, st) in self.inner.read().unwrap().map.iter() {
            f(*k, st);
        }
    }

    fn key_count(&self) -> usize {
        self.inner.read().unwrap().map.len()
    }

    fn shard_count(&self) -> usize {
        1
    }

    fn shard_of(&self, _key: Key) -> usize {
        0
    }

    fn keys_in_shard(&self, _shard: usize) -> Vec<Key> {
        self.inner.read().unwrap().map.keys().copied().collect()
    }

    fn wipe(&self) {
        let mut g = self.inner.write().unwrap();
        g.map.clear();
        g.tree.clear();
    }

    fn with_merkle<R>(&self, _shard: usize, f: impl FnOnce(&mut ShardTree) -> R) -> R {
        f(&mut self.inner.write().unwrap().tree)
    }
}
