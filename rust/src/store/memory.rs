//! Flat single-lock backend: the seed's original `HashMap` layout.

use std::collections::HashMap;
use std::fmt;
use std::sync::RwLock;

use super::backend::StorageBackend;
use super::Key;
use crate::kernel::Mechanism;

/// One flat map behind one store-wide reader/writer lock.
///
/// This is the simplest correct backend and the baseline the sharded
/// variant is benchmarked against (`benches/sharded_store.rs`): every
/// write serializes against every other operation on the store. Fine for
/// the single-threaded simulator and unit tests; a bottleneck for the
/// threaded TCP server.
pub struct InMemoryBackend<M: Mechanism> {
    map: RwLock<HashMap<Key, M::State>>,
}

impl<M: Mechanism> InMemoryBackend<M> {
    /// Empty backend.
    pub fn new() -> InMemoryBackend<M> {
        InMemoryBackend { map: RwLock::new(HashMap::new()) }
    }
}

impl<M: Mechanism> Default for InMemoryBackend<M> {
    fn default() -> Self {
        InMemoryBackend::new()
    }
}

impl<M: Mechanism> Clone for InMemoryBackend<M> {
    fn clone(&self) -> Self {
        InMemoryBackend { map: RwLock::new(self.map.read().unwrap().clone()) }
    }
}

impl<M: Mechanism> fmt::Debug for InMemoryBackend<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("InMemoryBackend")
            .field("keys", &self.map.read().unwrap().len())
            .finish()
    }
}

impl<M: Mechanism> StorageBackend<M> for InMemoryBackend<M> {
    fn with_state<R>(&self, key: Key, f: impl FnOnce(Option<&M::State>) -> R) -> R {
        f(self.map.read().unwrap().get(&key))
    }

    fn update<R>(&self, key: Key, f: impl FnOnce(&mut M::State) -> R) -> R {
        f(self.map.write().unwrap().entry(key).or_default())
    }

    fn update_batch<T>(&self, items: &[(Key, T)], mut f: impl FnMut(&mut M::State, &T)) {
        let mut map = self.map.write().unwrap();
        for (key, payload) in items {
            f(map.entry(*key).or_default(), payload);
        }
    }

    fn for_each(&self, mut f: impl FnMut(Key, &M::State)) {
        for (k, st) in self.map.read().unwrap().iter() {
            f(*k, st);
        }
    }

    fn key_count(&self) -> usize {
        self.map.read().unwrap().len()
    }

    fn shard_count(&self) -> usize {
        1
    }

    fn shard_of(&self, _key: Key) -> usize {
        0
    }

    fn keys_in_shard(&self, _shard: usize) -> Vec<Key> {
        self.map.read().unwrap().keys().copied().collect()
    }

    fn wipe(&self) {
        self.map.write().unwrap().clear();
    }
}
