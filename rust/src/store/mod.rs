//! Per-node versioned key store, generic over the causality mechanism.
//!
//! Each replica node owns one [`KeyStore`]: a map from keys to the
//! mechanism's per-key state (sibling versions + clocks). All mutation
//! funnels through [`KeyStore::write`] and [`KeyStore::merge_key`] so the
//! §4 kernel semantics are applied uniformly no matter where the mutation
//! came from (client PUT, replication fan-out, read repair, anti-entropy).

use std::collections::HashMap;

use crate::clocks::Actor;
use crate::kernel::{Mechanism, Val, WriteMeta};

/// Key identifier. The simulator and benches use dense numeric keys; the
/// TCP server hashes string keys into this space (see `server::protocol`).
pub type Key = u64;

/// A node-local versioned store.
#[derive(Debug, Clone)]
pub struct KeyStore<M: Mechanism> {
    mech: M,
    map: HashMap<Key, M::State>,
}

impl<M: Mechanism> KeyStore<M> {
    /// Empty store for a mechanism instance.
    pub fn new(mech: M) -> KeyStore<M> {
        KeyStore { mech, map: HashMap::new() }
    }

    /// The mechanism instance.
    pub fn mech(&self) -> &M {
        &self.mech
    }

    /// GET: current values + context (empty state when the key is absent).
    pub fn read(&self, key: Key) -> (Vec<Val>, M::Context) {
        match self.map.get(&key) {
            Some(st) => self.mech.read(st),
            None => self.mech.read(&M::State::default()),
        }
    }

    /// PUT at this node acting as coordinator `coord`.
    pub fn write(&mut self, key: Key, ctx: &M::Context, val: Val, coord: Actor, meta: &WriteMeta) {
        let st = self.map.entry(key).or_default();
        self.mech.write(st, ctx, val, coord, meta);
    }

    /// Merge an incoming replica state for `key` (replication/anti-entropy/
    /// read repair).
    pub fn merge_key(&mut self, key: Key, incoming: &M::State) {
        let st = self.map.entry(key).or_default();
        self.mech.merge(st, incoming);
    }

    /// Clone of the state for `key` (empty default when absent) — what a
    /// replica ships to a coordinator or peer.
    pub fn state(&self, key: Key) -> M::State {
        self.map.get(&key).cloned().unwrap_or_default()
    }

    /// Reference to the state if present.
    pub fn state_ref(&self, key: Key) -> Option<&M::State> {
        self.map.get(&key)
    }

    /// Live values for `key`.
    pub fn values(&self, key: Key) -> Vec<Val> {
        self.map.get(&key).map(|st| self.mech.values(st)).unwrap_or_default()
    }

    /// Number of keys stored.
    pub fn key_count(&self) -> usize {
        self.map.len()
    }

    /// Iterate stored keys.
    pub fn keys(&self) -> impl Iterator<Item = Key> + '_ {
        self.map.keys().copied()
    }

    /// Total causality-metadata bytes across keys (E7).
    pub fn metadata_bytes(&self) -> u64 {
        self.map.values().map(|st| self.mech.metadata_bytes(st) as u64).sum()
    }

    /// Largest sibling set currently stored.
    pub fn max_siblings(&self) -> usize {
        self.map
            .values()
            .map(|st| self.mech.sibling_count(st))
            .max()
            .unwrap_or(0)
    }

    /// Sibling count for one key.
    pub fn sibling_count(&self, key: Key) -> usize {
        self.map.get(&key).map(|st| self.mech.sibling_count(st)).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::mechs::DvvMech;

    fn store() -> KeyStore<DvvMech> {
        KeyStore::new(DvvMech)
    }
    fn coord() -> Actor {
        Actor::server(0)
    }
    fn meta() -> WriteMeta {
        WriteMeta::basic(Actor::client(0))
    }

    #[test]
    fn read_missing_key_is_empty() {
        let s = store();
        let (vals, _ctx) = s.read(42);
        assert!(vals.is_empty());
        assert_eq!(s.sibling_count(42), 0);
    }

    #[test]
    fn write_then_read() {
        let mut s = store();
        let (_, ctx) = s.read(1);
        s.write(1, &ctx, Val::new(10, 4), coord(), &meta());
        let (vals, _) = s.read(1);
        assert_eq!(vals, vec![Val::new(10, 4)]);
        assert_eq!(s.key_count(), 1);
    }

    #[test]
    fn blind_writes_accumulate_siblings() {
        let mut s = store();
        let empty = s.read(1).1;
        s.write(1, &empty, Val::new(1, 0), coord(), &meta());
        s.write(1, &empty, Val::new(2, 0), coord(), &meta());
        assert_eq!(s.sibling_count(1), 2);
        assert_eq!(s.max_siblings(), 2);
    }

    #[test]
    fn merge_key_converges_two_stores() {
        let mut s1 = store();
        let mut s2 = store();
        let empty = s1.read(1).1;
        s1.write(1, &empty, Val::new(1, 0), Actor::server(0), &meta());
        s2.write(1, &empty, Val::new(2, 0), Actor::server(1), &meta());
        let st2 = s2.state(1);
        s1.merge_key(1, &st2);
        let st1 = s1.state(1);
        s2.merge_key(1, &st1);
        let (mut v1, mut v2) = (s1.values(1), s2.values(1));
        v1.sort();
        v2.sort();
        assert_eq!(v1, v2);
        assert_eq!(v1.len(), 2);
    }

    #[test]
    fn metadata_accounting_sums_keys() {
        let mut s = store();
        for k in 0..10 {
            let (_, ctx) = s.read(k);
            s.write(k, &ctx, Val::new(k, 0), coord(), &meta());
        }
        assert!(s.metadata_bytes() > 0);
        assert_eq!(s.keys().count(), 10);
    }
}
