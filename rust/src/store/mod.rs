//! Per-node versioned key store, generic over the causality mechanism
//! *and* the storage backend.
//!
//! Each replica node owns one [`KeyStore`]: a map from keys to the
//! mechanism's per-key state (sibling versions + clocks). All mutation
//! funnels through [`KeyStore::write`] and [`KeyStore::merge_key`] so the
//! §4 kernel semantics are applied uniformly no matter where the mutation
//! came from (client PUT, replication fan-out, read repair, anti-entropy).
//!
//! Where the states live is the [`StorageBackend`]'s concern:
//!
//! * [`InMemoryBackend`] — one flat map behind one lock (default; the
//!   simulator and unit tests use this);
//! * [`ShardedBackend`] — lock-striped shards over a power-of-two key
//!   mask, so the threaded TCP server can run GET/PUT on different keys
//!   without contending (see `benches/sharded_store.rs` for the flat
//!   vs. sharded comparison);
//! * [`DurableBackend`] — the sharded map plus a per-shard, segmented,
//!   checksummed write-ahead log ([`wal`]): every mutation is logged
//!   before its lock is released, replay-on-open recovers the longest
//!   valid record prefix (torn tails are truncated and reported), and
//!   hot-key logs compact via snapshot segments. This is what
//!   `dvv-store serve --data-dir` runs on by default;
//! * [`LsmBackend`] — the LSM storage engine ([`lsm`], [`sst`]): a
//!   bounded memtable covered exactly by the WAL, bloom-filtered sorted
//!   runs on disk, size-tiered background compaction and a block read
//!   cache, so the working set can exceed RAM and restart replay is
//!   O(memtable). `dvv-store serve --data-dir ... --backend lsm`.
//!
//! Every [`KeyStore`] method takes `&self` — locking is internal to the
//! backend — so a store can be shared across server threads with a plain
//! `Arc`, no store-wide `Mutex`.
//!
//! ```no_run
//! // (no_run: doctest binaries don't get the xla rpath link flags)
//! use dvvstore::clocks::Actor;
//! use dvvstore::kernel::mechs::DvvMech;
//! use dvvstore::kernel::{Val, WriteMeta};
//! use dvvstore::store::KeyStore;
//!
//! let store = KeyStore::new(DvvMech);
//! let meta = WriteMeta::basic(Actor::client(0));
//!
//! // two blind writes (empty context) -> two concurrent siblings
//! let (_, empty) = store.read(1);
//! store.write(1, &empty, Val::new(10, 0), Actor::server(0), &meta);
//! store.write(1, &empty, Val::new(11, 0), Actor::server(0), &meta);
//! let (siblings, ctx) = store.read(1);
//! assert_eq!(siblings.len(), 2);
//!
//! // a write carrying the read context supersedes exactly what was read
//! store.write(1, &ctx, Val::new(12, 0), Actor::server(0), &meta);
//! assert_eq!(store.values(1), vec![Val::new(12, 0)]);
//! ```

pub mod backend;
mod durable;
pub mod lsm;
mod memory;
mod sharded;
pub mod sst;
pub mod wal;

pub use backend::StorageBackend;
pub use durable::{DurableBackend, DEFAULT_DURABLE_SHARDS};
pub use lsm::{LsmBackend, LsmOptions, DEFAULT_LSM_SHARDS};
pub use memory::InMemoryBackend;
pub use sharded::{ShardedBackend, DEFAULT_SHARDS};
pub use wal::{FsyncPolicy, RecoveryReport, WalOptions};

use std::fmt;

use crate::clocks::Actor;
use crate::kernel::{Mechanism, Val, WriteMeta};

/// Key identifier. The simulator and benches use dense numeric keys; the
/// TCP server hashes string keys into this space (see `server::protocol`).
pub type Key = u64;

/// A node-local versioned store over backend `B`.
///
/// `KeyStore<M>` (the default backend) is the flat single-lock layout;
/// `KeyStore<M, ShardedBackend<M>>` is the lock-striped layout the TCP
/// server shares across connection threads:
///
/// ```no_run
/// // (no_run: doctest binaries don't get the xla rpath link flags)
/// use std::sync::Arc;
/// use dvvstore::clocks::Actor;
/// use dvvstore::kernel::mechs::DvvMech;
/// use dvvstore::kernel::{Val, WriteMeta};
/// use dvvstore::store::{KeyStore, ShardedBackend, StorageBackend};
///
/// let store = Arc::new(KeyStore::with_backend(DvvMech, ShardedBackend::with_shards(8)));
/// let meta = WriteMeta::basic(Actor::client(0));
/// let handles: Vec<_> = (0..4u64)
///     .map(|t| {
///         let store = Arc::clone(&store);
///         let meta = meta.clone();
///         // writers on different keys take different stripe locks
///         std::thread::spawn(move || {
///             let (_, ctx) = store.read(t);
///             store.write(t, &ctx, Val::new(t, 0), Actor::server(0), &meta);
///         })
///     })
///     .collect();
/// for h in handles {
///     h.join().unwrap();
/// }
/// assert_eq!(store.key_count(), 4);
/// assert_eq!(store.backend().shard_count(), 8);
/// ```
pub struct KeyStore<M: Mechanism, B: StorageBackend<M> = InMemoryBackend<M>> {
    mech: M,
    backend: B,
}

impl<M: Mechanism> KeyStore<M> {
    /// Empty store for a mechanism instance, on the default flat
    /// [`InMemoryBackend`].
    pub fn new(mech: M) -> KeyStore<M> {
        KeyStore { mech, backend: InMemoryBackend::new() }
    }
}

impl<M: Mechanism, B: StorageBackend<M>> KeyStore<M, B> {
    /// Empty store over an explicit backend.
    pub fn with_backend(mech: M, backend: B) -> KeyStore<M, B> {
        KeyStore { mech, backend }
    }

    /// The mechanism instance.
    pub fn mech(&self) -> &M {
        &self.mech
    }

    /// The storage backend (shard layout, diagnostics).
    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// GET: current values + context (empty state when the key is absent).
    pub fn read(&self, key: Key) -> (Vec<Val>, M::Context) {
        self.backend.with_state(key, |st| match st {
            Some(st) => self.mech.read(st),
            None => self.mech.read(&M::State::default()),
        })
    }

    /// PUT at this node acting as coordinator `coord`.
    pub fn write(&self, key: Key, ctx: &M::Context, val: Val, coord: Actor, meta: &WriteMeta) {
        self.backend.update(key, |st| self.mech.write(st, ctx, val, coord, meta));
    }

    /// PUT that also returns the post-write state under the same lock
    /// acquisition — what a coordinator replicates to its peers (§4.1 put
    /// steps 2–4) without a read-back race.
    pub fn write_returning(
        &self,
        key: Key,
        ctx: &M::Context,
        val: Val,
        coord: Actor,
        meta: &WriteMeta,
    ) -> M::State {
        self.backend.update(key, |st| {
            self.mech.write(st, ctx, val, coord, meta);
            st.clone()
        })
    }

    /// PUT that additionally reports the pre-write live values alongside
    /// the post-write state, all under the same lock acquisition — so a
    /// ground-truth auditor ([`crate::oracle::SharedOracle`]) can
    /// classify the exact sibling-set delta of this mutation even while
    /// other threads race on the same key.
    pub fn write_audited(
        &self,
        key: Key,
        ctx: &M::Context,
        val: Val,
        coord: Actor,
        meta: &WriteMeta,
    ) -> (Vec<Val>, M::State) {
        self.backend.update(key, |st| {
            let before = self.mech.values(st);
            self.mech.write(st, ctx, val, coord, meta);
            (before, st.clone())
        })
    }

    /// Merge an incoming replica state for `key` (replication/anti-entropy/
    /// read repair).
    pub fn merge_key(&self, key: Key, incoming: &M::State) {
        self.backend.update(key, |st| self.mech.merge(st, incoming));
    }

    /// [`merge_key`](KeyStore::merge_key) that reports the (before, after)
    /// live values under one lock acquisition (oracle drop auditing).
    pub fn merge_key_audited(&self, key: Key, incoming: &M::State) -> (Vec<Val>, Vec<Val>) {
        self.backend.update(key, |st| {
            let before = self.mech.values(st);
            self.mech.merge(st, incoming);
            (before, self.mech.values(st))
        })
    }

    /// Merge a batch of incoming replica states, taking each backend lock
    /// at most once — the amortized path the batched replication fan-out
    /// uses ([`crate::coordinator::MergeBatch`]). A one-item batch costs
    /// exactly a [`merge_key`](KeyStore::merge_key).
    pub fn merge_batch(&self, items: &[(Key, M::State)]) {
        if let [(key, incoming)] = items {
            return self.merge_key(*key, incoming);
        }
        self.backend.update_batch(items, |st, incoming| self.mech.merge(st, incoming));
    }

    /// Clone of the state for `key` (empty default when absent) — what a
    /// replica ships to a coordinator or peer.
    pub fn state(&self, key: Key) -> M::State {
        self.backend.state_clone(key)
    }

    /// Visit the state for `key` without cloning (`None` when absent).
    pub fn with_state<R>(&self, key: Key, f: impl FnOnce(Option<&M::State>) -> R) -> R {
        self.backend.with_state(key, f)
    }

    /// Live values for `key`.
    pub fn values(&self, key: Key) -> Vec<Val> {
        self.backend
            .with_state(key, |st| st.map(|st| self.mech.values(st)).unwrap_or_default())
    }

    /// Number of keys stored.
    pub fn key_count(&self) -> usize {
        self.backend.key_count()
    }

    /// Iterate a snapshot of the stored keys.
    pub fn keys(&self) -> impl Iterator<Item = Key> {
        self.backend.keys().into_iter()
    }

    /// Number of backend shards (1 for the flat backend).
    pub fn shard_count(&self) -> usize {
        self.backend.shard_count()
    }

    /// The backend shard owning `key`.
    pub fn shard_of(&self, key: Key) -> usize {
        self.backend.shard_of(key)
    }

    /// Snapshot of the keys in one backend shard (anti-entropy iterates
    /// the store shard by shard; see [`crate::antientropy`]).
    pub fn keys_in_shard(&self, shard: usize) -> Vec<Key> {
        self.backend.keys_in_shard(shard)
    }

    /// Whole-store anti-entropy digest: the wrapping sum of every shard's
    /// hash-tree root ([`crate::antientropy::merkle`]). Shard roots are
    /// additive partial sums of the same per-key terms, so this value
    /// depends only on the key/state multiset — two converged replicas
    /// report equal roots even across different shard counts or backend
    /// types. Feeds `STATS merkle_root=` and the convergence audits.
    pub fn merkle_root(&self) -> u64 {
        (0..self.backend.shard_count())
            .fold(0u64, |acc, s| acc.wrapping_add(self.backend.merkle_root(s)))
    }

    /// Total causality-metadata bytes across keys, aggregated shard by
    /// shard on demand. Feeds `Metrics::metadata_bytes` in the simulator
    /// reports and the TCP server's `STATS` line. (The per-mechanism
    /// metadata *scaling* experiment — `benches/metadata.rs` — measures
    /// states directly through [`Mechanism::metadata_bytes`] instead.)
    pub fn metadata_bytes(&self) -> u64 {
        let mut total = 0u64;
        self.backend
            .for_each(|_, st| total += self.mech.metadata_bytes(st) as u64);
        total
    }

    /// Largest sibling set currently stored.
    pub fn max_siblings(&self) -> usize {
        let mut max = 0;
        self.backend
            .for_each(|_, st| max = max.max(self.mech.sibling_count(st)));
        max
    }

    /// Sibling count for one key.
    pub fn sibling_count(&self, key: Key) -> usize {
        self.backend
            .with_state(key, |st| st.map(|st| self.mech.sibling_count(st)).unwrap_or(0))
    }
}

impl<M: Mechanism, B: StorageBackend<M> + Clone> Clone for KeyStore<M, B> {
    fn clone(&self) -> Self {
        KeyStore { mech: self.mech.clone(), backend: self.backend.clone() }
    }
}

impl<M: Mechanism, B: StorageBackend<M>> fmt::Debug for KeyStore<M, B> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("KeyStore")
            .field("mechanism", &M::NAME)
            .field("backend", &self.backend)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::mechs::DvvMech;

    fn store() -> KeyStore<DvvMech> {
        KeyStore::new(DvvMech)
    }
    fn sharded() -> KeyStore<DvvMech, ShardedBackend<DvvMech>> {
        KeyStore::with_backend(DvvMech, ShardedBackend::with_shards(8))
    }
    fn coord() -> Actor {
        Actor::server(0)
    }
    fn meta() -> WriteMeta {
        WriteMeta::basic(Actor::client(0))
    }

    #[test]
    fn read_missing_key_is_empty() {
        let s = store();
        let (vals, _ctx) = s.read(42);
        assert!(vals.is_empty());
        assert_eq!(s.sibling_count(42), 0);
    }

    #[test]
    fn write_then_read() {
        let s = store();
        let (_, ctx) = s.read(1);
        s.write(1, &ctx, Val::new(10, 4), coord(), &meta());
        let (vals, _) = s.read(1);
        assert_eq!(vals, vec![Val::new(10, 4)]);
        assert_eq!(s.key_count(), 1);
    }

    #[test]
    fn blind_writes_accumulate_siblings() {
        let s = store();
        let empty = s.read(1).1;
        s.write(1, &empty, Val::new(1, 0), coord(), &meta());
        s.write(1, &empty, Val::new(2, 0), coord(), &meta());
        assert_eq!(s.sibling_count(1), 2);
        assert_eq!(s.max_siblings(), 2);
    }

    #[test]
    fn merge_key_converges_two_stores() {
        let s1 = store();
        let s2 = store();
        let empty = s1.read(1).1;
        s1.write(1, &empty, Val::new(1, 0), Actor::server(0), &meta());
        s2.write(1, &empty, Val::new(2, 0), Actor::server(1), &meta());
        let st2 = s2.state(1);
        s1.merge_key(1, &st2);
        let st1 = s1.state(1);
        s2.merge_key(1, &st1);
        let (mut v1, mut v2) = (s1.values(1), s2.values(1));
        v1.sort();
        v2.sort();
        assert_eq!(v1, v2);
        assert_eq!(v1.len(), 2);
    }

    #[test]
    fn metadata_accounting_sums_keys() {
        let s = store();
        for k in 0..10 {
            let (_, ctx) = s.read(k);
            s.write(k, &ctx, Val::new(k, 0), coord(), &meta());
        }
        assert!(s.metadata_bytes() > 0);
        assert_eq!(s.keys().count(), 10);
    }

    #[test]
    fn write_returning_matches_state() {
        let s = store();
        let (_, ctx) = s.read(9);
        let st = s.write_returning(9, &ctx, Val::new(5, 0), coord(), &meta());
        assert_eq!(st, s.state(9));
        assert_eq!(s.values(9), vec![Val::new(5, 0)]);
    }

    #[test]
    fn audited_mutations_report_sibling_deltas() {
        let s = store();
        let empty = s.read(1).1;
        let (before, st) = s.write_audited(1, &empty, Val::new(1, 0), coord(), &meta());
        assert!(before.is_empty());
        assert_eq!(st, s.state(1));
        // an informed write supersedes: before holds the old value
        let (_, ctx) = s.read(1);
        let (before, _) = s.write_audited(1, &ctx, Val::new(2, 0), coord(), &meta());
        assert_eq!(before, vec![Val::new(1, 0)]);
        assert_eq!(s.values(1), vec![Val::new(2, 0)]);

        // merge_key_audited: a dominating incoming state drops the local
        let other = store();
        other.merge_key(1, &s.state(1));
        let (_, octx) = other.read(1);
        other.write(1, &octx, Val::new(3, 0), Actor::server(1), &meta());
        let (before, after) = s.merge_key_audited(1, &other.state(1));
        assert_eq!(before, vec![Val::new(2, 0)]);
        assert_eq!(after, vec![Val::new(3, 0)]);
    }

    #[test]
    fn sharded_store_same_semantics() {
        let s = sharded();
        let empty = s.read(1).1;
        s.write(1, &empty, Val::new(1, 0), coord(), &meta());
        s.write(1, &empty, Val::new(2, 0), coord(), &meta());
        assert_eq!(s.sibling_count(1), 2);
        let (_, ctx) = s.read(1);
        s.write(1, &ctx, Val::new(3, 0), coord(), &meta());
        assert_eq!(s.values(1), vec![Val::new(3, 0)]);
        assert_eq!(s.shard_count(), 8);
        assert!(s.metadata_bytes() > 0);
    }

    #[test]
    fn merge_batch_equals_sequential_merges() {
        let src = store();
        let empty = src.read(0).1;
        for k in 0..20 {
            src.write(k, &empty, Val::new(k + 1, 0), Actor::server(1), &meta());
        }
        let items: Vec<(Key, _)> = src.keys().map(|k| (k, src.state(k))).collect();

        let batched = sharded();
        batched.merge_batch(&items);
        let sequential = sharded();
        for (k, st) in &items {
            sequential.merge_key(*k, st);
        }
        for k in 0..20 {
            assert_eq!(batched.state(k), sequential.state(k));
        }
        assert_eq!(batched.key_count(), 20);
    }

    #[test]
    fn shard_key_snapshots_partition_the_store() {
        let s = sharded();
        let empty = s.read(0).1;
        for k in 0..64 {
            s.write(k, &empty, Val::new(k + 1, 0), coord(), &meta());
        }
        let mut seen: Vec<Key> = (0..s.shard_count())
            .flat_map(|sh| s.keys_in_shard(sh))
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..64).collect::<Vec<Key>>());
    }
}
