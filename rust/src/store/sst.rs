//! Sorted-run (SSTable) files: the on-disk half of
//! [`LsmBackend`](super::LsmBackend).
//!
//! A **run** is an immutable, sorted, checksummed file of `(key, state)`
//! entries — the unit a memtable flush produces and compaction merges.
//! Runs are written once, fsynced, and never modified; recency is
//! encoded entirely in the *ordering* of a shard's run list (newest
//! wins), so readers never merge states across runs.
//!
//! # On-disk format
//!
//! ```text
//! [8] SST_MAGIC ("DVVSST01")
//! data blocks, back to back:
//!     block := [varint body_len][u32 LE crc32(body)][body]
//!     body  := entries, keys strictly ascending across the whole file
//!     entry := [varint entry_len][varint key][mechanism state encoding]
//! footer body:
//!     [varint entry_count][varint min_key][varint max_key]      (fence)
//!     [varint block_count] then per block:
//!         [varint offset][varint framed_len][varint first_key][varint last_key]
//!     [varint bloom_words][varint bloom_k][bloom_words x u64 LE]
//!     entry_count x ([varint key][u64 LE state_digest])          (key order)
//! tail:
//!     [u32 LE crc32(footer body)][u32 LE footer body len][8] SST_FOOTER_MAGIC
//! ```
//!
//! The footer carries everything a reader needs *without touching the
//! data region*: the key-range fence, the per-block index (so a point
//! read seeks at most one block), a bloom filter over the keys (so a
//! miss usually costs zero reads), and the per-entry state digests (so
//! [`LsmBackend`](super::LsmBackend) rebuilds its anti-entropy
//! [`ShardTree`](crate::antientropy::merkle::ShardTree) on open from
//! footers alone — no state decoding).
//!
//! # Validation
//!
//! [`Run::open`] checks the whole file before trusting any of it: both
//! magics, the footer CRC, every block CRC, entry framing, strict key
//! ascent, index/fence/digest consistency. Any mismatch is an `Err` —
//! never a panic — and the caller **quarantines** the file (renames it
//! to `*.quarantined`, see [`quarantine`]) so one damaged run costs
//! exactly that run; anti-entropy re-delivers what it held. The scan is
//! a sequential read with no state decoding, so open stays cheap
//! relative to a WAL replay of the same bytes.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};

use super::wal::crc32;
use super::Key;
use crate::clocks::encoding::{get_varint, put_varint};
use crate::error::{Error, Result};
use crate::kernel::digest::mix64;

/// First 8 bytes of every run file (format name + version).
pub const SST_MAGIC: [u8; 8] = *b"DVVSST01";

/// Last 8 bytes of every run file.
pub const SST_FOOTER_MAGIC: [u8; 8] = *b"DVVSSTFT";

/// Fixed tail size: footer CRC + footer length + tail magic.
const TAIL_LEN: usize = 4 + 4 + 8;

fn bad(path: &Path, what: &str) -> Error {
    Error::Codec(format!("run {}: {what}", path.display()))
}

/// Bloom filter over a run's keys: ~10 bits and 6 probes per key, built
/// by double hashing [`mix64`]. A negative answer is exact; a positive
/// one is wrong with probability under ~1 % at that sizing, which is the
/// fraction of point misses that still pay one block read.
#[derive(Debug, Clone)]
pub struct Bloom {
    words: Vec<u64>,
    k: u32,
}

impl Bloom {
    /// Filter sized for `entries` keys (power-of-two bit count, min 64).
    pub fn with_capacity(entries: usize) -> Bloom {
        let bits = (entries.max(1) * 10).next_power_of_two().max(64);
        Bloom { words: vec![0; bits / 64], k: 6 }
    }

    #[inline]
    fn probes(&self, key: Key) -> (u64, u64, u64) {
        let mask = (self.words.len() as u64 * 64) - 1;
        let h1 = mix64(key);
        // force h2 odd so the probe sequence walks the whole (power of
        // two sized) bit space
        let h2 = mix64(key ^ 0x9E37_79B9_7F4A_7C15) | 1;
        (h1, h2, mask)
    }

    /// Set `key`'s probe bits.
    pub fn insert(&mut self, key: Key) {
        let (h1, h2, mask) = self.probes(key);
        for i in 0..u64::from(self.k) {
            let bit = h1.wrapping_add(i.wrapping_mul(h2)) & mask;
            self.words[(bit / 64) as usize] |= 1 << (bit % 64);
        }
    }

    /// Might `key` be present? (`false` is definitive.)
    pub fn contains(&self, key: Key) -> bool {
        let (h1, h2, mask) = self.probes(key);
        (0..u64::from(self.k)).all(|i| {
            let bit = h1.wrapping_add(i.wrapping_mul(h2)) & mask;
            self.words[(bit / 64) as usize] & (1 << (bit % 64)) != 0
        })
    }

    fn encode(&self, buf: &mut Vec<u8>) {
        put_varint(buf, self.words.len() as u64);
        put_varint(buf, u64::from(self.k));
        for w in &self.words {
            buf.extend_from_slice(&w.to_le_bytes());
        }
    }

    fn decode(buf: &[u8], pos: &mut usize) -> Result<Bloom> {
        let words = get_varint(buf, pos)?;
        let k = get_varint(buf, pos)?;
        if words == 0 || !(words as usize).is_power_of_two() && words != 1 || k == 0 || k > 32 {
            return Err(Error::Codec(format!("bloom shape words={words} k={k}")));
        }
        let mut out = Vec::with_capacity(words as usize);
        for _ in 0..words {
            let bytes = crate::clocks::encoding::get_bytes(buf, pos, 8)?;
            out.push(u64::from_le_bytes(bytes.try_into().unwrap()));
        }
        Ok(Bloom { words: out, k: k as u32 })
    }
}

/// One data block's index entry.
#[derive(Debug, Clone, Copy)]
struct BlockMeta {
    /// Byte offset of the framed block from the start of the file.
    offset: u64,
    /// Framed length (varint header + CRC + body).
    len: u64,
    first: Key,
    last: Key,
}

/// Streaming writer: feed ascending `(key, digest, state)` entries, then
/// [`finish`](RunWriter::finish) to write, fsync, and re-open the file
/// as a validated [`Run`].
pub struct RunWriter {
    block_bytes: usize,
    /// The file image under construction (starts with [`SST_MAGIC`]).
    data: Vec<u8>,
    /// Current (unsealed) block body.
    cur: Vec<u8>,
    cur_first: Key,
    blocks: Vec<BlockMeta>,
    digests: Vec<(Key, u64)>,
    last_key: Option<Key>,
}

impl RunWriter {
    /// Writer targeting `block_bytes` per data block (min 64).
    pub fn new(block_bytes: usize) -> RunWriter {
        RunWriter {
            block_bytes: block_bytes.max(64),
            data: SST_MAGIC.to_vec(),
            cur: Vec::new(),
            cur_first: 0,
            blocks: Vec::new(),
            digests: Vec::new(),
            last_key: None,
        }
    }

    /// Append one entry. Keys must be strictly ascending; `state` is the
    /// mechanism's `encode_state` bytes.
    pub fn add(&mut self, key: Key, digest: u64, state: &[u8]) {
        assert!(
            self.last_key.map_or(true, |last| last < key),
            "run entries must be strictly ascending (got {key} after {:?})",
            self.last_key
        );
        if self.cur.is_empty() {
            self.cur_first = key;
        }
        let mut payload = Vec::with_capacity(10 + state.len());
        put_varint(&mut payload, key);
        payload.extend_from_slice(state);
        put_varint(&mut self.cur, payload.len() as u64);
        self.cur.extend_from_slice(&payload);
        self.digests.push((key, digest));
        self.last_key = Some(key);
        if self.cur.len() >= self.block_bytes {
            self.seal_block();
        }
    }

    fn seal_block(&mut self) {
        if self.cur.is_empty() {
            return;
        }
        let offset = self.data.len() as u64;
        put_varint(&mut self.data, self.cur.len() as u64);
        self.data.extend_from_slice(&crc32(&self.cur).to_le_bytes());
        self.data.extend_from_slice(&self.cur);
        self.blocks.push(BlockMeta {
            offset,
            len: self.data.len() as u64 - offset,
            first: self.cur_first,
            last: self.last_key.expect("sealed block holds entries"),
        });
        self.cur.clear();
    }

    /// Entries added so far.
    pub fn entry_count(&self) -> usize {
        self.digests.len()
    }

    /// Seal, write `path`, fsync, and open the result as a [`Run`]
    /// (validating our own output). At least one entry must have been
    /// added — empty runs are never written.
    pub fn finish(mut self, path: &Path) -> Result<Run> {
        self.seal_block();
        assert!(!self.blocks.is_empty(), "refusing to write an empty run");
        let mut footer = Vec::new();
        put_varint(&mut footer, self.digests.len() as u64);
        put_varint(&mut footer, self.digests[0].0);
        put_varint(&mut footer, self.digests[self.digests.len() - 1].0);
        put_varint(&mut footer, self.blocks.len() as u64);
        for b in &self.blocks {
            put_varint(&mut footer, b.offset);
            put_varint(&mut footer, b.len);
            put_varint(&mut footer, b.first);
            put_varint(&mut footer, b.last);
        }
        let mut bloom = Bloom::with_capacity(self.digests.len());
        for &(key, _) in &self.digests {
            bloom.insert(key);
        }
        bloom.encode(&mut footer);
        for &(key, digest) in &self.digests {
            put_varint(&mut footer, key);
            footer.extend_from_slice(&digest.to_le_bytes());
        }
        let crc = crc32(&footer).to_le_bytes();
        let len = (footer.len() as u32).to_le_bytes();
        self.data.extend_from_slice(&footer);
        self.data.extend_from_slice(&crc);
        self.data.extend_from_slice(&len);
        self.data.extend_from_slice(&SST_FOOTER_MAGIC);

        let mut file = OpenOptions::new().create_new(true).write(true).open(path)?;
        file.write_all(&self.data)?;
        file.sync_data()?;
        drop(file);
        let (run, _digests) = Run::open(path)?;
        Ok(run)
    }
}

/// An open, validated sorted-run file. Immutable; all reads go through
/// [`locate`](Run::locate) + [`read_block`](Run::read_block) or the
/// whole-run scans.
#[derive(Debug)]
pub struct Run {
    path: PathBuf,
    file: File,
    bytes: u64,
    entry_count: u64,
    min_key: Key,
    max_key: Key,
    blocks: Vec<BlockMeta>,
    bloom: Bloom,
}

impl Run {
    /// Open and fully validate a run file, returning the run plus its
    /// footer's `(key, state_digest)` pairs (ascending — what the LSM
    /// open feeds into its hash trees). Any structural damage — either
    /// magic, footer CRC, a block CRC, broken entry framing, key order,
    /// or index/fence/digest inconsistency — returns `Err`; the caller
    /// decides to [`quarantine`].
    pub fn open(path: &Path) -> Result<(Run, Vec<(Key, u64)>)> {
        let data = std::fs::read(path)?;
        if data.len() < SST_MAGIC.len() + TAIL_LEN {
            return Err(bad(path, "shorter than magic + tail"));
        }
        if data[..SST_MAGIC.len()] != SST_MAGIC {
            return Err(bad(path, "bad head magic"));
        }
        let tail = &data[data.len() - TAIL_LEN..];
        if tail[8..] != SST_FOOTER_MAGIC {
            return Err(bad(path, "bad tail magic"));
        }
        let footer_crc = u32::from_le_bytes(tail[..4].try_into().unwrap());
        let footer_len = u32::from_le_bytes(tail[4..8].try_into().unwrap()) as usize;
        let data_end = data
            .len()
            .checked_sub(TAIL_LEN + footer_len)
            .filter(|&end| end >= SST_MAGIC.len())
            .ok_or_else(|| bad(path, "footer length exceeds file"))?;
        let footer = &data[data_end..data_end + footer_len];
        if crc32(footer) != footer_crc {
            return Err(bad(path, "footer CRC mismatch"));
        }

        // parse the footer
        let mut pos = 0;
        let entry_count = get_varint(footer, &mut pos)?;
        let min_key = get_varint(footer, &mut pos)?;
        let max_key = get_varint(footer, &mut pos)?;
        let block_count = get_varint(footer, &mut pos)?;
        if entry_count == 0 || block_count == 0 || block_count > entry_count {
            return Err(bad(path, "empty or inconsistent entry/block counts"));
        }
        let mut blocks = Vec::with_capacity(block_count as usize);
        for _ in 0..block_count {
            let offset = get_varint(footer, &mut pos)?;
            let len = get_varint(footer, &mut pos)?;
            let first = get_varint(footer, &mut pos)?;
            let last = get_varint(footer, &mut pos)?;
            blocks.push(BlockMeta { offset, len, first, last });
        }
        let bloom = Bloom::decode(footer, &mut pos)?;
        let mut digests = Vec::with_capacity(entry_count as usize);
        for _ in 0..entry_count {
            let key = get_varint(footer, &mut pos)?;
            let bytes = crate::clocks::encoding::get_bytes(footer, &mut pos, 8)?;
            digests.push((key, u64::from_le_bytes(bytes.try_into().unwrap())));
        }
        crate::clocks::encoding::expect_end(footer, pos)?;

        // verify the data region against the index: contiguous coverage,
        // per-block CRC, entry framing, strict global key ascent, and
        // agreement with the fence and the digest key list — a
        // sequential scan, no state decoding
        let mut expect_offset = SST_MAGIC.len() as u64;
        let mut scanned_keys = 0usize;
        let mut prev_key: Option<Key> = None;
        for meta in &blocks {
            if meta.offset != expect_offset {
                return Err(bad(path, "index offsets are not contiguous"));
            }
            let start = meta.offset as usize;
            let end = start
                .checked_add(meta.len as usize)
                .filter(|&e| e <= data_end)
                .ok_or_else(|| bad(path, "block overruns the data region"))?;
            let entries = parse_block(path, &data[start..end])?;
            let (first, _) = entries.first().copied().ok_or_else(|| bad(path, "empty block"))?;
            let (last, _) = *entries.last().unwrap();
            if first != meta.first || last != meta.last {
                return Err(bad(path, "index fence disagrees with block contents"));
            }
            for &(key, _) in &entries {
                if prev_key.is_some_and(|p| p >= key) {
                    return Err(bad(path, "keys are not strictly ascending"));
                }
                if digests.get(scanned_keys).map(|d| d.0) != Some(key) {
                    return Err(bad(path, "digest keys disagree with block keys"));
                }
                prev_key = Some(key);
                scanned_keys += 1;
            }
            expect_offset = end as u64;
        }
        if expect_offset as usize != data_end {
            return Err(bad(path, "data region has bytes no block covers"));
        }
        if scanned_keys as u64 != entry_count {
            return Err(bad(path, "entry count disagrees with blocks"));
        }
        if digests[0].0 != min_key || digests[digests.len() - 1].0 != max_key {
            return Err(bad(path, "fence disagrees with digest keys"));
        }

        let bytes = data.len() as u64;
        drop(data);
        let file = File::open(path)?;
        let run = Run {
            path: path.to_path_buf(),
            file,
            bytes,
            entry_count,
            min_key,
            max_key,
            blocks,
            bloom,
        };
        Ok((run, digests))
    }

    /// File size in bytes.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Entries stored.
    pub fn entry_count(&self) -> u64 {
        self.entry_count
    }

    /// Key-range fence: smallest and largest key in the run.
    pub fn fence(&self) -> (Key, Key) {
        (self.min_key, self.max_key)
    }

    /// Number of data blocks.
    pub fn block_count(&self) -> usize {
        self.blocks.len()
    }

    /// The file this run lives in.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The block that could hold `key`, or `None` when the fence, the
    /// bloom filter, or the index rules it out — the "at most one block
    /// per overlapping run" guarantee of the read path.
    pub fn locate(&self, key: Key) -> Option<usize> {
        if key < self.min_key || key > self.max_key || !self.bloom.contains(key) {
            return None;
        }
        let idx = self.blocks.partition_point(|b| b.last < key);
        (idx < self.blocks.len() && self.blocks[idx].first <= key).then_some(idx)
    }

    /// Read one data block: `(key, state bytes)` entries, ascending.
    /// The block was CRC-verified at open; the CRC is re-checked here so
    /// bit rot *after* open still surfaces as an error, not garbage.
    pub fn read_block(&self, idx: usize) -> Result<Vec<(Key, Vec<u8>)>> {
        let meta = self.blocks[idx];
        let mut framed = vec![0u8; meta.len as usize];
        self.file.read_exact_at(&mut framed, meta.offset)?;
        parse_block(&self.path, &framed)
            .map(|entries| entries.into_iter().map(|(k, s)| (k, s.to_vec())).collect())
    }

    /// Visit every `(key, state bytes)` entry in key order (compaction,
    /// merged iteration, key snapshots). Sequential block reads.
    pub fn for_each_entry(&self, mut f: impl FnMut(Key, &[u8])) -> Result<()> {
        for idx in 0..self.blocks.len() {
            let meta = self.blocks[idx];
            let mut framed = vec![0u8; meta.len as usize];
            self.file.read_exact_at(&mut framed, meta.offset)?;
            for (key, state) in parse_block(&self.path, &framed)? {
                f(key, state);
            }
        }
        Ok(())
    }
}

/// Parse one framed block (`[varint body_len][crc][body]`), returning
/// `(key, state bytes)` slices into `framed`.
fn parse_block<'a>(path: &Path, framed: &'a [u8]) -> Result<Vec<(Key, &'a [u8])>> {
    let mut pos = 0;
    let body_len = get_varint(framed, &mut pos)? as usize;
    let crc_stored = u32::from_le_bytes(
        crate::clocks::encoding::get_bytes(framed, &mut pos, 4)?.try_into().unwrap(),
    );
    let body = crate::clocks::encoding::get_bytes(framed, &mut pos, body_len)?;
    if pos != framed.len() {
        return Err(bad(path, "block frame length disagrees with index"));
    }
    if crc32(body) != crc_stored {
        return Err(bad(path, "block CRC mismatch"));
    }
    let mut entries = Vec::new();
    let mut p = 0;
    while p < body.len() {
        let entry_len = get_varint(body, &mut p)? as usize;
        let payload = crate::clocks::encoding::get_bytes(body, &mut p, entry_len)?;
        let mut kp = 0;
        let key = get_varint(payload, &mut kp)?;
        entries.push((key, &payload[kp..]));
    }
    Ok(entries)
}

/// Rename a damaged run out of the live set (`<name>.quarantined`,
/// numbered on collision) so reopen never trips on it again but an
/// operator can still inspect the bytes. Returns the new path.
pub fn quarantine(path: &Path) -> Result<PathBuf> {
    let base = path.with_extension("sst.quarantined");
    let mut target = base.clone();
    let mut n = 1;
    while target.exists() {
        target = path.with_extension(format!("sst.quarantined{n}"));
        n += 1;
    }
    std::fs::rename(path, &target)?;
    Ok(target)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::temp_dir;

    fn state_bytes(key: Key) -> Vec<u8> {
        (0..(key % 13 + 1)).map(|j| ((key * 31 + j * 7) % 251) as u8).collect()
    }

    fn build(path: &Path, keys: &[Key], block_bytes: usize) -> Run {
        let mut w = RunWriter::new(block_bytes);
        for &k in keys {
            w.add(k, mix64(k ^ 1), &state_bytes(k));
        }
        w.finish(path).unwrap()
    }

    #[test]
    fn roundtrip_and_point_reads() {
        let dir = temp_dir("sst-roundtrip");
        let keys: Vec<Key> = (0..200).map(|i| i * 3 + 1).collect();
        let path = dir.join("run-00000000-0000.sst");
        let run = build(&path, &keys, 128);
        assert!(run.block_count() > 1, "fixture spans blocks");
        assert_eq!(run.entry_count(), 200);
        assert_eq!(run.fence(), (1, 598));
        for &k in &keys {
            let idx = run.locate(k).expect("present key locates");
            let entries = run.read_block(idx).unwrap();
            let i = entries.binary_search_by_key(&k, |e| e.0).expect("in block");
            assert_eq!(entries[i].1, state_bytes(k), "key {k}");
        }
        // absent keys: fence cuts outside, bloom+index cut inside
        assert_eq!(run.locate(0), None);
        assert_eq!(run.locate(599), None);
        let misses = (0..600u64)
            .filter(|k| k % 3 != 1)
            .filter(|&k| run.locate(k).is_some())
            .count();
        assert!(misses < 40, "bloom+index prune most absent keys, {misses} leaked");
        // whole-run scan sees every entry in order
        let mut seen = Vec::new();
        run.for_each_entry(|k, st| {
            assert_eq!(st, state_bytes(k));
            seen.push(k);
        })
        .unwrap();
        assert_eq!(seen, keys);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn open_returns_footer_digests() {
        let dir = temp_dir("sst-digests");
        let keys: Vec<Key> = (10..30).collect();
        let path = dir.join("run.sst");
        {
            let mut w = RunWriter::new(64);
            for &k in &keys {
                w.add(k, mix64(k ^ 1), &state_bytes(k));
            }
            w.finish(&path).unwrap();
        }
        let (_, digests) = Run::open(&path).unwrap();
        let expected: Vec<(Key, u64)> = keys.iter().map(|&k| (k, mix64(k ^ 1))).collect();
        assert_eq!(digests, expected);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[should_panic(expected = "strictly ascending")]
    fn writer_rejects_out_of_order_keys() {
        let mut w = RunWriter::new(64);
        w.add(5, 0, &[1]);
        w.add(5, 0, &[2]);
    }

    #[test]
    fn truncation_is_rejected_not_panicked() {
        let dir = temp_dir("sst-trunc");
        let path = dir.join("run.sst");
        build(&path, &(0..40).collect::<Vec<_>>(), 96);
        let pristine = std::fs::read(&path).unwrap();
        for cut in [0, 4, 9, pristine.len() / 2, pristine.len() - 1] {
            std::fs::write(&path, &pristine[..cut]).unwrap();
            assert!(Run::open(&path).is_err(), "cut at {cut} must be rejected");
        }
        std::fs::write(&path, &pristine).unwrap();
        assert!(Run::open(&path).is_ok(), "pristine bytes reopen");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn quarantine_renames_and_numbers() {
        let dir = temp_dir("sst-quarantine");
        let path = dir.join("run-00000001-0000.sst");
        std::fs::write(&path, b"damaged").unwrap();
        let q1 = quarantine(&path).unwrap();
        assert!(q1.to_string_lossy().ends_with(".sst.quarantined"));
        assert!(!path.exists());
        std::fs::write(&path, b"damaged again").unwrap();
        let q2 = quarantine(&path).unwrap();
        assert_ne!(q1, q2, "collision gets a numbered name");
        assert!(q1.exists() && q2.exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bloom_has_no_false_negatives() {
        let mut b = Bloom::with_capacity(500);
        for k in 0..500u64 {
            b.insert(k * 7);
        }
        for k in 0..500u64 {
            assert!(b.contains(k * 7));
        }
        let fp = (0..10_000u64).filter(|k| k % 7 != 0).filter(|&k| b.contains(k)).count();
        assert!(fp < 500, "false-positive rate stays low, got {fp}/10000");
    }
}
