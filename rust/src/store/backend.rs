//! The [`StorageBackend`] trait: where a [`KeyStore`]'s per-key states
//! actually live.
//!
//! A backend is a concurrent map from [`Key`] to the mechanism's per-key
//! state. All methods take `&self`: locking is the backend's private
//! concern, so a [`KeyStore`] can be shared across threads (`Arc`) and
//! two backends with different locking disciplines — one store-wide lock
//! vs. lock-striped shards — are interchangeable behind the same trait.
//!
//! The trait is deliberately *not* object-safe (the visitor methods are
//! generic): stores are monomorphized over their backend exactly like
//! they are over their [`Mechanism`], so the hot path pays no vtable.
//!
//! Implementations in this crate:
//!
//! * [`InMemoryBackend`](super::InMemoryBackend) — one flat map behind a
//!   single lock (the original seed layout; baseline in
//!   `benches/sharded_store.rs`);
//! * [`ShardedBackend`](super::ShardedBackend) — the key space split
//!   across power-of-two lock-striped shards, so operations on different
//!   keys rarely contend;
//! * [`DurableBackend`](super::DurableBackend) — the sharded map with a
//!   per-shard write-ahead log ([`super::wal`]), so a replica survives
//!   process death with at most its configured fsync window lost.
//!
//! [`KeyStore`]: super::KeyStore
//! [`Mechanism`]: crate::kernel::Mechanism

use std::fmt;

use super::wal::RecoveryReport;
use super::Key;
use crate::antientropy::merkle::ShardTree;
use crate::kernel::Mechanism;

/// A concurrent per-key state map for mechanism `M`.
///
/// Contract, for every implementation:
///
/// * a key that was never updated reads as absent (`None` in
///   [`with_state`](StorageBackend::with_state));
/// * [`update`](StorageBackend::update) materializes `M::State::default()`
///   for an absent key before calling the closure (the §4 kernel treats
///   "never written" and "empty state" identically);
/// * every key belongs to exactly one shard
///   (`shard_of(key) < shard_count()`), and
///   [`keys_in_shard`](StorageBackend::keys_in_shard) partitions
///   [`keys`](StorageBackend::keys);
/// * the partition is a pure function of the shard count: two backends
///   with equal `shard_count()` MUST agree on `shard_of` for every key
///   (in-tree backends use `key & (shard_count - 1)`); per-shard
///   anti-entropy relies on this to diff matching shards directly;
/// * each visitor runs under the internal lock covering the visited
///   key(s): closures must not call back into the same backend.
pub trait StorageBackend<M: Mechanism>: fmt::Debug + Send + Sync + 'static {
    /// Visit `key`'s state read-only; `None` when absent.
    fn with_state<R>(&self, key: Key, f: impl FnOnce(Option<&M::State>) -> R) -> R;

    /// Mutate `key`'s state in place, inserting a default state first when
    /// the key is absent.
    fn update<R>(&self, key: Key, f: impl FnOnce(&mut M::State) -> R) -> R;

    /// Apply `f` to each `(key, payload)` item, acquiring each internal
    /// lock at most once per batch — the lock-amortized path used by the
    /// batched replication fan-out ([`KeyStore::merge_batch`]).
    ///
    /// Items may be applied in any order *between* shards, but items of
    /// the same key are applied in slice order.
    ///
    /// [`KeyStore::merge_batch`]: super::KeyStore::merge_batch
    fn update_batch<T>(&self, items: &[(Key, T)], f: impl FnMut(&mut M::State, &T));

    /// Visit every stored `(key, state)` pair, one shard at a time.
    fn for_each(&self, f: impl FnMut(Key, &M::State));

    /// Number of keys stored.
    fn key_count(&self) -> usize;

    /// Number of shards (1 for unsharded backends).
    fn shard_count(&self) -> usize;

    /// The shard that owns `key` (always `< shard_count()`, defined for
    /// absent keys too).
    fn shard_of(&self, key: Key) -> usize;

    /// Snapshot of the keys currently stored in `shard`.
    fn keys_in_shard(&self, shard: usize) -> Vec<Key>;

    /// Destroy **all** state, durable storage included: the node rejoins
    /// empty and is refilled by its peers (the `Fault::Wipe` semantics —
    /// a disk that died).
    fn wipe(&self);

    /// Simulate process death followed by recovery: whatever the backend
    /// has not durably persisted is lost; the rest is rebuilt from
    /// durable storage. Volatile backends persist nothing, so their
    /// default is total loss — identical to [`wipe`](StorageBackend::wipe)
    /// — which is exactly what a process restart does to a RAM-only
    /// replica. [`DurableBackend`](super::DurableBackend) overrides this
    /// to keep its fsynced prefix.
    fn crash_restart(&self) -> RecoveryReport {
        self.wipe();
        RecoveryReport::default()
    }

    /// Bytes of durable log this backend holds (the `STATS wal_bytes=`
    /// figure); 0 for volatile backends.
    fn durable_bytes(&self) -> u64 {
        0
    }

    /// Snapshot of every stored key (shard by shard; no global order).
    fn keys(&self) -> Vec<Key> {
        let mut out = Vec::with_capacity(self.key_count());
        for s in 0..self.shard_count() {
            out.extend(self.keys_in_shard(s));
        }
        out
    }

    /// Clone of `key`'s state, or the default when absent — what a
    /// replica ships to a peer.
    fn state_clone(&self, key: Key) -> M::State {
        self.with_state(key, |st| st.cloned().unwrap_or_default())
    }

    /// Visit `shard`'s anti-entropy hash tree
    /// ([`crate::antientropy::merkle`]).
    ///
    /// In-tree backends override this to expose the tree they maintain
    /// incrementally on the write path (under the shard's stripe lock —
    /// the closure must not call back into the same backend). This
    /// default rebuilds a throwaway tree from the shard's current
    /// contents, so any conforming backend is merkle-diffable without
    /// opting in; it just pays O(shard) per call instead of O(1).
    fn with_merkle<R>(&self, shard: usize, f: impl FnOnce(&mut ShardTree) -> R) -> R {
        let mut tree = ShardTree::new();
        for key in self.keys_in_shard(shard) {
            self.with_state(key, |st| {
                if let Some(st) = st {
                    tree.record(key, M::state_digest(st));
                }
            });
        }
        f(&mut tree)
    }

    /// Root digest of `shard`'s hash tree (0 for an empty shard). Roots
    /// compose by wrapping addition: summing over shards gives a whole
    /// store's digest, comparable across different shard counts (see
    /// [`KeyStore::merkle_root`](super::KeyStore::merkle_root)).
    fn merkle_root(&self, shard: usize) -> u64 {
        self.with_merkle(shard, |tree| tree.root())
    }
}
