//! Write-ahead-logged backend: the sharded in-memory map of
//! [`ShardedBackend`](super::ShardedBackend) with log-ahead persistence
//! per shard, so a replica survives process death.
//!
//! Layout on disk: `<dir>/shard-<i>/segment-*.wal`, one
//! [`ShardWal`](super::wal::ShardWal) per shard. Each shard's map *and*
//! log live behind one mutex, so the record order in a shard's log is
//! exactly the mutation order of its keys — replay-in-order with
//! last-record-wins rebuilds the map precisely.
//!
//! Every mutation ([`StorageBackend::update`] /
//! [`StorageBackend::update_batch`]) appends the key's **post-state**
//! under the shard lock before the lock is released; by the time a
//! coordinator acks a write, the state is in the log (durably so under
//! [`FsyncPolicy::Always`](super::wal::FsyncPolicy)). Reads never touch
//! the log.
//!
//! I/O errors on the mutation path panic: the [`StorageBackend`]
//! mutation API is deliberately infallible (the §4 kernel never fails),
//! and a replica whose disk is gone *should* die — the cluster already
//! treats a dead replica correctly (sloppy quorum, hints, anti-entropy),
//! whereas silently dropping persistence would turn the next crash into
//! undetected data loss.
//!
//! Crash semantics (the `Fault::Restart` / `Fault::Wipe` pair):
//!
//! * [`crash_restart`](StorageBackend::crash_restart) — simulate process
//!   death and recovery: truncate each shard's log to its durable
//!   watermark (what a real power loss leaves), then replay from disk.
//!   Acknowledged-but-unsynced writes vanish *at this node*; hinted
//!   handoff and anti-entropy re-deliver them from the rest of the
//!   cluster.
//! * [`wipe`](StorageBackend::wipe) — total state loss (disk died): the
//!   node rejoins empty and is refilled entirely by its peers.

use std::collections::HashMap;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use super::backend::StorageBackend;
use super::wal::{RecoveryReport, ShardWal, WalOptions};
use super::Key;
use crate::antientropy::merkle::ShardTree;
use crate::clocks::encoding::{expect_end, get_varint, put_varint};
use crate::kernel::DurableMechanism;

/// Default shard count for durable backends — fewer than the in-memory
/// default (64) because every shard is a directory of real files.
pub const DEFAULT_DURABLE_SHARDS: usize = 8;

struct DurableShard<M: DurableMechanism> {
    map: HashMap<Key, M::State>,
    /// Anti-entropy hash tree over `map`; maintained incrementally under
    /// the shard lock, rebuilt from the replayed map on open (the WAL
    /// never stores digests — they are derivable).
    tree: ShardTree,
    wal: ShardWal,
    /// Encode scratch, reused across appends.
    buf: Vec<u8>,
}

impl<M: DurableMechanism> DurableShard<M> {
    /// Open the shard dir, replaying the log into a fresh map and
    /// rebuilding the hash tree from the recovered states.
    fn open(dir: &Path, opts: WalOptions) -> crate::Result<(DurableShard<M>, RecoveryReport)> {
        let mut map = HashMap::new();
        let (wal, report) = ShardWal::open(dir, opts, |payload| {
            let mut pos = 0;
            let key = get_varint(payload, &mut pos)?;
            let state = M::decode_state(payload, &mut pos)?;
            expect_end(payload, pos)?;
            map.insert(key, state); // physical log: last record wins
            Ok(())
        })?;
        let tree = ShardTree::rebuild(map.iter().map(|(&k, st)| (k, M::state_digest(st))));
        Ok((DurableShard { map, tree, wal, buf: Vec::new() }, report))
    }

    /// Record payload for `(key, state)`.
    fn payload(buf: &mut Vec<u8>, key: Key, state: &M::State) {
        buf.clear();
        put_varint(buf, key);
        M::encode_state(state, buf);
    }

    /// Append `key`'s current state to the log (and its `digest` — already
    /// computed by the caller's no-op check — to the hash tree), rolling
    /// (and compacting when mostly dead) as needed. Runs under the shard
    /// lock, so the log order is the mutation order.
    fn log_key(&mut self, key: Key, digest: u64) {
        let state = self.map.get(&key).expect("logged key was just updated");
        self.tree.record(key, digest);
        Self::payload(&mut self.buf, key, state);
        self.wal.append(&self.buf).expect("WAL append failed (see module docs)");
        if self.wal.needs_roll() {
            let snapshot = if self.wal.live_fraction_low(self.map.len()) {
                let mut payloads = Vec::with_capacity(self.map.len());
                for (k, st) in &self.map {
                    // encode straight into the Vec that is pushed — no
                    // per-key copy of the encoded record
                    let mut payload = Vec::new();
                    Self::payload(&mut payload, *k, st);
                    payloads.push(payload);
                }
                Some(payloads)
            } else {
                None
            };
            self.wal
                .roll(snapshot.as_deref())
                .expect("WAL roll failed (see module docs)");
        }
    }
}

/// See module docs.
pub struct DurableBackend<M: DurableMechanism> {
    shards: Box<[Mutex<DurableShard<M>>]>,
    mask: u64,
    dir: PathBuf,
    opts: WalOptions,
    report: RecoveryReport,
}

impl<M: DurableMechanism> DurableBackend<M> {
    /// Open (creating if absent) a durable backend rooted at `dir` with
    /// `shards` stripes (rounded up to a power of two), replaying every
    /// shard log. Recovery truncates torn tails and records what it
    /// discarded in [`recovery_report`](DurableBackend::recovery_report).
    pub fn open(
        dir: impl Into<PathBuf>,
        shards: usize,
        opts: WalOptions,
    ) -> crate::Result<DurableBackend<M>> {
        let dir = dir.into();
        let n = shards.max(1).next_power_of_two();
        let mut report = RecoveryReport::default();
        let mut built = Vec::with_capacity(n);
        for i in 0..n {
            let shard_dir = dir.join(format!("shard-{i:03}"));
            let (shard, shard_report) = DurableShard::open(&shard_dir, opts)?;
            report.absorb(&shard_report);
            built.push(Mutex::new(shard));
        }
        Ok(DurableBackend {
            shards: built.into_boxed_slice(),
            mask: (n - 1) as u64,
            dir,
            opts,
            report,
        })
    }

    #[inline]
    fn idx(&self, key: Key) -> usize {
        (key & self.mask) as usize
    }

    /// The backend's root directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// What the opening replay found (and discarded).
    pub fn recovery_report(&self) -> &RecoveryReport {
        &self.report
    }

    /// Fsync every shard log (a clean-shutdown barrier).
    pub fn flush(&self) -> crate::Result<()> {
        for shard in self.shards.iter() {
            shard.lock().unwrap().wal.sync()?;
        }
        Ok(())
    }

    /// Bytes of payload state held resident in RAM (the encoded size of
    /// every in-memory state). For this backend that is the *whole*
    /// dataset — the O(dataset) memory footprint `benches/storage.rs`
    /// contrasts with [`LsmBackend`](super::LsmBackend)'s bounded
    /// memtable + cache.
    pub fn resident_bytes(&self) -> u64 {
        let mut total = 0u64;
        let mut buf = Vec::new();
        for shard in self.shards.iter() {
            let guard = shard.lock().unwrap();
            for (k, st) in guard.map.iter() {
                buf.clear();
                DurableShard::<M>::payload(&mut buf, *k, st);
                total += buf.len() as u64;
            }
        }
        total
    }
}

impl<M: DurableMechanism> fmt::Debug for DurableBackend<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let keys: usize = self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum();
        f.debug_struct("DurableBackend")
            .field("dir", &self.dir)
            .field("shards", &self.shards.len())
            .field("keys", &keys)
            .field("wal_bytes", &self.durable_bytes())
            .finish()
    }
}

impl<M: DurableMechanism> StorageBackend<M> for DurableBackend<M> {
    fn with_state<R>(&self, key: Key, f: impl FnOnce(Option<&M::State>) -> R) -> R {
        f(self.shards[self.idx(key)].lock().unwrap().map.get(&key))
    }

    fn update<R>(&self, key: Key, f: impl FnOnce(&mut M::State) -> R) -> R {
        let mut guard = self.shards[self.idx(key)].lock().unwrap();
        let shard = &mut *guard;
        // skip the log when the closure turns out to be a no-op on an
        // existing key (anti-entropy / read-repair re-delivering covered
        // state): its post-state is already in the log. A key the update
        // *materialized* (before == None) always logs, even when the
        // closure leaves the default state untouched — the key is now
        // observable and must survive a restart.
        let before = shard.map.get(&key).map(|st| M::state_digest(st));
        let r = f(shard.map.entry(key).or_default());
        let after = M::state_digest(&shard.map[&key]);
        if before != Some(after) {
            shard.log_key(key, after);
        }
        r
    }

    fn update_batch<T>(&self, items: &[(Key, T)], mut f: impl FnMut(&mut M::State, &T)) {
        // sort item indices by shard, then take each shard lock once per
        // run (the same amortization as ShardedBackend::update_batch);
        // each item is logged under the lock right after its mutation
        let mut order: Vec<usize> = (0..items.len()).collect();
        order.sort_by_key(|&i| self.idx(items[i].0));
        let mut run = 0;
        while run < order.len() {
            let shard_idx = self.idx(items[order[run]].0);
            let mut guard = self.shards[shard_idx].lock().unwrap();
            let shard = &mut *guard;
            while run < order.len() {
                let (key, payload) = &items[order[run]];
                if self.idx(*key) != shard_idx {
                    break;
                }
                // same no-op skip as `update` (see there)
                let before = shard.map.get(key).map(|st| M::state_digest(st));
                f(shard.map.entry(*key).or_default(), payload);
                let after = M::state_digest(&shard.map[key]);
                if before != Some(after) {
                    shard.log_key(*key, after);
                }
                run += 1;
            }
        }
    }

    fn for_each(&self, mut f: impl FnMut(Key, &M::State)) {
        for shard in self.shards.iter() {
            for (k, st) in shard.lock().unwrap().map.iter() {
                f(*k, st);
            }
        }
    }

    fn key_count(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn shard_of(&self, key: Key) -> usize {
        self.idx(key)
    }

    fn keys_in_shard(&self, shard: usize) -> Vec<Key> {
        self.shards[shard].lock().unwrap().map.keys().copied().collect()
    }

    fn wipe(&self) {
        for shard in self.shards.iter() {
            let mut guard = shard.lock().unwrap();
            guard.map.clear();
            guard.tree.clear();
            guard.wal.wipe().expect("WAL wipe failed (see module docs)");
        }
    }

    fn crash_restart(&self) -> RecoveryReport {
        let mut report = RecoveryReport::default();
        for shard in self.shards.iter() {
            let mut guard = shard.lock().unwrap();
            guard
                .wal
                .simulate_power_loss()
                .expect("WAL truncate failed (see module docs)");
            let dir = guard.wal.dir().to_path_buf();
            let (fresh, shard_report) =
                DurableShard::open(&dir, self.opts).expect("WAL replay failed (see module docs)");
            *guard = fresh;
            report.absorb(&shard_report);
        }
        report
    }

    fn durable_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().wal.bytes()).sum()
    }

    fn with_merkle<R>(&self, shard: usize, f: impl FnOnce(&mut ShardTree) -> R) -> R {
        f(&mut self.shards[shard].lock().unwrap().tree)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clocks::Actor;
    use crate::kernel::mechs::DvvMech;
    use crate::kernel::{Val, WriteMeta};
    use crate::store::wal::FsyncPolicy;
    use crate::store::KeyStore;
    use crate::testkit::temp_dir;

    fn store(dir: &Path, opts: WalOptions) -> KeyStore<DvvMech, DurableBackend<DvvMech>> {
        KeyStore::with_backend(
            DvvMech,
            DurableBackend::open(dir, 4, opts).unwrap(),
        )
    }

    fn meta() -> WriteMeta {
        WriteMeta::basic(Actor::client(0))
    }

    #[test]
    fn writes_survive_close_and_reopen() {
        let dir = temp_dir("durable-reopen");
        let opts = WalOptions::default();
        {
            let s = store(&dir, opts);
            for k in 0..32u64 {
                let (_, ctx) = s.read(k);
                s.write(k, &ctx, Val::new(k + 1, 8), Actor::server(0), &meta());
            }
            assert_eq!(s.key_count(), 32);
            assert!(s.backend().durable_bytes() > 0);
        }
        let s = store(&dir, opts);
        assert_eq!(s.backend().recovery_report().records, 32);
        assert_eq!(s.key_count(), 32);
        for k in 0..32u64 {
            assert_eq!(s.values(k), vec![Val::new(k + 1, 8)], "key {k}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sibling_states_replay_exactly() {
        let dir = temp_dir("durable-siblings");
        let opts = WalOptions::default();
        let expected;
        {
            let s = store(&dir, opts);
            let empty = s.read(7).1;
            s.write(7, &empty, Val::new(1, 4), Actor::server(0), &meta());
            s.write(7, &empty, Val::new(2, 4), Actor::server(1), &meta());
            expected = s.state(7);
            assert_eq!(s.sibling_count(7), 2);
        }
        let s = store(&dir, opts);
        assert_eq!(s.state(7), expected, "recovered state is byte-identical");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn crash_restart_loses_only_the_unsynced_tail() {
        let dir = temp_dir("durable-crash");
        // sync only on explicit flush: everything unflushed is lost
        let opts = WalOptions { fsync: FsyncPolicy::Never, ..Default::default() };
        let s = store(&dir, opts);
        for k in 0..8u64 {
            let (_, ctx) = s.read(k);
            s.write(k, &ctx, Val::new(k + 1, 8), Actor::server(0), &meta());
        }
        s.backend().flush().unwrap(); // durable watermark: 8 keys
        for k in 8..16u64 {
            let (_, ctx) = s.read(k);
            s.write(k, &ctx, Val::new(k + 1, 8), Actor::server(0), &meta());
        }
        let report = s.backend().crash_restart();
        assert_eq!(report.records, 8, "only the flushed prefix recovers");
        assert_eq!(s.key_count(), 8);
        for k in 0..8u64 {
            assert_eq!(s.values(k).len(), 1, "synced key {k} survived");
        }
        for k in 8..16u64 {
            assert!(s.values(k).is_empty(), "unsynced key {k} lost");
        }
        // the store keeps working after recovery
        let (_, ctx) = s.read(99);
        s.write(99, &ctx, Val::new(500, 8), Actor::server(0), &meta());
        assert_eq!(s.values(99).len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fsync_always_survives_crash_completely() {
        let dir = temp_dir("durable-always");
        let opts = WalOptions { fsync: FsyncPolicy::Always, ..Default::default() };
        let s = store(&dir, opts);
        for k in 0..10u64 {
            let (_, ctx) = s.read(k);
            s.write(k, &ctx, Val::new(k + 1, 8), Actor::server(0), &meta());
        }
        let report = s.backend().crash_restart();
        assert_eq!(report.records, 10);
        assert_eq!(s.key_count(), 10, "fsync-always has no loss window");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn wipe_clears_disk_and_memory() {
        let dir = temp_dir("durable-wipe");
        let opts = WalOptions::default();
        let s = store(&dir, opts);
        for k in 0..8u64 {
            let (_, ctx) = s.read(k);
            s.write(k, &ctx, Val::new(k + 1, 8), Actor::server(0), &meta());
        }
        s.backend().wipe();
        assert_eq!(s.key_count(), 0);
        let report = s.backend().crash_restart();
        assert_eq!(report.records, 0, "nothing on disk either");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn hot_key_log_compacts() {
        let dir = temp_dir("durable-compact");
        let opts = WalOptions { segment_bytes: 512, fsync: FsyncPolicy::Never };
        let s = store(&dir, opts);
        // hammer one key: without compaction the log would hold every
        // post-state ever written
        for i in 0..400u64 {
            let (_, ctx) = s.read(3);
            s.write(3, &ctx, Val::new(i + 1, 8), Actor::server(0), &meta());
        }
        let bytes = s.backend().durable_bytes();
        assert!(
            bytes < 4096,
            "compaction kept the log near one live record, got {bytes} bytes"
        );
        // and the compacted log still recovers the current state
        let expected = s.state(3);
        drop(s);
        let s = store(&dir, opts);
        assert_eq!(s.state(3), expected);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn noop_merges_leave_durable_bytes_flat() {
        let dir = temp_dir("durable-noop");
        let opts = WalOptions::default();
        let s = store(&dir, opts);
        for k in 0..20u64 {
            let (_, ctx) = s.read(k);
            s.write(k, &ctx, Val::new(k + 1, 8), Actor::server(0), &meta());
        }
        let items: Vec<(Key, _)> = s.keys().map(|k| (k, s.state(k))).collect();
        let before = s.backend().durable_bytes();
        // N quiesced anti-entropy rounds: every merge re-delivers state
        // the replica already covers, via both the batch and the single
        // paths — neither may append
        for _ in 0..10 {
            s.merge_batch(&items);
            for (k, st) in &items {
                s.merge_key(*k, st);
            }
        }
        assert_eq!(
            s.backend().durable_bytes(),
            before,
            "convergent merge rounds must not grow the log"
        );
        // a genuinely new state still logs
        let (_, ctx) = s.read(0);
        s.write(0, &ctx, Val::new(999, 8), Actor::server(1), &meta());
        assert!(s.backend().durable_bytes() > before, "real change is logged");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn batch_merges_are_logged() {
        let dir = temp_dir("durable-batch");
        let opts = WalOptions::default();
        let src = KeyStore::new(DvvMech);
        let empty = src.read(0).1;
        for k in 0..20u64 {
            src.write(k, &empty, Val::new(k + 1, 0), Actor::server(1), &meta());
        }
        let items: Vec<(Key, _)> = src.keys().map(|k| (k, src.state(k))).collect();
        {
            let s = store(&dir, opts);
            s.merge_batch(&items);
            assert_eq!(s.key_count(), 20);
        }
        let s = store(&dir, opts);
        assert_eq!(s.key_count(), 20, "batched mutations hit the log too");
        for (k, st) in &items {
            assert_eq!(s.state(*k), *st);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
