//! Lock-striped backend: the key space split across power-of-two shards.

use std::collections::HashMap;
use std::fmt;
use std::sync::RwLock;

use super::backend::StorageBackend;
use super::Key;
use crate::antientropy::merkle::ShardTree;
use crate::kernel::Mechanism;

/// Default stripe count — enough that a handful of server threads on a
/// skewed (Zipf) workload rarely collide, small enough that aggregating
/// per-shard accounting stays cheap.
pub const DEFAULT_SHARDS: usize = 64;

/// One stripe: its key→state map plus the anti-entropy hash tree over
/// those keys, mutated together under the stripe lock so the tree never
/// lags the map.
struct Shard<M: Mechanism> {
    map: HashMap<Key, M::State>,
    tree: ShardTree,
}

impl<M: Mechanism> Shard<M> {
    fn empty() -> Shard<M> {
        Shard { map: HashMap::new(), tree: ShardTree::new() }
    }

    fn record(&mut self, key: Key) {
        // only called right after `map.entry(key)` materialized the state
        let st = &self.map[&key];
        self.tree.record(key, M::state_digest(st));
    }
}

/// The key space partitioned into `2^k` lock-striped shards.
///
/// A key belongs to shard `key & (shards - 1)` — a power-of-two mask on
/// the existing numeric [`Key`]. Both key populations the crate produces
/// are uniform under this mask: the TCP server pre-hashes string keys
/// ([`crate::cluster::ring::hash_str`]) and the simulator uses dense
/// numeric keys. Operations on keys in different shards take different
/// locks and proceed in parallel; reads on the same shard share its
/// reader lock.
///
/// Metadata and sibling accounting ([`StorageBackend::for_each`]) is
/// aggregated on demand, shard by shard, so no global lock ever exists.
pub struct ShardedBackend<M: Mechanism> {
    shards: Box<[RwLock<Shard<M>>]>,
    mask: u64,
}

impl<M: Mechanism> ShardedBackend<M> {
    /// Backend with [`DEFAULT_SHARDS`] stripes.
    pub fn new() -> ShardedBackend<M> {
        ShardedBackend::with_shards(DEFAULT_SHARDS)
    }

    /// Backend with at least `shards` stripes (rounded up to a power of
    /// two; minimum 1).
    pub fn with_shards(shards: usize) -> ShardedBackend<M> {
        let n = shards.max(1).next_power_of_two();
        let shards = (0..n).map(|_| RwLock::new(Shard::empty())).collect();
        ShardedBackend { shards, mask: (n - 1) as u64 }
    }

    #[inline]
    fn idx(&self, key: Key) -> usize {
        (key & self.mask) as usize
    }

    /// Number of keys currently stored in one shard (diagnostics; the
    /// balance check in this module's tests).
    pub fn shard_len(&self, shard: usize) -> usize {
        self.shards[shard].read().unwrap().map.len()
    }
}

impl<M: Mechanism> Default for ShardedBackend<M> {
    fn default() -> Self {
        ShardedBackend::new()
    }
}

impl<M: Mechanism> Clone for ShardedBackend<M> {
    fn clone(&self) -> Self {
        ShardedBackend {
            shards: self
                .shards
                .iter()
                .map(|s| {
                    let g = s.read().unwrap();
                    RwLock::new(Shard { map: g.map.clone(), tree: g.tree.clone() })
                })
                .collect(),
            mask: self.mask,
        }
    }
}

impl<M: Mechanism> fmt::Debug for ShardedBackend<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let keys: usize = self.shards.iter().map(|s| s.read().unwrap().map.len()).sum();
        f.debug_struct("ShardedBackend")
            .field("shards", &self.shards.len())
            .field("keys", &keys)
            .finish()
    }
}

impl<M: Mechanism> StorageBackend<M> for ShardedBackend<M> {
    fn with_state<R>(&self, key: Key, f: impl FnOnce(Option<&M::State>) -> R) -> R {
        f(self.shards[self.idx(key)].read().unwrap().map.get(&key))
    }

    fn update<R>(&self, key: Key, f: impl FnOnce(&mut M::State) -> R) -> R {
        let mut g = self.shards[self.idx(key)].write().unwrap();
        let r = f(g.map.entry(key).or_default());
        g.record(key);
        r
    }

    fn update_batch<T>(&self, items: &[(Key, T)], mut f: impl FnMut(&mut M::State, &T)) {
        if let [(key, payload)] = items {
            // single item: no grouping needed, one stripe lock
            let mut g = self.shards[self.idx(*key)].write().unwrap();
            f(g.map.entry(*key).or_default(), payload);
            g.record(*key);
            return;
        }
        // sort item indices by shard, then take each stripe lock once per
        // run — O(items log items) work, no per-shard allocation
        let mut order: Vec<usize> = (0..items.len()).collect();
        order.sort_by_key(|&i| self.idx(items[i].0));
        let mut run = 0;
        while run < order.len() {
            let shard = self.idx(items[order[run]].0);
            let mut g = self.shards[shard].write().unwrap();
            while run < order.len() {
                let (key, payload) = &items[order[run]];
                if self.idx(*key) != shard {
                    break;
                }
                f(g.map.entry(*key).or_default(), payload);
                g.record(*key);
                run += 1;
            }
        }
    }

    fn for_each(&self, mut f: impl FnMut(Key, &M::State)) {
        for shard in self.shards.iter() {
            for (k, st) in shard.read().unwrap().map.iter() {
                f(*k, st);
            }
        }
    }

    fn key_count(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().map.len()).sum()
    }

    fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn shard_of(&self, key: Key) -> usize {
        self.idx(key)
    }

    fn keys_in_shard(&self, shard: usize) -> Vec<Key> {
        self.shards[shard].read().unwrap().map.keys().copied().collect()
    }

    fn wipe(&self) {
        for shard in self.shards.iter() {
            let mut g = shard.write().unwrap();
            g.map.clear();
            g.tree.clear();
        }
    }

    fn with_merkle<R>(&self, shard: usize, f: impl FnOnce(&mut ShardTree) -> R) -> R {
        f(&mut self.shards[shard].write().unwrap().tree)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::mechs::DvvMech;

    type B = ShardedBackend<DvvMech>;

    #[test]
    fn shard_count_rounds_to_power_of_two() {
        assert_eq!(B::with_shards(1).shard_count(), 1);
        assert_eq!(B::with_shards(5).shard_count(), 8);
        assert_eq!(B::with_shards(64).shard_count(), 64);
        assert_eq!(B::with_shards(0).shard_count(), 1);
    }

    #[test]
    fn keys_partition_across_shards() {
        let b = B::with_shards(8);
        for k in 0..800u64 {
            b.update(k, |_st| {});
        }
        assert_eq!(b.key_count(), 800);
        let mut total = 0;
        for s in 0..8 {
            let keys = b.keys_in_shard(s);
            for &k in &keys {
                assert_eq!(b.shard_of(k), s);
            }
            // dense keys under a power-of-two mask land perfectly evenly
            assert_eq!(keys.len(), 100, "shard {s}");
            assert_eq!(b.shard_len(s), 100);
            total += keys.len();
        }
        assert_eq!(total, 800);
    }

    #[test]
    fn update_batch_touches_every_item() {
        let b = B::with_shards(4);
        let items: Vec<(u64, ())> = (0..100).map(|k| (k % 10, ())).collect();
        let mut applied = 0;
        b.update_batch(&items, |_st, ()| applied += 1);
        assert_eq!(applied, 100);
        assert_eq!(b.key_count(), 10);
    }

    #[test]
    fn absent_key_reads_as_none_after_other_writes() {
        let b = B::with_shards(4);
        b.update(1, |_st| {});
        assert!(b.with_state(2, |st| st.is_none()));
        assert!(b.with_state(1, |st| st.is_some()));
    }

    #[test]
    fn incremental_trees_match_default_rebuild() {
        use crate::kernel::Mechanism as _;
        let b = B::with_shards(4);
        let mech = DvvMech;
        let meta = crate::kernel::WriteMeta::basic(crate::clocks::Actor::client(0));
        for k in 0..64u64 {
            b.update(k, |st| {
                mech.write(
                    st,
                    &Default::default(),
                    crate::kernel::Val::new(k + 1, 0),
                    crate::clocks::Actor::server(0),
                    &meta,
                );
            });
        }
        for s in 0..b.shard_count() {
            let incremental = b.merkle_root(s);
            let rebuilt = ShardTree::rebuild(b.keys_in_shard(s).into_iter().map(|k| {
                (k, b.with_state(k, |st| DvvMech::state_digest(st.unwrap())))
            }))
            .root();
            assert_eq!(incremental, rebuilt, "shard {s}");
            assert_ne!(incremental, 0, "shard {s} holds keys");
        }
        b.wipe();
        for s in 0..b.shard_count() {
            assert_eq!(b.merkle_root(s), 0);
        }
    }
}
