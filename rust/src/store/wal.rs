//! Per-shard segmented write-ahead log: the durable half of
//! [`DurableBackend`](super::DurableBackend).
//!
//! # On-disk format
//!
//! A shard's log is a directory of numbered **segments**
//! (`segment-00000000.wal`, `segment-00000001.wal`, …). Each segment
//! opens with the 8-byte [`SEGMENT_MAGIC`] and then holds a sequence of
//! self-delimiting records:
//!
//! ```text
//! [varint payload_len][u32 LE crc32(payload)][payload]
//! payload = [varint key][mechanism state encoding]
//! ```
//!
//! Varints are the same LEB128 encoding the wire protocol uses
//! ([`crate::clocks::encoding`]); the CRC is IEEE 802.3 (the polynomial
//! of zlib/gzip). Records are **physical** (full post-write state, last
//! record per key wins on replay) rather than logical operations: the
//! [`StorageBackend`](super::StorageBackend) mutation API is an opaque
//! closure, so the post-state is the only thing the backend can know —
//! and replay becomes a simple in-order scan with no mechanism-specific
//! redo logic.
//!
//! # Fsync policy
//!
//! [`FsyncPolicy`] trades write latency against the crash-loss window:
//! `Always` fsyncs every append, `EveryN(n)` every `n`-th, `Never` only
//! on segment rolls. The log tracks its **synced watermark** — the byte
//! offset up to which the current segment is known durable (older
//! segments are fsynced when rolled, so they are durable end-to-end).
//! Simulated process death ([`ShardWal::simulate_power_loss`], used by
//! `DurableBackend::crash_restart`) truncates the current segment to
//! that watermark: exactly the bytes a real crash could lose.
//!
//! # Recovery
//!
//! [`ShardWal::open`] replays segments in order, handing each record's
//! payload to the caller. Replay stops at the first invalid record — a
//! truncated length, a short body, a CRC mismatch, or a payload the
//! state codec rejects — **truncates the log to the longest valid
//! prefix** (cutting the torn segment and deleting any segments after
//! it), and reports the discarded byte count in the returned
//! [`RecoveryReport`]. Replay never panics on any byte sequence
//! (`rust/tests/wal_recovery.rs` sweeps truncations and corruptions).
//!
//! # Compaction
//!
//! Appends are state snapshots, so a hot key makes most of the log dead
//! weight. When a segment fills and the live fraction (distinct keys /
//! records logged) has dropped below half, the roll writes a **snapshot
//! segment** — one record per live key — fsyncs it, and deletes every
//! older segment; otherwise the roll just starts a fresh segment.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::clocks::encoding::get_varint;
use crate::error::{Error, Result};

/// First 8 bytes of every segment file (format name + version).
pub const SEGMENT_MAGIC: [u8; 8] = *b"DVVWAL01";

/// Upper bound on a record's payload length. A length field promising
/// more is corruption by definition — rejected before any allocation.
pub const MAX_RECORD_LEN: u64 = 1 << 26;

/// When (and how often) appends reach the disk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// Fsync after every append: zero crash-loss window, slowest.
    Always,
    /// Fsync every `n`-th append: bounded loss window, amortized cost.
    EveryN(u32),
    /// Fsync only on segment rolls: fastest, largest loss window.
    Never,
}

impl FsyncPolicy {
    /// Parse a CLI/config spelling: `always`, `never`, a bare number
    /// `n`, or `every<n>` (what [`Display`](Self#impl-Display-for-FsyncPolicy)
    /// prints, so printed policies round-trip); `1` ≡ `always`.
    pub fn parse(s: &str) -> Result<FsyncPolicy> {
        match s {
            "always" => Ok(FsyncPolicy::Always),
            "never" => Ok(FsyncPolicy::Never),
            other => {
                let n = other.strip_prefix("every").unwrap_or(other);
                match n.parse::<u32>() {
                    Ok(0) => Err(Error::Config("fsync every-0 is meaningless".into())),
                    Ok(1) => Ok(FsyncPolicy::Always),
                    Ok(n) => Ok(FsyncPolicy::EveryN(n)),
                    Err(_) => Err(Error::Config(format!(
                        "bad fsync policy {s:?}; expected always|never|<n>|every<n>"
                    ))),
                }
            }
        }
    }
}

impl std::fmt::Display for FsyncPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FsyncPolicy::Always => f.write_str("always"),
            FsyncPolicy::EveryN(n) => write!(f, "every{n}"),
            FsyncPolicy::Never => f.write_str("never"),
        }
    }
}

/// Tunables for one shard log (and, via
/// [`DurableBackend::open`](super::DurableBackend::open), a whole
/// backend).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WalOptions {
    /// Roll to a new segment once the current one exceeds this size.
    pub segment_bytes: u64,
    /// Fsync cadence.
    pub fsync: FsyncPolicy,
}

impl Default for WalOptions {
    fn default() -> Self {
        WalOptions { segment_bytes: 1 << 20, fsync: FsyncPolicy::EveryN(64) }
    }
}

/// What recovery found (and discarded). Reports aggregate across shards
/// via [`absorb`](RecoveryReport::absorb).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// Records replayed into the store.
    pub records: u64,
    /// Bytes past the longest valid prefix, truncated away (torn tail,
    /// corrupt record, or orphaned later segments).
    pub discarded_bytes: u64,
    /// Segment files encountered (replayed or discarded).
    pub segments: u64,
    /// Sorted-run files an LSM open set aside because their checksums or
    /// framing failed validation ([`crate::store::LsmBackend`]); the
    /// damaged file is renamed `*.quarantined`, never deleted, so an
    /// operator can inspect it. Always 0 for the plain WAL backends.
    pub quarantined_runs: u64,
    /// Whether any truncation happened (`discarded_bytes > 0`).
    pub truncated: bool,
}

impl RecoveryReport {
    /// Fold another shard's report into this one.
    pub fn absorb(&mut self, other: &RecoveryReport) {
        self.records += other.records;
        self.discarded_bytes += other.discarded_bytes;
        self.segments += other.segments;
        self.quarantined_runs += other.quarantined_runs;
        self.truncated |= other.truncated;
    }
}

/// CRC-32 (IEEE 802.3), table-driven, no dependencies.
pub fn crc32(data: &[u8]) -> u32 {
    const fn table() -> [u32; 256] {
        let mut t = [0u32; 256];
        let mut i = 0;
        while i < 256 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            t[i] = c;
            i += 1;
        }
        t
    }
    static TABLE: [u32; 256] = table();
    let mut crc = !0u32;
    for &b in data {
        crc = (crc >> 8) ^ TABLE[((crc ^ u32::from(b)) & 0xff) as usize];
    }
    !crc
}

fn segment_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("segment-{seq:08}.wal"))
}

/// Segment sequence numbers present in `dir`, ascending.
fn segment_seqs(dir: &Path) -> Result<Vec<u64>> {
    let mut seqs = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let name = entry?.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(seq) = name
            .strip_prefix("segment-")
            .and_then(|rest| rest.strip_suffix(".wal"))
            .and_then(|digits| digits.parse::<u64>().ok())
        {
            seqs.push(seq);
        }
    }
    seqs.sort_unstable();
    Ok(seqs)
}

/// Scan one segment's bytes, calling `on_record` per valid payload.
/// Returns `(valid_prefix_len, records)`; a prefix shorter than the file
/// means the record at that offset (and everything after) is invalid.
fn scan_segment(bytes: &[u8], mut on_record: impl FnMut(&[u8]) -> Result<()>) -> (u64, u64) {
    if bytes.len() < SEGMENT_MAGIC.len() || bytes[..SEGMENT_MAGIC.len()] != SEGMENT_MAGIC {
        return (0, 0);
    }
    let mut pos = SEGMENT_MAGIC.len();
    let mut records = 0u64;
    loop {
        let record_start = pos;
        if pos == bytes.len() {
            return (record_start as u64, records);
        }
        let mut p = pos;
        let Ok(len) = get_varint(bytes, &mut p) else {
            return (record_start as u64, records); // torn length field
        };
        if len > MAX_RECORD_LEN || (len as usize) + 4 > bytes.len() - p {
            return (record_start as u64, records); // absurd or short body
        }
        let crc_stored = u32::from_le_bytes(bytes[p..p + 4].try_into().unwrap());
        let payload = &bytes[p + 4..p + 4 + len as usize];
        if crc32(payload) != crc_stored {
            return (record_start as u64, records); // bit rot / torn write
        }
        if on_record(payload).is_err() {
            return (record_start as u64, records); // codec rejected it
        }
        records += 1;
        pos = p + 4 + len as usize;
    }
}

/// One shard's append handle plus the bookkeeping recovery and
/// compaction need. Owned by a `DurableBackend` shard, mutated under
/// that shard's lock.
#[derive(Debug)]
pub struct ShardWal {
    dir: PathBuf,
    opts: WalOptions,
    file: File,
    seg_seq: u64,
    /// Bytes written to the current segment (including its magic).
    seg_len: u64,
    /// Durable watermark within the current segment.
    synced_len: u64,
    /// Appends since the last fsync (the `EveryN` counter).
    unsynced_appends: u32,
    /// Records across every live segment (compaction trigger input).
    records_in_log: u64,
    /// Bytes across every live segment (the `wal_bytes` stat).
    bytes_in_log: u64,
    /// Frame-assembly scratch, reused so the append hot path allocates
    /// nothing after warmup.
    scratch: Vec<u8>,
}

impl ShardWal {
    /// Open (creating if absent) the shard log in `dir`, replaying every
    /// valid record through `on_record` and truncating any invalid tail.
    pub fn open(
        dir: impl Into<PathBuf>,
        opts: WalOptions,
        mut on_record: impl FnMut(&[u8]) -> Result<()>,
    ) -> Result<(ShardWal, RecoveryReport)> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        let seqs = segment_seqs(&dir)?;
        let mut report = RecoveryReport::default();
        let mut records_in_log = 0u64;
        let mut bytes_in_log = 0u64;
        let mut cut: Option<(usize, u64)> = None; // (index into seqs, keep-len)
        for (i, &seq) in seqs.iter().enumerate() {
            report.segments += 1;
            let bytes = std::fs::read(segment_path(&dir, seq))?;
            let (valid_len, records) = scan_segment(&bytes, &mut on_record);
            records_in_log += records;
            report.records += records;
            if (valid_len as usize) < bytes.len() {
                report.discarded_bytes += bytes.len() as u64 - valid_len;
                bytes_in_log += valid_len.max(SEGMENT_MAGIC.len() as u64);
                cut = Some((i, valid_len));
                break;
            }
            bytes_in_log += bytes.len() as u64;
        }
        if let Some((i, keep)) = cut {
            // truncate the torn segment to its valid prefix (restoring
            // the magic if even that was damaged) and drop every later
            // segment — they are causally after the lost bytes
            let path = segment_path(&dir, seqs[i]);
            let f = OpenOptions::new().write(true).open(&path)?;
            f.set_len(keep)?;
            f.sync_data()?;
            drop(f);
            if keep < SEGMENT_MAGIC.len() as u64 {
                let mut f = OpenOptions::new().write(true).open(&path)?;
                f.write_all(&SEGMENT_MAGIC)?;
                f.sync_data()?;
            }
            for &seq in &seqs[i + 1..] {
                let path = segment_path(&dir, seq);
                report.discarded_bytes += std::fs::metadata(&path)?.len();
                report.segments += 1;
                std::fs::remove_file(&path)?;
            }
        }
        report.truncated = report.discarded_bytes > 0;

        // the writable tail is the last surviving segment (create
        // segment 0 on a fresh dir)
        let seg_seq = match cut {
            Some((i, _)) => seqs[i],
            None => seqs.last().copied().unwrap_or(0),
        };
        let path = segment_path(&dir, seg_seq);
        // a missing file is fresh; so is a sub-magic one (a 0-byte file
        // scans as "no records" without registering as torn) — both get
        // the magic so later appends land in a well-formed segment
        let had = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        if had < SEGMENT_MAGIC.len() as u64 {
            // `&File` is `Write`, so the binding itself can stay immutable
            Write::write_all(&mut (&file), &SEGMENT_MAGIC)?;
            file.sync_data()?;
            bytes_in_log += SEGMENT_MAGIC.len() as u64 - had;
        }
        let seg_len = std::fs::metadata(&path)?.len();
        // one fsync makes the claim below true even for a log written
        // under FsyncPolicy::Never and reopened cleanly: without it the
        // tail would be *marked* durable while the OS still owed it
        file.sync_data()?;
        let wal = ShardWal {
            dir,
            opts,
            file,
            seg_seq,
            seg_len,
            // everything that survived recovery was just re-validated
            // from disk and fsynced, so the whole current segment
            // counts as durable
            synced_len: seg_len,
            unsynced_appends: 0,
            records_in_log,
            bytes_in_log,
            scratch: Vec::new(),
        };
        Ok((wal, report))
    }

    /// The shard log directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The options this log runs with.
    pub fn options(&self) -> WalOptions {
        self.opts
    }

    /// Bytes across every live segment.
    pub fn bytes(&self) -> u64 {
        self.bytes_in_log
    }

    /// Records across every live segment.
    pub fn records(&self) -> u64 {
        self.records_in_log
    }

    /// Append one record (framing + checksum around `payload`), applying
    /// the fsync policy. The caller checks [`needs_roll`](ShardWal::needs_roll)
    /// afterwards.
    pub fn append(&mut self, payload: &[u8]) -> Result<()> {
        self.scratch.clear();
        crate::clocks::encoding::put_varint(&mut self.scratch, payload.len() as u64);
        self.scratch.extend_from_slice(&crc32(payload).to_le_bytes());
        self.scratch.extend_from_slice(payload);
        self.file.write_all(&self.scratch)?;
        let frame_len = self.scratch.len() as u64;
        self.seg_len += frame_len;
        self.bytes_in_log += frame_len;
        self.records_in_log += 1;
        match self.opts.fsync {
            FsyncPolicy::Always => self.sync()?,
            FsyncPolicy::EveryN(n) => {
                self.unsynced_appends += 1;
                if self.unsynced_appends >= n {
                    self.sync()?;
                }
            }
            FsyncPolicy::Never => {}
        }
        Ok(())
    }

    /// Fsync the current segment and advance the durable watermark.
    pub fn sync(&mut self) -> Result<()> {
        self.file.sync_data()?;
        self.synced_len = self.seg_len;
        self.unsynced_appends = 0;
        Ok(())
    }

    /// Has the current segment outgrown the roll threshold?
    pub fn needs_roll(&self) -> bool {
        self.seg_len >= self.opts.segment_bytes
    }

    /// Would a roll now be worth compacting? True when fewer than half
    /// the logged records are live (`live_keys` distinct keys).
    pub fn live_fraction_low(&self, live_keys: usize) -> bool {
        self.records_in_log > 2 * live_keys as u64
    }

    /// Roll to a fresh segment. With `snapshot: Some(payloads)` this is a
    /// **compacting** roll: the new segment is seeded with one record per
    /// live key, fsynced, and every older segment is deleted. The old
    /// segment is always fsynced first, so past segments are durable
    /// end-to-end and only the current one has a loss window.
    pub fn roll(&mut self, snapshot: Option<&[Vec<u8>]>) -> Result<()> {
        self.sync()?;
        let old_seq = self.seg_seq;
        self.seg_seq += 1;
        let path = segment_path(&self.dir, self.seg_seq);
        let mut file = OpenOptions::new().create_new(true).append(true).open(&path)?;
        file.write_all(&SEGMENT_MAGIC)?;
        self.file = file;
        self.seg_len = SEGMENT_MAGIC.len() as u64;
        self.bytes_in_log += SEGMENT_MAGIC.len() as u64;
        self.synced_len = 0;
        self.unsynced_appends = 0;
        if let Some(payloads) = snapshot {
            for payload in payloads {
                self.append(payload)?;
            }
            self.sync()?;
            // only after the snapshot is durable may its sources go
            for seq in segment_seqs(&self.dir)? {
                if seq <= old_seq {
                    std::fs::remove_file(segment_path(&self.dir, seq))?;
                }
            }
            self.records_in_log = payloads.len() as u64;
            self.bytes_in_log = self.seg_len;
        } else {
            self.sync()?;
        }
        Ok(())
    }

    /// Simulate the OS losing everything not yet fsynced (process death
    /// mid-write): truncate the current segment to the durable
    /// watermark. The in-memory map this log backs must be rebuilt by
    /// reopening the directory.
    pub fn simulate_power_loss(&mut self) -> Result<()> {
        let path = segment_path(&self.dir, self.seg_seq);
        let keep = self.synced_len.max(SEGMENT_MAGIC.len() as u64);
        let f = OpenOptions::new().write(true).open(&path)?;
        f.set_len(keep)?;
        f.sync_data()?;
        Ok(())
    }

    /// Delete every segment and start over empty (total state loss; the
    /// `Fault::Wipe` semantics).
    pub fn wipe(&mut self) -> Result<()> {
        for seq in segment_seqs(&self.dir)? {
            std::fs::remove_file(segment_path(&self.dir, seq))?;
        }
        self.seg_seq = 0;
        let path = segment_path(&self.dir, 0);
        let mut file = OpenOptions::new().create_new(true).append(true).open(&path)?;
        file.write_all(&SEGMENT_MAGIC)?;
        file.sync_data()?;
        self.file = file;
        self.seg_len = SEGMENT_MAGIC.len() as u64;
        self.synced_len = self.seg_len;
        self.unsynced_appends = 0;
        self.records_in_log = 0;
        self.bytes_in_log = self.seg_len;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::temp_dir;

    fn collect_open(dir: &Path, opts: WalOptions) -> (ShardWal, RecoveryReport, Vec<Vec<u8>>) {
        let mut seen = Vec::new();
        let (wal, report) = ShardWal::open(dir, opts, |payload| {
            seen.push(payload.to_vec());
            Ok(())
        })
        .unwrap();
        (wal, report, seen)
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // the standard IEEE test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn append_replay_roundtrip() {
        let dir = temp_dir("wal-roundtrip");
        let opts = WalOptions::default();
        let payloads: Vec<Vec<u8>> = (0..20u8).map(|i| vec![i; (i as usize % 7) + 1]).collect();
        {
            let (mut wal, report, seen) = collect_open(&dir, opts);
            assert_eq!(report, RecoveryReport { segments: 0, ..Default::default() });
            assert!(seen.is_empty());
            for p in &payloads {
                wal.append(p).unwrap();
            }
            assert_eq!(wal.records(), 20);
        }
        let (wal, report, seen) = collect_open(&dir, opts);
        assert_eq!(seen, payloads);
        assert_eq!(report.records, 20);
        assert_eq!(report.discarded_bytes, 0);
        assert!(!report.truncated);
        assert_eq!(wal.records(), 20);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tail_truncates_to_longest_valid_prefix() {
        let dir = temp_dir("wal-torn");
        let opts = WalOptions { fsync: FsyncPolicy::Never, ..Default::default() };
        {
            let (mut wal, _, _) = collect_open(&dir, opts);
            for i in 0..5u8 {
                wal.append(&[i; 10]).unwrap();
            }
        }
        // tear the tail mid-record: drop the file's last 3 bytes
        let path = segment_path(&dir, 0);
        let len = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 3).unwrap();
        drop(f);

        let (_, report, seen) = collect_open(&dir, opts);
        assert_eq!(seen.len(), 4, "last record torn, first four replay");
        assert_eq!(report.records, 4);
        assert!(report.truncated);
        // one record = 1-byte varint + 4-byte crc + 10 payload = 15; we
        // cut 3 bytes, so 12 torn bytes get discarded
        assert_eq!(report.discarded_bytes, 12);
        // recovery is idempotent: the log is clean now
        let (_, report2, seen2) = collect_open(&dir, opts);
        assert_eq!(seen2.len(), 4);
        assert!(!report2.truncated);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_byte_cuts_and_later_segments_are_dropped() {
        let dir = temp_dir("wal-corrupt");
        let opts =
            WalOptions { segment_bytes: 64, fsync: FsyncPolicy::Never };
        {
            let (mut wal, _, _) = collect_open(&dir, opts);
            for i in 0..12u8 {
                wal.append(&[i; 16]).unwrap();
                if wal.needs_roll() {
                    wal.roll(None).unwrap(); // plain rolls: keep history
                }
            }
        }
        let seqs = segment_seqs(&dir).unwrap();
        assert!(seqs.len() >= 3, "rolls produced segments: {seqs:?}");
        // flip one payload byte in the second segment
        let victim = segment_path(&dir, seqs[1]);
        let mut bytes = std::fs::read(&victim).unwrap();
        let at = SEGMENT_MAGIC.len() + 7;
        bytes[at] ^= 0xFF;
        std::fs::write(&victim, &bytes).unwrap();

        let (_, report, seen) = collect_open(&dir, opts);
        assert!(report.truncated);
        assert!(report.discarded_bytes > 0);
        // exactly segment 0's three records survive; the corrupt record
        // and all later segments are gone
        let expected: Vec<Vec<u8>> = (0..3u8).map(|i| vec![i; 16]).collect();
        assert_eq!(seen, expected, "recovered set is the pre-corruption record prefix");
        assert_eq!(segment_seqs(&dir).unwrap().len(), 2, "later segments deleted");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn power_loss_keeps_only_the_synced_watermark() {
        let dir = temp_dir("wal-powerloss");
        let opts = WalOptions { fsync: FsyncPolicy::EveryN(4), ..Default::default() };
        {
            let (mut wal, _, _) = collect_open(&dir, opts);
            for i in 0..10u8 {
                wal.append(&[i; 8]).unwrap();
            }
            // 10 appends, fsync every 4: records 0..8 are durable
            wal.simulate_power_loss().unwrap();
        }
        let (_, report, seen) = collect_open(&dir, opts);
        assert_eq!(seen.len(), 8, "the unsynced tail died with the process");
        assert!(!report.truncated, "power loss is not corruption");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn compacting_roll_keeps_one_record_per_live_key() {
        let dir = temp_dir("wal-compact");
        let opts = WalOptions { segment_bytes: 256, fsync: FsyncPolicy::Never };
        let (mut wal, _, _) = collect_open(&dir, opts);
        for i in 0..40u8 {
            wal.append(&[i % 4; 16]).unwrap(); // 4 live keys, 40 records
        }
        assert!(wal.live_fraction_low(4));
        let snapshot: Vec<Vec<u8>> = (0..4u8).map(|k| vec![k; 16]).collect();
        wal.roll(Some(&snapshot)).unwrap();
        assert_eq!(wal.records(), 4);
        assert_eq!(segment_seqs(&dir).unwrap().len(), 1, "old segments deleted");
        drop(wal);
        let (_, report, seen) = collect_open(&dir, opts);
        assert_eq!(report.records, 4);
        assert_eq!(seen, snapshot);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn wipe_resets_to_an_empty_log() {
        let dir = temp_dir("wal-wipe");
        let opts = WalOptions::default();
        let (mut wal, _, _) = collect_open(&dir, opts);
        for i in 0..5u8 {
            wal.append(&[i]).unwrap();
        }
        wal.wipe().unwrap();
        assert_eq!(wal.records(), 0);
        drop(wal);
        let (_, report, seen) = collect_open(&dir, opts);
        assert_eq!(report.records, 0);
        assert!(seen.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn garbage_file_never_panics() {
        let dir = temp_dir("wal-garbage");
        let opts = WalOptions::default();
        std::fs::write(segment_path(&dir, 0), b"not a wal at all").unwrap();
        let (_, report, seen) = collect_open(&dir, opts);
        assert!(seen.is_empty());
        assert!(report.truncated);
        assert_eq!(report.discarded_bytes, 16);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fsync_policy_parses() {
        assert_eq!(FsyncPolicy::parse("always").unwrap(), FsyncPolicy::Always);
        assert_eq!(FsyncPolicy::parse("never").unwrap(), FsyncPolicy::Never);
        assert_eq!(FsyncPolicy::parse("1").unwrap(), FsyncPolicy::Always);
        assert_eq!(FsyncPolicy::parse("64").unwrap(), FsyncPolicy::EveryN(64));
        assert!(FsyncPolicy::parse("0").is_err());
        assert!(FsyncPolicy::parse("sometimes").is_err());
        assert!(FsyncPolicy::parse("every0").is_err());
        // what Display prints parses back (operators copy program output)
        for policy in [FsyncPolicy::Always, FsyncPolicy::EveryN(7), FsyncPolicy::Never] {
            assert_eq!(FsyncPolicy::parse(&policy.to_string()).unwrap(), policy);
        }
    }
}
