//! LSM-tree backend: a durable [`StorageBackend`] whose working set can
//! exceed RAM.
//!
//! [`DurableBackend`](super::DurableBackend) keeps the **entire**
//! dataset in a `HashMap` with a log behind it: memory is O(dataset)
//! and restart replay is O(log). [`LsmBackend`] bounds both:
//!
//! * a **memtable** per shard holds only recently-written states, capped
//!   at [`LsmOptions::memtable_bytes`];
//! * the shard's WAL covers **exactly the memtable** — a flush writes
//!   the memtable as a sorted run, fsyncs it, then wipes the log — so
//!   restart replay is O(memtable), not O(history);
//! * flushed states live in immutable **sorted runs**
//!   ([`super::sst`]): per-run key-range fence, CRC'd blocks, a block
//!   index and a bloom filter in the footer, so a point read touches at
//!   most one block per overlapping run (and usually zero);
//! * **size-tiered compaction** on a background thread merges adjacent
//!   same-size-class runs (newest-wins, no tombstones — this store has
//!   no delete short of [`wipe`](StorageBackend::wipe)), replacing the
//!   durable backend's whole-snapshot roll;
//! * a per-shard **block cache** keeps recently-read decoded blocks so
//!   hot read sets stay cheap without holding cold data resident.
//!
//! # Recency model
//!
//! Runs are ordered newest-first and a key's newest occurrence wins —
//! states are **full** mechanism states (the same post-state records the
//! WAL carries), never deltas, so reads stop at the first hit and
//! compaction is pure newest-wins selection, no cross-run state merging.
//! Mutations read-modify-write: [`update`](StorageBackend::update)
//! pulls the current state up into the memtable first, so the memtable
//! entry is always the key's latest state.
//!
//! A closure that turns out to be a **no-op** (anti-entropy or
//! read-repair re-delivering covered state — the common case for a
//! quiesced cluster) leaves no trace: nothing is logged, and a clean
//! pull-up is dropped from the memtable again, so convergent AE rounds
//! leave `durable_bytes()` flat.
//!
//! # Crash model
//!
//! Every mutation's post-state is in the WAL before the shard lock is
//! released (durably under
//! [`FsyncPolicy::Always`](super::wal::FsyncPolicy)); runs are fsynced
//! before the WAL that covered their content is wiped, so there is no
//! window where a state is in neither. A crash mid-flush leaves the WAL
//! intact (replay redelivers the just-flushed states — duplicates, not
//! loss); a crash mid-compaction leaves the inputs intact (a finished
//! merged run shadows them; a partial one fails validation and is
//! quarantined on the next open, see below). I/O errors on the mutation
//! path panic for the same reason they do in
//! [`DurableBackend`](super::durable): a replica whose disk is gone
//! should die loudly, not drop persistence silently.
//!
//! On open every run is validated end to end; damaged files are renamed
//! `*.quarantined` — never deleted — counted in
//! [`RecoveryReport::quarantined_runs`], and the lost states are
//! re-delivered by anti-entropy from the rest of the cluster. Run files
//! are named `run-<seq>-<gen>.sst`: `seq` orders recency, and a merged
//! run reuses its newest input's `seq` with `gen + 1`, so recovery can
//! always reconstruct the correct order (and drop a superseded
//! same-`seq` input) from names alone — no manifest file to keep
//! crash-consistent.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::backend::StorageBackend;
use super::sst::{quarantine, Run, RunWriter};
use super::wal::{RecoveryReport, ShardWal, WalOptions};
use super::Key;
use crate::antientropy::merkle::ShardTree;
use crate::clocks::encoding::{expect_end, get_varint, put_varint};
use crate::kernel::DurableMechanism;
use crate::Result;

/// Default shard count — same as the durable backend's: each shard is a
/// directory of real files.
pub const DEFAULT_LSM_SHARDS: usize = super::durable::DEFAULT_DURABLE_SHARDS;

/// Tuning for an [`LsmBackend`].
#[derive(Debug, Clone, Copy)]
pub struct LsmOptions {
    /// The per-shard WAL's options. `segment_bytes` doubles as the WAL
    /// growth bound: the log never rolls (a flush wipes it instead), so
    /// outgrowing a segment forces a flush — this is what keeps a
    /// hot-key workload, whose memtable never grows, from growing the
    /// log without bound.
    pub wal: WalOptions,
    /// Flush the memtable to a sorted run once its encoded payload
    /// reaches this many bytes (per shard).
    pub memtable_bytes: usize,
    /// Target encoded size of one data block inside a run.
    pub block_bytes: usize,
    /// Decoded blocks the per-shard read cache may hold (0 disables).
    pub cache_blocks: usize,
    /// Adjacent runs of the same size class that trigger a compaction
    /// merge (the size-tiered fan-in).
    pub tier_runs: usize,
}

impl Default for LsmOptions {
    fn default() -> LsmOptions {
        LsmOptions {
            wal: WalOptions::default(),
            memtable_bytes: 1 << 20,
            block_bytes: 4096,
            cache_blocks: 64,
            tier_runs: 4,
        }
    }
}

fn run_name(seq: u64, gen: u32) -> String {
    format!("run-{seq:08}-{gen:04}.sst")
}

/// Parse `run-<seq>-<gen>.sst`; `None` for anything else.
fn parse_run_name(name: &str) -> Option<(u64, u32)> {
    let rest = name.strip_prefix("run-")?.strip_suffix(".sst")?;
    let (seq, gen) = rest.split_once('-')?;
    if seq.len() != 8 || gen.len() != 4 {
        return None;
    }
    Some((seq.parse().ok()?, gen.parse().ok()?))
}

/// One memtable entry: the key's latest state plus its encoded payload
/// size (what a WAL record / run entry for it costs), so the flush
/// trigger tracks real bytes without re-encoding.
struct MemEntry<S> {
    state: S,
    cost: usize,
}

/// An open run plus its ordering identity and footer digests (kept
/// resident: 16 bytes/key, the index that lets compaction and tree
/// rebuilds skip state decoding).
struct RunHandle {
    run: Run,
    seq: u64,
    gen: u32,
    /// Runtime-unique cache id — never reused, so stale cache slots can
    /// never alias a newer run's blocks.
    id: u64,
    /// `(key, state_digest)` ascending, straight from the footer.
    digests: Vec<(Key, u64)>,
}

struct CacheSlot<S> {
    tick: u64,
    bytes: u64,
    entries: Arc<Vec<(Key, S)>>,
}

/// LRU cache of decoded blocks, keyed by `(run id, block index)`.
struct BlockCache<S> {
    map: HashMap<(u64, usize), CacheSlot<S>>,
    cap: usize,
    tick: u64,
    hits: u64,
    misses: u64,
    bytes: u64,
}

impl<S> BlockCache<S> {
    fn new(cap: usize) -> BlockCache<S> {
        BlockCache { map: HashMap::new(), cap, tick: 0, hits: 0, misses: 0, bytes: 0 }
    }

    /// Drop every slot belonging to a run that no longer exists.
    fn purge_run(&mut self, run_id: u64) {
        self.map.retain(|&(id, _), slot| {
            let keep = id != run_id;
            if !keep {
                self.bytes -= slot.bytes;
            }
            keep
        });
    }

    fn clear(&mut self) {
        self.map.clear();
        self.bytes = 0;
    }
}

/// Where [`LsmShard::pull_up`] found the key's current state.
enum Origin {
    /// Already in the memtable (and therefore already WAL-covered).
    Mem,
    /// Pulled up from a sorted run (resident but not yet WAL-covered).
    Runs,
    /// Absent everywhere; a default state was materialized.
    Fresh,
}

struct LsmShard<M: DurableMechanism> {
    dir: PathBuf,
    opts: LsmOptions,
    mem: HashMap<Key, MemEntry<M::State>>,
    /// Sum of memtable entry costs (the flush trigger input).
    mem_bytes: usize,
    /// Union of the keys present in any run — exact, because nothing is
    /// ever deleted from the key space short of a wipe, so flushes only
    /// add to it and compaction preserves it.
    on_disk: BTreeSet<Key>,
    /// Newest first. A key's first occurrence walking this list is its
    /// latest flushed state.
    runs: Vec<RunHandle>,
    /// Anti-entropy hash tree over the shard's *latest* states,
    /// maintained incrementally on commit, rebuilt from run footers +
    /// WAL replay on open.
    tree: ShardTree,
    wal: ShardWal,
    cache: BlockCache<M::State>,
    next_seq: u64,
    next_run_id: u64,
    /// Encode scratch, reused across commits.
    buf: Vec<u8>,
}

impl<M: DurableMechanism> LsmShard<M> {
    /// Open the shard dir: validate and order every run (quarantining
    /// damaged ones), rebuild the hash tree from run footers, then
    /// replay the WAL into the memtable.
    fn open(dir: &Path, opts: LsmOptions) -> Result<(LsmShard<M>, RecoveryReport)> {
        std::fs::create_dir_all(dir)?;
        let mut report = RecoveryReport::default();

        // discover run files; an unparsable or damaged one is renamed
        // aside, never deleted
        let mut found: Vec<(u64, u32, PathBuf)> = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let name = match path.file_name().and_then(|n| n.to_str()) {
                Some(n) if n.ends_with(".sst") => n.to_string(),
                _ => continue,
            };
            match parse_run_name(&name) {
                Some((seq, gen)) => found.push((seq, gen, path)),
                None => {
                    quarantine(&path)?;
                    report.quarantined_runs += 1;
                }
            }
        }
        found.sort();

        let mut next_run_id = 0u64;
        let mut oldest_first: Vec<RunHandle> = Vec::new();
        for (seq, gen, path) in found {
            match Run::open(&path) {
                Ok((run, digests)) => {
                    // two valid runs sharing a seq: the higher gen is a
                    // finished compaction whose input-deletion was
                    // interrupted; the lower is fully shadowed by it
                    if oldest_first.last().is_some_and(|p| p.seq == seq) {
                        let stale = oldest_first.pop().expect("just checked");
                        let _ = std::fs::remove_file(stale.run.path());
                    }
                    oldest_first.push(RunHandle { run, seq, gen, id: next_run_id, digests });
                    next_run_id += 1;
                }
                Err(_) => {
                    quarantine(&path)?;
                    report.quarantined_runs += 1;
                }
            }
        }

        // footers alone rebuild the tree and the key union — no state
        // decoding; oldest→newest so the newest digest wins
        let mut tree = ShardTree::new();
        let mut on_disk = BTreeSet::new();
        for h in &oldest_first {
            for &(k, d) in &h.digests {
                tree.record(k, d);
                on_disk.insert(k);
            }
        }
        let next_seq = oldest_first.last().map_or(0, |h| h.seq + 1);

        // the WAL covers exactly the memtable: replay is O(memtable)
        let mut mem: HashMap<Key, MemEntry<M::State>> = HashMap::new();
        let (wal, wal_report) = ShardWal::open(dir, opts.wal, |payload| {
            let mut pos = 0;
            let key = get_varint(payload, &mut pos)?;
            let state = M::decode_state(payload, &mut pos)?;
            expect_end(payload, pos)?;
            mem.insert(key, MemEntry { state, cost: payload.len() });
            Ok(())
        })?;
        report.absorb(&wal_report);
        let mem_bytes = mem.values().map(|e| e.cost).sum();
        for (k, e) in &mem {
            tree.record(*k, M::state_digest(&e.state));
        }

        let mut runs = oldest_first;
        runs.reverse();
        Ok((
            LsmShard {
                dir: dir.to_path_buf(),
                opts,
                mem,
                mem_bytes,
                on_disk,
                runs,
                tree,
                wal,
                cache: BlockCache::new(opts.cache_blocks),
                next_seq,
                next_run_id,
                buf: Vec::new(),
            },
            report,
        ))
    }

    /// Decode one block (through the cache) and return a shared handle
    /// to its entries.
    fn load_block(&mut self, run_idx: usize, block_idx: usize) -> Arc<Vec<(Key, M::State)>> {
        let h = &self.runs[run_idx];
        let slot_key = (h.id, block_idx);
        self.cache.tick += 1;
        let tick = self.cache.tick;
        if let Some(slot) = self.cache.map.get_mut(&slot_key) {
            slot.tick = tick;
            self.cache.hits += 1;
            return Arc::clone(&slot.entries);
        }
        self.cache.misses += 1;
        let raw = h.run.read_block(block_idx).expect("run read failed (see module docs)");
        let mut bytes = 0u64;
        let mut entries = Vec::with_capacity(raw.len());
        for (k, payload) in raw {
            let mut pos = 0;
            let st = M::decode_state(&payload, &mut pos)
                .expect("run entry decode failed (framing was validated at open)");
            bytes += payload.len() as u64;
            entries.push((k, st));
        }
        let entries = Arc::new(entries);
        if self.cache.cap > 0 {
            if self.cache.map.len() >= self.cache.cap {
                if let Some(victim) =
                    self.cache.map.iter().min_by_key(|(_, s)| s.tick).map(|(&k, _)| k)
                {
                    let gone = self.cache.map.remove(&victim).expect("victim exists");
                    self.cache.bytes -= gone.bytes;
                }
            }
            self.cache.bytes += bytes;
            self.cache.map.insert(slot_key, CacheSlot { tick, bytes, entries: Arc::clone(&entries) });
        }
        entries
    }

    /// Latest flushed state of `key`, newest run first. Fence + bloom +
    /// block index cut non-holders, so this touches at most one block
    /// per overlapping run (bloom false positives pay one extra block).
    fn lookup_runs(&mut self, key: Key) -> Option<M::State> {
        for i in 0..self.runs.len() {
            let Some(block_idx) = self.runs[i].run.locate(key) else { continue };
            let block = self.load_block(i, block_idx);
            if let Ok(j) = block.binary_search_by_key(&key, |e| e.0) {
                return Some(block[j].1.clone());
            }
        }
        None
    }

    /// Make sure `key` has a memtable entry (the RMW pull-up), returning
    /// where its current state came from and its pre-mutation digest
    /// (`None` when the key was absent everywhere).
    fn pull_up(&mut self, key: Key) -> (Origin, Option<u64>) {
        if let Some(e) = self.mem.get(&key) {
            return (Origin::Mem, Some(M::state_digest(&e.state)));
        }
        if let Some(state) = self.lookup_runs(key) {
            let digest = M::state_digest(&state);
            self.buf.clear();
            put_varint(&mut self.buf, key);
            M::encode_state(&state, &mut self.buf);
            let cost = self.buf.len();
            self.mem.insert(key, MemEntry { state, cost });
            self.mem_bytes += cost;
            return (Origin::Runs, Some(digest));
        }
        self.mem.insert(key, MemEntry { state: M::State::default(), cost: 0 });
        (Origin::Fresh, None)
    }

    /// Drop a clean pull-up again: the closure changed nothing, so the
    /// memtable (and WAL) owes this key nothing.
    fn drop_clean(&mut self, key: Key) {
        let cost = self.mem.remove(&key).expect("clean pull-up is resident").cost;
        self.mem_bytes -= cost;
    }

    /// Persist `key`'s (changed) memtable state: WAL append + hash-tree
    /// record + cost re-accounting. Runs under the shard lock, so the
    /// log order is the mutation order.
    fn commit(&mut self, key: Key, digest: u64) {
        {
            let entry = self.mem.get(&key).expect("committed key is resident");
            self.buf.clear();
            put_varint(&mut self.buf, key);
            M::encode_state(&entry.state, &mut self.buf);
        }
        self.tree.record(key, digest);
        self.wal.append(&self.buf).expect("WAL append failed (see module docs)");
        let new_cost = self.buf.len();
        let entry = self.mem.get_mut(&key).expect("committed key is resident");
        self.mem_bytes = self.mem_bytes + new_cost - entry.cost;
        entry.cost = new_cost;
    }

    /// Flush when the memtable is over budget **or** the WAL outgrew a
    /// segment (the hot-key case: cost-stable rewrites grow the log, not
    /// the memtable). Returns whether a flush happened, so the caller
    /// can nudge the compactor after releasing the lock.
    fn maybe_flush(&mut self) -> bool {
        if self.mem.is_empty() || (self.mem_bytes < self.opts.memtable_bytes && !self.wal.needs_roll())
        {
            return false;
        }
        self.flush_mem();
        true
    }

    /// Write the memtable as a sorted run (fsynced), then wipe the WAL —
    /// order matters: the run is durable before the log that covered its
    /// content goes, so a crash between the two replays duplicates, not
    /// loses.
    fn flush_mem(&mut self) {
        let mut keys: Vec<Key> = self.mem.keys().copied().collect();
        keys.sort_unstable();
        let mut writer = RunWriter::new(self.opts.block_bytes);
        let mut digests = Vec::with_capacity(keys.len());
        for &k in &keys {
            let entry = &self.mem[&k];
            let digest = M::state_digest(&entry.state);
            self.buf.clear();
            M::encode_state(&entry.state, &mut self.buf);
            writer.add(k, digest, &self.buf);
            digests.push((k, digest));
        }
        let seq = self.next_seq;
        let path = self.dir.join(run_name(seq, 0));
        let run = writer.finish(&path).expect("run flush failed (see module docs)");
        self.next_seq += 1;
        self.on_disk.extend(keys);
        let id = self.next_run_id;
        self.next_run_id += 1;
        self.runs.insert(0, RunHandle { run, seq, gen: 0, id, digests });
        self.mem.clear();
        self.mem_bytes = 0;
        self.wal.wipe().expect("WAL wipe failed (see module docs)");
    }

    /// Size class of a run: log4 of its size in 4 KiB units, so runs
    /// within ~4x of each other merge together (classic size tiering).
    fn bucket(bytes: u64) -> u32 {
        let units = (bytes / 4096).max(1);
        (63 - units.leading_zeros()) / 2
    }

    /// The first (newest-most) window of ≥ `tier_runs` adjacent runs in
    /// one size class, as `[start, end)` into the newest-first list.
    fn compact_candidate(&self) -> Option<(usize, usize)> {
        let n = self.runs.len();
        let mut i = 0;
        while i < n {
            let class = Self::bucket(self.runs[i].run.bytes());
            let mut j = i + 1;
            while j < n && Self::bucket(self.runs[j].run.bytes()) == class {
                j += 1;
            }
            if j - i >= self.opts.tier_runs {
                return Some((i, j));
            }
            i = j;
        }
        None
    }

    /// Merge one adjacent window into a single run. Newest-wins by key;
    /// adjacency is what makes that sound (a merged window occupies its
    /// old position in the recency order). The merged run is named after
    /// its newest input's `seq` with `gen + 1`; inputs are deleted only
    /// after the merged run is durable and validated.
    fn compact_window(&mut self, start: usize, end: usize) -> Result<()> {
        let mut merged: BTreeMap<Key, (Vec<u8>, u64)> = BTreeMap::new();
        for h in self.runs[start..end].iter().rev() {
            // digests and entries are both ascending: zip them
            let mut digests = h.digests.iter().peekable();
            let mut scan_err = None;
            let walk = h.run.for_each_entry(|k, state| {
                while digests.next_if(|d| d.0 < k).is_some() {}
                match digests.peek() {
                    Some(&&(dk, dv)) if dk == k => {
                        merged.insert(k, (state.to_vec(), dv));
                    }
                    _ => scan_err = Some(()),
                }
            });
            walk?;
            if scan_err.is_some() {
                // open() verified digest keys == entry keys, so this is
                // post-open bit rot; abort, leave the inputs alone
                return Err(crate::error::Error::Codec(format!(
                    "run {}: footer digests no longer match entries",
                    h.run.path().display()
                )));
            }
        }
        let mut writer = RunWriter::new(self.opts.block_bytes);
        for (k, (state, digest)) in &merged {
            writer.add(*k, *digest, state);
        }
        let seq = self.runs[start].seq;
        let gen = self.runs[start..end].iter().map(|h| h.gen).max().expect("window nonempty") + 1;
        let path = self.dir.join(run_name(seq, gen));
        let run = writer.finish(&path)?;
        let digests: Vec<(Key, u64)> = merged.iter().map(|(k, (_, d))| (*k, *d)).collect();
        let id = self.next_run_id;
        self.next_run_id += 1;
        let replaced: Vec<RunHandle> = self
            .runs
            .splice(start..end, [RunHandle { run, seq, gen, id, digests }])
            .collect();
        for h in replaced {
            self.cache.purge_run(h.id);
            let _ = std::fs::remove_file(h.run.path());
        }
        Ok(())
    }

    /// One compaction step if one is due. A failed merge (disk full,
    /// post-open rot) leaves the inputs untouched and reports no
    /// progress so callers don't spin.
    fn compact_once(&mut self) -> bool {
        match self.compact_candidate() {
            Some((start, end)) => self.compact_window(start, end).is_ok(),
            None => false,
        }
    }

    /// Distinct keys in this shard (memtable ∪ runs).
    fn key_count(&self) -> usize {
        self.on_disk.len() + self.mem.keys().filter(|k| !self.on_disk.contains(k)).count()
    }
}

struct Inner<M: DurableMechanism> {
    shards: Box<[Mutex<LsmShard<M>>]>,
    mask: u64,
    dir: PathBuf,
    opts: LsmOptions,
    report: RecoveryReport,
}

/// See module docs.
pub struct LsmBackend<M: DurableMechanism> {
    inner: Arc<Inner<M>>,
    /// `Some` while the compactor thread runs; taking it (Drop) closes
    /// the channel and ends the thread.
    nudge: Mutex<Option<mpsc::Sender<()>>>,
    compactor: Mutex<Option<JoinHandle<()>>>,
}

impl<M: DurableMechanism> LsmBackend<M> {
    /// Open (creating if absent) an LSM backend rooted at `dir` with
    /// `shards` stripes (rounded up to a power of two), validating every
    /// run and replaying every shard WAL. Damaged runs are quarantined,
    /// torn WAL tails truncated; both are recorded in
    /// [`recovery_report`](LsmBackend::recovery_report). Also starts the
    /// background compactor thread (joined on drop).
    pub fn open(dir: impl Into<PathBuf>, shards: usize, opts: LsmOptions) -> Result<LsmBackend<M>> {
        let dir = dir.into();
        let n = shards.max(1).next_power_of_two();
        let mut report = RecoveryReport::default();
        let mut built = Vec::with_capacity(n);
        for i in 0..n {
            let shard_dir = dir.join(format!("shard-{i:03}"));
            let (shard, shard_report) = LsmShard::open(&shard_dir, opts)?;
            report.absorb(&shard_report);
            built.push(Mutex::new(shard));
        }
        let inner = Arc::new(Inner {
            shards: built.into_boxed_slice(),
            mask: (n - 1) as u64,
            dir,
            opts,
            report,
        });
        let (tx, rx) = mpsc::channel::<()>();
        let worker = Arc::clone(&inner);
        let handle = std::thread::Builder::new()
            .name("lsm-compactor".into())
            .spawn(move || {
                while rx.recv().is_ok() {
                    // drain coalesced nudges, then sweep every shard;
                    // the lock is re-taken per step so writers interleave
                    while rx.try_recv().is_ok() {}
                    for shard in worker.shards.iter() {
                        loop {
                            let Ok(mut guard) = shard.lock() else { return };
                            let progressed = guard.compact_once();
                            drop(guard);
                            if !progressed {
                                break;
                            }
                        }
                    }
                }
            })
            .expect("spawn lsm-compactor");
        Ok(LsmBackend {
            inner,
            nudge: Mutex::new(Some(tx)),
            compactor: Mutex::new(Some(handle)),
        })
    }

    #[inline]
    fn idx(&self, key: Key) -> usize {
        (key & self.inner.mask) as usize
    }

    /// Wake the compactor (after a flush, outside the shard lock).
    fn nudge(&self) {
        if let Some(tx) = self.nudge.lock().unwrap().as_ref() {
            let _ = tx.send(());
        }
    }

    /// The backend's root directory.
    pub fn dir(&self) -> &Path {
        &self.inner.dir
    }

    /// What the opening scan found: WAL records replayed, torn bytes
    /// discarded, runs quarantined.
    pub fn recovery_report(&self) -> &RecoveryReport {
        &self.inner.report
    }

    /// Fsync every shard WAL (a clean-shutdown barrier; run files are
    /// already fsynced at creation).
    pub fn flush(&self) -> Result<()> {
        for shard in self.inner.shards.iter() {
            shard.lock().unwrap().wal.sync()?;
        }
        Ok(())
    }

    /// Force every non-empty memtable out to a sorted run (tests and
    /// benches; production flushes happen on the write path).
    pub fn flush_memtables(&self) {
        let mut flushed = false;
        for shard in self.inner.shards.iter() {
            let mut guard = shard.lock().unwrap();
            if !guard.mem.is_empty() {
                guard.flush_mem();
                flushed = true;
            }
        }
        if flushed {
            self.nudge();
        }
    }

    /// Run compaction to quiescence on the calling thread (deterministic
    /// alternative to the background compactor for tests and benches).
    pub fn compact_now(&self) {
        for shard in self.inner.shards.iter() {
            loop {
                let mut guard = shard.lock().unwrap();
                let progressed = guard.compact_once();
                drop(guard);
                if !progressed {
                    break;
                }
            }
        }
    }

    /// Sorted runs currently live across all shards.
    pub fn run_count(&self) -> usize {
        self.inner.shards.iter().map(|s| s.lock().unwrap().runs.len()).sum()
    }

    /// Bytes held resident in RAM for payload state: memtables plus the
    /// decoded-block cache. This — not `durable_bytes` — is what stays
    /// sublinear as the dataset outgrows memory (`benches/storage.rs`).
    pub fn resident_bytes(&self) -> u64 {
        self.inner
            .shards
            .iter()
            .map(|s| {
                let guard = s.lock().unwrap();
                guard.mem_bytes as u64 + guard.cache.bytes
            })
            .sum()
    }

    /// `(hits, misses)` across every shard's block cache.
    pub fn cache_stats(&self) -> (u64, u64) {
        let mut hits = 0;
        let mut misses = 0;
        for shard in self.inner.shards.iter() {
            let guard = shard.lock().unwrap();
            hits += guard.cache.hits;
            misses += guard.cache.misses;
        }
        (hits, misses)
    }
}

impl<M: DurableMechanism> Drop for LsmBackend<M> {
    fn drop(&mut self) {
        // closing the channel ends the compactor's recv loop
        self.nudge.lock().unwrap().take();
        if let Some(handle) = self.compactor.lock().unwrap().take() {
            let _ = handle.join();
        }
    }
}

impl<M: DurableMechanism> fmt::Debug for LsmBackend<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let keys: usize = self.inner.shards.iter().map(|s| s.lock().unwrap().key_count()).sum();
        f.debug_struct("LsmBackend")
            .field("dir", &self.inner.dir)
            .field("shards", &self.inner.shards.len())
            .field("keys", &keys)
            .field("runs", &self.run_count())
            .finish()
    }
}

impl<M: DurableMechanism> StorageBackend<M> for LsmBackend<M> {
    fn with_state<R>(&self, key: Key, f: impl FnOnce(Option<&M::State>) -> R) -> R {
        let mut guard = self.inner.shards[self.idx(key)].lock().unwrap();
        let shard = &mut *guard;
        if let Some(e) = shard.mem.get(&key) {
            return f(Some(&e.state));
        }
        // reads never populate the memtable — only the block cache
        match shard.lookup_runs(key) {
            Some(state) => f(Some(&state)),
            None => f(None),
        }
    }

    fn update<R>(&self, key: Key, f: impl FnOnce(&mut M::State) -> R) -> R {
        let (r, flushed) = {
            let mut guard = self.inner.shards[self.idx(key)].lock().unwrap();
            let shard = &mut *guard;
            let (origin, pre) = shard.pull_up(key);
            let entry = shard.mem.get_mut(&key).expect("pulled up");
            let r = f(&mut entry.state);
            let post = M::state_digest(&entry.state);
            if pre == Some(post) {
                // no-op on an existing key: the WAL (or a run) already
                // holds exactly this state — log nothing
                if matches!(origin, Origin::Runs) {
                    shard.drop_clean(key);
                }
            } else {
                shard.commit(key, post);
            }
            (r, shard.maybe_flush())
        };
        if flushed {
            self.nudge();
        }
        r
    }

    fn update_batch<T>(&self, items: &[(Key, T)], mut f: impl FnMut(&mut M::State, &T)) {
        // sort item indices by shard, take each shard lock once per run
        // (same amortization as the other sharded backends); stable sort
        // keeps same-key items in slice order
        let mut order: Vec<usize> = (0..items.len()).collect();
        order.sort_by_key(|&i| self.idx(items[i].0));
        let mut flushed = false;
        let mut run = 0;
        while run < order.len() {
            let shard_idx = self.idx(items[order[run]].0);
            let mut guard = self.inner.shards[shard_idx].lock().unwrap();
            let shard = &mut *guard;
            while run < order.len() {
                let (key, payload) = &items[order[run]];
                if self.idx(*key) != shard_idx {
                    break;
                }
                let (origin, pre) = shard.pull_up(*key);
                let entry = shard.mem.get_mut(key).expect("pulled up");
                f(&mut entry.state, payload);
                let post = M::state_digest(&entry.state);
                if pre == Some(post) {
                    if matches!(origin, Origin::Runs) {
                        shard.drop_clean(*key);
                    }
                } else {
                    shard.commit(*key, post);
                }
                flushed |= shard.maybe_flush();
                run += 1;
            }
        }
        if flushed {
            self.nudge();
        }
    }

    fn for_each(&self, mut f: impl FnMut(Key, &M::State)) {
        // merged iteration: decode runs oldest→newest into a per-shard
        // newest-wins view, overlay the memtable, then visit. Holds
        // O(shard) decoded states transiently — the price of a full
        // scan; point reads never do this.
        for shard in self.inner.shards.iter() {
            let mut view: BTreeMap<Key, M::State> = BTreeMap::new();
            let guard = shard.lock().unwrap();
            for h in guard.runs.iter().rev() {
                h.run
                    .for_each_entry(|k, payload| {
                        let mut pos = 0;
                        let state = M::decode_state(payload, &mut pos)
                            .expect("run entry decode failed (framing was validated at open)");
                        view.insert(k, state);
                    })
                    .expect("run read failed (see module docs)");
            }
            for (k, e) in guard.mem.iter() {
                view.insert(*k, e.state.clone());
            }
            drop(guard);
            for (k, state) in &view {
                f(*k, state);
            }
        }
    }

    fn key_count(&self) -> usize {
        self.inner.shards.iter().map(|s| s.lock().unwrap().key_count()).sum()
    }

    fn shard_count(&self) -> usize {
        self.inner.shards.len()
    }

    fn shard_of(&self, key: Key) -> usize {
        self.idx(key)
    }

    fn keys_in_shard(&self, shard: usize) -> Vec<Key> {
        let guard = self.inner.shards[shard].lock().unwrap();
        let mut keys: Vec<Key> = guard.on_disk.iter().copied().collect();
        keys.extend(guard.mem.keys().filter(|k| !guard.on_disk.contains(k)));
        keys
    }

    fn wipe(&self) {
        for shard in self.inner.shards.iter() {
            let mut guard = shard.lock().unwrap();
            guard.mem.clear();
            guard.mem_bytes = 0;
            guard.on_disk.clear();
            guard.tree.clear();
            guard.cache.clear();
            for h in guard.runs.drain(..) {
                let _ = std::fs::remove_file(h.run.path());
            }
            guard.next_seq = 0;
            guard.wal.wipe().expect("WAL wipe failed (see module docs)");
        }
    }

    fn crash_restart(&self) -> RecoveryReport {
        let mut report = RecoveryReport::default();
        for shard in self.inner.shards.iter() {
            let mut guard = shard.lock().unwrap();
            guard
                .wal
                .simulate_power_loss()
                .expect("WAL truncate failed (see module docs)");
            let dir = guard.dir.clone();
            let (mut fresh, shard_report) =
                LsmShard::open(&dir, self.inner.opts).expect("LSM reopen failed (see module docs)");
            // runtime run ids must stay unique across the restart so any
            // surviving cache slot of the *old* incarnation can't alias
            // (the cache is fresh here anyway; this keeps the invariant
            // locally obvious)
            fresh.next_run_id = fresh.next_run_id.max(guard.next_run_id);
            *guard = fresh;
            report.absorb(&shard_report);
        }
        report
    }

    fn durable_bytes(&self) -> u64 {
        self.inner
            .shards
            .iter()
            .map(|s| {
                let guard = s.lock().unwrap();
                guard.wal.bytes() + guard.runs.iter().map(|h| h.run.bytes()).sum::<u64>()
            })
            .sum()
    }

    fn with_merkle<R>(&self, shard: usize, f: impl FnOnce(&mut ShardTree) -> R) -> R {
        f(&mut self.inner.shards[shard].lock().unwrap().tree)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clocks::Actor;
    use crate::kernel::mechs::DvvMech;
    use crate::kernel::{Val, WriteMeta};
    use crate::store::wal::FsyncPolicy;
    use crate::store::KeyStore;
    use crate::testkit::temp_dir;

    /// Tiny thresholds so a handful of writes exercises flush + tiering.
    fn small_opts(fsync: FsyncPolicy) -> LsmOptions {
        LsmOptions {
            wal: WalOptions { segment_bytes: 4096, fsync },
            memtable_bytes: 256,
            block_bytes: 128,
            cache_blocks: 8,
            tier_runs: 3,
        }
    }

    fn store(dir: &Path, opts: LsmOptions) -> KeyStore<DvvMech, LsmBackend<DvvMech>> {
        KeyStore::with_backend(DvvMech, LsmBackend::open(dir, 4, opts).unwrap())
    }

    fn meta() -> WriteMeta {
        WriteMeta::basic(Actor::client(0))
    }

    fn put(s: &KeyStore<DvvMech, LsmBackend<DvvMech>>, k: Key, v: u64) {
        let (_, ctx) = s.read(k);
        s.write(k, &ctx, Val::new(v, 8), Actor::server(0), &meta());
    }

    #[test]
    fn writes_survive_close_and_reopen_through_runs_and_wal() {
        let dir = temp_dir("lsm-reopen");
        let opts = small_opts(FsyncPolicy::Never);
        {
            let s = store(&dir, opts);
            for k in 0..64u64 {
                put(&s, k, k + 1);
            }
            assert!(s.backend().run_count() > 0, "tiny memtable forced flushes");
            assert_eq!(s.key_count(), 64);
        }
        let s = store(&dir, opts);
        let report = s.backend().recovery_report();
        assert_eq!(report.quarantined_runs, 0);
        assert_eq!(report.discarded_bytes, 0);
        assert_eq!(s.key_count(), 64);
        for k in 0..64u64 {
            assert_eq!(s.values(k), vec![Val::new(k + 1, 8)], "key {k}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn replay_is_bounded_by_the_memtable_not_history() {
        let dir = temp_dir("lsm-replay");
        let opts = small_opts(FsyncPolicy::Never);
        let wrote = 200u64;
        {
            let s = store(&dir, opts);
            for k in 0..wrote {
                put(&s, k, k + 1);
            }
        }
        let s = store(&dir, opts);
        let replayed = s.backend().recovery_report().records;
        assert!(
            replayed < wrote / 2,
            "replay covers the memtable only: {replayed} records for {wrote} writes"
        );
        assert_eq!(s.key_count(), wrote as usize, "the rest came from run footers");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn hot_key_cannot_grow_the_wal_without_bound() {
        let dir = temp_dir("lsm-hotkey");
        let opts = small_opts(FsyncPolicy::Never);
        let s = store(&dir, opts);
        // rewriting one key keeps mem_bytes flat, so only the WAL-size
        // flush trigger bounds the log
        for i in 0..800u64 {
            put(&s, 3, i + 1);
        }
        s.backend().compact_now();
        let total = s.backend().durable_bytes();
        assert!(
            total < 64 * 1024,
            "flush-on-segment-growth bounds the log+runs, got {total} bytes"
        );
        // and the latest value is the one that survives a reopen
        let expected = s.state(3);
        drop(s);
        let s = store(&dir, opts);
        assert_eq!(s.state(3), expected);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn noop_merges_leave_durable_bytes_flat() {
        let dir = temp_dir("lsm-noop");
        let opts = small_opts(FsyncPolicy::Never);
        let s = store(&dir, opts);
        for k in 0..20u64 {
            put(&s, k, k + 1);
        }
        let items: Vec<(Key, _)> = s.keys().map(|k| (k, s.state(k))).collect();
        let before = s.backend().durable_bytes();
        for _ in 0..10 {
            s.merge_batch(&items); // an AE round re-delivering covered state
        }
        assert_eq!(
            s.backend().durable_bytes(),
            before,
            "quiesced anti-entropy rounds must not write"
        );
        assert_eq!(s.key_count(), 20);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn compaction_merges_runs_and_keeps_every_read() {
        let dir = temp_dir("lsm-compact");
        let opts = small_opts(FsyncPolicy::Never);
        let s = store(&dir, opts);
        for round in 0..6u64 {
            for k in 0..40u64 {
                put(&s, k, round * 100 + k + 1);
            }
            s.backend().flush_memtables();
        }
        s.backend().compact_now();
        // every flushed run here is tiny (same size class), so at
        // quiescence each shard holds fewer than `tier_runs` runs —
        // regardless of how much the background compactor already did
        let after = s.backend().run_count();
        assert!(after < 3 * 4, "tiering merged the per-round runs, {after} left");
        assert_eq!(s.key_count(), 40);
        for k in 0..40u64 {
            assert_eq!(s.values(k), vec![Val::new(500 + k + 1, 8)], "newest round wins for {k}");
        }
        // merged files replay identically
        drop(s);
        let s = store(&dir, opts);
        assert_eq!(s.backend().recovery_report().quarantined_runs, 0);
        for k in 0..40u64 {
            assert_eq!(s.values(k), vec![Val::new(500 + k + 1, 8)]);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn crash_restart_loses_only_the_unsynced_memtable() {
        let dir = temp_dir("lsm-crash");
        let opts = small_opts(FsyncPolicy::Never);
        let s = store(&dir, opts);
        for k in 0..8u64 {
            put(&s, k, k + 1);
        }
        s.backend().flush_memtables(); // runs are fsynced at creation
        for k in 8..16u64 {
            put(&s, k, k + 1);
        }
        let report = s.backend().crash_restart();
        assert_eq!(report.quarantined_runs, 0);
        assert_eq!(s.key_count(), 8, "flushed keys survive, unsynced memtable is lost");
        for k in 0..8u64 {
            assert_eq!(s.values(k).len(), 1, "flushed key {k}");
        }
        for k in 8..16u64 {
            assert!(s.values(k).is_empty(), "unsynced key {k}");
        }
        // the store keeps working after recovery
        put(&s, 99, 500);
        assert_eq!(s.values(99).len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn wipe_clears_disk_and_memory() {
        let dir = temp_dir("lsm-wipe");
        let opts = small_opts(FsyncPolicy::Never);
        let s = store(&dir, opts);
        for k in 0..40u64 {
            put(&s, k, k + 1);
        }
        s.backend().wipe();
        assert_eq!(s.key_count(), 0);
        assert_eq!(s.backend().run_count(), 0);
        let report = s.backend().crash_restart();
        assert_eq!(report.records, 0, "nothing on disk either");
        assert_eq!(s.key_count(), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn block_cache_serves_repeated_reads() {
        let dir = temp_dir("lsm-cache");
        let opts = small_opts(FsyncPolicy::Never);
        let s = store(&dir, opts);
        for k in 0..32u64 {
            put(&s, k, k + 1);
        }
        s.backend().flush_memtables();
        for _ in 0..4 {
            for k in 0..32u64 {
                assert_eq!(s.values(k).len(), 1);
            }
        }
        let (hits, misses) = s.backend().cache_stats();
        assert!(
            hits > misses,
            "re-reads are served from the cache (hits {hits} vs misses {misses})"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn merged_iteration_sees_the_newest_state_exactly_once() {
        let dir = temp_dir("lsm-foreach");
        let opts = small_opts(FsyncPolicy::Never);
        let s = store(&dir, opts);
        for k in 0..24u64 {
            put(&s, k, k + 1);
        }
        s.backend().flush_memtables();
        for k in 0..24u64 {
            put(&s, k, 1000 + k); // shadow every flushed state
        }
        let mut seen: Vec<Key> = Vec::new();
        s.backend().for_each(|k, _| seen.push(k));
        seen.sort_unstable();
        assert_eq!(seen, (0..24u64).collect::<Vec<_>>(), "each key exactly once across mem + runs");
        for k in 0..24u64 {
            assert_eq!(s.values(k), vec![Val::new(1000 + k, 8)], "newest wins for {k}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn merkle_tree_tracks_states_across_flush_and_reopen() {
        let dir = temp_dir("lsm-merkle");
        let opts = small_opts(FsyncPolicy::Never);
        let roots_before;
        {
            let s = store(&dir, opts);
            for k in 0..48u64 {
                put(&s, k, k + 1);
            }
            s.backend().flush_memtables();
            s.backend().compact_now();
            roots_before = (0..s.shard_count())
                .map(|i| s.backend().merkle_root(i))
                .collect::<Vec<_>>();
        }
        let s = store(&dir, opts);
        let roots_after: Vec<u64> =
            (0..s.shard_count()).map(|i| s.backend().merkle_root(i)).collect();
        assert_eq!(roots_before, roots_after, "footer-rebuilt trees match the live ones");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
