//! The canonical client surface: **one causal KV API over every
//! transport**.
//!
//! The paper's client model (§2–§3) is a single narrow interface — GET
//! returns sibling values plus an opaque causal context, PUT supplies
//! that context back — and this module is its one definition:
//! [`KvClient`], with the context packaged as an opaque, versioned
//! [`CausalCtx`] token. Three transports implement it:
//!
//! * [`SimClient`] — the deterministic discrete-event simulator
//!   ([`crate::sim::Sim`]), driven interactively;
//! * [`LocalClient`] — the threaded in-process cluster
//!   ([`crate::server::LocalCluster`]), chaos-fabric-aware;
//! * [`TcpClient`] — real sockets, speaking binary protocol v2
//!   ([`crate::server::protocol`]).
//!
//! Workloads, fault schedules, and oracle audits are written once
//! against the trait ([`drive_workload`]) and run unchanged against all
//! three worlds — `rust/tests/api_transports.rs` asserts they reach
//! identical verdicts on the same seeded workload.
//!
//! The token stays opaque and cheap: it wraps the mechanism context
//! (encoded via [`crate::clocks::encoding`]) together with the value
//! ids the client observed — exactly what the causal ground-truth
//! oracle needs — behind a version byte, so its representation can
//! evolve without breaking stored or in-flight tokens.

pub mod local;
pub mod sim;
pub mod tcp;

pub use local::LocalClient;
pub use sim::{SimClient, SimTransport};
pub use tcp::{TcpClient, TopologyView};

use std::collections::HashMap;

use crate::clocks::encoding::{expect_end, get_bytes, get_varint, put_varint};
use crate::clocks::Actor;
use crate::error::{Error, Result};
use crate::kernel::crdt::Dot;
use crate::oracle::SetAudit;
use crate::store::Key;
use crate::testkit::Rng;
use crate::workload::{Driver, OpKind, SetOpKind, SetWorkload};

/// Version byte of the [`CausalCtx`] token encoding.
pub const CTX_VERSION: u8 = 1;

/// Cap on length fields inside a token (guards allocations when
/// decoding remote input).
const MAX_CTX_FIELD: u64 = 1 << 24;

/// An opaque, versioned causal-context token.
///
/// Returned by every GET and handed back on the next PUT of the same
/// key. It carries the mechanism's encoded context (a version vector
/// for DVV) plus the value ids the client observed — the ground truth
/// the [`crate::oracle`] audits against. Clients must treat it as
/// opaque bytes: [`encode`](CausalCtx::encode) /
/// [`decode`](CausalCtx::decode) define the stable wire form.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CausalCtx {
    /// Encoded mechanism context (e.g. `encode_vv` output).
    vv: Vec<u8>,
    /// Value ids the client observed when it received this context.
    observed: Vec<u64>,
}

impl CausalCtx {
    /// Wrap an encoded mechanism context plus the observed value ids.
    pub fn new(vv: Vec<u8>, observed: Vec<u64>) -> CausalCtx {
        CausalCtx { vv, observed }
    }

    /// The encoded mechanism context (empty = blind).
    pub fn vv_bytes(&self) -> &[u8] {
        &self.vv
    }

    /// The value ids observed with this context.
    pub fn observed(&self) -> &[u64] {
        &self.observed
    }

    /// Split into `(encoded context, observed ids)`.
    pub fn into_parts(self) -> (Vec<u8>, Vec<u64>) {
        (self.vv, self.observed)
    }

    /// True when the token carries neither context nor observations.
    pub fn is_empty(&self) -> bool {
        self.vv.is_empty() && self.observed.is_empty()
    }

    /// Stable wire form: `[version][vv len][vv bytes][count][ids…]`,
    /// varint integers.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(1 + self.vv.len() + self.observed.len() * 2 + 4);
        out.push(CTX_VERSION);
        put_varint(&mut out, self.vv.len() as u64);
        out.extend_from_slice(&self.vv);
        put_varint(&mut out, self.observed.len() as u64);
        for &id in &self.observed {
            put_varint(&mut out, id);
        }
        out
    }

    /// Decode a token, rejecting unknown versions, truncation, and
    /// trailing bytes (never panics on malformed input).
    pub fn decode(buf: &[u8]) -> Result<CausalCtx> {
        let version = *buf
            .first()
            .ok_or_else(|| Error::Codec("empty context token".into()))?;
        if version != CTX_VERSION {
            return Err(Error::Codec(format!(
                "context token v{version} unsupported (this build speaks v{CTX_VERSION})"
            )));
        }
        let mut pos = 1;
        let vv_len = get_varint(buf, &mut pos)?;
        if vv_len > MAX_CTX_FIELD {
            return Err(Error::Codec(format!("context field of {vv_len} bytes")));
        }
        let vv = get_bytes(buf, &mut pos, vv_len as usize)?.to_vec();
        let count = get_varint(buf, &mut pos)?;
        // each id costs at least one byte, so a count beyond the bytes
        // actually remaining is malformed — reject before any
        // count-driven allocation (remote input must not pick our
        // allocation sizes)
        if count > (buf.len() - pos) as u64 {
            return Err(Error::Codec(format!(
                "observed count {count} exceeds remaining token bytes"
            )));
        }
        let mut observed = Vec::new();
        for _ in 0..count {
            observed.push(get_varint(buf, &mut pos)?);
        }
        expect_end(buf, pos)?;
        Ok(CausalCtx { vv, observed })
    }
}

/// A GET's answer: sibling values plus the causal-context token. The
/// token's observed ids run parallel to `values`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GetReply {
    /// Sibling values (raw bytes), one per concurrent version.
    pub values: Vec<Vec<u8>>,
    /// The context to hand back on the next PUT of this key.
    pub ctx: CausalCtx,
}

impl GetReply {
    /// The write ids of the returned siblings (parallel to `values`).
    pub fn ids(&self) -> &[u64] {
        self.ctx.observed()
    }
}

/// A PUT's answer. Carrying the new write's id *and* the post-write
/// context in the reply is what lets a [`Session`] update itself — no
/// caller threads `wrote_id` by hand anymore.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PutReply {
    /// The id assigned to the written value.
    pub id: u64,
    /// The coordinator's post-write context, returned **only when the
    /// write left no concurrent siblings** — the one case where chaining
    /// another PUT on it is causally sound (it covers nothing the client
    /// has not observed). When a concurrent sibling survived, this is
    /// `None` and the stored context is consumed: the client must GET —
    /// and thereby observe the siblings — before it can supersede them.
    pub ctx: Option<CausalCtx>,
}

/// The canonical client surface (paper §2): GET returns siblings plus
/// an opaque context, PUT supplies that context back. Implemented by
/// [`SimClient`], [`LocalClient`], and [`TcpClient`].
pub trait KvClient {
    /// The actor identity this client writes as (oracle ground truth).
    fn actor(&self) -> Actor;

    /// Read a key: current siblings plus the causal-context token.
    fn get(&mut self, key: &str) -> Result<GetReply>;

    /// Write a key. `ctx` is the token from this client's latest GET of
    /// the key (`None` = blind write — the concurrency the paper's
    /// anomalies feed on).
    fn put(&mut self, key: &str, value: Vec<u8>, ctx: Option<&CausalCtx>) -> Result<PutReply>;
}

/// The typed-datatype client surface ([`crate::kernel::crdt`]):
/// server-side CRDT ops addressed by key. Unlike GET/PUT there is no
/// client-held context — the coordinator reads, mutates, and writes
/// under its own causal state, so the ops are single round trips and
/// conflict resolution never reaches the client. Implemented by all
/// three transports; workload harnesses are written once against this
/// trait ([`drive_set_workload`]).
pub trait TypedKvClient: KvClient {
    /// Add an element to an observed-remove set; returns the minted dot.
    fn sadd(&mut self, key: &str, elem: &[u8]) -> Result<Dot>;

    /// Remove an element's observed dots; returns the dots removed
    /// (empty = the element was not present at the coordinator's read).
    fn srem(&mut self, key: &str, elem: &[u8]) -> Result<Vec<Dot>>;

    /// List a set's members.
    fn smembers(&mut self, key: &str) -> Result<Vec<Vec<u8>>>;

    /// Add a signed delta to a PN-counter; returns the post-op value.
    fn incr(&mut self, key: &str, by: i64) -> Result<i64>;

    /// Read a PN-counter's value (0 when the key is absent).
    fn count(&mut self, key: &str) -> Result<i64>;

    /// Write a field in an observed-remove map; returns the minted dot.
    fn mput(&mut self, key: &str, field: &[u8], value: &[u8]) -> Result<Dot>;

    /// Read a field from an observed-remove map (`None` = absent).
    fn mget(&mut self, key: &str, field: &[u8]) -> Result<Option<Vec<u8>>>;
}

/// Per-client token cache: the §2 client state ("nothing but the
/// context of the last GET"), updated from replies so no id or context
/// is ever threaded by hand.
#[derive(Debug, Clone, Default)]
pub struct Session {
    ctxs: HashMap<String, CausalCtx>,
}

impl Session {
    /// Empty session.
    pub fn new() -> Session {
        Session::default()
    }

    /// The token to attach to a PUT of `key` (`None` = blind).
    pub fn ctx_for(&self, key: &str) -> Option<&CausalCtx> {
        self.ctxs.get(key)
    }

    /// Record a GET's reply for `key`.
    pub fn record_get(&mut self, key: &str, reply: &GetReply) {
        self.ctxs.insert(key.to_string(), reply.ctx.clone());
    }

    /// Record a PUT's reply for `key`: the returned post-write context
    /// replaces the stored one (or, absent one, the context is
    /// consumed — a stale context must never leak into a blind write).
    pub fn record_put(&mut self, key: &str, reply: &PutReply) {
        match &reply.ctx {
            Some(ctx) => {
                self.ctxs.insert(key.to_string(), ctx.clone());
            }
            None => {
                self.ctxs.remove(key);
            }
        }
    }
}

/// Outcome counts from [`drive_workload`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RunReport {
    /// Operations that succeeded.
    pub ok_ops: u64,
    /// Operations that failed (quorum not met / unavailable — expected
    /// under active faults).
    pub failed_ops: u64,
    /// Successful GETs.
    pub gets: u64,
    /// Successful PUTs.
    pub puts: u64,
    /// Largest sibling set any GET returned.
    pub max_siblings: usize,
}

/// Stable key-string naming for workload keys (see
/// [`crate::workload::key_name`]): every transport hashes the same
/// string onto the same ring position.
pub use crate::workload::key_name;

/// Deterministic PUT payload for `(client, seq)` — the same across
/// transports, so fault-free runs converge to identical value sets.
pub fn payload(client: usize, seq: u64, len: u32) -> Vec<u8> {
    let tag = format!("c{client}-w{seq}-");
    tag.into_bytes().into_iter().cycle().take(len as usize).collect()
}

/// Drive a workload [`Driver`] against one [`KvClient`] per client:
/// round-robin, closed-loop, sessions managed internally. This is the
/// single harness every transport runs under — the Zipf workloads, the
/// fault schedules, and the oracle audits never see a concrete
/// transport. `on_op(completed)` fires after every finished (or failed)
/// op — the hook chaos tests use to step a
/// [`crate::sim::failure::FaultPlan`] along the run.
///
/// Op failures are tolerated (they are the point of fault windows) and
/// tallied in the report; think times shape the virtual clock handed to
/// the driver but are not slept.
pub fn drive_workload<C: KvClient>(
    clients: &mut [C],
    driver: &mut dyn Driver,
    seed: u64,
    mut on_op: impl FnMut(u64),
) -> RunReport {
    let mut rng = Rng::new(seed);
    let mut sessions: Vec<Session> = (0..clients.len()).map(|_| Session::new()).collect();
    let mut put_seq: Vec<u64> = vec![0; clients.len()];
    let mut live: Vec<bool> = vec![true; clients.len()];
    let mut report = RunReport::default();
    let mut now_us: u64 = 0;
    let mut completed: u64 = 0;
    while live.iter().any(|&l| l) {
        for (i, client) in clients.iter_mut().enumerate() {
            if !live[i] {
                continue;
            }
            let Some(op) = driver.next_op(i, now_us, &mut rng) else {
                live[i] = false;
                continue;
            };
            now_us += op.think_us;
            let key = key_name(op.key);
            let outcome = match op.kind {
                OpKind::Get => client.get(&key).map(|reply| {
                    report.gets += 1;
                    report.max_siblings = report.max_siblings.max(reply.values.len());
                    sessions[i].record_get(&key, &reply);
                }),
                OpKind::Put { len } => {
                    let seq = put_seq[i];
                    put_seq[i] += 1;
                    let value = payload(i, seq, len);
                    let ctx = sessions[i].ctx_for(&key).cloned();
                    client.put(&key, value, ctx.as_ref()).map(|reply| {
                        report.puts += 1;
                        sessions[i].record_put(&key, &reply);
                    })
                }
            };
            match outcome {
                Ok(()) => report.ok_ops += 1,
                Err(_) => report.failed_ops += 1,
            }
            completed += 1;
            on_op(completed);
        }
    }
    report
}

/// Outcome counts from [`drive_set_workload`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SetRunReport {
    /// Operations that succeeded.
    pub ok_ops: u64,
    /// Operations that failed (expected under active faults).
    pub failed_ops: u64,
    /// Acked SADDs.
    pub adds: u64,
    /// Acked SREMs.
    pub removes: u64,
    /// Successful SMEMBERS reads.
    pub reads: u64,
    /// Largest membership any read returned.
    pub max_members: usize,
}

/// Drive a seeded ORSWOT workload against one [`TypedKvClient`] per
/// client: round-robin, closed-loop, every op's outcome recorded into
/// the [`SetAudit`] (acked ops become claims; failed ops become taint —
/// an in-doubt op may have partially landed). The typed-op counterpart
/// of [`drive_workload`]: chaos tests run it unchanged across all three
/// transports and compare [`crate::oracle::SetVerdict`]s. `on_op` fires
/// after every completed (or failed) op, the hook fault plans step on.
pub fn drive_set_workload<C: TypedKvClient>(
    clients: &mut [C],
    workload: &mut SetWorkload,
    key: &str,
    seed: u64,
    audit: &SetAudit,
    mut on_op: impl FnMut(u64),
) -> SetRunReport {
    let mut rng = Rng::new(seed);
    let mut live: Vec<bool> = vec![true; clients.len()];
    let mut report = SetRunReport::default();
    let mut completed: u64 = 0;
    while live.iter().any(|&l| l) {
        for (i, client) in clients.iter_mut().enumerate() {
            if !live[i] {
                continue;
            }
            let Some(op) = workload.next_set_op(i, &mut rng) else {
                live[i] = false;
                continue;
            };
            let ok = match op {
                SetOpKind::Add(idx) => {
                    let elem = crate::workload::set_elem(idx);
                    match client.sadd(key, &elem) {
                        Ok(_dot) => {
                            audit.add_ok(&elem);
                            report.adds += 1;
                            true
                        }
                        Err(_) => {
                            audit.add_failed(&elem);
                            false
                        }
                    }
                }
                SetOpKind::Remove(idx) => {
                    let elem = crate::workload::set_elem(idx);
                    match client.srem(key, &elem) {
                        Ok(_dots) => {
                            audit.remove_ok(&elem);
                            report.removes += 1;
                            true
                        }
                        Err(_) => {
                            audit.remove_failed(&elem);
                            false
                        }
                    }
                }
                SetOpKind::Members => match client.smembers(key) {
                    Ok(members) => {
                        report.reads += 1;
                        report.max_members = report.max_members.max(members.len());
                        true
                    }
                    Err(_) => false,
                },
            };
            if ok {
                report.ok_ops += 1;
            } else {
                report.failed_ops += 1;
            }
            completed += 1;
            on_op(completed);
        }
    }
    report
}

/// Read the current sibling values for every workload key through a
/// client (sorted, so transports can be compared set-wise).
pub fn snapshot_values<C: KvClient>(
    client: &mut C,
    keys: u64,
) -> Result<Vec<(Key, Vec<Vec<u8>>)>> {
    let mut out = Vec::with_capacity(keys as usize);
    for key in 0..keys {
        let mut reply = client.get(&key_name(key))?;
        reply.values.sort();
        out.push((key, reply.values));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_roundtrips() {
        for ctx in [
            CausalCtx::default(),
            CausalCtx::new(vec![1, 0, 5], vec![]),
            CausalCtx::new(vec![], vec![7, 8, 9]),
            CausalCtx::new(vec![2, 0, 3, 1, 9], vec![u64::MAX, 0, 300]),
        ] {
            let bytes = ctx.encode();
            assert_eq!(CausalCtx::decode(&bytes).unwrap(), ctx, "{ctx:?}");
        }
    }

    #[test]
    fn token_rejects_version_skew_and_truncation() {
        let mut bytes = CausalCtx::new(vec![1, 2, 3], vec![4, 5]).encode();
        // every strict prefix is rejected
        for cut in 0..bytes.len() {
            assert!(CausalCtx::decode(&bytes[..cut]).is_err(), "prefix {cut}");
        }
        // trailing garbage is rejected
        let mut long = bytes.clone();
        long.push(0);
        assert!(CausalCtx::decode(&long).is_err());
        // version skew is rejected
        bytes[0] = CTX_VERSION + 1;
        let err = CausalCtx::decode(&bytes).unwrap_err();
        assert!(err.to_string().contains("unsupported"), "{err}");
    }

    #[test]
    fn session_updates_itself_from_replies() {
        let mut s = Session::new();
        assert!(s.ctx_for("k").is_none());
        let get = GetReply {
            values: vec![b"a".to_vec()],
            ctx: CausalCtx::new(vec![1, 0, 1], vec![10]),
        };
        s.record_get("k", &get);
        assert_eq!(s.ctx_for("k"), Some(&get.ctx));

        // a PUT reply with a post-write context replaces the stored one
        let put = PutReply { id: 11, ctx: Some(CausalCtx::new(vec![1, 0, 2], vec![11])) };
        s.record_put("k", &put);
        assert_eq!(s.ctx_for("k"), put.ctx.as_ref());

        // a context-less reply consumes the stored context
        s.record_put("k", &PutReply { id: 12, ctx: None });
        assert!(s.ctx_for("k").is_none());
    }

    #[test]
    fn payloads_are_deterministic_and_distinct() {
        assert_eq!(payload(0, 1, 16), payload(0, 1, 16));
        assert_ne!(payload(0, 1, 16), payload(1, 1, 16));
        assert_ne!(payload(0, 1, 16), payload(0, 2, 16));
        assert_eq!(payload(3, 9, 32).len(), 32);
        assert!(payload(0, 0, 0).is_empty());
    }
}
