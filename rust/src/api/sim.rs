//! [`KvClient`] over the deterministic discrete-event simulator.
//!
//! The DES normally runs closed-loop behind a [`Driver`]; here it runs
//! *interactively* instead: each API call issues one op and pumps the
//! event queue until that op resolves ([`crate::sim::Sim::sync_get`] /
//! [`crate::sim::Sim::sync_put`]), advancing virtual time — and firing
//! any scheduled faults — along the way. Payload bytes live in a side
//! table (the simulator itself tracks value identity + length only).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use super::{CausalCtx, GetReply, KvClient, PutReply, TypedKvClient};
use crate::clocks::encoding::{decode_vv, encode_vv};
use crate::clocks::{Actor, VersionVector};
use crate::cluster::ring::hash_str;
use crate::config::StoreConfig;
use crate::error::Result;
use crate::kernel::crdt::Dot;
use crate::kernel::mechs::DvvMech;
use crate::sim::Sim;
use crate::testkit::Rng;
use crate::workload::{Driver, Op};

/// A driver that never issues ops: the interactive sim has no closed
/// loop of its own — every op arrives through the API.
struct Idle;

impl Driver for Idle {
    fn next_op(&mut self, _client: usize, _now_us: u64, _rng: &mut Rng) -> Option<Op> {
        None
    }
}

struct SimInner {
    sim: Sim<DvvMech>,
    /// Write id → payload bytes (the sim's `Val` carries identity only).
    blobs: HashMap<u64, Vec<u8>>,
}

/// One interactive DVV simulator shared by its [`SimClient`]s
/// (single-threaded, like the DES itself).
pub struct SimTransport {
    inner: Rc<RefCell<SimInner>>,
}

impl SimTransport {
    /// Build an interactive simulator for `clients` API clients.
    pub fn new(cfg: StoreConfig, clients: usize, seed: u64) -> Result<SimTransport> {
        let sim = Sim::new(DvvMech, cfg, clients, true, Box::new(Idle), seed)?;
        Ok(SimTransport {
            inner: Rc::new(RefCell::new(SimInner { sim, blobs: HashMap::new() })),
        })
    }

    /// The [`KvClient`] for client slot `idx`.
    pub fn client(&self, idx: usize) -> SimClient {
        SimClient { inner: Rc::clone(&self.inner), idx }
    }

    /// Run a closure against the underlying simulator (fault scheduling
    /// before the run, settling and audits after).
    pub fn with_sim<R>(&self, f: impl FnOnce(&mut Sim<DvvMech>) -> R) -> R {
        f(&mut self.inner.borrow_mut().sim)
    }
}

/// [`KvClient`] over one [`SimTransport`] client slot.
pub struct SimClient {
    inner: Rc<RefCell<SimInner>>,
    idx: usize,
}

impl KvClient for SimClient {
    fn actor(&self) -> Actor {
        Actor::client(self.idx as u32)
    }

    fn get(&mut self, key: &str) -> Result<GetReply> {
        let mut inner = self.inner.borrow_mut();
        let (values, ctx) = inner.sim.sync_get(self.idx, hash_str(key))?;
        let ids: Vec<u64> = values.iter().map(|v| v.id).collect();
        let bytes: Vec<Vec<u8>> = values
            .iter()
            .map(|v| inner.blobs.get(&v.id).cloned().unwrap_or_default())
            .collect();
        let mut vv = Vec::new();
        encode_vv(&ctx, &mut vv);
        Ok(GetReply { values: bytes, ctx: CausalCtx::new(vv, ids) })
    }

    fn put(&mut self, key: &str, value: Vec<u8>, ctx: Option<&CausalCtx>) -> Result<PutReply> {
        let (vv, observed): (VersionVector, Vec<u64>) = match ctx {
            Some(c) if !c.vv_bytes().is_empty() => {
                let mut pos = 0;
                (decode_vv(c.vv_bytes(), &mut pos)?, c.observed().to_vec())
            }
            Some(c) => (VersionVector::new(), c.observed().to_vec()),
            None => (VersionVector::new(), Vec::new()),
        };
        let len = value.len() as u32;
        let mut inner = self.inner.borrow_mut();
        // record the payload BEFORE issuing: a PUT that fails its quorum
        // has often still landed at the coordinator (sloppy semantics),
        // and its sibling must resolve to real bytes on later GETs. If
        // the op fails before the id is consumed, the next write's
        // pre-insert simply overwrites this entry.
        let id = inner.sim.peek_next_val();
        inner.blobs.insert(id, value);
        let (id, post) = inner.sim.sync_put(self.idx, hash_str(key), len, &vv, &observed)?;
        let ctx = post.map(|post| {
            let mut post_bytes = Vec::new();
            encode_vv(&post, &mut post_bytes);
            CausalCtx::new(post_bytes, vec![id])
        });
        Ok(PutReply { id, ctx })
    }
}

impl TypedKvClient for SimClient {
    // Typed payloads live in the sim's own side table (the op is a
    // server-side RMW — the client never holds the state bytes), so
    // these are straight delegations into the DES sync typed ops.
    fn sadd(&mut self, key: &str, elem: &[u8]) -> Result<Dot> {
        self.inner.borrow_mut().sim.sync_sadd(self.idx, hash_str(key), elem)
    }

    fn srem(&mut self, key: &str, elem: &[u8]) -> Result<Vec<Dot>> {
        self.inner.borrow_mut().sim.sync_srem(self.idx, hash_str(key), elem)
    }

    fn smembers(&mut self, key: &str) -> Result<Vec<Vec<u8>>> {
        self.inner.borrow_mut().sim.sync_smembers(self.idx, hash_str(key))
    }

    fn incr(&mut self, key: &str, by: i64) -> Result<i64> {
        self.inner.borrow_mut().sim.sync_incr(self.idx, hash_str(key), by)
    }

    fn count(&mut self, key: &str) -> Result<i64> {
        self.inner.borrow_mut().sim.sync_count(self.idx, hash_str(key))
    }

    fn mput(&mut self, key: &str, field: &[u8], value: &[u8]) -> Result<Dot> {
        self.inner.borrow_mut().sim.sync_mput(self.idx, hash_str(key), field, value)
    }

    fn mget(&mut self, key: &str, field: &[u8]) -> Result<Option<Vec<u8>>> {
        self.inner.borrow_mut().sim.sync_mget(self.idx, hash_str(key), field)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_client_get_put_siblings_supersede() {
        let mut cfg = StoreConfig::default();
        cfg.cluster.nodes = 3;
        cfg.cluster.replication = 3;
        cfg.cluster.read_quorum = 2;
        cfg.cluster.write_quorum = 2;
        let transport = SimTransport::new(cfg, 2, 42).unwrap();
        let mut c0 = transport.client(0);
        let mut c1 = transport.client(1);

        // blind writes from two clients -> siblings with real payloads
        c0.put("k", b"v1".to_vec(), None).unwrap();
        c1.put("k", b"v2".to_vec(), None).unwrap();
        let reply = c0.get("k").unwrap();
        let mut values = reply.values.clone();
        values.sort();
        assert_eq!(values, vec![b"v1".to_vec(), b"v2".to_vec()]);
        assert_eq!(reply.ids().len(), 2);

        // an informed write with the GET's token supersedes both
        c0.put("k", b"merged".to_vec(), Some(&reply.ctx)).unwrap();
        let after = c0.get("k").unwrap();
        assert_eq!(after.values, vec![b"merged".to_vec()]);
        transport.with_sim(|sim| {
            assert_eq!(sim.metrics.lost_updates, 0);
            assert!(sim.oracle.tracked() >= 3);
        });
    }

    #[test]
    fn sim_client_typed_ops_roundtrip() {
        let mut cfg = StoreConfig::default();
        cfg.cluster.nodes = 3;
        cfg.cluster.replication = 3;
        cfg.cluster.read_quorum = 2;
        cfg.cluster.write_quorum = 2;
        let transport = SimTransport::new(cfg, 2, 9).unwrap();
        let mut c0 = transport.client(0);
        let mut c1 = transport.client(1);

        c0.sadd("s", b"a").unwrap();
        c1.sadd("s", b"b").unwrap();
        assert_eq!(c0.smembers("s").unwrap(), vec![b"a".to_vec(), b"b".to_vec()]);
        assert_eq!(c0.srem("s", b"a").unwrap().len(), 1);
        assert_eq!(c1.smembers("s").unwrap(), vec![b"b".to_vec()]);

        assert_eq!(c0.incr("n", 4).unwrap(), 4);
        assert_eq!(c1.incr("n", -1).unwrap(), 3);
        assert_eq!(c1.count("n").unwrap(), 3);

        c0.mput("m", b"f", b"v").unwrap();
        assert_eq!(c1.mget("m", b"f").unwrap(), Some(b"v".to_vec()));
        assert_eq!(c1.mget("m", b"g").unwrap(), None);

        // kind confusion is rejected, not corrupting
        assert!(matches!(
            c0.incr("s", 1),
            Err(crate::error::Error::WrongType { .. })
        ));
        assert_eq!(c1.smembers("s").unwrap(), vec![b"b".to_vec()]);
    }

    #[test]
    fn put_reply_context_chains_without_rereading() {
        let transport = SimTransport::new(StoreConfig::default(), 1, 7).unwrap();
        let mut c = transport.client(0);
        let first = c.put("k", b"one".to_vec(), None).unwrap();
        // chain on the returned post-write context: no GET in between
        c.put("k", b"two".to_vec(), first.ctx.as_ref()).unwrap();
        let reply = c.get("k").unwrap();
        assert_eq!(reply.values, vec![b"two".to_vec()], "chained write supersedes");
    }
}
