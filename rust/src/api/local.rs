//! [`KvClient`] over the threaded in-process cluster.

use std::sync::Arc;

use super::{CausalCtx, GetReply, KvClient, PutReply, TypedKvClient};
use crate::clocks::Actor;
use crate::error::Result;
use crate::kernel::crdt::Dot;
use crate::kernel::mechs::DvvMech;
use crate::server::LocalCluster;
use crate::store::{ShardedBackend, StorageBackend};

/// A client of one [`LocalCluster`]: ops go straight at the quorum
/// paths under real concurrency, every inter-replica hop consulting the
/// cluster's chaos fabric, and — with a
/// [`crate::oracle::SharedOracle`] attached — every PUT is traced
/// (actor + observed ids travel with the write).
pub struct LocalClient<B: StorageBackend<DvvMech> = ShardedBackend<DvvMech>> {
    cluster: Arc<LocalCluster<B>>,
    actor: Actor,
}

impl<B: StorageBackend<DvvMech>> LocalClient<B> {
    /// A client writing as `actor` (one sequential actor per client —
    /// the oracle's ground-truth assumption).
    pub fn new(cluster: Arc<LocalCluster<B>>, actor: Actor) -> LocalClient<B> {
        LocalClient { cluster, actor }
    }
}

impl<B: StorageBackend<DvvMech>> KvClient for LocalClient<B> {
    fn actor(&self) -> Actor {
        self.actor
    }

    fn get(&mut self, key: &str) -> Result<GetReply> {
        let ans = self.cluster.get(key)?;
        Ok(GetReply { values: ans.values, ctx: CausalCtx::new(ans.context, ans.ids) })
    }

    fn put(&mut self, key: &str, value: Vec<u8>, ctx: Option<&CausalCtx>) -> Result<PutReply> {
        let (vv, observed): (&[u8], &[u64]) = match ctx {
            Some(c) => (c.vv_bytes(), c.observed()),
            None => (&[], &[]),
        };
        let (id, post) = self.cluster.put_api(key, value, vv, self.actor, observed)?;
        Ok(PutReply { id, ctx: post.map(|post| CausalCtx::new(post, vec![id])) })
    }
}

impl<B: StorageBackend<DvvMech>> TypedKvClient for LocalClient<B> {
    fn sadd(&mut self, key: &str, elem: &[u8]) -> Result<Dot> {
        self.cluster.set_add(key, elem)
    }

    fn srem(&mut self, key: &str, elem: &[u8]) -> Result<Vec<Dot>> {
        self.cluster.set_remove(key, elem)
    }

    fn smembers(&mut self, key: &str) -> Result<Vec<Vec<u8>>> {
        self.cluster.set_members(key)
    }

    fn incr(&mut self, key: &str, by: i64) -> Result<i64> {
        self.cluster.counter_incr(key, by)
    }

    fn count(&mut self, key: &str) -> Result<i64> {
        self.cluster.counter_value(key)
    }

    fn mput(&mut self, key: &str, field: &[u8], value: &[u8]) -> Result<Dot> {
        self.cluster.map_put(key, field, value)
    }

    fn mget(&mut self, key: &str, field: &[u8]) -> Result<Option<Vec<u8>>> {
        self.cluster.map_get(key, field)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oracle::SharedOracle;

    #[test]
    fn local_client_flow_is_traced() {
        let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
        let oracle = Arc::new(SharedOracle::new());
        cluster.attach_oracle(Arc::clone(&oracle));
        let mut c0 = LocalClient::new(Arc::clone(&cluster), Actor::client(0));
        let mut c1 = LocalClient::new(Arc::clone(&cluster), Actor::client(1));

        c0.put("k", b"v1".to_vec(), None).unwrap();
        c1.put("k", b"v2".to_vec(), None).unwrap();
        let reply = c0.get("k").unwrap();
        assert_eq!(reply.values.len(), 2, "blind writes are concurrent");
        assert_eq!(reply.ids().len(), 2);

        let merged = c0.put("k", b"m".to_vec(), Some(&reply.ctx)).unwrap();
        assert_eq!(c0.get("k").unwrap().values, vec![b"m".to_vec()]);
        assert!(merged.ctx.is_some(), "post-write context returned");
        assert_eq!(oracle.lost_updates(), 0);
        assert_eq!(oracle.unaudited_drops(), 0, "API writes are fully traced");
        assert!(oracle.correct_supersessions() > 0);
    }

    #[test]
    fn put_reply_context_chains_without_rereading() {
        let cluster = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
        let mut c = LocalClient::new(cluster, Actor::client(0));
        let first = c.put("k", b"one".to_vec(), None).unwrap();
        c.put("k", b"two".to_vec(), first.ctx.as_ref()).unwrap();
        assert_eq!(c.get("k").unwrap().values, vec![b"two".to_vec()]);
    }
}
