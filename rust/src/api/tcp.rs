//! [`KvClient`] over real sockets: binary wire protocol v2.
//!
//! [`TcpClient::connect`] performs the magic/version negotiation and
//! then speaks length-prefixed frames exclusively — no hex on the hot
//! path. PUT frames carry the client's actor id and its [`CausalCtx`]
//! token, so a server-side oracle audits live-TCP traffic exactly like
//! in-process traffic.

use std::io::{BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};

use super::{CausalCtx, GetReply, KvClient, PutReply};
use crate::clocks::Actor;
use crate::error::{Error, Result};
use crate::server::protocol::{self, BinRequest};

/// A connected protocol-v2 client.
pub struct TcpClient {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
    actor: Actor,
    /// Last topology epoch this client observed (from
    /// [`topology`](TcpClient::topology), [`join`](TcpClient::join),
    /// [`decommission`](TcpClient::decommission), or
    /// [`stats`](TcpClient::stats)); `0` until the first observation.
    seen_epoch: u64,
}

/// One membership view as reported by the server
/// ([`protocol::OP_TOPOLOGY_REPLY`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopologyView {
    /// Monotone membership epoch.
    pub epoch: u64,
    /// Total dense node ids allocated (members + decommissioned).
    pub slots: u64,
    /// Active member ids, ascending.
    pub members: Vec<u64>,
}

/// Map an unexpected reply frame onto an error: the server's `ERR`
/// payload verbatim, or a protocol error for anything else.
fn remote_err((opcode, payload): (u8, Vec<u8>)) -> Error {
    if opcode == protocol::OP_ERR {
        Error::Remote(String::from_utf8_lossy(&payload).into_owned())
    } else {
        Error::Protocol(format!("unexpected reply opcode {opcode:#04x}"))
    }
}

impl TcpClient {
    /// Connect and negotiate protocol v2: send the magic preamble, then
    /// require the server's `HELLO_ACK`. Fails cleanly (with the
    /// server's message) on version skew.
    pub fn connect(addr: impl ToSocketAddrs, actor: Actor) -> Result<TcpClient> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.write_all(&protocol::MAGIC)?;
        stream.write_all(&[protocol::VERSION, b'\n'])?;
        let mut reader = BufReader::new(stream.try_clone()?);
        match protocol::read_frame(&mut reader)? {
            (protocol::OP_HELLO_ACK, payload) if payload == [protocol::VERSION] => {
                Ok(TcpClient { reader, stream, actor, seen_epoch: 0 })
            }
            reply => Err(remote_err(reply)),
        }
    }

    /// One request frame out, one reply frame back.
    fn roundtrip(&mut self, req: &BinRequest) -> Result<(u8, Vec<u8>)> {
        let (opcode, payload) = protocol::encode_bin_request(req);
        protocol::write_frame(&mut self.stream, opcode, &payload)?;
        protocol::read_frame(&mut self.reader)
    }

    /// Pipeline: write every request frame back-to-back, then read the
    /// replies. The reactor serve loop executes pipelined frames
    /// concurrently on its worker pool but delivers replies in request
    /// order — `replies[i]` always answers `reqs[i]`.
    ///
    /// Replies are raw `(opcode, payload)` frames; callers decode (and
    /// decide per-slot whether an `OP_ERR` is fatal). Don't pipeline a
    /// `Quit`: the server closes after the `BYE`, so later slots would
    /// error out.
    pub fn pipeline(&mut self, reqs: &[BinRequest]) -> Result<Vec<(u8, Vec<u8>)>> {
        let mut batch = Vec::new();
        for req in reqs {
            let (opcode, payload) = protocol::encode_bin_request(req);
            protocol::write_frame(&mut batch, opcode, &payload)?;
        }
        self.stream.write_all(&batch)?;
        let mut replies = Vec::with_capacity(reqs.len());
        for _ in reqs {
            replies.push(protocol::read_frame(&mut self.reader)?);
        }
        Ok(replies)
    }

    /// Pipelined multi-GET: all keys in flight on this one connection,
    /// replies decoded in key order.
    pub fn pipeline_get(&mut self, keys: &[&str]) -> Result<Vec<GetReply>> {
        let reqs: Vec<BinRequest> =
            keys.iter().map(|k| BinRequest::Get { key: (*k).to_string() }).collect();
        let mut out = Vec::with_capacity(keys.len());
        for reply in self.pipeline(&reqs)? {
            match reply {
                (protocol::OP_VALUES, payload) => {
                    let (values, token) = protocol::decode_values(&payload)?;
                    out.push(GetReply { values, ctx: CausalCtx::decode(&token)? });
                }
                other => return Err(remote_err(other)),
            }
        }
        Ok(out)
    }

    /// Run a `FAULT`/`HEAL`/`RESTART`/`WIPE` admin command (text form)
    /// over the binary connection — chaos-engineering a live server,
    /// state loss included.
    pub fn admin(&mut self, line: &str) -> Result<()> {
        match self.roundtrip(&BinRequest::Admin { line: line.to_string() })? {
            (protocol::OP_OK, _) => Ok(()),
            reply => Err(remote_err(reply)),
        }
    }

    /// Server statistics:
    /// `(nodes, shards, metadata_bytes, hints, epoch, wal_bytes, merkle_root)`.
    #[allow(clippy::type_complexity)]
    pub fn stats(&mut self) -> Result<(u64, u64, u64, u64, u64, u64, u64)> {
        match self.roundtrip(&BinRequest::Stats)? {
            (protocol::OP_STATS_REPLY, payload) => {
                let stats = protocol::decode_stats_reply(&payload)?;
                self.seen_epoch = self.seen_epoch.max(stats.4);
                Ok(stats)
            }
            reply => Err(remote_err(reply)),
        }
    }

    /// Decode a topology frame, tracking the freshest epoch seen.
    fn topology_view(&mut self, payload: &[u8]) -> Result<TopologyView> {
        let (epoch, slots, members) = protocol::decode_topology_reply(payload)?;
        self.seen_epoch = self.seen_epoch.max(epoch);
        Ok(TopologyView { epoch, slots, members })
    }

    /// Discover (or refresh) the server's membership view mid-session —
    /// routing is server-side, so a client only needs this to *observe*
    /// an epoch bump; its GET/PUT session keeps working across one
    /// untouched.
    pub fn topology(&mut self) -> Result<TopologyView> {
        match self.roundtrip(&BinRequest::Topology)? {
            (protocol::OP_TOPOLOGY_REPLY, payload) => self.topology_view(&payload),
            reply => Err(remote_err(reply)),
        }
    }

    /// Admin: spin up a new replica. Returns `(new node id, view)` —
    /// the join reply's `slots` field is pinned to this request, so
    /// `slots - 1` is the id the server assigned it (stable even when
    /// joins race).
    pub fn join(&mut self) -> Result<(u64, TopologyView)> {
        match self.roundtrip(&BinRequest::Join)? {
            (protocol::OP_TOPOLOGY_REPLY, payload) => {
                let view = self.topology_view(&payload)?;
                // a remote reply is untrusted input: reject slots=0
                // instead of underflowing
                let id = view
                    .slots
                    .checked_sub(1)
                    .ok_or_else(|| Error::Protocol("join reply with zero slots".into()))?;
                Ok((id, view))
            }
            reply => Err(remote_err(reply)),
        }
    }

    /// Admin: retire a replica, handing off its keys. Returns the
    /// post-retirement view.
    pub fn decommission(&mut self, node: u64) -> Result<TopologyView> {
        let node = usize::try_from(node)
            .map_err(|_| Error::Protocol(format!("node id {node} out of range")))?;
        match self.roundtrip(&BinRequest::Decommission { node })? {
            (protocol::OP_TOPOLOGY_REPLY, payload) => self.topology_view(&payload),
            reply => Err(remote_err(reply)),
        }
    }

    /// The freshest topology epoch this client has observed (0 before
    /// any stats/topology/join/decommission reply).
    pub fn seen_epoch(&self) -> u64 {
        self.seen_epoch
    }

    /// Close the connection politely (waits for the server's `BYE`).
    pub fn quit(mut self) -> Result<()> {
        match self.roundtrip(&BinRequest::Quit)? {
            (protocol::OP_BYE, _) => Ok(()),
            reply => Err(remote_err(reply)),
        }
    }
}

impl KvClient for TcpClient {
    fn actor(&self) -> Actor {
        self.actor
    }

    fn get(&mut self, key: &str) -> Result<GetReply> {
        match self.roundtrip(&BinRequest::Get { key: key.to_string() })? {
            (protocol::OP_VALUES, payload) => {
                let (values, token) = protocol::decode_values(&payload)?;
                Ok(GetReply { values, ctx: CausalCtx::decode(&token)? })
            }
            reply => Err(remote_err(reply)),
        }
    }

    fn put(&mut self, key: &str, value: Vec<u8>, ctx: Option<&CausalCtx>) -> Result<PutReply> {
        let token = ctx.map(CausalCtx::encode).unwrap_or_default();
        let req = BinRequest::Put {
            key: key.to_string(),
            value,
            actor: self.actor.0,
            ctx_token: token,
        };
        match self.roundtrip(&req)? {
            (protocol::OP_PUT_OK, payload) => {
                let (id, token) = protocol::decode_put_ok(&payload)?;
                // empty token = no chainable post-write context (a
                // concurrent sibling survived the write)
                let ctx = if token.is_empty() { None } else { Some(CausalCtx::decode(&token)?) };
                Ok(PutReply { id, ctx })
            }
            reply => Err(remote_err(reply)),
        }
    }
}
