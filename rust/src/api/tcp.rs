//! [`KvClient`] over real sockets: binary wire protocol v2.
//!
//! [`TcpClient::connect`] performs the magic/version negotiation and
//! then speaks length-prefixed frames exclusively — no hex on the hot
//! path. PUT frames carry the client's actor id and its [`CausalCtx`]
//! token, so a server-side oracle audits live-TCP traffic exactly like
//! in-process traffic.

use std::io::{BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};

use super::{CausalCtx, GetReply, KvClient, PutReply, TypedKvClient};
use crate::clocks::{Actor, HlcTimestamp};
use crate::error::{Error, Result};
use crate::kernel::crdt::Dot;
use crate::server::protocol::{self, BinRequest};

/// A connected protocol-v2 client.
pub struct TcpClient {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
    actor: Actor,
    /// Last topology epoch this client observed (from
    /// [`topology`](TcpClient::topology), [`join`](TcpClient::join),
    /// [`decommission`](TcpClient::decommission), or
    /// [`stats`](TcpClient::stats)); `0` until the first observation.
    seen_epoch: u64,
}

/// One membership view as reported by the server
/// ([`protocol::OP_TOPOLOGY_REPLY`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TopologyView {
    /// Monotone membership epoch.
    pub epoch: u64,
    /// Total dense node ids allocated (members + decommissioned).
    pub slots: u64,
    /// Active member ids, ascending.
    pub members: Vec<u64>,
}

/// Map an unexpected reply frame onto an error: the server's `ERR`
/// payload verbatim, or a protocol error for anything else.
fn remote_err((opcode, payload): (u8, Vec<u8>)) -> Error {
    if opcode == protocol::OP_ERR {
        Error::Remote(String::from_utf8_lossy(&payload).into_owned())
    } else {
        Error::Protocol(format!("unexpected reply opcode {opcode:#04x}"))
    }
}

/// Parse complete `[u32 BE len][opcode][payload]` frames off the front
/// of `acc` into `replies`, stopping at `want` replies or the first
/// incomplete frame (whose bytes stay in `acc` for the next read).
fn take_frames(acc: &mut Vec<u8>, replies: &mut Vec<(u8, Vec<u8>)>, want: usize) -> Result<()> {
    let mut consumed = 0;
    while replies.len() < want {
        let rest = &acc[consumed..];
        if rest.len() < 4 {
            break;
        }
        let len = protocol::frame_len([rest[0], rest[1], rest[2], rest[3]])?;
        if rest.len() < 4 + len {
            break;
        }
        replies.push((rest[4], rest[5..4 + len].to_vec()));
        consumed += 4 + len;
    }
    acc.drain(..consumed);
    Ok(())
}

impl TcpClient {
    /// Connect and negotiate protocol v2: send the magic preamble, then
    /// require the server's `HELLO_ACK`. Fails cleanly (with the
    /// server's message) on version skew.
    pub fn connect(addr: impl ToSocketAddrs, actor: Actor) -> Result<TcpClient> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.write_all(&protocol::MAGIC)?;
        stream.write_all(&[protocol::VERSION, b'\n'])?;
        let mut reader = BufReader::new(stream.try_clone()?);
        match protocol::read_frame(&mut reader)? {
            (protocol::OP_HELLO_ACK, payload) if payload == [protocol::VERSION] => {
                Ok(TcpClient { reader, stream, actor, seen_epoch: 0 })
            }
            reply => Err(remote_err(reply)),
        }
    }

    /// One request frame out, one reply frame back.
    fn roundtrip(&mut self, req: &BinRequest) -> Result<(u8, Vec<u8>)> {
        let (opcode, payload) = protocol::encode_bin_request(req);
        protocol::write_frame(&mut self.stream, opcode, &payload)?;
        protocol::read_frame(&mut self.reader)
    }

    /// Pipeline: push every request frame back-to-back on one
    /// connection, draining replies as they become available. The serve
    /// loop executes a connection's frames in request order and replies
    /// in request order — `replies[i]` always answers `reqs[i]`.
    ///
    /// Writes and reads are interleaved while the batch is in flight:
    /// the server bounds each connection's in-flight window and write
    /// backlog by *refusing to read*, so a client that wrote the whole
    /// batch before reading anything would deadlock against it the
    /// moment the batch's request bytes and reply bytes together
    /// overflow the socket buffers (server blocked writing replies,
    /// client blocked writing requests). Draining mid-write keeps
    /// batches of any size safe.
    ///
    /// Replies are raw `(opcode, payload)` frames; callers decode (and
    /// decide per-slot whether an `OP_ERR` is fatal). Don't pipeline a
    /// `Quit`: the server closes after the `BYE`, so later slots would
    /// error out.
    pub fn pipeline(&mut self, reqs: &[BinRequest]) -> Result<Vec<(u8, Vec<u8>)>> {
        let mut batch = Vec::new();
        for req in reqs {
            let (opcode, payload) = protocol::encode_bin_request(req);
            protocol::write_frame(&mut batch, opcode, &payload)?;
        }
        // Replies are read raw off the stream, bypassing `self.reader`:
        // between operations the connection is reply-quiescent, so the
        // BufReader holds no buffered bytes (read-ahead could only ever
        // buffer replies to requests already sent, and every prior
        // operation consumed its replies in full).
        let mut replies = Vec::with_capacity(reqs.len());
        let mut acc: Vec<u8> = Vec::new();
        self.stream.set_nonblocking(true)?;
        let wrote = self.write_draining(&batch, &mut acc, &mut replies, reqs.len());
        let restored = self.stream.set_nonblocking(false);
        wrote?;
        restored?;
        // batch fully written: blocking reads for the remaining replies
        let mut chunk = [0u8; 64 * 1024];
        loop {
            take_frames(&mut acc, &mut replies, reqs.len())?;
            if replies.len() == reqs.len() {
                break;
            }
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    return Err(Error::Protocol("connection closed mid-pipeline".into()));
                }
                Ok(n) => acc.extend_from_slice(&chunk[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
        if !acc.is_empty() {
            return Err(Error::Protocol("excess reply bytes after pipelined batch".into()));
        }
        Ok(replies)
    }

    /// The nonblocking half of [`TcpClient::pipeline`]: push `batch`,
    /// and whenever the kernel send buffer fills, drain whatever
    /// replies have arrived (that is what lets the server's write side
    /// progress, which is what lets it read from us again).
    fn write_draining(
        &mut self,
        batch: &[u8],
        acc: &mut Vec<u8>,
        replies: &mut Vec<(u8, Vec<u8>)>,
        want: usize,
    ) -> Result<()> {
        let mut chunk = [0u8; 64 * 1024];
        let mut sent = 0;
        while sent < batch.len() {
            match self.stream.write(&batch[sent..]) {
                Ok(0) => {
                    return Err(Error::Protocol("connection closed mid-pipeline".into()));
                }
                Ok(n) => sent += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    match self.stream.read(&mut chunk) {
                        Ok(0) => {
                            return Err(Error::Protocol("connection closed mid-pipeline".into()));
                        }
                        Ok(n) => {
                            acc.extend_from_slice(&chunk[..n]);
                            take_frames(acc, replies, want)?;
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            // neither direction ready: the server is
                            // still executing — yield instead of
                            // spinning (std has no portable poll here)
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                        Err(e) => return Err(e.into()),
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }

    /// Pipelined multi-GET: all keys in flight on this one connection,
    /// replies decoded in key order.
    pub fn pipeline_get(&mut self, keys: &[&str]) -> Result<Vec<GetReply>> {
        let reqs: Vec<BinRequest> =
            keys.iter().map(|k| BinRequest::Get { key: (*k).to_string() }).collect();
        let mut out = Vec::with_capacity(keys.len());
        for reply in self.pipeline(&reqs)? {
            match reply {
                (protocol::OP_VALUES, payload) => {
                    let (values, token) = protocol::decode_values(&payload)?;
                    out.push(GetReply { values, ctx: CausalCtx::decode(&token)? });
                }
                other => return Err(remote_err(other)),
            }
        }
        Ok(out)
    }

    /// Run a `FAULT`/`HEAL`/`RESTART`/`WIPE` admin command (text form)
    /// over the binary connection — chaos-engineering a live server,
    /// state loss included.
    pub fn admin(&mut self, line: &str) -> Result<()> {
        match self.roundtrip(&BinRequest::Admin { line: line.to_string() })? {
            (protocol::OP_OK, _) => Ok(()),
            reply => Err(remote_err(reply)),
        }
    }

    /// Server statistics ([`protocol::StatsReply`]): cluster shape,
    /// storage/replication gauges, and the per-datatype typed key
    /// counts (`sets`/`counters`/`maps`).
    pub fn stats(&mut self) -> Result<protocol::StatsReply> {
        match self.roundtrip(&BinRequest::Stats)? {
            (protocol::OP_STATS_REPLY, payload) => {
                let stats = protocol::decode_stats_reply(&payload)?;
                self.seen_epoch = self.seen_epoch.max(stats.epoch);
                Ok(stats)
            }
            reply => Err(remote_err(reply)),
        }
    }

    /// Decode a topology frame, tracking the freshest epoch seen.
    fn topology_view(&mut self, payload: &[u8]) -> Result<TopologyView> {
        let (epoch, slots, members) = protocol::decode_topology_reply(payload)?;
        self.seen_epoch = self.seen_epoch.max(epoch);
        Ok(TopologyView { epoch, slots, members })
    }

    /// Discover (or refresh) the server's membership view mid-session —
    /// routing is server-side, so a client only needs this to *observe*
    /// an epoch bump; its GET/PUT session keeps working across one
    /// untouched.
    pub fn topology(&mut self) -> Result<TopologyView> {
        match self.roundtrip(&BinRequest::Topology)? {
            (protocol::OP_TOPOLOGY_REPLY, payload) => self.topology_view(&payload),
            reply => Err(remote_err(reply)),
        }
    }

    /// Admin: spin up a new replica. Returns `(new node id, view)` —
    /// the join reply's `slots` field is pinned to this request, so
    /// `slots - 1` is the id the server assigned it (stable even when
    /// joins race).
    pub fn join(&mut self) -> Result<(u64, TopologyView)> {
        match self.roundtrip(&BinRequest::Join)? {
            (protocol::OP_TOPOLOGY_REPLY, payload) => {
                let view = self.topology_view(&payload)?;
                // a remote reply is untrusted input: reject slots=0
                // instead of underflowing
                let id = view
                    .slots
                    .checked_sub(1)
                    .ok_or_else(|| Error::Protocol("join reply with zero slots".into()))?;
                Ok((id, view))
            }
            reply => Err(remote_err(reply)),
        }
    }

    /// Admin: retire a replica, handing off its keys. Returns the
    /// post-retirement view.
    pub fn decommission(&mut self, node: u64) -> Result<TopologyView> {
        let node = usize::try_from(node)
            .map_err(|_| Error::Protocol(format!("node id {node} out of range")))?;
        match self.roundtrip(&BinRequest::Decommission { node })? {
            (protocol::OP_TOPOLOGY_REPLY, payload) => self.topology_view(&payload),
            reply => Err(remote_err(reply)),
        }
    }

    /// The freshest topology epoch this client has observed (0 before
    /// any stats/topology/join/decommission reply).
    pub fn seen_epoch(&self) -> u64 {
        self.seen_epoch
    }

    /// Stream one cross-DC shipper batch ([`protocol::OP_SHIP`]): the
    /// origin zone, the shipper's HLC stamp, and `(key, encoded DVV
    /// state)` entries. Returns `(states applied, the receiving
    /// cluster's post-merge HLC reading)` — what a remote DC's shipper
    /// loop folds back into its own clock.
    pub fn ship(
        &mut self,
        zone: u64,
        ts: HlcTimestamp,
        entries: Vec<(u64, Vec<u8>)>,
    ) -> Result<(u64, HlcTimestamp)> {
        match self.roundtrip(&BinRequest::Ship { zone, ts, entries })? {
            (protocol::OP_SHIP_ACK, payload) => protocol::decode_ship_ack(&payload),
            reply => Err(remote_err(reply)),
        }
    }

    /// Close the connection politely (waits for the server's `BYE`).
    pub fn quit(mut self) -> Result<()> {
        match self.roundtrip(&BinRequest::Quit)? {
            (protocol::OP_BYE, _) => Ok(()),
            reply => Err(remote_err(reply)),
        }
    }
}

impl KvClient for TcpClient {
    fn actor(&self) -> Actor {
        self.actor
    }

    fn get(&mut self, key: &str) -> Result<GetReply> {
        match self.roundtrip(&BinRequest::Get { key: key.to_string() })? {
            (protocol::OP_VALUES, payload) => {
                let (values, token) = protocol::decode_values(&payload)?;
                Ok(GetReply { values, ctx: CausalCtx::decode(&token)? })
            }
            reply => Err(remote_err(reply)),
        }
    }

    fn put(&mut self, key: &str, value: Vec<u8>, ctx: Option<&CausalCtx>) -> Result<PutReply> {
        let token = ctx.map(CausalCtx::encode).unwrap_or_default();
        let req = BinRequest::Put {
            key: key.to_string(),
            value,
            actor: self.actor.0,
            ctx_token: token,
        };
        match self.roundtrip(&req)? {
            (protocol::OP_PUT_OK, payload) => {
                let (id, token) = protocol::decode_put_ok(&payload)?;
                // empty token = no chainable post-write context (a
                // concurrent sibling survived the write)
                let ctx = if token.is_empty() { None } else { Some(CausalCtx::decode(&token)?) };
                Ok(PutReply { id, ctx })
            }
            reply => Err(remote_err(reply)),
        }
    }
}

impl TypedKvClient for TcpClient {
    // One typed-opcode frame out, one typed reply frame back; the RMW
    // itself runs server-side, so these stay single-roundtrip.
    fn sadd(&mut self, key: &str, elem: &[u8]) -> Result<Dot> {
        let req = BinRequest::SAdd { key: key.to_string(), elem: elem.to_vec() };
        match self.roundtrip(&req)? {
            (protocol::OP_DOT_REPLY, payload) => protocol::decode_dot_reply(&payload),
            reply => Err(remote_err(reply)),
        }
    }

    fn srem(&mut self, key: &str, elem: &[u8]) -> Result<Vec<Dot>> {
        let req = BinRequest::SRem { key: key.to_string(), elem: elem.to_vec() };
        match self.roundtrip(&req)? {
            (protocol::OP_DOTS_REPLY, payload) => protocol::decode_dots_reply(&payload),
            reply => Err(remote_err(reply)),
        }
    }

    fn smembers(&mut self, key: &str) -> Result<Vec<Vec<u8>>> {
        match self.roundtrip(&BinRequest::SMembers { key: key.to_string() })? {
            (protocol::OP_MEMBERS_REPLY, payload) => protocol::decode_members_reply(&payload),
            reply => Err(remote_err(reply)),
        }
    }

    fn incr(&mut self, key: &str, by: i64) -> Result<i64> {
        match self.roundtrip(&BinRequest::Incr { key: key.to_string(), by })? {
            (protocol::OP_COUNT_REPLY, payload) => protocol::decode_count_reply(&payload),
            reply => Err(remote_err(reply)),
        }
    }

    fn count(&mut self, key: &str) -> Result<i64> {
        match self.roundtrip(&BinRequest::Count { key: key.to_string() })? {
            (protocol::OP_COUNT_REPLY, payload) => protocol::decode_count_reply(&payload),
            reply => Err(remote_err(reply)),
        }
    }

    fn mput(&mut self, key: &str, field: &[u8], value: &[u8]) -> Result<Dot> {
        let req = BinRequest::MPut {
            key: key.to_string(),
            field: field.to_vec(),
            value: value.to_vec(),
        };
        match self.roundtrip(&req)? {
            (protocol::OP_DOT_REPLY, payload) => protocol::decode_dot_reply(&payload),
            reply => Err(remote_err(reply)),
        }
    }

    fn mget(&mut self, key: &str, field: &[u8]) -> Result<Option<Vec<u8>>> {
        let req = BinRequest::MGet { key: key.to_string(), field: field.to_vec() };
        match self.roundtrip(&req)? {
            (protocol::OP_FIELD_REPLY, payload) => protocol::decode_field_reply(&payload),
            reply => Err(remote_err(reply)),
        }
    }
}
