//! Readiness-based serve loop: a `poll(2)` reactor with a worker pool
//! and per-connection frame pipelining.
//!
//! One reactor thread owns every connection's nonblocking socket and
//! buffers; a small pool of worker threads executes requests against
//! the cluster ([`super::ops`]) and feeds completions back. The stages
//! of a connection — read, decode, execute, write — are decoupled, so
//! one binary-v2 connection can have many frames in flight at once
//! while the replies still leave the socket in request order.
//! Execution is *serialized per connection* — at most one of a
//! connection's jobs is at the pool at a time, so a pipelined read
//! always observes the writes pipelined before it; parallelism comes
//! from many connections, not from reordering one connection's work.
//!
//! # Connection state machine
//!
//! ```text
//!            bytes           bytes            "DVV2"
//!   socket ──────▶ fill ──────────▶ [Sniff] ─────────▶ [Hello] ──▶ [Binary]
//!                 (rbuf)               │ any other byte              │ frame
//!                                      ▼                            ▼
//!                                   [Text] ──── line ──▶ dispatch(seq n)
//!                                                              │ pending
//!          worker pool (admits one job per conn at a time):    │
//!                             decode + execute + encode        ▼
//!   socket ◀────── try_write ◀── wbuf ◀── flush_done ◀── done[seq] (reorder)
//! ```
//!
//! Every parsed request gets the connection's next sequence number and
//! queues in the connection's `pending` list; [`Conn::pump`] admits
//! one job at a time to the shared worker queue, releasing the next
//! only when the previous completion returns — per-connection effect
//! order (read-your-writes) is preserved while different connections
//! execute in parallel across the pool. Completions land in the `done`
//! reorder buffer, and `flush_done` appends them to the write buffer
//! only in contiguous sequence order — that is the pipelining contract
//! (N requests in flight, N replies in order). Hello negotiation and
//! framing-level errors complete locally on the reactor (they answer
//! before any job could) through the same sequence numbers, so local
//! and worker replies interleave correctly.
//!
//! # Backpressure
//!
//! Two bounds, both per connection, both enforced by refusing to *read*
//! (the kernel's receive window then pushes back on the client):
//!
//! * at most [`MAX_INFLIGHT`] requests may be parsed-but-unflushed;
//! * once the write buffer backlog passes [`WBUF_HIGH`], no further
//!   reads happen until the peer drains replies.
//!
//! A frame body is only taken off `rbuf` once it arrived in full, and
//! `rbuf` only ever grows by bytes actually received — the
//! attacker-controlled length field never sizes an allocation.
//!
//! # Shutdown
//!
//! [`Handle::shutdown`] stops the accept path, marks every connection
//! as taking no further requests, and drains: in-flight jobs complete,
//! their replies flush, and the reactor exits once every connection is
//! quiet (bounded by [`SHUTDOWN_DRAIN`]). Only then are the workers
//! released and joined. When `shutdown` returns, no thread spawned by
//! [`spawn`] is running — nothing still holds the cluster `Arc`,
//! replacing the detached-worker 200 ms-timeout hack of the
//! thread-per-connection loop.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::ops::{self, TextReply};
use super::protocol;
use super::LocalCluster;
use crate::error::Result;
use crate::kernel::mechs::DvvMech;
use crate::store::StorageBackend;

/// Upper bound on parsed-but-unflushed requests per connection; past
/// it the reactor stops reading that socket.
pub(crate) const MAX_INFLIGHT: usize = 64;

/// Write-buffer backlog (bytes) past which the reactor stops reading a
/// connection until the peer drains replies.
pub(crate) const WBUF_HIGH: usize = 256 * 1024;

/// Compact the write buffer once this many flushed bytes accumulate at
/// its front.
const WBUF_COMPACT: usize = 64 * 1024;

/// Read chunk per `read(2)` call (also the growth step of `rbuf`).
const RBUF_CHUNK: usize = 64 * 1024;

/// How long a closed-by-server connection lingers reading (and
/// discarding) input, so the close cannot RST the final reply out of
/// the peer's receive queue (Linux purges it on RST).
const LINGER: Duration = Duration::from_millis(250);

/// Shutdown drain bound: in-flight requests get this long to complete
/// and flush before the reactor exits regardless.
const SHUTDOWN_DRAIN: Duration = Duration::from_secs(1);

/// Minimal FFI onto `poll(2)` — readiness notification without a
/// dependency (no `libc` crate in this tree).
mod sys {
    use std::os::raw::{c_int, c_ulong};

    /// `struct pollfd` (identical layout on every unix this builds on).
    #[repr(C)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: c_ulong, timeout: c_int) -> c_int;
    }

    /// `poll(2)`, retrying `EINTR`. `timeout_ms < 0` blocks
    /// indefinitely.
    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
        loop {
            let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as c_ulong, timeout_ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let err = std::io::Error::last_os_error();
            if err.kind() != std::io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

/// Wakes the reactor out of `poll` from another thread (worker
/// completions, shutdown). A loopback TCP pair keeps this in std: one
/// pending byte on `rx` makes the poll readable; `WouldBlock` on a
/// `wake` means a wake is already queued, which is all a wake means.
struct Waker {
    tx: Mutex<TcpStream>,
    rx: TcpStream,
}

impl Waker {
    fn new() -> Result<Waker> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let tx = TcpStream::connect(listener.local_addr()?)?;
        let ours = tx.local_addr()?;
        // the one-shot ephemeral listener is connectable by any local
        // process that races us; accept until the peer is our own
        // connect half, or a stranger would swallow the wakeup channel
        // (stalled completions, wedged shutdown)
        let rx = loop {
            let (rx, peer) = listener.accept()?;
            if peer == ours {
                break rx;
            }
        };
        rx.set_nonblocking(true)?;
        tx.set_nonblocking(true)?;
        tx.set_nodelay(true).ok();
        Ok(Waker { tx: Mutex::new(tx), rx })
    }

    fn wake(&self) {
        let _ = self.tx.lock().unwrap().write_all(&[1]);
    }

    /// Swallow queued wake bytes (reactor side, nonblocking).
    fn drain(&self) {
        let mut buf = [0u8; 256];
        loop {
            match (&self.rx).read(&mut buf) {
                Ok(n) if n > 0 => {}
                _ => break, // EOF, or WouldBlock: queue empty
            }
        }
    }
}

/// What a worker must do for one request.
enum Work {
    /// One intact binary-v2 frame (framing already validated).
    Bin { opcode: u8, payload: Vec<u8> },
    /// One complete text-protocol line (newline stripped, non-blank).
    Text { line: String },
}

/// One dispatched request.
struct Job {
    conn: u64,
    seq: u64,
    work: Work,
}

/// One executed reply, rendered to wire bytes.
struct Done {
    conn: u64,
    seq: u64,
    bytes: Vec<u8>,
    close: bool,
}

/// Reactor ⇄ worker-pool rendezvous.
struct Shared {
    jobs: Mutex<VecDeque<Job>>,
    jobs_cv: Condvar,
    done: Mutex<Vec<Done>>,
    /// Worker release flag — set only after the reactor finished
    /// draining, so workers keep executing during shutdown; they empty
    /// the queue before exiting.
    stop: AtomicBool,
}

impl Shared {
    fn new() -> Shared {
        Shared {
            jobs: Mutex::new(VecDeque::new()),
            jobs_cv: Condvar::new(),
            done: Mutex::new(Vec::new()),
            stop: AtomicBool::new(false),
        }
    }
}

/// Render one reply frame to bytes. [`ops::exec_bin_request`] already
/// degrades oversized results through `fits_frame`, so the fallback ERR
/// here is unreachable belt-and-braces, not a real path.
fn frame_bytes(opcode: u8, payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(payload.len() + 8);
    if protocol::write_frame(&mut buf, opcode, payload).is_err() {
        buf.clear();
        let _ = protocol::write_frame(&mut buf, protocol::OP_ERR, b"reply exceeded the frame cap");
    }
    buf
}

/// Worker thread: pop, execute against the cluster, push the rendered
/// completion, wake the reactor. Exits once released *and* the queue is
/// empty, so a shutdown drain never abandons an accepted request.
fn worker_loop<B: StorageBackend<DvvMech>>(
    shared: Arc<Shared>,
    waker: Arc<Waker>,
    cluster: Arc<LocalCluster<B>>,
) {
    loop {
        let job = {
            let mut jobs = shared.jobs.lock().unwrap();
            loop {
                if let Some(job) = jobs.pop_front() {
                    break job;
                }
                if shared.stop.load(Ordering::Relaxed) {
                    return;
                }
                jobs = shared.jobs_cv.wait(jobs).unwrap();
            }
        };
        let done = match job.work {
            Work::Bin { opcode, payload } => {
                let reply = ops::exec_bin_request(&cluster, opcode, &payload);
                Done {
                    conn: job.conn,
                    seq: job.seq,
                    bytes: frame_bytes(reply.opcode, &reply.payload),
                    close: reply.close,
                }
            }
            Work::Text { line } => match ops::exec_text_line(&cluster, &line) {
                TextReply::Line(text) => Done {
                    conn: job.conn,
                    seq: job.seq,
                    bytes: text.into_bytes(),
                    close: false,
                },
                TextReply::Bye => Done {
                    conn: job.conn,
                    seq: job.seq,
                    bytes: b"BYE\n".to_vec(),
                    close: true,
                },
            },
        };
        shared.done.lock().unwrap().push(done);
        waker.wake();
    }
}

/// Protocol position of a connection's byte stream.
enum Mode {
    /// Deciding text vs binary from the first bytes.
    Sniff,
    /// Binary magic seen; awaiting version byte + `\n`.
    Hello,
    /// Binary-v2 frames.
    Binary,
    /// Line-based text protocol.
    Text,
}

/// One reply waiting in the reorder buffer.
struct Reply {
    bytes: Vec<u8>,
    close: bool,
}

/// One connection owned by the reactor.
struct Conn {
    id: u64,
    stream: TcpStream,
    /// Unparsed input. Grows only by bytes actually received.
    rbuf: Vec<u8>,
    /// Encoded replies awaiting the socket.
    wbuf: Vec<u8>,
    /// Bytes of `wbuf` already written.
    wpos: usize,
    mode: Mode,
    /// Sequence number the next parsed request will get.
    next_seq: u64,
    /// Sequence number the next flushed reply must have.
    next_flush: u64,
    /// Out-of-order completions waiting for their turn.
    done: BTreeMap<u64, Reply>,
    /// Parsed requests not yet admitted to the worker pool: execution
    /// is serialized per connection (see [`Conn::pump`]).
    pending: VecDeque<Job>,
    /// One of this connection's jobs is at the workers right now.
    in_worker: bool,
    /// Parse/dispatch no further requests (server close or shutdown
    /// drain); input is read and discarded from here on.
    stop_requests: bool,
    /// The peer's read half reached EOF.
    peer_eof: bool,
    /// A close-marked reply was flushed: drop once `wbuf` drains (plus
    /// the linger-drain window).
    closing: bool,
    /// Tear down now; buffers abandoned (I/O error, poll error).
    dead: bool,
    /// End of the post-close linger-drain window.
    linger_until: Option<Instant>,
}

impl Conn {
    fn new(id: u64, stream: TcpStream) -> Conn {
        Conn {
            id,
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            mode: Mode::Sniff,
            next_seq: 0,
            next_flush: 0,
            done: BTreeMap::new(),
            pending: VecDeque::new(),
            in_worker: false,
            stop_requests: false,
            peer_eof: false,
            closing: false,
            dead: false,
            linger_until: None,
        }
    }

    /// Parsed-but-unflushed requests (queued for admission, in flight
    /// at a worker, or completed and waiting in the reorder buffer).
    fn outstanding(&self) -> usize {
        (self.next_seq - self.next_flush) as usize
    }

    /// Unwritten reply bytes.
    fn backlog(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    /// Should the reactor read this socket right now? This predicate
    /// *is* the backpressure: refusing to read makes the kernel receive
    /// window push back on a pipelining client.
    fn wants_read(&self) -> bool {
        if self.dead || self.peer_eof {
            return false;
        }
        if self.stop_requests || self.closing {
            return true; // discard mode: drain input so close won't RST
        }
        self.outstanding() < MAX_INFLIGHT && self.backlog() < WBUF_HIGH
    }

    fn wants_write(&self) -> bool {
        !self.dead && self.backlog() > 0
    }

    /// Read until `WouldBlock` (or a bound trips), parsing as bytes
    /// arrive. `scratch` is the reactor's shared read chunk.
    fn fill(&mut self, shared: &Shared, scratch: &mut [u8]) {
        loop {
            if !self.wants_read() {
                return;
            }
            match self.stream.read(scratch) {
                Ok(0) => {
                    self.peer_eof = true;
                    return;
                }
                Ok(n) => {
                    if self.stop_requests || self.closing {
                        continue; // linger/drain: discard
                    }
                    self.rbuf.extend_from_slice(&scratch[..n]);
                    self.parse(shared);
                    if n < scratch.len() {
                        return; // short read: socket very likely drained
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    return;
                }
            }
        }
    }

    /// Parse every complete request buffered in `rbuf`, dispatching
    /// each to the worker pool, until input runs short or a bound
    /// trips. Re-run after completions flush: parsing stops at
    /// [`MAX_INFLIGHT`] with bytes still buffered, and no further
    /// `POLLIN` will arrive for bytes already read off the socket.
    fn parse(&mut self, shared: &Shared) {
        loop {
            if self.stop_requests || self.closing {
                self.rbuf.clear();
                return;
            }
            match self.mode {
                Mode::Sniff => {
                    // bail to text on the first byte that diverges from
                    // the magic, so a short text command is answered
                    // without waiting for four bytes
                    let n = self.rbuf.len().min(protocol::MAGIC.len());
                    if self.rbuf[..n] == protocol::MAGIC[..n] {
                        if n < protocol::MAGIC.len() {
                            return; // an honest prefix: need more bytes
                        }
                        self.rbuf.drain(..protocol::MAGIC.len());
                        self.mode = Mode::Hello;
                    } else {
                        self.mode = Mode::Text;
                    }
                }
                Mode::Hello => {
                    if self.rbuf.len() < 2 {
                        return;
                    }
                    let (version, terminator) = (self.rbuf[0], self.rbuf[1]);
                    self.rbuf.drain(..2);
                    if terminator != b'\n' {
                        // a stray byte here would desynchronize every
                        // following frame
                        self.finish_local(frame_bytes(
                            protocol::OP_ERR,
                            b"malformed hello: missing newline after version byte",
                        ));
                        return;
                    }
                    if version != protocol::VERSION {
                        let msg = format!(
                            "unsupported protocol version {version} (server speaks {})",
                            protocol::VERSION
                        );
                        self.finish_local(frame_bytes(protocol::OP_ERR, msg.as_bytes()));
                        return;
                    }
                    self.complete_local(
                        frame_bytes(protocol::OP_HELLO_ACK, &[protocol::VERSION]),
                        false,
                    );
                    self.mode = Mode::Binary;
                }
                Mode::Binary => {
                    if self.outstanding() >= MAX_INFLIGHT || self.rbuf.len() < 4 {
                        return;
                    }
                    let header = [self.rbuf[0], self.rbuf[1], self.rbuf[2], self.rbuf[3]];
                    let len = match protocol::frame_len(header) {
                        Ok(len) => len,
                        Err(e) => {
                            // broken framing: the byte stream can no
                            // longer be trusted — ERR in sequence
                            // position, then close
                            self.finish_local(frame_bytes(
                                protocol::OP_ERR,
                                e.to_string().as_bytes(),
                            ));
                            return;
                        }
                    };
                    if self.rbuf.len() < 4 + len {
                        return; // whole frame or nothing
                    }
                    let mut body = self.rbuf[4..4 + len].to_vec();
                    self.rbuf.drain(..4 + len);
                    let payload = body.split_off(1);
                    self.dispatch(shared, Work::Bin { opcode: body[0], payload });
                }
                Mode::Text => {
                    if self.outstanding() >= MAX_INFLIGHT {
                        return;
                    }
                    let Some(nl) = self.rbuf.iter().position(|&b| b == b'\n') else {
                        if self.rbuf.len() > protocol::MAX_TEXT_LINE {
                            // a partial line past the cap can never
                            // complete legally
                            self.finish_local(b"ERR line too long\n".to_vec());
                        }
                        return;
                    };
                    if nl > protocol::MAX_TEXT_LINE {
                        // a *complete* line obeys the same cap: with a
                        // 64 KiB read chunk the newline can land in the
                        // very chunk that crossed the cap, and that
                        // must not smuggle an oversized line through
                        self.finish_local(b"ERR line too long\n".to_vec());
                        return;
                    }
                    let line = String::from_utf8_lossy(&self.rbuf[..nl]).into_owned();
                    self.rbuf.drain(..=nl);
                    if line.trim().is_empty() {
                        continue;
                    }
                    self.dispatch(shared, Work::Text { line });
                }
            }
        }
    }

    /// Queue one request under this connection's next sequence number.
    /// It reaches the worker pool through [`Conn::pump`], which keeps
    /// per-connection execution serial.
    fn dispatch(&mut self, shared: &Shared, work: Work) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.pending.push_back(Job { conn: self.id, seq, work });
        self.pump(shared);
    }

    /// Admit the next queued job to the pool — but only if none of this
    /// connection's jobs is there already. Requests from one connection
    /// therefore execute strictly in request order (a pipelined `GET`
    /// observes the `PUT` before it), while requests from *different*
    /// connections run in parallel across the workers. Called on
    /// dispatch and again whenever one of our completions returns.
    fn pump(&mut self, shared: &Shared) {
        if self.in_worker {
            return;
        }
        let Some(job) = self.pending.pop_front() else { return };
        self.in_worker = true;
        shared.jobs.lock().unwrap().push_back(job);
        shared.jobs_cv.notify_one();
    }

    /// Complete a request locally on the reactor (hello replies,
    /// framing errors) — same sequence space as worker completions, so
    /// ordering holds when local and pooled replies interleave.
    fn complete_local(&mut self, bytes: Vec<u8>, close: bool) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.done.insert(seq, Reply { bytes, close });
    }

    /// Local reply after which the server closes the connection.
    fn finish_local(&mut self, bytes: Vec<u8>) {
        self.complete_local(bytes, true);
        self.stop_requests = true;
        self.rbuf.clear();
    }

    /// Move contiguous completions, in sequence order, into the write
    /// buffer. A close-marked reply is the connection's last: later
    /// completions (requests pipelined past a QUIT) are discarded.
    fn flush_done(&mut self) {
        while let Some(reply) = self.done.remove(&self.next_flush) {
            self.next_flush += 1;
            self.wbuf.extend_from_slice(&reply.bytes);
            if reply.close {
                self.closing = true;
                self.stop_requests = true;
                self.done.clear();
                self.pending.clear(); // requests pipelined past the close
                self.next_flush = self.next_seq;
                self.rbuf.clear();
                return;
            }
        }
    }

    /// Push buffered replies out until the socket would block.
    fn try_write(&mut self) {
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => self.wpos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        } else if self.wpos > WBUF_COMPACT {
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
        if self.closing && self.backlog() == 0 && self.linger_until.is_none() {
            // final reply is out of our buffer: linger-drain so close
            // cannot RST it out of the peer's receive queue
            self.linger_until = Some(Instant::now() + LINGER);
        }
    }

    /// Tear the connection down now?
    fn finished(&self, now: Instant) -> bool {
        if self.dead {
            return true;
        }
        if self.closing {
            return self.backlog() == 0
                && (self.peer_eof || self.linger_until.is_some_and(|t| now >= t));
        }
        self.peer_eof && self.backlog() == 0 && self.outstanding() == 0
    }

    /// Quiet enough for shutdown: nothing parsed awaits execution or
    /// flushing, and every reply byte is on the wire.
    fn drained(&self) -> bool {
        self.dead || (self.outstanding() == 0 && self.backlog() == 0)
    }
}

/// The reactor thread's state. Not generic over the storage backend:
/// request execution lives in the workers, the reactor only moves
/// bytes.
struct Reactor {
    listener: TcpListener,
    conns: HashMap<u64, Conn>,
    /// Monotone connection ids — never reused, so a stale completion
    /// can never reach a different connection on a recycled slot.
    next_conn: u64,
    shared: Arc<Shared>,
    waker: Arc<Waker>,
    stop: Arc<AtomicBool>,
}

impl Reactor {
    fn run(mut self) {
        let mut scratch = vec![0u8; RBUF_CHUNK];
        let mut draining = false;
        let mut drain_deadline = Instant::now();
        loop {
            if !draining && self.stop.load(Ordering::Relaxed) {
                // shutdown: stop accepting and taking requests; what is
                // in flight completes and flushes
                draining = true;
                drain_deadline = Instant::now() + SHUTDOWN_DRAIN;
                for conn in self.conns.values_mut() {
                    conn.stop_requests = true;
                    conn.rbuf.clear();
                }
            }
            if draining
                && (self.conns.values().all(Conn::drained)
                    || Instant::now() >= drain_deadline)
            {
                break;
            }

            // poll set rebuilt per tick: waker, listener (while
            // accepting), then the connections with any interest
            let mut fds = Vec::with_capacity(self.conns.len() + 2);
            fds.push(sys::PollFd {
                fd: self.waker.rx.as_raw_fd(),
                events: sys::POLLIN,
                revents: 0,
            });
            let listener_idx = if draining {
                None
            } else {
                fds.push(sys::PollFd {
                    fd: self.listener.as_raw_fd(),
                    events: sys::POLLIN,
                    revents: 0,
                });
                Some(fds.len() - 1)
            };
            let conn_base = fds.len();
            let mut ids = Vec::with_capacity(self.conns.len());
            for conn in self.conns.values() {
                let mut events = 0i16;
                if conn.wants_read() {
                    events |= sys::POLLIN;
                }
                if conn.wants_write() {
                    events |= sys::POLLOUT;
                }
                if events == 0 && conn.peer_eof {
                    // nothing to ask for, and HUP would be re-reported
                    // every tick — keep it out of the set
                    continue;
                }
                fds.push(sys::PollFd {
                    fd: conn.stream.as_raw_fd(),
                    events,
                    revents: 0,
                });
                ids.push(conn.id);
            }
            // timers (linger windows, drain deadline) need ticks even
            // without readiness; otherwise sleep until woken
            let timeout = if draining
                || self.conns.values().any(|c| c.linger_until.is_some())
            {
                20
            } else {
                500
            };
            if sys::poll_fds(&mut fds, timeout).is_err() {
                break; // EINTR retried inside; anything else is fatal
            }

            if fds[0].revents != 0 {
                self.waker.drain();
            }
            if listener_idx.is_some_and(|i| fds[i].revents != 0) {
                self.accept_ready();
            }

            // worker completions into the per-connection reorder buffers
            let batch: Vec<Done> = std::mem::take(&mut *self.shared.done.lock().unwrap());
            for done in batch {
                if let Some(conn) = self.conns.get_mut(&done.conn) {
                    // the pool runs at most one of a connection's jobs
                    // at a time, so this completion is that one —
                    // release the next queued job
                    conn.in_worker = false;
                    // a completion at or past next_flush is live; below
                    // it, it raced a close that already discarded it
                    if done.seq >= conn.next_flush {
                        conn.done.insert(done.seq, Reply { bytes: done.bytes, close: done.close });
                    }
                    conn.pump(&self.shared);
                }
            }

            // readiness per connection
            for (i, &id) in ids.iter().enumerate() {
                let revents = fds[conn_base + i].revents;
                if revents == 0 {
                    continue;
                }
                let conn = self.conns.get_mut(&id).expect("polled conns exist");
                if revents & (sys::POLLERR | sys::POLLNVAL) != 0 {
                    conn.dead = true;
                    continue;
                }
                if revents & (sys::POLLIN | sys::POLLHUP) != 0 {
                    conn.fill(&self.shared, &mut scratch);
                    if revents & sys::POLLHUP != 0 && !conn.wants_read() {
                        // a backpressured connection refuses to read, so
                        // fill() cannot consume the hangup and poll
                        // would re-report it every tick (busy spin
                        // until the in-flight work drains) — POLLHUP
                        // means the peer is fully gone, so treat it as
                        // EOF outright
                        conn.peer_eof = true;
                    }
                }
            }

            // flush completions, resume stalled parses, write
            let now = Instant::now();
            for conn in self.conns.values_mut() {
                conn.flush_done();
                if !conn.rbuf.is_empty() {
                    // bytes parked by MAX_INFLIGHT / WBUF_HIGH: no new
                    // POLLIN will ever arrive for them, so parsing must
                    // resume from the completion path
                    conn.parse(&self.shared);
                    conn.flush_done();
                }
                conn.try_write();
            }
            self.conns.retain(|_, c| !c.finished(now));
        }
        // connections close here (dropped with the reactor); only then
        // are the workers released — the handle joins them after us
        drop(self.conns);
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.jobs_cv.notify_all();
    }

    /// Accept everything pending (edge until `WouldBlock`).
    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue; // shed: a blocking socket would wedge the loop
                    }
                    stream.set_nodelay(true).ok();
                    let id = self.next_conn;
                    self.next_conn += 1;
                    self.conns.insert(id, Conn::new(id, stream));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => break,
            }
        }
    }
}

/// A running reactor: the reactor thread plus its worker pool.
pub(crate) struct Handle {
    stop: Arc<AtomicBool>,
    waker: Arc<Waker>,
    shared: Arc<Shared>,
    reactor: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Handle {
    /// Deterministic teardown: drain in-flight requests, join the
    /// reactor, release and join the workers. On return no thread
    /// started by [`spawn`] is running.
    pub(crate) fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.waker.wake();
        if let Some(h) = self.reactor.take() {
            let _ = h.join();
        }
        // idempotent with the reactor's own release — and the only
        // release if the reactor thread died early
        self.shared.stop.store(true, Ordering::Relaxed);
        self.shared.jobs_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Start the reactor over an already-bound nonblocking listener.
/// `workers == 0` sizes the pool from available parallelism (clamped to
/// `2..=8` — below 2 a single slow request would stall unrelated
/// connections).
pub(crate) fn spawn<B: StorageBackend<DvvMech>>(
    listener: TcpListener,
    cluster: Arc<LocalCluster<B>>,
    workers: usize,
) -> Result<Handle> {
    let pool = if workers == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).clamp(2, 8)
    } else {
        workers
    };
    let waker = Arc::new(Waker::new()?);
    let shared = Arc::new(Shared::new());
    let stop = Arc::new(AtomicBool::new(false));

    let mut worker_handles = Vec::with_capacity(pool);
    let mut fail: Option<crate::error::Error> = None;
    for i in 0..pool {
        let spawned = std::thread::Builder::new().name(format!("dvv-exec-{i}")).spawn({
            let shared = Arc::clone(&shared);
            let waker = Arc::clone(&waker);
            let cluster = Arc::clone(&cluster);
            move || worker_loop(shared, waker, cluster)
        });
        match spawned {
            Ok(h) => worker_handles.push(h),
            Err(e) => {
                fail = Some(e.into());
                break;
            }
        }
    }
    let reactor = match fail {
        None => std::thread::Builder::new()
            .name("dvv-reactor".into())
            .spawn({
                let shared = Arc::clone(&shared);
                let waker = Arc::clone(&waker);
                let stop = Arc::clone(&stop);
                move || {
                    Reactor {
                        listener,
                        conns: HashMap::new(),
                        next_conn: 0,
                        shared,
                        waker,
                        stop,
                    }
                    .run()
                }
            })
            .map_err(crate::error::Error::from),
        Some(e) => Err(e),
    };
    match reactor {
        Ok(h) => Ok(Handle {
            stop,
            waker,
            shared,
            reactor: Some(h),
            workers: worker_handles,
        }),
        Err(e) => {
            // release whatever part of the pool started, then report
            shared.stop.store(true, Ordering::Relaxed);
            shared.jobs_cv.notify_all();
            for h in worker_handles {
                let _ = h.join();
            }
            Err(e)
        }
    }
}
