//! Typed CRDT operations over the replicated cluster.
//!
//! A CRDT key is an ordinary register key whose payload is an encoded
//! [`TypedState`] — so storage, WAL, Merkle anti-entropy, hinted
//! handoff, and cross-DC shipping all move it without knowing it exists.
//! What this module adds is the **server-side read-modify-write** every
//! typed op (`SADD`, `INCR`, `MPUT`, …) runs:
//!
//! 1. take the key's typed stripe lock (serializes RMWs per key; plain
//!    register GET/PUT never touch these locks);
//! 2. quorum-read the register siblings, decode each blob as a
//!    [`TypedState`] and join them (concurrent register siblings
//!    collapse by CRDT merge — this is also where a sibling left by a
//!    raced write gets folded back in);
//! 3. mint a dot under the coordinator's epoch-namespaced actor and
//!    apply the mutation;
//! 4. write the re-encoded state back through the ordinary register PUT
//!    path, **pinned** to the coordinator that served the read.
//!
//! The pin plus the stripe lock are what make dot minting safe (the
//! false-cover hazard, [`crate::kernel::crdt`] module docs): a dot for
//! actor `a` may only be minted from a state containing all of `a`'s
//! prior mints. The coordinator's local state is always part of the read
//! (it replies first), every prior mint under its actor was written to
//! its local store by the pinned PUT, and a restart or wipe — which
//! loses exactly that guarantee — bumps the node's `typed_epoch`, moving
//! subsequent mints to a fresh actor id instead of reusing counters.
//!
//! # Delta accounting
//!
//! Every mutation produces a [`CrdtDelta`] alongside the full state. The
//! fan-out still replicates the full state (correctness is the
//! register path's, untouched); what the delta changes is the **bytes a
//! wire fan-out needs**: for each receiver whose current typed clock
//! dominates the delta's `ctx_before`, a delta-shaped frame (the
//! added/removed dots plus causal context) would have sufficed, and the
//! cluster ledgers those bytes as delta-sent; receivers that can't cover
//! it are ledgered at full-state cost. `benches/crdt.rs` turns this
//! ledger into the delta-vs-full evidence, and
//! [`LocalCluster::crdt_repl_bytes`] exposes it.

use std::sync::atomic::Ordering;

use crate::clocks::vv::VersionVector;
use crate::clocks::Actor;
use crate::cluster::ring::hash_str;
use crate::cluster::NodeId;
use crate::coordinator::GetOp;
use crate::error::{Error, Result};
use crate::kernel::crdt::{mint_actor, CrdtDelta, CrdtKind, Dot, TypedState};
use crate::kernel::mechs::DvvMech;
use crate::store::{Key, StorageBackend};

use super::{with_scratch, LocalCluster, Node};

/// The replication-bytes profile of one typed mutation, handed to the
/// PUT fan-out so each receiver can be ledgered at delta or full cost.
#[derive(Debug, Clone)]
pub(crate) struct ReplProfile {
    /// The mutation's `ctx_before` (what a receiver must dominate to
    /// apply the delta); `None` for counter rows, which always apply.
    pub ctx_before: Option<VersionVector>,
    /// Encoded delta size.
    pub delta_len: u64,
    /// Encoded full-state size.
    pub full_len: u64,
}

/// A typed quorum read: who coordinated, the joined state, and the
/// register-level observations needed to commit a superseding write.
struct TypedRead {
    coordinator: NodeId,
    /// Joined state of every decodable sibling; `None` when the key has
    /// never held a typed value.
    state: Option<TypedState>,
    /// Register write ids observed (the oracle's ground truth and the
    /// supersession set for the follow-up PUT).
    ids: Vec<u64>,
    /// Encoded register causal context from the read.
    context: Vec<u8>,
}

impl<B: StorageBackend<DvvMech>> LocalCluster<B> {
    /// Ledger one fan-out receiver: delta-sized bytes when its current
    /// typed clock covers the mutation's context, full-state bytes
    /// otherwise (and full always, in the everything-full baseline
    /// column).
    pub(crate) fn tally_repl(&self, receiver: &Node<B>, k: Key, rp: &ReplProfile) {
        self.crdt_allfull_bytes.fetch_add(rp.full_len, Ordering::Relaxed);
        let covered = match &rp.ctx_before {
            None => true,
            Some(ctx) => self.receiver_covers(receiver, k, ctx),
        };
        if covered {
            self.crdt_delta_bytes.fetch_add(rp.delta_len, Ordering::Relaxed);
        } else {
            self.crdt_full_bytes.fetch_add(rp.full_len, Ordering::Relaxed);
        }
    }

    /// Would `receiver`'s current typed state for `k` cover a delta with
    /// the given `ctx_before`? Undecodable or missing blobs count as
    /// not-covered (the fallback is always safe).
    fn receiver_covers(&self, receiver: &Node<B>, k: Key, ctx: &VersionVector) -> bool {
        let mut clock = VersionVector::new();
        for v in receiver.store.values(k) {
            let bytes = self.blobs.get(v.id);
            if bytes.is_empty() {
                continue;
            }
            match TypedState::decode(&bytes) {
                Ok(st) => clock.join_from(&st.clock()),
                Err(_) => return false,
            }
        }
        ctx.dominated_by(&clock)
    }

    /// Quorum read + sibling-join for a typed key. Mirrors the register
    /// GET (sub-reads and read repair are fabric-routed, R replies
    /// required) but additionally reports the coordinator — the RMW must
    /// pin its write there — and decodes the sibling blobs. A blob the
    /// process no longer holds (blobs are process-local; a reopened
    /// durable cluster has metadata only) is skipped; a present but
    /// undecodable blob is an error.
    fn typed_read_at(&self, k: Key, zone: Option<usize>) -> Result<TypedRead> {
        with_scratch(|replicas, reached| {
            self.topology.replicas_into(k, self.quorum.n, replicas);
            let nodes = self.nodes.read().unwrap();
            let coordinator = self.pick_coordinator_in(replicas, zone)?;
            let quorum = self.scoped_quorum(replicas, coordinator);
            let mut op: GetOp<DvvMech> = GetOp::new(quorum);
            let mut answer = None;
            // the coordinator's local state is reply #1 — the quorum can
            // complete before a zone-preferred coordinator's slot in the
            // preference list comes up, and the RMW base MUST contain
            // every dot this node ever minted (the mint contract)
            let own = nodes[coordinator].store.state(k);
            reached.push(coordinator);
            if let Some(res) = op.on_reply(&self.mech, &own) {
                answer = Some(res);
            }
            for &node in replicas.iter() {
                if node == coordinator
                    || !(self.fabric.deliver(coordinator, node)
                        && self.fabric.deliver(node, coordinator))
                {
                    continue;
                }
                let state = nodes[node].store.state(k);
                reached.push(node);
                if let Some(res) = op.on_reply(&self.mech, &state) {
                    answer = Some(res);
                }
            }
            let res = answer.ok_or(Error::QuorumNotMet {
                got: op.replies(),
                needed: quorum.r,
            })?;
            let merged = op.merged().clone();
            for &node in reached.iter() {
                if node == coordinator || self.fabric.deliver(coordinator, node) {
                    self.merge_at_node(&nodes[node], k, &merged);
                }
            }
            let mut state: Option<TypedState> = None;
            for v in &res.values {
                let bytes = self.blobs.get(v.id);
                if bytes.is_empty() {
                    continue;
                }
                let sibling = TypedState::decode(&bytes)?;
                match &mut state {
                    None => state = Some(sibling),
                    Some(st) => st.merge(&sibling)?,
                }
            }
            let ids = res.values.iter().map(|v| v.id).collect();
            let mut context = Vec::new();
            crate::clocks::encoding::encode_vv(&res.context, &mut context);
            Ok(TypedRead { coordinator, state, ids, context })
        })
    }

    /// The shared read phase of every typed op: the joined state, or a
    /// [`Error::WrongType`] if the key holds a different kind than the
    /// op needs.
    fn typed_read_kinded(
        &self,
        key: &str,
        zone: Option<usize>,
        kind: CrdtKind,
    ) -> Result<Option<TypedState>> {
        let read = self.typed_read_at(hash_str(key), zone)?;
        match read.state {
            Some(st) if st.kind() != kind => Err(Error::WrongType {
                expected: kind.name(),
                found: st.kind().name(),
            }),
            other => Ok(other),
        }
    }

    /// The typed read-modify-write every mutating op runs (see module
    /// docs): stripe-lock, quorum-read + join, mint under the
    /// coordinator's epoch actor, mutate, commit pinned.
    fn typed_rmw<R>(
        &self,
        key: &str,
        zone: Option<usize>,
        kind: CrdtKind,
        mutate: impl FnOnce(&mut TypedState, Actor) -> (CrdtDelta, R),
    ) -> Result<R> {
        let k = hash_str(key);
        let _guard =
            self.typed_locks[(k as usize) & (self.typed_locks.len() - 1)].lock().unwrap();
        let read = self.typed_read_at(k, zone)?;
        let mut st = match read.state {
            Some(st) if st.kind() != kind => {
                return Err(Error::WrongType {
                    expected: kind.name(),
                    found: st.kind().name(),
                })
            }
            Some(st) => st,
            None => TypedState::fresh(kind),
        };
        let epoch = {
            let nodes = self.nodes.read().unwrap();
            nodes[read.coordinator].typed_epoch.load(Ordering::Relaxed)
        };
        let actor = mint_actor(read.coordinator, epoch);
        let (delta, out) = mutate(&mut st, actor);
        let value = st.encode_to_vec();
        let profile = ReplProfile {
            ctx_before: delta.ctx_before().cloned(),
            delta_len: delta.encoded_len() as u64,
            full_len: value.len() as u64,
        };
        self.put_inner(
            key,
            value,
            &read.context,
            actor,
            Some(&read.ids),
            zone,
            Some(read.coordinator),
            Some(&profile),
        )?;
        self.typed_kinds.lock().unwrap().insert(k, kind);
        Ok(out)
    }

    /// `SADD`: add `elem` to the set at `key`, returning the minted dot.
    pub fn set_add(&self, key: &str, elem: &[u8]) -> Result<Dot> {
        self.set_add_in_zone(key, elem, None)
    }

    /// Zone-coordinated [`set_add`](LocalCluster::set_add).
    pub fn set_add_in_zone(&self, key: &str, elem: &[u8], zone: Option<usize>) -> Result<Dot> {
        self.typed_rmw(key, zone, CrdtKind::Set, |st, actor| {
            let TypedState::Set(s) = st else { unreachable!("kind checked") };
            let dot = s.mint(actor);
            let delta = s.add(elem.to_vec(), dot);
            (CrdtDelta::Set(delta), dot)
        })
    }

    /// `SREM`: remove the *observed* dots of `elem`, returning them
    /// (empty when the element was not present — still a success: the
    /// observed-remove of nothing is nothing).
    pub fn set_remove(&self, key: &str, elem: &[u8]) -> Result<Vec<Dot>> {
        self.set_remove_in_zone(key, elem, None)
    }

    /// Zone-coordinated [`set_remove`](LocalCluster::set_remove).
    pub fn set_remove_in_zone(
        &self,
        key: &str,
        elem: &[u8],
        zone: Option<usize>,
    ) -> Result<Vec<Dot>> {
        self.typed_rmw(key, zone, CrdtKind::Set, |st, _actor| {
            let TypedState::Set(s) = st else { unreachable!("kind checked") };
            let (dots, delta) = s.remove(elem);
            (CrdtDelta::Set(delta), dots)
        })
    }

    /// `SMEMBERS`: the set's elements, ascending.
    pub fn set_members(&self, key: &str) -> Result<Vec<Vec<u8>>> {
        self.set_members_in_zone(key, None)
    }

    /// Zone-coordinated [`set_members`](LocalCluster::set_members).
    pub fn set_members_in_zone(&self, key: &str, zone: Option<usize>) -> Result<Vec<Vec<u8>>> {
        match self.typed_read_kinded(key, zone, CrdtKind::Set)? {
            None => Ok(Vec::new()),
            Some(TypedState::Set(s)) => Ok(s.members().map(|e| e.to_vec()).collect()),
            Some(_) => unreachable!("kind checked"),
        }
    }

    /// `INCR`: apply a signed increment to the counter at `key`,
    /// returning the post-op value.
    pub fn counter_incr(&self, key: &str, by: i64) -> Result<i64> {
        self.counter_incr_in_zone(key, by, None)
    }

    /// Zone-coordinated [`counter_incr`](LocalCluster::counter_incr).
    pub fn counter_incr_in_zone(&self, key: &str, by: i64, zone: Option<usize>) -> Result<i64> {
        self.typed_rmw(key, zone, CrdtKind::Counter, |st, actor| {
            let TypedState::Counter(c) = st else { unreachable!("kind checked") };
            let delta = c.incr(actor, by);
            (CrdtDelta::Counter(delta), c.value())
        })
    }

    /// `COUNT`: the counter's current value (0 for a never-written key).
    pub fn counter_value(&self, key: &str) -> Result<i64> {
        self.counter_value_in_zone(key, None)
    }

    /// Zone-coordinated [`counter_value`](LocalCluster::counter_value).
    pub fn counter_value_in_zone(&self, key: &str, zone: Option<usize>) -> Result<i64> {
        match self.typed_read_kinded(key, zone, CrdtKind::Counter)? {
            None => Ok(0),
            Some(TypedState::Counter(c)) => Ok(c.value()),
            Some(_) => unreachable!("kind checked"),
        }
    }

    /// `MPUT`: set `field` to `value` in the map at `key`, returning the
    /// minted dot.
    pub fn map_put(&self, key: &str, field: &[u8], value: &[u8]) -> Result<Dot> {
        self.map_put_in_zone(key, field, value, None)
    }

    /// Zone-coordinated [`map_put`](LocalCluster::map_put).
    pub fn map_put_in_zone(
        &self,
        key: &str,
        field: &[u8],
        value: &[u8],
        zone: Option<usize>,
    ) -> Result<Dot> {
        self.typed_rmw(key, zone, CrdtKind::Map, |st, actor| {
            let TypedState::Map(m) = st else { unreachable!("kind checked") };
            let dot = m.mint(actor);
            let delta = m.put(field.to_vec(), value.to_vec(), dot);
            (CrdtDelta::Map(delta), dot)
        })
    }

    /// `MGET`: the field's current value, `None` when absent.
    pub fn map_get(&self, key: &str, field: &[u8]) -> Result<Option<Vec<u8>>> {
        self.map_get_in_zone(key, field, None)
    }

    /// Zone-coordinated [`map_get`](LocalCluster::map_get).
    pub fn map_get_in_zone(
        &self,
        key: &str,
        field: &[u8],
        zone: Option<usize>,
    ) -> Result<Option<Vec<u8>>> {
        match self.typed_read_kinded(key, zone, CrdtKind::Map)? {
            None => Ok(None),
            Some(TypedState::Map(m)) => Ok(m.get(field).map(<[u8]>::to_vec)),
            Some(_) => unreachable!("kind checked"),
        }
    }

    /// Per-datatype key counts for `STATS` (`sets=`/`counters=`/`maps=`):
    /// how many keys this process has typed-written, by kind.
    pub fn typed_counts(&self) -> (u64, u64, u64) {
        let kinds = self.typed_kinds.lock().unwrap();
        let (mut sets, mut counters, mut maps) = (0, 0, 0);
        for kind in kinds.values() {
            match kind {
                CrdtKind::Set => sets += 1,
                CrdtKind::Counter => counters += 1,
                CrdtKind::Map => maps += 1,
            }
        }
        (sets, counters, maps)
    }

    /// The typed replication-bytes ledger: `(delta, full_fallback,
    /// always_full)` — what delta-shaped fan-out sent, what its
    /// full-state fallbacks sent, and what every-receiver-gets-the-full-
    /// state replication would have sent.
    pub fn crdt_repl_bytes(&self) -> (u64, u64, u64) {
        (
            self.crdt_delta_bytes.load(Ordering::Relaxed),
            self.crdt_full_bytes.load(Ordering::Relaxed),
            self.crdt_allfull_bytes.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn sadd_srem_smembers_roundtrip() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        let d1 = c.set_add("s", b"apple").unwrap();
        let d2 = c.set_add("s", b"pear").unwrap();
        assert_eq!(d1.actor, d2.actor, "same coordinator epoch actor");
        assert_eq!(d2.counter, d1.counter + 1, "contiguous mints");
        assert_eq!(
            c.set_members("s").unwrap(),
            vec![b"apple".to_vec(), b"pear".to_vec()]
        );
        let removed = c.set_remove("s", b"apple").unwrap();
        assert_eq!(removed, vec![d1]);
        assert_eq!(c.set_members("s").unwrap(), vec![b"pear".to_vec()]);
        assert!(c.set_remove("s", b"ghost").unwrap().is_empty());
    }

    #[test]
    fn counter_incr_and_read() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        assert_eq!(c.counter_value("n").unwrap(), 0);
        assert_eq!(c.counter_incr("n", 5).unwrap(), 5);
        assert_eq!(c.counter_incr("n", -2).unwrap(), 3);
        assert_eq!(c.counter_value("n").unwrap(), 3);
    }

    #[test]
    fn map_put_get() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        assert_eq!(c.map_get("m", b"f").unwrap(), None);
        c.map_put("m", b"f", b"v1").unwrap();
        c.map_put("m", b"f", b"v2").unwrap();
        assert_eq!(c.map_get("m", b"f").unwrap(), Some(b"v2".to_vec()));
    }

    #[test]
    fn wrong_kind_is_rejected_not_corrupted() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        c.set_add("k", b"x").unwrap();
        assert!(matches!(c.counter_incr("k", 1), Err(Error::WrongType { .. })));
        assert!(matches!(c.map_get("k", b"f"), Err(Error::WrongType { .. })));
        // the set is untouched by the rejected ops
        assert_eq!(c.set_members("k").unwrap(), vec![b"x".to_vec()]);
    }

    #[test]
    fn typed_counts_track_kinds() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        c.set_add("s1", b"x").unwrap();
        c.set_add("s2", b"x").unwrap();
        c.counter_incr("n", 1).unwrap();
        c.map_put("m", b"f", b"v").unwrap();
        assert_eq!(c.typed_counts(), (2, 1, 1));
    }

    #[test]
    fn repl_ledger_prefers_deltas_once_replicas_are_warm() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        for i in 0..40u32 {
            c.set_add("big", format!("element-{i:04}").as_bytes()).unwrap();
        }
        let (delta, full, allfull) = c.crdt_repl_bytes();
        assert!(delta > 0, "warm replicas are delta-coverable");
        assert!(
            delta + full < allfull,
            "delta shaping must beat always-full: {delta}+{full} vs {allfull}"
        );
        assert_eq!(c.set_members("big").unwrap().len(), 40);
    }

    #[test]
    fn restart_bumps_the_mint_actor_epoch() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        let d1 = c.set_add("k", b"a").unwrap();
        let coord = {
            // the coordinator is the first live preference-list node
            c.replicas_of("k")[0]
        };
        c.restart_node(coord);
        let d2 = c.set_add("k", b"b").unwrap();
        // the volatile backend lost the coordinator's state; the fresh
        // epoch actor must differ so no counter is ever reused
        if d2.actor == d1.actor {
            panic!("restart must move mints to a fresh actor epoch");
        }
        // peers still held the state, so nothing was lost
        let members = c.set_members("k").unwrap();
        assert_eq!(members, vec![b"a".to_vec(), b"b".to_vec()]);
    }

    #[test]
    fn concurrent_typed_adds_on_one_key_all_survive() {
        let c = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
        let mut handles = Vec::new();
        for t in 0..4u32 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for i in 0..10u32 {
                    c.set_add("shared", format!("t{t}-e{i}").as_bytes()).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.set_members("shared").unwrap().len(), 40);
    }

    #[test]
    fn plain_register_keys_are_untouched_by_typed_machinery() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        c.put("r", b"plain".to_vec(), &[]).unwrap();
        c.set_add("s", b"x").unwrap();
        assert_eq!(c.get("r").unwrap().values, vec![b"plain".to_vec()]);
        assert_eq!(c.typed_counts(), (1, 0, 0));
    }
}
