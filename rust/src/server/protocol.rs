//! Line-based text protocol for the TCP server.
//!
//! ```text
//! -> GET <key>
//! <- VALUES <n> <ctx-hex>
//! <- VALUE <hex>            (n lines)
//! -> PUT <key> <value-hex> [ctx-hex]
//! <- OK
//! -> STATS
//! <- STATS nodes=<n> shards=<s> metadata_bytes=<b>
//! -> QUIT
//! <- BYE
//! ```
//!
//! Errors render as `ERR <message>`. Hex keeps the framing trivial and
//! binary-safe without pulling in an encoder dependency.

use crate::error::{Error, Result};

/// Encode bytes as lowercase hex (empty input → `-`).
pub fn hex_encode(data: &[u8]) -> String {
    if data.is_empty() {
        return "-".to_string();
    }
    let mut out = String::with_capacity(data.len() * 2);
    for b in data {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

/// Decode `-` or hex into bytes.
pub fn hex_decode(s: &str) -> Result<Vec<u8>> {
    if s == "-" {
        return Ok(Vec::new());
    }
    if s.len() % 2 != 0 {
        return Err(Error::Protocol(format!("odd hex length {}", s.len())));
    }
    (0..s.len())
        .step_by(2)
        .map(|i| {
            u8::from_str_radix(&s[i..i + 2], 16)
                .map_err(|_| Error::Protocol(format!("bad hex at {i}")))
        })
        .collect()
}

/// A parsed client request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Read a key.
    Get {
        /// Key string.
        key: String,
    },
    /// Write a key.
    Put {
        /// Key string.
        key: String,
        /// Payload bytes.
        value: Vec<u8>,
        /// Context bytes from a prior GET (may be empty).
        context: Vec<u8>,
    },
    /// Server statistics.
    Stats,
    /// Close the connection.
    Quit,
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let mut parts = line.trim().split_whitespace();
    let cmd = parts.next().unwrap_or("");
    match cmd.to_ascii_uppercase().as_str() {
        "GET" => {
            let key = parts
                .next()
                .ok_or_else(|| Error::Protocol("GET needs a key".into()))?;
            Ok(Request::Get { key: key.to_string() })
        }
        "PUT" => {
            let key = parts
                .next()
                .ok_or_else(|| Error::Protocol("PUT needs a key".into()))?;
            let value = hex_decode(
                parts
                    .next()
                    .ok_or_else(|| Error::Protocol("PUT needs a value".into()))?,
            )?;
            let context = match parts.next() {
                Some(ctx) => hex_decode(ctx)?,
                None => Vec::new(),
            };
            Ok(Request::Put { key: key.to_string(), value, context })
        }
        "STATS" => Ok(Request::Stats),
        "QUIT" => Ok(Request::Quit),
        other => Err(Error::Protocol(format!("unknown command {other:?}"))),
    }
}

/// Render a GET answer.
pub fn format_values(values: &[Vec<u8>], context: &[u8]) -> String {
    let mut out = format!("VALUES {} {}\n", values.len(), hex_encode(context));
    for v in values {
        out.push_str(&format!("VALUE {}\n", hex_encode(v)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_roundtrip() {
        for data in [vec![], vec![0u8], vec![0xde, 0xad, 0xbe, 0xef]] {
            assert_eq!(hex_decode(&hex_encode(&data)).unwrap(), data);
        }
        assert_eq!(hex_encode(&[]), "-");
        assert!(hex_decode("abc").is_err());
        assert!(hex_decode("zz").is_err());
    }

    #[test]
    fn parse_get_put() {
        assert_eq!(
            parse_request("GET user:1").unwrap(),
            Request::Get { key: "user:1".into() }
        );
        assert_eq!(
            parse_request("PUT k 6869").unwrap(),
            Request::Put { key: "k".into(), value: b"hi".to_vec(), context: vec![] }
        );
        let with_ctx = parse_request("PUT k 00 0101").unwrap();
        assert_eq!(
            with_ctx,
            Request::Put { key: "k".into(), value: vec![0], context: vec![1, 1] }
        );
    }

    #[test]
    fn parse_errors() {
        assert!(parse_request("GET").is_err());
        assert!(parse_request("PUT k").is_err());
        assert!(parse_request("NOPE x").is_err());
        assert!(parse_request("").is_err());
    }

    #[test]
    fn case_insensitive_commands() {
        assert_eq!(parse_request("quit").unwrap(), Request::Quit);
        assert_eq!(parse_request("stats").unwrap(), Request::Stats);
    }

    #[test]
    fn format_values_shape() {
        let text = format_values(&[b"a".to_vec(), b"b".to_vec()], &[9]);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "VALUES 2 09");
        assert_eq!(lines[1], "VALUE 61");
        assert_eq!(lines[2], "VALUE 62");
    }
}
