//! Line-based text protocol for the TCP server.
//!
//! ```text
//! -> GET <key>
//! <- VALUES <n> <ctx-hex>
//! <- VALUE <hex>            (n lines)
//! -> PUT <key> <value-hex> [ctx-hex]
//! <- OK
//! -> STATS
//! <- STATS nodes=<n> shards=<s> metadata_bytes=<b> hints=<h>
//! -> QUIT
//! <- BYE
//! ```
//!
//! Fault-injection admin commands drive the cluster's
//! [`Fabric`](super::fabric::Fabric) at runtime:
//!
//! ```text
//! -> FAULT CRASH <node>             crash one replica
//! -> FAULT PARTITION <a,b> <c,d>    symmetric two-group partition
//! -> FAULT DROP <prob>              probabilistic message loss [0, 1]
//! -> FAULT DELAY <us>               extra per-message delay (bounded)
//! -> HEAL <node>                    recover one replica
//! -> HEAL                           heal everything, drain hints
//! <- OK
//! ```
//!
//! Errors render as `ERR <message>`. Hex keeps the framing trivial and
//! binary-safe without pulling in an encoder dependency.

use crate::error::{Error, Result};

/// Encode bytes as lowercase hex (empty input → `-`).
pub fn hex_encode(data: &[u8]) -> String {
    if data.is_empty() {
        return "-".to_string();
    }
    let mut out = String::with_capacity(data.len() * 2);
    for b in data {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

/// Decode `-` or hex into bytes.
pub fn hex_decode(s: &str) -> Result<Vec<u8>> {
    if s == "-" {
        return Ok(Vec::new());
    }
    // validate every char up front: `from_str_radix` would accept a
    // leading `+` inside a pair, and the byte-indexed slicing below
    // would panic on a multibyte char boundary (remote input must never
    // panic a connection thread or be silently reinterpreted)
    if let Some(bad) = s.chars().find(|c| !c.is_ascii_hexdigit()) {
        return Err(Error::Protocol(format!("bad hex char {bad:?}")));
    }
    if s.len() % 2 != 0 {
        return Err(Error::Protocol(format!("odd hex length {}", s.len())));
    }
    (0..s.len())
        .step_by(2)
        .map(|i| {
            u8::from_str_radix(&s[i..i + 2], 16)
                .map_err(|_| Error::Protocol(format!("bad hex at {i}")))
        })
        .collect()
}

/// A parsed client request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Read a key.
    Get {
        /// Key string.
        key: String,
    },
    /// Write a key.
    Put {
        /// Key string.
        key: String,
        /// Payload bytes.
        value: Vec<u8>,
        /// Context bytes from a prior GET (may be empty).
        context: Vec<u8>,
    },
    /// Server statistics.
    Stats,
    /// Inject a fault into the chaos fabric (admin).
    Fault(FaultCmd),
    /// Recover one node, or — with no node — heal every fault and drain
    /// parked hints (admin).
    Heal {
        /// The node to recover; `None` heals everything.
        node: Option<usize>,
    },
    /// Close the connection.
    Quit,
}

/// A parsed `FAULT` admin subcommand.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultCmd {
    /// Crash one replica.
    Crash {
        /// Replica id.
        node: usize,
    },
    /// Symmetric partition between two node groups.
    Partition {
        /// Left group.
        left: Vec<usize>,
        /// Right group.
        right: Vec<usize>,
    },
    /// Probabilistic message loss, parts-per-million (the wire format is
    /// a probability in `[0, 1]`; ppm keeps the enum `Eq`).
    Drop {
        /// Drop rate in parts-per-million.
        ppm: u32,
    },
    /// Fixed extra per-message delay (µs, capped at delivery time).
    Delay {
        /// Extra delay in µs.
        us: u64,
    },
}

fn parse_node(s: &str) -> Result<usize> {
    s.parse()
        .map_err(|_| Error::Protocol(format!("bad node id {s:?}")))
}

fn parse_group(s: &str) -> Result<Vec<usize>> {
    let ids: Vec<usize> = s
        .split(',')
        .filter(|part| !part.is_empty())
        .map(parse_node)
        .collect::<Result<_>>()?;
    if ids.is_empty() {
        return Err(Error::Protocol(format!("empty node group {s:?}")));
    }
    Ok(ids)
}

fn parse_fault(parts: &mut std::str::SplitWhitespace<'_>) -> Result<FaultCmd> {
    let kind = parts
        .next()
        .ok_or_else(|| Error::Protocol("FAULT needs CRASH|PARTITION|DROP|DELAY".into()))?;
    match kind.to_ascii_uppercase().as_str() {
        "CRASH" => {
            let node = parse_node(
                parts
                    .next()
                    .ok_or_else(|| Error::Protocol("FAULT CRASH needs a node".into()))?,
            )?;
            Ok(FaultCmd::Crash { node })
        }
        "PARTITION" => {
            let left = parse_group(
                parts
                    .next()
                    .ok_or_else(|| Error::Protocol("FAULT PARTITION needs two groups".into()))?,
            )?;
            let right = parse_group(
                parts
                    .next()
                    .ok_or_else(|| Error::Protocol("FAULT PARTITION needs two groups".into()))?,
            )?;
            Ok(FaultCmd::Partition { left, right })
        }
        "DROP" => {
            let raw = parts
                .next()
                .ok_or_else(|| Error::Protocol("FAULT DROP needs a probability".into()))?;
            let prob: f64 = raw
                .parse()
                .map_err(|_| Error::Protocol(format!("bad probability {raw:?}")))?;
            if !(0.0..=1.0).contains(&prob) {
                return Err(Error::Protocol(format!("probability {prob} not in [0, 1]")));
            }
            Ok(FaultCmd::Drop { ppm: crate::sim::failure::drop_ppm(prob) })
        }
        "DELAY" => {
            let raw = parts
                .next()
                .ok_or_else(|| Error::Protocol("FAULT DELAY needs microseconds".into()))?;
            let us = raw
                .parse()
                .map_err(|_| Error::Protocol(format!("bad delay {raw:?}")))?;
            Ok(FaultCmd::Delay { us })
        }
        other => Err(Error::Protocol(format!("unknown FAULT kind {other:?}"))),
    }
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let mut parts = line.trim().split_whitespace();
    let cmd = parts.next().unwrap_or("");
    match cmd.to_ascii_uppercase().as_str() {
        "GET" => {
            let key = parts
                .next()
                .ok_or_else(|| Error::Protocol("GET needs a key".into()))?;
            Ok(Request::Get { key: key.to_string() })
        }
        "PUT" => {
            let key = parts
                .next()
                .ok_or_else(|| Error::Protocol("PUT needs a key".into()))?;
            let value = hex_decode(
                parts
                    .next()
                    .ok_or_else(|| Error::Protocol("PUT needs a value".into()))?,
            )?;
            let context = match parts.next() {
                Some(ctx) => hex_decode(ctx)?,
                None => Vec::new(),
            };
            Ok(Request::Put { key: key.to_string(), value, context })
        }
        "STATS" => Ok(Request::Stats),
        "FAULT" => Ok(Request::Fault(parse_fault(&mut parts)?)),
        "HEAL" => {
            let node = parts.next().map(parse_node).transpose()?;
            Ok(Request::Heal { node })
        }
        "QUIT" => Ok(Request::Quit),
        other => Err(Error::Protocol(format!("unknown command {other:?}"))),
    }
}

/// Render a GET answer.
pub fn format_values(values: &[Vec<u8>], context: &[u8]) -> String {
    let mut out = format!("VALUES {} {}\n", values.len(), hex_encode(context));
    for v in values {
        out.push_str(&format!("VALUE {}\n", hex_encode(v)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_roundtrip() {
        for data in [vec![], vec![0u8], vec![0xde, 0xad, 0xbe, 0xef]] {
            assert_eq!(hex_decode(&hex_encode(&data)).unwrap(), data);
        }
        assert_eq!(hex_encode(&[]), "-");
        assert!(hex_decode("abc").is_err());
        assert!(hex_decode("zz").is_err());
    }

    #[test]
    fn parse_get_put() {
        assert_eq!(
            parse_request("GET user:1").unwrap(),
            Request::Get { key: "user:1".into() }
        );
        assert_eq!(
            parse_request("PUT k 6869").unwrap(),
            Request::Put { key: "k".into(), value: b"hi".to_vec(), context: vec![] }
        );
        let with_ctx = parse_request("PUT k 00 0101").unwrap();
        assert_eq!(
            with_ctx,
            Request::Put { key: "k".into(), value: vec![0], context: vec![1, 1] }
        );
    }

    #[test]
    fn parse_errors() {
        assert!(parse_request("GET").is_err());
        assert!(parse_request("PUT k").is_err());
        assert!(parse_request("NOPE x").is_err());
        assert!(parse_request("").is_err());
    }

    #[test]
    fn case_insensitive_commands() {
        assert_eq!(parse_request("quit").unwrap(), Request::Quit);
        assert_eq!(parse_request("stats").unwrap(), Request::Stats);
        assert_eq!(
            parse_request("fault crash 2").unwrap(),
            Request::Fault(FaultCmd::Crash { node: 2 })
        );
        assert_eq!(parse_request("heal").unwrap(), Request::Heal { node: None });
    }

    #[test]
    fn parse_fault_commands() {
        assert_eq!(
            parse_request("FAULT CRASH 1").unwrap(),
            Request::Fault(FaultCmd::Crash { node: 1 })
        );
        assert_eq!(
            parse_request("FAULT PARTITION 0,1 2,3").unwrap(),
            Request::Fault(FaultCmd::Partition { left: vec![0, 1], right: vec![2, 3] })
        );
        assert_eq!(
            parse_request("FAULT DROP 0.25").unwrap(),
            Request::Fault(FaultCmd::Drop { ppm: 250_000 })
        );
        assert_eq!(
            parse_request("FAULT DELAY 1500").unwrap(),
            Request::Fault(FaultCmd::Delay { us: 1500 })
        );
        assert_eq!(parse_request("HEAL 2").unwrap(), Request::Heal { node: Some(2) });
    }

    #[test]
    fn malformed_fault_commands_are_rejected() {
        for bad in [
            "FAULT",
            "FAULT CRASH",
            "FAULT CRASH x",
            "FAULT PARTITION 0,1",
            "FAULT PARTITION , 2",
            "FAULT DROP",
            "FAULT DROP 1.5",
            "FAULT DROP -0.1",
            "FAULT DROP abc",
            "FAULT DELAY",
            "FAULT DELAY -5",
            "FAULT WIGGLE 1",
            "HEAL x",
        ] {
            assert!(parse_request(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn format_values_shape() {
        let text = format_values(&[b"a".to_vec(), b"b".to_vec()], &[9]);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "VALUES 2 09");
        assert_eq!(lines[1], "VALUE 61");
        assert_eq!(lines[2], "VALUE 62");
    }
}
