//! Wire protocols for the TCP server: the legacy line-based **text
//! protocol (v1)** and the length-prefixed **binary protocol (v2)**.
//!
//! A connection's protocol is negotiated by its first bytes: a v2 client
//! opens with [`MAGIC`] + a version byte + `\n` and the server answers
//! with an [`OP_HELLO_ACK`] frame; anything else falls back to the text
//! protocol, so old clients keep working unchanged (see
//! [`super::tcp`]).
//!
//! # Binary protocol v2
//!
//! Every frame is `[u32 big-endian length][u8 opcode][payload]`, the
//! length counting opcode + payload and capped at [`MAX_FRAME_LEN`].
//! Integers inside payloads are LEB128 varints
//! ([`crate::clocks::encoding`]); byte fields are length-prefixed. PUT
//! frames carry the client's actor id and its opaque causal-context
//! token ([`crate::api::CausalCtx`]) — context *and* observed ids — so
//! binary writes are oracle-traceable end to end, and the `PUT_OK`
//! reply returns the new write's id plus the coordinator's post-write
//! token when the write left no concurrent siblings (an empty token
//! means a sibling survived: GET before superseding). Hex never
//! appears on the binary hot path.
//!
//! # Text protocol v1
//!
//! ```text
//! -> GET <key>
//! <- VALUES <n> <ctx-hex>
//! <- VALUE <hex>            (n lines)
//! -> PUT <key> <value-hex> [ctx-hex]
//! <- OK
//! -> STATS
//! <- STATS nodes=<n> shards=<s> metadata_bytes=<b> hints=<h> epoch=<e> wal_bytes=<w> merkle_root=<m> zones=<z> ship_lag=<l> sets=<c> counters=<c> maps=<c>
//! -> QUIT
//! <- BYE
//! ```
//!
//! Typed CRDT ops ([`crate::kernel::crdt`]) address sets, counters, and
//! maps by key; element and field arguments are hex like values:
//!
//! ```text
//! -> SADD <key> <elem-hex>          add-wins set insert
//! <- OK dot=<actor>:<counter>          the dot minted for the add
//! -> SREM <key> <elem-hex>          remove observed dots only
//! <- OK removed=<a:n,b:m | ->          the dots removed (`-` = none seen)
//! -> SMEMBERS <key>
//! <- MEMBERS <n>
//! <- MEMBER <hex>                   (n lines)
//! -> INCR <key> <delta>             PN-counter add (delta may be negative)
//! <- OK value=<v>                      post-increment value
//! -> COUNT <key>
//! <- OK value=<v>
//! -> MPUT <key> <field-hex> <value-hex>
//! <- OK dot=<actor>:<counter>
//! -> MGET <key> <field-hex>
//! <- FIELD <hex | ->                   `-` = absent field
//! ```
//!
//! Fault-injection admin commands drive the cluster's
//! [`Fabric`](super::fabric::Fabric) at runtime:
//!
//! ```text
//! -> FAULT CRASH <node>             crash one replica
//! -> FAULT PARTITION <a,b> <c,d>    symmetric two-group partition
//! -> FAULT DROP <prob>              probabilistic message loss [0, 1]
//! -> FAULT DELAY <us>               extra per-message delay (bounded)
//! -> HEAL <node>                    recover one replica
//! -> HEAL                           heal everything, drain hints
//! <- OK
//! ```
//!
//! Durability admin commands drive a replica's storage backend (real
//! state loss, not just unreachability — see [`crate::store::wal`]):
//!
//! ```text
//! -> RESTART <node>                 crash-restart the node's process;
//! <- OK replayed=<r> discarded=<b>     unpersisted state is lost and the
//!                                      WAL replays the persisted prefix
//! -> WIPE <node>                    destroy the node's state entirely
//! <- OK                                (peers refill it via anti-entropy)
//! ```
//!
//! Elastic-topology admin commands change membership at runtime (binary
//! clients use the dedicated [`OP_JOIN`] / [`OP_DECOMMISSION`] /
//! [`OP_TOPOLOGY`] opcodes instead):
//!
//! ```text
//! -> JOIN                           spin up a new replica, re-home ranges
//! <- OK id=<id> epoch=<e>
//! -> DECOMMISSION <node>            retire a replica, hand off its keys
//! <- OK epoch=<e>
//! -> TOPOLOGY                       current membership view
//! <- TOPOLOGY epoch=<e> slots=<n> members=<a,b,c>
//! ```
//!
//! Errors render as `ERR <message>`. Hex keeps the framing trivial and
//! binary-safe without pulling in an encoder dependency.

use crate::error::{Error, Result};

/// Lowercase hex digits, indexed by nibble.
const HEX_DIGITS: &[u8; 16] = b"0123456789abcdef";

/// Encode bytes as lowercase hex (empty input → `-`).
///
/// Table-driven: two nibble lookups per byte instead of a `format!`
/// round trip — this runs on every text-protocol value and context.
pub fn hex_encode(data: &[u8]) -> String {
    if data.is_empty() {
        return "-".to_string();
    }
    let mut out = Vec::with_capacity(data.len() * 2);
    for &b in data {
        out.push(HEX_DIGITS[usize::from(b >> 4)]);
        out.push(HEX_DIGITS[usize::from(b & 0x0f)]);
    }
    // the table is pure ASCII, so the bytes are valid UTF-8
    String::from_utf8(out).expect("hex digits are ASCII")
}

/// Decode `-` or hex into bytes.
pub fn hex_decode(s: &str) -> Result<Vec<u8>> {
    if s == "-" {
        return Ok(Vec::new());
    }
    // validate every char up front: `from_str_radix` would accept a
    // leading `+` inside a pair, and the byte-indexed slicing below
    // would panic on a multibyte char boundary (remote input must never
    // panic a connection thread or be silently reinterpreted)
    if let Some(bad) = s.chars().find(|c| !c.is_ascii_hexdigit()) {
        return Err(Error::Protocol(format!("bad hex char {bad:?}")));
    }
    if s.len() % 2 != 0 {
        return Err(Error::Protocol(format!("odd hex length {}", s.len())));
    }
    (0..s.len())
        .step_by(2)
        .map(|i| {
            u8::from_str_radix(&s[i..i + 2], 16)
                .map_err(|_| Error::Protocol(format!("bad hex at {i}")))
        })
        .collect()
}

/// A parsed client request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Read a key.
    Get {
        /// Key string.
        key: String,
    },
    /// Write a key.
    Put {
        /// Key string.
        key: String,
        /// Payload bytes.
        value: Vec<u8>,
        /// Context bytes from a prior GET (may be empty).
        context: Vec<u8>,
    },
    /// Add an element to an observed-remove set (mints a dot).
    SAdd {
        /// Key string.
        key: String,
        /// Element bytes.
        elem: Vec<u8>,
    },
    /// Remove an element's *observed* dots from a set.
    SRem {
        /// Key string.
        key: String,
        /// Element bytes.
        elem: Vec<u8>,
    },
    /// List a set's members.
    SMembers {
        /// Key string.
        key: String,
    },
    /// Add a (possibly negative) delta to a PN-counter.
    Incr {
        /// Key string.
        key: String,
        /// Signed delta.
        by: i64,
    },
    /// Read a PN-counter's value.
    Count {
        /// Key string.
        key: String,
    },
    /// Write a field in an observed-remove map (mints a dot).
    MPut {
        /// Key string.
        key: String,
        /// Field bytes.
        field: Vec<u8>,
        /// Value bytes.
        value: Vec<u8>,
    },
    /// Read a field from an observed-remove map.
    MGet {
        /// Key string.
        key: String,
        /// Field bytes.
        field: Vec<u8>,
    },
    /// Server statistics.
    Stats,
    /// Inject a fault into the chaos fabric (admin).
    Fault(FaultCmd),
    /// Recover one node, or — with no node — heal every fault and drain
    /// parked hints (admin).
    Heal {
        /// The node to recover; `None` heals everything.
        node: Option<usize>,
    },
    /// Admit a new replica at runtime (admin).
    Join,
    /// Retire a replica at runtime, handing off its keys (admin).
    Decommission {
        /// The node to retire.
        node: usize,
    },
    /// Report the current membership view (epoch, slots, members).
    Topology,
    /// Crash-restart one replica's process: unpersisted state is lost,
    /// the WAL replays the persisted prefix (admin).
    Restart {
        /// The node to restart.
        node: usize,
    },
    /// Destroy one replica's state entirely, disk included (admin).
    Wipe {
        /// The node to wipe.
        node: usize,
    },
    /// Close the connection.
    Quit,
}

/// A parsed `FAULT` admin subcommand.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultCmd {
    /// Crash one replica.
    Crash {
        /// Replica id.
        node: usize,
    },
    /// Symmetric partition between two node groups.
    Partition {
        /// Left group.
        left: Vec<usize>,
        /// Right group.
        right: Vec<usize>,
    },
    /// Probabilistic message loss, parts-per-million (the wire format is
    /// a probability in `[0, 1]`; ppm keeps the enum `Eq`).
    Drop {
        /// Drop rate in parts-per-million.
        ppm: u32,
    },
    /// Fixed extra per-message delay (µs, capped at delivery time).
    Delay {
        /// Extra delay in µs.
        us: u64,
    },
}

fn parse_node(s: &str) -> Result<usize> {
    s.parse()
        .map_err(|_| Error::Protocol(format!("bad node id {s:?}")))
}

fn parse_group(s: &str) -> Result<Vec<usize>> {
    let ids: Vec<usize> = s
        .split(',')
        .filter(|part| !part.is_empty())
        .map(parse_node)
        .collect::<Result<_>>()?;
    if ids.is_empty() {
        return Err(Error::Protocol(format!("empty node group {s:?}")));
    }
    Ok(ids)
}

fn parse_fault(parts: &mut std::str::SplitWhitespace<'_>) -> Result<FaultCmd> {
    let kind = parts
        .next()
        .ok_or_else(|| Error::Protocol("FAULT needs CRASH|PARTITION|DROP|DELAY".into()))?;
    match kind.to_ascii_uppercase().as_str() {
        "CRASH" => {
            let node = parse_node(
                parts
                    .next()
                    .ok_or_else(|| Error::Protocol("FAULT CRASH needs a node".into()))?,
            )?;
            Ok(FaultCmd::Crash { node })
        }
        "PARTITION" => {
            let left = parse_group(
                parts
                    .next()
                    .ok_or_else(|| Error::Protocol("FAULT PARTITION needs two groups".into()))?,
            )?;
            let right = parse_group(
                parts
                    .next()
                    .ok_or_else(|| Error::Protocol("FAULT PARTITION needs two groups".into()))?,
            )?;
            Ok(FaultCmd::Partition { left, right })
        }
        "DROP" => {
            let raw = parts
                .next()
                .ok_or_else(|| Error::Protocol("FAULT DROP needs a probability".into()))?;
            let prob: f64 = raw
                .parse()
                .map_err(|_| Error::Protocol(format!("bad probability {raw:?}")))?;
            if !(0.0..=1.0).contains(&prob) {
                return Err(Error::Protocol(format!("probability {prob} not in [0, 1]")));
            }
            Ok(FaultCmd::Drop { ppm: crate::sim::failure::drop_ppm(prob) })
        }
        "DELAY" => {
            let raw = parts
                .next()
                .ok_or_else(|| Error::Protocol("FAULT DELAY needs microseconds".into()))?;
            let us = raw
                .parse()
                .map_err(|_| Error::Protocol(format!("bad delay {raw:?}")))?;
            Ok(FaultCmd::Delay { us })
        }
        other => Err(Error::Protocol(format!("unknown FAULT kind {other:?}"))),
    }
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let mut parts = line.trim().split_whitespace();
    let cmd = parts.next().unwrap_or("");
    match cmd.to_ascii_uppercase().as_str() {
        "GET" => {
            let key = parts
                .next()
                .ok_or_else(|| Error::Protocol("GET needs a key".into()))?;
            Ok(Request::Get { key: key.to_string() })
        }
        "PUT" => {
            let key = parts
                .next()
                .ok_or_else(|| Error::Protocol("PUT needs a key".into()))?;
            let value = hex_decode(
                parts
                    .next()
                    .ok_or_else(|| Error::Protocol("PUT needs a value".into()))?,
            )?;
            let context = match parts.next() {
                Some(ctx) => hex_decode(ctx)?,
                None => Vec::new(),
            };
            Ok(Request::Put { key: key.to_string(), value, context })
        }
        "SADD" | "SREM" => {
            let key = parts
                .next()
                .ok_or_else(|| Error::Protocol(format!("{cmd} needs a key")))?;
            let elem = hex_decode(
                parts
                    .next()
                    .ok_or_else(|| Error::Protocol(format!("{cmd} needs an element")))?,
            )?;
            let key = key.to_string();
            if cmd.eq_ignore_ascii_case("SADD") {
                Ok(Request::SAdd { key, elem })
            } else {
                Ok(Request::SRem { key, elem })
            }
        }
        "SMEMBERS" => {
            let key = parts
                .next()
                .ok_or_else(|| Error::Protocol("SMEMBERS needs a key".into()))?;
            Ok(Request::SMembers { key: key.to_string() })
        }
        "INCR" => {
            let key = parts
                .next()
                .ok_or_else(|| Error::Protocol("INCR needs a key".into()))?;
            let raw = parts
                .next()
                .ok_or_else(|| Error::Protocol("INCR needs a delta".into()))?;
            let by: i64 = raw
                .parse()
                .map_err(|_| Error::Protocol(format!("bad delta {raw:?}")))?;
            Ok(Request::Incr { key: key.to_string(), by })
        }
        "COUNT" => {
            let key = parts
                .next()
                .ok_or_else(|| Error::Protocol("COUNT needs a key".into()))?;
            Ok(Request::Count { key: key.to_string() })
        }
        "MPUT" => {
            let key = parts
                .next()
                .ok_or_else(|| Error::Protocol("MPUT needs a key".into()))?;
            let field = hex_decode(
                parts
                    .next()
                    .ok_or_else(|| Error::Protocol("MPUT needs a field".into()))?,
            )?;
            let value = hex_decode(
                parts
                    .next()
                    .ok_or_else(|| Error::Protocol("MPUT needs a value".into()))?,
            )?;
            Ok(Request::MPut { key: key.to_string(), field, value })
        }
        "MGET" => {
            let key = parts
                .next()
                .ok_or_else(|| Error::Protocol("MGET needs a key".into()))?;
            let field = hex_decode(
                parts
                    .next()
                    .ok_or_else(|| Error::Protocol("MGET needs a field".into()))?,
            )?;
            Ok(Request::MGet { key: key.to_string(), field })
        }
        "STATS" => Ok(Request::Stats),
        "FAULT" => Ok(Request::Fault(parse_fault(&mut parts)?)),
        "HEAL" => {
            let node = parts.next().map(parse_node).transpose()?;
            Ok(Request::Heal { node })
        }
        "JOIN" => Ok(Request::Join),
        "DECOMMISSION" => {
            let node = parse_node(
                parts
                    .next()
                    .ok_or_else(|| Error::Protocol("DECOMMISSION needs a node".into()))?,
            )?;
            Ok(Request::Decommission { node })
        }
        "TOPOLOGY" => Ok(Request::Topology),
        "RESTART" => {
            let node = parse_node(
                parts
                    .next()
                    .ok_or_else(|| Error::Protocol("RESTART needs a node".into()))?,
            )?;
            Ok(Request::Restart { node })
        }
        "WIPE" => {
            let node = parse_node(
                parts
                    .next()
                    .ok_or_else(|| Error::Protocol("WIPE needs a node".into()))?,
            )?;
            Ok(Request::Wipe { node })
        }
        "QUIT" => Ok(Request::Quit),
        other => Err(Error::Protocol(format!("unknown command {other:?}"))),
    }
}

/// Render a GET answer.
pub fn format_values(values: &[Vec<u8>], context: &[u8]) -> String {
    let mut out = format!("VALUES {} {}\n", values.len(), hex_encode(context));
    for v in values {
        out.push_str(&format!("VALUE {}\n", hex_encode(v)));
    }
    out
}

// ===================================================================
// Binary protocol v2
// ===================================================================

use crate::clocks::encoding::{
    expect_end, get_bytes, get_varint, get_zigzag, put_varint, put_zigzag,
};
use crate::kernel::crdt::{decode_dot, decode_dots, encode_dot, encode_dots, Dot};

/// Connection preamble of a v2 client: these four bytes, then one
/// version byte, then `\n`. Any other opening byte sequence selects the
/// text protocol.
pub const MAGIC: [u8; 4] = *b"DVV2";

/// Current binary wire-format version, negotiated in the hello
/// exchange. Bumped to 3 when the elastic-topology revision extended
/// [`OP_STATS_REPLY`] with a fifth (epoch) field and added the
/// membership opcodes, to 4 when the durability revision appended a
/// sixth (`wal_bytes`) field, and to 5 when the hash-tree anti-entropy
/// revision appended a seventh (`merkle_root`), and to 6 when the
/// geo-replication revision appended an eighth (`zones`) and ninth
/// (`ship_lag`) field and added the cross-DC shipping opcodes
/// ([`OP_SHIP`] / [`OP_SHIP_ACK`]): the stats payload decodes strictly
/// (`expect_end`), so an older binary would misparse the longer reply
/// mid-session — version negotiation turns that silent skew into a
/// clean hello-time rejection. (The `DVV2` magic names the protocol
/// family, not this byte.) Bumped to 7 when the CRDT revision added the
/// typed-datatype opcodes ([`OP_SADD`] … [`OP_MGET`], replies
/// [`OP_DOT_REPLY`] … [`OP_FIELD_REPLY`]) and appended three datatype
/// counts (`sets`, `counters`, `maps`) to [`OP_STATS_REPLY`].
pub const VERSION: u8 = 7;

/// Upper bound on a frame's length field (16 MiB). A header promising
/// more is rejected before any allocation.
pub const MAX_FRAME_LEN: u32 = 1 << 24;

/// Upper bound on one buffered text-protocol line (64 KiB). A client
/// that streams bytes without ever sending `\n` is answered with
/// `ERR line too long` and disconnected instead of growing server
/// memory without bound.
pub const MAX_TEXT_LINE: usize = 64 * 1024;

/// Whether a reply payload of `payload_len` bytes fits in one v2 frame
/// (the length field counts opcode + payload, so the cap leaves room
/// for the opcode byte). The single source of truth for the cap
/// arithmetic: [`write_frame`] enforces it and reply builders consult
/// it, so an oversized result degrades to an `OP_ERR` instead of
/// tripping `write_frame` and killing the connection.
pub fn fits_frame(payload_len: usize) -> bool {
    (payload_len as u64).saturating_add(1) <= u64::from(MAX_FRAME_LEN)
}

/// Request opcode: read a key. Payload: key bytes (UTF-8).
pub const OP_GET: u8 = 0x01;
/// Request opcode: write a key. Payload:
/// `[klen][key][vlen][value][actor][tlen][ctx token]` (varint lengths).
pub const OP_PUT: u8 = 0x02;
/// Request opcode: server statistics. Empty payload.
pub const OP_STATS: u8 = 0x03;
/// Request opcode: admin command (`FAULT …` / `HEAL …` in text form).
pub const OP_ADMIN: u8 = 0x04;
/// Request opcode: close the connection. Empty payload.
pub const OP_QUIT: u8 = 0x05;
/// Request opcode: admit a new replica (admin). Empty payload; replies
/// with an [`OP_TOPOLOGY_REPLY`] whose epoch and `slots` come from this
/// join specifically — `slots - 1` is the id assigned to *this*
/// request, stable even when joins race.
pub const OP_JOIN: u8 = 0x06;
/// Request opcode: retire a replica (admin). Payload: varint node id;
/// replies with an [`OP_TOPOLOGY_REPLY`] of the post-retirement view.
pub const OP_DECOMMISSION: u8 = 0x07;
/// Request opcode: current membership view. Empty payload; replies with
/// an [`OP_TOPOLOGY_REPLY`] — how a long-lived client discovers and
/// refreshes routing across epoch bumps mid-session.
pub const OP_TOPOLOGY: u8 = 0x08;
/// Request opcode: a cross-DC shipper batch (geo-replication). Payload:
/// `[zone][hlc l][hlc c][count]` then `[key][slen][state]` per entry —
/// the origin zone, the shipper's hybrid-logical-clock stamp, and the
/// encoded DVV states to merge. Replies with [`OP_SHIP_ACK`].
pub const OP_SHIP: u8 = 0x09;
/// Request opcode: add an element to an observed-remove set. Payload:
/// `[klen][key][elen][elem]` (varint lengths). Replies with an
/// [`OP_DOT_REPLY`] carrying the minted dot.
pub const OP_SADD: u8 = 0x0A;
/// Request opcode: remove an element's observed dots from a set.
/// Payload: `[klen][key][elen][elem]`. Replies with an
/// [`OP_DOTS_REPLY`] listing the dots actually removed (empty = the
/// element was not present).
pub const OP_SREM: u8 = 0x0B;
/// Request opcode: list a set's members. Payload: key bytes (UTF-8).
/// Replies with an [`OP_MEMBERS_REPLY`].
pub const OP_SMEMBERS: u8 = 0x0C;
/// Request opcode: add a signed delta to a PN-counter. Payload:
/// `[klen][key][zigzag delta]`. Replies with an [`OP_COUNT_REPLY`]
/// carrying the post-increment value.
pub const OP_INCR: u8 = 0x0D;
/// Request opcode: read a PN-counter. Payload: key bytes (UTF-8).
/// Replies with an [`OP_COUNT_REPLY`].
pub const OP_COUNT: u8 = 0x0E;
/// Request opcode: write a field in an observed-remove map. Payload:
/// `[klen][key][flen][field][vlen][value]`. Replies with an
/// [`OP_DOT_REPLY`].
pub const OP_MPUT: u8 = 0x0F;
/// Request opcode: read a field from an observed-remove map. Payload:
/// `[klen][key][flen][field]`. Replies with an [`OP_FIELD_REPLY`].
pub const OP_MGET: u8 = 0x10;

/// Response opcode: negotiation ack. Payload: the accepted version byte.
pub const OP_HELLO_ACK: u8 = 0x80;
/// Response opcode: GET answer. Payload:
/// `[tlen][ctx token][count]` then `[vlen][value]` per sibling — the
/// token's observed ids run parallel to the values.
pub const OP_VALUES: u8 = 0x81;
/// Response opcode: PUT ack. Payload: `[id][tlen][post-write ctx
/// token]`; an empty token means no chainable context (a concurrent
/// sibling survived the write).
pub const OP_PUT_OK: u8 = 0x82;
/// Response opcode: generic success (admin commands). Empty payload.
pub const OP_OK: u8 = 0x83;
/// Response opcode: statistics. Payload:
/// `[nodes][shards][metadata_bytes][hints][epoch][wal_bytes][merkle_root][zones][ship_lag]`
/// varints.
pub const OP_STATS_REPLY: u8 = 0x84;
/// Response opcode: membership view (answer to [`OP_JOIN`],
/// [`OP_DECOMMISSION`], and [`OP_TOPOLOGY`]). Payload:
/// `[epoch][slots][count][member ids…]` varints — `slots` is the total
/// dense ids allocated, so after a JOIN the newcomer's id is
/// `slots - 1`.
pub const OP_TOPOLOGY_REPLY: u8 = 0x87;
/// Response opcode: error. Payload: UTF-8 message. The connection stays
/// usable unless the framing itself was broken.
pub const OP_ERR: u8 = 0x85;
/// Response opcode: goodbye (answer to [`OP_QUIT`]). Empty payload.
pub const OP_BYE: u8 = 0x86;
/// Response opcode: shipper-batch ack (answer to [`OP_SHIP`]). Payload:
/// `[applied][hlc l][hlc c]` — the number of states merged and the
/// receiving node's post-merge hybrid-logical-clock reading.
pub const OP_SHIP_ACK: u8 = 0x88;
/// Response opcode: one minted dot (answer to [`OP_SADD`] /
/// [`OP_MPUT`]). Payload: `[actor][counter]` varints, counter ≥ 1.
pub const OP_DOT_REPLY: u8 = 0x89;
/// Response opcode: the dots an [`OP_SREM`] removed. Payload:
/// `[count]` then `[actor][counter]` per dot, strictly ascending.
pub const OP_DOTS_REPLY: u8 = 0x8A;
/// Response opcode: a set's members (answer to [`OP_SMEMBERS`]).
/// Payload: `[count]` then `[elen][elem]` per member.
pub const OP_MEMBERS_REPLY: u8 = 0x8B;
/// Response opcode: a counter value (answer to [`OP_INCR`] /
/// [`OP_COUNT`]). Payload: one zigzag varint
/// ([`crate::clocks::encoding::put_zigzag`]).
pub const OP_COUNT_REPLY: u8 = 0x8C;
/// Response opcode: a map field read (answer to [`OP_MGET`]). Payload:
/// `[present u8]` then, when present is 1, `[vlen][value]` — the
/// explicit flag keeps an absent field distinct from an empty value.
pub const OP_FIELD_REPLY: u8 = 0x8D;

/// A parsed binary (v2) request frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BinRequest {
    /// Read a key.
    Get {
        /// Key string.
        key: String,
    },
    /// Write a key, traced: the writing actor and its causal-context
    /// token travel with the payload.
    Put {
        /// Key string.
        key: String,
        /// Payload bytes.
        value: Vec<u8>,
        /// Raw id of the writing [`crate::clocks::Actor`].
        actor: u32,
        /// Encoded [`crate::api::CausalCtx`] token (empty = blind write
        /// with nothing observed).
        ctx_token: Vec<u8>,
    },
    /// Add an element to an observed-remove set.
    SAdd {
        /// Key string.
        key: String,
        /// Element bytes.
        elem: Vec<u8>,
    },
    /// Remove an element's observed dots from a set.
    SRem {
        /// Key string.
        key: String,
        /// Element bytes.
        elem: Vec<u8>,
    },
    /// List a set's members.
    SMembers {
        /// Key string.
        key: String,
    },
    /// Add a signed delta to a PN-counter.
    Incr {
        /// Key string.
        key: String,
        /// Signed delta.
        by: i64,
    },
    /// Read a PN-counter's value.
    Count {
        /// Key string.
        key: String,
    },
    /// Write a field in an observed-remove map.
    MPut {
        /// Key string.
        key: String,
        /// Field bytes.
        field: Vec<u8>,
        /// Value bytes.
        value: Vec<u8>,
    },
    /// Read a field from an observed-remove map.
    MGet {
        /// Key string.
        key: String,
        /// Field bytes.
        field: Vec<u8>,
    },
    /// Server statistics.
    Stats,
    /// Admin command in text form (`FAULT …` / `HEAL …`), reusing the
    /// text parser so both protocols drive the same fabric switchboard.
    Admin {
        /// The admin command line.
        line: String,
    },
    /// Admit a new replica (admin).
    Join,
    /// Retire a replica (admin).
    Decommission {
        /// The node to retire.
        node: usize,
    },
    /// Current membership view.
    Topology,
    /// A cross-DC shipper batch (geo-replication): HLC-stamped encoded
    /// DVV states streamed from a remote datacenter for merging.
    Ship {
        /// Origin datacenter of the batch.
        zone: u64,
        /// The shipper's hybrid-logical-clock stamp at send time.
        ts: crate::clocks::HlcTimestamp,
        /// `(key, encoded DVV state)` entries to merge.
        entries: Vec<(u64, Vec<u8>)>,
    },
    /// Close the connection.
    Quit,
}

/// Validate a frame header, returning the body length (opcode +
/// payload).
pub fn frame_len(header: [u8; 4]) -> Result<usize> {
    let len = u32::from_be_bytes(header);
    if len == 0 {
        return Err(Error::Protocol("zero-length frame".into()));
    }
    if len > MAX_FRAME_LEN {
        return Err(Error::Protocol(format!(
            "oversized frame: {len} bytes (max {MAX_FRAME_LEN})"
        )));
    }
    Ok(len as usize)
}

/// Write one frame: `[u32 BE length][opcode][payload]`.
pub fn write_frame(w: &mut impl std::io::Write, opcode: u8, payload: &[u8]) -> Result<()> {
    if !fits_frame(payload.len()) {
        return Err(Error::Protocol(format!(
            "frame too large to send: {} bytes",
            payload.len() as u64 + 1
        )));
    }
    let len = payload.len() as u64 + 1;
    w.write_all(&(len as u32).to_be_bytes())?;
    w.write_all(&[opcode])?;
    w.write_all(payload)?;
    Ok(())
}

/// Read one frame with plain blocking I/O (client side; the server's
/// timeout-aware loop lives in [`super::tcp`]). Returns
/// `(opcode, payload)`.
pub fn read_frame(r: &mut impl std::io::Read) -> Result<(u8, Vec<u8>)> {
    let mut header = [0u8; 4];
    r.read_exact(&mut header)?;
    let len = frame_len(header)?;
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    let payload = body.split_off(1);
    Ok((body[0], payload))
}

/// Read a varint length/count field, bounded by the bytes actually
/// remaining after it (every counted element costs at least one byte).
/// Rejecting here keeps remote input from picking allocation sizes.
fn get_len(buf: &[u8], pos: &mut usize) -> Result<usize> {
    let len = get_varint(buf, pos)?;
    if len > (buf.len() - *pos) as u64 {
        return Err(Error::Protocol(format!(
            "length field {len} exceeds the {} remaining payload bytes",
            buf.len() - *pos
        )));
    }
    Ok(len as usize)
}

fn utf8(bytes: &[u8], what: &str) -> Result<String> {
    String::from_utf8(bytes.to_vec())
        .map_err(|_| Error::Protocol(format!("{what} is not valid UTF-8")))
}

/// Encode the shared `[klen][key][blen][blob]` payload shape of the
/// typed ops that carry a key plus one opaque byte argument (SADD /
/// SREM element, MGET field).
fn encode_key_blob(key: &str, blob: &[u8]) -> Vec<u8> {
    let mut p = Vec::with_capacity(key.len() + blob.len() + 8);
    put_varint(&mut p, key.len() as u64);
    p.extend_from_slice(key.as_bytes());
    put_varint(&mut p, blob.len() as u64);
    p.extend_from_slice(blob);
    p
}

/// Decode the `[klen][key][blen][blob]` payload shape strictly
/// (trailing bytes rejected).
fn decode_key_blob(payload: &[u8]) -> Result<(String, Vec<u8>)> {
    let mut pos = 0;
    let klen = get_len(payload, &mut pos)?;
    let key = utf8(get_bytes(payload, &mut pos, klen)?, "key")?;
    let blen = get_len(payload, &mut pos)?;
    let blob = get_bytes(payload, &mut pos, blen)?.to_vec();
    expect_end(payload, pos)?;
    Ok((key, blob))
}

/// Encode a binary request as `(opcode, payload)`.
pub fn encode_bin_request(req: &BinRequest) -> (u8, Vec<u8>) {
    match req {
        BinRequest::Get { key } => (OP_GET, key.as_bytes().to_vec()),
        BinRequest::Put { key, value, actor, ctx_token } => {
            let mut p =
                Vec::with_capacity(key.len() + value.len() + ctx_token.len() + 16);
            put_varint(&mut p, key.len() as u64);
            p.extend_from_slice(key.as_bytes());
            put_varint(&mut p, value.len() as u64);
            p.extend_from_slice(value);
            put_varint(&mut p, u64::from(*actor));
            put_varint(&mut p, ctx_token.len() as u64);
            p.extend_from_slice(ctx_token);
            (OP_PUT, p)
        }
        BinRequest::SAdd { key, elem } => (OP_SADD, encode_key_blob(key, elem)),
        BinRequest::SRem { key, elem } => (OP_SREM, encode_key_blob(key, elem)),
        BinRequest::SMembers { key } => (OP_SMEMBERS, key.as_bytes().to_vec()),
        BinRequest::Incr { key, by } => {
            let mut p = Vec::with_capacity(key.len() + 12);
            put_varint(&mut p, key.len() as u64);
            p.extend_from_slice(key.as_bytes());
            put_zigzag(&mut p, *by);
            (OP_INCR, p)
        }
        BinRequest::Count { key } => (OP_COUNT, key.as_bytes().to_vec()),
        BinRequest::MPut { key, field, value } => {
            let mut p = Vec::with_capacity(key.len() + field.len() + value.len() + 12);
            put_varint(&mut p, key.len() as u64);
            p.extend_from_slice(key.as_bytes());
            put_varint(&mut p, field.len() as u64);
            p.extend_from_slice(field);
            put_varint(&mut p, value.len() as u64);
            p.extend_from_slice(value);
            (OP_MPUT, p)
        }
        BinRequest::MGet { key, field } => (OP_MGET, encode_key_blob(key, field)),
        BinRequest::Stats => (OP_STATS, Vec::new()),
        BinRequest::Admin { line } => (OP_ADMIN, line.as_bytes().to_vec()),
        BinRequest::Join => (OP_JOIN, Vec::new()),
        BinRequest::Decommission { node } => {
            let mut p = Vec::with_capacity(4);
            put_varint(&mut p, *node as u64);
            (OP_DECOMMISSION, p)
        }
        BinRequest::Topology => (OP_TOPOLOGY, Vec::new()),
        BinRequest::Ship { zone, ts, entries } => {
            let states: usize = entries.iter().map(|(_, s)| s.len() + 16).sum();
            let mut p = Vec::with_capacity(states + 24);
            put_varint(&mut p, *zone);
            crate::clocks::hlc::encode_hlc(ts, &mut p);
            put_varint(&mut p, entries.len() as u64);
            for (key, state) in entries {
                put_varint(&mut p, *key);
                put_varint(&mut p, state.len() as u64);
                p.extend_from_slice(state);
            }
            (OP_SHIP, p)
        }
        BinRequest::Quit => (OP_QUIT, Vec::new()),
    }
}

/// Decode a binary request frame. Any malformed payload — truncation,
/// bad UTF-8, out-of-range fields, trailing bytes, unknown opcode —
/// errors cleanly.
pub fn decode_bin_request(opcode: u8, payload: &[u8]) -> Result<BinRequest> {
    match opcode {
        OP_GET => Ok(BinRequest::Get { key: utf8(payload, "key")? }),
        OP_PUT => {
            let mut pos = 0;
            let klen = get_len(payload, &mut pos)?;
            let key = utf8(get_bytes(payload, &mut pos, klen)?, "key")?;
            let vlen = get_len(payload, &mut pos)?;
            let value = get_bytes(payload, &mut pos, vlen)?.to_vec();
            let actor = get_varint(payload, &mut pos)?;
            let actor = u32::try_from(actor)
                .map_err(|_| Error::Protocol(format!("actor id {actor} out of range")))?;
            let tlen = get_len(payload, &mut pos)?;
            let ctx_token = get_bytes(payload, &mut pos, tlen)?.to_vec();
            expect_end(payload, pos)?;
            Ok(BinRequest::Put { key, value, actor, ctx_token })
        }
        OP_SADD => {
            let (key, elem) = decode_key_blob(payload)?;
            Ok(BinRequest::SAdd { key, elem })
        }
        OP_SREM => {
            let (key, elem) = decode_key_blob(payload)?;
            Ok(BinRequest::SRem { key, elem })
        }
        OP_SMEMBERS => Ok(BinRequest::SMembers { key: utf8(payload, "key")? }),
        OP_INCR => {
            let mut pos = 0;
            let klen = get_len(payload, &mut pos)?;
            let key = utf8(get_bytes(payload, &mut pos, klen)?, "key")?;
            let by = get_zigzag(payload, &mut pos)?;
            expect_end(payload, pos)?;
            Ok(BinRequest::Incr { key, by })
        }
        OP_COUNT => Ok(BinRequest::Count { key: utf8(payload, "key")? }),
        OP_MPUT => {
            let mut pos = 0;
            let klen = get_len(payload, &mut pos)?;
            let key = utf8(get_bytes(payload, &mut pos, klen)?, "key")?;
            let flen = get_len(payload, &mut pos)?;
            let field = get_bytes(payload, &mut pos, flen)?.to_vec();
            let vlen = get_len(payload, &mut pos)?;
            let value = get_bytes(payload, &mut pos, vlen)?.to_vec();
            expect_end(payload, pos)?;
            Ok(BinRequest::MPut { key, field, value })
        }
        OP_MGET => {
            let (key, field) = decode_key_blob(payload)?;
            Ok(BinRequest::MGet { key, field })
        }
        OP_STATS => {
            expect_end(payload, 0)?;
            Ok(BinRequest::Stats)
        }
        OP_ADMIN => Ok(BinRequest::Admin { line: utf8(payload, "admin line")? }),
        OP_JOIN => {
            expect_end(payload, 0)?;
            Ok(BinRequest::Join)
        }
        OP_DECOMMISSION => {
            let mut pos = 0;
            let node = get_varint(payload, &mut pos)?;
            let node = usize::try_from(node)
                .map_err(|_| Error::Protocol(format!("node id {node} out of range")))?;
            expect_end(payload, pos)?;
            Ok(BinRequest::Decommission { node })
        }
        OP_TOPOLOGY => {
            expect_end(payload, 0)?;
            Ok(BinRequest::Topology)
        }
        OP_SHIP => {
            let mut pos = 0;
            let zone = get_varint(payload, &mut pos)?;
            let ts = crate::clocks::hlc::decode_hlc(payload, &mut pos)?;
            let count = get_len(payload, &mut pos)?;
            // no `with_capacity(count)`: a hostile count must not pick
            // the allocation size (same rule as `decode_values`)
            let mut entries = Vec::new();
            for _ in 0..count {
                let key = get_varint(payload, &mut pos)?;
                let slen = get_len(payload, &mut pos)?;
                entries.push((key, get_bytes(payload, &mut pos, slen)?.to_vec()));
            }
            expect_end(payload, pos)?;
            Ok(BinRequest::Ship { zone, ts, entries })
        }
        OP_QUIT => {
            expect_end(payload, 0)?;
            Ok(BinRequest::Quit)
        }
        other => Err(Error::Protocol(format!("unknown opcode {other:#04x}"))),
    }
}

/// Encode an [`OP_VALUES`] payload: ctx token + sibling values.
pub fn encode_values(values: &[Vec<u8>], ctx_token: &[u8]) -> Vec<u8> {
    let total: usize = values.iter().map(|v| v.len() + 4).sum();
    let mut p = Vec::with_capacity(ctx_token.len() + total + 8);
    put_varint(&mut p, ctx_token.len() as u64);
    p.extend_from_slice(ctx_token);
    put_varint(&mut p, values.len() as u64);
    for v in values {
        put_varint(&mut p, v.len() as u64);
        p.extend_from_slice(v);
    }
    p
}

/// Decode an [`OP_VALUES`] payload into `(values, ctx_token)`.
pub fn decode_values(payload: &[u8]) -> Result<(Vec<Vec<u8>>, Vec<u8>)> {
    let mut pos = 0;
    let tlen = get_len(payload, &mut pos)?;
    let ctx_token = get_bytes(payload, &mut pos, tlen)?.to_vec();
    let count = get_len(payload, &mut pos)?;
    // no `with_capacity(count)`: even the remaining-bytes bound would
    // let a hostile count reserve ~24x its wire size in Vec headers
    let mut values = Vec::new();
    for _ in 0..count {
        let vlen = get_len(payload, &mut pos)?;
        values.push(get_bytes(payload, &mut pos, vlen)?.to_vec());
    }
    expect_end(payload, pos)?;
    Ok((values, ctx_token))
}

/// Encode an [`OP_PUT_OK`] payload: write id + post-write ctx token.
pub fn encode_put_ok(id: u64, ctx_token: &[u8]) -> Vec<u8> {
    let mut p = Vec::with_capacity(ctx_token.len() + 12);
    put_varint(&mut p, id);
    put_varint(&mut p, ctx_token.len() as u64);
    p.extend_from_slice(ctx_token);
    p
}

/// Decode an [`OP_PUT_OK`] payload into `(id, ctx_token)`.
pub fn decode_put_ok(payload: &[u8]) -> Result<(u64, Vec<u8>)> {
    let mut pos = 0;
    let id = get_varint(payload, &mut pos)?;
    let tlen = get_len(payload, &mut pos)?;
    let ctx_token = get_bytes(payload, &mut pos, tlen)?.to_vec();
    expect_end(payload, pos)?;
    Ok((id, ctx_token))
}

/// Encode an [`OP_DOT_REPLY`] payload: one minted dot.
pub fn encode_dot_reply(dot: &Dot) -> Vec<u8> {
    let mut p = Vec::with_capacity(8);
    encode_dot(dot, &mut p);
    p
}

/// Decode an [`OP_DOT_REPLY`] payload.
pub fn decode_dot_reply(payload: &[u8]) -> Result<Dot> {
    let mut pos = 0;
    let dot = decode_dot(payload, &mut pos)?;
    expect_end(payload, pos)?;
    Ok(dot)
}

/// Encode an [`OP_DOTS_REPLY`] payload: the dots an SREM removed
/// (strictly ascending; empty = nothing observed).
pub fn encode_dots_reply(dots: &[Dot]) -> Vec<u8> {
    let mut p = Vec::with_capacity(dots.len() * 6 + 4);
    encode_dots(dots, &mut p);
    p
}

/// Decode an [`OP_DOTS_REPLY`] payload.
pub fn decode_dots_reply(payload: &[u8]) -> Result<Vec<Dot>> {
    let mut pos = 0;
    let dots = decode_dots(payload, &mut pos)?;
    expect_end(payload, pos)?;
    Ok(dots)
}

/// Encode an [`OP_MEMBERS_REPLY`] payload: a set's members.
pub fn encode_members_reply(members: &[Vec<u8>]) -> Vec<u8> {
    let total: usize = members.iter().map(|m| m.len() + 4).sum();
    let mut p = Vec::with_capacity(total + 4);
    put_varint(&mut p, members.len() as u64);
    for m in members {
        put_varint(&mut p, m.len() as u64);
        p.extend_from_slice(m);
    }
    p
}

/// Decode an [`OP_MEMBERS_REPLY`] payload.
pub fn decode_members_reply(payload: &[u8]) -> Result<Vec<Vec<u8>>> {
    let mut pos = 0;
    let count = get_len(payload, &mut pos)?;
    // no `with_capacity(count)`: a hostile count must not pick the
    // allocation size (same rule as `decode_values`)
    let mut members = Vec::new();
    for _ in 0..count {
        let mlen = get_len(payload, &mut pos)?;
        members.push(get_bytes(payload, &mut pos, mlen)?.to_vec());
    }
    expect_end(payload, pos)?;
    Ok(members)
}

/// Encode an [`OP_COUNT_REPLY`] payload: one zigzag-varint counter
/// value.
pub fn encode_count_reply(value: i64) -> Vec<u8> {
    let mut p = Vec::with_capacity(10);
    put_zigzag(&mut p, value);
    p
}

/// Decode an [`OP_COUNT_REPLY`] payload.
pub fn decode_count_reply(payload: &[u8]) -> Result<i64> {
    let mut pos = 0;
    let value = get_zigzag(payload, &mut pos)?;
    expect_end(payload, pos)?;
    Ok(value)
}

/// Encode an [`OP_FIELD_REPLY`] payload: an explicit presence flag,
/// then the value bytes when present — `None` (absent field) and
/// `Some(empty)` must stay distinguishable on the wire.
pub fn encode_field_reply(value: Option<&[u8]>) -> Vec<u8> {
    match value {
        None => vec![0],
        Some(v) => {
            let mut p = Vec::with_capacity(v.len() + 6);
            p.push(1);
            put_varint(&mut p, v.len() as u64);
            p.extend_from_slice(v);
            p
        }
    }
}

/// Decode an [`OP_FIELD_REPLY`] payload.
pub fn decode_field_reply(payload: &[u8]) -> Result<Option<Vec<u8>>> {
    let mut pos = 0;
    let present = get_bytes(payload, &mut pos, 1)?[0];
    match present {
        0 => {
            expect_end(payload, pos)?;
            Ok(None)
        }
        1 => {
            let vlen = get_len(payload, &mut pos)?;
            let value = get_bytes(payload, &mut pos, vlen)?.to_vec();
            expect_end(payload, pos)?;
            Ok(Some(value))
        }
        other => Err(Error::Protocol(format!("bad presence flag {other}"))),
    }
}

/// A decoded [`OP_STATS_REPLY`]: every gauge the server exposes, in
/// wire order. Grew one field per protocol revision — a named struct
/// keeps call sites readable where a 12-tuple would not.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatsReply {
    /// Live replica count.
    pub nodes: u64,
    /// Shards per node.
    pub shards: u64,
    /// Clock-metadata bytes across the cluster.
    pub metadata_bytes: u64,
    /// Parked hints awaiting handoff.
    pub hints: u64,
    /// Current membership epoch.
    pub epoch: u64,
    /// WAL bytes on disk across the cluster.
    pub wal_bytes: u64,
    /// Combined Merkle root over all shards.
    pub merkle_root: u64,
    /// Datacenter (zone) count.
    pub zones: u64,
    /// Cross-DC shipping lag (pending entries).
    pub ship_lag: u64,
    /// Keys holding an observed-remove set.
    pub sets: u64,
    /// Keys holding a PN-counter.
    pub counters: u64,
    /// Keys holding an observed-remove map.
    pub maps: u64,
}

/// Encode an [`OP_STATS_REPLY`] payload.
pub fn encode_stats_reply(s: &StatsReply) -> Vec<u8> {
    let mut p = Vec::with_capacity(52);
    put_varint(&mut p, s.nodes);
    put_varint(&mut p, s.shards);
    put_varint(&mut p, s.metadata_bytes);
    put_varint(&mut p, s.hints);
    put_varint(&mut p, s.epoch);
    put_varint(&mut p, s.wal_bytes);
    put_varint(&mut p, s.merkle_root);
    put_varint(&mut p, s.zones);
    put_varint(&mut p, s.ship_lag);
    put_varint(&mut p, s.sets);
    put_varint(&mut p, s.counters);
    put_varint(&mut p, s.maps);
    p
}

/// Decode an [`OP_STATS_REPLY`] payload.
pub fn decode_stats_reply(payload: &[u8]) -> Result<StatsReply> {
    let mut pos = 0;
    let s = StatsReply {
        nodes: get_varint(payload, &mut pos)?,
        shards: get_varint(payload, &mut pos)?,
        metadata_bytes: get_varint(payload, &mut pos)?,
        hints: get_varint(payload, &mut pos)?,
        epoch: get_varint(payload, &mut pos)?,
        wal_bytes: get_varint(payload, &mut pos)?,
        merkle_root: get_varint(payload, &mut pos)?,
        zones: get_varint(payload, &mut pos)?,
        ship_lag: get_varint(payload, &mut pos)?,
        sets: get_varint(payload, &mut pos)?,
        counters: get_varint(payload, &mut pos)?,
        maps: get_varint(payload, &mut pos)?,
    };
    expect_end(payload, pos)?;
    Ok(s)
}

/// Encode an [`OP_SHIP_ACK`] payload: states applied + the receiver's
/// post-merge HLC reading.
pub fn encode_ship_ack(applied: u64, ts: &crate::clocks::HlcTimestamp) -> Vec<u8> {
    let mut p = Vec::with_capacity(24);
    put_varint(&mut p, applied);
    crate::clocks::hlc::encode_hlc(ts, &mut p);
    p
}

/// Decode an [`OP_SHIP_ACK`] payload into `(applied, hlc)`.
pub fn decode_ship_ack(payload: &[u8]) -> Result<(u64, crate::clocks::HlcTimestamp)> {
    let mut pos = 0;
    let applied = get_varint(payload, &mut pos)?;
    let ts = crate::clocks::hlc::decode_hlc(payload, &mut pos)?;
    expect_end(payload, pos)?;
    Ok((applied, ts))
}

/// Encode an [`OP_TOPOLOGY_REPLY`] payload:
/// `[epoch][slots][count][member ids…]`.
pub fn encode_topology_reply(epoch: u64, slots: u64, members: &[u64]) -> Vec<u8> {
    let mut p = Vec::with_capacity(members.len() * 2 + 12);
    put_varint(&mut p, epoch);
    put_varint(&mut p, slots);
    put_varint(&mut p, members.len() as u64);
    for &m in members {
        put_varint(&mut p, m);
    }
    p
}

/// Decode an [`OP_TOPOLOGY_REPLY`] payload into
/// `(epoch, slots, member ids)`.
pub fn decode_topology_reply(payload: &[u8]) -> Result<(u64, u64, Vec<u64>)> {
    let mut pos = 0;
    let epoch = get_varint(payload, &mut pos)?;
    let slots = get_varint(payload, &mut pos)?;
    let count = get_len(payload, &mut pos)?;
    // the remaining-bytes bound in `get_len` caps the allocation
    let mut members = Vec::new();
    for _ in 0..count {
        members.push(get_varint(payload, &mut pos)?);
    }
    expect_end(payload, pos)?;
    Ok((epoch, slots, members))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hex_roundtrip() {
        for data in [vec![], vec![0u8], vec![0xde, 0xad, 0xbe, 0xef]] {
            assert_eq!(hex_decode(&hex_encode(&data)).unwrap(), data);
        }
        assert_eq!(hex_encode(&[]), "-");
        assert!(hex_decode("abc").is_err());
        assert!(hex_decode("zz").is_err());
    }

    #[test]
    fn parse_get_put() {
        assert_eq!(
            parse_request("GET user:1").unwrap(),
            Request::Get { key: "user:1".into() }
        );
        assert_eq!(
            parse_request("PUT k 6869").unwrap(),
            Request::Put { key: "k".into(), value: b"hi".to_vec(), context: vec![] }
        );
        let with_ctx = parse_request("PUT k 00 0101").unwrap();
        assert_eq!(
            with_ctx,
            Request::Put { key: "k".into(), value: vec![0], context: vec![1, 1] }
        );
    }

    #[test]
    fn parse_errors() {
        assert!(parse_request("GET").is_err());
        assert!(parse_request("PUT k").is_err());
        assert!(parse_request("NOPE x").is_err());
        assert!(parse_request("").is_err());
    }

    #[test]
    fn case_insensitive_commands() {
        assert_eq!(parse_request("quit").unwrap(), Request::Quit);
        assert_eq!(parse_request("stats").unwrap(), Request::Stats);
        assert_eq!(
            parse_request("fault crash 2").unwrap(),
            Request::Fault(FaultCmd::Crash { node: 2 })
        );
        assert_eq!(parse_request("heal").unwrap(), Request::Heal { node: None });
    }

    #[test]
    fn parse_fault_commands() {
        assert_eq!(
            parse_request("FAULT CRASH 1").unwrap(),
            Request::Fault(FaultCmd::Crash { node: 1 })
        );
        assert_eq!(
            parse_request("FAULT PARTITION 0,1 2,3").unwrap(),
            Request::Fault(FaultCmd::Partition { left: vec![0, 1], right: vec![2, 3] })
        );
        assert_eq!(
            parse_request("FAULT DROP 0.25").unwrap(),
            Request::Fault(FaultCmd::Drop { ppm: 250_000 })
        );
        assert_eq!(
            parse_request("FAULT DELAY 1500").unwrap(),
            Request::Fault(FaultCmd::Delay { us: 1500 })
        );
        assert_eq!(parse_request("HEAL 2").unwrap(), Request::Heal { node: Some(2) });
    }

    #[test]
    fn parse_elastic_admin_commands() {
        assert_eq!(parse_request("JOIN").unwrap(), Request::Join);
        assert_eq!(parse_request("join").unwrap(), Request::Join);
        assert_eq!(
            parse_request("DECOMMISSION 2").unwrap(),
            Request::Decommission { node: 2 }
        );
        assert_eq!(parse_request("TOPOLOGY").unwrap(), Request::Topology);
        assert!(parse_request("DECOMMISSION").is_err());
        assert!(parse_request("DECOMMISSION x").is_err());
    }

    #[test]
    fn parse_durability_admin_commands() {
        assert_eq!(parse_request("RESTART 1").unwrap(), Request::Restart { node: 1 });
        assert_eq!(parse_request("restart 1").unwrap(), Request::Restart { node: 1 });
        assert_eq!(parse_request("WIPE 0").unwrap(), Request::Wipe { node: 0 });
        assert!(parse_request("RESTART").is_err());
        assert!(parse_request("RESTART x").is_err());
        assert!(parse_request("WIPE").is_err());
        assert!(parse_request("WIPE -1").is_err());
    }

    #[test]
    fn malformed_fault_commands_are_rejected() {
        for bad in [
            "FAULT",
            "FAULT CRASH",
            "FAULT CRASH x",
            "FAULT PARTITION 0,1",
            "FAULT PARTITION , 2",
            "FAULT DROP",
            "FAULT DROP 1.5",
            "FAULT DROP -0.1",
            "FAULT DROP abc",
            "FAULT DELAY",
            "FAULT DELAY -5",
            "FAULT WIGGLE 1",
            "HEAL x",
        ] {
            assert!(parse_request(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn format_values_shape() {
        let text = format_values(&[b"a".to_vec(), b"b".to_vec()], &[9]);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "VALUES 2 09");
        assert_eq!(lines[1], "VALUE 61");
        assert_eq!(lines[2], "VALUE 62");
    }

    #[test]
    fn bin_requests_roundtrip() {
        let cases = [
            BinRequest::Get { key: "user:1".into() },
            BinRequest::Put {
                key: "k".into(),
                value: b"payload".to_vec(),
                actor: 7,
                ctx_token: vec![1, 0, 0],
            },
            BinRequest::Put {
                key: String::new(),
                value: Vec::new(),
                actor: 0,
                ctx_token: Vec::new(),
            },
            BinRequest::Stats,
            BinRequest::Admin { line: "FAULT CRASH 1".into() },
            BinRequest::Join,
            BinRequest::Decommission { node: 3 },
            BinRequest::Topology,
            BinRequest::Ship {
                zone: 1,
                ts: crate::clocks::HlcTimestamp::new(123_456, 7),
                entries: vec![(42, vec![1, 2, 3]), (99, Vec::new())],
            },
            BinRequest::Ship {
                zone: 0,
                ts: crate::clocks::HlcTimestamp::default(),
                entries: Vec::new(),
            },
            BinRequest::Quit,
        ];
        for req in cases {
            let (opcode, payload) = encode_bin_request(&req);
            assert_eq!(decode_bin_request(opcode, &payload).unwrap(), req, "{req:?}");
        }
    }

    #[test]
    fn bin_request_rejects_malformed_payloads() {
        // unknown opcode
        assert!(decode_bin_request(0x7f, &[]).is_err());
        // trailing bytes on no-payload requests
        assert!(decode_bin_request(OP_STATS, &[1]).is_err());
        assert!(decode_bin_request(OP_QUIT, &[0]).is_err());
        assert!(decode_bin_request(OP_JOIN, &[0]).is_err());
        assert!(decode_bin_request(OP_TOPOLOGY, &[9]).is_err());
        // DECOMMISSION payload must be exactly one varint
        assert!(decode_bin_request(OP_DECOMMISSION, &[]).is_err());
        assert!(decode_bin_request(OP_DECOMMISSION, &[1, 1]).is_err());
        // bad UTF-8 key
        assert!(decode_bin_request(OP_GET, &[0xff, 0xfe]).is_err());
        // every strict prefix of a valid PUT payload must be rejected
        let (_, payload) = encode_bin_request(&BinRequest::Put {
            key: "key".into(),
            value: b"value".to_vec(),
            actor: 3,
            ctx_token: vec![1, 0, 1, 42],
        });
        for cut in 0..payload.len() {
            assert!(
                decode_bin_request(OP_PUT, &payload[..cut]).is_err(),
                "prefix of len {cut} must be rejected"
            );
        }
        // trailing garbage after a valid PUT payload
        let mut long = payload.clone();
        long.push(0);
        assert!(decode_bin_request(OP_PUT, &long).is_err());
        // every strict prefix of a SHIP batch must be rejected, and so
        // must trailing garbage — a half-delivered cross-DC batch can
        // never half-apply
        let (_, ship) = encode_bin_request(&BinRequest::Ship {
            zone: 1,
            ts: crate::clocks::HlcTimestamp::new(1 << 40, 3),
            entries: vec![(7, vec![9, 9]), (8, vec![1])],
        });
        for cut in 0..ship.len() {
            assert!(
                decode_bin_request(OP_SHIP, &ship[..cut]).is_err(),
                "ship prefix of len {cut} must be rejected"
            );
        }
        let mut long = ship.clone();
        long.push(0);
        assert!(decode_bin_request(OP_SHIP, &long).is_err());
    }

    #[test]
    fn ship_ack_roundtrips_and_rejects_truncation() {
        let ts = crate::clocks::HlcTimestamp::new(987_654, 2);
        let p = encode_ship_ack(3, &ts);
        assert_eq!(decode_ship_ack(&p).unwrap(), (3, ts));
        for cut in 0..p.len() {
            assert!(decode_ship_ack(&p[..cut]).is_err(), "ack prefix {cut}");
        }
        let mut long = p.clone();
        long.push(1);
        assert!(decode_ship_ack(&long).is_err());
    }

    #[test]
    fn frame_headers_are_validated() {
        assert!(frame_len(0u32.to_be_bytes()).is_err(), "zero length");
        assert!(frame_len((MAX_FRAME_LEN + 1).to_be_bytes()).is_err(), "oversized");
        assert_eq!(frame_len(5u32.to_be_bytes()).unwrap(), 5);
    }

    #[test]
    fn fits_frame_boundary_matches_write_frame() {
        let max = MAX_FRAME_LEN as usize;
        // payload of MAX - 1 bytes -> length field == MAX: the largest
        // frame that may legally cross the wire
        assert!(fits_frame(max - 1));
        // payload of MAX bytes -> length field == MAX + 1: one past
        assert!(!fits_frame(max));
        assert!(!fits_frame(usize::MAX), "saturating add must not wrap");

        // write_frame must agree with fits_frame at both boundary
        // lengths — the guard in the GET path relies on it
        let payload = vec![0u8; max - 1];
        let mut buf = Vec::new();
        write_frame(&mut buf, OP_VALUES, &payload).unwrap();
        assert_eq!(buf.len(), 4 + max);
        assert_eq!(frame_len(buf[..4].try_into().unwrap()).unwrap(), max);

        let payload = vec![0u8; max];
        assert!(write_frame(&mut std::io::sink(), OP_VALUES, &payload).is_err());
    }

    #[test]
    fn frames_roundtrip_through_a_buffer() {
        let mut buf = Vec::new();
        write_frame(&mut buf, OP_GET, b"key").unwrap();
        write_frame(&mut buf, OP_QUIT, &[]).unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap(), (OP_GET, b"key".to_vec()));
        assert_eq!(read_frame(&mut r).unwrap(), (OP_QUIT, Vec::new()));
    }

    #[test]
    fn response_payloads_roundtrip() {
        let values = vec![b"a".to_vec(), Vec::new(), b"long value".to_vec()];
        let token = vec![1, 2, 0, 1, 9];
        let p = encode_values(&values, &token);
        assert_eq!(decode_values(&p).unwrap(), (values, token.clone()));

        let p = encode_put_ok(99, &token);
        assert_eq!(decode_put_ok(&p).unwrap(), (99, token));

        let stats = StatsReply {
            nodes: 3,
            shards: 64,
            metadata_bytes: 12345,
            hints: 2,
            epoch: 7,
            wal_bytes: 4096,
            merkle_root: 0xDEAD_BEEF,
            zones: 2,
            ship_lag: 5,
            sets: 11,
            counters: 4,
            maps: 1,
        };
        let p = encode_stats_reply(&stats);
        assert_eq!(decode_stats_reply(&p).unwrap(), stats);
        // truncating any suffix (e.g. a pre-v7 nine-field reply) is a
        // strict decode error, which is why VERSION was bumped
        for cut in 0..p.len() {
            assert!(decode_stats_reply(&p[..cut]).is_err(), "prefix {cut} decoded");
        }

        let p = encode_topology_reply(5, 6, &[0, 2, 3, 5]);
        assert_eq!(decode_topology_reply(&p).unwrap(), (5, 6, vec![0, 2, 3, 5]));
        let p = encode_topology_reply(1, 1, &[0]);
        assert_eq!(decode_topology_reply(&p).unwrap(), (1, 1, vec![0]));
    }

    #[test]
    fn topology_reply_rejects_truncation_and_trailing_bytes() {
        let p = encode_topology_reply(9, 4, &[0, 1, 3]);
        for cut in 0..p.len() {
            assert!(decode_topology_reply(&p[..cut]).is_err(), "prefix {cut}");
        }
        let mut long = p.clone();
        long.push(0);
        assert!(decode_topology_reply(&long).is_err());
    }

    #[test]
    fn response_payloads_reject_truncation() {
        let p = encode_values(&[b"abc".to_vec()], &[1, 0, 0]);
        for cut in 0..p.len() {
            assert!(decode_values(&p[..cut]).is_err(), "values prefix {cut}");
        }
        let p = encode_put_ok(7, &[1, 0, 0]);
        for cut in 0..p.len() {
            assert!(decode_put_ok(&p[..cut]).is_err(), "put_ok prefix {cut}");
        }
    }

    #[test]
    fn parse_typed_crdt_commands() {
        assert_eq!(
            parse_request("SADD s 6869").unwrap(),
            Request::SAdd { key: "s".into(), elem: b"hi".to_vec() }
        );
        assert_eq!(
            parse_request("srem s 68").unwrap(),
            Request::SRem { key: "s".into(), elem: b"h".to_vec() }
        );
        assert_eq!(
            parse_request("SMEMBERS s").unwrap(),
            Request::SMembers { key: "s".into() }
        );
        assert_eq!(
            parse_request("INCR c -3").unwrap(),
            Request::Incr { key: "c".into(), by: -3 }
        );
        assert_eq!(parse_request("COUNT c").unwrap(), Request::Count { key: "c".into() });
        assert_eq!(
            parse_request("MPUT m 61 62").unwrap(),
            Request::MPut { key: "m".into(), field: b"a".to_vec(), value: b"b".to_vec() }
        );
        assert_eq!(
            parse_request("MGET m 61").unwrap(),
            Request::MGet { key: "m".into(), field: b"a".to_vec() }
        );
        // `-` means empty bytes, matching PUT's value convention
        assert_eq!(
            parse_request("SADD s -").unwrap(),
            Request::SAdd { key: "s".into(), elem: Vec::new() }
        );
        for bad in [
            "SADD", "SADD s", "SADD s zz", "SREM s", "SMEMBERS", "INCR c", "INCR c x",
            "INCR c 1.5", "COUNT", "MPUT m", "MPUT m 61", "MGET m",
        ] {
            assert!(parse_request(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn typed_bin_requests_roundtrip() {
        let cases = [
            BinRequest::SAdd { key: "s".into(), elem: b"elem".to_vec() },
            BinRequest::SAdd { key: String::new(), elem: Vec::new() },
            BinRequest::SRem { key: "s".into(), elem: b"elem".to_vec() },
            BinRequest::SMembers { key: "s".into() },
            BinRequest::Incr { key: "c".into(), by: -42 },
            BinRequest::Incr { key: "c".into(), by: i64::MAX },
            BinRequest::Incr { key: "c".into(), by: i64::MIN },
            BinRequest::Count { key: "c".into() },
            BinRequest::MPut { key: "m".into(), field: b"f".to_vec(), value: b"v".to_vec() },
            BinRequest::MPut { key: "m".into(), field: Vec::new(), value: Vec::new() },
            BinRequest::MGet { key: "m".into(), field: b"f".to_vec() },
        ];
        for req in cases {
            let (opcode, payload) = encode_bin_request(&req);
            assert_eq!(decode_bin_request(opcode, &payload).unwrap(), req, "{req:?}");
        }
    }

    #[test]
    fn typed_bin_requests_reject_truncation_and_trailing_bytes() {
        // every strict prefix of each typed request must be rejected
        // (truncation at every field boundary included), and so must
        // one trailing byte — the decoders are strict end to end
        let cases = [
            encode_bin_request(&BinRequest::SAdd { key: "set".into(), elem: b"el".to_vec() }),
            encode_bin_request(&BinRequest::SRem { key: "set".into(), elem: b"el".to_vec() }),
            encode_bin_request(&BinRequest::Incr { key: "ctr".into(), by: -77 }),
            encode_bin_request(&BinRequest::MPut {
                key: "map".into(),
                field: b"field".to_vec(),
                value: b"value".to_vec(),
            }),
            encode_bin_request(&BinRequest::MGet { key: "map".into(), field: b"f".to_vec() }),
        ];
        for (opcode, payload) in cases {
            for cut in 0..payload.len() {
                assert!(
                    decode_bin_request(opcode, &payload[..cut]).is_err(),
                    "op {opcode:#04x} prefix of len {cut} must be rejected"
                );
            }
            let mut long = payload.clone();
            long.push(0);
            assert!(
                decode_bin_request(opcode, &long).is_err(),
                "op {opcode:#04x} trailing byte must be rejected"
            );
        }
        // a hostile length field larger than the remaining payload is
        // rejected before it can size an allocation
        let mut p = Vec::new();
        put_varint(&mut p, 1 << 40);
        assert!(decode_bin_request(OP_SADD, &p).is_err());
        assert!(decode_bin_request(OP_MPUT, &p).is_err());
    }

    #[test]
    fn typed_reply_payloads_roundtrip() {
        let dot = Dot { actor: crate::clocks::Actor::server(3), counter: 17 };
        assert_eq!(decode_dot_reply(&encode_dot_reply(&dot)).unwrap(), dot);

        let dots = vec![
            Dot { actor: crate::clocks::Actor::server(1), counter: 2 },
            Dot { actor: crate::clocks::Actor::server(1), counter: 5 },
            Dot { actor: crate::clocks::Actor::server(4), counter: 1 },
        ];
        assert_eq!(decode_dots_reply(&encode_dots_reply(&dots)).unwrap(), dots);
        assert_eq!(decode_dots_reply(&encode_dots_reply(&[])).unwrap(), Vec::<Dot>::new());

        let members = vec![b"a".to_vec(), Vec::new(), b"long member".to_vec()];
        assert_eq!(decode_members_reply(&encode_members_reply(&members)).unwrap(), members);

        for v in [0i64, 1, -1, 42, -42, i64::MAX, i64::MIN] {
            assert_eq!(decode_count_reply(&encode_count_reply(v)).unwrap(), v);
        }

        // absent and empty-value fields stay distinguishable
        assert_eq!(decode_field_reply(&encode_field_reply(None)).unwrap(), None);
        assert_eq!(
            decode_field_reply(&encode_field_reply(Some(&[]))).unwrap(),
            Some(Vec::new())
        );
        assert_eq!(
            decode_field_reply(&encode_field_reply(Some(b"v"))).unwrap(),
            Some(b"v".to_vec())
        );
    }

    #[test]
    fn typed_reply_payloads_reject_truncation_and_garbage() {
        let dot = Dot { actor: crate::clocks::Actor::server(1), counter: 9 };
        let payloads = [
            encode_dot_reply(&dot),
            encode_dots_reply(&[dot, Dot { actor: crate::clocks::Actor::server(2), counter: 1 }]),
            encode_members_reply(&[b"abc".to_vec(), b"d".to_vec()]),
            encode_count_reply(-123_456),
            encode_field_reply(Some(b"value")),
            encode_field_reply(None),
        ];
        let decoders: [fn(&[u8]) -> bool; 6] = [
            |p| decode_dot_reply(p).is_ok(),
            |p| decode_dots_reply(p).is_ok(),
            |p| decode_members_reply(p).is_ok(),
            |p| decode_count_reply(p).is_ok(),
            |p| decode_field_reply(p).is_ok(),
            |p| decode_field_reply(p).is_ok(),
        ];
        for (p, ok) in payloads.iter().zip(decoders) {
            assert!(ok(p), "untruncated payload must decode");
            for cut in 0..p.len() {
                assert!(!ok(&p[..cut]), "prefix {cut} of {p:?} must be rejected");
            }
            let mut long = p.clone();
            long.push(0);
            assert!(!ok(&long), "trailing byte after {p:?} must be rejected");
        }
        // a counter-zero dot and an unsorted dot list never decode
        assert!(decode_dot_reply(&[0, 0]).is_err());
        let unsorted = {
            let mut p = Vec::new();
            put_varint(&mut p, 2);
            encode_dot(&Dot { actor: crate::clocks::Actor::server(2), counter: 1 }, &mut p);
            encode_dot(&Dot { actor: crate::clocks::Actor::server(1), counter: 1 }, &mut p);
            p
        };
        assert!(decode_dots_reply(&unsorted).is_err());
        // a bad presence flag is rejected
        assert!(decode_field_reply(&[2]).is_err());
    }

    #[test]
    fn hex_lut_matches_reference_format() {
        let data: Vec<u8> = (0..=255).collect();
        let reference: String = data.iter().map(|b| format!("{b:02x}")).collect();
        assert_eq!(hex_encode(&data), reference);
    }
}
