//! Deployable store: an in-process replicated cluster behind a TCP text
//! protocol (`dvv-store serve`).
//!
//! Unlike the discrete-event simulator (which models latency and failure
//! for experiments), this is a real store: N replica shards in one
//! process, quorum get/put through the same [`crate::coordinator`] state
//! machines, dotted version vectors as the causality mechanism, and real
//! bytes for values. String keys hash onto the same consistent ring used
//! everywhere else.

pub mod protocol;
pub mod tcp;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::clocks::vv::VersionVector;
use crate::clocks::Actor;
use crate::cluster::ring::{hash_str, Ring};
use crate::coordinator::{GetOp, PutOp, QuorumSpec};
use crate::error::Result;
use crate::kernel::mechs::DvvMech;
use crate::kernel::{Val, WriteMeta};
use crate::store::KeyStore;

/// A GET's answer: sibling payloads plus the encoded causal context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GetAnswer {
    /// Sibling values (raw bytes), one per concurrent version.
    pub values: Vec<Vec<u8>>,
    /// Opaque context to pass back on PUT (encoded version vector).
    pub context: Vec<u8>,
}

/// An in-process replicated DVV store.
pub struct LocalCluster {
    nodes: Vec<Mutex<KeyStore<DvvMech>>>,
    blobs: Mutex<HashMap<u64, Vec<u8>>>,
    ring: Ring,
    quorum: QuorumSpec,
    next_id: AtomicU64,
    mech: DvvMech,
}

impl LocalCluster {
    /// Build with `nodes` shards and quorum `(n, r, w)`.
    pub fn new(nodes: usize, n: usize, r: usize, w: usize) -> Result<LocalCluster> {
        let quorum = QuorumSpec::new(n.min(nodes), r.min(n), w.min(n))?;
        Ok(LocalCluster {
            nodes: (0..nodes).map(|_| Mutex::new(KeyStore::new(DvvMech))).collect(),
            blobs: Mutex::new(HashMap::new()),
            ring: Ring::new(nodes, 64)?,
            quorum,
            next_id: AtomicU64::new(1),
            mech: DvvMech,
        })
    }

    /// Number of shards.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// GET through a read quorum with read repair.
    pub fn get(&self, key: &str) -> Result<GetAnswer> {
        let k = hash_str(key);
        let replicas = self.ring.replicas_for(k, self.quorum.n);
        let mut op: GetOp<DvvMech> = GetOp::new(self.quorum);
        let mut answer = None;
        for &node in &replicas {
            let state = self.nodes[node].lock().unwrap().state(k);
            if let Some(res) = op.on_reply(&self.mech, &state) {
                answer = Some(res);
            }
        }
        // read repair with the fully merged state
        let merged = op.merged().clone();
        for &node in &replicas {
            self.nodes[node].lock().unwrap().merge_key(k, &merged);
        }
        let res = answer.ok_or(crate::Error::QuorumNotMet {
            got: op.replies(),
            needed: self.quorum.r,
        })?;
        let blobs = self.blobs.lock().unwrap();
        let values = res
            .values
            .iter()
            .map(|v| blobs.get(&v.id).cloned().unwrap_or_default())
            .collect();
        let mut context = Vec::new();
        crate::clocks::encoding::encode_vv(&res.context, &mut context);
        Ok(GetAnswer { values, context })
    }

    /// PUT through a write quorum. `context` is the bytes from a prior
    /// GET (empty slice = blind write).
    pub fn put(&self, key: &str, value: Vec<u8>, context: &[u8]) -> Result<()> {
        let k = hash_str(key);
        let ctx: VersionVector = if context.is_empty() {
            VersionVector::new()
        } else {
            let mut pos = 0;
            crate::clocks::encoding::decode_vv(context, &mut pos)?
        };
        let replicas = self.ring.replicas_for(k, self.quorum.n);
        let coordinator = replicas[0];
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let val = Val::new(id, value.len() as u32);
        self.blobs.lock().unwrap().insert(id, value);

        let meta = WriteMeta {
            client: Actor::client(0),
            physical_us: 0,
            client_seq: None,
        };
        // §4.1: update + sync at the coordinator...
        let state = {
            let mut store = self.nodes[coordinator].lock().unwrap();
            store.write(k, &ctx, val, Actor::server(coordinator as u32), &meta);
            store.state(k)
        };
        // ...then replicate the synced state
        let mut op = PutOp::new(self.quorum);
        let mut done = op.satisfied_immediately();
        for &node in replicas.iter().skip(1) {
            self.nodes[node].lock().unwrap().merge_key(k, &state);
            if op.on_ack() {
                done = true;
            }
        }
        debug_assert!(done || self.quorum.w > replicas.len());
        Ok(())
    }

    /// Current sibling count for a key (diagnostics).
    pub fn siblings(&self, key: &str) -> usize {
        let k = hash_str(key);
        let replicas = self.ring.replicas_for(k, self.quorum.n);
        replicas
            .iter()
            .map(|&n| self.nodes[n].lock().unwrap().sibling_count(k))
            .max()
            .unwrap_or(0)
    }

    /// Total causality metadata bytes across shards (diagnostics).
    pub fn metadata_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.lock().unwrap().metadata_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        c.put("user:1", b"alice".to_vec(), &[]).unwrap();
        let ans = c.get("user:1").unwrap();
        assert_eq!(ans.values, vec![b"alice".to_vec()]);
        assert!(!ans.context.is_empty());
    }

    #[test]
    fn blind_concurrent_puts_make_siblings() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        c.put("k", b"v1".to_vec(), &[]).unwrap();
        c.put("k", b"v2".to_vec(), &[]).unwrap();
        let ans = c.get("k").unwrap();
        assert_eq!(ans.values.len(), 2, "blind writes are concurrent");
    }

    #[test]
    fn contextful_put_supersedes_siblings() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        c.put("k", b"v1".to_vec(), &[]).unwrap();
        c.put("k", b"v2".to_vec(), &[]).unwrap();
        let ans = c.get("k").unwrap();
        c.put("k", b"merged".to_vec(), &ans.context).unwrap();
        let after = c.get("k").unwrap();
        assert_eq!(after.values, vec![b"merged".to_vec()]);
    }

    #[test]
    fn missing_key_is_empty_not_error() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        let ans = c.get("nope").unwrap();
        assert!(ans.values.is_empty());
    }

    #[test]
    fn many_keys_route_across_shards() {
        let c = LocalCluster::new(5, 3, 2, 2).unwrap();
        for i in 0..50 {
            c.put(&format!("key{i}"), format!("val{i}").into_bytes(), &[]).unwrap();
        }
        for i in 0..50 {
            let ans = c.get(&format!("key{i}")).unwrap();
            assert_eq!(ans.values, vec![format!("val{i}").into_bytes()]);
        }
        assert!(c.metadata_bytes() > 0);
    }

    #[test]
    fn single_node_cluster_works() {
        let c = LocalCluster::new(1, 1, 1, 1).unwrap();
        c.put("k", b"x".to_vec(), &[]).unwrap();
        assert_eq!(c.get("k").unwrap().values, vec![b"x".to_vec()]);
    }
}
