//! Deployable store: an in-process replicated cluster behind a TCP text
//! protocol (`dvv-store serve`).
//!
//! Unlike the discrete-event simulator (which models latency and failure
//! for experiments), this is a real store: N replica [`Node`]s in one
//! process, quorum get/put through the same [`crate::coordinator`] state
//! machines, dotted version vectors as the causality mechanism, and real
//! bytes for values. String keys hash onto the same consistent ring used
//! everywhere else.
//!
//! Concurrency layout: there is **no store-wide lock**. Each replica
//! [`Node`] keeps its versioned states in a pluggable
//! [`StorageBackend`](crate::store::StorageBackend) — the TCP server uses
//! the power-of-two lock-striped [`ShardedBackend`] — so concurrent
//! GET/PUT on different keys proceed in parallel, and GETs on the same
//! shard share its reader lock. Value payloads live in a similarly
//! striped blob table keyed by write id. PUT replicates its synced state
//! with one stripe-lock acquisition per peer; multi-key fan-out —
//! [`LocalCluster::anti_entropy_round`], which reconciles replica pairs
//! shard by shard through the bulk [`crate::antientropy`] path —
//! accumulates per-peer merges in a
//! [`MergeBatch`](crate::coordinator::MergeBatch) and applies each peer's
//! batch with one stripe-lock round per shard ([`KeyStore::merge_batch`]).
//!
//! Fault injection: every inter-replica interaction — PUT fan-out, GET
//! sub-reads, read repair, anti-entropy exchanges, hint delivery — is
//! routed through the cluster's [`fabric::Fabric`] switchboard, so
//! crashes, partitions, loss, and delay can be injected at runtime (the
//! `FAULT`/`HEAL` admin commands, or a [`crate::sim::failure::FaultPlan`]
//! stepped by a test). Writes use a **sloppy quorum**: when a home
//! replica is unreachable, the coordinator hands the synced state to the
//! next reachable node off the preference list along with a *hint*
//! naming the intended home; [`LocalCluster::drain_hints`] (also run at
//! the start of every anti-entropy round) delivers hints once the home
//! is reachable again. A [`crate::oracle::SharedOracle`] can be attached
//! to audit every discarded version under real concurrency.
//!
//! Geo-replication (zone-aware clusters, built with
//! [`LocalCluster::with_zones`]): replica placement spreads each key's
//! preference list across datacenters, quorums are scoped to the
//! coordinator's zone (a DC keeps serving while partitioned from the
//! others), writes destined for remote-DC homes are parked for the
//! async cross-DC shipper ([`LocalCluster::ship_round`]) instead of the
//! synchronous fan-out, and every replica carries a hybrid logical
//! clock ([`crate::clocks::Hlc`]) stamped from the fabric's fault
//! cursor plus its injected per-node skew.

pub mod fabric;
pub(crate) mod ops;
pub mod protocol;
#[cfg(unix)]
pub(crate) mod reactor;
pub mod tcp;
pub mod typed;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use crate::antientropy;
use crate::clocks::vv::VersionVector;
use crate::clocks::{Actor, Hlc, HlcTimestamp};
use crate::cluster::ring::hash_str;
use crate::cluster::{NodeId, Topology};
use crate::coordinator::{GetOp, MergeBatch, PutOp, QuorumSpec};
use crate::error::Result;
use crate::kernel::mechs::DvvMech;
use crate::kernel::{Mechanism, Val, WriteMeta};
use crate::oracle::SharedOracle;
use crate::sim::failure::{Fault, FaultPlan};
use crate::store::wal::{RecoveryReport, WalOptions};
use crate::store::{
    DurableBackend, Key, KeyStore, LsmBackend, LsmOptions, ShardedBackend, StorageBackend,
};
use self::fabric::Fabric;

thread_local! {
    /// Per-thread scratch for preference-list walks, reused across ops so
    /// the GET/PUT hot paths allocate no per-op `Vec<NodeId>`
    /// ([`Topology::replicas_into`] fills a caller buffer).
    static SCRATCH: std::cell::RefCell<(Vec<NodeId>, Vec<NodeId>)> =
        const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
}

/// Borrow the thread's two scratch buffers, cleared. Falls back to fresh
/// buffers on (impossible today) re-entrancy rather than panicking a
/// connection thread.
fn with_scratch<R>(f: impl FnOnce(&mut Vec<NodeId>, &mut Vec<NodeId>) -> R) -> R {
    SCRATCH.with(|cell| match cell.try_borrow_mut() {
        Ok(mut bufs) => {
            let (a, b) = &mut *bufs;
            a.clear();
            b.clear();
            f(a, b)
        }
        Err(_) => f(&mut Vec::new(), &mut Vec::new()),
    })
}

/// The per-key replica state the cluster's mechanism keeps.
type DvvState = <DvvMech as Mechanism>::State;

/// A GET's answer: sibling payloads plus the encoded causal context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GetAnswer {
    /// Sibling values (raw bytes), one per concurrent version.
    pub values: Vec<Vec<u8>>,
    /// Write ids parallel to `values` — what a traced client reports as
    /// `observed` on its next PUT ([`LocalCluster::put_traced`]).
    pub ids: Vec<u64>,
    /// Opaque context to pass back on PUT (encoded version vector).
    pub context: Vec<u8>,
}

/// One replica: a versioned DVV key store over backend `B`. Connection
/// threads operate on a `Node` through `&self`; the locks inside the
/// backend are the only synchronization.
#[derive(Debug)]
pub struct Node<B: StorageBackend<DvvMech> = ShardedBackend<DvvMech>> {
    id: usize,
    store: KeyStore<DvvMech, B>,
    /// Hybrid logical clock; advances on geo clusters only (coordinator
    /// stamps on PUT, receivers fold in shipped timestamps).
    hlc: Mutex<Hlc>,
    /// Restart/wipe generation for CRDT dot minting: state loss must
    /// never reuse a dot counter, so typed ops mint under a *fresh*
    /// actor id after every crash-restart or wipe (see
    /// [`typed`] and the false-cover hazard in
    /// [`crate::kernel::crdt`]).
    typed_epoch: AtomicU64,
}

impl<B: StorageBackend<DvvMech>> Node<B> {
    /// Replica id (dense, matches ring node ids).
    pub fn id(&self) -> usize {
        self.id
    }

    /// The replica's versioned store.
    pub fn store(&self) -> &KeyStore<DvvMech, B> {
        &self.store
    }

    /// The replica's latest hybrid-logical-clock reading.
    pub fn hlc_last(&self) -> HlcTimestamp {
        self.hlc.lock().unwrap().last()
    }
}

/// Striped blob table: write-id → payload bytes. Ids are sequential, so
/// a power-of-two mask spreads them evenly across stripes.
#[derive(Debug)]
struct BlobStore {
    stripes: Box<[Mutex<HashMap<u64, Vec<u8>>>]>,
    mask: u64,
}

impl BlobStore {
    fn new(stripes: usize) -> BlobStore {
        let n = stripes.max(1).next_power_of_two();
        BlobStore {
            stripes: (0..n).map(|_| Mutex::new(HashMap::new())).collect(),
            mask: (n - 1) as u64,
        }
    }

    fn insert(&self, id: u64, bytes: Vec<u8>) {
        self.stripes[(id & self.mask) as usize]
            .lock()
            .unwrap()
            .insert(id, bytes);
    }

    fn get(&self, id: u64) -> Vec<u8> {
        self.stripes[(id & self.mask) as usize]
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .unwrap_or_default()
    }
}

/// A sloppy-quorum write parked at a stand-in node, waiting for its home
/// replica to become reachable again.
#[derive(Debug, Clone)]
struct Hint {
    /// The stand-in currently holding the state.
    holder: NodeId,
    /// The preference-list replica the write was meant for.
    home: NodeId,
    /// The key.
    key: Key,
    /// The synced state to merge at `home` on heal.
    state: DvvState,
}

/// An in-process replicated DVV store with **elastic membership**: the
/// node table and the epoch-versioned [`Topology`] both mutate at
/// runtime ([`join_node`](LocalCluster::join_node) /
/// [`decommission_node`](LocalCluster::decommission_node)), while
/// concurrent GET/PUT route through whatever epoch they observe.
pub struct LocalCluster<B: StorageBackend<DvvMech> = ShardedBackend<DvvMech>> {
    /// Dense node table; grows on join, never shrinks (a decommissioned
    /// node keeps its slot so hints and handoff stay routable). Ops hold
    /// the read lock for their duration, which also means a join (write
    /// lock) can never interleave with an op — only decommissions can.
    nodes: RwLock<Vec<Arc<Node<B>>>>,
    /// Backend factory, retained so joined nodes get the same storage
    /// layout the cluster was built with.
    make_backend: Mutex<Box<dyn FnMut(usize) -> B + Send>>,
    blobs: BlobStore,
    topology: Topology,
    quorum: QuorumSpec,
    next_id: AtomicU64,
    mech: DvvMech,
    fabric: Fabric,
    hints: Mutex<Vec<Hint>>,
    /// Cross-DC ship queue (geo clusters): writes whose home replica
    /// lives in another zone wait here — `holder` is the origin
    /// coordinator, `home` the remote-DC replica — until
    /// [`ship_round`](LocalCluster::ship_round) streams them over.
    ship: Mutex<Vec<Hint>>,
    oracle: OnceLock<Arc<SharedOracle>>,
    /// Serializes join/decommission (ops never take this).
    membership: Mutex<()>,
    /// Divergence detector for anti-entropy and join-rebalance pulls:
    /// hash-tree walk (default) or the whole-shard scan — the exact
    /// oracle the equivalence tests compare against
    /// ([`set_ae_merkle`](LocalCluster::set_ae_merkle)).
    ae_use_merkle: AtomicBool,
    /// Stripe locks serializing typed read-modify-write ops per key
    /// (power-of-two count; see [`typed`]). Register GET/PUT never
    /// touch these.
    typed_locks: Box<[Mutex<()>]>,
    /// Datatype registry for STATS: which kind each typed-written key
    /// holds (coordinator-process view; see
    /// [`typed_counts`](LocalCluster::typed_counts)).
    typed_kinds: Mutex<HashMap<Key, crate::kernel::crdt::CrdtKind>>,
    /// Replication-bytes ledger for typed ops: what delta-shaped fan-out
    /// actually sent / what full-state fallback sent / what always-full
    /// replication would have sent (see
    /// [`crdt_repl_bytes`](LocalCluster::crdt_repl_bytes)).
    crdt_delta_bytes: AtomicU64,
    crdt_full_bytes: AtomicU64,
    crdt_allfull_bytes: AtomicU64,
}

impl LocalCluster {
    /// Build with `nodes` replicas and quorum `(n, r, w)`, using the
    /// default per-replica shard count.
    pub fn new(nodes: usize, n: usize, r: usize, w: usize) -> Result<LocalCluster> {
        LocalCluster::with_shards(nodes, n, r, w, crate::store::DEFAULT_SHARDS)
    }

    /// Build with an explicit per-replica shard (stripe) count.
    pub fn with_shards(
        nodes: usize,
        n: usize,
        r: usize,
        w: usize,
        shards: usize,
    ) -> Result<LocalCluster> {
        LocalCluster::with_backends(nodes, n, r, w, |_| ShardedBackend::with_shards(shards))
    }

    /// Build a **zone-aware** (geo) cluster: `zones[i]` is node `i`'s
    /// datacenter. One node per zone leads each preference list, quorums
    /// scope to the coordinator's zone, and remote-DC homes receive
    /// writes through the async shipper.
    pub fn with_zones(zones: &[usize], n: usize, r: usize, w: usize) -> Result<LocalCluster> {
        LocalCluster::with_backends_zoned(zones, n, r, w, |_| {
            ShardedBackend::with_shards(crate::store::DEFAULT_SHARDS)
        })
    }
}

impl LocalCluster<DurableBackend<DvvMech>> {
    /// Build a **durable** cluster: every replica's store is a
    /// [`DurableBackend`] rooted at `<dir>/node-<id>` with `shards`
    /// stripes (rounded up to a power of two), write-ahead logged with
    /// the given [`WalOptions`]. Opening an existing directory recovers
    /// each replica from its logs (torn tails are truncated; what the
    /// logs lack, hinted handoff and anti-entropy re-deliver from the
    /// other replicas). This is what `dvv-store serve --data-dir` runs
    /// on, and what [`restart_node`](LocalCluster::restart_node)
    /// exercises in tests.
    pub fn with_data_dir(
        nodes: usize,
        n: usize,
        r: usize,
        w: usize,
        shards: usize,
        dir: impl Into<std::path::PathBuf>,
        opts: WalOptions,
    ) -> Result<LocalCluster<DurableBackend<DvvMech>>> {
        LocalCluster::with_data_dir_inner(nodes, None, n, r, w, shards, dir.into(), opts)
    }

    /// The zone-aware durable cluster (`zones[i]` = node `i`'s
    /// datacenter) — what `dvv-store serve --zones` runs on.
    pub fn with_data_dir_zoned(
        zones: &[usize],
        n: usize,
        r: usize,
        w: usize,
        shards: usize,
        dir: impl Into<std::path::PathBuf>,
        opts: WalOptions,
    ) -> Result<LocalCluster<DurableBackend<DvvMech>>> {
        LocalCluster::with_data_dir_inner(zones.len(), Some(zones), n, r, w, shards, dir.into(), opts)
    }

    #[allow(clippy::too_many_arguments)]
    fn with_data_dir_inner(
        nodes: usize,
        zones: Option<&[usize]>,
        n: usize,
        r: usize,
        w: usize,
        shards: usize,
        dir: std::path::PathBuf,
        opts: WalOptions,
    ) -> Result<LocalCluster<DurableBackend<DvvMech>>> {
        // open the initial replicas *eagerly* so an unusable data dir
        // (permission denied, path is a file, …) surfaces as a clean
        // `Err` instead of a panic inside the infallible backend
        // factory; the factory consumes these in id order and only
        // falls back to a lazy open for nodes joined later at runtime
        let mut ready: std::collections::VecDeque<DurableBackend<DvvMech>> = (0..nodes)
            .map(|id| DurableBackend::open(dir.join(format!("node-{id}")), shards, opts))
            .collect::<Result<_>>()?;
        LocalCluster::with_backends_inner(nodes, zones, n, r, w, move |id| {
            ready.pop_front().unwrap_or_else(|| {
                DurableBackend::open(dir.join(format!("node-{id}")), shards, opts)
                    .expect("open durable backend for joined node")
            })
        })
    }
}

impl LocalCluster<LsmBackend<DvvMech>> {
    /// Build an **LSM-backed** cluster: every replica's store is an
    /// [`LsmBackend`] rooted at `<dir>/node-<id>` — bounded memtable,
    /// bloom-filtered sorted runs, background compaction — so a
    /// replica's working set can exceed RAM. Same recovery story as
    /// [`with_data_dir`](LocalCluster::with_data_dir), plus damaged run
    /// files are quarantined (not deleted) and refilled by anti-entropy.
    /// This is what `dvv-store serve --backend lsm` runs on.
    pub fn with_lsm_dir(
        nodes: usize,
        n: usize,
        r: usize,
        w: usize,
        shards: usize,
        dir: impl Into<std::path::PathBuf>,
        opts: LsmOptions,
    ) -> Result<LocalCluster<LsmBackend<DvvMech>>> {
        LocalCluster::with_lsm_dir_inner(nodes, None, n, r, w, shards, dir.into(), opts)
    }

    /// The zone-aware LSM cluster (`zones[i]` = node `i`'s datacenter).
    pub fn with_lsm_dir_zoned(
        zones: &[usize],
        n: usize,
        r: usize,
        w: usize,
        shards: usize,
        dir: impl Into<std::path::PathBuf>,
        opts: LsmOptions,
    ) -> Result<LocalCluster<LsmBackend<DvvMech>>> {
        LocalCluster::with_lsm_dir_inner(zones.len(), Some(zones), n, r, w, shards, dir.into(), opts)
    }

    #[allow(clippy::too_many_arguments)]
    fn with_lsm_dir_inner(
        nodes: usize,
        zones: Option<&[usize]>,
        n: usize,
        r: usize,
        w: usize,
        shards: usize,
        dir: std::path::PathBuf,
        opts: LsmOptions,
    ) -> Result<LocalCluster<LsmBackend<DvvMech>>> {
        // eager opens for the same reason as `with_data_dir_inner`: an
        // unusable data dir is an `Err`, not a factory panic
        let mut ready: std::collections::VecDeque<LsmBackend<DvvMech>> = (0..nodes)
            .map(|id| LsmBackend::open(dir.join(format!("node-{id}")), shards, opts))
            .collect::<Result<_>>()?;
        LocalCluster::with_backends_inner(nodes, zones, n, r, w, move |id| {
            ready.pop_front().unwrap_or_else(|| {
                LsmBackend::open(dir.join(format!("node-{id}")), shards, opts)
                    .expect("open LSM backend for joined node")
            })
        })
    }
}

impl<B: StorageBackend<DvvMech>> LocalCluster<B> {
    /// Build over an explicit storage backend per replica (`make` is
    /// called once per node id) — how the chaos tests run the same
    /// cluster over both the flat and the sharded backend.
    pub fn with_backends(
        nodes: usize,
        n: usize,
        r: usize,
        w: usize,
        make: impl FnMut(usize) -> B + Send + 'static,
    ) -> Result<LocalCluster<B>> {
        LocalCluster::with_backends_inner(nodes, None, n, r, w, make)
    }

    /// Zone-aware variant of
    /// [`with_backends`](LocalCluster::with_backends): `zones[i]` is
    /// node `i`'s datacenter (the node count is `zones.len()`).
    pub fn with_backends_zoned(
        zones: &[usize],
        n: usize,
        r: usize,
        w: usize,
        make: impl FnMut(usize) -> B + Send + 'static,
    ) -> Result<LocalCluster<B>> {
        LocalCluster::with_backends_inner(zones.len(), Some(zones), n, r, w, make)
    }

    fn with_backends_inner(
        nodes: usize,
        zones: Option<&[usize]>,
        n: usize,
        r: usize,
        w: usize,
        mut make: impl FnMut(usize) -> B + Send + 'static,
    ) -> Result<LocalCluster<B>> {
        let quorum = QuorumSpec::new(n.min(nodes), r.min(n), w.min(n))?;
        let topology = match zones {
            Some(z) => Topology::with_zones(z, 64)?,
            None => Topology::new(nodes, 64)?,
        };
        Ok(LocalCluster {
            nodes: RwLock::new(
                (0..nodes)
                    .map(|id| {
                        Arc::new(Node {
                            id,
                            store: KeyStore::with_backend(DvvMech, make(id)),
                            hlc: Mutex::new(Hlc::new()),
                            typed_epoch: AtomicU64::new(0),
                        })
                    })
                    .collect(),
            ),
            make_backend: Mutex::new(Box::new(make)),
            blobs: BlobStore::new(16),
            topology,
            quorum,
            next_id: AtomicU64::new(1),
            mech: DvvMech,
            fabric: Fabric::new(nodes, 0xFA_B0),
            hints: Mutex::new(Vec::new()),
            ship: Mutex::new(Vec::new()),
            oracle: OnceLock::new(),
            membership: Mutex::new(()),
            ae_use_merkle: AtomicBool::new(true),
            typed_locks: (0..64).map(|_| Mutex::new(())).collect(),
            typed_kinds: Mutex::new(HashMap::new()),
            crdt_delta_bytes: AtomicU64::new(0),
            crdt_full_bytes: AtomicU64::new(0),
            crdt_allfull_bytes: AtomicU64::new(0),
        })
    }

    /// Select the anti-entropy divergence detector: `true` (the default)
    /// walks the incremental hash trees
    /// ([`antientropy::diff_pairs_in_shard_merkle`]); `false` falls back
    /// to the whole-shard scan — kept as the exact oracle the merkle
    /// equivalence tests run both ways.
    pub fn set_ae_merkle(&self, on: bool) {
        self.ae_use_merkle.store(on, Ordering::Relaxed);
    }

    /// Whether anti-entropy currently uses the hash-tree walk.
    pub fn ae_merkle(&self) -> bool {
        self.ae_use_merkle.load(Ordering::Relaxed)
    }

    /// Total node slots (members plus decommissioned; dense ids).
    pub fn node_count(&self) -> usize {
        self.nodes.read().unwrap().len()
    }

    /// Number of active members.
    pub fn member_count(&self) -> usize {
        self.topology.member_count()
    }

    /// Active member ids, ascending.
    pub fn members(&self) -> Vec<NodeId> {
        self.topology.members()
    }

    /// Current membership epoch (monotone; one bump per join or
    /// decommission).
    pub fn epoch(&self) -> u64 {
        self.topology.epoch()
    }

    /// The shared, epoch-versioned topology every op routes through.
    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    /// Per-replica shard (stripe) count.
    pub fn shard_count(&self) -> usize {
        self.nodes
            .read()
            .unwrap()
            .first()
            .map(|n| n.store.shard_count())
            .unwrap_or(0)
    }

    /// One replica (tests, diagnostics, anti-entropy drivers).
    pub fn node(&self, id: usize) -> Arc<Node<B>> {
        Arc::clone(&self.nodes.read().unwrap()[id])
    }

    /// The quorum parameters in force.
    pub fn quorum(&self) -> QuorumSpec {
        self.quorum
    }

    /// The chaos fabric every inter-replica message consults.
    pub fn fabric(&self) -> &Fabric {
        &self.fabric
    }

    /// Attach a ground-truth auditor. Every subsequent store mutation
    /// reports its sibling-set delta; writes that should count must go
    /// through [`put_traced`](LocalCluster::put_traced). A second attach
    /// is ignored.
    pub fn attach_oracle(&self, oracle: Arc<SharedOracle>) {
        let _ = self.oracle.set(oracle);
    }

    /// The attached oracle, if any.
    pub fn oracle(&self) -> Option<&Arc<SharedOracle>> {
        self.oracle.get()
    }

    /// The preference list (home replicas) for a key.
    pub fn replicas_of(&self, key: &str) -> Vec<NodeId> {
        self.topology.replicas_for(hash_str(key), self.quorum.n)
    }

    /// First *live* node of the preference list coordinates (clients can
    /// reach any node; crashed ones fail over to the next).
    fn pick_coordinator(&self, replicas: &[NodeId]) -> Result<NodeId> {
        replicas
            .iter()
            .copied()
            .find(|&n| self.fabric.is_up(n))
            .ok_or_else(|| crate::Error::Unavailable("no live replica to coordinate".into()))
    }

    /// Zone-preferring coordinator pick: a live preference-list replica
    /// in `zone` coordinates when one exists (a geo client talks to its
    /// local DC), otherwise any live replica — what keeps both halves of
    /// a DC partition serving their local clients.
    fn pick_coordinator_in(&self, replicas: &[NodeId], zone: Option<usize>) -> Result<NodeId> {
        if let Some(z) = zone {
            let local = replicas
                .iter()
                .copied()
                .find(|&n| self.topology.zone_of(n) == z && self.fabric.is_up(n));
            if let Some(n) = local {
                return Ok(n);
            }
        }
        self.pick_coordinator(replicas)
    }

    /// Whether this cluster replicates across more than one zone.
    pub fn geo(&self) -> bool {
        self.topology.is_zone_aware() && self.topology.zone_count() > 1
    }

    /// Number of distinct zones among active members (1 when flat).
    pub fn zone_count(&self) -> usize {
        self.topology.zone_count()
    }

    /// The zone a node lives in (0 on flat clusters).
    pub fn zone_of(&self, node: NodeId) -> usize {
        self.topology.zone_of(node)
    }

    /// A node's physical-clock reading: the fabric's fault cursor plus
    /// the node's injected skew ([`Fabric::add_clock_skew`]), floored at
    /// zero — the HLC's physical input, so a `ClockSkew` fault exercises
    /// exactly the backward-jump anomaly hybrid clocks absorb.
    fn phys(&self, node: NodeId) -> u64 {
        (self.fabric.cursor_us() as i64 + self.fabric.clock_skew_us(node)).max(0) as u64
    }

    /// Scope the quorum to the coordinator's zone: R and W are capped at
    /// the number of preference-list replicas in that zone (floored at
    /// one — the coordinator itself). Flat clusters keep the global
    /// quorum untouched.
    fn scoped_quorum(&self, replicas: &[NodeId], coordinator: NodeId) -> QuorumSpec {
        if !self.geo() {
            return self.quorum;
        }
        let z = self.topology.zone_of(coordinator);
        let local = replicas
            .iter()
            .filter(|&&n| self.topology.zone_of(n) == z)
            .count()
            .max(1);
        QuorumSpec::new(self.quorum.n, self.quorum.r.min(local), self.quorum.w.min(local))
            .expect("zone-scoped quorum stays valid")
    }

    /// Coordinator-local PUT (§4.1 update + sync under one shard lock),
    /// with oracle drop-auditing when attached.
    fn write_at_node(
        &self,
        node: &Node<B>,
        key: Key,
        ctx: &VersionVector,
        val: Val,
        meta: &WriteMeta,
    ) -> DvvState {
        let coord = Actor::server(node.id as u32);
        if let Some(oracle) = self.oracle.get() {
            let (before, state) = node.store.write_audited(key, ctx, val, coord, meta);
            oracle.record_drops(&before, &self.mech.values(&state));
            state
        } else {
            node.store.write_returning(key, ctx, val, coord, meta)
        }
    }

    /// Replica-side merge (replication, read repair, anti-entropy, hint
    /// delivery, handoff), with oracle drop-auditing when attached.
    fn merge_at_node(&self, node: &Node<B>, key: Key, incoming: &DvvState) {
        if let Some(oracle) = self.oracle.get() {
            let (before, after) = node.store.merge_key_audited(key, incoming);
            oracle.record_drops(&before, &after);
        } else {
            node.store.merge_key(key, incoming);
        }
    }

    /// GET through a read quorum with read repair. Sub-reads and the
    /// repair push are fabric-routed; unreachable replicas simply do not
    /// reply, and fewer than `R` replies is a quorum failure.
    pub fn get(&self, key: &str) -> Result<GetAnswer> {
        self.get_in_zone(key, None)
    }

    /// GET with a preferred coordinator zone: a live preference-list
    /// replica in `zone` coordinates when one exists, and the read
    /// quorum scopes to the coordinator's zone
    /// ([`scoped_quorum`](LocalCluster::scoped_quorum)). `None` (and any
    /// flat cluster) behaves exactly like [`get`](LocalCluster::get).
    pub fn get_in_zone(&self, key: &str, zone: Option<usize>) -> Result<GetAnswer> {
        let k = hash_str(key);
        with_scratch(|replicas, reached| self.get_at(k, zone, replicas, reached))
    }

    /// The GET body, working in the caller's scratch buffers (`replicas`
    /// holds the preference list, `reached` the replicas that answered)
    /// so the hot path allocates no per-op `Vec<NodeId>`.
    fn get_at(
        &self,
        k: Key,
        zone: Option<usize>,
        replicas: &mut Vec<NodeId>,
        reached: &mut Vec<NodeId>,
    ) -> Result<GetAnswer> {
        self.topology.replicas_into(k, self.quorum.n, replicas);
        let nodes = self.nodes.read().unwrap();
        let coordinator = self.pick_coordinator_in(replicas, zone)?;
        let quorum = self.scoped_quorum(replicas, coordinator);
        let mut op: GetOp<DvvMech> = GetOp::new(quorum);
        let mut answer = None;
        for &node in replicas.iter() {
            // a sub-read is a round trip: request out, state reply back
            if node != coordinator
                && !(self.fabric.deliver(coordinator, node)
                    && self.fabric.deliver(node, coordinator))
            {
                continue;
            }
            let state = nodes[node].store.state(k);
            reached.push(node);
            if let Some(res) = op.on_reply(&self.mech, &state) {
                answer = Some(res);
            }
        }
        let res = answer.ok_or(crate::Error::QuorumNotMet {
            got: op.replies(),
            needed: quorum.r,
        })?;
        // read repair with the fully merged state, on every replica that
        // answered (the push is one more fabric-routed message)
        let merged = op.merged().clone();
        for &node in reached.iter() {
            if node == coordinator || self.fabric.deliver(coordinator, node) {
                self.merge_at_node(&nodes[node], k, &merged);
            }
        }
        let values = res.values.iter().map(|v| self.blobs.get(v.id)).collect();
        let ids = res.values.iter().map(|v| v.id).collect();
        let mut context = Vec::new();
        crate::clocks::encoding::encode_vv(&res.context, &mut context);
        Ok(GetAnswer { values, ids, context })
    }

    /// PUT through a (sloppy) write quorum. `context` is the bytes from
    /// a prior GET (empty slice = blind write).
    ///
    /// Untraced: with an oracle attached this write is *not* registered
    /// (the caller cannot supply the observed ids), and any sibling it
    /// displaces is tallied as unaudited rather than misclassified —
    /// oracle-verified runs should write through
    /// [`put_traced`](LocalCluster::put_traced) exclusively.
    pub fn put(&self, key: &str, value: Vec<u8>, context: &[u8]) -> Result<()> {
        self.put_inner(key, value, context, Actor::client(0), None, None, None, None)
            .map(|_| ())
    }

    /// Traced PUT for the client API: like
    /// [`put_traced`](LocalCluster::put_traced), but also returning the
    /// coordinator's post-write context (encoded version vector) — what
    /// [`crate::api::PutReply`] carries so a session can update itself
    /// without re-reading.
    ///
    /// The context is returned **only when the write left no concurrent
    /// siblings** (the post-write state is exactly the client's own
    /// version). A surviving sibling means the state's context covers an
    /// event the client never observed — chaining a PUT on it would
    /// silently destroy that concurrent write (a true lost update), so
    /// the client must GET (and thereby observe the siblings) first.
    pub fn put_api(
        &self,
        key: &str,
        value: Vec<u8>,
        context: &[u8],
        client: Actor,
        observed: &[u64],
    ) -> Result<(u64, Option<Vec<u8>>)> {
        let (id, state) =
            self.put_inner(key, value, context, client, Some(observed), None, None, None)?;
        let (vals, post_ctx) = self.mech.read(&state);
        let post = if vals.len() == 1 && vals[0].id == id {
            let mut bytes = Vec::new();
            crate::clocks::encoding::encode_vv(&post_ctx, &mut bytes);
            Some(bytes)
        } else {
            None
        };
        Ok((id, post))
    }

    /// PUT that also registers ground truth with an attached oracle:
    /// `client` is the writing actor (one sequential actor per real
    /// client) and `observed` the value ids from that client's latest GET
    /// of this key. Returns the new write's id.
    ///
    /// Fault semantics (§4.1 under partition): the synced state fans out
    /// to every home replica through the fabric. Homes that cannot be
    /// reached are replaced by stand-ins — the next reachable nodes off
    /// the preference list — which store the state *plus a hint* naming
    /// the intended home ([`drain_hints`](LocalCluster::drain_hints)
    /// delivers it on heal). The write succeeds when `W` distinct nodes
    /// (home or stand-in, coordinator included) acknowledged.
    pub fn put_traced(
        &self,
        key: &str,
        value: Vec<u8>,
        context: &[u8],
        client: Actor,
        observed: &[u64],
    ) -> Result<u64> {
        self.put_inner(key, value, context, client, Some(observed), None, None, None)
            .map(|(id, _)| id)
    }

    /// Traced PUT with a preferred coordinator zone: the write commits
    /// on a quorum scoped to the coordinator's zone and remote-DC homes
    /// are parked for the async shipper — the geo write path. `None`
    /// (and any flat cluster) behaves exactly like
    /// [`put_traced`](LocalCluster::put_traced).
    pub fn put_traced_in_zone(
        &self,
        key: &str,
        value: Vec<u8>,
        context: &[u8],
        client: Actor,
        observed: &[u64],
        zone: Option<usize>,
    ) -> Result<u64> {
        self.put_inner(key, value, context, client, Some(observed), zone, None, None)
            .map(|(id, _)| id)
    }

    /// Shared PUT path; `observed: None` marks an untraced write that an
    /// attached oracle must not register. Returns the new write's id and
    /// the coordinator's post-write state snapshot (captured atomically
    /// under the stripe lock; callers that don't need it drop it so the
    /// untraced hot path pays nothing extra).
    ///
    /// `pin` forces the coordinator (the typed read-modify-write path
    /// must commit at the node whose state and actor epoch it minted its
    /// dot from); `repl` attaches the typed replication-bytes profile
    /// tallied at every fan-out receiver (see [`typed`]). Register
    /// callers pass `None` for both.
    #[allow(clippy::too_many_arguments)]
    fn put_inner(
        &self,
        key: &str,
        value: Vec<u8>,
        context: &[u8],
        client: Actor,
        observed: Option<&[u64]>,
        zone: Option<usize>,
        pin: Option<NodeId>,
        repl: Option<&typed::ReplProfile>,
    ) -> Result<(u64, DvvState)> {
        let k = hash_str(key);
        with_scratch(|walk, aux| {
            self.put_at(k, value, context, client, observed, zone, pin, repl, walk, aux)
        })
    }

    /// The PUT body, working in the caller's scratch buffers: `walk`
    /// holds the preference list and is lazily extended with stand-in
    /// candidates ([`Topology::next_distinct`]) instead of materializing
    /// a full-cluster preference list per faulted write; `aux` holds the
    /// missed homes and is then reused for the epoch-guard home list.
    #[allow(clippy::too_many_arguments)]
    fn put_at(
        &self,
        k: Key,
        value: Vec<u8>,
        context: &[u8],
        client: Actor,
        observed: Option<&[u64]>,
        zone: Option<usize>,
        pin: Option<NodeId>,
        repl: Option<&typed::ReplProfile>,
        walk: &mut Vec<NodeId>,
        aux: &mut Vec<NodeId>,
    ) -> Result<(u64, DvvState)> {
        let ctx: VersionVector = if context.is_empty() {
            VersionVector::new()
        } else {
            let mut pos = 0;
            crate::clocks::encoding::decode_vv(context, &mut pos)?
        };
        let epoch = self.topology.epoch();
        self.topology.replicas_into(k, self.quorum.n, walk);
        let home_count = walk.len();
        let nodes = self.nodes.read().unwrap();
        let coordinator = match pin {
            // the pinned node read the state this write was derived
            // from; committing anywhere else would break the dot-mint
            // contract, so a crash in the gap fails the op instead
            Some(n) if self.fabric.is_up(n) => n,
            Some(n) => return Err(crate::Error::Unavailable(format!("pinned node {n} is down"))),
            None => self.pick_coordinator_in(&walk[..home_count], zone)?,
        };
        let quorum = self.scoped_quorum(&walk[..home_count], coordinator);
        let geo = self.geo();
        let my_zone = self.topology.zone_of(coordinator);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let val = Val::new(id, value.len() as u32);
        self.blobs.insert(id, value);
        if let (Some(oracle), Some(observed)) = (self.oracle.get(), observed) {
            // ground truth is fixed by what the client saw, before the
            // value can appear (or be dropped) anywhere
            oracle.on_write(client, k, id, observed);
        }

        let meta = WriteMeta { client, physical_us: 0, client_seq: None };
        // §4.1: update + sync at the coordinator, under one shard lock...
        let state = self.write_at_node(&nodes[coordinator], k, &ctx, val, &meta);
        if geo {
            // stamp the coordinator's hybrid clock (its skewed physical
            // reading dominates; the counter absorbs backward jumps)
            let pt = self.phys(coordinator);
            nodes[coordinator].hlc.lock().unwrap().now(pt);
        }
        // ...then replicate the synced state to each home replica. A PUT
        // carries exactly one key, so this is a direct per-peer merge;
        // multi-key fan-out (anti-entropy) goes through `MergeBatch`.
        let mut op = PutOp::new(quorum);
        let mut done = op.satisfied_immediately();
        for &node in walk.iter().take(home_count) {
            if node == coordinator {
                continue;
            }
            if geo && self.topology.zone_of(node) != my_zone {
                // a remote-DC home: parked for the async cross-DC
                // shipper instead of the synchronous fan-out — it
                // neither counts toward W nor takes a stand-in
                if let Some(rp) = repl {
                    self.tally_repl(&nodes, node, k, rp);
                }
                self.ship.lock().unwrap().push(Hint {
                    holder: coordinator,
                    home: node,
                    key: k,
                    state: state.clone(),
                });
                continue;
            }
            if self.fabric.deliver(coordinator, node) {
                if let Some(rp) = repl {
                    self.tally_repl(&nodes, node, k, rp);
                }
                self.merge_at_node(&nodes[node], k, &state);
                // the ack is its own message; a lost ack leaves the data
                // in place but does not count toward the quorum
                if self.fabric.deliver(node, coordinator) && op.on_ack() {
                    done = true;
                }
            } else {
                aux.push(node);
            }
        }
        // sloppy quorum + hinted handoff: *every* unreachable home gets a
        // stand-in off the preference list holding the state plus a hint
        // — even when the quorum is already met, since the hint (not
        // anti-entropy) is what gets the write home promptly on heal.
        // Stand-in acks count toward the quorum like home acks.
        // `walk[home_count..used]` are consumed stand-ins; the tail past
        // `used` holds pulled-but-unused candidates (one that merely lost
        // a drop roll stays available for the next home), and more are
        // pulled off the ring walk only on demand.
        let mut used = home_count;
        for &home in aux.iter() {
            let mut chosen = None;
            for j in used..walk.len() {
                if self.fabric.deliver(coordinator, walk[j]) {
                    chosen = Some(j);
                    break;
                }
            }
            while chosen.is_none() {
                let Some(cand) = self.topology.next_distinct(k, walk) else { break };
                if self.fabric.deliver(coordinator, cand) {
                    chosen = Some(walk.len() - 1);
                }
            }
            if let Some(j) = chosen {
                walk.swap(used, j);
                let holder = walk[used];
                used += 1;
                if let Some(rp) = repl {
                    self.tally_repl(&nodes, holder, k, rp);
                }
                self.merge_at_node(&nodes[holder], k, &state);
                self.hints.lock().unwrap().push(Hint {
                    holder,
                    home,
                    key: k,
                    state: state.clone(),
                });
                if self.fabric.deliver(holder, coordinator) && op.on_ack() {
                    done = true;
                }
            }
        }
        // epoch guard: membership changed under this op (only a
        // decommission can — a join needs the node-table write lock our
        // read guard blocks). A home we just wrote may already have been
        // swept, so re-deliver the synced state to the key's *current*
        // homes; nothing may be stranded on a retiree.
        if self.topology.epoch() != epoch {
            self.topology.replicas_into(k, self.quorum.n, aux);
            for &home in aux.iter() {
                if home == coordinator {
                    continue;
                }
                if self.fabric.deliver(coordinator, home) {
                    if let Some(rp) = repl {
                        self.tally_repl(&nodes, home, k, rp);
                    }
                    self.merge_at_node(&nodes[home], k, &state);
                } else {
                    self.hints.lock().unwrap().push(Hint {
                        holder: coordinator,
                        home,
                        key: k,
                        state: state.clone(),
                    });
                }
            }
        }
        if done {
            Ok((id, state))
        } else {
            Err(crate::Error::QuorumNotMet { got: op.acks(), needed: quorum.w })
        }
    }

    /// Try to deliver every parked hint whose home replica is reachable
    /// from its holder; undeliverable hints stay parked. Returns the
    /// number delivered. Run automatically at the start of every
    /// [`anti_entropy_round`](LocalCluster::anti_entropy_round).
    ///
    /// Hints are churn-aware: a hint whose home was decommissioned while
    /// it sat parked re-routes to the key's *current* homes instead —
    /// the state must land where the key now lives, not on a retiree.
    pub fn drain_hints(&self) -> usize {
        let pending: Vec<Hint> = std::mem::take(&mut *self.hints.lock().unwrap());
        if pending.is_empty() {
            return 0;
        }
        let nodes = self.nodes.read().unwrap();
        let mut delivered = 0;
        let mut parked = Vec::new();
        for hint in pending {
            if self.topology.is_member(hint.home) {
                if self.fabric.deliver(hint.holder, hint.home) {
                    self.merge_at_node(&nodes[hint.home], hint.key, &hint.state);
                    delivered += 1;
                } else {
                    parked.push(hint);
                }
            } else {
                // home retired mid-park: fan the state to the key's
                // current homes, re-parking the unreachable ones
                let mut any = false;
                for home in self.topology.replicas_for(hint.key, self.quorum.n) {
                    if self.fabric.deliver(hint.holder, home) {
                        self.merge_at_node(&nodes[home], hint.key, &hint.state);
                        any = true;
                    } else {
                        parked.push(Hint { home, ..hint.clone() });
                    }
                }
                if any {
                    delivered += 1;
                }
            }
        }
        if !parked.is_empty() {
            self.hints.lock().unwrap().append(&mut parked);
        }
        delivered
    }

    /// Hints currently parked at stand-in nodes.
    pub fn pending_hints(&self) -> usize {
        self.hints.lock().unwrap().len()
    }

    /// One cross-DC shipper round (geo clusters): stream every parked
    /// remote-DC write from its origin coordinator to its home replica —
    /// each delivery is a fabric-routed message, the receiver folds the
    /// shipper's HLC timestamp into its own clock, then merges the
    /// state. Undeliverable entries stay parked (a partitioned DC's
    /// backlog drains on heal); entries whose home retired mid-park
    /// re-route through the hint machinery. Returns the number
    /// delivered. Run automatically at the start of every
    /// [`anti_entropy_round`](LocalCluster::anti_entropy_round).
    pub fn ship_round(&self) -> usize {
        let pending: Vec<Hint> = std::mem::take(&mut *self.ship.lock().unwrap());
        if pending.is_empty() {
            return 0;
        }
        let nodes = self.nodes.read().unwrap();
        let mut shipped = 0;
        let mut parked = Vec::new();
        for entry in pending {
            if !self.topology.is_member(entry.home) {
                // home retired while parked: the hint path re-routes the
                // state to the key's current homes
                self.hints.lock().unwrap().push(entry);
                continue;
            }
            if self.fabric.deliver(entry.holder, entry.home) {
                let ts = nodes[entry.holder].hlc.lock().unwrap().now(self.phys(entry.holder));
                nodes[entry.home].hlc.lock().unwrap().recv(self.phys(entry.home), ts);
                self.merge_at_node(&nodes[entry.home], entry.key, &entry.state);
                shipped += 1;
            } else {
                parked.push(entry);
            }
        }
        if !parked.is_empty() {
            self.ship.lock().unwrap().append(&mut parked);
        }
        shipped
    }

    /// Cross-DC writes still waiting in the ship queue (the
    /// `STATS ship_lag=` figure; 0 on flat clusters).
    pub fn ship_lag(&self) -> usize {
        self.ship.lock().unwrap().len()
    }

    /// Apply a cross-DC shipper batch received **over the wire**
    /// ([`protocol::OP_SHIP`]): each encoded DVV state is decoded
    /// strictly and merged at every home replica of its key
    /// (oracle-audited), and every touched home folds the remote
    /// shipper's HLC stamp into its own clock first — receive before
    /// merge, so the receiving DC's clocks dominate everything the batch
    /// carried. Returns the number of states applied and the largest
    /// post-merge HLC reading. A malformed state rejects the whole
    /// batch before anything merges: a half-decodable batch must not
    /// half-apply.
    pub fn apply_ship(
        &self,
        ts: HlcTimestamp,
        entries: &[(Key, Vec<u8>)],
    ) -> Result<(u64, HlcTimestamp)> {
        let mut states = Vec::with_capacity(entries.len());
        for (key, bytes) in entries {
            let mut pos = 0;
            let state = <DvvMech as crate::kernel::DurableMechanism>::decode_state(bytes, &mut pos)?;
            crate::clocks::encoding::expect_end(bytes, pos)?;
            states.push((*key, state));
        }
        let nodes = self.nodes.read().unwrap();
        let mut latest = ts;
        let mut homes: Vec<NodeId> = Vec::new();
        for (key, state) in &states {
            self.topology.replicas_into(*key, self.quorum.n, &mut homes);
            for &home in homes.iter() {
                let reading = nodes[home].hlc.lock().unwrap().recv(self.phys(home), ts);
                latest = latest.max(reading);
                self.merge_at_node(&nodes[home], *key, state);
            }
        }
        Ok((states.len() as u64, latest))
    }

    /// One push–pull anti-entropy round: drain deliverable hints, then
    /// reconcile every mutually-reachable replica pair, diffing shard by
    /// shard through the bulk sync path and accumulating the merged
    /// states in a per-peer [`MergeBatch`]. Each side then applies its
    /// whole batch with [`KeyStore::merge_batch`] — one stripe-lock round
    /// per shard instead of one lock per key (per-key audited merges when
    /// an oracle is attached). The per-shard diff is the hash-tree walk
    /// by default (O(log n) digests per quiesced pair) or the exact scan
    /// (see [`set_ae_merkle`](LocalCluster::set_ae_merkle)). Returns the
    /// number of key reconciliations applied (per pair).
    pub fn anti_entropy_round(&self) -> usize {
        self.drain_hints();
        self.ship_round();
        let merkle = self.ae_merkle();
        let members = self.topology.members();
        let nodes = self.nodes.read().unwrap();
        let mut reconciled = 0;
        for (ai, &a) in members.iter().enumerate() {
            for &b in members.iter().skip(ai + 1) {
                // the exchange needs both directions of the link this round
                if !self.fabric.deliver(a, b) || !self.fabric.deliver(b, a) {
                    continue;
                }
                let (sa, sb) = (&nodes[a].store, &nodes[b].store);
                let mut batch: MergeBatch<DvvMech> = MergeBatch::new(nodes.len());
                for shard in 0..sa.shard_count() {
                    let pairs = if merkle {
                        antientropy::diff_pairs_in_shard_merkle(sa, sb, shard)
                    } else {
                        antientropy::diff_pairs_in_shard(sa, sb, shard)
                    };
                    if pairs.is_empty() {
                        continue;
                    }
                    for (key, merged) in antientropy::sync_scalar(&pairs) {
                        batch.push(a, key, merged.clone());
                        batch.push(b, key, merged);
                    }
                }
                reconciled += batch.len() / 2;
                for (node, items) in batch.drain() {
                    if self.oracle.get().is_some() {
                        for (key, state) in &items {
                            self.merge_at_node(&nodes[node], *key, state);
                        }
                    } else {
                        nodes[node].store.merge_batch(&items);
                    }
                }
            }
        }
        reconciled
    }

    // -----------------------------------------------------------------
    // elastic membership
    // -----------------------------------------------------------------

    /// Admit a new replica at runtime: allocate the next dense id, build
    /// its store from the cluster's backend factory, grow the fabric
    /// (clean links), bump the topology epoch, and re-home the key
    /// ranges the newcomer now owns by pulling them from the members
    /// through the anti-entropy bulk-sync path (fabric-routed and
    /// oracle-audited, so a chaos schedule applies to the transfer; a
    /// dropped transfer is healed by later anti-entropy rounds). Returns
    /// `(new node id, new epoch)`.
    pub fn join_node(&self) -> (NodeId, u64) {
        self.join_node_in_zone(0)
    }

    /// [`join_node`](LocalCluster::join_node) into an explicit zone —
    /// how a geo cluster grows a specific datacenter.
    pub fn join_node_in_zone(&self, zone: usize) -> (NodeId, u64) {
        let _serial = self.membership.lock().unwrap();
        let id = {
            let mut nodes = self.nodes.write().unwrap();
            let id = nodes.len();
            let backend = (self.make_backend.lock().unwrap())(id);
            nodes.push(Arc::new(Node {
                id,
                store: KeyStore::with_backend(DvvMech, backend),
                hlc: Mutex::new(Hlc::new()),
                typed_epoch: AtomicU64::new(0),
            }));
            id
        };
        // grow the fabric before the topology can route to the id
        self.fabric.grow_to(id + 1);
        let (tid, epoch) = self.topology.join_in_zone(zone);
        debug_assert_eq!(tid, id, "node table and topology agree on dense ids");
        self.rebalance_join(id);
        (id, epoch)
    }

    /// Pull every key range the joined node now owns from the members,
    /// shard by shard through the anti-entropy diff (the subtree walk by
    /// default — a newcomer's empty trees make every populated subtree
    /// diverge, so the pull degrades gracefully to a bulk transfer — or
    /// the exact scan, per [`set_ae_merkle`](LocalCluster::set_ae_merkle))
    /// + [`antientropy::sync_scalar`], the same bulk path a normal
    /// anti-entropy round uses.
    fn rebalance_join(&self, id: NodeId) {
        let merkle = self.ae_merkle();
        let members = self.topology.members();
        let nodes = self.nodes.read().unwrap();
        let target = &nodes[id];
        let mut homes: Vec<NodeId> = Vec::new();
        for &m in members.iter().filter(|&&m| m != id) {
            // the transfer is a message exchange with the source
            if !self.fabric.deliver(m, id) {
                continue;
            }
            for shard in 0..nodes[m].store.shard_count() {
                let raw = if merkle {
                    antientropy::diff_pairs_in_shard_merkle(&nodes[m].store, &target.store, shard)
                } else {
                    antientropy::diff_pairs_in_shard(&nodes[m].store, &target.store, shard)
                };
                let pairs: Vec<antientropy::KeyPair> = raw
                    .into_iter()
                    .filter(|pair| {
                        self.topology.replicas_into(pair.key, self.quorum.n, &mut homes);
                        homes.contains(&id)
                    })
                    .collect();
                if pairs.is_empty() {
                    continue;
                }
                for (key, merged) in antientropy::sync_scalar(&pairs) {
                    self.merge_at_node(target, key, &merged);
                }
            }
        }
    }

    /// Retire a member at runtime: bump the topology (its ranges
    /// re-route; the id is never reused), then hand off every key it
    /// holds to the key's new homes — reachable homes get the state
    /// merged (oracle-audited) immediately, unreachable ones get a
    /// parked hint so **nothing is lost even when the retiree is cut off
    /// mid-chaos**. Finally, hints parked *for* the retiree re-route to
    /// current homes. The node object keeps its slot (hints may still
    /// name it as holder) but serves no new traffic. Returns the new
    /// epoch.
    ///
    /// Refused when the survivor set would be smaller than the
    /// read/write quorum needs.
    pub fn decommission_node(&self, id: NodeId) -> Result<u64> {
        let _serial = self.membership.lock().unwrap();
        if !self.topology.is_member(id) {
            return Err(crate::Error::Config(format!("node {id} is not an active member")));
        }
        let remaining = self.topology.member_count() - 1;
        if remaining < self.quorum.r.max(self.quorum.w) {
            return Err(crate::Error::Config(format!(
                "decommissioning node {id} would leave {remaining} members — \
                 fewer than the quorum needs"
            )));
        }
        let epoch = self.topology.decommission(id)?;
        {
            let nodes = self.nodes.read().unwrap();
            let src = &nodes[id];
            let mut homes: Vec<NodeId> = Vec::new();
            for shard in 0..src.store.shard_count() {
                for k in src.store.keys_in_shard(shard) {
                    let state = src.store.state(k);
                    self.topology.replicas_into(k, self.quorum.n, &mut homes);
                    for &home in homes.iter() {
                        if self.fabric.deliver(id, home) {
                            self.merge_at_node(&nodes[home], k, &state);
                        } else {
                            self.hints.lock().unwrap().push(Hint {
                                holder: id,
                                home,
                                key: k,
                                state: state.clone(),
                            });
                        }
                    }
                }
            }
        }
        // hints parked with the retiree as home re-route to current homes
        self.drain_hints();
        Ok(epoch)
    }

    // -----------------------------------------------------------------
    // durability faults
    // -----------------------------------------------------------------

    /// Crash-restart one replica's **process**: its storage backend
    /// loses whatever it had not durably persisted and recovers the
    /// rest ([`StorageBackend::crash_restart`]). On a
    /// [`DurableBackend`] that is the unsynced WAL tail; on the
    /// volatile backends it is everything — the distinction the
    /// durability chaos test exercises. Returns what recovery replayed
    /// and discarded. The node keeps serving immediately; hinted
    /// handoff and anti-entropy close the lost gap from its peers.
    pub fn restart_node(&self, id: NodeId) -> RecoveryReport {
        let nodes = self.nodes.read().unwrap();
        match nodes.get(id) {
            Some(node) => {
                // any state loss invalidates the node's dot counters:
                // typed ops must mint under a fresh actor from now on
                node.typed_epoch.fetch_add(1, Ordering::Relaxed);
                node.store.backend().crash_restart()
            }
            None => RecoveryReport::default(), // plans may race a join
        }
    }

    /// Destroy one replica's state entirely (disk included): the node
    /// stays a member and rejoins empty; anti-entropy refills it.
    pub fn wipe_node(&self, id: NodeId) {
        let nodes = self.nodes.read().unwrap();
        if let Some(node) = nodes.get(id) {
            node.typed_epoch.fetch_add(1, Ordering::Relaxed);
            node.store.backend().wipe();
        }
    }

    /// Total durable-log bytes across the active members (the
    /// `STATS wal_bytes=` figure; 0 on volatile backends).
    pub fn wal_bytes(&self) -> u64 {
        let members = self.topology.members();
        let nodes = self.nodes.read().unwrap();
        members
            .iter()
            .map(|&m| nodes[m].store.backend().durable_bytes())
            .sum()
    }

    /// Each active member's whole-store hash-tree root
    /// ([`KeyStore::merkle_root`]) — the convergence witness the chaos
    /// audits assert on: after healing and quiescent anti-entropy, every
    /// member reports the same root.
    pub fn merkle_roots(&self) -> Vec<(NodeId, u64)> {
        let members = self.topology.members();
        let nodes = self.nodes.read().unwrap();
        members
            .iter()
            .map(|&m| (m, nodes[m].store.merkle_root()))
            .collect()
    }

    /// The `STATS merkle_root=` figure: when every active member reports
    /// the same store root, that root; while members still diverge, a
    /// mix of the distinct roots — so the value is *stable* exactly when
    /// the cluster is converged, and an external observer polling STATS
    /// sees it settle.
    pub fn merkle_root(&self) -> u64 {
        let mut roots: Vec<u64> = self.merkle_roots().into_iter().map(|(_, r)| r).collect();
        roots.sort_unstable();
        roots.dedup();
        if roots.len() == 1 {
            roots[0]
        } else {
            roots
                .into_iter()
                .fold(0u64, |acc, r| crate::kernel::digest::mix64(acc ^ r))
        }
    }

    /// Step a [`FaultPlan`] — churn included — against this cluster:
    /// membership faults spin up / retire real nodes through
    /// [`join_node`](LocalCluster::join_node) and
    /// [`decommission_node`](LocalCluster::decommission_node); state-loss
    /// faults hit the node's storage backend
    /// ([`restart_node`](LocalCluster::restart_node) /
    /// [`wipe_node`](LocalCluster::wipe_node)); everything else hits the
    /// fabric as in [`Fabric::advance`]. One seeded schedule thereby
    /// drives the DES ([`FaultPlan::apply`]) and the threaded cluster
    /// identically.
    pub fn advance_plan(&self, plan: &FaultPlan, to_us: u64) {
        self.fabric.advance_each(plan, to_us, |fault| match fault {
            Fault::Join { .. } => {
                let _ = self.join_node();
            }
            Fault::Decommission { node, .. } => {
                // refused decommissions (quorum floor) are skipped, like
                // a crash of an unknown node
                let _ = self.decommission_node(*node);
            }
            Fault::Restart { node, .. } => {
                let _ = self.restart_node(*node);
            }
            Fault::Wipe { node, .. } => self.wipe_node(*node),
            other => self.fabric.apply_fault(other),
        });
    }

    /// Current sibling count for a key (diagnostics).
    pub fn siblings(&self, key: &str) -> usize {
        let k = hash_str(key);
        let replicas = self.topology.replicas_for(k, self.quorum.n);
        let nodes = self.nodes.read().unwrap();
        replicas
            .iter()
            .map(|&n| nodes[n].store.sibling_count(k))
            .max()
            .unwrap_or(0)
    }

    /// Total causality metadata bytes across the active members
    /// (diagnostics; a retiree's frozen remnants are not counted).
    pub fn metadata_bytes(&self) -> u64 {
        let members = self.topology.members();
        let nodes = self.nodes.read().unwrap();
        members.iter().map(|&m| nodes[m].store.metadata_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        c.put("user:1", b"alice".to_vec(), &[]).unwrap();
        let ans = c.get("user:1").unwrap();
        assert_eq!(ans.values, vec![b"alice".to_vec()]);
        assert_eq!(ans.ids.len(), 1);
        assert!(!ans.context.is_empty());
    }

    #[test]
    fn blind_concurrent_puts_make_siblings() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        c.put("k", b"v1".to_vec(), &[]).unwrap();
        c.put("k", b"v2".to_vec(), &[]).unwrap();
        let ans = c.get("k").unwrap();
        assert_eq!(ans.values.len(), 2, "blind writes are concurrent");
    }

    #[test]
    fn contextful_put_supersedes_siblings() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        c.put("k", b"v1".to_vec(), &[]).unwrap();
        c.put("k", b"v2".to_vec(), &[]).unwrap();
        let ans = c.get("k").unwrap();
        c.put("k", b"merged".to_vec(), &ans.context).unwrap();
        let after = c.get("k").unwrap();
        assert_eq!(after.values, vec![b"merged".to_vec()]);
    }

    #[test]
    fn missing_key_is_empty_not_error() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        let ans = c.get("nope").unwrap();
        assert!(ans.values.is_empty());
        assert!(ans.ids.is_empty());
    }

    #[test]
    fn many_keys_route_across_nodes() {
        let c = LocalCluster::new(5, 3, 2, 2).unwrap();
        for i in 0..50 {
            c.put(&format!("key{i}"), format!("val{i}").into_bytes(), &[]).unwrap();
        }
        for i in 0..50 {
            let ans = c.get(&format!("key{i}")).unwrap();
            assert_eq!(ans.values, vec![format!("val{i}").into_bytes()]);
        }
        assert!(c.metadata_bytes() > 0);
    }

    #[test]
    fn single_node_cluster_works() {
        let c = LocalCluster::new(1, 1, 1, 1).unwrap();
        c.put("k", b"x".to_vec(), &[]).unwrap();
        assert_eq!(c.get("k").unwrap().values, vec![b"x".to_vec()]);
    }

    #[test]
    fn explicit_shard_count_is_honored() {
        let c = LocalCluster::with_shards(3, 3, 2, 2, 8).unwrap();
        assert_eq!(c.shard_count(), 8);
        c.put("k", b"x".to_vec(), &[]).unwrap();
        assert_eq!(c.get("k").unwrap().values, vec![b"x".to_vec()]);
    }

    #[test]
    fn flat_backend_cluster_works() {
        let c = LocalCluster::with_backends(3, 3, 2, 2, |_| {
            crate::store::InMemoryBackend::new()
        })
        .unwrap();
        assert_eq!(c.shard_count(), 1);
        c.put("k", b"v1".to_vec(), &[]).unwrap();
        c.put("k", b"v2".to_vec(), &[]).unwrap();
        let ans = c.get("k").unwrap();
        assert_eq!(ans.values.len(), 2);
        c.put("k", b"m".to_vec(), &ans.context).unwrap();
        assert_eq!(c.get("k").unwrap().values, vec![b"m".to_vec()]);
    }

    #[test]
    fn anti_entropy_reconciles_a_diverged_replica() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        // diverge node 0 directly, bypassing the quorum path
        let k = hash_str("lost-update");
        let id = c.next_id.fetch_add(1, Ordering::Relaxed);
        let (_, ctx) = c.node(0).store().read(k);
        c.node(0).store().write(
            k,
            &ctx,
            Val::new(id, 1),
            Actor::server(0),
            &WriteMeta::basic(Actor::client(9)),
        );
        assert_eq!(c.node(1).store().sibling_count(k), 0, "diverged");

        let reconciled = c.anti_entropy_round();
        assert!(reconciled > 0);
        for n in 0..3 {
            assert_eq!(
                c.node(n).store().state(k),
                c.node(0).store().state(k),
                "node {n} converged"
            );
        }
        // a second round finds nothing left to do
        assert_eq!(c.anti_entropy_round(), 0);
    }

    #[test]
    fn concurrent_puts_distinct_keys_do_not_interfere() {
        let c = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
        let mut handles = Vec::new();
        for t in 0..4 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for i in 0..25 {
                    let key = format!("t{t}-k{i}");
                    c.put(&key, key.clone().into_bytes(), &[]).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        for t in 0..4 {
            for i in 0..25 {
                let key = format!("t{t}-k{i}");
                assert_eq!(c.get(&key).unwrap().values, vec![key.into_bytes()]);
            }
        }
    }

    #[test]
    fn crashed_coordinator_fails_over_to_next_replica() {
        let c = LocalCluster::new(4, 3, 2, 2).unwrap();
        let replicas = c.replicas_of("k");
        c.fabric().crash(replicas[0]);
        c.put("k", b"x".to_vec(), &[]).unwrap();
        let ans = c.get("k").unwrap();
        assert_eq!(ans.values, vec![b"x".to_vec()]);
        // the crashed node never saw the write
        assert_eq!(c.node(replicas[0]).store().sibling_count(hash_str("k")), 0);
    }

    #[test]
    fn all_replicas_down_is_unavailable() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        for n in 0..3 {
            c.fabric().crash(n);
        }
        assert!(matches!(c.put("k", b"x".to_vec(), &[]), Err(crate::Error::Unavailable(_))));
        assert!(matches!(c.get("k"), Err(crate::Error::Unavailable(_))));
        c.fabric().heal_all();
        c.put("k", b"x".to_vec(), &[]).unwrap();
    }

    #[test]
    fn partition_starves_the_read_quorum() {
        // R = N = 3: any unreachable replica must fail the read
        let c = LocalCluster::new(3, 3, 3, 1).unwrap();
        c.put("k", b"x".to_vec(), &[]).unwrap();
        let replicas = c.replicas_of("k");
        c.fabric().partition_groups(&[replicas[0]], &[replicas[1]]);
        let err = c.get("k").unwrap_err();
        assert!(matches!(err, crate::Error::QuorumNotMet { got: 2, needed: 3 }), "{err}");
        c.fabric().heal_all();
        assert_eq!(c.get("k").unwrap().values, vec![b"x".to_vec()]);
    }

    #[test]
    fn join_node_rebalances_and_serves() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        for i in 0..40 {
            c.put(&format!("key{i}"), format!("val{i}").into_bytes(), &[]).unwrap();
        }
        let epoch_before = c.epoch();
        let (id, epoch) = c.join_node();
        assert_eq!(id, 3);
        assert_eq!(epoch, epoch_before + 1);
        assert_eq!(c.node_count(), 4);
        assert_eq!(c.members(), vec![0, 1, 2, 3]);
        assert_eq!(c.fabric().node_count(), 4, "fabric grew with the join");
        // the newcomer owns ranges and received their data
        assert!(c.node(3).store().key_count() > 0, "join handoff populated the node");
        // every key still reads back through whatever epoch routes now
        for i in 0..40 {
            let ans = c.get(&format!("key{i}")).unwrap();
            assert_eq!(ans.values, vec![format!("val{i}").into_bytes()]);
        }
        // a fresh write can land on the newcomer's ranges
        for i in 40..80 {
            c.put(&format!("key{i}"), b"x".to_vec(), &[]).unwrap();
        }
    }

    #[test]
    fn decommission_rehomes_every_key() {
        let c = LocalCluster::new(4, 3, 2, 2).unwrap();
        for i in 0..40 {
            c.put(&format!("key{i}"), format!("val{i}").into_bytes(), &[]).unwrap();
        }
        let epoch = c.decommission_node(1).unwrap();
        assert_eq!(epoch, c.epoch());
        assert_eq!(c.members(), vec![0, 2, 3]);
        assert_eq!(c.node_count(), 4, "the slot stays allocated");
        // no preference list names the retiree; reads survive
        for i in 0..40 {
            let key = format!("key{i}");
            assert!(!c.replicas_of(&key).contains(&1));
            let ans = c.get(&key).unwrap();
            assert_eq!(ans.values, vec![format!("val{i}").into_bytes()]);
        }
        // handoff completeness: everything the retiree holds is present
        // on the key's current homes
        let retiree = c.node(1);
        let keys: Vec<Key> = retiree.store().keys().collect();
        for k in keys {
            for v in retiree.store().values(k) {
                let covered = c.topology().replicas_for(k, c.quorum().n).iter().any(|&h| {
                    c.node(h).store().values(k).iter().any(|s| s.id == v.id)
                });
                assert!(covered, "value {} on key {k} not re-homed", v.id);
            }
        }
        assert_eq!(c.pending_hints(), 0, "clean fabric: no hints parked");
    }

    #[test]
    fn decommission_under_partition_parks_hints_then_drains() {
        let c = LocalCluster::new(4, 3, 2, 2).unwrap();
        for i in 0..30 {
            c.put(&format!("k{i}"), b"v".to_vec(), &[]).unwrap();
        }
        // cut the retiree off from everyone, then decommission it
        let others: Vec<NodeId> = vec![0, 2, 3];
        c.fabric().partition_groups(&[1], &others);
        c.decommission_node(1).unwrap();
        assert!(c.pending_hints() > 0, "unreachable homes got parked hints");
        c.fabric().heal_all();
        c.drain_hints();
        assert_eq!(c.pending_hints(), 0);
        // after the drain, everything the retiree held is covered
        let retiree = c.node(1);
        let keys: Vec<Key> = retiree.store().keys().collect();
        for k in keys {
            for v in retiree.store().values(k) {
                let covered = c.topology().replicas_for(k, c.quorum().n).iter().any(|&h| {
                    c.node(h).store().values(k).iter().any(|s| s.id == v.id)
                });
                assert!(covered, "value {} on key {k} stranded", v.id);
            }
        }
    }

    #[test]
    fn decommission_guards_the_quorum_floor() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        c.decommission_node(0).unwrap();
        // 2 members left; R = W = 2 — another decommission must refuse
        assert!(c.decommission_node(1).is_err());
        assert!(c.decommission_node(0).is_err(), "already retired");
        assert!(c.decommission_node(9).is_err(), "unknown id");
        // ops still work with the floor intact
        c.put("k", b"x".to_vec(), &[]).unwrap();
        assert_eq!(c.get("k").unwrap().values, vec![b"x".to_vec()]);
    }

    #[test]
    fn churn_plan_drives_membership_through_advance_plan() {
        let c = LocalCluster::new(4, 3, 2, 2).unwrap();
        let plan = crate::sim::failure::FaultPlan::new()
            .join_at(100)
            .decommission_at(200, 2)
            .crash_window(0, 300, 400);
        c.advance_plan(&plan, 150);
        assert_eq!(c.node_count(), 5);
        assert_eq!(c.epoch(), crate::cluster::topology::INITIAL_EPOCH + 1);
        c.advance_plan(&plan, 350);
        assert_eq!(c.members(), vec![0, 1, 3, 4]);
        assert!(!c.fabric().is_up(0), "non-membership faults still hit the fabric");
        c.advance_plan(&plan, 500);
        assert!(c.fabric().is_up(0));
    }

    #[test]
    fn writes_racing_a_decommission_are_never_stranded() {
        // hammer writes from worker threads while the main thread
        // decommissions a node; the epoch guard + handoff must leave
        // every write readable afterwards
        let c = Arc::new(LocalCluster::new(4, 3, 2, 2).unwrap());
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut workers = Vec::new();
        for t in 0..3u32 {
            let c = Arc::clone(&c);
            let stop = Arc::clone(&stop);
            workers.push(std::thread::spawn(move || {
                let mut written = Vec::new();
                let mut i = 0u32;
                while !stop.load(Ordering::Relaxed) {
                    let key = format!("t{t}-k{i}");
                    c.put(&key, key.clone().into_bytes(), &[]).unwrap();
                    written.push(key);
                    i += 1;
                }
                written
            }));
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
        c.decommission_node(1).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(10));
        stop.store(true, Ordering::Relaxed);
        c.drain_hints();
        for worker in workers {
            for key in worker.join().unwrap() {
                let ans = c.get(&key).unwrap();
                assert_eq!(ans.values, vec![key.into_bytes()], "write lost across churn");
            }
        }
    }

    #[test]
    fn durable_cluster_survives_a_full_reopen() {
        let dir = crate::testkit::temp_dir("cluster-reopen");
        let opts = WalOptions::default();
        {
            let c = LocalCluster::with_data_dir(3, 3, 2, 2, 4, &dir, opts).unwrap();
            for i in 0..30 {
                c.put(&format!("key{i}"), format!("val{i}").into_bytes(), &[]).unwrap();
            }
            assert!(c.wal_bytes() > 0);
        }
        // a brand-new cluster over the same directory recovers the
        // versioned states (values live in the blob table, which is
        // process-local — so assert on ids/siblings, not bytes)
        let c = LocalCluster::with_data_dir(3, 3, 2, 2, 4, &dir, opts).unwrap();
        for i in 0..30 {
            let k = hash_str(&format!("key{i}"));
            let survivors: usize = c
                .replicas_of(&format!("key{i}"))
                .iter()
                .filter(|&&n| c.node(n).store().sibling_count(k) == 1)
                .count();
            assert!(survivors >= 2, "key{i} recovered on a write quorum");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn restarted_node_recovers_and_peers_close_the_gap() {
        let dir = crate::testkit::temp_dir("cluster-restart");
        // fsync never: a crash-restart loses everything since the last
        // segment roll — the worst case the gap-closing must absorb
        let opts = WalOptions {
            fsync: crate::store::FsyncPolicy::Never,
            ..WalOptions::default()
        };
        let c = LocalCluster::with_data_dir(4, 3, 2, 2, 4, &dir, opts).unwrap();
        for i in 0..40 {
            c.put(&format!("key{i}"), format!("val{i}").into_bytes(), &[]).unwrap();
        }
        let report = c.restart_node(1);
        assert!(!report.truncated, "power loss is clean truncation, not corruption");
        // anti-entropy refills whatever node 1 lost (bounded: a
        // convergence bug must fail, not hang)
        let mut rounds = 0;
        while c.anti_entropy_round() > 0 {
            rounds += 1;
            assert!(rounds < 32, "anti-entropy failed to quiesce");
        }
        for i in 0..40 {
            let ans = c.get(&format!("key{i}")).unwrap();
            assert_eq!(ans.values, vec![format!("val{i}").into_bytes()]);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unusable_data_dir_is_a_clean_error_not_a_panic() {
        let dir = crate::testkit::temp_dir("cluster-baddir");
        // block node-0's directory with a plain file: the eager open in
        // with_data_dir must surface this as Err
        std::fs::write(dir.join("node-0"), b"not a directory").unwrap();
        assert!(LocalCluster::with_data_dir(3, 3, 2, 2, 4, &dir, WalOptions::default()).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn wiped_volatile_node_is_refilled_by_anti_entropy() {
        // wipe works on every backend, not just the durable one
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        for i in 0..20 {
            c.put(&format!("key{i}"), b"v".to_vec(), &[]).unwrap();
        }
        c.wipe_node(0);
        assert_eq!(c.node(0).store().key_count(), 0);
        let mut rounds = 0;
        while c.anti_entropy_round() > 0 {
            rounds += 1;
            assert!(rounds < 32, "anti-entropy failed to quiesce");
        }
        assert!(c.node(0).store().key_count() > 0, "peers refilled the wiped node");
        for i in 0..20 {
            let ans = c.get(&format!("key{i}")).unwrap();
            assert_eq!(ans.values, vec![b"v".to_vec()]);
        }
        assert_eq!(c.wal_bytes(), 0, "volatile backends report no wal bytes");
    }

    #[test]
    fn geo_put_parks_remote_homes_then_ship_round_delivers() {
        // N = 4 over [0,0,1,1]: every node is a home, two per zone
        let c = LocalCluster::with_zones(&[0, 0, 1, 1], 4, 1, 1).unwrap();
        assert!(c.geo());
        assert_eq!(c.zone_count(), 2);
        let id = c
            .put_traced_in_zone("k", b"v".to_vec(), &[], Actor::client(0), &[], Some(0))
            .unwrap();
        assert!(id > 0);
        // both zone-1 homes were parked, not fanned out synchronously
        assert_eq!(c.ship_lag(), 2);
        let k = hash_str("k");
        assert_eq!(c.node(2).store().sibling_count(k), 0);
        assert_eq!(c.node(3).store().sibling_count(k), 0);
        assert_eq!(c.ship_round(), 2);
        assert_eq!(c.ship_lag(), 0);
        for n in 0..4 {
            assert_eq!(c.node(n).store().sibling_count(k), 1, "node {n} has the write");
        }
        // the receiving DC's clocks saw the shipped timestamp
        assert!(c.node(2).hlc_last() > HlcTimestamp::default());
        assert_eq!(c.get_in_zone("k", Some(1)).unwrap().values, vec![b"v".to_vec()]);
    }

    #[test]
    fn dc_partition_serves_both_halves_then_heals_and_converges() {
        let c = LocalCluster::with_zones(&[0, 0, 0, 1, 1, 1], 3, 2, 2).unwrap();
        let oracle = Arc::new(SharedOracle::new());
        c.attach_oracle(Arc::clone(&oracle));
        c.fabric().partition_groups(&[0, 1, 2], &[3, 4, 5]);
        // each DC keeps serving its local clients through its own
        // zone-scoped quorum while fully cut off from the other
        let (a, b) = (Actor::client(0), Actor::client(1));
        for i in 0..20 {
            c.put_traced_in_zone(&format!("a{i}"), b"a".to_vec(), &[], a, &[], Some(0)).unwrap();
            c.put_traced_in_zone(&format!("b{i}"), b"b".to_vec(), &[], b, &[], Some(1)).unwrap();
            assert_eq!(c.get_in_zone(&format!("a{i}"), Some(0)).unwrap().values.len(), 1);
            assert_eq!(c.get_in_zone(&format!("b{i}"), Some(1)).unwrap().values.len(), 1);
        }
        c.fabric().heal_all();
        let mut rounds = 0;
        while c.anti_entropy_round() > 0 {
            rounds += 1;
            assert!(rounds < 32, "anti-entropy failed to quiesce after heal");
        }
        assert_eq!(c.ship_lag(), 0, "heal drained the cross-DC backlog");
        let roots = c.merkle_roots();
        assert!(roots.iter().all(|&(_, r)| r == roots[0].1), "members converged");
        assert_eq!(oracle.lost_updates(), 0, "no acked update was lost");
        for i in 0..20 {
            assert_eq!(c.get(&format!("a{i}")).unwrap().values, vec![b"a".to_vec()]);
            assert_eq!(c.get(&format!("b{i}")).unwrap().values, vec![b"b".to_vec()]);
        }
    }

    #[test]
    fn geo_hlc_stays_monotone_under_backward_fabric_skew() {
        let c = LocalCluster::with_zones(&[0, 1], 2, 1, 1).unwrap();
        let plan = FaultPlan::new().clock_skew_at(50, 0, -5_000_000);
        c.advance_plan(&plan, 100);
        assert!(c.fabric().clock_skew_us(0) < 0, "skew fault reached the fabric");
        // node 0 (zone 0) coordinates every put; its physical reading is
        // pinned at 0 by the huge backward jump, so only the HLC counter
        // can carry order — and it must
        let mut prev = c.node(0).hlc_last();
        for i in 0..10 {
            c.put_traced_in_zone(&format!("k{i}"), b"v".to_vec(), &[], Actor::client(0), &[], Some(0))
                .unwrap();
            let now = c.node(0).hlc_last();
            assert!(now > prev, "HLC went backwards: {now} <= {prev}");
            prev = now;
        }
    }

    #[test]
    fn flat_cluster_never_touches_the_ship_queue() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        assert!(!c.geo());
        assert_eq!(c.zone_count(), 1);
        for i in 0..10 {
            c.put(&format!("k{i}"), b"v".to_vec(), &[]).unwrap();
        }
        assert_eq!(c.ship_lag(), 0);
        assert_eq!(c.ship_round(), 0);
    }

    #[test]
    fn oracle_audits_quorum_traffic() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        let oracle = Arc::new(SharedOracle::new());
        c.attach_oracle(Arc::clone(&oracle));
        let a1 = Actor::client(1);
        let a2 = Actor::client(2);
        let id1 = c.put_traced("k", b"v1".to_vec(), &[], a1, &[]).unwrap();
        let id2 = c.put_traced("k", b"v2".to_vec(), &[], a2, &[]).unwrap();
        let ans = c.get("k").unwrap();
        assert_eq!(ans.ids.len(), 2);
        // an informed merge write supersedes both siblings; every drop it
        // causes across the replicas is a correct supersession
        c.put_traced("k", b"m".to_vec(), &ans.context, a1, &ans.ids).unwrap();
        assert_eq!(c.get("k").unwrap().values, vec![b"m".to_vec()]);
        assert_eq!(oracle.lost_updates(), 0);
        assert!(oracle.correct_supersessions() > 0);
        assert_eq!(oracle.tracked(), 3);
        assert!(oracle.with_inner(|o| o.concurrent(id1, id2)));
    }
}
