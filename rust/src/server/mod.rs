//! Deployable store: an in-process replicated cluster behind a TCP text
//! protocol (`dvv-store serve`).
//!
//! Unlike the discrete-event simulator (which models latency and failure
//! for experiments), this is a real store: N replica [`Node`]s in one
//! process, quorum get/put through the same [`crate::coordinator`] state
//! machines, dotted version vectors as the causality mechanism, and real
//! bytes for values. String keys hash onto the same consistent ring used
//! everywhere else.
//!
//! Concurrency layout: there is **no store-wide lock**. Each replica
//! [`Node`] keeps its versioned states in a
//! [`ShardedBackend`](crate::store::ShardedBackend) — power-of-two
//! lock-striped shards — so concurrent GET/PUT on different keys proceed
//! in parallel, and GETs on the same shard share its reader lock. Value
//! payloads live in a similarly striped blob table keyed by write id.
//! PUT replicates its synced state with one stripe-lock acquisition per
//! peer; multi-key fan-out — [`LocalCluster::anti_entropy_round`], which
//! reconciles replica pairs shard by shard through the bulk
//! [`crate::antientropy`] path — accumulates per-peer merges in a
//! [`MergeBatch`](crate::coordinator::MergeBatch) and applies each peer's
//! batch with one stripe-lock round per shard ([`KeyStore::merge_batch`]).

pub mod protocol;
pub mod tcp;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::antientropy;
use crate::clocks::vv::VersionVector;
use crate::clocks::Actor;
use crate::cluster::ring::{hash_str, Ring};
use crate::coordinator::{GetOp, MergeBatch, PutOp, QuorumSpec};
use crate::error::Result;
use crate::kernel::mechs::DvvMech;
use crate::kernel::{Val, WriteMeta};
use crate::store::{KeyStore, ShardedBackend};

/// A GET's answer: sibling payloads plus the encoded causal context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GetAnswer {
    /// Sibling values (raw bytes), one per concurrent version.
    pub values: Vec<Vec<u8>>,
    /// Opaque context to pass back on PUT (encoded version vector).
    pub context: Vec<u8>,
}

/// One replica: a lock-striped DVV key store. Connection threads operate
/// on a `Node` through `&self`; the per-shard locks inside the backend
/// are the only synchronization.
#[derive(Debug)]
pub struct Node {
    id: usize,
    store: KeyStore<DvvMech, ShardedBackend<DvvMech>>,
}

impl Node {
    fn new(id: usize, shards: usize) -> Node {
        Node {
            id,
            store: KeyStore::with_backend(DvvMech, ShardedBackend::with_shards(shards)),
        }
    }

    /// Replica id (dense, matches ring node ids).
    pub fn id(&self) -> usize {
        self.id
    }

    /// The replica's versioned store.
    pub fn store(&self) -> &KeyStore<DvvMech, ShardedBackend<DvvMech>> {
        &self.store
    }
}

/// Striped blob table: write-id → payload bytes. Ids are sequential, so
/// a power-of-two mask spreads them evenly across stripes.
#[derive(Debug)]
struct BlobStore {
    stripes: Box<[Mutex<HashMap<u64, Vec<u8>>>]>,
    mask: u64,
}

impl BlobStore {
    fn new(stripes: usize) -> BlobStore {
        let n = stripes.max(1).next_power_of_two();
        BlobStore {
            stripes: (0..n).map(|_| Mutex::new(HashMap::new())).collect(),
            mask: (n - 1) as u64,
        }
    }

    fn insert(&self, id: u64, bytes: Vec<u8>) {
        self.stripes[(id & self.mask) as usize]
            .lock()
            .unwrap()
            .insert(id, bytes);
    }

    fn get(&self, id: u64) -> Vec<u8> {
        self.stripes[(id & self.mask) as usize]
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .unwrap_or_default()
    }
}

/// An in-process replicated DVV store.
pub struct LocalCluster {
    nodes: Vec<Node>,
    blobs: BlobStore,
    ring: Ring,
    quorum: QuorumSpec,
    next_id: AtomicU64,
    mech: DvvMech,
}

impl LocalCluster {
    /// Build with `nodes` replicas and quorum `(n, r, w)`, using the
    /// default per-replica shard count.
    pub fn new(nodes: usize, n: usize, r: usize, w: usize) -> Result<LocalCluster> {
        LocalCluster::with_shards(nodes, n, r, w, crate::store::DEFAULT_SHARDS)
    }

    /// Build with an explicit per-replica shard (stripe) count.
    pub fn with_shards(
        nodes: usize,
        n: usize,
        r: usize,
        w: usize,
        shards: usize,
    ) -> Result<LocalCluster> {
        let quorum = QuorumSpec::new(n.min(nodes), r.min(n), w.min(n))?;
        Ok(LocalCluster {
            nodes: (0..nodes).map(|id| Node::new(id, shards)).collect(),
            blobs: BlobStore::new(16),
            ring: Ring::new(nodes, 64)?,
            quorum,
            next_id: AtomicU64::new(1),
            mech: DvvMech,
        })
    }

    /// Number of replica nodes.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Per-replica shard (stripe) count.
    pub fn shard_count(&self) -> usize {
        self.nodes.first().map(|n| n.store.shard_count()).unwrap_or(0)
    }

    /// One replica (tests, diagnostics, anti-entropy drivers).
    pub fn node(&self, id: usize) -> &Node {
        &self.nodes[id]
    }

    /// GET through a read quorum with read repair.
    pub fn get(&self, key: &str) -> Result<GetAnswer> {
        let k = hash_str(key);
        let replicas = self.ring.replicas_for(k, self.quorum.n);
        let mut op: GetOp<DvvMech> = GetOp::new(self.quorum);
        let mut answer = None;
        for &node in &replicas {
            let state = self.nodes[node].store.state(k);
            if let Some(res) = op.on_reply(&self.mech, &state) {
                answer = Some(res);
            }
        }
        // read repair with the fully merged state
        let merged = op.merged().clone();
        for &node in &replicas {
            self.nodes[node].store.merge_key(k, &merged);
        }
        let res = answer.ok_or(crate::Error::QuorumNotMet {
            got: op.replies(),
            needed: self.quorum.r,
        })?;
        let values = res.values.iter().map(|v| self.blobs.get(v.id)).collect();
        let mut context = Vec::new();
        crate::clocks::encoding::encode_vv(&res.context, &mut context);
        Ok(GetAnswer { values, context })
    }

    /// PUT through a write quorum. `context` is the bytes from a prior
    /// GET (empty slice = blind write).
    pub fn put(&self, key: &str, value: Vec<u8>, context: &[u8]) -> Result<()> {
        let k = hash_str(key);
        let ctx: VersionVector = if context.is_empty() {
            VersionVector::new()
        } else {
            let mut pos = 0;
            crate::clocks::encoding::decode_vv(context, &mut pos)?
        };
        let replicas = self.ring.replicas_for(k, self.quorum.n);
        let coordinator = replicas[0];
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let val = Val::new(id, value.len() as u32);
        self.blobs.insert(id, value);

        let meta = WriteMeta {
            client: Actor::client(0),
            physical_us: 0,
            client_seq: None,
        };
        // §4.1: update + sync at the coordinator, under one shard lock...
        let state = self.nodes[coordinator].store.write_returning(
            k,
            &ctx,
            val,
            Actor::server(coordinator as u32),
            &meta,
        );
        // ...then replicate the synced state to each peer. A PUT carries
        // exactly one key, so this is a direct per-peer merge; multi-key
        // fan-out (anti-entropy) goes through `MergeBatch` instead.
        let mut op = PutOp::new(self.quorum);
        let mut done = op.satisfied_immediately();
        for &node in replicas.iter().skip(1) {
            self.nodes[node].store.merge_key(k, &state);
            if op.on_ack() {
                done = true;
            }
        }
        debug_assert!(done || self.quorum.w > replicas.len());
        Ok(())
    }

    /// One push–pull anti-entropy round: reconcile every replica pair,
    /// diffing shard by shard through the bulk sync path and accumulating
    /// the merged states in a per-peer [`MergeBatch`]. Each side then
    /// applies its whole batch with [`KeyStore::merge_batch`] — one
    /// stripe-lock round per shard instead of one lock per key. Returns
    /// the number of key reconciliations applied (per pair).
    pub fn anti_entropy_round(&self) -> usize {
        let mut reconciled = 0;
        for (a, node_a) in self.nodes.iter().enumerate() {
            for (b, node_b) in self.nodes.iter().enumerate().skip(a + 1) {
                let (sa, sb) = (&node_a.store, &node_b.store);
                let mut batch: MergeBatch<DvvMech> = MergeBatch::new(self.nodes.len());
                for shard in 0..sa.shard_count() {
                    let pairs = antientropy::diff_pairs_in_shard(sa, sb, shard);
                    if pairs.is_empty() {
                        continue;
                    }
                    for (key, merged) in antientropy::sync_scalar(&pairs) {
                        batch.push(a, key, merged.clone());
                        batch.push(b, key, merged);
                    }
                }
                reconciled += batch.len() / 2;
                for (node, items) in batch.drain() {
                    self.nodes[node].store.merge_batch(&items);
                }
            }
        }
        reconciled
    }

    /// Current sibling count for a key (diagnostics).
    pub fn siblings(&self, key: &str) -> usize {
        let k = hash_str(key);
        let replicas = self.ring.replicas_for(k, self.quorum.n);
        replicas
            .iter()
            .map(|&n| self.nodes[n].store.sibling_count(k))
            .max()
            .unwrap_or(0)
    }

    /// Total causality metadata bytes across replicas (diagnostics).
    pub fn metadata_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.store.metadata_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        c.put("user:1", b"alice".to_vec(), &[]).unwrap();
        let ans = c.get("user:1").unwrap();
        assert_eq!(ans.values, vec![b"alice".to_vec()]);
        assert!(!ans.context.is_empty());
    }

    #[test]
    fn blind_concurrent_puts_make_siblings() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        c.put("k", b"v1".to_vec(), &[]).unwrap();
        c.put("k", b"v2".to_vec(), &[]).unwrap();
        let ans = c.get("k").unwrap();
        assert_eq!(ans.values.len(), 2, "blind writes are concurrent");
    }

    #[test]
    fn contextful_put_supersedes_siblings() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        c.put("k", b"v1".to_vec(), &[]).unwrap();
        c.put("k", b"v2".to_vec(), &[]).unwrap();
        let ans = c.get("k").unwrap();
        c.put("k", b"merged".to_vec(), &ans.context).unwrap();
        let after = c.get("k").unwrap();
        assert_eq!(after.values, vec![b"merged".to_vec()]);
    }

    #[test]
    fn missing_key_is_empty_not_error() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        let ans = c.get("nope").unwrap();
        assert!(ans.values.is_empty());
    }

    #[test]
    fn many_keys_route_across_nodes() {
        let c = LocalCluster::new(5, 3, 2, 2).unwrap();
        for i in 0..50 {
            c.put(&format!("key{i}"), format!("val{i}").into_bytes(), &[]).unwrap();
        }
        for i in 0..50 {
            let ans = c.get(&format!("key{i}")).unwrap();
            assert_eq!(ans.values, vec![format!("val{i}").into_bytes()]);
        }
        assert!(c.metadata_bytes() > 0);
    }

    #[test]
    fn single_node_cluster_works() {
        let c = LocalCluster::new(1, 1, 1, 1).unwrap();
        c.put("k", b"x".to_vec(), &[]).unwrap();
        assert_eq!(c.get("k").unwrap().values, vec![b"x".to_vec()]);
    }

    #[test]
    fn explicit_shard_count_is_honored() {
        let c = LocalCluster::with_shards(3, 3, 2, 2, 8).unwrap();
        assert_eq!(c.shard_count(), 8);
        c.put("k", b"x".to_vec(), &[]).unwrap();
        assert_eq!(c.get("k").unwrap().values, vec![b"x".to_vec()]);
    }

    #[test]
    fn anti_entropy_reconciles_a_diverged_replica() {
        let c = LocalCluster::new(3, 3, 2, 2).unwrap();
        // diverge node 0 directly, bypassing the quorum path
        let k = hash_str("lost-update");
        let id = c.next_id.fetch_add(1, Ordering::Relaxed);
        let (_, ctx) = c.node(0).store().read(k);
        c.node(0).store().write(
            k,
            &ctx,
            Val::new(id, 1),
            Actor::server(0),
            &WriteMeta::basic(Actor::client(9)),
        );
        assert_eq!(c.node(1).store().sibling_count(k), 0, "diverged");

        let reconciled = c.anti_entropy_round();
        assert!(reconciled > 0);
        for n in 0..3 {
            assert_eq!(
                c.node(n).store().state(k),
                c.node(0).store().state(k),
                "node {n} converged"
            );
        }
        // a second round finds nothing left to do
        assert_eq!(c.anti_entropy_round(), 0);
    }

    #[test]
    fn concurrent_puts_distinct_keys_do_not_interfere() {
        use std::sync::Arc;
        let c = Arc::new(LocalCluster::new(3, 3, 2, 2).unwrap());
        let mut handles = Vec::new();
        for t in 0..4 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for i in 0..25 {
                    let key = format!("t{t}-k{i}");
                    c.put(&key, key.clone().into_bytes(), &[]).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        for t in 0..4 {
            for i in 0..25 {
                let key = format!("t{t}-k{i}");
                assert_eq!(c.get(&key).unwrap().values, vec![key.into_bytes()]);
            }
        }
    }
}
