//! Request execution shared by every serve loop.
//!
//! The thread-per-connection loop ([`super::tcp`]) and the poll reactor
//! ([`super::reactor`]) differ only in *how bytes arrive and leave*; what
//! a decoded request **does** to the cluster is defined exactly once,
//! here. [`exec_text_line`] and [`exec_bin_request`] are pure
//! request→reply functions over a [`LocalCluster`]: no I/O, no
//! connection state, safe to call from any worker thread. That is what
//! lets the reactor run many requests from one connection concurrently
//! while both serve loops stay wire-identical (the transport-equivalence
//! and protocol-fuzz suites pass unchanged against either).

use super::protocol::{self, format_values, parse_request, BinRequest, FaultCmd, Request};
use super::LocalCluster;
use crate::api::CausalCtx;
use crate::clocks::Actor;
use crate::error::Result;
use crate::kernel::mechs::DvvMech;
use crate::store::StorageBackend;

/// Reply to one text-protocol line.
#[derive(Debug)]
pub(crate) enum TextReply {
    /// Write this (newline-terminated) reply and keep serving.
    Line(String),
    /// Write `BYE\n` and close the connection.
    Bye,
}

/// Reply to one binary-v2 frame.
#[derive(Debug)]
pub(crate) struct BinReply {
    /// Reply opcode.
    pub opcode: u8,
    /// Reply payload (always frame-sized: oversized results degrade to
    /// an `OP_ERR` here, so writing the frame cannot fail).
    pub payload: Vec<u8>,
    /// Close the connection after flushing this reply (`QUIT`).
    pub close: bool,
}

/// Apply a `FAULT` admin command to the cluster's chaos fabric.
fn apply_fault<B: StorageBackend<DvvMech>>(cluster: &LocalCluster<B>, cmd: FaultCmd) -> String {
    let fabric = cluster.fabric();
    let nodes = cluster.node_count();
    match cmd {
        FaultCmd::Crash { node } if node < nodes => {
            fabric.crash(node);
            "OK\n".to_string()
        }
        FaultCmd::Crash { node } => format!("ERR node {node} out of range\n"),
        FaultCmd::Partition { left, right } => {
            if let Some(bad) = left.iter().chain(&right).find(|&&n| n >= nodes) {
                format!("ERR node {bad} out of range\n")
            } else {
                fabric.partition_groups(&left, &right);
                "OK\n".to_string()
            }
        }
        FaultCmd::Drop { ppm } => {
            fabric.set_drop_prob(f64::from(ppm) / 1_000_000.0);
            "OK\n".to_string()
        }
        FaultCmd::Delay { us } => {
            fabric.set_extra_delay_us(us);
            "OK\n".to_string()
        }
    }
}

/// Apply a `RESTART` admin command: crash-restart one replica's storage
/// (unpersisted state lost, WAL replayed).
fn apply_restart<B: StorageBackend<DvvMech>>(cluster: &LocalCluster<B>, node: usize) -> String {
    if node >= cluster.node_count() {
        return format!("ERR node {node} out of range\n");
    }
    let report = cluster.restart_node(node);
    format!(
        "OK replayed={} discarded={}\n",
        report.records, report.discarded_bytes
    )
}

/// Apply a `WIPE` admin command: destroy one replica's state entirely.
fn apply_wipe<B: StorageBackend<DvvMech>>(cluster: &LocalCluster<B>, node: usize) -> String {
    if node >= cluster.node_count() {
        return format!("ERR node {node} out of range\n");
    }
    cluster.wipe_node(node);
    "OK\n".to_string()
}

/// Render the membership view as a text-protocol line (one consistent
/// snapshot — epoch and members cannot straddle a concurrent bump).
fn topology_line<B: StorageBackend<DvvMech>>(cluster: &LocalCluster<B>) -> String {
    let (epoch, slots, members) = cluster.topology().snapshot();
    let members: Vec<String> = members.iter().map(|m| m.to_string()).collect();
    format!("TOPOLOGY epoch={epoch} slots={slots} members={}\n", members.join(","))
}

/// Encode the membership view as an [`protocol::OP_TOPOLOGY_REPLY`]
/// payload (one consistent snapshot).
fn topology_frame<B: StorageBackend<DvvMech>>(cluster: &LocalCluster<B>) -> Vec<u8> {
    let (epoch, slots, members) = cluster.topology().snapshot();
    let members: Vec<u64> = members.iter().map(|&m| m as u64).collect();
    protocol::encode_topology_reply(epoch, slots as u64, &members)
}

/// Apply a `HEAL` admin command: recover one node, or reset every fault
/// axis and drain parked hints.
fn apply_heal<B: StorageBackend<DvvMech>>(
    cluster: &LocalCluster<B>,
    node: Option<usize>,
) -> String {
    match node {
        Some(n) if n < cluster.node_count() => {
            cluster.fabric().recover(n);
            cluster.drain_hints();
            "OK\n".to_string()
        }
        Some(n) => format!("ERR node {n} out of range\n"),
        None => {
            cluster.fabric().heal_all();
            cluster.drain_hints();
            "OK\n".to_string()
        }
    }
}

/// Execute one text-protocol request line (without its trailing
/// newline). The caller has already skipped blank lines.
pub(crate) fn exec_text_line<B: StorageBackend<DvvMech>>(
    cluster: &LocalCluster<B>,
    line: &str,
) -> TextReply {
    let reply = match parse_request(line) {
        Ok(Request::Get { key }) => match cluster.get(&key) {
            Ok(ans) => format_values(&ans.values, &ans.context),
            Err(e) => format!("ERR {e}\n"),
        },
        Ok(Request::Put { key, value, context }) => match cluster.put(&key, value, &context) {
            Ok(()) => "OK\n".to_string(),
            Err(e) => format!("ERR {e}\n"),
        },
        Ok(Request::SAdd { key, elem }) => match cluster.set_add(&key, &elem) {
            Ok(dot) => format!("OK dot={dot}\n"),
            Err(e) => format!("ERR {e}\n"),
        },
        Ok(Request::SRem { key, elem }) => match cluster.set_remove(&key, &elem) {
            Ok(dots) if dots.is_empty() => "OK removed=-\n".to_string(),
            Ok(dots) => {
                let dots: Vec<String> = dots.iter().map(|d| d.to_string()).collect();
                format!("OK removed={}\n", dots.join(","))
            }
            Err(e) => format!("ERR {e}\n"),
        },
        Ok(Request::SMembers { key }) => match cluster.set_members(&key) {
            Ok(members) => {
                let mut out = format!("MEMBERS {}\n", members.len());
                for m in &members {
                    out.push_str(&format!("MEMBER {}\n", protocol::hex_encode(m)));
                }
                out
            }
            Err(e) => format!("ERR {e}\n"),
        },
        Ok(Request::Incr { key, by }) => match cluster.counter_incr(&key, by) {
            Ok(value) => format!("OK value={value}\n"),
            Err(e) => format!("ERR {e}\n"),
        },
        Ok(Request::Count { key }) => match cluster.counter_value(&key) {
            Ok(value) => format!("OK value={value}\n"),
            Err(e) => format!("ERR {e}\n"),
        },
        Ok(Request::MPut { key, field, value }) => {
            match cluster.map_put(&key, &field, &value) {
                Ok(dot) => format!("OK dot={dot}\n"),
                Err(e) => format!("ERR {e}\n"),
            }
        }
        Ok(Request::MGet { key, field }) => match cluster.map_get(&key, &field) {
            // an absent field and an empty value both render `-` in
            // text (hex_encode's empty convention); the binary
            // OP_FIELD_REPLY keeps them distinct
            Ok(Some(value)) => format!("FIELD {}\n", protocol::hex_encode(&value)),
            Ok(None) => "FIELD -\n".to_string(),
            Err(e) => format!("ERR {e}\n"),
        },
        Ok(Request::Stats) => {
            let (sets, counters, maps) = cluster.typed_counts();
            format!(
                "STATS nodes={} shards={} metadata_bytes={} hints={} epoch={} wal_bytes={} merkle_root={} zones={} ship_lag={} sets={} counters={} maps={}\n",
                cluster.node_count(),
                cluster.shard_count(),
                cluster.metadata_bytes(),
                cluster.pending_hints(),
                cluster.epoch(),
                cluster.wal_bytes(),
                cluster.merkle_root(),
                cluster.zone_count(),
                cluster.ship_lag(),
                sets,
                counters,
                maps
            )
        }
        Ok(Request::Fault(cmd)) => apply_fault(cluster, cmd),
        Ok(Request::Heal { node }) => apply_heal(cluster, node),
        Ok(Request::Restart { node }) => apply_restart(cluster, node),
        Ok(Request::Wipe { node }) => apply_wipe(cluster, node),
        Ok(Request::Join) => {
            let (id, epoch) = cluster.join_node();
            format!("OK id={id} epoch={epoch}\n")
        }
        Ok(Request::Decommission { node }) => match cluster.decommission_node(node) {
            Ok(epoch) => format!("OK epoch={epoch}\n"),
            Err(e) => format!("ERR {e}\n"),
        },
        Ok(Request::Topology) => topology_line(cluster),
        Ok(Request::Quit) => return TextReply::Bye,
        Err(e) => format!("ERR {e}\n"),
    };
    TextReply::Line(reply)
}

/// Decode a binary PUT and run it through the traced quorum path: the
/// frame's actor + ctx token make the write oracle-auditable end to end.
fn put_binary<B: StorageBackend<DvvMech>>(
    cluster: &LocalCluster<B>,
    key: &str,
    value: Vec<u8>,
    actor: u32,
    ctx_token: &[u8],
) -> Result<(u64, Option<Vec<u8>>)> {
    let (vv, observed) = if ctx_token.is_empty() {
        (Vec::new(), Vec::new())
    } else {
        CausalCtx::decode(ctx_token)?.into_parts()
    };
    cluster.put_api(key, value, &vv, Actor(actor), &observed)
}

/// Map a text-protocol admin status line (`OK\n` / `ERR …\n`) onto a
/// binary reply frame.
fn admin_status(status: String) -> (u8, Vec<u8>) {
    match status.strip_prefix("ERR ") {
        Some(msg) => (protocol::OP_ERR, msg.trim_end().as_bytes().to_vec()),
        None => (protocol::OP_OK, Vec::new()),
    }
}

/// Execute one intact binary-v2 frame (framing already validated by the
/// serve loop; a malformed *payload* is reported as `OP_ERR` and keeps
/// the connection usable).
pub(crate) fn exec_bin_request<B: StorageBackend<DvvMech>>(
    cluster: &LocalCluster<B>,
    opcode: u8,
    payload: &[u8],
) -> BinReply {
    let mut close = false;
    let (op, body): (u8, Vec<u8>) = match protocol::decode_bin_request(opcode, payload) {
        Ok(BinRequest::Get { key }) => match cluster.get(&key) {
            Ok(ans) => {
                let token = CausalCtx::new(ans.context, ans.ids).encode();
                let payload = protocol::encode_values(&ans.values, &token);
                // a sibling set too large for one frame must degrade to
                // an ERR reply, not abort the connection when
                // write_frame refuses it
                if !protocol::fits_frame(payload.len()) {
                    (
                        protocol::OP_ERR,
                        format!(
                            "reply of {} bytes exceeds the {}-byte frame cap",
                            payload.len(),
                            protocol::MAX_FRAME_LEN
                        )
                        .into_bytes(),
                    )
                } else {
                    (protocol::OP_VALUES, payload)
                }
            }
            Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
        },
        Ok(BinRequest::Put { key, value, actor, ctx_token }) => {
            match put_binary(cluster, &key, value, actor, &ctx_token) {
                Ok((id, post)) => {
                    // empty token = no chainable context (a concurrent
                    // sibling survived; GET to merge)
                    let token = post
                        .map(|post| CausalCtx::new(post, vec![id]).encode())
                        .unwrap_or_default();
                    (protocol::OP_PUT_OK, protocol::encode_put_ok(id, &token))
                }
                Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
            }
        }
        Ok(BinRequest::SAdd { key, elem }) => match cluster.set_add(&key, &elem) {
            Ok(dot) => (protocol::OP_DOT_REPLY, protocol::encode_dot_reply(&dot)),
            Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
        },
        Ok(BinRequest::SRem { key, elem }) => match cluster.set_remove(&key, &elem) {
            Ok(dots) => (protocol::OP_DOTS_REPLY, protocol::encode_dots_reply(&dots)),
            Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
        },
        Ok(BinRequest::SMembers { key }) => match cluster.set_members(&key) {
            Ok(members) => {
                let payload = protocol::encode_members_reply(&members);
                // same degradation rule as GET: an oversized member set
                // becomes an ERR reply, not a dead connection
                if !protocol::fits_frame(payload.len()) {
                    (
                        protocol::OP_ERR,
                        format!(
                            "reply of {} bytes exceeds the {}-byte frame cap",
                            payload.len(),
                            protocol::MAX_FRAME_LEN
                        )
                        .into_bytes(),
                    )
                } else {
                    (protocol::OP_MEMBERS_REPLY, payload)
                }
            }
            Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
        },
        Ok(BinRequest::Incr { key, by }) => match cluster.counter_incr(&key, by) {
            Ok(value) => (protocol::OP_COUNT_REPLY, protocol::encode_count_reply(value)),
            Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
        },
        Ok(BinRequest::Count { key }) => match cluster.counter_value(&key) {
            Ok(value) => (protocol::OP_COUNT_REPLY, protocol::encode_count_reply(value)),
            Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
        },
        Ok(BinRequest::MPut { key, field, value }) => {
            match cluster.map_put(&key, &field, &value) {
                Ok(dot) => (protocol::OP_DOT_REPLY, protocol::encode_dot_reply(&dot)),
                Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
            }
        }
        Ok(BinRequest::MGet { key, field }) => match cluster.map_get(&key, &field) {
            Ok(value) => {
                (protocol::OP_FIELD_REPLY, protocol::encode_field_reply(value.as_deref()))
            }
            Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
        },
        Ok(BinRequest::Stats) => {
            let (sets, counters, maps) = cluster.typed_counts();
            let stats = protocol::StatsReply {
                nodes: cluster.node_count() as u64,
                shards: cluster.shard_count() as u64,
                metadata_bytes: cluster.metadata_bytes(),
                hints: cluster.pending_hints() as u64,
                epoch: cluster.epoch(),
                wal_bytes: cluster.wal_bytes(),
                merkle_root: cluster.merkle_root(),
                zones: cluster.zone_count() as u64,
                ship_lag: cluster.ship_lag() as u64,
                sets,
                counters,
                maps,
            };
            (protocol::OP_STATS_REPLY, protocol::encode_stats_reply(&stats))
        }
        Ok(BinRequest::Join) => {
            // the reply's epoch and slots come from *this* join's return
            // value, so `slots - 1` is the id assigned to this request
            // even when joins race (a fresh snapshot could report
            // another join's slots); the member list is an advisory
            // snapshot
            let (id, epoch) = cluster.join_node();
            let members: Vec<u64> = cluster.members().iter().map(|&m| m as u64).collect();
            (
                protocol::OP_TOPOLOGY_REPLY,
                protocol::encode_topology_reply(epoch, id as u64 + 1, &members),
            )
        }
        Ok(BinRequest::Decommission { node }) => match cluster.decommission_node(node) {
            Ok(_) => (protocol::OP_TOPOLOGY_REPLY, topology_frame(cluster)),
            Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
        },
        Ok(BinRequest::Topology) => (protocol::OP_TOPOLOGY_REPLY, topology_frame(cluster)),
        Ok(BinRequest::Ship { zone: _, ts, entries }) => match cluster.apply_ship(ts, &entries) {
            Ok((applied, hlc)) => {
                (protocol::OP_SHIP_ACK, protocol::encode_ship_ack(applied, &hlc))
            }
            Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
        },
        Ok(BinRequest::Admin { line }) => match parse_request(&line) {
            Ok(Request::Fault(cmd)) => admin_status(apply_fault(cluster, cmd)),
            Ok(Request::Heal { node }) => admin_status(apply_heal(cluster, node)),
            // durability faults ride the ADMIN frame in text form —
            // real storage loss at a live replica, over the wire
            Ok(Request::Restart { node }) => admin_status(apply_restart(cluster, node)),
            Ok(Request::Wipe { node }) => admin_status(apply_wipe(cluster, node)),
            // text-form elastic ops work over ADMIN too; the dedicated
            // opcodes return the richer topology frame
            Ok(Request::Join) => {
                let _ = cluster.join_node();
                (protocol::OP_OK, Vec::new())
            }
            Ok(Request::Decommission { node }) => match cluster.decommission_node(node) {
                Ok(_) => (protocol::OP_OK, Vec::new()),
                Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
            },
            Ok(Request::Topology) => (protocol::OP_TOPOLOGY_REPLY, topology_frame(cluster)),
            Ok(_) => (
                protocol::OP_ERR,
                b"ADMIN accepts FAULT/HEAL/JOIN/DECOMMISSION/TOPOLOGY/RESTART/WIPE \
                  commands only"
                    .to_vec(),
            ),
            Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
        },
        Ok(BinRequest::Quit) => {
            close = true;
            (protocol::OP_BYE, Vec::new())
        }
        // malformed payload inside an intact frame: report and keep the
        // connection (framing is still trustworthy)
        Err(e) => (protocol::OP_ERR, e.to_string().into_bytes()),
    };
    BinReply { opcode: op, payload: body, close }
}
